#!/usr/bin/env python3
"""Validate BENCH_*.json bench-trajectory files against their schema line.

Each trajectory file is append-only JSON-lines (see scripts/capture_bench.sh):

  line 1   {"meta":"schema","bench":NAME,"fields":[...],"companions":[...]}
  then     {"meta":"run","bench":NAME,"date":...}   one per capture
  and      {"bench":NAME,field:value,...}           raw BENCH_JSON records

Rules enforced:
  * the first line must be the schema line (meta == "schema", a bench name,
    and a non-empty field list);
  * a data line for the primary bench must carry exactly {"bench"} plus the
    schema fields, every value a number or null;
  * a data line for a companion bench (listed in "companions") may carry any
    fields, but values must still be numbers or null;
  * any other bench name is an error — extend "companions" deliberately.

Usage:
  scripts/check_bench_schema.py [FILE...]     # default: all BENCH_*.json
  ... | scripts/check_bench_schema.py --against FILE
                                              # validate stdin lines (with or
                                              # without the BENCH_JSON prefix)
                                              # against FILE's schema line

Exit status is non-zero if any line fails; failures name the file and line.
"""

import glob
import json
import os
import sys

PREFIX = "BENCH_JSON "


def fail(where, lineno, msg):
    print(f"{where}:{lineno}: {msg}", file=sys.stderr)
    return 1


def load_schema(path):
    """Parse and sanity-check FILE's first line; returns the schema dict."""
    with open(path, encoding="utf-8") as f:
        first = f.readline().strip()
    try:
        schema = json.loads(first)
    except json.JSONDecodeError as e:
        raise ValueError(f"{path}:1: schema line is not JSON: {e}")
    if not isinstance(schema, dict) or schema.get("meta") != "schema":
        raise ValueError(f'{path}:1: first line must be a {{"meta":"schema"}} line')
    if not isinstance(schema.get("bench"), str) or not schema["bench"]:
        raise ValueError(f"{path}:1: schema needs a non-empty bench name")
    fields = schema.get("fields")
    if not isinstance(fields, list) or not fields or not all(
        isinstance(x, str) for x in fields
    ):
        raise ValueError(f"{path}:1: schema needs a non-empty string field list")
    companions = schema.get("companions", [])
    if not isinstance(companions, list) or not all(
        isinstance(x, str) for x in companions
    ):
        raise ValueError(f"{path}:1: companions must be a string list")
    return schema


def check_data_line(schema, obj, where, lineno):
    """Validate one parsed record against the schema; returns error count."""
    if obj.get("meta") == "run":
        if not isinstance(obj.get("bench"), str) or "date" not in obj:
            return fail(where, lineno, "run line needs bench and date")
        return 0
    bench = obj.get("bench")
    if not isinstance(bench, str):
        return fail(where, lineno, "data line needs a string bench name")
    values = {k: v for k, v in obj.items() if k != "bench"}
    bad = [k for k, v in values.items() if not isinstance(v, (int, float)) or isinstance(v, bool)]
    bad = [k for k in bad if values[k] is not None]
    if bad:
        return fail(where, lineno, f"non-numeric values for {sorted(bad)}")
    if bench == schema["bench"]:
        want = set(schema["fields"])
        got = set(values)
        if got != want:
            missing = sorted(want - got)
            extra = sorted(got - want)
            return fail(
                where, lineno, f"field mismatch: missing {missing}, extra {extra}"
            )
        return 0
    if bench in schema.get("companions", []):
        return 0
    return fail(
        where,
        lineno,
        f'unknown bench "{bench}" (primary is "{schema["bench"]}", '
        f"companions {schema.get('companions', [])})",
    )


def check_lines(schema, lines, where, start_lineno):
    errors = 0
    for lineno, raw in enumerate(lines, start=start_lineno):
        line = raw.strip()
        if not line:
            continue
        if line.startswith(PREFIX):
            line = line[len(PREFIX):]
        try:
            obj = json.loads(line)
        except json.JSONDecodeError as e:
            errors += fail(where, lineno, f"not JSON: {e}")
            continue
        if not isinstance(obj, dict):
            errors += fail(where, lineno, "line is not a JSON object")
            continue
        errors += check_data_line(schema, obj, where, lineno)
    return errors


def check_file(path):
    schema = load_schema(path)
    with open(path, encoding="utf-8") as f:
        lines = f.readlines()
    return check_lines(schema, lines[1:], path, 2)


def main(argv):
    if argv[:1] == ["--against"]:
        if len(argv) != 2:
            print("usage: check_bench_schema.py --against FILE", file=sys.stderr)
            return 2
        schema = load_schema(argv[1])
        errors = check_lines(schema, sys.stdin.readlines(), "<stdin>", 1)
        return 1 if errors else 0

    paths = argv or sorted(glob.glob(os.path.join(os.path.dirname(__file__) or ".", "..", "BENCH_*.json")))
    if not paths:
        print("no BENCH_*.json files found", file=sys.stderr)
        return 2
    errors = 0
    for path in paths:
        try:
            n = check_file(path)
        except (OSError, ValueError) as e:
            print(e, file=sys.stderr)
            errors += 1
            continue
        errors += n
        if n == 0:
            print(f"{path}: OK")
    return 1 if errors else 0


if __name__ == "__main__":
    try:
        sys.exit(main(sys.argv[1:]))
    except BrokenPipeError:
        sys.exit(0)
