#!/usr/bin/env bash
# Capture a Chrome trace from a serve run: enables telemetry + tracing on
# the deployed config and writes a trace_event JSON file loadable in
# https://ui.perfetto.dev or chrome://tracing.
#
#   scripts/capture_trace.sh                                # serve_demo -> trace.json
#   scripts/capture_trace.sh configs/serve_demo.toml t.json
set -euo pipefail
cd "$(dirname "$0")/.."

config="${1:-configs/serve_demo.toml}"
out="${2:-trace.json}"

cargo run --release --quiet -- serve --config "$config" --telemetry --trace "$out"
echo "trace written to $out — open in https://ui.perfetto.dev or chrome://tracing"
