#!/usr/bin/env bash
# Refresh a BENCH_*.json trajectory file from a bench binary's BENCH_JSON
# lines. Each run appends one dated block, so the file accumulates the
# cross-PR trajectory instead of overwriting it.
#
#   scripts/capture_bench.sh                       # serve_saturation (default)
#   scripts/capture_bench.sh engine_throughput     # any other bench
#   BENCH_QUICK=1 scripts/capture_bench.sh         # quick-mode numbers
set -euo pipefail
cd "$(dirname "$0")/.."

bench="${1:-serve_saturation}"
out="BENCH_${bench#serve_}.json"
[ "$bench" = "serve_saturation" ] && out="BENCH_saturation.json"
[ "$bench" = "fleet_scale" ] && out="BENCH_fleet.json"

run_log=$(mktemp)
trap 'rm -f "$run_log"' EXIT
cargo bench --bench "$bench" | tee "$run_log"

# Validate the new lines against the trajectory's schema line before they
# land — a drifted field set fails the capture instead of poisoning the
# append-only history.
if [ -f "$out" ] && command -v python3 >/dev/null 2>&1; then
  grep '^BENCH_JSON ' "$run_log" | python3 scripts/check_bench_schema.py --against "$out"
elif [ -f "$out" ]; then
  echo "warning: python3 not found, skipping schema validation" >&2
else
  echo "note: $out does not exist yet, skipping schema validation" >&2
fi

{
  printf '{"meta":"run","bench":"%s","date":"%s","quick":%s,"host":"%s"}\n' \
    "$bench" "$(date -u +%Y-%m-%dT%H:%M:%SZ)" \
    "$([ -n "${BENCH_QUICK:-}" ] && echo true || echo false)" \
    "$(uname -sm | tr ' ' '-')"
  grep '^BENCH_JSON ' "$run_log" | sed 's/^BENCH_JSON //'
} >> "$out"

echo "appended $(grep -c '^BENCH_JSON ' "$run_log") lines to $out"
