#!/usr/bin/env bash
# Gate the telemetry overhead measured by `cargo bench --bench perf_hotpath`
# (section 6 emits a `BENCH_JSON {"bench":"telemetry_overhead",...}` line):
# fail if overhead_pct exceeds the budget. CI runs this so instrumentation
# at default sampling can never quietly tax the hot path.
#
#   cargo bench --bench perf_hotpath | tee run.log
#   scripts/check_overhead.sh run.log        # default budget: 5 %
#   scripts/check_overhead.sh run.log 7.5    # custom budget
set -euo pipefail

log="${1:?usage: check_overhead.sh RUN_LOG [BUDGET_PCT]}"
budget="${2:-5}"

line=$(grep '^BENCH_JSON {"bench":"telemetry_overhead"' "$log" | tail -n 1 || true)
if [ -z "$line" ]; then
  echo "error: no telemetry_overhead BENCH_JSON line in $log" >&2
  exit 1
fi

pct=$(printf '%s\n' "$line" | sed 's/.*"overhead_pct"://; s/[,}].*//')
if [ "$pct" = "null" ] || [ -z "$pct" ]; then
  echo "error: overhead_pct missing or null in: $line" >&2
  exit 1
fi

awk -v p="$pct" -v b="$budget" 'BEGIN {
  if (p > b) {
    printf "FAIL: telemetry overhead %.2f %% exceeds the %.2f %% budget\n", p, b
    exit 1
  }
  printf "OK: telemetry overhead %.2f %% within the %.2f %% budget\n", p, b
}'
