#!/usr/bin/env bash
# Gate the packed-vs-scalar speedup measured by `cargo bench --bench
# perf_hotpath` (section 7 emits `BENCH_JSON {"bench":"packed_step_conv",...}`
# and `packed_step_fc` lines, one per activity point): fail unless the best
# measured speedup of each kernel clears the bar. CI runs this so the packed
# word-parallel step can never quietly regress below the scalar sparse path.
#
#   cargo bench --bench perf_hotpath | tee run.log
#   scripts/check_speedup.sh run.log        # default bar: 1.5x
#   scripts/check_speedup.sh run.log 1.2    # relaxed bar (noisy runners)
set -euo pipefail

log="${1:?usage: check_speedup.sh RUN_LOG [MIN_SPEEDUP]}"
bar="${2:-1.5}"

fail=0
for bench in packed_step_conv packed_step_fc; do
  lines=$(grep "^BENCH_JSON {\"bench\":\"$bench\"" "$log" || true)
  if [ -z "$lines" ]; then
    echo "error: no $bench BENCH_JSON line in $log" >&2
    exit 1
  fi
  best=$(printf '%s\n' "$lines" | sed 's/.*"speedup"://; s/[,}].*//' | sort -g | tail -n 1)
  if [ "$best" = "null" ] || [ -z "$best" ]; then
    echo "error: speedup missing or null in $bench lines" >&2
    exit 1
  fi
  if awk -v s="$best" -v b="$bar" 'BEGIN { exit !(s >= b) }'; then
    printf 'OK: %s best speedup %.2fx clears the %.2fx bar\n' "$bench" "$best" "$bar"
  else
    printf 'FAIL: %s best speedup %.2fx below the %.2fx bar\n' "$bench" "$best" "$bar"
    fail=1
  fi
done
exit "$fail"
