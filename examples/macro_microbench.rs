//! Macro microbenchmark: sweep resolutions and operand shapes on the
//! bit-accurate simulator, reporting cycles, energy and throughput — the
//! numbers behind Fig. 7(a) and Table I, from the macro's point of view.
//!
//! ```sh
//! cargo run --release --example macro_microbench
//! ```

use flexspim::cim::ops::OperatingPoint;
use flexspim::cim::{CimMacro, MacroConfig};
use flexspim::energy::MacroEnergyModel;
use flexspim::snn::quant::{max_val, min_val};
use flexspim::util::rng::Rng;

fn bench_config(w_bits: u32, p_bits: u32, n_c: u32, neurons: usize) -> Option<(f64, f64, u64)> {
    let cfg = MacroConfig::flexspim(w_bits, p_bits, n_c, 1, neurons);
    cfg.validate().ok()?;
    let mut mac = CimMacro::new(cfg).ok()?;
    let mut rng = Rng::new(99);
    for n in 0..neurons {
        mac.load_weight(n, 0, rng.range_i64(min_val(w_bits), max_val(w_bits)));
        mac.load_vmem(n, rng.range_i64(min_val(p_bits), max_val(p_bits)));
    }
    mac.reset_counters();
    for _ in 0..8 {
        mac.cim_accumulate(0, None);
    }
    let model = MacroEnergyModel::nominal();
    let c = mac.counters();
    let pj_per_sop = model.pj_per_sop(c);
    let op = OperatingPoint::nominal();
    let gsops = cfg.peak_sops(op.system_clock_hz) / 1e9;
    Some((pj_per_sop, gsops, c.cim_cycles))
}

fn main() {
    println!("== resolution sweep (bit-serial N_C = 1, 256 neurons) ==");
    println!("{:>6} {:>6} {:>10} {:>10} {:>8}", "w", "p", "pJ/SOP", "GSOPS", "cycles");
    for (w, p) in [(1u32, 2u32), (2, 4), (4, 8), (6, 11), (8, 16), (12, 24), (16, 32)] {
        if let Some((pj, gsops, cyc)) = bench_config(w, p, 1, 256) {
            println!("{w:>6} {p:>6} {pj:>10.3} {gsops:>10.2} {cyc:>8}");
        }
    }

    println!("\n== shape sweep (8b/16b, 32 output channels) ==");
    println!("{:>8} {:>6} {:>10} {:>10}", "shape", "cols", "pJ/SOP", "GSOPS");
    for n_c in [1u32, 2, 4, 8, 16] {
        let neurons = (256 / n_c as usize).min(32);
        if let Some((pj, gsops, _)) = bench_config(8, 16, n_c, neurons) {
            println!(
                "{:>5}x{:<2} {:>6} {:>10.3} {:>10.2}",
                16u32.div_ceil(n_c),
                n_c,
                neurons * n_c as usize,
                pj,
                gsops
            );
        }
    }

    println!("\n== voltage scaling (8b/16b bit-serial) ==");
    println!("{:>6} {:>10} {:>10} {:>10}", "vdd", "MHz", "pJ/SOP", "mW");
    for vdd in [0.9, 1.0, 1.1] {
        let op = OperatingPoint::at_vdd(vdd);
        let model = MacroEnergyModel::at_vdd(vdd);
        let e = model.sop_pj_analytic(8, 16, 1, 256, 256).total_pj();
        let cfg = MacroConfig::flexspim(8, 16, 1, 1, 256);
        let sops = cfg.peak_sops(op.system_clock_hz);
        println!(
            "{vdd:>6.1} {:>10.1} {e:>10.3} {:>10.2}",
            op.system_clock_hz / 1e6,
            sops * e * 1e-12 * 1e3
        );
    }
    println!("\npaper anchors: 1.2-2.5 GSOPS, 5.7-7.2 pJ/SOP, 6.8-17.9 mW");
}
