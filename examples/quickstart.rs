//! Quickstart: the FlexSpIM deployment API in five minutes, no artifacts
//! needed.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! 1. Describe a deployment as data: topology (with per-layer operand
//!    resolution — the paper's headline flexibility), substrate, backend,
//!    and serve settings, via the fluent builder.
//! 2. Materialize tiers from the one spec and run an inference.
//! 3. Round-trip the same spec through TOML — what `flexspim run
//!    --config configs/*.toml` consumes.
//! 4. Peek under the hood: the bit-accurate CIM macro and the
//!    hybrid-stationary dataflow mapper the deployment drives.

use flexspim::cim::{CimMacro, MacroConfig};
use flexspim::dataflow::{Mapper, Policy};
use flexspim::deploy::DeploymentSpec;
use flexspim::energy::MacroEnergyModel;
use flexspim::events::{GestureClass, GestureGenerator};
use flexspim::snn::network::scnn_dvs_gesture;
use flexspim::snn::quant::max_val;
use flexspim::snn::Resolution;
use flexspim::util::rng::Rng;

fn main() -> flexspim::Result<()> {
    // --- 1. One typed spec describes the whole deployment. Resolutions
    //        are bitwise-granular per layer (Fig. 6a).
    let spec = DeploymentSpec::builder("quickstart")
        .timesteps(8)
        .conv("C1", 2, 8, 3, 4, 1, 48, 48, Resolution::new(4, 9))
        .fc("F1", 8 * 12 * 12, 32, Resolution::new(4, 9))
        .fc("F2", 32, 10, Resolution::new(5, 10))
        .macros(4)
        .policy(Policy::HsOpt)
        .native_backend(42) // pure Rust, runs everywhere
        .workers(2)
        .build()?;

    // --- 2. Every tier materializes from the same spec: .coordinator()
    //        here; .engine() / .service() take the identical plan.
    let deployment = spec.deploy()?;
    let mut coord = deployment.coordinator()?;
    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(7);
    let sample = gen.sample(GestureClass::HandClap, &mut rng);
    let result = coord.run_sample(&sample, Some(GestureClass::HandClap.label()))?;
    println!(
        "ran {} on {} macros: predicted class {} ({} SOPs, {:.1} nJ modeled)",
        deployment.network().name,
        deployment.spec().substrate.macros,
        result.prediction,
        result.metrics.sops,
        result.metrics.energy.total_pj() / 1e3,
    );

    // --- 3. The same spec as TOML (configs/*.toml ship ready-made
    //        presets; `flexspim serve --config <file>` needs no recompile).
    println!("\nthis deployment as TOML:\n{}", deployment.spec().to_toml());

    // --- 4a. Under the hood: the bit-accurate macro at an arbitrary
    //         resolution and operand shape (Fig. 3b's example).
    let cfg = MacroConfig::flexspim(5, 10, 3, 8, 16); // 16 neurons × 8 synapses
    let mut mac = CimMacro::new(cfg).expect("fits in the 512x256 array");
    for neuron in 0..16 {
        for syn in 0..8 {
            mac.load_weight(neuron, syn, ((neuron * 7 + syn * 3) % 31) as i64 - 15);
        }
    }
    let theta = max_val(10) / 2;
    let spikes_in = [true, false, true, true, false, false, true, false];
    let spikes_out = mac.timestep(&spikes_in, theta);
    let model = MacroEnergyModel::nominal();
    let c = mac.counters();
    println!(
        "macro demo: {} of 16 neurons fired; {:.2} pJ/SOP at 1.1 V (paper: 5.7-7.2 at 8b/16b)",
        spikes_out.iter().filter(|&&s| s).count(),
        model.pj_per_sop(c),
    );

    // --- 4b. The dataflow decision the substrate section controls: map
    //         the paper's SCNN onto two macros under each policy.
    let net = scnn_dvs_gesture();
    let mapper = Mapper::flexspim(2);
    println!("\nSCNN on 2 macros — avoided operand traffic per timestep:");
    let ws = mapper.map(&net, Policy::WsOnly).avoided_traffic_bits(&net);
    for policy in [Policy::WsOnly, Policy::HsMin, Policy::HsOpt] {
        let m = mapper.map(&net, policy);
        let avoided = m.avoided_traffic_bits(&net);
        println!(
            "  {:<8} {:>9} bits  ({:+.1} % vs WS-only)  util {:.0} %",
            policy.label(),
            avoided,
            100.0 * (avoided as f64 / ws as f64 - 1.0),
            100.0 * m.utilization()
        );
    }
    println!("\n(next: `flexspim serve --config configs/serve_demo.toml`)");
    Ok(())
}
