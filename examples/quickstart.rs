//! Quickstart: the FlexSpIM public API in five minutes, no artifacts
//! needed.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! 1. Simulate the bit-accurate CIM macro at an arbitrary resolution and
//!    operand shape (the paper's two circuit-level contributions).
//! 2. Price the run with the silicon-calibrated energy model.
//! 3. Map the reference SCNN onto two macros under every dataflow policy
//!    and see the hybrid-stationarity gain (Fig. 4).

use flexspim::cim::{CimMacro, MacroConfig};
use flexspim::dataflow::{Mapper, Policy};
use flexspim::energy::MacroEnergyModel;
use flexspim::snn::network::scnn_dvs_gesture;
use flexspim::snn::quant::max_val;

fn main() {
    // --- 1. A macro with 5-bit weights, 10-bit membrane potentials,
    //        operands shaped over N_C = 3 columns (Fig. 3b's example).
    let cfg = MacroConfig::flexspim(5, 10, 3, 8, 16); // 16 neurons × 8 synapses
    let mut mac = CimMacro::new(cfg).expect("fits in the 512x256 array");
    for neuron in 0..16 {
        for syn in 0..8 {
            mac.load_weight(neuron, syn, ((neuron * 7 + syn * 3) % 31) as i64 - 15);
        }
    }

    // Event-driven: present input spikes, macro accumulates and fires.
    let theta = max_val(10) / 2;
    let spikes_in = [true, false, true, true, false, false, true, false];
    let spikes_out = mac.timestep(&spikes_in, theta);
    println!("input spikes : {spikes_in:?}");
    println!(
        "output spikes: {:?} ({} fired)",
        spikes_out,
        spikes_out.iter().filter(|&&s| s).count()
    );
    println!(
        "vmem[0..4]   : {:?}",
        (0..4).map(|n| mac.peek_vmem(n)).collect::<Vec<_>>()
    );

    // --- 2. Energy: the simulator counted every precharge, adder toggle,
    //        carry hop and standby cycle; the calibrated model prices them.
    let model = MacroEnergyModel::nominal();
    let c = mac.counters();
    println!(
        "\nledger: {} cycles, {} adder ops, {} carry hops, {} EB reads",
        c.cim_cycles, c.adder_ops, c.carry_hops, c.eb_reads
    );
    println!(
        "energy: {:.2} pJ total -> {:.2} pJ/SOP at 1.1 V (paper: 5.7-7.2 pJ/SOP at 8b/16b)",
        model.price_pj(c),
        model.pj_per_sop(c)
    );

    // --- 3. Dataflow: map the paper's SCNN onto two macros.
    let net = scnn_dvs_gesture();
    let mapper = Mapper::flexspim(2);
    println!("\nSCNN on 2 macros — avoided operand traffic per timestep:");
    let ws = mapper.map(&net, Policy::WsOnly).avoided_traffic_bits(&net);
    for policy in [Policy::WsOnly, Policy::HsMin, Policy::HsOpt] {
        let m = mapper.map(&net, policy);
        let avoided = m.avoided_traffic_bits(&net);
        println!(
            "  {:<8} {:>9} bits  ({:+.1} % vs WS-only)  util {:.0} %",
            policy.label(),
            avoided,
            100.0 * (avoided as f64 / ws as f64 - 1.0),
            100.0 * m.utilization()
        );
    }
    println!("\n(next: `make artifacts` then `cargo run --release --example gesture_inference`)");
}
