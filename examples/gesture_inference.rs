//! End-to-end gesture inference on the full three-layer stack.
//!
//! Events (synthetic DVS) → per-timestep spike frames → the AOT-compiled
//! SCNN running under the PJRT runtime → predictions, with energy and
//! latency from the calibrated models. Deployment goes through the
//! unified spec: the builder selects the `pjrt` backend and the
//! [`flexspim::deploy::Deployment`] materializes the coordinator (the
//! runner itself prefers `artifacts/weights_trained.bin` when present —
//! run `examples/train_snn` or `flexspim train` first, otherwise the
//! shipped random-init weights give chance accuracy).
//!
//! ```sh
//! make artifacts
//! cargo run --release --example gesture_inference -- [samples-per-class] [seed]
//! ```

use anyhow::Result;
use flexspim::dataflow::Policy;
use flexspim::deploy::DeploymentSpec;
use flexspim::events::{GestureClass, GestureGenerator};
use flexspim::runtime::artifacts_dir;
use flexspim::snn::network::scnn_dvs_gesture;
use flexspim::util::rng::Rng;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let samples: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(2);
    let seed: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(7);

    let dir = artifacts_dir();
    if dir.join("weights_trained.bin").exists() {
        println!("using trained weights: {}", dir.join("weights_trained.bin").display());
    } else {
        println!("using shipped (untrained) weights — accuracy will be chance;");
        println!("run `cargo run --release --example train_snn` first for a real model");
    }

    // One spec, PJRT backend; the same spec with `.native_backend(seed)`
    // would run artifact-free.
    let spec = DeploymentSpec::builder("gesture-inference")
        .network(&scnn_dvs_gesture())
        .macros(16)
        .policy(Policy::HsOpt)
        .pjrt_backend(Some(dir))
        .build()?;
    let deployment = spec.deploy()?;
    let mut coord = deployment.coordinator()?;

    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(seed);
    let data = gen.dataset(samples, &mut rng);
    println!("\nrunning {} samples ({} classes × {samples}) ...\n", data.len(), 10);

    let mut confusion = vec![vec![0u32; 10]; 10];
    let mut total = flexspim::coordinator::RunMetrics::default();
    for (stream, label) in &data {
        let r = coord.run_sample(stream, Some(*label))?;
        confusion[*label][r.prediction] += 1;
        total.merge(&r.metrics);
    }

    println!("{}", total.report());
    println!("confusion matrix (rows = truth):");
    print!("      ");
    for c in 0..10 {
        print!("{c:>4}");
    }
    println!();
    for (label, row) in confusion.iter().enumerate() {
        print!("{:>5} ", GestureClass::from_label(label).label());
        for &v in row {
            print!("{v:>4}");
        }
        println!();
    }
    Ok(())
}
