//! Dataflow explorer: how does the hybrid-stationary gain scale with the
//! number of macros? (The paper's Fig. 4 at 2 macros plus the "further
//! gains with more macros" observation of §II-B.)
//!
//! ```sh
//! cargo run --release --example dataflow_explorer
//! ```

use flexspim::dataflow::{Mapper, Policy};
use flexspim::energy::SystemEnergyModel;
use flexspim::snn::network::scnn_dvs_gesture;

fn main() {
    let net = scnn_dvs_gesture();
    println!(
        "workload: {} ({} layers, {} kB weights, {} kB membrane state)\n",
        net.name,
        net.layers.len(),
        net.total_weight_bits() / 8192,
        net.total_vmem_bits() / 8192
    );

    println!("avoided operand traffic per timestep (bits):");
    print!("{:>8}", "macros");
    for p in Policy::ALL {
        print!("{:>12}", p.label());
    }
    println!("{:>10}", "HS gain");
    for macros in [1usize, 2, 4, 8, 16, 32] {
        let mapper = Mapper::flexspim(macros);
        print!("{macros:>8}");
        let mut ws = 0u64;
        let mut best = 0u64;
        for p in Policy::ALL {
            let m = mapper.map(&net, p);
            let avoided = m.avoided_traffic_bits(&net);
            if p == Policy::WsOnly {
                ws = avoided;
            }
            best = best.max(avoided);
            print!("{avoided:>12}");
        }
        println!("{:>9.1} %", 100.0 * (best as f64 / ws.max(1) as f64 - 1.0));
    }

    // Energy view at 95 % sparsity: what the avoided traffic buys.
    println!("\nmodeled energy per timestep at 95 % input sparsity (µJ):");
    print!("{:>8}", "macros");
    for p in Policy::ALL {
        print!("{:>12}", p.label());
    }
    println!();
    for macros in [1usize, 2, 4, 8, 16, 32] {
        let mapper = Mapper::flexspim(macros);
        let sys = SystemEnergyModel::flexspim(macros);
        print!("{macros:>8}");
        for p in Policy::ALL {
            let m = mapper.map(&net, p);
            let e = sys.evaluate(&net, &m, 0.95, None).total_pj() * 1e-6;
            print!("{e:>12.3}");
        }
        println!();
    }

    // Per-layer detail at the paper's 2-macro point.
    println!("\nper-layer mapping detail (2 macros, HS-min):");
    let m = Mapper::flexspim(2).map(&net, Policy::HsMin);
    println!("{}", m.table(&net));
}
