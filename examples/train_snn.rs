//! End-to-end training driver — the repo's E2E validation run.
//!
//! The Rust coordinator drives the AOT-compiled surrogate-gradient train
//! step (`train_step.hlo.txt`) over synthetic DVS gesture batches, logs
//! the loss curve, saves the trained weights, and finally evaluates the
//! *quantized integer* model through the inference path (the
//! silicon-faithful semantics). Python is nowhere on this path.
//!
//! ```sh
//! make artifacts
//! cargo run --release --example train_snn -- [steps] [lr] [eval-samples]
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use std::io::Write;

use anyhow::Result;
use flexspim::coordinator::Coordinator;
use flexspim::dataflow::Policy;
use flexspim::events::GestureGenerator;
use flexspim::runtime::trainer::synth_batch;
use flexspim::runtime::{artifacts_dir, Runtime, ScnnRunner, TrainRunner};
use flexspim::util::rng::Rng;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let lr: f32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.01);
    let eval_samples: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(3);

    let rt = Runtime::cpu()?;
    let dir = artifacts_dir();
    println!("PJRT platform: {} | artifacts: {}", rt.platform(), dir.display());
    let mut trainer = TrainRunner::load(&rt, &dir)?;

    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(2024);
    let mut loss_log = Vec::with_capacity(steps);
    let t0 = std::time::Instant::now();
    println!("training {steps} steps, batch 4 × 16 timesteps, lr {lr} ...");
    for step in 0..steps {
        let (frames, labels) = synth_batch(&gen, &mut rng);
        let m = trainer.step(&frames, &labels, lr)?;
        loss_log.push((step, m.loss, m.accuracy));
        if step % 10 == 0 || step + 1 == steps {
            println!(
                "step {step:4}  loss {:8.4}  batch-acc {:4.2}  ({:.1} s elapsed)",
                m.loss,
                m.accuracy,
                t0.elapsed().as_secs_f64()
            );
        }
    }

    // Persist the loss curve and trained weights.
    let curve_path = dir.join("train_loss.csv");
    let mut f = std::fs::File::create(&curve_path)?;
    writeln!(f, "step,loss,batch_accuracy")?;
    for (s, l, a) in &loss_log {
        writeln!(f, "{s},{l},{a}")?;
    }
    println!("loss curve -> {}", curve_path.display());

    let wf = trainer.to_weight_file();
    let wpath = dir.join("weights_trained.bin");
    save_weight_file(&wf, &wpath)?;
    println!("trained weights -> {}", wpath.display());

    // Loss must have gone down over the run (early mean vs late mean).
    let k = (steps / 5).max(1);
    let early: f32 = loss_log[..k].iter().map(|(_, l, _)| l).sum::<f32>() / k as f32;
    let late: f32 =
        loss_log[steps - k..].iter().map(|(_, l, _)| l).sum::<f32>() / k as f32;
    println!("mean loss: first {k} steps {early:.3} -> last {k} steps {late:.3}");

    // --- Integer-model evaluation through the inference path.
    println!("\nevaluating quantized integer model ({eval_samples} samples/class) ...");
    let exe = rt.load_hlo(&dir.join("scnn_step.hlo.txt"))?;
    let runner = ScnnRunner::new(exe, wf)?;
    let mut coord = Coordinator::with_runner(runner, 16, Policy::HsOpt)?;
    let mut eval_rng = Rng::new(777);
    let data = gen.dataset(eval_samples, &mut eval_rng);
    let metrics = coord.run_dataset(&data)?;
    println!("{}", metrics.report());
    Ok(())
}

fn save_weight_file(wf: &flexspim::runtime::WeightFile, path: &std::path::Path) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(b"FSPW")?;
    f.write_all(&(wf.layers.len() as i32).to_le_bytes())?;
    for l in &wf.layers {
        f.write_all(&(l.name.len() as i32).to_le_bytes())?;
        f.write_all(l.name.as_bytes())?;
        f.write_all(&(l.w_bits as i32).to_le_bytes())?;
        f.write_all(&(l.p_bits as i32).to_le_bytes())?;
        f.write_all(&(l.dims.len() as i32).to_le_bytes())?;
        for &d in &l.dims {
            f.write_all(&(d as i32).to_le_bytes())?;
        }
        for &v in &l.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}
