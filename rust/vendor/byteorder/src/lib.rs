//! Minimal, API-compatible shim for the subset of [`byteorder`] that
//! flexspim uses: `LittleEndian`, `BigEndian`, and the `ReadBytesExt`
//! methods `read_i32`, `read_u32`, `read_f32`, and `read_f32_into`.

use std::io;

/// Byte-order conversion for fixed-width reads.
pub trait ByteOrder {
    /// Decode an `i32` from 4 bytes.
    fn read_i32(buf: [u8; 4]) -> i32;
    /// Decode a `u32` from 4 bytes.
    fn read_u32(buf: [u8; 4]) -> u32;
    /// Decode an `f32` from 4 bytes.
    fn read_f32(buf: [u8; 4]) -> f32;
}

/// Little-endian byte order.
pub enum LittleEndian {}

/// Big-endian byte order.
pub enum BigEndian {}

impl ByteOrder for LittleEndian {
    fn read_i32(buf: [u8; 4]) -> i32 {
        i32::from_le_bytes(buf)
    }
    fn read_u32(buf: [u8; 4]) -> u32 {
        u32::from_le_bytes(buf)
    }
    fn read_f32(buf: [u8; 4]) -> f32 {
        f32::from_le_bytes(buf)
    }
}

impl ByteOrder for BigEndian {
    fn read_i32(buf: [u8; 4]) -> i32 {
        i32::from_be_bytes(buf)
    }
    fn read_u32(buf: [u8; 4]) -> u32 {
        u32::from_be_bytes(buf)
    }
    fn read_f32(buf: [u8; 4]) -> f32 {
        f32::from_be_bytes(buf)
    }
}

/// Extension methods for reading numbers from any `io::Read`.
pub trait ReadBytesExt: io::Read {
    /// Read a 4-byte signed integer.
    fn read_i32<B: ByteOrder>(&mut self) -> io::Result<i32> {
        let mut buf = [0u8; 4];
        self.read_exact(&mut buf)?;
        Ok(B::read_i32(buf))
    }

    /// Read a 4-byte unsigned integer.
    fn read_u32<B: ByteOrder>(&mut self) -> io::Result<u32> {
        let mut buf = [0u8; 4];
        self.read_exact(&mut buf)?;
        Ok(B::read_u32(buf))
    }

    /// Read a 4-byte float.
    fn read_f32<B: ByteOrder>(&mut self) -> io::Result<f32> {
        let mut buf = [0u8; 4];
        self.read_exact(&mut buf)?;
        Ok(B::read_f32(buf))
    }

    /// Fill `dst` with 4-byte floats.
    fn read_f32_into<B: ByteOrder>(&mut self, dst: &mut [f32]) -> io::Result<()> {
        // One bulk read, then decode in place: weights files hold millions
        // of floats and per-element syscalls would dominate.
        let mut raw = vec![0u8; dst.len() * 4];
        self.read_exact(&mut raw)?;
        for (d, chunk) in dst.iter_mut().zip(raw.chunks_exact(4)) {
            *d = B::read_f32([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        Ok(())
    }
}

impl<R: io::Read + ?Sized> ReadBytesExt for R {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn little_endian_reads() {
        let bytes: Vec<u8> = vec![0x01, 0x00, 0x00, 0x00, 0x00, 0x00, 0x80, 0x3F];
        let mut cur = &bytes[..];
        assert_eq!(cur.read_i32::<LittleEndian>().unwrap(), 1);
        assert_eq!(cur.read_f32::<LittleEndian>().unwrap(), 1.0);
    }

    #[test]
    fn f32_into_bulk() {
        let mut bytes = Vec::new();
        for v in [1.5f32, -2.25, 0.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let mut dst = [0f32; 3];
        (&bytes[..]).read_f32_into::<LittleEndian>(&mut dst).unwrap();
        assert_eq!(dst, [1.5, -2.25, 0.0]);
    }

    #[test]
    fn short_read_errors() {
        let bytes = [0u8; 2];
        assert!((&bytes[..]).read_i32::<LittleEndian>().is_err());
    }

    #[test]
    fn big_endian_reads() {
        let bytes: Vec<u8> = vec![0x00, 0x00, 0x00, 0x02];
        let mut cur = &bytes[..];
        assert_eq!(cur.read_i32::<BigEndian>().unwrap(), 2);
    }
}
