//! Minimal, API-compatible shim for the subset of [`anyhow`] that flexspim
//! uses: `Error`, `Result`, the `Context` extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The real crate is not vendored in the offline build environment; this
//! shim keeps the exact call sites compiling unchanged so the full crate
//! can be dropped back in without touching application code. Like the real
//! `anyhow::Error`, this `Error` deliberately does **not** implement
//! `std::error::Error` — that is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.

use std::fmt;

/// An error with a human-readable message and a chain of context frames
/// (outermost context first, root cause last).
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { frames: vec![message.to_string()] }
    }

    /// Push an outer context frame (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.frames.insert(0, context.to_string());
        self
    }

    /// The root cause (innermost frame).
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // The whole chain, outermost first. (The real anyhow shows only the
        // outermost frame here; joining keeps nested context intact when an
        // `Error` is itself re-wrapped through the Display-based shim path.)
        write!(f, "{}", self.frames.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.frames.split_first() {
            None => write!(f, "Error"),
            Some((first, rest)) => {
                write!(f, "{first}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for frame in rest {
                        write!(f, "\n    {frame}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to fallible values.
pub trait Context<T> {
    /// Wrap the error with an outer context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or any error
/// value — mirrors `anyhow::anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error — mirrors `anyhow::bail!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds — mirrors
/// `anyhow::ensure!`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn from_std_error_and_question_mark() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("missing thing"));
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening weights").unwrap_err();
        assert_eq!(format!("{e}"), "opening weights: missing thing");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("opening weights") && dbg.contains("missing thing"));
        assert_eq!(e.root_cause(), "missing thing");
    }

    #[test]
    fn with_context_and_option() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "slot 3");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(format!("{}", f(12).unwrap_err()).contains("12"));
        assert!(format!("{}", f(3).unwrap_err()).contains("right out"));
        let from_string = anyhow!(String::from("owned message"));
        assert_eq!(format!("{from_string}"), "owned message");
    }
}
