//! Offline stub of the `xla` PJRT bindings used by flexspim's runtime.
//!
//! Two tiers:
//!
//! * [`Literal`] and its conversion helpers are **fully functional** host
//!   implementations (typed buffer + dims + tuple support) — everything the
//!   pure-Rust code paths and unit tests need.
//! * The PJRT execution surface ([`PjRtClient`], [`PjRtLoadedExecutable`],
//!   HLO parsing) compiles but is **gated**: `PjRtClient::cpu()` returns a
//!   descriptive error because the native XLA runtime is not vendored in
//!   this offline build. Artifact-gated tests and binaries detect missing
//!   artifacts before constructing a client, so they skip cleanly.
//!
//! Replacing this stub with the full `xla` crate (see /opt/xla-example in
//! the original build environment) re-enables AOT HLO execution without any
//! application-code changes.

use std::fmt;
use std::path::Path;

/// Error type for all stub operations.
#[derive(Debug, Clone)]
pub struct Error {
    message: String,
}

impl Error {
    fn new<M: fmt::Display>(message: M) -> Self {
        Error { message: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.message)
    }
}

impl std::error::Error for Error {}

/// Result alias for stub operations.
pub type Result<T> = std::result::Result<T, Error>;

const NO_RUNTIME: &str = "the native XLA/PJRT runtime is not vendored in this offline build; \
     swap rust/vendor/xla for the full xla crate to execute AOT HLO artifacts";

// --------------------------------------------------------------- literals

/// Element type of a literal buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    /// 32-bit signed integer.
    I32,
    /// 32-bit float.
    F32,
}

/// Internal typed storage (public only because [`NativeType`] mentions it).
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Buffer {
    I32(Vec<i32>),
    F32(Vec<f32>),
    Tuple(Vec<Literal>),
}

/// A host-side typed tensor value, mirroring `xla::Literal`.
#[derive(Debug, Clone)]
pub struct Literal {
    buffer: Buffer,
    dims: Vec<i64>,
}

/// Rust scalar types storable in a [`Literal`].
pub trait NativeType: Copy + Sized {
    /// Element type tag.
    const TYPE: ElementType;
    /// Pack a slice into a buffer.
    fn pack(values: &[Self]) -> Buffer;
    /// Unpack a buffer, failing on a type mismatch.
    fn unpack(buffer: &Buffer) -> Option<Vec<Self>>;
}

impl NativeType for i32 {
    const TYPE: ElementType = ElementType::I32;
    fn pack(values: &[Self]) -> Buffer {
        Buffer::I32(values.to_vec())
    }
    fn unpack(buffer: &Buffer) -> Option<Vec<Self>> {
        match buffer {
            Buffer::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for f32 {
    const TYPE: ElementType = ElementType::F32;
    fn pack(values: &[Self]) -> Buffer {
        Buffer::F32(values.to_vec())
    }
    fn unpack(buffer: &Buffer) -> Option<Vec<Self>> {
        match buffer {
            Buffer::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        Literal { buffer: T::pack(values), dims: vec![values.len() as i64] }
    }

    /// Rank-0 (scalar) f32 literal.
    pub fn scalar(value: f32) -> Literal {
        Literal { buffer: Buffer::F32(vec![value]), dims: vec![] }
    }

    /// Tuple literal from elements.
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        let n = elements.len() as i64;
        Literal { buffer: Buffer::Tuple(elements), dims: vec![n] }
    }

    /// Number of scalar elements (1 for scalars, element count otherwise).
    pub fn element_count(&self) -> usize {
        match &self.buffer {
            Buffer::I32(v) => v.len(),
            Buffer::F32(v) => v.len(),
            Buffer::Tuple(v) => v.len(),
        }
    }

    /// Logical dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Reinterpret the buffer with new logical dims (element count must
    /// match the dims product).
    pub fn reshape(mut self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error::new(format!(
                "reshape {:?} ({} elements) to {:?} ({} elements)",
                self.dims,
                self.element_count(),
                dims,
                n
            )));
        }
        self.dims = dims.to_vec();
        Ok(self)
    }

    /// Extract the flattened elements as `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unpack(&self.buffer)
            .ok_or_else(|| Error::new(format!("literal is not of element type {:?}", T::TYPE)))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.buffer {
            Buffer::Tuple(v) => Ok(v),
            _ => Err(Error::new("literal is not a tuple")),
        }
    }
}

// ---------------------------------------------------------------- HLO text

/// Parsed (well: retained) HLO module text, mirroring `xla::HloModuleProto`.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text: String,
    name: String,
}

impl HloModuleProto {
    /// Load HLO text from a file. Parsing/validation happens at compile
    /// time in the real bindings; the stub only checks readability.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(Path::new(path))
            .map_err(|e| Error::new(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { text, name: path.to_string() })
    }

    /// The retained module text.
    pub fn text(&self) -> &str {
        &self.text
    }
}

/// A computation handle wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    name: String,
}

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { name: proto.name.clone() }
    }
}

// ------------------------------------------------------------ PJRT (gated)

/// PJRT client handle. In this offline stub, construction always fails
/// with a descriptive error — see the module docs.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU client. Always errors in the offline stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::new(NO_RUNTIME))
    }

    /// Platform string.
    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    /// Compile a computation. Unreachable in the stub (no client can be
    /// constructed), present for API compatibility.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(NO_RUNTIME))
    }
}

/// A device-resident buffer produced by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal. Unreachable in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(NO_RUNTIME))
    }
}

/// A compiled executable. Unreachable in the stub.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments. Unreachable in the stub.
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(NO_RUNTIME))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_vec1_reshape_roundtrip() {
        let l = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]).reshape(&[2, 3]).unwrap();
        assert_eq!(l.dims(), &[2, 3]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert!(l.to_vec::<f32>().is_err(), "type mismatch detected");
    }

    #[test]
    fn reshape_validates_element_count() {
        assert!(Literal::vec1(&[1i32, 2]).reshape(&[3]).is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(2.5);
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![2.5]);
        assert!(s.dims().is_empty());
        let t = Literal::tuple(vec![Literal::vec1(&[1i32]), Literal::scalar(0.5)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::scalar(1.0).to_tuple().is_err());
    }

    #[test]
    fn pjrt_is_gated_with_descriptive_error() {
        let e = PjRtClient::cpu().err().expect("stub must refuse");
        assert!(format!("{e}").contains("offline"), "{e}");
    }
}
