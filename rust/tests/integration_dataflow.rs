//! Cross-module integration: workload ↔ mapper ↔ scheduler ↔ energy
//! model consistency, plus property tests on coordinator-level invariants
//! (no PJRT needed — these always run).

use flexspim::cim::{CimMacro, MacroConfig};
use flexspim::coordinator::Scheduler;
use flexspim::dataflow::{Mapper, Policy};
use flexspim::energy::{MacroEnergyModel, SystemEnergyModel};
use flexspim::snn::network::scnn_dvs_gesture;
use flexspim::snn::quant::{max_val, min_val, wrap};
use flexspim::snn::{LayerSpec, Network, Resolution};
use flexspim::util::proptest_lite::{check, prop_assert, prop_eq, Config};

#[test]
fn mapping_residency_never_exceeds_capacity_property() {
    // Invariant: for random workloads, macro counts, and policies, the
    // mapper never oversubscribes CIM and avoided+streamed covers every
    // operand exactly once.
    check("mapper-invariants", &Config { cases: 80, ..Default::default() }, |c| {
        let n_layers = c.rng.range_usize(1, 8);
        let mut dim_in = c.rng.range_usize(4, 64);
        let mut layers = Vec::new();
        for i in 0..n_layers {
            let out = c.rng.range_usize(2, 64);
            let res = Resolution::new(
                c.rng.range_i64(1, 8) as u32,
                c.rng.range_i64(2, 16) as u32,
            );
            layers.push(LayerSpec::fc(&format!("f{i}"), dim_in, out, res));
            dim_in = out;
        }
        let net = Network::new("rand", layers, 4);
        let macros = c.rng.range_usize(1, 8);
        let mapper = Mapper::flexspim(macros);
        for policy in Policy::ALL {
            let m = mapper.map(&net, policy);
            prop_assert(m.used_bits <= m.capacity_bits, "capacity respected")?;
            // Conservation: avoided + streamed == total operand traffic.
            let total: u64 = net
                .layers
                .iter()
                .map(|l| l.weight_bits() + 2 * l.vmem_bits())
                .sum();
            prop_eq(
                m.avoided_traffic_bits(&net) + m.streamed_traffic_bits(&net),
                total,
                &format!("{policy} traffic conservation"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn more_macros_never_hurt_property() {
    // Monotonicity: adding CIM capacity never reduces avoided traffic.
    let net = scnn_dvs_gesture();
    for policy in Policy::ALL {
        let mut last = 0u64;
        for macros in 1..=20 {
            let m = Mapper::flexspim(macros).map(&net, policy);
            let avoided = m.avoided_traffic_bits(&net);
            assert!(
                avoided >= last,
                "{policy} at {macros} macros: {avoided} < {last}"
            );
            last = avoided;
        }
    }
}

#[test]
fn system_energy_decreases_with_macro_count() {
    let net = scnn_dvs_gesture();
    let mut last = f64::INFINITY;
    for macros in [1usize, 2, 4, 8, 16, 32] {
        let mapping = Mapper::flexspim(macros).map(&net, Policy::HsOpt);
        let sys = SystemEnergyModel::flexspim(macros);
        let e = sys.evaluate(&net, &mapping, 0.95, None).total_pj();
        assert!(e <= last * 1.0001, "{macros} macros: {e} > {last}");
        last = e;
    }
}

#[test]
fn scheduler_and_energy_agree_on_shapes() {
    // The scheduler's chosen shape must be executable on the macro and
    // priced by the analytic model without panicking, for every layer.
    let net = scnn_dvs_gesture();
    let mapping = Mapper::flexspim(16).map(&net, Policy::HsOpt);
    let sched = Scheduler::default().plan(&net, &mapping);
    let model = MacroEnergyModel::nominal();
    for (plan, layer) in sched.layers.iter().zip(&net.layers) {
        let e = model.sop_pj_analytic(
            layer.res.w_bits,
            layer.res.p_bits,
            plan.n_c,
            plan.parallel_neurons,
            256,
        );
        assert!(e.total_pj() > 0.0);
        assert!(plan.parallel_neurons * plan.n_c as usize <= 256);
    }
}

#[test]
fn macro_sim_energy_close_to_analytic_across_random_configs() {
    // The bit-accurate simulator and the analytic pricing must stay
    // within a few percent for random configurations (the analytic form
    // feeds the system extrapolation; the simulator is ground truth).
    check("sim-vs-analytic", &Config { cases: 40, ..Default::default() }, |c| {
        let w = c.rng.range_i64(1, 8) as u32;
        let p = c.rng.range_i64(w as i64, 16) as u32;
        let n_c = c.rng.range_i64(1, p as i64) as u32;
        let neurons = c.rng.range_usize(1, (256 / n_c as usize).min(48));
        let cfg = MacroConfig::flexspim(w, p, n_c, 1, neurons);
        if cfg.validate().is_err() {
            return Ok(());
        }
        let mut mac = CimMacro::new(cfg).unwrap();
        for n in 0..neurons {
            mac.load_weight(n, 0, c.rng.range_i64(min_val(w), max_val(w)));
            mac.load_vmem(n, c.rng.range_i64(min_val(p), max_val(p)));
        }
        mac.reset_counters();
        for _ in 0..3 {
            mac.cim_accumulate(0, None);
        }
        let model = MacroEnergyModel::nominal();
        let sim = model.pj_per_sop(mac.counters());
        let ana = model.sop_pj_analytic(w, p, n_c, neurons, 256).total_pj();
        let rel = (sim - ana).abs() / ana;
        prop_assert(
            rel < 0.08,
            &format!("w={w} p={p} n_c={n_c} neurons={neurons}: sim {sim:.3} vs ana {ana:.3}"),
        )
    });
}

#[test]
fn event_driven_macro_matches_lif_over_long_runs_property() {
    // Multi-timestep, multi-synapse stress: the macro and the golden LIF
    // must agree after dozens of timesteps, including wraparound and
    // firing dynamics.
    check("macro-vs-lif-long", &Config { cases: 20, ..Default::default() }, |c| {
        let w_bits = c.rng.range_i64(2, 6) as u32;
        let p_bits = c.rng.range_i64(w_bits as i64 + 1, 12) as u32;
        let n_c = c.rng.range_i64(1, 3) as u32;
        let neurons = c.rng.range_usize(1, 8);
        let fan_in = c.rng.range_usize(1, 6);
        let cfg = MacroConfig::flexspim(w_bits, p_bits, n_c, fan_in, neurons);
        if cfg.validate().is_err() {
            return Ok(());
        }
        let mut mac = CimMacro::new(cfg).unwrap();
        let weights: Vec<Vec<i64>> = (0..neurons)
            .map(|_| {
                (0..fan_in)
                    .map(|_| c.rng.range_i64(min_val(w_bits), max_val(w_bits)))
                    .collect()
            })
            .collect();
        let theta = c.rng.range_i64(1, max_val(p_bits));
        let mut lif = flexspim::snn::lif::LifLayer::new(
            weights.clone(),
            Resolution::new(w_bits, p_bits),
            theta,
        );
        for (n, row) in weights.iter().enumerate() {
            for (j, &wv) in row.iter().enumerate() {
                mac.load_weight(n, j, wv);
            }
        }
        for t in 0..24 {
            let spikes: Vec<bool> = (0..fan_in).map(|_| c.rng.chance(0.35)).collect();
            let expect = lif.step(&spikes);
            let got = mac.timestep(&spikes, theta);
            prop_eq(got, expect, &format!("t={t} spikes"))?;
            for n in 0..neurons {
                prop_eq(mac.peek_vmem(n), lif.v[n], &format!("t={t} neuron {n}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn wrap_consistency_between_modules() {
    // snn::quant::wrap is the single source of truth; spot-check the
    // Python-exported semantics on boundary values here too.
    for bits in 1..=31 {
        let m = 1i64 << bits;
        assert_eq!(wrap(m / 2, bits), -m / 2);
        assert_eq!(wrap(-m / 2 - 1, bits), m / 2 - 1);
        assert_eq!(wrap(m, bits), 0);
    }
}
