//! Property tests for the ingest jitter buffer's drop accounting.
//!
//! The saturation harness reports loss figures straight off the
//! [`ReorderBuffer`] counters, so they must partition *exactly*: every
//! valid event offered to `push` ends up in precisely one of
//! `delivered`, `late_dropped`, `overflow_dropped`, or
//! `flush_discarded` once the session closes — no event double-counted,
//! none lost off the books. Rejected (`Err`) pushes stay outside the
//! ledger entirely.
//!
//! Driven under adversarial arrival patterns: forward-biased random
//! walks with backward jumps (transport reordering), tiny buffers
//! (overflow), interleaved polls (late drops), and a close point that
//! may truncate in-flight events (flush discards).

use flexspim::events::DvsEvent;
use flexspim::serve::{IngestConfig, MicroWindow, ReorderBuffer};
use flexspim::util::proptest_lite::{check, prop_assert, prop_eq, Config};

const W: u16 = 8;
const H: u16 = 8;
/// Event timestamps stay below this; `max_future_us` sits far above it so
/// the future-bound rejection never fires and every push enters the ledger.
const T_MAX: u64 = 2048;

fn consume(
    windows: &[MicroWindow],
    expected_t0: &mut u64,
    delivered: &mut u64,
    lasts: &mut u64,
) -> Result<(), String> {
    for w in windows {
        prop_eq(w.t0_us, *expected_t0, "windows are contiguous")?;
        prop_assert(w.t1_us >= w.t0_us, "window span is non-negative")?;
        prop_assert(
            w.events.windows(2).all(|p| p[0].t_us <= p[1].t_us),
            "window events are time-sorted",
        )?;
        prop_assert(
            w.events.iter().all(|e| w.t0_us <= e.t_us && (e.t_us < w.t1_us || w.last)),
            "window events fall inside the window span",
        )?;
        *expected_t0 = w.t1_us;
        *delivered += w.events.len() as u64;
        *lasts += u64::from(w.last);
    }
    Ok(())
}

#[test]
fn drop_counters_partition_exactly_under_adversarial_arrivals() {
    check("ingest-partition", &Config::default(), |c| {
        let window_us = 1 + c.rng.below(200);
        let cfg = IngestConfig {
            width: W,
            height: H,
            window_us,
            max_lateness_us: c.rng.below(3 * window_us),
            max_pending: 1 + c.rng.below(1 + c.size as u64 / 2) as usize,
            max_future_us: 2 * T_MAX,
        };
        let mut b = ReorderBuffer::new(cfg);

        let mut pushed = 0u64;
        let mut delivered = 0u64;
        let mut lasts = 0u64;
        let mut expected_t0 = 0u64;
        let mut t = 0u64;
        for _ in 0..c.size * 4 {
            // Forward-biased walk with occasional backward jumps, the
            // shape a reordering transport actually produces.
            if c.rng.chance(0.3) {
                t = t.saturating_sub(c.rng.below(2 * window_us));
            } else {
                t = (t + c.rng.below(window_us + 1)).min(T_MAX);
            }
            if c.rng.chance(0.05) {
                // Invalid input: rejected, and must never enter the ledger.
                let before = b.pushed;
                prop_assert(
                    b.push(DvsEvent { t_us: t, x: W, y: 0, polarity: true }).is_err(),
                    "out-of-bounds pixel is an Err",
                )?;
                prop_eq(b.pushed, before, "Err pushes stay off the books")?;
                continue;
            }
            let e = DvsEvent {
                t_us: t,
                x: c.rng.below(W as u64) as u16,
                y: c.rng.below(H as u64) as u16,
                polarity: c.rng.chance(0.5),
            };
            b.push(e).map_err(|e| format!("valid push rejected: {e}"))?;
            pushed += 1;
            if c.rng.chance(0.25) {
                consume(&b.poll(), &mut expected_t0, &mut delivered, &mut lasts)?;
            }
        }
        consume(&b.poll(), &mut expected_t0, &mut delivered, &mut lasts)?;

        // Close somewhere between the frontier and past the watermark, so
        // flushes both truncate pending events and absorb them.
        let end = b.emitted_until_us().max(b.watermark_us().saturating_sub(c.rng.below(512)));
        let flushed = b.flush(end).map_err(|e| format!("flush rejected: {e}"))?;
        consume(&flushed, &mut expected_t0, &mut delivered, &mut lasts)?;

        prop_eq(lasts, 1, "exactly one last-marked window per session")?;
        prop_assert(flushed.last().is_some_and(|w| w.last), "flush ends with the last window")?;
        prop_eq(b.pushed, pushed, "every Ok push is counted")?;
        prop_eq(b.delivered, delivered, "delivered matches the emitted windows")?;
        prop_eq(b.pending_len(), 0, "flush leaves nothing pending")?;
        prop_eq(
            b.delivered + b.late_dropped + b.overflow_dropped + b.flush_discarded,
            b.pushed,
            "drop counters partition every pushed event exactly",
        )
    });
}

#[test]
fn accepted_events_are_either_delivered_or_flush_discarded() {
    // With no polls before the close, nothing can go late after
    // acceptance: the accepted/dropped split at push time must be
    // conserved through the flush.
    check("ingest-accepted-conserved", &Config { cases: 128, ..Config::default() }, |c| {
        let window_us = 1 + c.rng.below(100);
        let cfg = IngestConfig {
            width: W,
            height: H,
            window_us,
            max_lateness_us: c.rng.below(window_us),
            max_pending: 1 + c.rng.below(16) as usize,
            max_future_us: 2 * T_MAX,
        };
        let mut b = ReorderBuffer::new(cfg);
        for _ in 0..c.size * 2 {
            let e = DvsEvent {
                t_us: c.rng.below(T_MAX),
                x: c.rng.below(W as u64) as u16,
                y: c.rng.below(H as u64) as u16,
                polarity: true,
            };
            b.push(e).map_err(|e| format!("valid push rejected: {e}"))?;
        }
        prop_eq(b.late_dropped, 0, "no window was emitted, so nothing is late")?;
        let end = c.rng.below(T_MAX);
        let flushed = b.flush(end).map_err(|e| format!("flush rejected: {e}"))?;
        let emitted: u64 = flushed.iter().map(|w| w.events.len() as u64).sum();
        prop_eq(b.accepted, emitted + b.flush_discarded, "accepted splits at the close")?;
        prop_eq(
            b.delivered + b.overflow_dropped + b.flush_discarded,
            b.pushed,
            "partition without lateness",
        )
    });
}
