//! Dense-vs-sparse bit-identity: the event-driven execution engine
//! (`snn::events`) must reproduce the dense golden models *exactly* —
//! spikes, membrane potentials, and predictions — across random conv/FC
//! geometries, operand resolutions, thresholds, and spike activities.
//!
//! The thresholds are deliberately drawn small relative to the weight
//! range so multi-fire residuals (`v ≥ 2θ` after a timestep) occur often:
//! those are exactly the cases where a naive "fire-check only touched
//! neurons" scheme diverges from the dense per-neuron scan, and where the
//! sparse engine's refire set must step in. Activities sweep from fully
//! silent frames (refire-only paths) all the way to 100 %-dense ones (the
//! packed word-parallel kernels' saturation case).

use flexspim::runtime::{NativeScnn, StepBackend};
use flexspim::snn::conv::ConvLifLayer;
use flexspim::snn::events::{EventConvLayer, EventFcLayer, SpikeList};
use flexspim::snn::lif::LifLayer;
use flexspim::snn::{LayerSpec, Network, Resolution};
use flexspim::util::proptest_lite::{check, prop_eq, Config};

#[test]
fn prop_event_conv_matches_dense_conv() {
    check(
        "event-conv-vs-dense",
        &Config { cases: 60, ..Default::default() },
        |c| {
            let in_ch = c.rng.range_usize(1, 3);
            let out_ch = c.rng.range_usize(1, 4);
            let k = *c.rng.choose(&[1usize, 3]);
            let stride = *c.rng.choose(&[1usize, 2]);
            let pad = c.rng.range_usize(0, k / 2);
            let h = c.rng.range_usize(k.max(3), 7);
            let w_bits = c.rng.range_i64(2, 5) as u32;
            let p_bits = c.rng.range_i64(6, 12) as u32;
            let res = Resolution::new(w_bits, p_bits);
            let spec = LayerSpec::conv("p", in_ch, out_ch, k, stride, pad, h, h, res);
            let hi = flexspim::snn::quant::max_val(w_bits);
            let lo = flexspim::snn::quant::min_val(w_bits);
            let weights: Vec<i64> = (0..spec.num_weights())
                .map(|_| c.rng.range_i64(lo, hi))
                .collect();
            // Small thresholds provoke multi-fire residuals.
            let theta = c.rng.range_i64(1, 8);
            let mut sparse = EventConvLayer::new(spec.clone(), weights.clone(), theta);
            let mut dense = ConvLifLayer::new(spec, weights, theta);

            let in_dim = in_ch * h * h;
            for t in 0..6 {
                // Sweep activity including fully-silent frames.
                let activity = *c.rng.choose(&[0.0, 0.02, 0.1, 0.3, 0.5, 1.0]);
                let bits: Vec<bool> = (0..in_dim).map(|_| c.rng.chance(activity)).collect();
                let a = sparse.step(&SpikeList::from_dense(&bits));
                let b = dense.step(&bits);
                prop_eq(a.to_dense(), b, &format!("t={t} spikes"))?;
                prop_eq(
                    sparse.vmem().to_vec(),
                    dense.v.clone(),
                    &format!("t={t} vmem"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_event_fc_matches_dense_lif() {
    check(
        "event-fc-vs-dense",
        &Config { cases: 80, ..Default::default() },
        |c| {
            let in_dim = c.rng.range_usize(1, 24);
            let out_dim = c.rng.range_usize(1, 8);
            let w_bits = c.rng.range_i64(2, 5) as u32;
            let p_bits = c.rng.range_i64(6, 12) as u32;
            let res = Resolution::new(w_bits, p_bits);
            let hi = flexspim::snn::quant::max_val(w_bits);
            let lo = flexspim::snn::quant::min_val(w_bits);
            let weights: Vec<Vec<i64>> = (0..out_dim)
                .map(|_| (0..in_dim).map(|_| c.rng.range_i64(lo, hi)).collect())
                .collect();
            let theta = c.rng.range_i64(1, 8);
            let mut sparse = EventFcLayer::new(weights.clone(), res, theta);
            let mut dense = LifLayer::new(weights, res, theta);
            for t in 0..6 {
                let activity = *c.rng.choose(&[0.0, 0.05, 0.2, 0.5, 1.0]);
                let bits: Vec<bool> = (0..in_dim).map(|_| c.rng.chance(activity)).collect();
                let a = sparse.step(&SpikeList::from_dense(&bits));
                let b = dense.step(&bits);
                prop_eq(a.to_dense(), b, &format!("t={t} spikes"))?;
                prop_eq(
                    sparse.vmem().to_vec(),
                    dense.v.clone(),
                    &format!("t={t} vmem"),
                )?;
            }
            Ok(())
        },
    );
}

/// The conv layer's packed word-parallel step and its scalar reference
/// step are the same function: run two clones of one layer — one through
/// `step`, one through `step_scalar` — against the dense oracle, at every
/// activity including 100 % dense, and demand identical spikes and vmem.
/// The paths also share the packed pending masks, so alternating them on
/// a third clone checks the interleaved hand-off.
#[test]
fn prop_conv_packed_scalar_and_dense_paths_agree() {
    check(
        "conv-packed-vs-scalar-vs-dense",
        &Config { cases: 40, ..Default::default() },
        |c| {
            let in_ch = c.rng.range_usize(1, 3);
            let out_ch = c.rng.range_usize(1, 4);
            let k = *c.rng.choose(&[1usize, 3]);
            let stride = *c.rng.choose(&[1usize, 2]);
            let pad = c.rng.range_usize(0, k / 2);
            let h = c.rng.range_usize(k.max(3), 9);
            let res = Resolution::new(c.rng.range_i64(2, 5) as u32, c.rng.range_i64(6, 12) as u32);
            let spec = LayerSpec::conv("p", in_ch, out_ch, k, stride, pad, h, h, res);
            let hi = flexspim::snn::quant::max_val(res.w_bits);
            let lo = flexspim::snn::quant::min_val(res.w_bits);
            let weights: Vec<i64> = (0..spec.num_weights())
                .map(|_| c.rng.range_i64(lo, hi))
                .collect();
            let theta = c.rng.range_i64(1, 8);
            let mut packed = EventConvLayer::new(spec.clone(), weights.clone(), theta);
            let mut scalar = packed.clone();
            let mut mixed = packed.clone();
            let mut dense = ConvLifLayer::new(spec, weights, theta);

            let in_dim = in_ch * h * h;
            for t in 0..6 {
                let activity = *c.rng.choose(&[0.0, 0.1, 0.4, 1.0]);
                let bits: Vec<bool> = (0..in_dim).map(|_| c.rng.chance(activity)).collect();
                let frame = SpikeList::from_dense(&bits);
                let a = packed.step(&frame);
                let b = scalar.step_scalar(&frame);
                let m = if t % 2 == 0 {
                    mixed.step(&frame)
                } else {
                    mixed.step_scalar(&frame)
                };
                let d = dense.step(&bits);
                prop_eq(a.to_dense(), d.clone(), &format!("t={t} packed spikes"))?;
                prop_eq(b.to_dense(), d.clone(), &format!("t={t} scalar spikes"))?;
                prop_eq(m.to_dense(), d, &format!("t={t} interleaved spikes"))?;
                prop_eq(packed.vmem().to_vec(), dense.v.clone(), &format!("t={t} packed vmem"))?;
                prop_eq(scalar.vmem().to_vec(), dense.v.clone(), &format!("t={t} scalar vmem"))?;
                prop_eq(mixed.vmem().to_vec(), dense.v.clone(), &format!("t={t} mixed vmem"))?;
            }
            Ok(())
        },
    );
}

/// The FC layer's bit-plane popcount kernel and its scalar column-add
/// kernel are forced (via the cutover knob) on two clones and checked
/// against the dense LIF at every activity including 100 % dense.
#[test]
fn prop_fc_forced_kernels_agree_with_dense() {
    check(
        "fc-forced-packed-vs-scalar-vs-dense",
        &Config { cases: 60, ..Default::default() },
        |c| {
            let in_dim = c.rng.range_usize(1, 90);
            let out_dim = c.rng.range_usize(1, 8);
            let w_bits = c.rng.range_i64(1, 5) as u32;
            let p_bits = c.rng.range_i64(6, 12) as u32;
            let res = Resolution::new(w_bits, p_bits);
            let hi = flexspim::snn::quant::max_val(w_bits);
            let lo = flexspim::snn::quant::min_val(w_bits);
            let weights: Vec<Vec<i64>> = (0..out_dim)
                .map(|_| (0..in_dim).map(|_| c.rng.range_i64(lo, hi)).collect())
                .collect();
            let theta = c.rng.range_i64(1, 8);
            let mut packed = EventFcLayer::new(weights.clone(), res, theta);
            packed.set_packed_cutover(0); // every non-silent frame uses popcounts
            let mut scalar = EventFcLayer::new(weights.clone(), res, theta);
            scalar.set_packed_cutover(usize::MAX); // never
            let mut dense = LifLayer::new(weights, res, theta);
            for t in 0..6 {
                let activity = *c.rng.choose(&[0.0, 0.1, 0.4, 1.0]);
                let bits: Vec<bool> = (0..in_dim).map(|_| c.rng.chance(activity)).collect();
                let frame = SpikeList::from_dense(&bits);
                let a = packed.step(&frame);
                let b = scalar.step(&frame);
                let d = dense.step(&bits);
                prop_eq(a.to_dense(), d.clone(), &format!("t={t} packed spikes"))?;
                prop_eq(b.to_dense(), d, &format!("t={t} scalar spikes"))?;
                prop_eq(packed.vmem().to_vec(), dense.v.clone(), &format!("t={t} packed vmem"))?;
                prop_eq(scalar.vmem().to_vec(), dense.v.clone(), &format!("t={t} scalar vmem"))?;
            }
            Ok(())
        },
    );
}

/// Mid-window restore equivalence on the sparse engine: checkpoint at an
/// index that is *not* a micro-window boundary (frame 3 of 8 under the
/// serve tier's 4-frame windows), restore into a fresh backend, and
/// finish. Restoring must rebuild the refire sets from the snapshot, so
/// spikes, counts, and final vmem match the uninterrupted run exactly.
#[test]
fn prop_mid_window_restore_is_bit_identical() {
    check(
        "mid-window-restore",
        &Config { cases: 10, ..Default::default() },
        |c| {
            let r = Resolution::new(4, 9);
            let net = Network::new(
                "restore",
                vec![
                    LayerSpec::conv("C1", 2, 4, 3, 2, 1, 12, 12, r),
                    LayerSpec::fc("F1", 4 * 6 * 6, 10, r),
                ],
                8,
            );
            let seed = c.rng.next_u64();
            let in_dim = 2 * 12 * 12;
            let frames: Vec<SpikeList> = (0..8)
                .map(|_| {
                    let bits: Vec<bool> =
                        (0..in_dim).map(|_| c.rng.chance(0.15)).collect();
                    SpikeList::from_dense(&bits)
                })
                .collect();

            let mut mono = NativeScnn::new(net.clone(), seed);
            let mono_out: Vec<_> = frames
                .iter()
                .map(|f| mono.step(f).map_err(|e| e.to_string()))
                .collect::<Result<_, _>>()?;

            let cut = 3; // inside the first serve micro-window pair
            let mut head = NativeScnn::new(net.clone(), seed);
            let mut out: Vec<_> = frames[..cut]
                .iter()
                .map(|f| head.step(f).map_err(|e| e.to_string()))
                .collect::<Result<_, _>>()?;
            let checkpoint = head.snapshot();
            drop(head);

            let mut tail = NativeScnn::new(net, seed);
            tail.restore(&checkpoint).map_err(|e| e.to_string())?;
            for f in &frames[cut..] {
                out.push(tail.step(f).map_err(|e| e.to_string())?);
            }

            for (i, (a, b)) in mono_out.iter().zip(&out).enumerate() {
                prop_eq(a.out_spikes.clone(), b.out_spikes.clone(), &format!("step {i}"))?;
                prop_eq(a.counts.clone(), b.counts.clone(), &format!("step {i} counts"))?;
            }
            prop_eq(mono.snapshot(), tail.snapshot(), "final vmem")
        },
    );
}

/// Live precision reconfiguration equivalence: running k frames at the
/// base resolution, switching a live backend via `set_resolutions`, and
/// finishing must be bit-identical to a *freshly built* net at the target
/// resolution (same seed) that restores the rescaled checkpoint — spikes,
/// counts, and final vmem, across random geometries, random target
/// resolutions in both directions (grow and shrink, weight and membrane),
/// and activities up to 100 %. A second checkpoint taken *after* the
/// switch, mid-window, restores into a third fresh backend and finishes
/// identically — the serve tier's snapshot/commit cycle across a tier
/// move.
#[test]
fn prop_set_resolutions_matches_fresh_build_at_target() {
    check(
        "set-resolutions-vs-fresh-build",
        &Config { cases: 12, ..Default::default() },
        |c| {
            let in_side = c.rng.range_usize(6, 10);
            let ch = c.rng.range_usize(2, 5);
            let stride = *c.rng.choose(&[1usize, 2]);
            let rand_res = |rng: &mut flexspim::util::rng::Rng| {
                Resolution::new(rng.range_i64(2, 6) as u32, rng.range_i64(6, 12) as u32)
            };
            let (b1, b2) = (rand_res(c.rng), rand_res(c.rng));
            let conv = LayerSpec::conv("C1", 2, ch, 3, stride, 1, in_side, in_side, b1);
            let (oc, oh, ow) = conv.out_shape();
            let net = Network::new(
                "reconf",
                vec![conv, LayerSpec::fc("F1", oc * oh * ow, 10, b2)],
                8,
            );
            let base: Vec<(u32, u32)> =
                net.layers.iter().map(|l| (l.res.w_bits, l.res.p_bits)).collect();
            let (t1, t2) = (rand_res(c.rng), rand_res(c.rng));
            let target = vec![(t1.w_bits, t1.p_bits), (t2.w_bits, t2.p_bits)];
            let seed = c.rng.next_u64();

            let in_dim = 2 * in_side * in_side;
            let frames: Vec<SpikeList> = (0..8)
                .map(|_| {
                    let activity = *c.rng.choose(&[0.0, 0.1, 0.4, 1.0]);
                    let bits: Vec<bool> =
                        (0..in_dim).map(|_| c.rng.chance(activity)).collect();
                    SpikeList::from_dense(&bits)
                })
                .collect();

            // Live path: k frames at base, switch, finish.
            let cut = c.rng.range_usize(1, 4);
            let mut live = NativeScnn::new(net.clone(), seed);
            for f in &frames[..cut] {
                live.step(f).map_err(|e| e.to_string())?;
            }
            let checkpoint = live.snapshot();
            live.set_resolutions(&target);
            prop_eq(
                live.snapshot(),
                checkpoint.rescaled(&base, &target),
                "switch rescales, never resets",
            )?;

            // Oracle: fresh build at the target resolution, same seed,
            // restoring the rescaled checkpoint.
            let tnet = net.with_resolutions(&[t1, t2]);
            let mut fresh = NativeScnn::new(tnet.clone(), seed);
            fresh.restore(&checkpoint.rescaled(&base, &target)).map_err(|e| e.to_string())?;

            // Finish both, checkpointing once more mid-window after the
            // switch into a third backend (the serve snapshot/restore
            // cycle across a tier move).
            let recut = cut + 2;
            let mut third: Option<NativeScnn> = None;
            for (t, f) in frames[cut..].iter().enumerate() {
                let a = live.step(f).map_err(|e| e.to_string())?;
                let b = fresh.step(f).map_err(|e| e.to_string())?;
                prop_eq(a.out_spikes.clone(), b.out_spikes.clone(), &format!("t={t} out"))?;
                prop_eq(a.counts.clone(), b.counts.clone(), &format!("t={t} counts"))?;
                if let Some(m) = third.as_mut() {
                    let d = m.step(f).map_err(|e| e.to_string())?;
                    prop_eq(a.out_spikes.clone(), d.out_spikes.clone(), &format!("t={t} 3rd"))?;
                }
                if cut + t + 1 == recut {
                    let mut m = NativeScnn::new(tnet.clone(), seed);
                    m.restore(&live.snapshot()).map_err(|e| e.to_string())?;
                    third = Some(m);
                }
            }
            prop_eq(live.snapshot(), fresh.snapshot(), "final vmem")?;
            if let Some(m) = third {
                prop_eq(live.snapshot(), m.snapshot(), "final vmem via mid-window restore")?;
            }
            Ok(())
        },
    );
}

/// Random full networks through the backend interface: the sparse engine
/// and the dense-reference oracle must agree on every step's spike list,
/// per-layer counts, the final membrane snapshot, and the prediction.
#[test]
fn prop_sparse_backend_matches_dense_reference_network() {
    check(
        "sparse-net-vs-dense-net",
        &Config { cases: 12, ..Default::default() },
        |c| {
            let in_side = c.rng.range_usize(6, 12);
            let ch = c.rng.range_usize(2, 6);
            let stride = *c.rng.choose(&[1usize, 2]);
            let r1 = Resolution::new(c.rng.range_i64(3, 5) as u32, c.rng.range_i64(8, 11) as u32);
            let r2 = Resolution::new(c.rng.range_i64(3, 6) as u32, c.rng.range_i64(8, 12) as u32);
            let conv = LayerSpec::conv("C1", 2, ch, 3, stride, 1, in_side, in_side, r1);
            let (oc, oh, ow) = conv.out_shape();
            let net = Network::new(
                "prop",
                vec![
                    conv.clone(),
                    LayerSpec::fc("F1", oc * oh * ow, 12, r2),
                    LayerSpec::fc("F2", 12, 10, r2),
                ],
                4,
            );
            let seed = c.rng.next_u64();
            let mut sparse = NativeScnn::new(net.clone(), seed);
            let mut dense = NativeScnn::new_dense_reference(net, seed);

            let in_dim = 2 * in_side * in_side;
            let mut rate_a = vec![0i64; 10];
            let mut rate_b = vec![0i64; 10];
            for t in 0..8 {
                let activity = *c.rng.choose(&[0.0, 0.05, 0.25, 1.0]);
                let bits: Vec<bool> = (0..in_dim).map(|_| c.rng.chance(activity)).collect();
                let frame = SpikeList::from_dense(&bits);
                let a = sparse.step(&frame).map_err(|e| e.to_string())?;
                let b = dense.step(&frame).map_err(|e| e.to_string())?;
                prop_eq(a.out_spikes.clone(), b.out_spikes.clone(), &format!("t={t} out"))?;
                prop_eq(a.counts, b.counts, &format!("t={t} counts"))?;
                for &ci in a.out_spikes.active() {
                    rate_a[ci as usize] += 1;
                }
                for &ci in b.out_spikes.active() {
                    rate_b[ci as usize] += 1;
                }
            }
            prop_eq(sparse.snapshot(), dense.snapshot(), "final vmem")?;
            prop_eq(rate_a, rate_b, "rate-coded prediction logits")
        },
    );
}
