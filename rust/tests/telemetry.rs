//! Integration tests for the telemetry subsystem: registry determinism
//! under concurrent updates, Chrome-trace export well-formedness, the
//! `[telemetry]` TOML round trip, and the serve tier's exporters on a
//! real deployed service (the acceptance bar: valid Prometheus text, a
//! deterministic JSON snapshot, and the autoscaler's decision trail in
//! the flight recorder).
//!
//! Metric assertions use per-service registries (no cross-test state);
//! the JSON exports are re-parsed with `util::json_lite`, the reader
//! that keeps the hand-rolled writers honest.

use flexspim::dataflow::Policy;
use flexspim::deploy::{AutoscaleSpec, DeploymentSpec};
use flexspim::serve::{gesture_traffic, StreamingService};
use flexspim::snn::{LayerSpec, Network, Resolution};
use flexspim::telemetry::{trace, FlightEvent, Registry};
use flexspim::util::json_lite::{self, Value};

const SEED: u64 = 0x7E1E;
const MACROS: usize = 4;

/// Compact SCNN over the 48×48 gesture substrate (4 micro-windows per
/// 100-ms session under the default session clock).
fn test_net() -> Network {
    let r = Resolution::new(4, 9);
    Network::new(
        "telemetry-itest",
        vec![
            LayerSpec::conv("C1", 2, 4, 3, 4, 1, 48, 48, r),
            LayerSpec::fc("F1", 4 * 12 * 12, 32, r),
            LayerSpec::fc("F2", 32, 10, Resolution::new(5, 10)),
        ],
        16,
    )
}

/// A telemetry-enabled service through the deployment API — the same
/// path `flexspim serve --config ... --telemetry` takes.
fn telemetry_service(autoscale: Option<AutoscaleSpec>) -> StreamingService {
    let mut builder = DeploymentSpec::builder("telemetry-itest")
        .network(&test_net())
        .macros(MACROS)
        .policy(Policy::HsOpt)
        .native_backend(SEED)
        .workers(2)
        .telemetry_enabled(true);
    if let Some(spec) = autoscale {
        builder = builder.autoscale(spec);
    }
    builder
        .build()
        .expect("spec is valid")
        .deploy()
        .expect("spec deploys")
        .service()
        .expect("service materializes")
}

#[test]
fn registry_snapshot_is_deterministic_under_concurrent_updates() {
    // Observation values are dyadic rationals (k / 1024) whose partial
    // sums are all exactly representable, and the total count stays far
    // below the reservoir cap — so both the retained percentile set and
    // the running sum are independent of thread interleaving, and the
    // concurrent registry must render byte-identically to a sequential
    // reference fed the same multiset.
    let concurrent = Registry::new();
    std::thread::scope(|scope| {
        for t in 0..8u64 {
            let r = &concurrent;
            scope.spawn(move || {
                let c = r.counter("t_ops_total", &[("tier", "test")]);
                let h = r.histogram("t_lat", &[]);
                for i in 0..500u64 {
                    c.inc();
                    h.observe(((t * 500 + i) % 97 + 1) as f64 / 1024.0);
                }
            });
        }
    });

    let reference = Registry::new();
    let c = reference.counter("t_ops_total", &[("tier", "test")]);
    let h = reference.histogram("t_lat", &[]);
    for n in 0..8 * 500u64 {
        c.inc();
        h.observe((n % 97 + 1) as f64 / 1024.0);
    }

    let snap = concurrent.snapshot();
    assert_eq!(snap.counter_total("t_ops_total"), 4000);
    assert_eq!(snap.histogram_count("t_lat"), 4000);
    let a = snap.to_json();
    assert_eq!(a, concurrent.snapshot().to_json(), "quiescent re-export is byte-identical");
    assert_eq!(a, reference.snapshot().to_json(), "interleaving must not change the export");
    json_lite::parse(&a).expect("snapshot JSON parses");
}

#[test]
fn chrome_trace_export_is_well_formed() {
    trace::set_tracing(true, 1);
    for _ in 0..5 {
        let _outer = trace::span("itest.outer");
        let _inner = trace::span("itest.inner");
    }
    trace::set_tracing(false, 64);

    let json = trace::chrome_trace_json();
    let doc = json_lite::parse(&json).expect("trace JSON parses");
    let events = doc.get("traceEvents").and_then(Value::as_arr).expect("traceEvents array");
    assert!(events.len() >= 10, "both span sites recorded 5 hits each");
    let mut names = std::collections::BTreeSet::new();
    for e in events {
        assert_eq!(e.get("ph").and_then(Value::as_str), Some("X"), "complete events");
        assert_eq!(e.get("cat").and_then(Value::as_str), Some("flexspim"));
        assert_eq!(e.get("pid").and_then(Value::as_num), Some(1.0));
        assert!(e.get("ts").and_then(Value::as_num).is_some_and(|v| v >= 0.0));
        assert!(e.get("dur").and_then(Value::as_num).is_some_and(|v| v >= 0.0));
        assert!(e.get("tid").and_then(Value::as_num).is_some_and(|v| v >= 1.0));
        names.insert(e.get("name").and_then(Value::as_str).expect("named").to_string());
    }
    assert!(names.contains("itest.outer") && names.contains("itest.inner"), "{names:?}");
    let ts: Vec<f64> =
        events.iter().map(|e| e.get("ts").and_then(Value::as_num).unwrap()).collect();
    assert!(ts.windows(2).all(|w| w[0] <= w[1]), "events are sorted by timestamp");
}

#[test]
fn telemetry_toml_round_trips_through_the_deployment_spec() {
    let spec = DeploymentSpec::builder("telemetry-itest")
        .network(&test_net())
        .native_backend(SEED)
        .telemetry_enabled(true)
        .tracing(32)
        .build()
        .unwrap();
    let text = spec.to_toml();
    assert!(text.contains("[telemetry]"), "non-default telemetry is emitted:\n{text}");
    let parsed = DeploymentSpec::from_toml_str(&text).unwrap();
    assert_eq!(parsed, spec);
    assert_eq!(parsed.to_toml(), text, "serialization is a fixed point");
}

#[test]
fn serve_run_exports_prometheus_and_a_deterministic_snapshot() {
    let svc = telemetry_service(None);
    let traffic = gesture_traffic(6, 21, 0);
    let report = svc.serve(&traffic, 32).expect("serve run");
    assert_eq!(report.finished_sessions, 6);
    assert_eq!(report.windows_shed, 0, "nominal load must not shed");

    // Registry counts must agree exactly with the service's own report.
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.counter_total("flexspim_serve_admitted_total"), report.windows_done);
    assert_eq!(snap.counter_total("flexspim_serve_windows_done_total"), report.windows_done);
    assert_eq!(snap.counter_total("flexspim_serve_shed_total"), 0);
    assert_eq!(
        snap.histogram_count("flexspim_serve_window_latency_seconds"),
        report.windows_done
    );
    assert_eq!(snap.histogram_count("flexspim_serve_queue_wait_seconds"), report.windows_done);

    // Prometheus text exposition carries every serve family.
    let text = svc.metrics().prometheus_text();
    for family in [
        "# TYPE flexspim_serve_admitted_total counter",
        "# TYPE flexspim_serve_windows_done_total counter",
        "# TYPE flexspim_serve_shed_total counter",
        "# TYPE flexspim_serve_target_workers gauge",
        "# TYPE flexspim_serve_queue_wait_seconds summary",
        "# TYPE flexspim_serve_window_latency_seconds summary",
    ] {
        assert!(text.contains(family), "missing `{family}` in:\n{text}");
    }
    assert!(text.contains("flexspim_serve_windows_done_total{tier=\"serve\"}"));

    // The acceptance bar: the JSON snapshot parses, and re-exporting the
    // quiescent registry is byte-identical.
    let a = snap.to_json();
    assert_eq!(a, svc.metrics().snapshot().to_json());
    let doc = json_lite::parse(&a).expect("snapshot JSON parses");
    assert!(doc.get("counters").and_then(Value::as_arr).is_some_and(|c| !c.is_empty()));
    assert!(doc.get("histograms").and_then(Value::as_arr).is_some());

    // Flight recorder: the accounting partition holds and the ring saw
    // the admissions.
    let rec = svc.recorder();
    assert_eq!(rec.recorded(), rec.len() as u64 + rec.dropped());
    assert!(!rec.is_empty());
    assert!(rec.events_of_kind("admit").len() as u64 <= report.windows_done);
}

#[test]
fn autoscaler_decisions_and_verdicts_land_in_the_flight_recorder() {
    let spec = AutoscaleSpec {
        enabled: true,
        min_workers: 1,
        max_workers: 2,
        slo_p99_ms: 1000.0,
        interval_ms: 1,
        queue_high: 1000,
        hysteresis_ticks: 2,
    };
    let svc = telemetry_service(Some(spec));
    let traffic = gesture_traffic(6, 33, 0);
    svc.serve(&traffic, 32).expect("autoscaled serve run");

    let rec = svc.recorder();
    let decisions = rec.events_of_kind("autoscale-decision");
    assert!(!decisions.is_empty(), "every decide() tick is a flight event");
    for d in &decisions {
        let FlightEvent::AutoscaleDecision { current, target, .. } = &d.event else {
            panic!("kind filter returned a non-decision event: {:?}", d.event);
        };
        assert!(*current >= 1 && *current <= 2, "inputs are live worker counts");
        assert!(*target >= 1 && *target <= 2, "the verdict stays inside [min, max]");
    }
    assert_eq!(rec.recorded(), rec.len() as u64 + rec.dropped());
    let dump = svc.recorder().dump();
    assert!(dump.contains("autoscale-decision"), "dump renders the decision trail:\n{dump}");
}
