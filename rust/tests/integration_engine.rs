//! Deterministic engine integration: a batch of synthetic gesture streams
//! through the 4-worker parallel engine must produce byte-identical
//! spikes, rates, and metrics to the sequential `Coordinator` run with the
//! same seeds. Runs everywhere — the pure-Rust `NativeScnn` backend needs
//! no artifacts and no PJRT.
//!
//! "Byte-identical" covers everything the model computes: predictions,
//! rate vectors, SOP counts, the full energy breakdown (exact f64
//! equality — both paths execute the same float operations in the same
//! order), and the per-shard CIM event ledger. Host wall-clock is the one
//! field that legitimately differs.

use flexspim::coordinator::{Coordinator, Engine, InferenceResult, RunMetrics};
use flexspim::dataflow::Policy;
use flexspim::events::{EventStream, GestureClass, GestureGenerator};
use flexspim::runtime::NativeScnn;
use flexspim::snn::{LayerSpec, Network, Resolution};
use flexspim::util::rng::Rng;

const SEED: u64 = 0xC0FFEE;
const MACROS: usize = 4;

/// A compact SCNN over the 48×48 gesture substrate: conv → conv → fc →
/// fc(10), small enough that debug-mode test runs stay fast while every
/// layer kind and the full metrics path is exercised.
fn test_net() -> Network {
    let r = Resolution::new(4, 9);
    Network::new(
        "engine-itest",
        vec![
            LayerSpec::conv("C1", 2, 4, 3, 4, 1, 48, 48, r),
            LayerSpec::conv("C2", 4, 8, 3, 2, 1, 12, 12, Resolution::new(5, 10)),
            LayerSpec::fc("F1", 8 * 6 * 6, 32, r),
            LayerSpec::fc("F2", 32, 10, Resolution::new(5, 10)),
        ],
        4,
    )
}

fn batch(n: usize, stream_seed: u64) -> Vec<(EventStream, usize)> {
    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(stream_seed);
    (0..n)
        .map(|i| {
            let label = i % 10;
            (gen.sample(GestureClass::from_label(label), &mut rng), label)
        })
        .collect()
}

fn assert_metrics_identical(a: &RunMetrics, b: &RunMetrics, ctx: &str) {
    assert_eq!(a.samples, b.samples, "{ctx}: samples");
    assert_eq!(a.correct, b.correct, "{ctx}: correct");
    assert_eq!(a.timesteps, b.timesteps, "{ctx}: timesteps");
    assert_eq!(a.sops, b.sops, "{ctx}: sops");
    assert_eq!(a.mean_sparsity, b.mean_sparsity, "{ctx}: mean_sparsity");
    assert_eq!(a.energy.compute_pj, b.energy.compute_pj, "{ctx}: compute_pj");
    assert_eq!(a.energy.movement_pj, b.energy.movement_pj, "{ctx}: movement_pj");
    assert_eq!(a.energy.spike_pj, b.energy.spike_pj, "{ctx}: spike_pj");
    assert_eq!(a.energy.load_pj, b.energy.load_pj, "{ctx}: load_pj");
    assert_eq!(a.cim, b.cim, "{ctx}: CIM ledger");
    assert_eq!(a.modeled_latency_s, b.modeled_latency_s, "{ctx}: modeled latency");
    // wallclock_s is host timing and legitimately differs.
}

fn assert_results_identical(a: &InferenceResult, b: &InferenceResult, ctx: &str) {
    assert_eq!(a.prediction, b.prediction, "{ctx}: prediction");
    assert_eq!(a.rate, b.rate, "{ctx}: rate");
    assert_metrics_identical(&a.metrics, &b.metrics, ctx);
}

#[test]
fn four_worker_engine_matches_sequential_coordinator() {
    let net = test_net();
    let data = batch(8, 21);

    // Sequential reference: the Coordinator over its own backend instance.
    let backend = Box::new(NativeScnn::new(net.clone(), SEED));
    let mut coord = Coordinator::with_backend(backend, MACROS, Policy::HsOpt).unwrap();
    let seq: Vec<InferenceResult> = data
        .iter()
        .map(|(s, l)| coord.run_sample(s, Some(*l)).unwrap())
        .collect();

    // Batched: 4 workers, each constructing its own backend from the seed.
    let engine = Engine::native(net, SEED, MACROS, Policy::HsOpt, 4);
    let parallel = engine.run_batch(&data).unwrap();
    assert_eq!(parallel.workers, 4);
    assert_eq!(parallel.results.len(), seq.len());

    for (i, (s, p)) in seq.iter().zip(&parallel.results).enumerate() {
        assert_results_identical(s, p, &format!("sample {i}"));
    }

    // Aggregates merge in submission order on both paths.
    let mut seq_total = RunMetrics::default();
    for r in &seq {
        seq_total.merge(&r.metrics);
    }
    assert_metrics_identical(&seq_total, &parallel.metrics, "batch aggregate");
    assert!(parallel.metrics.sops > 0, "batch did real work");
    assert!(parallel.metrics.cim.cim_cycles > 0, "shard ledgers charged");
}

#[test]
fn run_dataset_delegates_to_the_same_merge() {
    let net = test_net();
    let data = batch(5, 33);
    let mut coord = Coordinator::with_backend(
        Box::new(NativeScnn::new(net.clone(), SEED)),
        MACROS,
        Policy::HsOpt,
    )
    .unwrap();
    let seq_metrics = coord.run_dataset(&data).unwrap();
    let batch_metrics = Engine::native(net, SEED, MACROS, Policy::HsOpt, 4)
        .run_batch(&data)
        .unwrap()
        .metrics;
    assert_metrics_identical(&seq_metrics, &batch_metrics, "run_dataset vs engine");
}

#[test]
fn worker_count_does_not_change_results() {
    let net = test_net();
    let data = batch(6, 55);
    let runs: Vec<_> = [1usize, 2, 8]
        .iter()
        .map(|&w| {
            Engine::native(net.clone(), SEED, MACROS, Policy::HsOpt, w)
                .run_batch(&data)
                .unwrap()
        })
        .collect();
    for r in &runs[1..] {
        for (i, (a, b)) in runs[0].results.iter().zip(&r.results).enumerate() {
            assert_results_identical(a, b, &format!("workers={} sample {i}", r.workers));
        }
        assert_metrics_identical(&runs[0].metrics, &r.metrics, "aggregate across pools");
    }
}

#[test]
fn policies_change_energy_but_not_spikes() {
    // The dataflow policy moves energy between compute/movement buckets;
    // it must never perturb the computed spikes.
    let net = test_net();
    let data = batch(3, 77);
    let run = |policy| {
        Engine::native(net.clone(), SEED, 2, policy, 2)
            .run_batch(&data)
            .unwrap()
    };
    let ws = run(Policy::WsOnly);
    let hs = run(Policy::HsOpt);
    for (a, b) in ws.results.iter().zip(&hs.results) {
        assert_eq!(a.rate, b.rate, "spikes are policy-invariant");
    }
    assert!(ws.metrics.energy.total_pj() > 0.0);
    assert!(hs.metrics.energy.total_pj() > 0.0);
    // HS-opt's search space contains every WS-only configuration, so its
    // avoided operand traffic dominates (the Fig. 4b objective).
    let net = test_net();
    let ws_plan = flexspim::coordinator::SamplePlan::new(net.clone(), 2, Policy::WsOnly);
    let hs_plan = flexspim::coordinator::SamplePlan::new(net.clone(), 2, Policy::HsOpt);
    assert!(
        hs_plan.mapping.avoided_traffic_bits(&net) >= ws_plan.mapping.avoided_traffic_bits(&net),
        "HS-opt must avoid at least as much traffic as WS-only"
    );
}
