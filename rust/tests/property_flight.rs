//! Property tests for the flight recorder's ring accounting.
//!
//! The recorder's dump header and the `--dump-telemetry` report both
//! read the counters straight off the ring, so they must partition
//! *exactly* at every step: every recorded event is either retained in
//! the ring or counted as dropped — never both, never neither — and
//! the retained window is always the newest `capacity` events, in
//! order, with contiguous sequence numbers and monotone timestamps.

use flexspim::telemetry::{FlightEvent, FlightRecorder};
use flexspim::util::proptest_lite::{check, prop_assert, prop_eq, Config};

/// A random event of every kind, so the partition is kind-agnostic.
fn any_event(pick: u64, i: u64) -> FlightEvent {
    match pick {
        0 => FlightEvent::Admit { session: i % 8, seq: i },
        1 => FlightEvent::Shed { session: i % 8 },
        2 => FlightEvent::Evict { session: i % 8, evictions: 1 + i % 3, spill_bits: 512 * i },
        3 => FlightEvent::EarlyExit { session: i % 8, margin: 0.5 + i as f64 },
        4 => FlightEvent::AutoscaleDecision {
            current: 1 + (i % 4) as usize,
            p99_ms: i as f64 * 0.25,
            queued: (i % 32) as usize,
            calm_ticks: (i % 5) as u32,
            target: 1 + (i % 4) as usize,
        },
        5 => FlightEvent::ScaleUp { from: 1, to: 2 },
        6 => FlightEvent::ScaleDown { from: 2, to: 1 },
        _ => FlightEvent::Error { message: format!("e{i}") },
    }
}

#[test]
fn ring_wrap_and_drop_partition_exactly_at_every_step() {
    check("flight-partition", &Config::default(), |c| {
        let capacity = 1 + c.rng.below(1 + c.size as u64) as usize;
        let rec = FlightRecorder::new(capacity);
        prop_eq(rec.capacity(), capacity, "capacity is preserved")?;

        // Push anywhere between an empty run and several wraps.
        let total = c.rng.below(4 * capacity as u64 + 8);
        for i in 0..total {
            rec.record(any_event(c.rng.below(8), i));
            prop_eq(
                rec.recorded(),
                rec.len() as u64 + rec.dropped(),
                "retained + dropped covers every record, at every step",
            )?;
        }

        prop_eq(rec.recorded(), total, "every record is counted")?;
        prop_eq(rec.len() as u64, total.min(capacity as u64), "retained = min(total, cap)")?;
        prop_eq(rec.dropped(), total.saturating_sub(capacity as u64), "dropped = overflow")?;
        prop_eq(rec.is_empty(), total == 0, "is_empty agrees with the count")?;

        // The retained window is exactly the newest records, in order.
        let evs = rec.events();
        prop_eq(evs.len(), rec.len(), "events() returns the retained window")?;
        if let (Some(first), Some(last)) = (evs.first(), evs.last()) {
            prop_eq(first.seq, total - evs.len() as u64, "oldest retained follows the drops")?;
            prop_eq(last.seq, total - 1, "newest record is always retained")?;
        }
        prop_assert(
            evs.windows(2).all(|w| w[0].seq + 1 == w[1].seq),
            "retained sequence numbers are contiguous",
        )?;
        prop_assert(
            evs.windows(2).all(|w| w[0].ts_us <= w[1].ts_us),
            "retained timestamps are monotone",
        )?;

        // The dump header states the same partition.
        let dump = rec.dump();
        prop_assert(
            dump.starts_with(&format!(
                "flight recorder: {total} recorded, {} retained, {} dropped (cap {capacity})",
                rec.len(),
                rec.dropped()
            )),
            "dump header states the exact partition",
        )
    });
}

#[test]
fn partition_holds_under_concurrent_recording() {
    let rec = FlightRecorder::new(32);
    std::thread::scope(|scope| {
        for t in 0..4u64 {
            let rec = &rec;
            scope.spawn(move || {
                for i in 0..200u64 {
                    rec.record(FlightEvent::Admit { session: t, seq: i });
                }
            });
        }
    });
    assert_eq!(rec.recorded(), 800);
    assert_eq!(rec.len(), 32);
    assert_eq!(rec.dropped(), 768);
    assert_eq!(rec.recorded(), rec.len() as u64 + rec.dropped());
    let evs = rec.events();
    assert!(evs.windows(2).all(|w| w[0].seq < w[1].seq), "ring order follows sequence order");
    assert_eq!(evs.last().unwrap().seq, 799, "the final record is retained");
}
