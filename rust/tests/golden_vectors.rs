//! Cross-language golden-vector tests: the Python oracle (ref.py), the
//! Rust fixed-point LIF, and the bit-accurate CIM macro simulator must
//! agree on the exact integer semantics of the IF update.
//!
//! Vectors are exported by `python -m compile.aot` into
//! `artifacts/golden/`; tests skip (with a notice) if artifacts are not
//! built so `cargo test` works on a fresh checkout.

use flexspim::cim::{CimMacro, MacroConfig};
use flexspim::runtime::artifacts_dir;
use flexspim::snn::lif::LifLayer;
use flexspim::snn::Resolution;

struct FcCase {
    w_bits: u32,
    p_bits: u32,
    theta: i64,
    weights: Vec<Vec<i64>>,
    spikes: Vec<bool>,
    vmem_in: Vec<i64>,
    spk_expect: Vec<bool>,
    vmem_expect: Vec<i64>,
}

fn parse_cases(text: &str) -> Vec<FcCase> {
    let mut tokens = text.split_whitespace().map(|t| t.parse::<i64>().unwrap());
    let mut next = || tokens.next().expect("truncated golden file");
    let n_cases = next() as usize;
    let mut cases = Vec::with_capacity(n_cases);
    for _ in 0..n_cases {
        let (w_bits, p_bits, theta) = (next() as u32, next() as u32, next());
        let out_dim = next() as usize;
        let in_dim = next() as usize;
        let weights: Vec<Vec<i64>> = (0..out_dim)
            .map(|_| (0..in_dim).map(|_| next()).collect())
            .collect();
        let spikes: Vec<bool> = (0..in_dim).map(|_| next() != 0).collect();
        let vmem_in: Vec<i64> = (0..out_dim).map(|_| next()).collect();
        let spk_expect: Vec<bool> = (0..out_dim).map(|_| next() != 0).collect();
        let vmem_expect: Vec<i64> = (0..out_dim).map(|_| next()).collect();
        cases.push(FcCase {
            w_bits,
            p_bits,
            theta,
            weights,
            spikes,
            vmem_in,
            spk_expect,
            vmem_expect,
        });
    }
    cases
}

fn load_cases() -> Option<Vec<FcCase>> {
    let path = artifacts_dir().join("golden/if_step_fc.txt");
    if !path.exists() {
        eprintln!("skipping golden tests: {} missing (run make artifacts)", path.display());
        return None;
    }
    Some(parse_cases(&std::fs::read_to_string(path).unwrap()))
}

#[test]
fn lif_layer_matches_python_oracle() {
    let Some(cases) = load_cases() else { return };
    assert!(cases.len() >= 5);
    for (ci, c) in cases.iter().enumerate() {
        let res = Resolution::new(c.w_bits, c.p_bits);
        let mut layer = LifLayer::new(c.weights.clone(), res, c.theta);
        layer.v = c.vmem_in.clone();
        let spk = layer.step(&c.spikes);
        assert_eq!(spk, c.spk_expect, "case {ci}: spikes");
        assert_eq!(layer.v, c.vmem_expect, "case {ci}: vmem");
    }
}

#[test]
fn cim_macro_matches_python_oracle() {
    let Some(cases) = load_cases() else { return };
    for (ci, c) in cases.iter().enumerate() {
        let out_dim = c.weights.len();
        let in_dim = c.weights[0].len();
        // Exercise several operand shapes per case — same result expected
        // from all (shape invariance is a hardware contribution).
        for n_c in [1u32, 2, c.p_bits.min(5)] {
            let cfg = MacroConfig::flexspim(c.w_bits, c.p_bits, n_c, in_dim, out_dim);
            if cfg.validate().is_err() {
                continue;
            }
            let mut mac = CimMacro::new(cfg).unwrap();
            for (n, row) in c.weights.iter().enumerate() {
                for (j, &w) in row.iter().enumerate() {
                    mac.load_weight(n, j, w);
                }
                mac.load_vmem(n, c.vmem_in[n]);
            }
            let spk = mac.timestep(&c.spikes, c.theta);
            assert_eq!(spk, c.spk_expect, "case {ci} n_c {n_c}: spikes");
            for n in 0..out_dim {
                assert_eq!(
                    mac.peek_vmem(n),
                    c.vmem_expect[n],
                    "case {ci} n_c {n_c} neuron {n}: vmem"
                );
            }
        }
    }
}

#[test]
fn quantize_check_cross_validates() {
    // Covered in depth by runtime::weights tests; here assert the file
    // itself is consistent (modulus = 2 × half > theta).
    let path = artifacts_dir().join("golden/quantize_check.txt");
    if !path.exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let text = std::fs::read_to_string(path).unwrap();
    let mut lines = text.lines();
    let n: usize = lines.next().unwrap().trim().parse().unwrap();
    assert_eq!(n, 9);
    for line in lines {
        let v: Vec<i64> = line.split_whitespace().map(|t| t.parse().unwrap()).collect();
        assert_eq!(v[0], 2 * v[1]);
        assert!(v[2] >= 1 && v[2] < v[1]);
        assert!(v[5] <= v[6], "min <= max");
    }
}
