//! Cross-language golden-vector tests: the Python oracle (ref.py), the
//! Rust fixed-point LIF, and the bit-accurate CIM macro simulator must
//! agree on the exact integer semantics of the IF update.
//!
//! Vectors are exported by `python -m compile.aot` into
//! `artifacts/golden/`; tests skip (with a notice) if artifacts are not
//! built so `cargo test` works on a fresh checkout. Malformed golden files
//! fail with a descriptive token-level error, never a bare `unwrap` panic.

use flexspim::cim::{CimMacro, MacroConfig};
use flexspim::runtime::artifacts_dir;
use flexspim::snn::lif::LifLayer;
use flexspim::snn::Resolution;

struct FcCase {
    w_bits: u32,
    p_bits: u32,
    theta: i64,
    weights: Vec<Vec<i64>>,
    spikes: Vec<bool>,
    vmem_in: Vec<i64>,
    spk_expect: Vec<bool>,
    vmem_expect: Vec<i64>,
}

/// Whitespace-token reader that reports *where* and *why* a golden file is
/// malformed instead of unwrapping.
struct TokenReader<'a> {
    tokens: std::str::SplitWhitespace<'a>,
    consumed: usize,
}

impl<'a> TokenReader<'a> {
    fn new(text: &'a str) -> Self {
        TokenReader { tokens: text.split_whitespace(), consumed: 0 }
    }

    fn next_i64(&mut self, what: &str) -> Result<i64, String> {
        let tok = self.tokens.next().ok_or_else(|| {
            format!(
                "truncated golden file: expected {what} after {} tokens",
                self.consumed
            )
        })?;
        self.consumed += 1;
        tok.parse::<i64>().map_err(|e| {
            format!(
                "malformed golden file at token {} ({what}): {tok:?} is not an integer ({e})",
                self.consumed
            )
        })
    }

    fn next_usize(&mut self, what: &str) -> Result<usize, String> {
        let v = self.next_i64(what)?;
        usize::try_from(v).map_err(|_| {
            format!(
                "malformed golden file at token {} ({what}): {v} is not a valid count",
                self.consumed
            )
        })
    }
}

fn parse_cases(text: &str) -> Result<Vec<FcCase>, String> {
    let mut r = TokenReader::new(text);
    let n_cases = r.next_usize("case count")?;
    if n_cases > 10_000 {
        return Err(format!("implausible case count {n_cases}"));
    }
    let mut cases = Vec::with_capacity(n_cases);
    for ci in 0..n_cases {
        let w_bits = r.next_i64("w_bits")? as u32;
        let p_bits = r.next_i64("p_bits")? as u32;
        let theta = r.next_i64("theta")?;
        let out_dim = r.next_usize("out_dim")?;
        let in_dim = r.next_usize("in_dim")?;
        if !(1..=64).contains(&w_bits) || !(1..=64).contains(&p_bits) {
            return Err(format!(
                "case {ci}: resolution {w_bits}b/{p_bits}b outside supported 1..=64"
            ));
        }
        if out_dim == 0 || in_dim == 0 || out_dim > 4096 || in_dim > 4096 {
            return Err(format!("case {ci}: implausible dims {out_dim}x{in_dim}"));
        }
        let weights: Vec<Vec<i64>> = (0..out_dim)
            .map(|_| (0..in_dim).map(|_| r.next_i64("weight")).collect())
            .collect::<Result<_, _>>()?;
        let spikes: Vec<bool> = (0..in_dim)
            .map(|_| r.next_i64("spike").map(|v| v != 0))
            .collect::<Result<_, _>>()?;
        let vmem_in: Vec<i64> =
            (0..out_dim).map(|_| r.next_i64("vmem_in")).collect::<Result<_, _>>()?;
        let spk_expect: Vec<bool> = (0..out_dim)
            .map(|_| r.next_i64("expected spike").map(|v| v != 0))
            .collect::<Result<_, _>>()?;
        let vmem_expect: Vec<i64> = (0..out_dim)
            .map(|_| r.next_i64("expected vmem"))
            .collect::<Result<_, _>>()?;
        cases.push(FcCase {
            w_bits,
            p_bits,
            theta,
            weights,
            spikes,
            vmem_in,
            spk_expect,
            vmem_expect,
        });
    }
    Ok(cases)
}

fn load_cases() -> Option<Vec<FcCase>> {
    let path = artifacts_dir().join("golden/if_step_fc.txt");
    if !path.exists() {
        eprintln!("skipping golden tests: {} missing (run make artifacts)", path.display());
        return None;
    }
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: unreadable golden file: {e}", path.display()));
    match parse_cases(&text) {
        Ok(cases) => Some(cases),
        Err(msg) => panic!("{}: {msg}", path.display()),
    }
}

#[test]
fn lif_layer_matches_python_oracle() {
    let Some(cases) = load_cases() else { return };
    assert!(cases.len() >= 5);
    for (ci, c) in cases.iter().enumerate() {
        let res = Resolution::new(c.w_bits, c.p_bits);
        let mut layer = LifLayer::new(c.weights.clone(), res, c.theta);
        layer.v = c.vmem_in.clone();
        let spk = layer.step(&c.spikes);
        assert_eq!(spk, c.spk_expect, "case {ci}: spikes");
        assert_eq!(layer.v, c.vmem_expect, "case {ci}: vmem");
    }
}

#[test]
fn cim_macro_matches_python_oracle() {
    let Some(cases) = load_cases() else { return };
    for (ci, c) in cases.iter().enumerate() {
        let out_dim = c.weights.len();
        let in_dim = c.weights[0].len();
        // Exercise several operand shapes per case — same result expected
        // from all (shape invariance is a hardware contribution).
        for n_c in [1u32, 2, c.p_bits.min(5)] {
            let cfg = MacroConfig::flexspim(c.w_bits, c.p_bits, n_c, in_dim, out_dim);
            if cfg.validate().is_err() {
                continue;
            }
            let mut mac = CimMacro::new(cfg).unwrap();
            for (n, row) in c.weights.iter().enumerate() {
                for (j, &w) in row.iter().enumerate() {
                    mac.load_weight(n, j, w);
                }
                mac.load_vmem(n, c.vmem_in[n]);
            }
            let spk = mac.timestep(&c.spikes, c.theta);
            assert_eq!(spk, c.spk_expect, "case {ci} n_c {n_c}: spikes");
            for n in 0..out_dim {
                assert_eq!(
                    mac.peek_vmem(n),
                    c.vmem_expect[n],
                    "case {ci} n_c {n_c} neuron {n}: vmem"
                );
            }
        }
    }
}

#[test]
fn quantize_check_cross_validates() {
    // Covered in depth by runtime::weights tests; here assert the file
    // itself is consistent (modulus = 2 × half > theta).
    let path = artifacts_dir().join("golden/quantize_check.txt");
    if !path.exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: unreadable golden file: {e}", path.display()));
    let mut lines = text.lines();
    let header = lines
        .next()
        .unwrap_or_else(|| panic!("{}: empty golden file", path.display()));
    let n: usize = header.trim().parse().unwrap_or_else(|e| {
        panic!("{}: bad layer count {header:?}: {e}", path.display())
    });
    assert_eq!(n, 9);
    for (li, line) in lines.enumerate() {
        let v: Vec<i64> = line
            .split_whitespace()
            .map(|t| {
                t.parse().unwrap_or_else(|e| {
                    panic!("{}: layer {li}: bad token {t:?}: {e}", path.display())
                })
            })
            .collect();
        assert!(
            v.len() >= 7,
            "{}: layer {li}: expected 7 fields, got {}",
            path.display(),
            v.len()
        );
        assert_eq!(v[0], 2 * v[1]);
        assert!(v[2] >= 1 && v[2] < v[1]);
        assert!(v[5] <= v[6], "min <= max");
    }
}

#[test]
fn parse_cases_reports_descriptive_errors() {
    // Truncation names the missing field and position.
    let err = parse_cases("1 4 8 10 2").unwrap_err();
    assert!(err.contains("truncated") && err.contains("in_dim"), "{err}");
    // Non-integer tokens name the offending token.
    let err = parse_cases("1 4 8 banana 2 2").unwrap_err();
    assert!(err.contains("banana"), "{err}");
    // Implausible headers are rejected before allocating.
    let err = parse_cases("1 99 8 10 2 2").unwrap_err();
    assert!(err.contains("resolution"), "{err}");
    let err = parse_cases("-3").unwrap_err();
    assert!(err.contains("count"), "{err}");
    // A well-formed single case parses.
    let ok = parse_cases(
        "1  4 8 3  2 2  1 -1  2 -2  1 0  5 6  1 0  2 6",
    )
    .unwrap();
    assert_eq!(ok.len(), 1);
    assert_eq!(ok[0].weights, vec![vec![1, -1], vec![2, -2]]);
    assert_eq!(ok[0].spikes, vec![true, false]);
}
