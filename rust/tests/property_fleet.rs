//! Property: migrating a live session between fleet nodes is invisible
//! to the math.
//!
//! A session snapshotted mid-stream (and mid-window: the cut lands at an
//! arbitrary event index, so a partially accumulated window travels in
//! the reorder buffer), moved over the link, and restored on a freshly
//! built replica must finish **bit-identical** to the same stream served
//! on one node — accumulated class rates, membrane checkpoint, window
//! counts, tier, and prediction all equal. This holds across input
//! densities up to 100 % activity (every pixel, both polarities, every
//! frame) and across an administrative precision-tier switch performed
//! just before the move, and it is the correctness anchor the fleet
//! rebalancer (join/leave/autoscale) stands on.
//!
//! The ledger side is pinned too: each move is priced at the *exported*
//! tier's membrane widths, so a tier-1 checkpoint is cheaper on the link
//! than the tier-0 image.

use flexspim::dataflow::Policy;
use flexspim::deploy::FleetSpec;
use flexspim::events::DvsEvent;
use flexspim::fleet::Fleet;
use flexspim::serve::{tiers_for, ServiceConfig, SessionResult, SessionTraffic, StreamingService};
use flexspim::snn::{LayerSpec, Network, Resolution};
use flexspim::util::rng::Rng;

const SEED: u64 = 0xF1EE7;
const MACROS: usize = 2;
const SESSION: u64 = 11;

fn small_net() -> Network {
    let r = Resolution::new(4, 9);
    Network::new(
        "fleet-prop",
        vec![
            LayerSpec::conv("C1", 2, 4, 3, 4, 1, 48, 48, r),
            LayerSpec::fc("F1", 4 * 12 * 12, 10, Resolution::new(5, 10)),
        ],
        16,
    )
}

/// One worker, deterministic admission, and an ingest bound sized for a
/// 100 %-density stream — every run of the same action sequence executes
/// the same windows in the same order.
fn cfg() -> ServiceConfig {
    let mut cfg = ServiceConfig::nominal(1);
    cfg.deterministic_admission = true;
    cfg.session.max_pending_events = 1 << 18;
    cfg
}

/// A synthetic stream at `density` ∈ (0, 1]: per frame, each of the
/// 48×48×2 pixel/polarity sites fires with probability `density`
/// (deterministically from `seed`); at 1.0 every site fires every frame.
fn dense_traffic(density: f64, seed: u64) -> SessionTraffic {
    let session = ServiceConfig::nominal(1).session;
    let (w, h) = (session.width, session.height);
    let frames = 16u64;
    let mut rng = Rng::new(seed);
    let mut events = Vec::new();
    for f in 0..frames {
        let t_us = f * session.step_us;
        for y in 0..h {
            for x in 0..w {
                for polarity in [false, true] {
                    if density >= 1.0 || (rng.below(1_000_000) as f64) < density * 1e6 {
                        events.push(DvsEvent { t_us, x, y, polarity });
                    }
                }
            }
        }
    }
    SessionTraffic { id: SESSION, label: Some(3), end_us: frames * session.step_us, events }
}

/// Serve the whole stream on a single node: open → first half → drain →
/// (optional tier switch) → second half → close → drain.
fn run_reference(traffic: &SessionTraffic, tier_switch: Option<usize>) -> SessionResult {
    let svc = StreamingService::native(small_net(), SEED, MACROS, Policy::HsOpt, cfg());
    let half = traffic.events.len() / 2;
    svc.run_with(|s| {
        s.open_session(traffic.id, traffic.label)?;
        s.ingest(traffic.id, &traffic.events[..half])?;
        s.drain()?;
        if let Some(tier) = tier_switch {
            s.set_session_tier(traffic.id, tier)?;
        }
        s.ingest(traffic.id, &traffic.events[half..])?;
        s.close_session(traffic.id, traffic.end_us)?;
        s.drain()
    })
    .expect("reference run");
    svc.session_result(traffic.id).expect("session exists")
}

/// Same action sequence on a 2-node fleet, with the session migrated to
/// the other node between the halves (after the optional tier switch, so
/// the checkpoint crosses the link at the *new* resolution).
fn run_migrated(
    traffic: &SessionTraffic,
    tier_switch: Option<usize>,
) -> (SessionResult, u64, u64) {
    let mut fleet = Fleet::native(
        small_net(),
        SEED,
        MACROS,
        Policy::HsOpt,
        cfg(),
        FleetSpec { nodes: 2, ..FleetSpec::default() },
    )
    .expect("fleet builds");
    fleet
        .run_with(|h| {
            let from = h.open_session(traffic.id, traffic.label)?;
            let half = traffic.events.len() / 2;
            h.ingest(traffic.id, &traffic.events[..half])?;
            h.drain()?;
            if let Some(tier) = tier_switch {
                h.set_session_tier(traffic.id, tier)?;
            }
            let to = h.live_nodes().into_iter().find(|&n| n != from).expect("two nodes");
            assert!(
                h.migrate_session(traffic.id, to)?,
                "nothing is in flight after drain, so the export must succeed"
            );
            assert_eq!(h.session_node(traffic.id), Some(to));
            h.ingest(traffic.id, &traffic.events[half..])?;
            h.close_session(traffic.id, traffic.end_us)?;
            h.drain()
        })
        .expect("fleet run");
    let result = fleet.session_result(traffic.id).expect("session exists");
    (result, fleet.ledger().migrations, fleet.ledger().vmem_move_bits)
}

fn assert_bit_identical(reference: &SessionResult, migrated: &SessionResult, what: &str) {
    assert_eq!(migrated.rate, reference.rate, "{what}: accumulated class rates diverged");
    assert_eq!(migrated.state, reference.state, "{what}: membrane checkpoints diverged");
    assert_eq!(migrated.windows_done, reference.windows_done, "{what}: window counts diverged");
    assert_eq!(migrated.windows_shed, reference.windows_shed, "{what}: shed counts diverged");
    assert_eq!(migrated.tier, reference.tier, "{what}: resolution tiers diverged");
    assert_eq!(migrated.prediction, reference.prediction, "{what}: predictions diverged");
    assert_eq!(
        migrated.rolling_prediction, reference.rolling_prediction,
        "{what}: rolling predictions diverged"
    );
    assert_eq!(migrated.finished, reference.finished, "{what}: completion states diverged");
    assert!(reference.finished, "{what}: the stream must run to completion");
    assert!(reference.windows_done > 0, "{what}: the stream must execute windows");
}

#[test]
fn migration_is_bit_identical_up_to_full_activity() {
    for &density in &[0.25, 0.5, 1.0] {
        let traffic = dense_traffic(density, 0xD05E + (density * 100.0) as u64);
        let reference = run_reference(&traffic, None);
        let (migrated, migrations, moved_bits) = run_migrated(&traffic, None);
        assert_bit_identical(&reference, &migrated, &format!("density {density}"));
        assert_eq!(migrations, 1);
        // The checkpoint crossed at tier 0: every neuron at its layer's
        // deployed membrane width.
        let expected: u64 = small_net()
            .layers
            .iter()
            .map(|l| l.num_neurons() as u64 * l.res.p_bits as u64)
            .sum();
        assert_eq!(moved_bits, expected, "density {density}: tier-0 checkpoint mispriced");
    }
}

#[test]
fn migration_across_a_tier_switch_is_bit_identical() {
    let traffic = dense_traffic(1.0, 0x71E5);
    let reference = run_reference(&traffic, Some(1));
    let (migrated, migrations, moved_bits) = run_migrated(&traffic, Some(1));
    assert_bit_identical(&reference, &migrated, "tier switch");
    assert_eq!(reference.tier, 1, "the administrative retier must stick");
    assert_eq!(migrations, 1);
    // The move was priced at the *tier-1* membrane widths — migrating a
    // down-tiered session is cheaper on the link.
    let tiers = tiers_for(&small_net(), cfg().precision.max_delta);
    let tier1: u64 = small_net()
        .layers
        .iter()
        .zip(&tiers[1])
        .map(|(l, &(_, p_bits))| l.num_neurons() as u64 * p_bits as u64)
        .sum();
    let tier0: u64 = small_net()
        .layers
        .iter()
        .map(|l| l.num_neurons() as u64 * l.res.p_bits as u64)
        .sum();
    assert_eq!(moved_bits, tier1, "tier-1 checkpoint mispriced");
    assert!(tier1 < tier0, "a lower tier must shrink the checkpoint");
}
