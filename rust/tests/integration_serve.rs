//! Windowed-vs-monolithic bit-identity: a sample streamed through the
//! serve tier in ≥4 micro-windows must produce identical spikes, final
//! vmem, and prediction to the same sample run monolithically through the
//! sequential `Coordinator` — the serve subsystem's correctness anchor.
//!
//! Integers (spikes, rates, SOPs, timesteps, the CIM event ledger, the
//! vmem snapshot) are compared exactly. Float aggregates (energy,
//! sparsity, modeled latency) execute the same per-frame operations but
//! accumulate via per-window partial sums, so they are compared to within
//! 1e-12 relative — float addition is not associative across the window
//! grouping.

use flexspim::coordinator::Coordinator;
use flexspim::dataflow::Policy;
use flexspim::events::{EventStream, GestureClass, GestureGenerator};
use flexspim::runtime::NativeScnn;
use flexspim::serve::{gesture_traffic, ServiceConfig, SessionTraffic, StreamingService};
use flexspim::snn::{LayerSpec, Network, Resolution};
use flexspim::util::rng::Rng;
use flexspim::util::stats::rel_diff;

const SEED: u64 = 0x5E55;
const MACROS: usize = 4;

/// Compact SCNN over the 48×48 gesture substrate with 16 timesteps, so a
/// 100-ms sample chops into exactly 4 micro-windows of 4 frames under the
/// default session config.
fn test_net() -> Network {
    let r = Resolution::new(4, 9);
    Network::new(
        "serve-itest",
        vec![
            LayerSpec::conv("C1", 2, 4, 3, 4, 1, 48, 48, r),
            LayerSpec::fc("F1", 4 * 12 * 12, 32, r),
            LayerSpec::fc("F2", 32, 10, Resolution::new(5, 10)),
        ],
        16,
    )
}

fn coordinator() -> Coordinator {
    Coordinator::with_backend(
        Box::new(NativeScnn::new(test_net(), SEED)),
        MACROS,
        Policy::HsOpt,
    )
    .unwrap()
}

#[test]
fn streamed_windows_match_monolithic_coordinator() {
    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(99);
    let stream = gen.sample(GestureClass::AirDrums, &mut rng);
    let label = GestureClass::AirDrums.label();

    // Monolithic reference: the sequential coordinator, whole sample.
    let mut coord = coordinator();
    let mono = coord.run_sample(&stream, Some(label)).unwrap();
    let mono_state = coord.state();

    // Streamed: the same events through the serve tier, 4 windows of 4
    // frames, incremental vmem between windows.
    let svc = StreamingService::native(
        test_net(),
        SEED,
        MACROS,
        Policy::HsOpt,
        ServiceConfig::nominal(2),
    );
    let traffic = vec![SessionTraffic {
        id: 7,
        label: Some(label),
        end_us: stream.duration_us,
        events: stream.events.clone(),
    }];
    let report = svc.serve(&traffic, 50).unwrap();
    assert_eq!(report.windows_done, 4, "the acceptance bar requires >= 4 windows");
    assert_eq!(report.windows_shed, 0);
    assert_eq!(report.events_dropped, 0);
    assert_eq!(report.evictions, 0, "one session fits the nominal budget");

    let s = svc.session_result(7).unwrap();
    assert!(s.finished);
    assert_eq!(s.windows_done, 4);
    // Exact integer identity.
    assert_eq!(s.rate, mono.rate, "spikes");
    assert_eq!(s.prediction, mono.prediction, "prediction");
    assert_eq!(s.state, mono_state, "final vmem");
    assert_eq!(s.metrics.timesteps, mono.metrics.timesteps, "frames");
    assert_eq!(s.metrics.in_events, mono.metrics.in_events, "input events");
    assert_eq!(s.metrics.sops, mono.metrics.sops, "SOPs");
    assert_eq!(s.metrics.cim, mono.metrics.cim, "CIM event ledger");
    // Float aggregates: same operations, per-window partial-sum grouping.
    assert!(rel_diff(s.metrics.mean_sparsity, mono.metrics.mean_sparsity) < 1e-12);
    assert!(
        rel_diff(s.metrics.energy.total_pj(), mono.metrics.energy.total_pj()) < 1e-12
    );
    assert!(
        rel_diff(s.metrics.modeled_latency_s, mono.metrics.modeled_latency_s) < 1e-12
    );
}

#[test]
fn jittered_multi_session_streaming_matches_per_sample_coordinator() {
    // Eight concurrent sessions with 10 ms of arrival jitter over a
    // 4-worker pool: every session's streamed result must equal the
    // offline coordinator run of its (time-ordered) sample.
    let traffic = gesture_traffic(8, 42, 10_000);
    let svc = StreamingService::native(
        test_net(),
        SEED,
        MACROS,
        Policy::HsOpt,
        ServiceConfig::nominal(4),
    );
    let report = svc.serve(&traffic, 24).unwrap();
    assert_eq!(report.sessions, 8);
    assert_eq!(report.finished_sessions, 8);
    assert_eq!(report.windows_shed, 0, "nominal load must not shed");
    assert_eq!(report.events_dropped, 0, "jitter is below the reorder slack");
    assert_eq!(report.windows_done, 32);
    assert_eq!(report.latency.count(), 32);
    assert!(report.latency.p50() > 0.0);
    assert!(report.latency.p99() >= report.latency.p50());
    assert!(report.metrics.sops > 0);

    let mut coord = coordinator();
    for t in &traffic {
        // The jitter buffer must have restored time order: the reference
        // is the sorted stream.
        let stream =
            EventStream::new(48, 48, t.end_us, t.events.clone()).expect("valid traffic");
        let mono = coord.run_sample(&stream, t.label).unwrap();
        let s = svc.session_result(t.id).unwrap();
        assert_eq!(s.rate, mono.rate, "session {}: spikes", t.id);
        assert_eq!(s.prediction, mono.prediction, "session {}: prediction", t.id);
        assert_eq!(s.state, coord.state(), "session {}: final vmem", t.id);
        assert_eq!(s.metrics.sops, mono.metrics.sops, "session {}: SOPs", t.id);
        assert_eq!(s.metrics.cim, mono.metrics.cim, "session {}: ledger", t.id);
    }
}
