//! Zero-allocation steady-state guarantee of the packed hot path.
//!
//! A counting `#[global_allocator]` wraps the system allocator; after one
//! warm-up window has grown every scratch buffer to its high-water mark,
//! re-running the identical window trajectory — coordinator window loop,
//! backend step, state snapshot, and the serve tier's micro-window encoder
//! — must perform **zero** heap allocations.
//!
//! Everything lives in a single `#[test]`: libtest runs tests on parallel
//! threads sharing this process-wide counter, so the measurements must be
//! sequential within one test (the Cargo manifest also gives this file its
//! own binary for the same reason).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use flexspim::coordinator::{SampleBuffers, SamplePlan};
use flexspim::dataflow::Policy;
use flexspim::events::DvsEvent;
use flexspim::runtime::{NativeScnn, StateSnapshot, StepBackend};
use flexspim::serve::{encode_window_into, EncodeScratch, MicroWindow, SessionConfig};
use flexspim::snn::events::SpikeList;
use flexspim::snn::{LayerSpec, Network, Resolution};
use flexspim::util::rng::Rng;

/// Counts every allocating entry point; frees are not interesting (a
/// steady state that allocates and frees each window still churns).
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// Conv → FC → FC network small enough for a fast test but exercising
/// both event-layer kinds on the packed path.
fn test_net() -> Network {
    let r = Resolution::new(4, 9);
    Network::new(
        "alloc-steady",
        vec![
            LayerSpec::conv("C1", 2, 4, 3, 2, 1, 16, 16, r),
            LayerSpec::fc("F1", 4 * 8 * 8, 16, r),
            LayerSpec::fc("F2", 16, 10, Resolution::new(5, 10)),
        ],
        4,
    )
}

/// Input frames for the window: one 100 %-dense frame first (so warm-up
/// drives every buffer to its worst-case capacity), then sparse frames.
fn test_frames(dim: usize) -> Vec<SpikeList> {
    let mut frames = vec![SpikeList::from_dense(&vec![true; dim])];
    let mut rng = Rng::new(11);
    for _ in 0..7 {
        let dense: Vec<bool> = (0..dim).map(|_| rng.chance(0.15)).collect();
        frames.push(SpikeList::from_dense(&dense));
    }
    frames
}

#[test]
fn steady_state_window_is_allocation_free() {
    flexspim::telemetry::set_enabled(false);

    // --- coordinator window loop + native backend ---------------------
    let net = test_net();
    let dim = 2 * 16 * 16;
    let frames = test_frames(dim);
    let plan = SamplePlan::new(net.clone(), 2, Policy::HsOpt);
    let mut backend = NativeScnn::new(net, 3);
    let mut bufs = SampleBuffers::default();
    let mut rate = vec![0i64; 10];

    // Warm-up: the identical trajectory re-runs below, so one pass grows
    // every scratch (spike ping-pong buffers, per-layer accumulators,
    // FC word buffers, step-result counts) to its exact high-water mark.
    backend.reset();
    let warm = plan.run_frames(&mut backend, &mut bufs, &frames, &mut rate).unwrap();
    assert!(warm.in_events > 0 && warm.sops > 0, "warm-up window must do real work");

    let before = allocations();
    for _ in 0..3 {
        backend.reset();
        rate.fill(0);
        plan.run_frames(&mut backend, &mut bufs, &frames, &mut rate).unwrap();
    }
    assert_eq!(
        allocations() - before,
        0,
        "steady-state run_frames window must not touch the heap"
    );

    // --- state snapshot reuse (the serve checkpoint path) --------------
    let mut snap = StateSnapshot::default();
    backend.snapshot_into(&mut snap); // warm: sizes the per-layer vectors
    let before = allocations();
    for _ in 0..3 {
        backend.snapshot_into(&mut snap);
    }
    assert_eq!(allocations() - before, 0, "snapshot_into must reuse its buffers");
    assert_eq!(snap, backend.snapshot(), "reused snapshot matches a fresh one");

    // --- serve micro-window encoder scratch reuse ----------------------
    let cfg = SessionConfig::default_48();
    let mut rng = Rng::new(29);
    let events: Vec<DvsEvent> = (0..512)
        .map(|_| DvsEvent {
            t_us: rng.below(cfg.window_us()),
            x: rng.below(cfg.width as u64) as u16,
            y: rng.below(cfg.height as u64) as u16,
            polarity: rng.chance(0.5),
        })
        .collect();
    let window = MicroWindow { t0_us: 0, t1_us: cfg.window_us(), events, last: false };
    let mut scratch = EncodeScratch::default();
    let n = encode_window_into(&cfg, &window, &mut scratch).len(); // warm
    assert_eq!(n, cfg.frames_per_window);

    let before = allocations();
    for _ in 0..3 {
        let enc = encode_window_into(&cfg, &window, &mut scratch);
        assert_eq!(enc.len(), cfg.frames_per_window);
    }
    assert_eq!(
        allocations() - before,
        0,
        "steady-state window encoding must not touch the heap"
    );
}
