//! Runtime integration tests: the PJRT-loaded artifacts must reproduce
//! the Python-computed golden trace, and the whole coordinator stack must
//! run end to end. These are the tests that prove the three layers
//! compose.
//!
//! Each test creates its own PJRT client: the `xla` crate's client is
//! `Rc`-based (not `Send`), and cargo runs test functions on separate
//! threads. Tests skip gracefully when artifacts are missing.

use flexspim::coordinator::Coordinator;
use flexspim::dataflow::Policy;
use flexspim::events::{GestureClass, GestureGenerator};
use flexspim::runtime::{artifacts_dir, Runtime, ScnnRunner, StepBackend, StepResult};
use flexspim::snn::events::SpikeList;
use flexspim::util::rng::Rng;

fn runtime() -> Runtime {
    Runtime::cpu().expect("PJRT CPU client")
}

fn artifacts_ready() -> bool {
    let ok = artifacts_dir().join("scnn_step.hlo.txt").exists();
    if !ok {
        eprintln!("skipping: artifacts not built (run make artifacts)");
    }
    ok
}

/// The flagship cross-layer test: run the compiled scnn_step for three
/// timesteps on the golden input frame and compare the output spikes and
/// per-layer counts with what Python's Pallas path computed.
#[test]
fn scnn_step_matches_python_golden_trace() {
    if !artifacts_ready() {
        return;
    }
    let dir = artifacts_dir();
    let tpath = dir.join("golden/scnn_trace.txt");
    let trace = std::fs::read_to_string(&tpath)
        .unwrap_or_else(|e| panic!("{}: unreadable golden trace: {e}", tpath.display()));
    let mut tok = trace.split_whitespace().map(|t| {
        t.parse::<i64>()
            .unwrap_or_else(|e| panic!("{}: bad token {t:?}: {e}", tpath.display()))
    });
    let mut next = || tok.next().expect("truncated golden trace (run make artifacts)");

    let steps = next() as usize;
    // qparams 9×3 — must equal what the runner derives from weights.bin.
    let qparams: Vec<[i32; 3]> = (0..9)
        .map(|_| [next() as i32, next() as i32, next() as i32])
        .collect();
    let frame: Vec<i32> = (0..2 * 48 * 48).map(|_| next() as i32).collect();

    // The golden trace was computed with the shipped random-init weights.
    let mut runner = ScnnRunner::load_untrained(&runtime(), &dir).unwrap();
    assert_eq!(runner.qparams(), &qparams[..], "quantizer divergence");

    for step in 0..steps {
        let expect_spk: Vec<i32> = (0..10).map(|_| next() as i32).collect();
        let expect_counts: Vec<i32> = (0..9).map(|_| next() as i32).collect();
        let r = runner.step(&frame).unwrap();
        assert_eq!(r.out_spikes.to_i32(), expect_spk, "step {step}: output spikes");
        assert_eq!(r.counts, expect_counts, "step {step}: per-layer counts");
    }
}

#[test]
fn runner_resets_and_is_deterministic() {
    if !artifacts_ready() {
        return;
    }
    let mut runner = ScnnRunner::load(&runtime(), &artifacts_dir()).unwrap();
    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(5);
    let stream = gen.sample(GestureClass::RightWave, &mut rng);
    let frames: Vec<Vec<i32>> = flexspim::events::encode_frames(&stream, 4)
        .iter()
        .map(|f| f.as_input_vector().iter().map(|&b| b as i32).collect())
        .collect();
    let a = runner.infer(&frames).unwrap();
    let b = runner.infer(&frames).unwrap();
    assert_eq!(a, b, "infer must reset state and be deterministic");
}

#[test]
fn resolution_reconfiguration_changes_behaviour_not_validity() {
    if !artifacts_ready() {
        return;
    }
    let mut runner = ScnnRunner::load(&runtime(), &artifacts_dir()).unwrap();
    let frame: Vec<i32> = (0..4608).map(|i| ((i * 37) % 13 == 0) as i32).collect();
    let base = runner.step(&frame).unwrap();
    // Reconfigure to a coarser resolution at runtime (chip flexibility).
    runner.set_resolutions(&[(3, 8); 9]);
    let coarse = runner.step(&frame).unwrap();
    assert_eq!(base.counts.len(), coarse.counts.len());
    // Spike counts stay within layer sizes.
    let net = runner.network().clone();
    for (c, l) in coarse.counts.iter().zip(&net.layers) {
        assert!(*c >= 0 && (*c as usize) <= l.num_neurons());
    }
}

#[test]
fn per_layer_artifacts_compile_and_run() {
    if !artifacts_ready() {
        return;
    }
    let dir = artifacts_dir();
    // Smallest layer: FC3 (128 -> 10), fixed resolution 7b/12b.
    let exe = runtime().load_hlo(&dir.join("layer_FC3.hlo.txt")).unwrap();
    let w: Vec<i32> = (0..10 * 128).map(|i| (i % 7) as i32 - 3).collect();
    let s: Vec<i32> = (0..128).map(|i| (i % 5 == 0) as i32).collect();
    let v = vec![0i32; 10];
    let out = exe
        .run(&[
            flexspim::runtime::client::lit_i32(&w, &[10, 128]).unwrap(),
            flexspim::runtime::client::lit_i32(&s, &[128]).unwrap(),
            flexspim::runtime::client::lit_i32(&v, &[10]).unwrap(),
        ])
        .unwrap();
    assert_eq!(out.len(), 2, "spikes + vmem");
    let spk = flexspim::runtime::client::to_vec_i32(&out[0]).unwrap();
    let vm = flexspim::runtime::client::to_vec_i32(&out[1]).unwrap();
    assert_eq!(spk.len(), 10);
    assert_eq!(vm.len(), 10);
    // Cross-check against the Rust golden LIF (theta from aot.py:
    // max_val(12)/2 = 1023).
    let weights: Vec<Vec<i64>> = (0..10)
        .map(|o| (0..128).map(|i| w[o * 128 + i] as i64).collect())
        .collect();
    let mut layer = flexspim::snn::lif::LifLayer::new(
        weights,
        flexspim::snn::Resolution::new(7, 12),
        1023,
    );
    let spikes_b: Vec<bool> = s.iter().map(|&x| x != 0).collect();
    let expect = layer.step(&spikes_b);
    let got: Vec<bool> = spk.iter().map(|&x| x != 0).collect();
    assert_eq!(got, expect, "layer artifact vs Rust LIF");
    assert_eq!(vm.iter().map(|&x| x as i64).collect::<Vec<_>>(), layer.v);
}

/// Snapshot/restore round-trip over the *trait* (SpikeList) interface on
/// the PJRT-shim backend: run half a sample, checkpoint, restore into a
/// fresh runner, finish — outputs and final state must equal the
/// monolithic run. The native-backend twin of this check lives in
/// `runtime::native` (`snapshot_restore_resumes_bit_identically`).
#[test]
fn pjrt_snapshot_restore_resumes_bit_identically() {
    if !artifacts_ready() {
        return;
    }
    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(23);
    let stream = gen.sample(GestureClass::LeftWave, &mut rng);
    let frames: Vec<SpikeList> = flexspim::events::encode_frames(&stream, 8)
        .iter()
        .map(|f| f.to_spike_list())
        .collect();

    let mut mono = ScnnRunner::load(&runtime(), &artifacts_dir()).unwrap();
    let mono_out: Vec<StepResult> = frames
        .iter()
        .map(|f| StepBackend::step(&mut mono, f).unwrap())
        .collect();
    let mono_state = StepBackend::snapshot(&mono);

    let mut first = ScnnRunner::load(&runtime(), &artifacts_dir()).unwrap();
    let half = frames.len() / 2;
    let mut windowed: Vec<StepResult> = frames[..half]
        .iter()
        .map(|f| StepBackend::step(&mut first, f).unwrap())
        .collect();
    let checkpoint = StepBackend::snapshot(&first);
    drop(first);

    let mut second = ScnnRunner::load(&runtime(), &artifacts_dir()).unwrap();
    StepBackend::restore(&mut second, &checkpoint).unwrap();
    windowed.extend(
        frames[half..]
            .iter()
            .map(|f| StepBackend::step(&mut second, f).unwrap()),
    );

    for (i, (a, b)) in mono_out.iter().zip(&windowed).enumerate() {
        assert_eq!(a.out_spikes, b.out_spikes, "step {i}: spikes");
        assert_eq!(a.counts, b.counts, "step {i}: counts");
    }
    assert_eq!(mono_state, StepBackend::snapshot(&second), "final vmem");
}

#[test]
fn coordinator_end_to_end_sample() {
    if !artifacts_ready() {
        return;
    }
    let runner = ScnnRunner::load(&runtime(), &artifacts_dir()).unwrap();
    let mut coord = Coordinator::with_runner(runner, 16, Policy::HsOpt).unwrap();
    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(11);
    let stream = gen.sample(GestureClass::ArmRoll, &mut rng);
    let r = coord.run_sample(&stream, Some(7)).unwrap();
    assert!(r.prediction < 10);
    let m = &r.metrics;
    assert_eq!(m.timesteps, 16);
    assert!(m.sops > 0, "SOPs must be counted");
    assert!(m.energy.total_pj() > 0.0);
    assert!(m.mean_sparsity > 0.80 && m.mean_sparsity < 1.0);
    assert!(m.modeled_latency_s > 0.0);
}

#[test]
fn coordinator_policy_changes_energy() {
    if !artifacts_ready() {
        return;
    }
    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(13);
    let stream = gen.sample(GestureClass::HandClap, &mut rng);

    let run = |policy| {
        let runner = ScnnRunner::load(&runtime(), &artifacts_dir()).unwrap();
        let mut coord = Coordinator::with_runner(runner, 2, policy).unwrap();
        coord.run_sample(&stream, None).unwrap().metrics.energy.total_pj()
    };
    let ws = run(Policy::WsOnly);
    let hs = run(Policy::HsOpt);
    assert!(
        hs < ws,
        "HS must save energy vs WS-only at 2 macros: {hs:.1} vs {ws:.1} pJ"
    );
}
