//! Property-test net over the CIM bit-level semantics.
//!
//! The safety net for the accumulate hot-loop rewrite: for random operand
//! resolutions (`w_bits`/`p_bits` in 1..=16), random operand shapes
//! (`N_C`), random macro geometries, and random spike/mask patterns, the
//! bit-level macro simulator must agree with a naive `i64` MAC +
//! integrate-and-fire oracle — values, spikes, and masking semantics.

use flexspim::cim::{CimMacro, MacroConfig};
use flexspim::snn::quant::{max_val, min_val, wrap};
use flexspim::util::proptest_lite::{check, prop_eq, Config};

/// Naive integer oracle of one macro: plain wrapped MAC + threshold.
struct Oracle {
    w: Vec<Vec<i64>>,
    v: Vec<i64>,
    p_bits: u32,
}

impl Oracle {
    fn accumulate(&mut self, synapse: usize, mask: Option<&[bool]>) {
        for n in 0..self.v.len() {
            if mask.map_or(true, |m| m[n]) {
                self.v[n] = wrap(self.v[n] + self.w[n][synapse], self.p_bits);
            }
        }
    }

    fn fire(&mut self, threshold: i64) -> Vec<bool> {
        let t = wrap(threshold, self.p_bits);
        self.v
            .iter_mut()
            .map(|v| {
                if *v >= t {
                    *v = wrap(*v - t, self.p_bits);
                    true
                } else {
                    false
                }
            })
            .collect()
    }

    fn timestep(&mut self, spikes: &[bool], threshold: i64) -> Vec<bool> {
        for (j, &s) in spikes.iter().enumerate() {
            if s {
                self.accumulate(j, None);
            }
        }
        self.fire(threshold)
    }
}

/// Draw a random macro + matching oracle with loaded weights and state.
fn random_pair(
    c: &mut flexspim::util::proptest_lite::Case,
) -> Option<(CimMacro, Oracle, MacroConfig)> {
    let w_bits = c.rng.range_i64(1, 16) as u32;
    let p_bits = c.rng.range_i64(1, 16) as u32;
    let n_c = c.rng.range_i64(1, p_bits as i64) as u32;
    let neurons = c.rng.range_usize(1, 8);
    let fan_in = c.rng.range_usize(1, 6);
    let cfg = MacroConfig::flexspim(w_bits, p_bits, n_c, fan_in, neurons);
    if cfg.validate().is_err() {
        return None;
    }
    let mut mac = CimMacro::new(cfg).unwrap();
    let mut w = vec![vec![0i64; fan_in]; neurons];
    let mut v = vec![0i64; neurons];
    for n in 0..neurons {
        for (j, slot) in w[n].iter_mut().enumerate() {
            *slot = c.rng.range_i64(min_val(w_bits), max_val(w_bits));
            mac.load_weight(n, j, *slot);
        }
        v[n] = c.rng.range_i64(min_val(p_bits), max_val(p_bits));
        mac.load_vmem(n, v[n]);
    }
    Some((mac, Oracle { w, v, p_bits }, cfg))
}

#[test]
fn prop_timestep_equals_mac_if_oracle() {
    check(
        "cim-timestep-vs-oracle",
        &Config { cases: 150, ..Default::default() },
        |c| {
            let Some((mut mac, mut oracle, cfg)) = random_pair(c) else {
                return Ok(());
            };
            for t in 0..3 {
                let spikes: Vec<bool> =
                    (0..cfg.fan_in).map(|_| c.rng.chance(0.5)).collect();
                let theta = c.rng.range_i64(1, max_val(cfg.p_bits).max(1));
                let got = mac.timestep(&spikes, theta);
                let expect = oracle.timestep(&spikes, theta);
                prop_eq(
                    got,
                    expect,
                    &format!(
                        "t={t} spikes (w={} p={} n_c={} fan_in={})",
                        cfg.w_bits, cfg.p_bits, cfg.n_c, cfg.fan_in
                    ),
                )?;
                for n in 0..cfg.neurons {
                    prop_eq(
                        mac.peek_vmem(n),
                        oracle.v[n],
                        &format!("t={t} vmem neuron {n} (w={} p={})", cfg.w_bits, cfg.p_bits),
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_masked_accumulate_equals_oracle() {
    check(
        "cim-masked-accumulate-vs-oracle",
        &Config { cases: 120, ..Default::default() },
        |c| {
            let Some((mut mac, mut oracle, cfg)) = random_pair(c) else {
                return Ok(());
            };
            for _ in 0..5 {
                let j = c.rng.range_usize(0, cfg.fan_in - 1);
                let mask: Option<Vec<bool>> = if c.rng.chance(0.5) {
                    Some((0..cfg.neurons).map(|_| c.rng.chance(0.5)).collect())
                } else {
                    None
                };
                mac.cim_accumulate(j, mask.as_deref());
                oracle.accumulate(j, mask.as_deref());
            }
            for n in 0..cfg.neurons {
                prop_eq(
                    mac.peek_vmem(n),
                    oracle.v[n],
                    &format!(
                        "vmem neuron {n} (w={} p={} n_c={})",
                        cfg.w_bits, cfg.p_bits, cfg.n_c
                    ),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fire_thresholds_match_oracle_including_negative() {
    // Negative and extreme thresholds exercise the signed MSB-first
    // comparator paths; the oracle compares against the wrapped threshold,
    // exactly as the broadcast threshold bits do in silicon.
    check(
        "cim-fire-vs-oracle",
        &Config { cases: 120, ..Default::default() },
        |c| {
            let Some((mut mac, mut oracle, cfg)) = random_pair(c) else {
                return Ok(());
            };
            for _ in 0..3 {
                let theta = c.rng.range_i64(min_val(cfg.p_bits), max_val(cfg.p_bits));
                let got = mac.cim_fire(theta);
                let expect = oracle.fire(theta);
                prop_eq(got, expect, &format!("theta={theta} p={}", cfg.p_bits))?;
                for n in 0..cfg.neurons {
                    prop_eq(
                        mac.peek_vmem(n),
                        oracle.v[n],
                        &format!("post-fire vmem {n} theta={theta} p={}", cfg.p_bits),
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_counters_are_data_independent_for_accumulate() {
    // The engine's shard calibration relies on accumulate (and the fire
    // compare pass) having data-independent ledgers: same config + same
    // mask, different stored values, identical counter deltas.
    check(
        "cim-accumulate-ledger-config-pure",
        &Config { cases: 80, ..Default::default() },
        |c| {
            let w_bits = c.rng.range_i64(1, 12) as u32;
            let p_bits = c.rng.range_i64(1, 16) as u32;
            let n_c = c.rng.range_i64(1, p_bits as i64) as u32;
            let neurons = c.rng.range_usize(1, 6);
            let cfg = MacroConfig::flexspim(w_bits, p_bits, n_c, 2, neurons);
            if cfg.validate().is_err() {
                return Ok(());
            }
            let mask: Option<Vec<bool>> = if c.rng.chance(0.5) {
                Some((0..neurons).map(|_| c.rng.chance(0.5)).collect())
            } else {
                None
            };
            let mut deltas = Vec::new();
            for _ in 0..2 {
                let mut mac = CimMacro::new(cfg).unwrap();
                for n in 0..neurons {
                    for j in 0..2 {
                        mac.load_weight(n, j, c.rng.range_i64(min_val(w_bits), max_val(w_bits)));
                    }
                    mac.load_vmem(n, c.rng.range_i64(min_val(p_bits), max_val(p_bits)));
                }
                let before = *mac.counters();
                mac.cim_accumulate(0, mask.as_deref());
                deltas.push(mac.counters().delta(&before));
            }
            prop_eq(
                deltas[0],
                deltas[1],
                &format!("accumulate ledger (w={w_bits} p={p_bits} n_c={n_c})"),
            )
        },
    );
}
