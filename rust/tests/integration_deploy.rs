//! Deployment-API integration: TOML round-trips, builder-vs-TOML
//! equivalence, invalid-spec rejection, shipped-config/preset pinning,
//! and end-to-end bit-identity of a preset-loaded SCNN against the
//! hardcoded `scnn_dvs_gesture()` network.

use std::path::Path;

use flexspim::coordinator::Coordinator;
use flexspim::dataflow::Policy;
use flexspim::deploy::{presets, DeploymentSpec};
use flexspim::events::{GestureClass, GestureGenerator};
use flexspim::runtime::NativeScnn;
use flexspim::snn::network::scnn_dvs_gesture;
use flexspim::snn::Resolution;
use flexspim::util::rng::Rng;

const SEED: u64 = 42;

/// The builder spec used for the equivalence tests.
fn builder_spec() -> DeploymentSpec {
    DeploymentSpec::builder("equiv")
        .timesteps(8)
        .conv("C1", 2, 4, 3, 4, 1, 48, 48, Resolution::new(4, 9))
        .fc("F1", 4 * 12 * 12, 16, Resolution::new(4, 9))
        .fc("F2", 16, 10, Resolution::new(5, 10))
        .macros(4)
        .policy(Policy::HsMin)
        .native_backend(7)
        .workers(2)
        .resident_budget_kb(32)
        .deterministic_admission(true)
        .early_exit(0.5, 3)
        .build()
        .expect("valid spec")
}

/// The same deployment written by hand as TOML.
const EQUIV_TOML: &str = r#"
[network]
name = "equiv"
timesteps = 8

[layer.1]
type = "conv"
name = "C1"
in_ch = 2
out_ch = 4
kernel = 3
stride = 4
pad = 1
in_h = 48
in_w = 48
w_bits = 4
p_bits = 9

[layer.2]
type = "fc"
name = "F1"
in_dim = 576
out_dim = 16
w_bits = 4
p_bits = 9

[layer.3]
type = "fc"
name = "F2"
in_dim = 16
out_dim = 10
w_bits = 5
p_bits = 10

[substrate]
macros = 4
policy = "hs-min"

[backend]
kind = "native"
seed = 7

[serve]
workers = 2
budget_kb = 32
deterministic = true
exit_margin = 0.5
exit_min_windows = 3
"#;

#[test]
fn toml_round_trip_is_lossless() {
    let spec = builder_spec();
    let text = spec.to_toml();
    let parsed = DeploymentSpec::from_toml_str(&text).expect("serialized spec parses");
    assert_eq!(parsed, spec, "TOML -> spec -> TOML must be lossless");
    assert_eq!(parsed.to_toml(), text, "serialization is a fixed point");
}

#[test]
fn builder_and_toml_specs_are_identical() {
    let from_builder = builder_spec();
    let from_toml = DeploymentSpec::from_toml_str(EQUIV_TOML).expect("hand TOML parses");
    assert_eq!(from_toml, from_builder);
}

#[test]
fn builder_and_toml_deployments_run_identically() {
    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(9);
    let stream = gen.sample(GestureClass::RightCw, &mut rng);

    let run = |spec: DeploymentSpec| {
        let mut coord = spec.deploy().unwrap().coordinator().unwrap();
        coord.run_sample(&stream, Some(3)).unwrap()
    };
    let a = run(builder_spec());
    let b = run(DeploymentSpec::from_toml_str(EQUIV_TOML).unwrap());
    assert_eq!(a.prediction, b.prediction);
    assert_eq!(a.rate, b.rate);
    assert_eq!(a.metrics.sops, b.metrics.sops);
    assert_eq!(a.metrics.cim, b.metrics.cim);
    assert_eq!(a.metrics.energy.total_pj(), b.metrics.energy.total_pj());
}

#[test]
fn shipped_configs_match_their_presets() {
    for (file, preset) in [
        ("configs/scnn_dvs_gesture.toml", presets::SCNN_DVS_GESTURE),
        ("configs/serve_demo.toml", presets::SERVE_DEMO),
        ("configs/fleet_demo.toml", presets::FLEET_DEMO),
    ] {
        let from_file = DeploymentSpec::load(Path::new(file))
            .unwrap_or_else(|e| panic!("{file}: {e}"));
        let from_preset = presets::spec(preset).expect("known preset");
        assert_eq!(from_file, from_preset, "{file} drifted from preset '{preset}'");
    }
}

#[test]
fn invalid_specs_are_rejected_with_rich_errors() {
    // Shape-chain mismatch.
    let err = DeploymentSpec::builder("bad")
        .fc("a", 10, 20, Resolution::new(4, 8))
        .fc("b", 21, 5, Resolution::new(4, 8))
        .build()
        .unwrap_err();
    assert!(format!("{err}").contains("shape chain"), "got: {err}");

    // Bad policy (TOML).
    let err = DeploymentSpec::from_toml_str(
        "[network]\npreset = \"serve-demo\"\n[substrate]\npolicy = \"bogus\"\n",
    )
    .unwrap_err();
    assert!(format!("{err}").contains("unknown policy"), "got: {err}");

    // Zero workers (TOML).
    let err = DeploymentSpec::from_toml_str(
        "[network]\npreset = \"serve-demo\"\n[serve]\nworkers = 0\n",
    )
    .unwrap_err();
    assert!(format!("{err}").contains("workers"), "got: {err}");

    // Unknown keys never pass silently.
    let err = DeploymentSpec::from_toml_str(
        "[network]\npreset = \"serve-demo\"\nmacros = 4\n",
    )
    .unwrap_err();
    assert!(format!("{err}").contains("network.macros"), "got: {err}");
}

#[test]
fn preset_loaded_scnn_matches_hardcoded_network_end_to_end() {
    // The shipped config -> Deployment path and the historical
    // hand-constructed path must execute bit-identically: same spikes,
    // same prediction, same SOPs and CIM ledger, same final state.
    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(5);
    let stream = gen.sample(GestureClass::HandClap, &mut rng);

    let deployment = DeploymentSpec::load(Path::new("configs/scnn_dvs_gesture.toml"))
        .expect("shipped config loads")
        .deploy()
        .expect("deploys");
    let mut from_config = deployment.coordinator().expect("coordinator");

    let backend = Box::new(NativeScnn::new(scnn_dvs_gesture(), SEED));
    let mut reference = Coordinator::with_backend(backend, 16, Policy::HsOpt).unwrap();

    let a = from_config.run_sample(&stream, Some(0)).unwrap();
    let b = reference.run_sample(&stream, Some(0)).unwrap();
    assert_eq!(a.prediction, b.prediction);
    assert_eq!(a.rate, b.rate, "classifier spike counts must be bit-identical");
    assert_eq!(a.metrics.sops, b.metrics.sops);
    assert_eq!(a.metrics.in_events, b.metrics.in_events);
    assert_eq!(a.metrics.cim, b.metrics.cim, "shard ledger must agree");
    assert_eq!(a.metrics.energy.total_pj(), b.metrics.energy.total_pj());
    assert_eq!(from_config.state(), reference.state(), "final vmem");
}

#[test]
fn toml_topology_serves_without_recompiling() {
    // The acceptance scenario: a custom topology defined purely as data
    // drives the streaming tier.
    let toml = r#"
        [network]
        name = "custom-serve"
        timesteps = 16

        [layer.1]
        type = "conv"
        in_ch = 2
        out_ch = 4
        kernel = 3
        stride = 4
        pad = 1
        in_h = 48
        in_w = 48
        w_bits = 4
        p_bits = 9

        [layer.2]
        type = "fc"
        in_dim = 576
        out_dim = 10
        w_bits = 5
        p_bits = 10

        [substrate]
        macros = 2

        [serve]
        workers = 2
    "#;
    let deployment = DeploymentSpec::from_toml_str(toml)
        .expect("custom TOML parses")
        .deploy()
        .expect("deploys");
    let svc = deployment.service().expect("service materializes");
    let traffic = flexspim::serve::gesture_traffic(4, 13, 0);
    let report = svc.serve(&traffic, 32).expect("serve run");
    assert_eq!(report.sessions, 4);
    assert_eq!(report.finished_sessions, 4);
    assert!(report.windows_done > 0);
    for id in 0..4u64 {
        let s = svc.session_result(id).expect("session served");
        assert!(s.prediction < 10);
        assert!(s.finished);
    }
}

#[test]
fn shipped_fleet_config_materializes_and_serves() {
    // The fleet acceptance path as data: the shipped config boots a
    // 4-node fleet, boot weight broadcasts land on the ledger, and a
    // small drive finishes sessions across the replicas.
    let dep = DeploymentSpec::load(Path::new("configs/fleet_demo.toml"))
        .expect("shipped fleet config loads")
        .deploy()
        .expect("deploys");
    assert_eq!(dep.spec().fleet.nodes, 4);
    assert_eq!(dep.spec().fleet.max_nodes, 8);
    let mut fleet = dep.fleet().expect("fleet materializes");
    assert_eq!(fleet.live_nodes(), vec![0, 1, 2, 3]);
    assert_eq!(fleet.nodes().len(), 8, "autoscale standbys are pre-spawned");
    assert_eq!(
        fleet.ledger().weight_push_bits,
        4 * dep.network().total_weight_bits()
    );
    let traffic = flexspim::serve::gesture_traffic(6, 23, 0);
    let cfg = flexspim::serve::LoadConfig {
        arrivals: flexspim::serve::ArrivalProcess::Poisson { rate_per_sec: 300.0 },
        time_scale: 40.0,
        chunk: 512,
        seed: 11,
    };
    let report = fleet.drive_open_loop(&traffic, &cfg).expect("fleet drive");
    assert_eq!(report.fleet.sessions, 6);
    assert_eq!(report.fleet.finished_sessions, 6);
    assert!(report.fleet.windows_done > 0);
}

#[test]
fn pjrt_backend_drives_engine_and_service_end_to_end() {
    // The carried PJRT deployment path: a spec selecting the pjrt backend
    // must materialize the batched engine and the streaming service and
    // run them end-to-end over the compiled artifacts. Gated on the AOT
    // artifacts being built (`make artifacts`); skips cleanly otherwise.
    if !flexspim::runtime::artifacts_dir().join("scnn_step.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built (run make artifacts)");
        return;
    }
    let mut spec = presets::spec(presets::SCNN_DVS_GESTURE).expect("known preset");
    spec.backend = flexspim::deploy::BackendSpec::Pjrt { artifacts: None };
    spec.serve.workers = 1; // the PJRT runner loads per worker thread
    let deployment = spec.deploy().expect("pjrt spec deploys");

    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(29);
    let data: Vec<_> = (0..2)
        .map(|i| (gen.sample(GestureClass::ALL[i % 10], &mut rng), i % 10))
        .collect();
    let batch = deployment.engine().expect("engine").run_batch(&data).expect("batch");
    assert_eq!(batch.results.len(), 2);
    assert!(batch.metrics.sops > 0);

    let svc = deployment.service().expect("service");
    let traffic = flexspim::serve::gesture_traffic(2, 31, 0);
    let report = svc.serve(&traffic, 32).expect("serve run");
    assert_eq!(report.finished_sessions, 2);
    assert!(report.windows_done > 0);
    for id in 0..2u64 {
        let s = svc.session_result(id).expect("session served");
        assert!(s.prediction < 10);
        assert!(s.finished);
    }
}

#[test]
fn one_spec_drives_all_three_tiers_consistently() {
    // Coordinator, engine, and service materialized from one spec agree
    // on what a sample computes.
    let spec = DeploymentSpec::from_toml_str(EQUIV_TOML).unwrap();
    let deployment = spec.deploy().unwrap();

    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(17);
    let data: Vec<_> = (0..4)
        .map(|i| (gen.sample(GestureClass::ALL[i % 10], &mut rng), i % 10))
        .collect();

    let mut coord = deployment.coordinator().unwrap();
    let seq = coord.run_dataset(&data).unwrap();
    let batch = deployment.engine().unwrap().run_batch(&data).unwrap();
    assert_eq!(seq.sops, batch.metrics.sops);
    assert_eq!(seq.cim, batch.metrics.cim);
    assert_eq!(seq.correct, batch.metrics.correct);

    // The service executes the same network (window-split equivalence is
    // pinned in integration_serve.rs; here: it materializes and serves).
    let svc = deployment.service().unwrap();
    assert_eq!(svc.plan().net.name, "equiv");
    assert_eq!(svc.config().workers, 2);
    assert!(svc.config().deterministic_admission);
}
