//! Golden-vector regression pins for the fig6 resolution sweep.
//!
//! The fig6 module's own tests assert *bands* (reduction within the
//! paper's neighborhood, monotone shrinking). These tests pin the exact
//! outputs — every byte of the size accounting is pure arithmetic over
//! the reference topology, so any drift in layer shapes, resolution
//! choices, the constrained-menu rule, or the sweep's floor clamps shows
//! up as a literal mismatch here, not as a silent re-baseline. The
//! literals were derived by hand from the layer table (weights × w_bits
//! summed per layer) and cross-check `Network::total_weight_bits`.

use flexspim::coordinator::Coordinator;
use flexspim::dataflow::Policy;
use flexspim::events::{GestureClass, GestureGenerator};
use flexspim::figures::fig6;
use flexspim::runtime::NativeScnn;
use flexspim::snn::network::scnn_dvs_gesture;
use flexspim::snn::{LayerSpec, Network, Resolution};
use flexspim::util::rng::Rng;

/// Fig. 6(a): the flexible-vs-constrained footprints, bit-exact.
#[test]
fn size_study_pins_exact_footprints() {
    let (flex, fixed) = fig6::size_study();
    // FlexSpIM per-layer choice: 4/5/5/6/6/7-bit convs, 5/5/7-bit FCs.
    assert_eq!(flex.model_bits, 5_113_152);
    assert_eq!(flex.conv_bits, 516_672);
    // [4]-constrained menu (w <= 4 -> 4, else 8): only L1 stays at 4 bit.
    assert_eq!(fixed.model_bits, 7_993_952);
    assert_eq!(fixed.conv_bits, 643_680);
    let r = fig6::footprint_reduction();
    let expect = 1.0 - 5_113_152.0 / 7_993_952.0;
    assert!((r - expect).abs() < 1e-15, "reduction {r} != {expect}");
    assert!((r - 0.360_372_4).abs() < 1e-6, "headline ~36 %: {r}");
}

/// Fig. 6(b): the uniform down-scaling grid, per-tier, bit-exact — both
/// the total and the conv-only footprints, plus the δ3 per-layer
/// resolutions where the 2-bit weight floor engages.
#[test]
fn scaling_sweep_pins_exact_grid() {
    let configs = fig6::scaling_configs();
    assert_eq!(configs.len(), 4);
    let expected_total = [5_113_152u64, 4_113_800, 3_114_448, 2_115_312];
    let expected_conv = [516_672u64, 436_104, 355_536, 275_184];
    for (i, (label, res)) in configs.iter().enumerate() {
        assert_eq!(label, &format!("base-{i}b"));
        let net = scnn_dvs_gesture().with_resolutions(
            &res.iter().map(|&(w, p)| Resolution::new(w, p)).collect::<Vec<_>>(),
        );
        assert_eq!(net.total_weight_bits(), expected_total[i], "tier {i} total");
        assert_eq!(net.conv_weight_bits(), expected_conv[i], "tier {i} conv");
    }
    // δ3 engages the 2-bit weight floor on L1/L2/L3/FC1/FC2; membrane
    // bits stay clear of their 4-bit floor throughout.
    assert_eq!(
        configs[3].1,
        vec![(2, 6), (2, 7), (2, 7), (3, 8), (3, 8), (4, 9), (2, 7), (2, 7), (4, 9)]
    );
}

fn sweep_coordinator(seed: u64) -> Coordinator {
    let net = Network::new(
        "fig6-sweep",
        vec![
            LayerSpec::conv("C1", 2, 4, 3, 4, 1, 48, 48, Resolution::new(4, 9)),
            LayerSpec::fc("F1", 4 * 12 * 12, 10, Resolution::new(5, 10)),
        ],
        8,
    );
    Coordinator::with_backend(Box::new(NativeScnn::new(net, seed)), 4, Policy::HsOpt).unwrap()
}

/// The accuracy sweep itself is a deterministic function of (seed, data):
/// two independently built coordinators produce bit-identical points, a
/// repeated sweep on one live coordinator reproduces itself exactly
/// (set_resolutions rebuilds deterministically), and the per-point size
/// accounting matches the direct computation.
#[test]
fn accuracy_sweep_is_deterministic_and_sizes_agree() {
    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(19);
    let data: Vec<_> = (0..3)
        .map(|i| (gen.sample(GestureClass::ALL[i % 10], &mut rng), i % 10))
        .collect();
    let mut a = sweep_coordinator(33);
    let configs = fig6::scaling_configs_for(a.network());
    let pa = fig6::accuracy_sweep(&mut a, &data, &configs).unwrap();
    let pa2 = fig6::accuracy_sweep(&mut a, &data, &configs).unwrap();
    let mut b = sweep_coordinator(33);
    let pb = fig6::accuracy_sweep(&mut b, &data, &configs).unwrap();
    assert_eq!(pa.len(), 4);
    for (i, (x, (y, z))) in pa.iter().zip(pb.iter().zip(&pa2)).enumerate() {
        let acc = x.accuracy.expect("sweep measures accuracy");
        assert!((0.0..=1.0).contains(&acc), "tier {i} accuracy {acc}");
        assert_eq!(x.accuracy, y.accuracy, "tier {i}: independent builds agree");
        assert_eq!(x.accuracy, z.accuracy, "tier {i}: repeat sweep agrees");
        assert_eq!(x.resolutions, configs[i].1);
        let net = sweep_coordinator(33).network().with_resolutions(
            &x.resolutions.iter().map(|&(w, p)| Resolution::new(w, p)).collect::<Vec<_>>(),
        );
        assert_eq!(x.model_bits, net.total_weight_bits(), "tier {i} size");
        assert_eq!(x.conv_bits, net.conv_weight_bits(), "tier {i} conv size");
    }
}
