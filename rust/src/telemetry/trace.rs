//! Scoped-span tracing with Chrome `trace_event` export.
//!
//! A [`span`] guards a region of interest: it captures a start time on
//! creation and records `(name, ts, dur, thread)` when dropped. Records
//! land in bounded per-thread rings (each thread pushes to its own ring
//! under an uncontended mutex, so hot threads never serialize on each
//! other; the exporter locks rings one by one). When the ring wraps,
//! the oldest record is evicted and counted in [`dropped_total`].
//!
//! Cost discipline: with tracing disabled (the default), a span site is
//! **one relaxed atomic load** — the guard is inert and `Drop` does
//! nothing. With tracing enabled, a global round-robin sampler admits
//! every `sample_every`-th span *site hit*, so even an enabled
//! configuration stays out of the hot path's way (the `[telemetry]`
//! `trace_sample` knob; the CI `telemetry-overhead` step enforces the
//! <5% budget).
//!
//! Export: [`chrome_trace_json`] renders every retained record as a
//! Chrome `trace_event` complete event (`"ph":"X"`, microsecond
//! timestamps) — load the file in Perfetto or `chrome://tracing`. The
//! event list is sorted by (timestamp, thread, name) so identical span
//! sets render identically. `scripts/capture_trace.sh` wraps the CLI
//! path (`serve --trace FILE`).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Per-thread ring capacity (records; oldest evicted on wrap).
const RING_CAP: usize = 4096;

static TRACING: AtomicBool = AtomicBool::new(false);
static SAMPLE_EVERY: AtomicU32 = AtomicU32::new(64);
/// Global round-robin sample counter across all threads.
static SAMPLE_COUNTER: AtomicU64 = AtomicU64::new(0);
/// Monotonic thread-id source for trace records.
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// One recorded span.
#[derive(Debug, Clone, Copy)]
struct SpanRecord {
    name: &'static str,
    /// Start, microseconds since the trace epoch.
    ts_us: u64,
    /// Duration in microseconds.
    dur_us: u64,
    /// Recording thread's trace id.
    tid: u64,
}

struct Ring {
    buf: VecDeque<SpanRecord>,
    dropped: u64,
}

impl Ring {
    fn push(&mut self, r: SpanRecord) {
        if self.buf.len() >= RING_CAP {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(r);
    }
}

/// Every thread's ring, registered at first use.
fn rings() -> &'static Mutex<Vec<Arc<Mutex<Ring>>>> {
    static RINGS: OnceLock<Mutex<Vec<Arc<Mutex<Ring>>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

/// The trace epoch: fixed at the first recorded span, so timestamps
/// are small non-negative microsecond offsets.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

thread_local! {
    static LOCAL: (Arc<Mutex<Ring>>, u64) = {
        let ring = Arc::new(Mutex::new(Ring { buf: VecDeque::new(), dropped: 0 }));
        rings().lock().unwrap().push(ring.clone());
        (ring, NEXT_TID.fetch_add(1, Ordering::Relaxed))
    };
}

/// Turn span recording on/off and set the sampling period (`1` records
/// every span; `n` records every n-th site hit). `sample_every` is
/// clamped to ≥ 1. Deploying a spec with `[telemetry] trace = true`
/// calls this; benches flip it around measured regions.
pub fn set_tracing(enabled: bool, sample_every: u32) {
    SAMPLE_EVERY.store(sample_every.max(1), Ordering::Relaxed);
    TRACING.store(enabled, Ordering::Relaxed);
}

/// True when span recording is on.
pub fn tracing_enabled() -> bool {
    TRACING.load(Ordering::Relaxed)
}

/// Guard returned by [`span`]: records the enclosed region on drop
/// when it was sampled, and is fully inert otherwise.
#[must_use = "a span measures the scope it lives in"]
pub struct Span {
    name: &'static str,
    start: Option<Instant>,
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            let epoch = epoch();
            let ts_us = start.duration_since(epoch).as_micros() as u64;
            let dur_us = start.elapsed().as_micros() as u64;
            LOCAL.with(|(ring, tid)| {
                ring.lock().unwrap().push(SpanRecord {
                    name: self.name,
                    ts_us,
                    dur_us,
                    tid: *tid,
                });
            });
        }
    }
}

/// Open a scoped span named `name`. Bind it (`let _span = span(...)`)
/// so it drops at scope end. Disabled or unsampled sites return an
/// inert guard after a single relaxed atomic load.
pub fn span(name: &'static str) -> Span {
    if !TRACING.load(Ordering::Relaxed) {
        return Span { name, start: None };
    }
    let n = SAMPLE_EVERY.load(Ordering::Relaxed).max(1) as u64;
    if n > 1 && SAMPLE_COUNTER.fetch_add(1, Ordering::Relaxed) % n != 0 {
        return Span { name, start: None };
    }
    // Touch the epoch before taking the start time so ts_us ≥ 0 even
    // for the very first span.
    let _ = epoch();
    Span { name, start: Some(Instant::now()) }
}

/// Total records evicted from wrapped rings since process start.
pub fn dropped_total() -> u64 {
    rings().lock().unwrap().iter().map(|r| r.lock().unwrap().dropped).sum()
}

/// Total records currently retained across all rings.
pub fn recorded_total() -> u64 {
    rings().lock().unwrap().iter().map(|r| r.lock().unwrap().buf.len() as u64).sum()
}

/// Render every retained span as Chrome `trace_event` JSON (the
/// `{"traceEvents":[...]}` object form). Events are complete events
/// (`"ph":"X"`) with microsecond `ts`/`dur`, `pid` 1, and the
/// recording thread's id as `tid`; the list is sorted by
/// (ts, tid, name) so the rendering is deterministic for a given set
/// of records. Load the output in Perfetto or `chrome://tracing`.
pub fn chrome_trace_json() -> String {
    let mut records: Vec<SpanRecord> = Vec::new();
    for ring in rings().lock().unwrap().iter() {
        records.extend(ring.lock().unwrap().buf.iter().copied());
    }
    records.sort_by(|a, b| {
        a.ts_us.cmp(&b.ts_us).then(a.tid.cmp(&b.tid)).then(a.name.cmp(b.name))
    });
    let events: Vec<String> = records
        .iter()
        .map(|r| {
            format!(
                "{{\"name\":\"{}\",\"cat\":\"flexspim\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":1,\"tid\":{}}}",
                r.name, r.ts_us, r.dur_us, r.tid
            )
        })
        .collect();
    format!("{{\"traceEvents\":[{}]}}", events.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tracing state is process-global; each test enables sample-every-1
    // recording, asserts on *relative* growth (parallel tests may also
    // record), and restores the disabled default.
    #[test]
    fn disabled_spans_record_nothing() {
        let before = recorded_total();
        set_tracing(false, 1);
        for _ in 0..32 {
            let _s = span("noop");
        }
        // Only spans from concurrently running tests can appear; this
        // thread contributed none while disabled.
        LOCAL.with(|(ring, _)| {
            assert!(ring
                .lock()
                .unwrap()
                .buf
                .iter()
                .all(|r| r.name != "noop"));
        });
        let _ = before;
    }

    #[test]
    fn enabled_spans_are_recorded_and_exported() {
        set_tracing(true, 1);
        {
            let _s = span("test.enabled_span");
        }
        set_tracing(false, 64);
        let json = chrome_trace_json();
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"name\":\"test.enabled_span\""), "span exported: {json}");
        assert!(json.contains("\"ph\":\"X\""));
    }

    #[test]
    fn sampling_admits_a_fraction() {
        set_tracing(true, 1000);
        let mut active = 0;
        for _ in 0..100 {
            let s = span("test.sampled");
            if s.start.is_some() {
                active += 1;
            }
        }
        set_tracing(false, 64);
        assert!(active <= 2, "1/1000 sampling admits ~0 of 100 hits, got {active}");
    }

    #[test]
    fn ring_wrap_drops_oldest_and_counts() {
        let mut ring = Ring { buf: VecDeque::new(), dropped: 0 };
        for i in 0..(RING_CAP as u64 + 10) {
            ring.push(SpanRecord { name: "w", ts_us: i, dur_us: 0, tid: 0 });
        }
        assert_eq!(ring.buf.len(), RING_CAP);
        assert_eq!(ring.dropped, 10);
        assert_eq!(ring.buf.front().unwrap().ts_us, 10, "oldest evicted first");
    }
}
