//! Typed metrics registry with Prometheus-text and JSON exporters.
//!
//! Metrics are registered by name plus a label set (`tier`, `worker`,
//! `session`, `layer`, …) and come in three flavors:
//!
//! * [`Counter`] — monotonically increasing `u64` (lock-free).
//! * [`Gauge`] — instantaneous `i64` (lock-free).
//! * [`Histogram`] — latency-style distribution backed by the
//!   sorted-reservoir [`LatencyStats`], exported as p50/p95/p99
//!   summaries.
//!
//! Registration returns cheap cloneable handles (an `Arc` around the
//! cell), so hot paths update without touching the registry lock. Two
//! exporters read a consistent view: [`Registry::prometheus_text`]
//! (standard text exposition, scrapeable) and [`Registry::snapshot`]
//! — a [`TelemetrySnapshot`] whose [`TelemetrySnapshot::to_json`]
//! rendering is *deterministic* (BTree iteration order, fixed number
//! formatting), so benches and tests can assert on it byte-for-byte.
//!
//! A process-wide registry lives behind [`global`] (the engine's
//! hot-path counters batch into it); services own private registries
//! so concurrent tests never share state.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::coordinator::metrics::LatencyStats;

/// Identity of one metric: name plus sorted label pairs.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
struct MetricKey {
    name: String,
    labels: Vec<(String, String)>,
}

impl MetricKey {
    fn new(name: &str, labels: &[(&str, &str)]) -> MetricKey {
        let mut labels: Vec<(String, String)> =
            labels.iter().map(|&(k, v)| (k.to_string(), v.to_string())).collect();
        labels.sort();
        MetricKey { name: name.to_string(), labels }
    }
}

/// Monotonic counter handle. Clones share the same cell.
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add `n` to the counter.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Add 1.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous gauge handle. Clones share the same cell.
#[derive(Debug, Clone, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    /// Set the gauge to `v`.
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Add `d` (may be negative).
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Distribution handle backed by a sorted-reservoir [`LatencyStats`].
/// Clones share the same reservoir.
#[derive(Debug, Clone, Default)]
pub struct Histogram(Arc<Mutex<LatencyStats>>);

impl Histogram {
    /// Record one observation (seconds, or any unit — the exporter is
    /// unit-agnostic).
    pub fn observe(&self, v: f64) {
        self.0.lock().unwrap().push(v);
    }

    /// Observations recorded so far.
    pub fn count(&self) -> u64 {
        self.0.lock().unwrap().count() as u64
    }

    /// A point-in-time copy of the underlying reservoir.
    pub fn stats(&self) -> LatencyStats {
        self.0.lock().unwrap().clone()
    }
}

#[derive(Default)]
struct RegistryInner {
    counters: BTreeMap<MetricKey, Counter>,
    gauges: BTreeMap<MetricKey, Gauge>,
    histograms: BTreeMap<MetricKey, Histogram>,
}

/// A collection of named metrics with deterministic export order.
///
/// `counter`/`gauge`/`histogram` get-or-register: the first call for a
/// (name, labels) pair creates the metric, later calls return a handle
/// to the same cell — so instrumentation sites just ask for what they
/// need with no separate registration step.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<RegistryInner>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get or register the counter `name` with `labels`.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        let key = MetricKey::new(name, labels);
        self.inner.lock().unwrap().counters.entry(key).or_default().clone()
    }

    /// Get or register the gauge `name` with `labels`.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Gauge {
        let key = MetricKey::new(name, labels);
        self.inner.lock().unwrap().gauges.entry(key).or_default().clone()
    }

    /// Get or register the histogram `name` with `labels`.
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Histogram {
        let key = MetricKey::new(name, labels);
        self.inner.lock().unwrap().histograms.entry(key).or_default().clone()
    }

    /// Prometheus text exposition of every registered metric: `# TYPE`
    /// lines per family, histograms as summaries with `quantile`
    /// labels plus `_sum`/`_count` series. Deterministic (sorted by
    /// name, then labels).
    pub fn prometheus_text(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::new();
        let mut last_family = String::new();
        for (key, c) in &inner.counters {
            type_line(&mut out, &mut last_family, &key.name, "counter");
            out.push_str(&format!("{}{} {}\n", key.name, label_text(&key.labels, &[]), c.get()));
        }
        for (key, g) in &inner.gauges {
            type_line(&mut out, &mut last_family, &key.name, "gauge");
            out.push_str(&format!("{}{} {}\n", key.name, label_text(&key.labels, &[]), g.get()));
        }
        for (key, h) in &inner.histograms {
            type_line(&mut out, &mut last_family, &key.name, "summary");
            let stats = h.stats();
            for (q, v) in [(0.5, stats.p50()), (0.95, stats.p95()), (0.99, stats.p99())] {
                out.push_str(&format!(
                    "{}{} {}\n",
                    key.name,
                    label_text(&key.labels, &[("quantile", &format!("{q}"))]),
                    prom_num(v)
                ));
            }
            let count = stats.count() as u64;
            out.push_str(&format!(
                "{}_sum{} {}\n",
                key.name,
                label_text(&key.labels, &[]),
                prom_num(stats.mean() * count as f64)
            ));
            out.push_str(&format!("{}_count{} {count}\n", key.name, label_text(&key.labels, &[])));
        }
        out
    }

    /// A consistent point-in-time view of every metric, for JSON export
    /// and direct assertions.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        let inner = self.inner.lock().unwrap();
        TelemetrySnapshot {
            counters: inner
                .counters
                .iter()
                .map(|(k, c)| CounterSample {
                    name: k.name.clone(),
                    labels: k.labels.clone(),
                    value: c.get(),
                })
                .collect(),
            gauges: inner
                .gauges
                .iter()
                .map(|(k, g)| GaugeSample {
                    name: k.name.clone(),
                    labels: k.labels.clone(),
                    value: g.get(),
                })
                .collect(),
            histograms: inner
                .histograms
                .iter()
                .map(|(k, h)| {
                    let stats = h.stats();
                    let count = stats.count() as u64;
                    HistogramSample {
                        name: k.name.clone(),
                        labels: k.labels.clone(),
                        count,
                        sum: stats.mean() * count as f64,
                        p50: stats.p50(),
                        p95: stats.p95(),
                        p99: stats.p99(),
                    }
                })
                .collect(),
        }
    }
}

/// Emit a `# TYPE` header when entering a new metric family.
fn type_line(out: &mut String, last_family: &mut String, name: &str, kind: &str) {
    if last_family != name {
        out.push_str(&format!("# TYPE {name} {kind}\n"));
        last_family.clear();
        last_family.push_str(name);
    }
}

/// Render a label set as `{k="v",...}` (empty string when no labels),
/// with `extra` pairs appended. Values are escaped per the exposition
/// format (backslash, quote, newline).
fn label_text(labels: &[(String, String)], extra: &[(&str, &str)]) -> String {
    if labels.is_empty() && extra.is_empty() {
        return String::new();
    }
    let escape = |v: &str| v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n");
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape(v)))
        .collect();
    parts.extend(extra.iter().map(|&(k, v)| format!("{k}=\"{}\"", escape(v))));
    format!("{{{}}}", parts.join(","))
}

/// Prometheus sample-value formatting: integral floats without a
/// fraction, otherwise 6 decimals; non-finite as `NaN`.
fn prom_num(v: f64) -> String {
    json_num(v)
}

/// JSON number formatting shared with `util::bench::json_line`:
/// integral values render without a fraction, non-finite as `NaN` →
/// the JSON exporter maps that to `null`.
fn json_num(v: f64) -> String {
    if !v.is_finite() {
        return "NaN".to_string();
    }
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.6}")
    }
}

/// One counter's value at snapshot time.
#[derive(Debug, Clone, PartialEq)]
pub struct CounterSample {
    /// Metric name.
    pub name: String,
    /// Sorted label pairs.
    pub labels: Vec<(String, String)>,
    /// Counter value.
    pub value: u64,
}

/// One gauge's value at snapshot time.
#[derive(Debug, Clone, PartialEq)]
pub struct GaugeSample {
    /// Metric name.
    pub name: String,
    /// Sorted label pairs.
    pub labels: Vec<(String, String)>,
    /// Gauge value.
    pub value: i64,
}

/// One histogram's summary at snapshot time.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSample {
    /// Metric name.
    pub name: String,
    /// Sorted label pairs.
    pub labels: Vec<(String, String)>,
    /// Observations recorded.
    pub count: u64,
    /// Sum over all observations.
    pub sum: f64,
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
}

/// Point-in-time export of a [`Registry`], with a deterministic JSON
/// rendering for bench/test assertions.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TelemetrySnapshot {
    /// All counters, sorted by (name, labels).
    pub counters: Vec<CounterSample>,
    /// All gauges, sorted by (name, labels).
    pub gauges: Vec<GaugeSample>,
    /// All histogram summaries, sorted by (name, labels).
    pub histograms: Vec<HistogramSample>,
}

impl TelemetrySnapshot {
    /// Sum of every counter named `name` across label sets (0 when
    /// absent) — the common test assertion.
    pub fn counter_total(&self, name: &str) -> u64 {
        self.counters.iter().filter(|c| c.name == name).map(|c| c.value).sum()
    }

    /// Total observation count of every histogram named `name`.
    pub fn histogram_count(&self, name: &str) -> u64 {
        self.histograms.iter().filter(|h| h.name == name).map(|h| h.count).sum()
    }

    /// Deterministic single-line JSON rendering: fixed key order,
    /// sorted metrics, `json_line`-style number formatting (integral
    /// values without a fraction, non-finite as `null`). Two snapshots
    /// of identical metric state render byte-identically.
    pub fn to_json(&self) -> String {
        let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
        let labels_json = |labels: &[(String, String)]| {
            let parts: Vec<String> = labels
                .iter()
                .map(|(k, v)| format!("\"{}\":\"{}\"", esc(k), esc(v)))
                .collect();
            format!("{{{}}}", parts.join(","))
        };
        let num = |v: f64| {
            let s = json_num(v);
            if s == "NaN" {
                "null".to_string()
            } else {
                s
            }
        };
        let counters: Vec<String> = self
            .counters
            .iter()
            .map(|c| {
                format!(
                    "{{\"name\":\"{}\",\"labels\":{},\"value\":{}}}",
                    esc(&c.name),
                    labels_json(&c.labels),
                    c.value
                )
            })
            .collect();
        let gauges: Vec<String> = self
            .gauges
            .iter()
            .map(|g| {
                format!(
                    "{{\"name\":\"{}\",\"labels\":{},\"value\":{}}}",
                    esc(&g.name),
                    labels_json(&g.labels),
                    g.value
                )
            })
            .collect();
        let histograms: Vec<String> = self
            .histograms
            .iter()
            .map(|h| {
                format!(
                    "{{\"name\":\"{}\",\"labels\":{},\"count\":{},\"sum\":{},\"p50\":{},\"p95\":{},\"p99\":{}}}",
                    esc(&h.name),
                    labels_json(&h.labels),
                    h.count,
                    num(h.sum),
                    num(h.p50),
                    num(h.p95),
                    num(h.p99)
                )
            })
            .collect();
        format!(
            "{{\"counters\":[{}],\"gauges\":[{}],\"histograms\":[{}]}}",
            counters.join(","),
            gauges.join(","),
            histograms.join(",")
        )
    }
}

/// The process-wide registry (lazily created). Engine-tier hot-path
/// counters live here; services keep their own [`Registry`] instances.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// Pre-registered handles for the engine hot path, fetched once and
/// cached — `run_frames` batches one `add` per counter per window, so
/// the enabled cost is four relaxed atomic adds per window (and one
/// load when disabled).
pub struct HotPathCounters {
    /// Spike frames executed.
    pub frames: Counter,
    /// Input spike events consumed.
    pub in_events: Counter,
    /// Synaptic operations performed.
    pub sops: Counter,
    /// Micro-windows completed.
    pub windows: Counter,
}

impl HotPathCounters {
    /// Batch one executed window into the counters.
    pub fn record_window(&self, frames: u64, in_events: u64, sops: u64) {
        self.frames.add(frames);
        self.in_events.add(in_events);
        self.sops.add(sops);
        self.windows.inc();
    }
}

/// The engine's cached hot-path counters in the [`global`] registry.
pub fn hot() -> &'static HotPathCounters {
    static HOT: OnceLock<HotPathCounters> = OnceLock::new();
    HOT.get_or_init(|| {
        let g = global();
        let labels = &[("tier", "engine")];
        HotPathCounters {
            frames: g.counter("flexspim_engine_frames_total", labels),
            in_events: g.counter("flexspim_engine_in_events_total", labels),
            sops: g.counter("flexspim_engine_sops_total", labels),
            windows: g.counter("flexspim_engine_windows_total", labels),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_share_cells_and_labels_distinguish() {
        let r = Registry::new();
        let a = r.counter("x_total", &[("tier", "serve")]);
        let b = r.counter("x_total", &[("tier", "serve")]);
        let c = r.counter("x_total", &[("tier", "engine")]);
        a.add(2);
        b.inc();
        c.add(10);
        assert_eq!(a.get(), 3, "same (name, labels) shares one cell");
        assert_eq!(c.get(), 10);
        assert_eq!(r.snapshot().counter_total("x_total"), 13);
    }

    #[test]
    fn label_order_is_canonicalized() {
        let r = Registry::new();
        let a = r.counter("y_total", &[("b", "2"), ("a", "1")]);
        let b = r.counter("y_total", &[("a", "1"), ("b", "2")]);
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2, "label order must not split the metric");
    }

    #[test]
    fn gauge_set_and_add() {
        let r = Registry::new();
        let g = r.gauge("depth", &[]);
        g.set(5);
        g.add(-2);
        assert_eq!(g.get(), 3);
        assert_eq!(r.snapshot().gauges[0].value, 3);
    }

    #[test]
    fn histogram_summarizes_through_latency_stats() {
        let r = Registry::new();
        let h = r.histogram("lat_seconds", &[("tier", "serve")]);
        for i in 1..=100 {
            h.observe(i as f64 * 1e-3);
        }
        let snap = r.snapshot();
        assert_eq!(snap.histogram_count("lat_seconds"), 100);
        let s = &snap.histograms[0];
        assert!((s.p50 - 0.050).abs() < 2e-3, "p50 {}", s.p50);
        assert!(s.p99 >= s.p95 && s.p95 >= s.p50);
        assert!((s.sum - 5.05).abs() < 1e-9);
    }

    #[test]
    fn prometheus_text_shape() {
        let r = Registry::new();
        r.counter("c_total", &[("tier", "serve")]).add(7);
        r.gauge("g_now", &[]).set(-4);
        r.histogram("h_seconds", &[("worker", "0")]).observe(0.5);
        let text = r.prometheus_text();
        assert!(text.contains("# TYPE c_total counter\n"));
        assert!(text.contains("c_total{tier=\"serve\"} 7\n"));
        assert!(text.contains("# TYPE g_now gauge\n"));
        assert!(text.contains("g_now -4\n"));
        assert!(text.contains("# TYPE h_seconds summary\n"));
        assert!(text.contains("h_seconds{worker=\"0\",quantile=\"0.5\"} 0.500000\n"));
        assert!(text.contains("h_seconds_count{worker=\"0\"} 1\n"));
        assert!(text.contains("h_seconds_sum{worker=\"0\"} 0.500000\n"));
    }

    #[test]
    fn json_snapshot_is_deterministic_and_escapes() {
        let r = Registry::new();
        r.counter("c_total", &[("note", "a\"b")]).add(1);
        r.histogram("h", &[]).observe(2.0);
        let a = r.snapshot().to_json();
        let b = r.snapshot().to_json();
        assert_eq!(a, b, "unchanged state renders byte-identically");
        assert!(a.contains("\"note\":\"a\\\"b\""), "label values are escaped: {a}");
        assert!(a.contains("\"p50\":2"), "integral floats render without fraction: {a}");
        assert!(a.starts_with("{\"counters\":["));
    }

    #[test]
    fn empty_histogram_percentiles_render_null() {
        let r = Registry::new();
        let _ = r.histogram("empty", &[]);
        let json = r.snapshot().to_json();
        assert!(json.contains("\"p50\":null"), "NaN percentiles become null: {json}");
    }

    #[test]
    fn hot_counters_batch_into_global() {
        let before = global().snapshot().counter_total("flexspim_engine_windows_total");
        hot().record_window(4, 100, 2000);
        let snap = global().snapshot();
        assert!(snap.counter_total("flexspim_engine_windows_total") >= before + 1);
        assert!(snap.counter_total("flexspim_engine_sops_total") >= 2000);
    }
}
