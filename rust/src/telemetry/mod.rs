//! Observability for the full serve stack: logging, metrics, tracing,
//! and a flight recorder.
//!
//! The paper's headline numbers are all *measured* quantities, and the
//! ROADMAP items ahead (fleet traffic ledgers, a live precision
//! controller) need in-flight visibility rather than end-of-run
//! aggregates. This module is that layer, in four pieces:
//!
//! * [`log`] — a leveled logger (`log_error!` … `log_trace!` macros)
//!   gated by `FLEXSPIM_LOG` / `--verbosity`, so library code never
//!   writes unconditionally to stderr. Info-level output goes to stdout
//!   verbatim (CLI reports and `BENCH_JSON` lines keep their format).
//! * [`metrics`] — a typed counter/gauge/histogram registry with two
//!   exporters: Prometheus text exposition and a deterministic JSON
//!   snapshot ([`metrics::TelemetrySnapshot`]) that tests assert on.
//!   Histograms reuse the sorted-reservoir
//!   [`LatencyStats`](crate::coordinator::metrics::LatencyStats).
//! * [`trace`] — scoped spans around the hot seams (window step, frame
//!   step, ingest, queue wait, snapshot/restore), recorded into bounded
//!   per-thread rings and exportable as Chrome `trace_event` JSON
//!   (open in Perfetto or `chrome://tracing`). A sampling knob keeps
//!   the default cost to one relaxed atomic load per span site.
//! * [`recorder`] — a bounded ring of the last N structured service
//!   events (admissions, sheds, evictions, scale decisions, early
//!   exits) for after-the-fact diagnosis of saturation failures.
//!
//! Configuration rides the deploy plumbing: a `[telemetry]` section in
//! [`DeploymentSpec`](crate::deploy::DeploymentSpec) (TOML/builder/CLI
//! overlays) enables recording globally via [`set_enabled`] and
//! per-service via [`TelemetryConfig`]. Everything is off by default
//! and the instrumentation points cost a single relaxed atomic load
//! when disabled (bounded by the CI `telemetry-overhead` smoke step).

pub mod log;
pub mod metrics;
pub mod recorder;
pub mod trace;

pub use metrics::{Counter, Gauge, Histogram, Registry, TelemetrySnapshot};
pub use recorder::{FlightEvent, FlightRecorder};

use std::sync::atomic::{AtomicBool, Ordering};

/// Global telemetry master switch (process-wide hot-path gate).
static ENABLED: AtomicBool = AtomicBool::new(false);

/// True when telemetry recording is globally enabled.
///
/// Hot paths (e.g. the engine's per-window counter batch) check this
/// single relaxed load before touching the registry, so the disabled
/// cost is one atomic read.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Flip the global telemetry switch. [`crate::deploy`] calls this when
/// a spec with `[telemetry] enabled = true` deploys; benches flip it to
/// measure the instrumented-vs-bare overhead.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Per-service telemetry configuration — the runtime twin of
/// [`crate::deploy::TelemetrySpec`], carried on
/// [`ServiceConfig`](crate::serve::ServiceConfig) so each service
/// records into its own registry/recorder deterministically (tests
/// never race on process-global state).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TelemetryConfig {
    /// Record service metrics and flight-recorder events.
    pub enabled: bool,
    /// Flight-recorder ring capacity (last N events are kept).
    pub flight_capacity: usize,
}

impl TelemetryConfig {
    /// Telemetry off (the default): recording sites reduce to a bool
    /// check, the flight ring keeps its nominal capacity if enabled
    /// later.
    pub fn disabled() -> TelemetryConfig {
        TelemetryConfig { enabled: false, flight_capacity: 256 }
    }
}

impl Default for TelemetryConfig {
    fn default() -> TelemetryConfig {
        TelemetryConfig::disabled()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_default_is_disabled() {
        let c = TelemetryConfig::default();
        assert!(!c.enabled);
        assert_eq!(c.flight_capacity, 256);
        assert_eq!(c, TelemetryConfig::disabled());
    }
}
