//! Flight recorder: a bounded ring of the last N structured service
//! events.
//!
//! Saturation-regime failures are hard to diagnose from aggregate
//! counters — by the time a run ends, the interesting part (what the
//! admission path and the autoscaler were *doing* when latency blew
//! up) is gone. Each [`crate::serve::StreamingService`] therefore keeps
//! a [`FlightRecorder`]: every admission, shed, eviction, early exit,
//! and autoscaler decision is appended as a timestamped
//! [`FlightEvent`]; the ring keeps the last `capacity` of them and the
//! accounting partitions exactly (`recorded == retained + dropped`,
//! property-tested in `rust/tests/property_flight.rs`).
//!
//! The ring is dumped on service error and on demand via
//! `flexspim serve --dump-telemetry`.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

/// One structured service event.
#[derive(Debug, Clone, PartialEq)]
pub enum FlightEvent {
    /// A micro-window was admitted to the run queue.
    Admit {
        /// Session id.
        session: u64,
        /// Global admission sequence number.
        seq: u64,
    },
    /// A micro-window was shed by the load-shed policy.
    Shed {
        /// Session id.
        session: u64,
    },
    /// Residency admission evicted other sessions' vmem to DRAM.
    Evict {
        /// The session whose admission caused the eviction.
        session: u64,
        /// Sessions evicted.
        evictions: u64,
        /// Bits spilled to DRAM.
        spill_bits: u64,
    },
    /// A session crossed the early-exit confidence bound.
    EarlyExit {
        /// Session id.
        session: u64,
        /// Confidence margin at the exit.
        margin: f64,
    },
    /// One precision-controller verdict that moved a session's
    /// resolution tier, with the inputs that drove it.
    PrecisionDecision {
        /// Session id.
        session: u64,
        /// Resolution tier before (0 = deployed full precision).
        from: usize,
        /// Resolution tier after.
        to: usize,
        /// Rolling p99 input (milliseconds).
        p99_ms: f64,
        /// Queued windows input.
        queued: usize,
        /// The session's smoothed classification margin input.
        margin: f64,
    },
    /// One autoscaler `decide()` tick: its inputs and verdict.
    AutoscaleDecision {
        /// Workers active at the tick.
        current: usize,
        /// Rolling p99 input (milliseconds).
        p99_ms: f64,
        /// Queued windows input.
        queued: usize,
        /// Consecutive calm ticks before this one.
        calm_ticks: u32,
        /// The verdict: target worker count.
        target: usize,
    },
    /// The worker pool grew.
    ScaleUp {
        /// Workers before.
        from: usize,
        /// Workers after.
        to: usize,
    },
    /// The worker pool shrank.
    ScaleDown {
        /// Workers before.
        from: usize,
        /// Workers after.
        to: usize,
    },
    /// A worker hit a fatal error.
    Error {
        /// The error rendering.
        message: String,
    },
}

impl FlightEvent {
    /// Short event-kind tag (the dump/report key).
    pub fn kind(&self) -> &'static str {
        match self {
            FlightEvent::Admit { .. } => "admit",
            FlightEvent::Shed { .. } => "shed",
            FlightEvent::Evict { .. } => "evict",
            FlightEvent::EarlyExit { .. } => "early-exit",
            FlightEvent::PrecisionDecision { .. } => "precision-decision",
            FlightEvent::AutoscaleDecision { .. } => "autoscale-decision",
            FlightEvent::ScaleUp { .. } => "scale-up",
            FlightEvent::ScaleDown { .. } => "scale-down",
            FlightEvent::Error { .. } => "error",
        }
    }
}

/// A [`FlightEvent`] with its recording order and time.
#[derive(Debug, Clone, PartialEq)]
pub struct Recorded {
    /// 0-based global sequence number of the record.
    pub seq: u64,
    /// Microseconds since the recorder was created.
    pub ts_us: u64,
    /// The event.
    pub event: FlightEvent,
}

struct RecorderInner {
    ring: VecDeque<Recorded>,
    recorded: u64,
    dropped: u64,
}

/// Bounded ring of the last `capacity` service events.
///
/// Accounting invariant: at all times
/// `recorded() == events().len() as u64 + dropped()` — every recorded
/// event is either retained or counted as dropped, never both, never
/// neither.
pub struct FlightRecorder {
    inner: Mutex<RecorderInner>,
    capacity: usize,
    t0: Instant,
}

impl FlightRecorder {
    /// Empty recorder keeping the last `capacity` events (≥ 1).
    pub fn new(capacity: usize) -> FlightRecorder {
        FlightRecorder {
            inner: Mutex::new(RecorderInner {
                ring: VecDeque::new(),
                recorded: 0,
                dropped: 0,
            }),
            capacity: capacity.max(1),
            t0: Instant::now(),
        }
    }

    /// Ring capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Append one event, evicting the oldest when full.
    pub fn record(&self, event: FlightEvent) {
        let ts_us = self.t0.elapsed().as_micros() as u64;
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.recorded;
        inner.recorded += 1;
        if inner.ring.len() >= self.capacity {
            inner.ring.pop_front();
            inner.dropped += 1;
        }
        inner.ring.push_back(Recorded { seq, ts_us, event });
    }

    /// Events recorded since creation (retained or dropped).
    pub fn recorded(&self) -> u64 {
        self.inner.lock().unwrap().recorded
    }

    /// Events evicted by ring wrap.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Events currently retained.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().ring.len()
    }

    /// True when nothing is retained.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// A copy of the retained events, oldest first.
    pub fn events(&self) -> Vec<Recorded> {
        self.inner.lock().unwrap().ring.iter().cloned().collect()
    }

    /// Retained events of one kind, oldest first.
    pub fn events_of_kind(&self, kind: &str) -> Vec<Recorded> {
        self.events().into_iter().filter(|r| r.event.kind() == kind).collect()
    }

    /// Human-readable dump: a header with the exact accounting
    /// partition, then one line per retained event, oldest first.
    pub fn dump(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = format!(
            "flight recorder: {} recorded, {} retained, {} dropped (cap {})\n",
            inner.recorded,
            inner.ring.len(),
            inner.dropped,
            self.capacity
        );
        for r in &inner.ring {
            out.push_str(&format!(
                "  [+{:>10.6}s] #{:<6} {:<18} {:?}\n",
                r.ts_us as f64 * 1e-6,
                r.seq,
                r.event.kind(),
                r.event
            ));
        }
        out
    }
}

impl std::fmt::Debug for FlightRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.inner.lock().unwrap();
        f.debug_struct("FlightRecorder")
            .field("capacity", &self.capacity)
            .field("recorded", &inner.recorded)
            .field("retained", &inner.ring.len())
            .field("dropped", &inner.dropped)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_keeps_newest_and_partitions_exactly() {
        let rec = FlightRecorder::new(4);
        for i in 0..10u64 {
            rec.record(FlightEvent::Admit { session: 1, seq: i });
        }
        assert_eq!(rec.recorded(), 10);
        assert_eq!(rec.len(), 4);
        assert_eq!(rec.dropped(), 6);
        assert_eq!(rec.recorded(), rec.len() as u64 + rec.dropped());
        let evs = rec.events();
        assert_eq!(evs.first().unwrap().seq, 6, "oldest retained is #6");
        assert_eq!(evs.last().unwrap().seq, 9);
        assert!(evs.windows(2).all(|w| w[0].seq + 1 == w[1].seq));
    }

    #[test]
    fn kinds_and_dump_render() {
        let rec = FlightRecorder::new(8);
        rec.record(FlightEvent::Shed { session: 3 });
        rec.record(FlightEvent::AutoscaleDecision {
            current: 1,
            p99_ms: 12.5,
            queued: 9,
            calm_ticks: 0,
            target: 2,
        });
        rec.record(FlightEvent::ScaleUp { from: 1, to: 2 });
        assert_eq!(rec.events_of_kind("scale-up").len(), 1);
        assert_eq!(rec.events_of_kind("autoscale-decision").len(), 1);
        let dump = rec.dump();
        assert!(dump.starts_with("flight recorder: 3 recorded, 3 retained, 0 dropped"));
        assert!(dump.contains("scale-up"));
        assert!(dump.contains("p99_ms: 12.5"));
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let rec = FlightRecorder::new(0);
        assert_eq!(rec.capacity(), 1);
        rec.record(FlightEvent::Shed { session: 0 });
        rec.record(FlightEvent::Shed { session: 1 });
        assert_eq!(rec.len(), 1);
        assert_eq!(rec.dropped(), 1);
    }
}
