//! Leveled logging: the crate's only sanctioned path to stdout/stderr.
//!
//! Library and CLI code log through the `log_error!` … `log_trace!`
//! macros instead of ad-hoc `println!`/`eprintln!`, so every line is
//! gated by one global [`Level`] set from the `FLEXSPIM_LOG`
//! environment variable ([`init_from_env`]) or the CLI `--verbosity`
//! flag.
//!
//! Routing keeps existing consumers working: [`Level::Info`] writes the
//! message *bare* to stdout (CLI reports, bench tables, and the
//! `BENCH_JSON` trajectory lines keep their exact format and remain
//! greppable), while every other level goes to stderr prefixed with
//! `[level]`. Raising the threshold above `info` therefore silences
//! normal report output too — useful for machine-read runs.

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first. The global threshold admits a
/// message when `message level <= threshold`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable or user-visible failures.
    Error = 0,
    /// Degraded-but-continuing conditions (missing artifacts, skips).
    Warn = 1,
    /// Normal report output (the default threshold; goes to stdout).
    Info = 2,
    /// Diagnostic detail for debugging a run.
    Debug = 3,
    /// Per-event firehose.
    Trace = 4,
}

impl Level {
    /// Parse a level name (case-insensitive) or numeric threshold 0–4.
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" | "0" => Some(Level::Error),
            "warn" | "warning" | "1" => Some(Level::Warn),
            "info" | "2" => Some(Level::Info),
            "debug" | "3" => Some(Level::Debug),
            "trace" | "4" => Some(Level::Trace),
            _ => None,
        }
    }

    /// Lower-case level name (the stderr prefix).
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }
}

/// Global threshold; `Info` by default so CLI/bench output is visible
/// out of the box.
static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global log threshold.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// The current global log threshold.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// True when a message at `l` would currently be written.
pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Initialize the threshold from the `FLEXSPIM_LOG` environment
/// variable, if set to a parseable level. Unparseable values are
/// ignored (the default stays), never fatal.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("FLEXSPIM_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

/// Write one message at `l` (no-op when the threshold excludes it).
/// Info goes bare to stdout; everything else to stderr with a `[level]`
/// prefix. Prefer the `log_*!` macros over calling this directly.
pub fn write(l: Level, args: fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    if l == Level::Info {
        println!("{args}");
    } else {
        eprintln!("[{}] {args}", l.as_str());
    }
}

/// Log at [`Level::Error`] (stderr, `[error]` prefix).
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::telemetry::log::write($crate::telemetry::log::Level::Error, format_args!($($arg)*))
    };
}

/// Log at [`Level::Warn`] (stderr, `[warn]` prefix).
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::telemetry::log::write($crate::telemetry::log::Level::Warn, format_args!($($arg)*))
    };
}

/// Log at [`Level::Info`] — bare stdout, the normal report channel.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::telemetry::log::write($crate::telemetry::log::Level::Info, format_args!($($arg)*))
    };
}

/// Log at [`Level::Debug`] (stderr, `[debug]` prefix).
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::telemetry::log::write($crate::telemetry::log::Level::Debug, format_args!($($arg)*))
    };
}

/// Log at [`Level::Trace`] (stderr, `[trace]` prefix).
#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::telemetry::log::write($crate::telemetry::log::Level::Trace, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_names_and_numbers() {
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse(" info "), Some(Level::Info));
        assert_eq!(Level::parse("3"), Some(Level::Debug));
        assert_eq!(Level::parse("4"), Some(Level::Trace));
        assert_eq!(Level::parse("loud"), None);
    }

    #[test]
    fn levels_order_most_severe_first() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
        assert_eq!(Level::Error.as_str(), "error");
        assert_eq!(Level::Trace.as_str(), "trace");
    }

    // `enabled()`/`set_level()` mutate process-global state shared with
    // parallel tests, so the round-trip restores the default at the end.
    #[test]
    fn threshold_gates_levels() {
        let before = level();
        set_level(Level::Warn);
        assert!(enabled(Level::Error) && enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(before);
    }
}
