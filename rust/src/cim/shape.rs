//! Operand shaping (paper §II-A, Fig. 3).
//!
//! A `bits`-wide operand may be mapped onto any `N_R × N_C` rectangle of
//! the array with `N_R·N_C ≥ bits`. Bits are laid out boustrophedon
//! (ping-pong): even rows run LSB→MSB left-to-right, odd rows
//! right-to-left, so that the carry leaving the last column of one row is
//! consumed by the *same* PC in the next row — inter-PC movement stays
//! bounded to direct neighbors regardless of operand width, which is what
//! makes the scheme scalable (paper §II-A, last paragraph).

/// Shape of one multi-bit operand in the array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OperandShape {
    /// Operand width in bits.
    pub bits: u32,
    /// Columns occupied (`N_C`).
    pub n_c: u32,
}

impl OperandShape {
    /// Construct and validate a shape.
    pub fn new(bits: u32, n_c: u32) -> Self {
        assert!(bits >= 1, "operand must have at least one bit");
        assert!(n_c >= 1, "shape must occupy at least one column");
        OperandShape { bits, n_c }
    }

    /// Rows occupied (`N_R = ceil(bits / N_C)`).
    pub fn n_r(&self) -> u32 {
        self.bits.div_ceil(self.n_c)
    }

    /// Bit position stored at `(row, col_offset)` within the rectangle,
    /// honoring the ping-pong layout. Returns `None` for padding cells
    /// (positions ≥ `bits` in the last row).
    pub fn bit_at(&self, row: u32, col_offset: u32) -> Option<u32> {
        debug_assert!(row < self.n_r() && col_offset < self.n_c);
        let within = if row % 2 == 0 {
            col_offset
        } else {
            self.n_c - 1 - col_offset // ping-pong: odd rows reversed
        };
        let pos = row * self.n_c + within;
        if pos < self.bits {
            Some(pos)
        } else {
            None
        }
    }

    /// Column offset (within the rectangle) holding bit `pos`.
    pub fn col_of_bit(&self, pos: u32) -> u32 {
        debug_assert!(pos < self.bits);
        let row = pos / self.n_c;
        let within = pos % self.n_c;
        if row % 2 == 0 {
            within
        } else {
            self.n_c - 1 - within
        }
    }

    /// Row (within the rectangle) holding bit `pos`.
    pub fn row_of_bit(&self, pos: u32) -> u32 {
        debug_assert!(pos < self.bits);
        pos / self.n_c
    }

    /// Visit order of column offsets for row `row` during the bit-serial
    /// walk: always LSB-of-the-row first, i.e. left→right on even rows and
    /// right→left on odd rows.
    pub fn visit_order(&self, row: u32) -> Vec<u32> {
        if row % 2 == 0 {
            (0..self.n_c).collect()
        } else {
            (0..self.n_c).rev().collect()
        }
    }

    /// Padding cells in the last row (waste for non-divisible shapes).
    pub fn padding_bits(&self) -> u32 {
        self.n_r() * self.n_c - self.bits
    }
}

/// Enumerate all shapes for `bits` with `n_c` up to `max_cols` that waste
/// no more than one row of padding — the design space swept in Fig. 7a.
pub fn enumerate_shapes(bits: u32, max_cols: u32) -> Vec<OperandShape> {
    (1..=max_cols.min(bits))
        .map(|n_c| OperandShape::new(bits, n_c))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{check, prop_assert, prop_eq, Config};

    #[test]
    fn row_count() {
        assert_eq!(OperandShape::new(16, 1).n_r(), 16); // bit-serial
        assert_eq!(OperandShape::new(16, 16).n_r(), 1); // bit-parallel
        assert_eq!(OperandShape::new(16, 4).n_r(), 4); // 4×4
        assert_eq!(OperandShape::new(12, 3).n_r(), 4); // Fig. 3e: 4×3
        assert_eq!(OperandShape::new(10, 3).n_r(), 4); // padded
    }

    #[test]
    fn fig3e_pingpong_layout() {
        // 12 bits over 4×3 (Fig. 3e): row0 = b0 b1 b2, row1 = b5 b4 b3, ...
        let s = OperandShape::new(12, 3);
        assert_eq!(s.bit_at(0, 0), Some(0));
        assert_eq!(s.bit_at(0, 2), Some(2));
        assert_eq!(s.bit_at(1, 0), Some(5));
        assert_eq!(s.bit_at(1, 2), Some(3));
        assert_eq!(s.bit_at(2, 0), Some(6));
        assert_eq!(s.bit_at(3, 2), Some(9));
    }

    #[test]
    fn padding_cells_are_none() {
        let s = OperandShape::new(10, 3); // 4 rows, last row holds b9 only
        // Row 3 is odd -> reversed: col_offset 2 holds b9, offsets 0,1 pad.
        assert_eq!(s.bit_at(3, 2), Some(9));
        assert_eq!(s.bit_at(3, 1), None);
        assert_eq!(s.bit_at(3, 0), None);
        assert_eq!(s.padding_bits(), 2);
    }

    #[test]
    fn carry_continuity_across_rows() {
        // The MSB-of-row column must equal the LSB-of-next-row column:
        // that is the whole point of the ping-pong layout.
        for bits in [4u32, 9, 12, 16, 24, 33] {
            for n_c in 1..=bits {
                let s = OperandShape::new(bits, n_c);
                for row in 0..s.n_r() - 1 {
                    let msb_of_row = ((row + 1) * n_c - 1).min(bits - 1);
                    let lsb_of_next = (row + 1) * n_c;
                    if lsb_of_next >= bits {
                        continue;
                    }
                    assert_eq!(
                        s.col_of_bit(msb_of_row),
                        s.col_of_bit(lsb_of_next),
                        "bits={bits} n_c={n_c} row={row}"
                    );
                }
            }
        }
    }

    #[test]
    fn prop_bit_mapping_is_a_bijection() {
        check("shape-bijection", &Config::default(), |c| {
            let bits = c.rng.range_i64(1, 64) as u32;
            let n_c = c.rng.range_i64(1, bits as i64) as u32;
            let s = OperandShape::new(bits, n_c);
            let mut seen = vec![false; bits as usize];
            for row in 0..s.n_r() {
                for col in 0..s.n_c {
                    if let Some(pos) = s.bit_at(row, col) {
                        prop_assert(!seen[pos as usize], "duplicate bit position")?;
                        seen[pos as usize] = true;
                        prop_eq(s.col_of_bit(pos), col, "col_of_bit inverse")?;
                        prop_eq(s.row_of_bit(pos), row, "row_of_bit inverse")?;
                    }
                }
            }
            prop_assert(seen.iter().all(|&b| b), "all bits placed")
        });
    }

    #[test]
    fn visit_order_starts_at_row_lsb() {
        let s = OperandShape::new(12, 3);
        assert_eq!(s.visit_order(0), vec![0, 1, 2]);
        assert_eq!(s.visit_order(1), vec![2, 1, 0]);
        // First visited cell of each row is the row's LSB.
        for row in 0..s.n_r() {
            let first = s.visit_order(row)[0];
            assert_eq!(s.bit_at(row, first), Some(row * 3));
        }
    }

    #[test]
    fn enumerate_shape_sweep() {
        let shapes = enumerate_shapes(16, 256);
        assert_eq!(shapes.len(), 16);
        assert!(shapes.iter().any(|s| s.n_c == 1 && s.n_r() == 16));
        assert!(shapes.iter().any(|s| s.n_c == 16 && s.n_r() == 1));
    }
}
