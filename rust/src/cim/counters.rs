//! Energy-event ledger.
//!
//! The macro simulator does not compute joules; it counts the discrete
//! circuit events that the silicon spends energy on. The calibrated model
//! in [`crate::energy`] converts the ledger into pJ using coefficients
//! fitted to the paper's measurements (Fig. 7a, Table I). Keeping the two
//! concerns separate lets the same simulation be re-priced at different
//! supply voltages (the paper's 0.9–1.1 V range).

/// Counts of energy-bearing events accumulated during simulation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EnergyCounters {
    /// Internal CIM row-cycles executed (one 5-phase operation each).
    pub cim_cycles: u64,
    /// Active-column × cycle products (precharge + SA + adder energy).
    pub active_col_cycles: u64,
    /// Standby-column × cycle products (clock/leakage at gated energy).
    pub standby_col_cycles: u64,
    /// Wordline-pair activations (row decoder + WL driver).
    pub wl_activations: u64,
    /// Sense-amplifier evaluations (two per active column per CIM cycle).
    pub sa_reads: u64,
    /// Full-adder evaluations in PCs.
    pub adder_ops: u64,
    /// Write-backs of sum bits into the array (phase 5 of Fig. 2c).
    pub writebacks: u64,
    /// Carry propagation hops between neighboring PCs.
    pub carry_hops: u64,
    /// Emulation-bit (sign-extension) reads replacing array reads.
    pub eb_reads: u64,
    /// Comparator evaluations (threshold check).
    pub compare_ops: u64,
    /// Bits moved through the macro I/O port (loads, drains, spikes).
    pub io_bits: u64,
    /// Plain SRAM bit-writes through the port (operand loading).
    pub sram_writes: u64,
    /// Plain SRAM bit-reads through the port (operand draining).
    pub sram_reads: u64,
    /// Completed synaptic operations (for throughput/efficiency reporting).
    pub sops: u64,
}

impl EnergyCounters {
    /// Zeroed ledger.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add another ledger into this one.
    pub fn merge(&mut self, other: &EnergyCounters) {
        self.cim_cycles += other.cim_cycles;
        self.active_col_cycles += other.active_col_cycles;
        self.standby_col_cycles += other.standby_col_cycles;
        self.wl_activations += other.wl_activations;
        self.sa_reads += other.sa_reads;
        self.adder_ops += other.adder_ops;
        self.writebacks += other.writebacks;
        self.carry_hops += other.carry_hops;
        self.eb_reads += other.eb_reads;
        self.compare_ops += other.compare_ops;
        self.io_bits += other.io_bits;
        self.sram_writes += other.sram_writes;
        self.sram_reads += other.sram_reads;
        self.sops += other.sops;
    }

    /// Scale every event count by `k` — prices `k` repetitions of an
    /// operation whose per-op ledger was measured once (the engine's
    /// shard-calibration path).
    pub fn scaled(&self, k: u64) -> EnergyCounters {
        EnergyCounters {
            cim_cycles: self.cim_cycles * k,
            active_col_cycles: self.active_col_cycles * k,
            standby_col_cycles: self.standby_col_cycles * k,
            wl_activations: self.wl_activations * k,
            sa_reads: self.sa_reads * k,
            adder_ops: self.adder_ops * k,
            writebacks: self.writebacks * k,
            carry_hops: self.carry_hops * k,
            eb_reads: self.eb_reads * k,
            compare_ops: self.compare_ops * k,
            io_bits: self.io_bits * k,
            sram_writes: self.sram_writes * k,
            sram_reads: self.sram_reads * k,
            sops: self.sops * k,
        }
    }

    /// Merge per-shard ledgers of **one operation executed in lockstep**
    /// across column-group shards of the same physical macro.
    ///
    /// Column-proportional events (sense amps, adders, write-backs, carry
    /// hops, EB reads, comparators, I/O, SOPs) simply sum. Row-cycle events
    /// (`cim_cycles`, `wl_activations`) are shared by every shard driven by
    /// the common row decoder, so the merged count is the *maximum* over
    /// shards (a shard that skips a conditional pass still idles while its
    /// siblings cycle). Standby activity is then derived from the invariant
    /// `active + standby = total_cols` per cycle — which is why the total
    /// column count of the merged view is a parameter.
    pub fn merge_lockstep(deltas: &[EnergyCounters], total_cols: u64) -> EnergyCounters {
        let mut out = EnergyCounters::new();
        for d in deltas {
            out.active_col_cycles += d.active_col_cycles;
            out.sa_reads += d.sa_reads;
            out.adder_ops += d.adder_ops;
            out.writebacks += d.writebacks;
            out.carry_hops += d.carry_hops;
            out.eb_reads += d.eb_reads;
            out.compare_ops += d.compare_ops;
            out.io_bits += d.io_bits;
            out.sram_writes += d.sram_writes;
            out.sram_reads += d.sram_reads;
            out.sops += d.sops;
            out.cim_cycles = out.cim_cycles.max(d.cim_cycles);
            out.wl_activations = out.wl_activations.max(d.wl_activations);
        }
        out.standby_col_cycles = (out.cim_cycles * total_cols).saturating_sub(out.active_col_cycles);
        out
    }

    /// Difference (self - baseline), for measuring a single operation.
    pub fn delta(&self, baseline: &EnergyCounters) -> EnergyCounters {
        EnergyCounters {
            cim_cycles: self.cim_cycles - baseline.cim_cycles,
            active_col_cycles: self.active_col_cycles - baseline.active_col_cycles,
            standby_col_cycles: self.standby_col_cycles - baseline.standby_col_cycles,
            wl_activations: self.wl_activations - baseline.wl_activations,
            sa_reads: self.sa_reads - baseline.sa_reads,
            adder_ops: self.adder_ops - baseline.adder_ops,
            writebacks: self.writebacks - baseline.writebacks,
            carry_hops: self.carry_hops - baseline.carry_hops,
            eb_reads: self.eb_reads - baseline.eb_reads,
            compare_ops: self.compare_ops - baseline.compare_ops,
            io_bits: self.io_bits - baseline.io_bits,
            sram_writes: self.sram_writes - baseline.sram_writes,
            sram_reads: self.sram_reads - baseline.sram_reads,
            sops: self.sops - baseline.sops,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_and_delta_roundtrip() {
        let mut a = EnergyCounters::new();
        a.cim_cycles = 10;
        a.adder_ops = 7;
        let mut b = EnergyCounters::new();
        b.cim_cycles = 5;
        b.adder_ops = 3;
        b.io_bits = 2;
        let snapshot = a;
        a.merge(&b);
        assert_eq!(a.cim_cycles, 15);
        assert_eq!(a.adder_ops, 10);
        assert_eq!(a.io_bits, 2);
        assert_eq!(a.delta(&snapshot), b);
    }

    #[test]
    fn scaled_multiplies_every_field() {
        let mut a = EnergyCounters::new();
        a.cim_cycles = 3;
        a.adder_ops = 5;
        a.sops = 1;
        let s = a.scaled(4);
        assert_eq!(s.cim_cycles, 12);
        assert_eq!(s.adder_ops, 20);
        assert_eq!(s.sops, 4);
        assert_eq!(a.scaled(0), EnergyCounters::new());
    }

    #[test]
    fn lockstep_merge_sums_columns_maxes_cycles() {
        let mut a = EnergyCounters::new();
        a.cim_cycles = 16;
        a.wl_activations = 16;
        a.active_col_cycles = 64;
        a.adder_ops = 64;
        let mut b = EnergyCounters::new();
        b.cim_cycles = 32; // sibling ran a conditional pass too
        b.wl_activations = 32;
        b.active_col_cycles = 96;
        b.adder_ops = 96;
        let m = EnergyCounters::merge_lockstep(&[a, b], 10);
        assert_eq!(m.cim_cycles, 32, "row cycles shared, not summed");
        assert_eq!(m.wl_activations, 32);
        assert_eq!(m.active_col_cycles, 160);
        assert_eq!(m.adder_ops, 160);
        assert_eq!(m.standby_col_cycles, 32 * 10 - 160, "derived standby");
    }

    #[test]
    fn default_is_zero() {
        let c = EnergyCounters::new();
        assert_eq!(c.cim_cycles + c.sops + c.io_bits, 0);
    }
}
