//! The FlexSpIM CIM macro: 512×256 6T array + 256 peripheral circuits.
//!
//! Layout contract (paper Fig. 3): each resident neuron owns a group of
//! `N_C` adjacent columns. Within the group, each of its `fan_in` weights
//! occupies `N_R_w = ceil(w_bits/N_C)` rows and the membrane potential
//! occupies `N_R_p = ceil(p_bits/N_C)` rows, all using the same ping-pong
//! bit layout so that weight bit *k* and membrane bit *k* sit in the same
//! column (the 1-bit adders add aligned bits).
//!
//! A synaptic accumulate (`v += w_j`, triggered by an input spike on
//! synapse *j*) runs `N_R_p` internal row-cycles of the 5-phase operation
//! (Fig. 2c); weight rows past `N_R_w` are replaced by emulation-bit sign
//! extension. The threshold step (`cim_fire`) is a bit-serial MSB-first
//! comparison followed by a conditional reset-by-subtraction pass.
//!
//! Every operation updates the [`EnergyCounters`] ledger; the calibrated
//! model in [`crate::energy`] prices the ledger in joules.

use super::array::SramArray;
use super::counters::EnergyCounters;
use super::pc::{Pc, PcMode};
use super::shape::OperandShape;
use crate::snn::quant::{bit_of, wrap};

/// Static configuration of a macro instance.
#[derive(Debug, Clone, Copy)]
pub struct MacroConfig {
    /// Array rows (512 in the fabricated chip).
    pub rows: usize,
    /// Array columns / PCs (256 in the fabricated chip).
    pub cols: usize,
    /// Weight bit-width (arbitrary, ≥1 — contribution #1).
    pub w_bits: u32,
    /// Membrane-potential bit-width (arbitrary, ≥1).
    pub p_bits: u32,
    /// Columns per operand (`N_C`, contribution #2 — operand shaping).
    pub n_c: u32,
    /// Synapses stored per neuron.
    pub fan_in: usize,
    /// Parallel neurons resident in the macro.
    pub neurons: usize,
}

impl MacroConfig {
    /// The fabricated chip's array dimensions with a chosen operand config.
    pub fn flexspim(w_bits: u32, p_bits: u32, n_c: u32, fan_in: usize, neurons: usize) -> Self {
        MacroConfig { rows: 512, cols: 256, w_bits, p_bits, n_c, fan_in, neurons }
    }

    /// Weight operand shape.
    pub fn shape_w(&self) -> OperandShape {
        OperandShape::new(self.w_bits, self.n_c)
    }

    /// Membrane-potential operand shape.
    pub fn shape_p(&self) -> OperandShape {
        OperandShape::new(self.p_bits, self.n_c)
    }

    /// Rows used per neuron group.
    pub fn rows_per_neuron(&self) -> usize {
        self.fan_in * self.shape_w().n_r() as usize + self.shape_p().n_r() as usize
    }

    /// Internal row-cycles per synaptic accumulate.
    pub fn cycles_per_accumulate(&self) -> u64 {
        self.shape_p().n_r() as u64
    }

    /// Validate that the configuration fits the array.
    pub fn validate(&self) -> Result<(), String> {
        if self.neurons == 0 || self.fan_in == 0 {
            return Err("need at least one neuron and one synapse".into());
        }
        let need_cols = self.neurons * self.n_c as usize;
        if need_cols > self.cols {
            return Err(format!(
                "column overflow: {need_cols} needed, {} available",
                self.cols
            ));
        }
        let need_rows = self.rows_per_neuron();
        if need_rows > self.rows {
            return Err(format!(
                "row overflow: {need_rows} needed, {} available",
                self.rows
            ));
        }
        Ok(())
    }

    /// Peak synaptic throughput at `freq_hz` (SOP/s): all resident neurons
    /// accumulate in parallel, one accumulate per `cycles_per_accumulate`.
    pub fn peak_sops(&self, freq_hz: f64) -> f64 {
        self.neurons as f64 * freq_hz / self.cycles_per_accumulate() as f64
    }
}

/// The macro simulator.
///
/// The type is `Send` (plain owned buffers, no interior mutability), so the
/// parallel inference engine can host one macro per layer shard per worker
/// thread — asserted by a compile-time check in the tests below.
#[derive(Debug, Clone)]
pub struct CimMacro {
    cfg: MacroConfig,
    array: SramArray,
    pcs: Vec<Pc>,
    /// Decoded mirror of the stored weights (`[neuron][synapse]`, row-major)
    /// maintained by `load_weight`. The array remains the source of truth
    /// (`peek_weight` reads it back bit by bit); the mirror only spares the
    /// accumulate hot loop a bit-gather per operand.
    w_cache: Vec<i64>,
    counters: EnergyCounters,
}

impl CimMacro {
    /// Build a macro; PC modes are derived from the layout (the silicon
    /// equivalent: the controller writes the two control bitcells per PC).
    pub fn new(cfg: MacroConfig) -> Result<Self, String> {
        cfg.validate()?;
        let array = SramArray::new(cfg.rows, cfg.cols);
        let mut pcs = vec![Pc::default(); cfg.cols];
        for n in 0..cfg.neurons {
            for c in 0..cfg.n_c as usize {
                let col = n * cfg.n_c as usize + c;
                pcs[col].mode = if c == 0 {
                    PcMode::Boundary
                } else {
                    // Even rows ripple left→right; the static control bits
                    // encode the chain topology, parity picks direction.
                    PcMode::ChainLeft
                };
            }
        }
        let w_cache = vec![0i64; cfg.neurons * cfg.fan_in];
        Ok(CimMacro { cfg, array, pcs, w_cache, counters: EnergyCounters::new() })
    }

    /// Configuration.
    pub fn config(&self) -> &MacroConfig {
        &self.cfg
    }

    /// Energy-event ledger accumulated so far.
    pub fn counters(&self) -> &EnergyCounters {
        &self.counters
    }

    /// Reset the ledger (e.g. after warm-up).
    pub fn reset_counters(&mut self) {
        self.counters = EnergyCounters::new();
    }

    fn col_base(&self, neuron: usize) -> usize {
        debug_assert!(neuron < self.cfg.neurons);
        neuron * self.cfg.n_c as usize
    }

    fn weight_row_base(&self, synapse: usize) -> usize {
        debug_assert!(synapse < self.cfg.fan_in);
        synapse * self.cfg.shape_w().n_r() as usize
    }

    fn vmem_row_base(&self) -> usize {
        self.cfg.fan_in * self.cfg.shape_w().n_r() as usize
    }

    // ---------------------------------------------------------------- I/O

    /// Load a weight through the I/O port (counted as SRAM writes).
    pub fn load_weight(&mut self, neuron: usize, synapse: usize, value: i64) {
        let shape = self.cfg.shape_w();
        let base_row = self.weight_row_base(synapse);
        let base_col = self.col_base(neuron);
        self.write_operand(value, &shape, base_row, base_col, self.cfg.w_bits);
        self.w_cache[neuron * self.cfg.fan_in + synapse] = wrap(value, self.cfg.w_bits);
    }

    /// Load a membrane potential through the I/O port.
    pub fn load_vmem(&mut self, neuron: usize, value: i64) {
        let shape = self.cfg.shape_p();
        let base_row = self.vmem_row_base();
        let base_col = self.col_base(neuron);
        self.write_operand(value, &shape, base_row, base_col, self.cfg.p_bits);
    }

    fn write_operand(&mut self, value: i64, shape: &OperandShape, base_row: usize, base_col: usize, bits: u32) {
        let v = wrap(value, bits);
        for row in 0..shape.n_r() {
            for col in 0..shape.n_c {
                if let Some(pos) = shape.bit_at(row, col) {
                    let b = bit_of(v, pos, bits);
                    self.array.set(base_row + row as usize, base_col + col as usize, b);
                    self.counters.sram_writes += 1;
                    self.counters.io_bits += 1;
                }
            }
        }
    }

    /// Drain a membrane potential through the I/O port (counted).
    pub fn read_vmem(&mut self, neuron: usize) -> i64 {
        let v = self.peek_vmem(neuron);
        self.counters.sram_reads += self.cfg.p_bits as u64;
        self.counters.io_bits += self.cfg.p_bits as u64;
        v
    }

    /// Test/debug view of a stored membrane potential (not counted).
    pub fn peek_vmem(&self, neuron: usize) -> i64 {
        self.read_operand_raw(self.cfg.shape_p(), self.vmem_row_base(), self.col_base(neuron), self.cfg.p_bits)
    }

    /// Test/debug view of a stored weight (not counted).
    pub fn peek_weight(&self, neuron: usize, synapse: usize) -> i64 {
        self.read_operand_raw(
            self.cfg.shape_w(),
            self.weight_row_base(synapse),
            self.col_base(neuron),
            self.cfg.w_bits,
        )
    }

    fn read_operand_raw(&self, shape: OperandShape, base_row: usize, base_col: usize, bits: u32) -> i64 {
        let mut acc: i64 = 0;
        for row in 0..shape.n_r() {
            for col in 0..shape.n_c {
                if let Some(pos) = shape.bit_at(row, col) {
                    if self.array.get(base_row + row as usize, base_col + col as usize) {
                        if pos == bits - 1 {
                            acc -= 1i64 << pos; // MSB carries negative weight
                        } else {
                            acc += 1i64 << pos;
                        }
                    }
                }
            }
        }
        acc
    }

    // ------------------------------------------------------------- compute

    /// One synaptic CIM accumulate: `v ← wrap(v + w[synapse], p_bits)` for
    /// every resident neuron whose `mask` entry is true (`None` = all).
    ///
    /// Models `N_R_p` row-cycles of the 5-phase operation. Masked and
    /// unowned columns sit in standby (87 % energy reduction, Fig. 7a).
    ///
    /// The event ledger is derived analytically per row (the per-row adder
    /// programme is a pure function of the operand shape), while the data
    /// update runs word-level: each active neuron's stored operands are
    /// gathered once, added as two's-complement words, and the sum bits are
    /// scattered back. This replaces the original per-bit full-adder ripple
    /// — it is the hot loop every engine worker spins on — and is pinned to
    /// the bit-serial semantics by `prop_accumulate_bit_exact_across_shapes`
    /// below plus the MAC+IF oracle properties in `rust/tests/property_cim.rs`
    /// (`prop_timestep_equals_mac_if_oracle` and friends).
    pub fn cim_accumulate(&mut self, synapse: usize, mask: Option<&[bool]>) {
        assert!(synapse < self.cfg.fan_in);
        if let Some(m) = mask {
            assert_eq!(m.len(), self.cfg.neurons);
        }
        let shape_p = self.cfg.shape_p();
        let n_r_p = shape_p.n_r();
        let w_row_base = self.weight_row_base(synapse);
        let v_row_base = self.vmem_row_base();
        let n_c = self.cfg.n_c;
        let p_bits = self.cfg.p_bits;
        let w_bits = self.cfg.w_bits;

        let active_neurons: Vec<usize> = (0..self.cfg.neurons)
            .filter(|&n| mask.map_or(true, |m| m[n]))
            .collect();
        if n_c == 1 {
            // Bit-serial layout: every neuron owns exactly one column, so
            // the whole row of 1-bit adders evaluates as word-parallel
            // boolean algebra (64 PCs per u64) — same events, same result,
            // ~20x faster simulation. Verified against the generic path by
            // the shape-invariance property tests.
            return self.accumulate_serial_wordwise(w_row_base, &active_neurons);
        }
        let n_active = active_neurons.len() as u64;
        let active_cols = n_active * n_c as u64;

        // --- Ledger: one entry per row-cycle. Within row `row`, the shared
        // adder programme covers bit positions `[row·N_C, row·N_C + len)`;
        // positions at or beyond `w_bits` read the emulation bit (sign
        // extension) instead of a stored weight bit.
        for row in 0..n_r_p {
            let start = row * n_c;
            let len = n_c.min(p_bits - start) as u64;
            let w_in_row = w_bits.saturating_sub(start).min(len as u32) as u64;
            let eb_in_row = len - w_in_row;

            // Phases 1-2: precharge + dual-WL activation.
            self.counters.cim_cycles += 1;
            self.counters.wl_activations += 1;
            self.counters.active_col_cycles += active_cols;
            self.counters.standby_col_cycles += self.cfg.cols as u64 - active_cols;
            self.counters.sa_reads += 2 * active_cols;
            // Phases 3-5: adder evaluation, carry ripple, write-back.
            self.counters.eb_reads += eb_in_row * n_active;
            self.counters.adder_ops += len * n_active;
            self.counters.writebacks += len * n_active;
            self.counters.carry_hops += (len - 1) * n_active;
        }

        // --- Data: word-level accumulate per neuron group. `w_cache` holds
        // the decoded (w_bits-wrapped) weight; only its low p_bits matter
        // for the sum, exactly as in the bit-serial walk.
        for &n in &active_neurons {
            let base_col = self.col_base(n);
            let w = self.w_cache[n * self.cfg.fan_in + synapse];
            let v = self.read_operand_raw(shape_p, v_row_base, base_col, p_bits);
            self.write_operand_raw(wrap(v + w, p_bits), &shape_p, v_row_base, base_col, p_bits);
        }
        self.counters.sops += n_active;
    }

    /// Uncounted operand scatter: write `value`'s bits into the array at a
    /// shaped rectangle (compute-path write-back, not an I/O-port load).
    fn write_operand_raw(
        &mut self,
        value: i64,
        shape: &OperandShape,
        base_row: usize,
        base_col: usize,
        bits: u32,
    ) {
        for pos in 0..bits {
            self.array.set(
                base_row + shape.row_of_bit(pos) as usize,
                base_col + shape.col_of_bit(pos) as usize,
                bit_of(value, pos, bits),
            );
        }
    }

    /// Word-parallel accumulate for the `N_C = 1` bit-serial layout: one
    /// u64 lane carries 64 peripheral circuits. Carry registers live in a
    /// per-column carry word that hops rows in place (with `N_C = 1` the
    /// ping-pong is trivial: the carry stays in its own column).
    fn accumulate_serial_wordwise(&mut self, w_row_base: usize, active: &[usize]) {
        let p_bits = self.cfg.p_bits as usize;
        let w_bits = self.cfg.w_bits as usize;
        let v_row_base = self.vmem_row_base();
        let words = self.cfg.cols.div_ceil(64);

        // Active-column mask (column == neuron index for N_C = 1).
        let mut mask = vec![0u64; words];
        for &n in active {
            mask[n / 64] |= 1u64 << (n % 64);
        }

        // Emulation-bit word: weight sign from the stored MSB row.
        let sign_w: Vec<u64> = self.array.row_words(w_row_base + w_bits - 1).to_vec();

        let n_active = active.len() as u64;
        let mut carry = vec![0u64; words];
        let mut out = vec![0u64; words];
        for row in 0..p_bits {
            self.counters.cim_cycles += 1;
            self.counters.wl_activations += 1;
            self.counters.active_col_cycles += n_active;
            self.counters.standby_col_cycles += self.cfg.cols as u64 - n_active;
            self.counters.sa_reads += 2 * n_active;
            self.counters.adder_ops += n_active;
            self.counters.writebacks += n_active;
            if row >= w_bits {
                self.counters.eb_reads += n_active;
            }

            let a_src: &[u64] = if row < w_bits {
                self.array.row_words(w_row_base + row)
            } else {
                &sign_w
            };
            // Copy a to avoid aliasing with the write below.
            let a_row: Vec<u64> = a_src.to_vec();
            let v_row = v_row_base + row;
            {
                let b_row = self.array.row_words(v_row);
                for w in 0..words {
                    let a = a_row[w] & mask[w];
                    let b = b_row[w];
                    let c = carry[w];
                    let sum = a ^ b ^ c;
                    let cout = (a & b) | (c & (a ^ b));
                    out[w] = (sum & mask[w]) | (b & !mask[w]);
                    carry[w] = cout & mask[w];
                }
            }
            self.array.write_row_words(v_row, &out);
        }
        self.counters.sops += n_active;
    }

    /// Threshold step for all resident neurons: bit-serial MSB-first
    /// comparison against `threshold`, then conditional reset-by-
    /// subtraction for neurons that fired. Returns the spike vector.
    pub fn cim_fire(&mut self, threshold: i64) -> Vec<bool> {
        let shape_p = self.cfg.shape_p();
        let n_r_p = shape_p.n_r();
        let p_bits = self.cfg.p_bits;
        let t = wrap(threshold, p_bits);
        let v_row_base = self.vmem_row_base();
        let n_c = self.cfg.n_c;
        let total_cols = (self.cfg.neurons * n_c as usize) as u64;

        // --- Comparison pass: walk rows MSB→LSB; within a row, bits in
        // descending significance. The controller broadcasts threshold bits.
        for pc in self.pcs.iter_mut() {
            pc.reset_cmp();
        }
        let mut fired = vec![false; self.cfg.neurons];
        for row in (0..n_r_p).rev() {
            self.counters.cim_cycles += 1;
            self.counters.wl_activations += 1;
            self.counters.active_col_cycles += total_cols;
            self.counters.standby_col_cycles += self.cfg.cols as u64 - total_cols;
            self.counters.sa_reads += total_cols;
            // Row programme (MSB-of-row first), shared by all neuron
            // groups: (col_offset, threshold bit, is_sign).
            let mut order = shape_p.visit_order(row);
            order.reverse();
            let programme: Vec<(usize, bool, bool)> = order
                .iter()
                .filter_map(|&co| {
                    shape_p.bit_at(row, co).map(|pos| {
                        (co as usize, bit_of(t, pos, p_bits), pos == p_bits - 1)
                    })
                })
                .collect();
            self.counters.compare_ops +=
                programme.len() as u64 * self.cfg.neurons as u64;
            let v_row = v_row_base + row as usize;
            for n in 0..self.cfg.neurons {
                let base_col = self.col_base(n);
                // Comparator state is carried per neuron group in the
                // group's boundary PC.
                if self.pcs[base_col].cmp_state != super::pc::CmpState::Equal {
                    continue; // latched: the silicon comparator is idle too
                }
                for &(co, t_bit, is_sign) in &programme {
                    let v_bit = self.array.get(v_row, base_col + co);
                    let pc = &mut self.pcs[base_col];
                    pc.compare_step(v_bit, t_bit, is_sign);
                }
            }
        }
        for (n, f) in fired.iter_mut().enumerate() {
            // Greater or Equal fires (v >= t).
            *f = self.pcs[self.col_base(n)].compare_result();
            self.counters.io_bits += 1; // spike out through the port
        }

        // --- Conditional subtraction pass: v ← v - t for fired neurons,
        // implemented as bit-serial add of (!t) with initial carry 1.
        let any = fired.iter().any(|&f| f);
        if any {
            let active: Vec<usize> =
                (0..self.cfg.neurons).filter(|&n| fired[n]).collect();
            let active_cols = active.len() as u64 * n_c as u64;
            for row in 0..n_r_p {
                self.counters.cim_cycles += 1;
                self.counters.wl_activations += 1;
                self.counters.active_col_cycles += active_cols;
                self.counters.standby_col_cycles += self.cfg.cols as u64 - active_cols;
                self.counters.sa_reads += active_cols;
                // Row programme shared by all fired neurons:
                // (col_offset, !t bit broadcast by the controller).
                let programme: Vec<(usize, bool)> = shape_p
                    .visit_order(row)
                    .iter()
                    .filter_map(|&co| {
                        shape_p
                            .bit_at(row, co)
                            .map(|pos| (co as usize, !bit_of(t, pos, p_bits)))
                    })
                    .collect();
                self.counters.adder_ops += programme.len() as u64 * active.len() as u64;
                self.counters.writebacks += programme.len() as u64 * active.len() as u64;
                self.counters.carry_hops +=
                    (programme.len().saturating_sub(1)) as u64 * active.len() as u64;
                let v_row = v_row_base + row as usize;
                for &n in &active {
                    let base_col = self.col_base(n);
                    let first_col = base_col + programme[0].0;
                    let mut carry =
                        if row == 0 { true } else { self.pcs[first_col].carry_reg };
                    let mut last_col = first_col;
                    for &(co, a) in &programme {
                        let col = base_col + co;
                        let b = self.array.get(v_row, col);
                        let (sum, cout) = Pc::full_add(a, b, carry);
                        self.array.set(v_row, col, sum);
                        carry = cout;
                        last_col = col;
                    }
                    self.pcs[last_col].carry_reg = carry;
                }
            }
        }
        fired
    }

    /// Convenience: process one timestep of input spikes event-driven —
    /// accumulate every spiking synapse, then fire. Returns output spikes.
    pub fn timestep(&mut self, spikes_in: &[bool], threshold: i64) -> Vec<bool> {
        assert_eq!(spikes_in.len(), self.cfg.fan_in);
        for (j, &s) in spikes_in.iter().enumerate() {
            if s {
                self.cim_accumulate(j, None);
            }
        }
        self.cim_fire(threshold)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::quant::{max_val, min_val};
    use crate::util::proptest_lite::{check, prop_eq, Config};

    fn mk(w_bits: u32, p_bits: u32, n_c: u32, fan_in: usize, neurons: usize) -> CimMacro {
        CimMacro::new(MacroConfig::flexspim(w_bits, p_bits, n_c, fan_in, neurons)).unwrap()
    }

    #[test]
    fn macro_is_send_and_sync() {
        // The engine hosts one macro per layer shard per worker thread.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<CimMacro>();
        assert_send_sync::<EnergyCounters>();
    }

    #[test]
    fn weight_cache_mirrors_array() {
        // The hot-loop weight mirror must always agree with the bit-level
        // readback, including overwrites and out-of-range wrap-on-load.
        let mut m = mk(4, 9, 3, 3, 4);
        m.load_weight(1, 2, -5);
        m.load_weight(1, 2, 7);
        m.load_weight(2, 0, 9); // wraps to -7 in 4 bits
        assert_eq!(m.peek_weight(1, 2), 7);
        assert_eq!(m.peek_weight(2, 0), -7);
        // The accumulate path consumes the mirror; results must match the
        // array readback semantics.
        m.load_vmem(1, 10);
        m.load_vmem(2, 10);
        m.cim_accumulate(2, None);
        m.cim_accumulate(0, None);
        assert_eq!(m.peek_vmem(1), 10 + 7);
        assert_eq!(m.peek_vmem(2), 10 - 7);
    }

    #[test]
    fn weight_vmem_roundtrip() {
        let mut m = mk(5, 10, 3, 4, 8);
        m.load_weight(2, 1, -13);
        m.load_vmem(2, 301);
        assert_eq!(m.peek_weight(2, 1), -13);
        assert_eq!(m.peek_vmem(2), 301);
        // Other slots untouched.
        assert_eq!(m.peek_weight(2, 0), 0);
        assert_eq!(m.peek_vmem(3), 0);
    }

    #[test]
    fn accumulate_matches_golden_basic() {
        let mut m = mk(4, 8, 1, 2, 4); // pure bit-serial
        for n in 0..4 {
            m.load_weight(n, 0, n as i64 - 2); // -2,-1,0,1
            m.load_vmem(n, 10 * n as i64);
        }
        m.cim_accumulate(0, None);
        for n in 0..4 {
            assert_eq!(m.peek_vmem(n), wrap(10 * n as i64 + (n as i64 - 2), 8), "n={n}");
        }
    }

    #[test]
    fn accumulate_wraps_like_two_complement() {
        let mut m = mk(4, 4, 2, 1, 1);
        m.load_weight(0, 0, 5);
        m.load_vmem(0, 6);
        m.cim_accumulate(0, None); // 11 -> wraps to -5 in 4 bits
        assert_eq!(m.peek_vmem(0), -5);
    }

    #[test]
    fn sign_extension_via_eb() {
        // w_bits < p_bits: negative weights must sign-extend over the
        // emulation bits for upper vmem rows.
        let mut m = mk(3, 12, 2, 1, 2);
        m.load_weight(0, 0, -4); // most negative 3-bit value
        m.load_weight(1, 0, 3);
        m.load_vmem(0, 100);
        m.load_vmem(1, 100);
        m.cim_accumulate(0, None);
        assert_eq!(m.peek_vmem(0), 96);
        assert_eq!(m.peek_vmem(1), 103);
        assert!(m.counters().eb_reads > 0, "EB must have been exercised");
    }

    #[test]
    fn masked_neurons_untouched() {
        let mut m = mk(4, 8, 1, 1, 3);
        for n in 0..3 {
            m.load_weight(n, 0, 3);
            m.load_vmem(n, 1);
        }
        m.cim_accumulate(0, Some(&[true, false, true]));
        assert_eq!(m.peek_vmem(0), 4);
        assert_eq!(m.peek_vmem(1), 1, "masked neuron unchanged");
        assert_eq!(m.peek_vmem(2), 4);
    }

    #[test]
    fn fire_compare_and_reset() {
        let mut m = mk(4, 8, 2, 1, 3);
        m.load_vmem(0, 50);
        m.load_vmem(1, 20);
        m.load_vmem(2, 30); // exactly at threshold
        let spikes = m.cim_fire(30);
        assert_eq!(spikes, vec![true, false, true]);
        assert_eq!(m.peek_vmem(0), 20, "reset by subtraction");
        assert_eq!(m.peek_vmem(1), 20, "subthreshold untouched");
        assert_eq!(m.peek_vmem(2), 0);
    }

    #[test]
    fn fire_with_negative_vmem() {
        let mut m = mk(4, 6, 3, 1, 2);
        m.load_vmem(0, -5);
        m.load_vmem(1, 7);
        let spikes = m.cim_fire(3);
        assert_eq!(spikes, vec![false, true]);
        assert_eq!(m.peek_vmem(0), -5);
        assert_eq!(m.peek_vmem(1), 4);
    }

    #[test]
    fn timestep_matches_lif_layer() {
        use crate::snn::lif::LifLayer;
        use crate::snn::quant::Resolution;
        let res = Resolution::new(4, 10);
        let weights = vec![
            vec![3, -2, 1, 4],
            vec![-1, -1, 2, 2],
            vec![4, 4, 4, 4],
        ];
        let mut golden = LifLayer::new(weights.clone(), res, 6);
        let mut m = mk(4, 10, 2, 4, 3);
        for (n, row) in weights.iter().enumerate() {
            for (j, &w) in row.iter().enumerate() {
                m.load_weight(n, j, w);
            }
        }
        let patterns = [
            vec![true, false, true, false],
            vec![true, true, true, true],
            vec![false, false, false, true],
            vec![true, false, false, false],
        ];
        for p in &patterns {
            let expect = golden.step(p);
            let got = m.timestep(p, 6);
            assert_eq!(got, expect, "spikes for {p:?}");
            for n in 0..3 {
                assert_eq!(m.peek_vmem(n), golden.v[n], "vmem neuron {n}");
            }
        }
    }

    #[test]
    fn prop_accumulate_bit_exact_across_shapes() {
        // The flagship property: for random resolutions, shapes, and
        // operand values, the bit-serial shaped CIM add equals wrapped
        // integer addition — FlexSpIM's arbitrary resolution (contribution
        // #1) and arbitrary shape (contribution #2) preserve exactness.
        check(
            "cim-accumulate-bit-exact",
            &Config { cases: 120, ..Default::default() },
            |c| {
                let w_bits = c.rng.range_i64(1, 12) as u32;
                let p_bits = c.rng.range_i64(w_bits as i64, 20) as u32;
                let n_c = c.rng.range_i64(1, p_bits as i64) as u32;
                let neurons = c.rng.range_usize(1, 4);
                let fan_in = c.rng.range_usize(1, 3);
                let cfg = MacroConfig::flexspim(w_bits, p_bits, n_c, fan_in, neurons);
                if cfg.validate().is_err() {
                    return Ok(()); // skip configs that don't fit
                }
                let mut m = CimMacro::new(cfg).unwrap();
                let mut golden = vec![0i64; neurons];
                let mut ws = vec![vec![0i64; fan_in]; neurons];
                for n in 0..neurons {
                    for j in 0..fan_in {
                        let w = c.rng.range_i64(min_val(w_bits), max_val(w_bits));
                        ws[n][j] = w;
                        m.load_weight(n, j, w);
                    }
                    let v = c.rng.range_i64(min_val(p_bits), max_val(p_bits));
                    golden[n] = v;
                    m.load_vmem(n, v);
                }
                for _ in 0..4 {
                    let j = c.rng.range_usize(0, fan_in - 1);
                    m.cim_accumulate(j, None);
                    for n in 0..neurons {
                        golden[n] = wrap(golden[n] + ws[n][j], p_bits);
                    }
                }
                for n in 0..neurons {
                    prop_eq(
                        m.peek_vmem(n),
                        golden[n],
                        &format!("w={w_bits} p={p_bits} n_c={n_c} neuron {n}"),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_shape_invariance() {
        // Same operands, different shapes -> identical results (the paper's
        // energy varies <24 % across shapes, the *values* not at all).
        check("cim-shape-invariance", &Config { cases: 60, ..Default::default() }, |c| {
            let w_bits = c.rng.range_i64(2, 8) as u32;
            let p_bits = c.rng.range_i64(w_bits as i64, 16) as u32;
            let w = c.rng.range_i64(min_val(w_bits), max_val(w_bits));
            let v0 = c.rng.range_i64(min_val(p_bits), max_val(p_bits));
            let mut results = Vec::new();
            for n_c in 1..=p_bits {
                let cfg = MacroConfig::flexspim(w_bits, p_bits, n_c, 1, 1);
                if cfg.validate().is_err() {
                    continue;
                }
                let mut m = CimMacro::new(cfg).unwrap();
                m.load_weight(0, 0, w);
                m.load_vmem(0, v0);
                m.cim_accumulate(0, None);
                results.push(m.peek_vmem(0));
            }
            let expect = wrap(v0 + w, p_bits);
            for r in &results {
                prop_eq(*r, expect, &format!("w={w} v0={v0} p_bits={p_bits}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn counters_track_shape_activity() {
        // 16-bit operand bit-serial (1 col × 16 rows) vs bit-parallel
        // (16 cols × 1 row): same adder work, different cycle counts.
        let mut serial = mk(8, 16, 1, 1, 1);
        serial.load_weight(0, 0, 7);
        serial.cim_accumulate(0, None);
        let s = *serial.counters();

        let mut parallel = mk(8, 16, 16, 1, 1);
        parallel.load_weight(0, 0, 7);
        parallel.cim_accumulate(0, None);
        let p = parallel.counters();

        assert_eq!(s.cim_cycles, 16);
        assert_eq!(p.cim_cycles, 1);
        assert_eq!(s.adder_ops, p.adder_ops, "same total adder evaluations");
        assert_eq!(s.carry_hops, 0, "bit-serial: no inter-PC hops");
        assert_eq!(p.carry_hops, 15, "bit-parallel: full ripple");
        assert_eq!(s.sops, 1);
        assert_eq!(p.sops, 1);
    }

    #[test]
    fn validate_rejects_overflow() {
        assert!(MacroConfig::flexspim(8, 16, 1, 600, 1).validate().is_err());
        assert!(MacroConfig::flexspim(8, 16, 4, 4, 100).validate().is_err());
        assert!(MacroConfig::flexspim(8, 16, 4, 4, 64).validate().is_ok());
    }

    #[test]
    fn peak_throughput_matches_paper() {
        // Table I: 2.5 GSOPS at 157 MHz with 8b/16b bit-serial mapping and
        // 256 single-column neurons.
        let cfg = MacroConfig::flexspim(8, 16, 1, 1, 256);
        let gsops = cfg.peak_sops(157e6) / 1e9;
        assert!((gsops - 2.512).abs() < 0.02, "got {gsops}");
        // 1.2 GSOPS at 75.5 MHz.
        let gsops_lo = cfg.peak_sops(75.5e6) / 1e9;
        assert!((gsops_lo - 1.208).abs() < 0.02, "got {gsops_lo}");
    }
}
