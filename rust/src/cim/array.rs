//! The 6T SRAM bit array.
//!
//! Bits are packed row-major into `u64` words so that the two-wordline CIM
//! read (`AND` on BL, `NOR` on BLB — paper Fig. 2b) can be evaluated 64
//! columns at a time. The array itself is passive storage; all smarts live
//! in the peripheral circuits ([`super::pc`]).

/// Dense bit array with row/column addressing.
#[derive(Debug, Clone)]
pub struct SramArray {
    rows: usize,
    cols: usize,
    words_per_row: usize,
    bits: Vec<u64>,
}

impl SramArray {
    /// Allocate a zeroed array.
    pub fn new(rows: usize, cols: usize) -> Self {
        assert!(rows > 0 && cols > 0);
        let words_per_row = cols.div_ceil(64);
        SramArray { rows, cols, words_per_row, bits: vec![0; rows * words_per_row] }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Total capacity in bits.
    pub fn capacity_bits(&self) -> u64 {
        (self.rows * self.cols) as u64
    }

    #[inline]
    fn index(&self, row: usize, col: usize) -> (usize, u64) {
        debug_assert!(row < self.rows && col < self.cols, "({row},{col}) oob");
        (row * self.words_per_row + col / 64, 1u64 << (col % 64))
    }

    /// Read one bitcell.
    #[inline]
    pub fn get(&self, row: usize, col: usize) -> bool {
        let (w, m) = self.index(row, col);
        self.bits[w] & m != 0
    }

    /// Write one bitcell.
    #[inline]
    pub fn set(&mut self, row: usize, col: usize, v: bool) {
        let (w, m) = self.index(row, col);
        if v {
            self.bits[w] |= m;
        } else {
            self.bits[w] &= !m;
        }
    }

    /// The digital-CIM two-wordline read over a whole row pair (Fig. 2b):
    /// per column, `bl = A AND B` and `blb = NOT A AND NOT B` (NOR). The
    /// PC reconstructs `A XOR B = NOT(bl) AND NOT(blb)`.
    /// Returns packed `(and_words, nor_words)`.
    pub fn cim_read_pair(&self, row_a: usize, row_b: usize) -> (Vec<u64>, Vec<u64>) {
        assert!(row_a != row_b, "CIM read requires two distinct wordlines");
        let a = &self.bits[row_a * self.words_per_row..(row_a + 1) * self.words_per_row];
        let b = &self.bits[row_b * self.words_per_row..(row_b + 1) * self.words_per_row];
        let and: Vec<u64> = a.iter().zip(b).map(|(&x, &y)| x & y).collect();
        let nor: Vec<u64> = a.iter().zip(b).map(|(&x, &y)| !x & !y).collect();
        (and, nor)
    }

    /// Packed words of one row (read-only view).
    pub fn row_words(&self, row: usize) -> &[u64] {
        &self.bits[row * self.words_per_row..(row + 1) * self.words_per_row]
    }

    /// Write a full row from packed words (trailing bits beyond `cols`
    /// are masked off).
    pub fn write_row_words(&mut self, row: usize, words: &[u64]) {
        assert_eq!(words.len(), self.words_per_row);
        let dst = &mut self.bits[row * self.words_per_row..(row + 1) * self.words_per_row];
        dst.copy_from_slice(words);
        // Mask unused high bits of the last word for clean equality checks.
        let used = self.cols % 64;
        if used != 0 {
            let last = row * self.words_per_row + self.words_per_row - 1;
            self.bits[last] &= (1u64 << used) - 1;
        }
    }

    /// Clear all bits.
    pub fn clear(&mut self) {
        self.bits.fill(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn get_set_roundtrip() {
        let mut a = SramArray::new(8, 100);
        assert!(!a.get(3, 99));
        a.set(3, 99, true);
        assert!(a.get(3, 99));
        a.set(3, 99, false);
        assert!(!a.get(3, 99));
    }

    #[test]
    fn capacity() {
        let a = SramArray::new(512, 256);
        assert_eq!(a.capacity_bits(), 131_072); // 16 kB — the paper's macro
    }

    #[test]
    fn cim_read_truth_table() {
        let mut a = SramArray::new(2, 4);
        // col: 0 -> (0,0), 1 -> (0,1), 2 -> (1,0), 3 -> (1,1)
        a.set(0, 2, true);
        a.set(0, 3, true);
        a.set(1, 1, true);
        a.set(1, 3, true);
        let (and, nor) = a.cim_read_pair(0, 1);
        for col in 0..4 {
            let x = a.get(0, col);
            let y = a.get(1, col);
            assert_eq!(and[0] >> col & 1 == 1, x && y, "AND col {col}");
            assert_eq!(nor[0] >> col & 1 == 1, !x && !y, "NOR col {col}");
            // XOR reconstruction used by the PC adder:
            let xor = (and[0] >> col & 1 == 0) && (nor[0] >> col & 1 == 0);
            assert_eq!(xor, x ^ y, "XOR col {col}");
        }
    }

    #[test]
    #[should_panic(expected = "two distinct wordlines")]
    fn same_row_pair_rejected() {
        let a = SramArray::new(4, 4);
        a.cim_read_pair(2, 2);
    }

    #[test]
    fn row_words_roundtrip_with_masking() {
        let mut a = SramArray::new(2, 70); // 2 words/row, 6 used bits in word 1
        a.write_row_words(0, &[u64::MAX, u64::MAX]);
        assert!(a.get(0, 69));
        let w = a.row_words(0);
        assert_eq!(w[1], (1u64 << 6) - 1, "unused bits masked");
    }

    #[test]
    fn random_fill_consistency() {
        let mut rng = Rng::new(1);
        let mut a = SramArray::new(64, 200);
        let mut shadow = vec![vec![false; 200]; 64];
        for _ in 0..5000 {
            let r = rng.range_usize(0, 63);
            let c = rng.range_usize(0, 199);
            let v = rng.chance(0.5);
            a.set(r, c, v);
            shadow[r][c] = v;
        }
        for r in 0..64 {
            for c in 0..200 {
                assert_eq!(a.get(r, c), shadow[r][c]);
            }
        }
    }
}
