//! Column-group sharding of one logical macro across several [`CimMacro`]
//! instances.
//!
//! The parallel inference engine splits a layer's resident neurons across
//! macros per the [`crate::dataflow::Mapper`] assignment. Physically the
//! shards are column groups driven in lockstep by a common row decoder:
//! every shard sees the same wordline activations while only its own
//! columns toggle. [`ShardedMacro`] reproduces that contract in software —
//! it delegates every operation to the per-shard macros and merges the
//! per-operation counter deltas with
//! [`EnergyCounters::merge_lockstep`], so that an N-way sharded run is
//! bit- and ledger-identical to the equivalent un-sharded macro (pinned by
//! the interleaved property test below).

use super::counters::EnergyCounters;
use super::macro_unit::{CimMacro, MacroConfig};
use crate::snn::events::SpikeList;

/// Several [`CimMacro`] shards executing one logical macro in lockstep.
#[derive(Debug, Clone)]
pub struct ShardedMacro {
    shards: Vec<CimMacro>,
    /// First neuron index of each shard (parallel to `shards`).
    offsets: Vec<usize>,
    /// Total neurons across shards.
    neurons: usize,
    /// Column count of the logical (merged) macro — drives derived standby.
    total_cols: u64,
    counters: EnergyCounters,
}

impl ShardedMacro {
    /// Split `cfg` into shards of `parts[i]` neurons each (must sum to
    /// `cfg.neurons`). Each shard macro is sized tight to its column group
    /// (`parts[i] × N_C` columns); the logical macro keeps `cfg.cols`
    /// columns, so unowned columns show up as derived standby activity.
    pub fn split(cfg: MacroConfig, parts: &[usize]) -> Result<ShardedMacro, String> {
        if parts.is_empty() || parts.iter().any(|&p| p == 0) {
            return Err("every shard needs at least one neuron".into());
        }
        let total: usize = parts.iter().sum();
        if total != cfg.neurons {
            return Err(format!(
                "shard sizes sum to {total}, macro has {} neurons",
                cfg.neurons
            ));
        }
        let mut shards = Vec::with_capacity(parts.len());
        let mut offsets = Vec::with_capacity(parts.len());
        let mut start = 0usize;
        for &p in parts {
            let shard_cfg = MacroConfig {
                cols: p * cfg.n_c as usize,
                neurons: p,
                ..cfg
            };
            shards.push(CimMacro::new(shard_cfg)?);
            offsets.push(start);
            start += p;
        }
        Ok(ShardedMacro {
            shards,
            offsets,
            neurons: cfg.neurons,
            total_cols: cfg.cols as u64,
            counters: EnergyCounters::new(),
        })
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Total resident neurons.
    pub fn neurons(&self) -> usize {
        self.neurons
    }

    /// Lockstep-merged event ledger accumulated so far.
    pub fn counters(&self) -> &EnergyCounters {
        &self.counters
    }

    /// Reset the merged ledger and every shard ledger.
    pub fn reset_counters(&mut self) {
        self.counters = EnergyCounters::new();
        for s in &mut self.shards {
            s.reset_counters();
        }
    }

    /// Shard index and local neuron index for a global neuron index.
    fn locate(&self, neuron: usize) -> (usize, usize) {
        assert!(neuron < self.neurons, "neuron {neuron} out of range");
        let shard = self
            .offsets
            .partition_point(|&o| o <= neuron)
            .saturating_sub(1);
        (shard, neuron - self.offsets[shard])
    }

    /// Run `op` on every shard (passing the shard's first global neuron
    /// index) and fold the per-op counter deltas into the lockstep-merged
    /// ledger.
    fn lockstep<R>(&mut self, mut op: impl FnMut(&mut CimMacro, usize) -> R) -> Vec<R> {
        let mut deltas = Vec::with_capacity(self.shards.len());
        let mut outs = Vec::with_capacity(self.shards.len());
        for (s, &start) in self.shards.iter_mut().zip(&self.offsets) {
            let before = *s.counters();
            outs.push(op(s, start));
            deltas.push(s.counters().delta(&before));
        }
        self.counters
            .merge(&EnergyCounters::merge_lockstep(&deltas, self.total_cols));
        outs
    }

    /// Run `op` on the single shard owning `neuron` (passing the local
    /// neuron index) and fold its counter delta into the merged ledger.
    fn single_shard<R>(&mut self, neuron: usize, op: impl FnOnce(&mut CimMacro, usize) -> R) -> R {
        let (si, local) = self.locate(neuron);
        let before = *self.shards[si].counters();
        let out = op(&mut self.shards[si], local);
        let delta = self.shards[si].counters().delta(&before);
        self.counters
            .merge(&EnergyCounters::merge_lockstep(&[delta], self.total_cols));
        out
    }

    /// Load a weight into the owning shard (counted as I/O there).
    pub fn load_weight(&mut self, neuron: usize, synapse: usize, value: i64) {
        self.single_shard(neuron, |shard, local| shard.load_weight(local, synapse, value));
    }

    /// Load a membrane potential into the owning shard.
    pub fn load_vmem(&mut self, neuron: usize, value: i64) {
        self.single_shard(neuron, |shard, local| shard.load_vmem(local, value));
    }

    /// Test/debug view of a stored membrane potential (not counted).
    pub fn peek_vmem(&self, neuron: usize) -> i64 {
        let (si, local) = self.locate(neuron);
        self.shards[si].peek_vmem(local)
    }

    /// Test/debug view of a stored weight (not counted).
    pub fn peek_weight(&self, neuron: usize, synapse: usize) -> i64 {
        let (si, local) = self.locate(neuron);
        self.shards[si].peek_weight(local, synapse)
    }

    /// Lockstep synaptic accumulate across all shards; `mask` (if given)
    /// covers the global neuron range.
    pub fn cim_accumulate(&mut self, synapse: usize, mask: Option<&[bool]>) {
        if let Some(m) = mask {
            assert_eq!(m.len(), self.neurons);
        }
        self.lockstep(|shard, start| match mask {
            None => shard.cim_accumulate(synapse, None),
            Some(m) => {
                let local = &m[start..start + shard.config().neurons];
                shard.cim_accumulate(synapse, Some(local));
            }
        });
    }

    /// Lockstep threshold step; returns the concatenated spike vector in
    /// global neuron order.
    pub fn cim_fire(&mut self, threshold: i64) -> Vec<bool> {
        let fired = self.lockstep(|shard, _start| shard.cim_fire(threshold));
        fired.into_iter().flatten().collect()
    }

    /// Event-driven timestep: accumulate every spiking synapse, then fire.
    pub fn timestep(&mut self, spikes_in: &[bool], threshold: i64) -> Vec<bool> {
        // Same contract as `CimMacro::timestep`: a short/long spike vector
        // is a caller bug, not a partial update.
        assert_eq!(spikes_in.len(), self.shards[0].config().fan_in);
        for (j, &s) in spikes_in.iter().enumerate() {
            if s {
                self.cim_accumulate(j, None);
            }
        }
        self.cim_fire(threshold)
    }

    /// Event-driven timestep over a sparse [`SpikeList`]: walk the active
    /// synapse indices directly — no dense scan — then fire. Ledger- and
    /// bit-identical to [`Self::timestep`] on the densified vector, since
    /// the dense path also accumulates only active synapses (in the same
    /// ascending order).
    pub fn timestep_events(&mut self, spikes_in: &SpikeList, threshold: i64) -> Vec<bool> {
        assert_eq!(spikes_in.dim(), self.shards[0].config().fan_in);
        for &j in spikes_in.active() {
            self.cim_accumulate(j as usize, None);
        }
        self.cim_fire(threshold)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::quant::{max_val, min_val, wrap};
    use crate::util::proptest_lite::{check, prop_eq, Config};

    #[test]
    fn split_validates_partition() {
        let cfg = MacroConfig::flexspim(4, 8, 2, 2, 6);
        assert!(ShardedMacro::split(cfg, &[3, 3]).is_ok());
        assert!(ShardedMacro::split(cfg, &[4, 3]).is_err(), "sum mismatch");
        assert!(ShardedMacro::split(cfg, &[6, 0]).is_err(), "empty shard");
        assert!(ShardedMacro::split(cfg, &[]).is_err());
    }

    #[test]
    fn locate_and_peek_roundtrip() {
        let cfg = MacroConfig::flexspim(5, 10, 2, 2, 7);
        let mut sm = ShardedMacro::split(cfg, &[2, 3, 2]).unwrap();
        for n in 0..7 {
            sm.load_weight(n, 1, n as i64 - 3);
            sm.load_vmem(n, 11 * n as i64);
        }
        for n in 0..7 {
            assert_eq!(sm.peek_weight(n, 1), n as i64 - 3, "weight {n}");
            assert_eq!(sm.peek_vmem(n), 11 * n as i64, "vmem {n}");
        }
    }

    /// The satellite property: an interleaved sequence of accumulate/fire
    /// operations on a two-way sharded macro, merged through the lockstep
    /// counter-merge API, equals one un-sharded macro run — membrane
    /// potentials, spikes, and the full energy ledger.
    #[test]
    fn prop_two_shards_equal_one_macro() {
        check(
            "sharded-vs-monolithic",
            &Config { cases: 60, ..Default::default() },
            |c| {
                let w_bits = c.rng.range_i64(1, 8) as u32;
                let p_bits = c.rng.range_i64(w_bits as i64, 14) as u32;
                let n_c = c.rng.range_i64(1, p_bits as i64) as u32;
                let neurons = c.rng.range_usize(2, 8);
                let fan_in = c.rng.range_usize(1, 3);
                let cfg = MacroConfig {
                    rows: 512,
                    cols: neurons * n_c as usize + c.rng.range_usize(0, 8),
                    w_bits,
                    p_bits,
                    n_c,
                    fan_in,
                    neurons,
                };
                if cfg.validate().is_err() {
                    return Ok(());
                }
                let cut = c.rng.range_usize(1, neurons - 1);
                let mut full = CimMacro::new(cfg).unwrap();
                let mut sharded = ShardedMacro::split(cfg, &[cut, neurons - cut]).unwrap();

                for n in 0..neurons {
                    for j in 0..fan_in {
                        let w = c.rng.range_i64(min_val(w_bits), max_val(w_bits));
                        full.load_weight(n, j, w);
                        sharded.load_weight(n, j, w);
                    }
                    let v = c.rng.range_i64(min_val(p_bits), max_val(p_bits));
                    full.load_vmem(n, v);
                    sharded.load_vmem(n, v);
                }

                // Interleave accumulates (masked and unmasked) with fires.
                let theta = c.rng.range_i64(1, max_val(p_bits).max(1));
                for _ in 0..6 {
                    match c.rng.range_usize(0, 2) {
                        0 => {
                            let j = c.rng.range_usize(0, fan_in - 1);
                            full.cim_accumulate(j, None);
                            sharded.cim_accumulate(j, None);
                        }
                        1 => {
                            let j = c.rng.range_usize(0, fan_in - 1);
                            let m: Vec<bool> =
                                (0..neurons).map(|_| c.rng.chance(0.6)).collect();
                            full.cim_accumulate(j, Some(&m));
                            sharded.cim_accumulate(j, Some(&m));
                        }
                        _ => {
                            let a = full.cim_fire(theta);
                            let b = sharded.cim_fire(theta);
                            prop_eq(a, b, "spike vectors")?;
                        }
                    }
                }

                for n in 0..neurons {
                    prop_eq(
                        sharded.peek_vmem(n),
                        full.peek_vmem(n),
                        &format!("vmem neuron {n} (w={w_bits} p={p_bits} n_c={n_c})"),
                    )?;
                }
                prop_eq(
                    *sharded.counters(),
                    *full.counters(),
                    &format!("ledger (w={w_bits} p={p_bits} n_c={n_c} cut={cut})"),
                )
            },
        );
    }

    #[test]
    fn event_timestep_matches_dense_timestep() {
        let cfg = MacroConfig::flexspim(4, 9, 3, 4, 6);
        let mut dense = ShardedMacro::split(cfg, &[2, 4]).unwrap();
        let mut sparse = ShardedMacro::split(cfg, &[2, 4]).unwrap();
        for n in 0..6 {
            for j in 0..4 {
                let w = ((n * 7 + j) % 13) as i64 - 6;
                dense.load_weight(n, j, w);
                sparse.load_weight(n, j, w);
            }
        }
        let spikes = [true, false, false, true];
        let list = SpikeList::from_dense(&spikes);
        for t in 0..4 {
            let a = dense.timestep(&spikes, 15);
            let b = sparse.timestep_events(&list, 15);
            assert_eq!(a, b, "timestep {t}");
        }
        assert_eq!(dense.counters(), sparse.counters(), "ledger identity");
        for n in 0..6 {
            assert_eq!(dense.peek_vmem(n), sparse.peek_vmem(n), "neuron {n}");
        }
    }

    #[test]
    fn timestep_matches_monolithic() {
        let cfg = MacroConfig::flexspim(4, 9, 3, 4, 6);
        let mut full = CimMacro::new(cfg).unwrap();
        let mut sharded = ShardedMacro::split(cfg, &[1, 2, 3]).unwrap();
        for n in 0..6 {
            for j in 0..4 {
                let w = ((n * 5 + j * 3) % 15) as i64 - 7;
                full.load_weight(n, j, w);
                sharded.load_weight(n, j, w);
            }
        }
        let spikes = [true, false, true, true];
        for t in 0..5 {
            let a = full.timestep(&spikes, 20);
            let b = sharded.timestep(&spikes, 20);
            assert_eq!(a, b, "timestep {t}");
        }
        assert_eq!(sharded.counters(), full.counters());
        for n in 0..6 {
            assert_eq!(sharded.peek_vmem(n), full.peek_vmem(n));
            // Cross-check against the plain integer LIF semantics.
            let mut v = 0i64;
            for t in 0..5 {
                let _ = t;
                for (j, &s) in spikes.iter().enumerate() {
                    if s {
                        v = wrap(v + full.peek_weight(n, j), 9);
                    }
                }
                if v >= 20 {
                    v = wrap(v - 20, 9);
                }
            }
            assert_eq!(full.peek_vmem(n), v, "neuron {n} LIF oracle");
        }
    }
}
