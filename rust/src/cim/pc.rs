//! Peripheral circuit (PC) model.
//!
//! One PC sits under every column (paper Fig. 2e): dual sense amplifier,
//! a 1-bit full adder (Neural-Cache style [14]), a carry-select circuit,
//! a comparator bit, and I/O logic. Two control bitcells per column define
//! the PC state (Fig. 3d), which selects where the adder's carry-in comes
//! from — this is what chains neighboring PCs into arbitrary-width adders
//! and what powers unused columns down.

/// PC operating mode, decoded from the two control bitcells (Fig. 3d).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PcMode {
    /// Column unused: clock-gated, precharge disabled (87 % energy cut).
    Standby,
    /// First column of an operand: carry-in is 0 (row 0) or the PC's own
    /// stored carry register (subsequent rows, after ping-pong turn).
    Boundary,
    /// Chained column: carry-in arrives from the left neighbor's carry-out.
    ChainLeft,
    /// Chained column: carry-in arrives from the right neighbor's carry-out.
    ChainRight,
}

impl PcMode {
    /// Encode to the 2-bit control-bitcell pattern.
    pub fn encode(self) -> u8 {
        match self {
            PcMode::Standby => 0b00,
            PcMode::Boundary => 0b01,
            PcMode::ChainLeft => 0b10,
            PcMode::ChainRight => 0b11,
        }
    }

    /// Decode from the 2-bit control-bitcell pattern.
    pub fn decode(bits: u8) -> PcMode {
        match bits & 0b11 {
            0b00 => PcMode::Standby,
            0b01 => PcMode::Boundary,
            0b10 => PcMode::ChainLeft,
            _ => PcMode::ChainRight,
        }
    }
}

/// Per-column peripheral circuit state.
#[derive(Debug, Clone)]
pub struct Pc {
    /// Current mode (from control bitcells).
    pub mode: PcMode,
    /// Carry register: holds the inter-row carry at operand boundaries.
    pub carry_reg: bool,
    /// Comparator state for the bit-serial threshold comparison.
    pub cmp_state: CmpState,
}

/// Bit-serial comparator state (evaluated MSB→LSB).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpState {
    /// Still equal so far.
    Equal,
    /// Membrane potential proven greater than threshold.
    Greater,
    /// Membrane potential proven less than threshold.
    Less,
}

impl Default for Pc {
    fn default() -> Self {
        Pc { mode: PcMode::Standby, carry_reg: false, cmp_state: CmpState::Equal }
    }
}

impl Pc {
    /// Full-adder evaluation: returns `(sum, carry_out)`.
    ///
    /// The silicon computes this from the BL/BLB readout (Fig. 2b):
    /// `and = A·B`, `nor = !A·!B`, `xor = !and·!nor`, then
    /// `sum = xor ^ cin`, `cout = and + xor·cin` — identical truth table
    /// to the boolean formulation below, asserted by the unit test.
    #[inline]
    pub fn full_add(a: bool, b: bool, cin: bool) -> (bool, bool) {
        let sum = a ^ b ^ cin;
        let cout = (a & b) | (cin & (a ^ b));
        (sum, cout)
    }

    /// Full-adder evaluation from the CIM readout signals (AND/NOR of the
    /// two bitcells), as the PC actually receives them.
    #[inline]
    pub fn full_add_from_readout(and: bool, nor: bool, cin: bool) -> (bool, bool) {
        let xor = !and & !nor;
        let sum = xor ^ cin;
        let cout = and | (xor & cin);
        (sum, cout)
    }

    /// One MSB-first comparison step between a membrane bit and the
    /// corresponding threshold bit. For signed operands the MSB step is
    /// inverted (1 in the sign position means *smaller*).
    #[inline]
    pub fn compare_step(&mut self, v_bit: bool, t_bit: bool, is_sign_bit: bool) {
        if self.cmp_state != CmpState::Equal {
            return;
        }
        if v_bit != t_bit {
            let v_wins = if is_sign_bit { !v_bit } else { v_bit };
            self.cmp_state = if v_wins { CmpState::Greater } else { CmpState::Less };
        }
    }

    /// Resolve the comparison: `v >= threshold`.
    #[inline]
    pub fn compare_result(&self) -> bool {
        matches!(self.cmp_state, CmpState::Greater | CmpState::Equal)
    }

    /// Reset comparator for a new comparison.
    pub fn reset_cmp(&mut self) {
        self.cmp_state = CmpState::Equal;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_adder_truth_table() {
        for a in [false, true] {
            for b in [false, true] {
                for c in [false, true] {
                    let (s, co) = Pc::full_add(a, b, c);
                    let total = a as u8 + b as u8 + c as u8;
                    assert_eq!(s, total & 1 == 1);
                    assert_eq!(co, total >= 2);
                }
            }
        }
    }

    #[test]
    fn readout_adder_matches_boolean_adder() {
        // The PC sees (AND, NOR) from the bitlines, not (A, B). Both
        // formulations must agree for all input combinations (Fig. 2b).
        for a in [false, true] {
            for b in [false, true] {
                for c in [false, true] {
                    let and = a & b;
                    let nor = !a & !b;
                    assert_eq!(
                        Pc::full_add_from_readout(and, nor, c),
                        Pc::full_add(a, b, c),
                        "a={a} b={b} c={c}"
                    );
                }
            }
        }
    }

    #[test]
    fn mode_encoding_roundtrip() {
        for m in [PcMode::Standby, PcMode::Boundary, PcMode::ChainLeft, PcMode::ChainRight] {
            assert_eq!(PcMode::decode(m.encode()), m);
        }
    }

    #[test]
    fn comparator_unsigned_paths() {
        // v = 0b101 (5) vs t = 0b011 (3), MSB first, no sign bit.
        let mut pc = Pc::default();
        pc.compare_step(true, false, false); // MSB differs: v wins
        pc.compare_step(false, true, false); // latched; ignored
        pc.compare_step(true, true, false);
        assert!(pc.compare_result());

        pc.reset_cmp();
        // v = 2 (010) vs t = 3 (011): equal, equal, then t wins.
        pc.compare_step(false, false, false);
        pc.compare_step(true, true, false);
        pc.compare_step(false, true, false);
        assert!(!pc.compare_result());

        pc.reset_cmp();
        // equal values -> v >= t holds.
        for _ in 0..3 {
            pc.compare_step(true, true, false);
        }
        assert!(pc.compare_result());
    }

    #[test]
    fn comparator_signed_msb() {
        // v = -1 (sign bit 1) vs t = +1 (sign bit 0): v < t.
        let mut pc = Pc::default();
        pc.compare_step(true, false, true);
        assert!(!pc.compare_result());

        pc.reset_cmp();
        // v = +1 vs t = -1: v > t.
        pc.compare_step(false, true, true);
        assert!(pc.compare_result());
    }
}
