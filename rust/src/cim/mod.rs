//! Bit-accurate digital CIM macro simulator — the substrate that replaces
//! the paper's fabricated 40-nm chip.
//!
//! The FlexSpIM macro (paper Fig. 2d) is a 512×256 6T SRAM array whose
//! columns each carry a peripheral circuit (PC) with a dual sense
//! amplifier, a 1-bit full adder, carry-select logic, a comparator, and I/O
//! logic. Two wordlines are activated per internal cycle, giving each
//! column `AND`/`NOR` of the two stored bits, from which the PC forms a
//! full adder (Fig. 2b). Multi-bit operands are laid out over arbitrary
//! `N_R × N_C` rectangles (Fig. 3) — carries chain across neighboring PCs
//! within a row and hop rows through per-PC carry registers with a
//! ping-pong left/right direction.
//!
//! Everything architecturally observable is modeled: the 5-phase operation
//! (Fig. 2c), control-bitcell PC states, emulation-bit sign extension,
//! per-column standby gating, and an event ledger ([`counters`]) that the
//! calibrated energy model converts to joules.

pub mod array;
pub mod counters;
pub mod macro_unit;
pub mod ops;
pub mod pc;
pub mod shape;
pub mod sharded;

pub use array::SramArray;
pub use counters::EnergyCounters;
pub use macro_unit::{CimMacro, MacroConfig};
pub use pc::{Pc, PcMode};
pub use shape::OperandShape;
pub use sharded::ShardedMacro;
