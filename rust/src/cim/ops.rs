//! Timing model of the 5-phase CIM operation (paper Fig. 2c).
//!
//! Nominal measurement conditions: a 157-MHz *system* clock defines one
//! complete CIM row-operation, while a 942-MHz *internal* clock sequences
//! the phases inside it (942 / 157 = 6 internal ticks: five phases plus a
//! guard slot). This module turns cycle counts from the simulator into
//! wall-clock latency and throughput at any supported operating point.

/// The five phases of one CIM row-operation (Fig. 2c).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// 1 — precharge BL/BLB to VDD.
    Precharge,
    /// 2 — dual-WL activation: AND/NOR evaluation on BL/BLB.
    Evaluate,
    /// 3 — sum + carry generation in the PC.
    AddGenerate,
    /// 4 — half-select-prevention precharge.
    GuardPrecharge,
    /// 5 — write-back of the new membrane-potential bit.
    WriteBack,
}

/// All phases in execution order.
pub const PHASES: [Phase; 5] = [
    Phase::Precharge,
    Phase::Evaluate,
    Phase::AddGenerate,
    Phase::GuardPrecharge,
    Phase::WriteBack,
];

/// Macro operating point (supply + clocks), bounded by the silicon's
/// measured range (Table I: 0.9–1.1 V, 75.5–157 MHz).
#[derive(Debug, Clone, Copy)]
pub struct OperatingPoint {
    /// Core supply voltage in volts.
    pub vdd: f64,
    /// System clock (one CIM row-operation per cycle), Hz.
    pub system_clock_hz: f64,
}

impl OperatingPoint {
    /// Nominal point: 1.1 V, 157 MHz (paper §III-A).
    pub fn nominal() -> Self {
        OperatingPoint { vdd: 1.1, system_clock_hz: 157e6 }
    }

    /// Low-voltage point: 0.9 V, 75.5 MHz.
    pub fn low_voltage() -> Self {
        OperatingPoint { vdd: 0.9, system_clock_hz: 75.5e6 }
    }

    /// Internal phase clock: 6 ticks per system cycle (942 MHz at nominal).
    pub fn internal_clock_hz(&self) -> f64 {
        self.system_clock_hz * 6.0
    }

    /// Validate against the measured silicon envelope.
    pub fn validate(&self) -> Result<(), String> {
        if !(0.9..=1.1).contains(&self.vdd) {
            return Err(format!("vdd {} outside measured 0.9-1.1 V range", self.vdd));
        }
        if !(75.5e6..=157e6).contains(&self.system_clock_hz) {
            return Err(format!(
                "clock {} outside measured 75.5-157 MHz range",
                self.system_clock_hz
            ));
        }
        Ok(())
    }

    /// Wall-clock seconds for `cim_cycles` row-operations.
    pub fn latency_s(&self, cim_cycles: u64) -> f64 {
        cim_cycles as f64 / self.system_clock_hz
    }

    /// Linear frequency interpolation between the two measured points as a
    /// function of VDD (simple but monotone — adequate for scaling studies).
    pub fn at_vdd(vdd: f64) -> Self {
        let t = ((vdd - 0.9) / (1.1 - 0.9)).clamp(0.0, 1.0);
        OperatingPoint { vdd, system_clock_hz: 75.5e6 + t * (157e6 - 75.5e6) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_order_and_count() {
        assert_eq!(PHASES.len(), 5);
        assert_eq!(PHASES[0], Phase::Precharge);
        assert_eq!(PHASES[4], Phase::WriteBack);
    }

    #[test]
    fn nominal_clocks_match_paper() {
        let op = OperatingPoint::nominal();
        assert_eq!(op.vdd, 1.1);
        assert_eq!(op.system_clock_hz, 157e6);
        // 157 MHz × 6 = 942 MHz internal clock, as measured.
        assert!((op.internal_clock_hz() - 942e6).abs() < 1e3);
        op.validate().unwrap();
        OperatingPoint::low_voltage().validate().unwrap();
    }

    #[test]
    fn envelope_enforced() {
        assert!(OperatingPoint { vdd: 1.3, system_clock_hz: 100e6 }.validate().is_err());
        assert!(OperatingPoint { vdd: 1.0, system_clock_hz: 200e6 }.validate().is_err());
    }

    #[test]
    fn latency_scaling() {
        let op = OperatingPoint::nominal();
        // A 16-row accumulate takes 16 system cycles.
        let dt = op.latency_s(16);
        assert!((dt - 16.0 / 157e6).abs() < 1e-15);
    }

    #[test]
    fn vdd_interpolation_endpoints() {
        let lo = OperatingPoint::at_vdd(0.9);
        let hi = OperatingPoint::at_vdd(1.1);
        assert!((lo.system_clock_hz - 75.5e6).abs() < 1.0);
        assert!((hi.system_clock_hz - 157e6).abs() < 1.0);
        let mid = OperatingPoint::at_vdd(1.0);
        assert!(mid.system_clock_hz > lo.system_clock_hz);
        assert!(mid.system_clock_hz < hi.system_clock_hz);
    }
}
