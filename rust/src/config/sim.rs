//! Typed simulation configuration assembled from a TOML-lite document.

use std::path::Path;

use super::toml_lite::Doc;
use crate::dataflow::Policy;

/// Top-level simulation configuration (CLI `--config file.toml`).
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Number of CIM macros in the system.
    pub num_macros: usize,
    /// Dataflow policy.
    pub policy: Policy,
    /// Supply voltage (0.9–1.1 V envelope).
    pub vdd: f64,
    /// Samples per class for dataset runs.
    pub samples_per_class: usize,
    /// RNG seed.
    pub seed: u64,
    /// Timesteps per inference.
    pub timesteps: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            num_macros: 16,
            policy: Policy::HsOpt,
            vdd: 1.1,
            samples_per_class: 2,
            seed: 42,
            timesteps: 16,
        }
    }
}

impl SimConfig {
    /// Parse from a document, falling back to defaults per key.
    pub fn from_doc(doc: &Doc) -> Result<Self, String> {
        let d = SimConfig::default();
        let policy = match doc.str_or("sim.policy", "hs-opt").as_str() {
            "ws-only" => Policy::WsOnly,
            "os-only" => Policy::OsOnly,
            "hs-min" => Policy::HsMin,
            "hs-max" => Policy::HsMax,
            "hs-opt" => Policy::HsOpt,
            other => return Err(format!("unknown policy '{other}'")),
        };
        let cfg = SimConfig {
            num_macros: doc.int_or("sim.macros", d.num_macros as i64) as usize,
            policy,
            vdd: doc.float_or("sim.vdd", d.vdd),
            samples_per_class: doc.int_or("sim.samples_per_class", d.samples_per_class as i64)
                as usize,
            seed: doc.int_or("sim.seed", d.seed as i64) as u64,
            timesteps: doc.int_or("sim.timesteps", d.timesteps as i64) as usize,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &Path) -> Result<Self, String> {
        Self::from_doc(&Doc::load(path)?)
    }

    /// Sanity limits.
    pub fn validate(&self) -> Result<(), String> {
        if self.num_macros == 0 || self.num_macros > 4096 {
            return Err(format!("macros {} out of range", self.num_macros));
        }
        if !(0.9..=1.1).contains(&self.vdd) {
            return Err(format!("vdd {} outside 0.9-1.1 V", self.vdd));
        }
        if self.timesteps == 0 || self.timesteps > 1024 {
            return Err("timesteps out of range".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        SimConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_overrides() {
        let doc = Doc::parse(
            "[sim]\nmacros = 4\npolicy = \"hs-min\"\nvdd = 0.9\nseed = 7",
        )
        .unwrap();
        let c = SimConfig::from_doc(&doc).unwrap();
        assert_eq!(c.num_macros, 4);
        assert_eq!(c.policy, Policy::HsMin);
        assert_eq!(c.vdd, 0.9);
        assert_eq!(c.seed, 7);
        assert_eq!(c.timesteps, 16, "default retained");
    }

    #[test]
    fn rejects_bad_values() {
        let doc = Doc::parse("[sim]\npolicy = \"nope\"").unwrap();
        assert!(SimConfig::from_doc(&doc).is_err());
        let doc = Doc::parse("[sim]\nvdd = 1.5").unwrap();
        assert!(SimConfig::from_doc(&doc).is_err());
        let doc = Doc::parse("[sim]\nmacros = 0").unwrap();
        assert!(SimConfig::from_doc(&doc).is_err());
    }
}
