//! Configuration system.
//!
//! Experiments are driven by small TOML files (see `configs/` at the repo
//! root). serde is not vendored offline, so [`toml_lite`] implements the
//! subset we need (tables, strings, ints, floats, bools, homogeneous
//! arrays, comments) with typed accessors, and [`sim`] defines the typed
//! simulation config assembled from a parsed document.

pub mod sim;
pub mod toml_lite;

pub use sim::SimConfig;
pub use toml_lite::{Doc, Value};
