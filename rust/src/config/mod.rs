//! Configuration plumbing.
//!
//! Deployments are driven by small TOML files (see `configs/` at the repo
//! root). serde is not vendored offline, so [`toml_lite`] implements the
//! subset we need (tables, strings, ints, floats, bools, homogeneous
//! arrays, comments) with typed accessors. The typed deployment
//! configuration assembled from a parsed document lives in
//! [`crate::deploy`] ([`crate::deploy::DeploymentSpec`] subsumed the old
//! `SimConfig`).

pub mod toml_lite;

pub use toml_lite::{Doc, Value};
