//! A small TOML-subset parser.
//!
//! Supported: `[table]` / `[table.sub]` headers, `key = value` with string,
//! integer, float, boolean, and homogeneous array values, `#` comments and
//! blank lines. Unsupported TOML (multi-line strings, inline tables, dates,
//! array-of-tables) is rejected with a line-numbered error. This covers the
//! experiment configs in `configs/` without pulling in serde.

use std::collections::BTreeMap;

/// A parsed scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// 64-bit signed integer.
    Int(i64),
    /// 64-bit float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Homogeneous array.
    Array(Vec<Value>),
}

impl Value {
    /// String view, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Integer view (ints only; floats are not silently truncated).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Float view (accepts ints too, widened).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Boolean view.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array view.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// A parsed document: dotted-key → value map.
/// Keys inside `[a.b]` tables are flattened to `a.b.key`.
#[derive(Debug, Clone, Default)]
pub struct Doc {
    entries: BTreeMap<String, Value>,
}

impl Doc {
    /// Parse a TOML-subset document.
    pub fn parse(text: &str) -> Result<Doc, String> {
        let mut entries = BTreeMap::new();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: malformed table header", lineno + 1))?
                    .trim();
                if name.is_empty() || name.starts_with('[') {
                    return Err(format!(
                        "line {}: unsupported or empty table header",
                        lineno + 1
                    ));
                }
                prefix = format!("{name}.");
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let value = parse_value(val.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            let full = format!("{prefix}{key}");
            if entries.insert(full.clone(), value).is_some() {
                return Err(format!("line {}: duplicate key '{full}'", lineno + 1));
            }
        }
        Ok(Doc { entries })
    }

    /// Load and parse a file.
    pub fn load(path: &std::path::Path) -> Result<Doc, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Doc::parse(&text)
    }

    /// Raw value by dotted key.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    /// String with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    /// Integer with default.
    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_int).unwrap_or(default)
    }

    /// Float with default (ints widen).
    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_float).unwrap_or(default)
    }

    /// Bool with default.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Required key of any type.
    pub fn require(&self, key: &str) -> Result<&Value, String> {
        self.get(key).ok_or_else(|| format!("missing config key '{key}'"))
    }

    /// All keys under a dotted prefix (e.g. `layers.`), sorted.
    pub fn keys_under(&self, prefix: &str) -> Vec<&str> {
        self.entries
            .keys()
            .filter(|k| k.starts_with(prefix))
            .map(|k| k.as_str())
            .collect()
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string must not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string: {s}"))?;
        if inner.contains('"') {
            return Err(format!("embedded quote unsupported: {s}"));
        }
        return Ok(Value::Str(unescape(inner)));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated array: {s}"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: Result<Vec<Value>, String> = split_top_level(inner)
            .into_iter()
            .map(|p| parse_value(p.trim()))
            .collect();
        let items = items?;
        let homogeneous = items
            .windows(2)
            .all(|w| std::mem::discriminant(&w[0]) == std::mem::discriminant(&w[1]));
        if !homogeneous {
            return Err(format!("heterogeneous array unsupported: {s}"));
        }
        return Ok(Value::Array(items));
    }
    // Numbers: underscores allowed as separators.
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("unparseable value: {s}"))
}

fn unescape(s: &str) -> String {
    s.replace("\\n", "\n").replace("\\t", "\t").replace("\\\\", "\\")
}

/// Split an array body on commas that are not nested inside brackets/strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_tables() {
        let doc = Doc::parse(
            r#"
            # comment
            name = "flexspim"   # trailing comment
            rows = 512
            vdd = 1.1
            enabled = true

            [macro]
            cols = 256
            [macro.pc]
            standby = 0.13
            "#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "flexspim");
        assert_eq!(doc.int_or("rows", 0), 512);
        assert!((doc.float_or("vdd", 0.0) - 1.1).abs() < 1e-12);
        assert!(doc.bool_or("enabled", false));
        assert_eq!(doc.int_or("macro.cols", 0), 256);
        assert!((doc.float_or("macro.pc.standby", 0.0) - 0.13).abs() < 1e-12);
    }

    #[test]
    fn parses_arrays() {
        let doc = Doc::parse("bits = [1, 2, 4, 8]\nnames = [\"a\", \"b\"]").unwrap();
        let bits: Vec<i64> = doc
            .get("bits")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|v| v.as_int().unwrap())
            .collect();
        assert_eq!(bits, vec![1, 2, 4, 8]);
        assert_eq!(doc.get("names").unwrap().as_array().unwrap().len(), 2);
    }

    #[test]
    fn int_float_distinction() {
        let doc = Doc::parse("i = 3\nf = 3.5").unwrap();
        assert_eq!(doc.get("i").unwrap().as_int(), Some(3));
        assert_eq!(doc.get("f").unwrap().as_int(), None);
        assert_eq!(doc.get("f").unwrap().as_float(), Some(3.5));
        assert_eq!(doc.get("i").unwrap().as_float(), Some(3.0));
    }

    #[test]
    fn underscore_numbers() {
        let doc = Doc::parse("big = 1_000_000").unwrap();
        assert_eq!(doc.int_or("big", 0), 1_000_000);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = Doc::parse("s = \"a#b\"").unwrap();
        assert_eq!(doc.str_or("s", ""), "a#b");
    }

    #[test]
    fn rejects_malformed() {
        assert!(Doc::parse("[unclosed").is_err());
        assert!(Doc::parse("novalue").is_err());
        assert!(Doc::parse("k = ").is_err());
        assert!(Doc::parse("k = \"unterminated").is_err());
        assert!(Doc::parse("k = [1, \"x\"]").is_err());
        assert!(Doc::parse("k = 1\nk = 2").is_err());
    }

    #[test]
    fn keys_under_prefix() {
        let doc = Doc::parse("[a]\nx = 1\ny = 2\n[b]\nz = 3").unwrap();
        assert_eq!(doc.keys_under("a."), vec!["a.x", "a.y"]);
    }
}
