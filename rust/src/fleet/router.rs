//! Consistent-hash session routing for the fleet tier.
//!
//! Sessions are sticky: the first `route` of a key pins it to a node and
//! every later lookup returns the same node until an explicit `repin`
//! (migration) or `unpin`. Placement comes from a consistent-hash ring
//! with virtual nodes ([`HashRing`]), so node joins and leaves remap only
//! the keys adjacent to the moved ring points (~1/N of the key space per
//! join) instead of reshuffling everything — which matters here because a
//! remapped key is not a cache miss but a *live session migration* whose
//! vmem checkpoint moves over the inter-node link (priced by
//! [`super::ledger::FleetLedger`]).
//!
//! Per-node capacity is enforced at pin time: a full node spills the new
//! session to the next distinct node in ring order, preserving ring
//! locality as far as the capacity allows.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, ensure};

use crate::util::rng::splitmix64;
use crate::Result;

/// Hash a session key onto the ring.
fn hash_key(key: u64) -> u64 {
    let mut s = key;
    splitmix64(&mut s)
}

/// A consistent-hash ring with `vnodes` virtual points per node.
#[derive(Debug, Clone, Default)]
pub struct HashRing {
    vnodes: usize,
    /// Ring points, sorted by (hash, node).
    points: Vec<(u64, usize)>,
    /// Live node ids, ascending.
    live: Vec<usize>,
}

impl HashRing {
    /// An empty ring with `vnodes` virtual points per node.
    pub fn new(vnodes: usize) -> HashRing {
        HashRing { vnodes: vnodes.max(1), points: Vec::new(), live: Vec::new() }
    }

    /// Live node ids, ascending.
    pub fn live(&self) -> &[usize] {
        &self.live
    }

    /// Number of live nodes.
    pub fn len(&self) -> usize {
        self.live.len()
    }

    /// No live nodes.
    pub fn is_empty(&self) -> bool {
        self.live.is_empty()
    }

    /// Whether `node` is on the ring.
    pub fn contains(&self, node: usize) -> bool {
        self.live.binary_search(&node).is_ok()
    }

    /// Add `node`'s virtual points to the ring (no-op when present).
    pub fn add(&mut self, node: usize) {
        if self.contains(node) {
            return;
        }
        // Each node seeds its own splitmix64 stream, so a node's points
        // are stable across joins/leaves of *other* nodes — the property
        // consistent hashing is for.
        let mut s = 0x9E37_79B9_7F4A_7C15u64 ^ (node as u64).wrapping_mul(0x100_0000_01B3);
        for _ in 0..self.vnodes {
            self.points.push((splitmix64(&mut s), node));
        }
        self.points.sort_unstable();
        let pos = self.live.binary_search(&node).unwrap_err();
        self.live.insert(pos, node);
    }

    /// Remove `node`'s virtual points (no-op when absent).
    pub fn remove(&mut self, node: usize) {
        self.points.retain(|&(_, n)| n != node);
        if let Ok(pos) = self.live.binary_search(&node) {
            self.live.remove(pos);
        }
    }

    /// The ring successor of `key`: the node owning the first point at or
    /// past the key's hash (wrapping). `None` on an empty ring.
    pub fn owner(&self, key: u64) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        let h = hash_key(key);
        let idx = self.points.partition_point(|&(p, _)| p < h);
        let (_, node) = self.points[idx % self.points.len()];
        Some(node)
    }

    /// All live nodes in ring order starting at the key's successor —
    /// the capacity spill-over sequence (first entry == [`Self::owner`]).
    pub fn candidates(&self, key: u64) -> Vec<usize> {
        if self.points.is_empty() {
            return Vec::new();
        }
        let h = hash_key(key);
        let start = self.points.partition_point(|&(p, _)| p < h);
        let mut out = Vec::with_capacity(self.live.len());
        for i in 0..self.points.len() {
            let (_, node) = self.points[(start + i) % self.points.len()];
            if !out.contains(&node) {
                out.push(node);
                if out.len() == self.live.len() {
                    break;
                }
            }
        }
        out
    }
}

/// Sticky session router: a [`HashRing`] plus the pin table and per-node
/// capacity bookkeeping. Pure placement logic — no I/O, no services —
/// so rebalancing decisions are unit-testable; [`super::Fleet`] executes
/// the migrations this router plans.
#[derive(Debug, Clone)]
pub struct SessionRouter {
    ring: HashRing,
    /// Sticky sessions per node; `0` = unbounded.
    capacity: usize,
    /// Session key → pinned node.
    pins: BTreeMap<u64, usize>,
    /// Pinned sessions per live node.
    loads: BTreeMap<usize, usize>,
}

impl SessionRouter {
    /// An empty router over a fresh ring.
    pub fn new(vnodes: usize, capacity: usize) -> SessionRouter {
        SessionRouter {
            ring: HashRing::new(vnodes),
            capacity,
            pins: BTreeMap::new(),
            loads: BTreeMap::new(),
        }
    }

    /// The underlying ring (read-only).
    pub fn ring(&self) -> &HashRing {
        &self.ring
    }

    /// Live node ids, ascending.
    pub fn live(&self) -> &[usize] {
        self.ring.live()
    }

    /// Whether `node` is live.
    pub fn contains(&self, node: usize) -> bool {
        self.ring.contains(node)
    }

    /// Pinned sessions on `node`.
    pub fn load(&self, node: usize) -> usize {
        self.loads.get(&node).copied().unwrap_or(0)
    }

    /// Total pinned sessions across the fleet.
    pub fn total_pinned(&self) -> usize {
        self.pins.len()
    }

    /// Whether `node` can accept one more pinned session.
    pub fn has_capacity(&self, node: usize) -> bool {
        self.capacity == 0 || self.load(node) < self.capacity
    }

    /// Add a node to the ring (routable immediately).
    pub fn add_node(&mut self, node: usize) {
        self.ring.add(node);
        self.loads.entry(node).or_insert(0);
    }

    /// Remove a node from the ring. Its pins stay in the table (the
    /// sessions still live on that node!) until the caller migrates them
    /// with [`Self::repin`] — a removed node routes no *new* sessions.
    pub fn remove_node(&mut self, node: usize) {
        self.ring.remove(node);
    }

    /// Route `key`: return its pinned node, or pin it to the first node
    /// in ring order with spare capacity. Errors when no live node has
    /// room.
    pub fn route(&mut self, key: u64) -> Result<usize> {
        if let Some(&node) = self.pins.get(&key) {
            return Ok(node);
        }
        ensure!(!self.ring.is_empty(), "fleet has no live nodes");
        for node in self.ring.candidates(key) {
            if self.has_capacity(node) {
                self.pins.insert(key, node);
                *self.loads.entry(node).or_insert(0) += 1;
                return Ok(node);
            }
        }
        bail!(
            "fleet is full: every live node holds its {} pinned sessions",
            self.capacity
        )
    }

    /// The node `key` is pinned to, if any.
    pub fn lookup(&self, key: u64) -> Option<usize> {
        self.pins.get(&key).copied()
    }

    /// Move an existing pin to `to` (migration bookkeeping).
    pub fn repin(&mut self, key: u64, to: usize) -> Result<()> {
        let from = *self
            .pins
            .get(&key)
            .ok_or_else(|| anyhow!("session {key} is not pinned"))?;
        if from == to {
            return Ok(());
        }
        if let Some(l) = self.loads.get_mut(&from) {
            *l = l.saturating_sub(1);
        }
        *self.loads.entry(to).or_insert(0) += 1;
        self.pins.insert(key, to);
        Ok(())
    }

    /// Drop a pin (session removed from the fleet).
    pub fn unpin(&mut self, key: u64) {
        if let Some(node) = self.pins.remove(&key) {
            if let Some(l) = self.loads.get_mut(&node) {
                *l = l.saturating_sub(1);
            }
        }
    }

    /// All keys pinned to `node`, ascending.
    pub fn keys_on(&self, node: usize) -> Vec<u64> {
        self.pins
            .iter()
            .filter(|&(_, &n)| n == node)
            .map(|(&k, _)| k)
            .collect()
    }

    /// Keys a fresh join of `node` should attract: pinned elsewhere but
    /// now ring-owned by `node`. Consistent hashing keeps this to ~1/N of
    /// the pinned keys; everything else stays sticky where it is.
    pub fn rebalance_keys_for(&self, node: usize) -> Vec<u64> {
        self.pins
            .iter()
            .filter(|&(&k, &pinned)| pinned != node && self.ring.owner(k) == Some(node))
            .map(|(&k, _)| k)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring4() -> HashRing {
        let mut r = HashRing::new(16);
        for n in 0..4 {
            r.add(n);
        }
        r
    }

    #[test]
    fn ring_spreads_keys_across_nodes() {
        let r = ring4();
        let mut counts = [0usize; 4];
        for k in 0..1000u64 {
            counts[r.owner(k).unwrap()] += 1;
        }
        for (n, &c) in counts.iter().enumerate() {
            assert!(c > 50, "node {n} owns only {c}/1000 keys — ring badly skewed");
        }
    }

    #[test]
    fn candidates_start_at_owner_and_cover_all_live_nodes() {
        let r = ring4();
        for k in 0..50u64 {
            let c = r.candidates(k);
            assert_eq!(c[0], r.owner(k).unwrap());
            let mut sorted = c.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3], "all live nodes, each once");
        }
    }

    #[test]
    fn removal_only_remaps_the_removed_nodes_keys() {
        let r = ring4();
        let before: Vec<usize> = (0..500u64).map(|k| r.owner(k).unwrap()).collect();
        let mut r2 = r.clone();
        r2.remove(2);
        for (k, &owner) in before.iter().enumerate() {
            if owner != 2 {
                assert_eq!(
                    r2.owner(k as u64),
                    Some(owner),
                    "key {k} moved although node 2 never owned it"
                );
            } else {
                assert_ne!(r2.owner(k as u64), Some(2));
            }
        }
    }

    #[test]
    fn routing_is_sticky() {
        let mut router = SessionRouter::new(16, 0);
        for n in 0..3 {
            router.add_node(n);
        }
        let first = router.route(42).unwrap();
        // Ring churn does not move an existing pin.
        router.add_node(3);
        assert_eq!(router.route(42).unwrap(), first);
        assert_eq!(router.lookup(42), Some(first));
        assert_eq!(router.load(first), 1);
    }

    #[test]
    fn capacity_spills_to_ring_successors_then_errors() {
        let mut router = SessionRouter::new(16, 1);
        router.add_node(0);
        router.add_node(1);
        let a = router.route(1).unwrap();
        let b = router.route(2).unwrap();
        assert_ne!(a, b, "second session must spill past the full node");
        let err = router.route(3).unwrap_err();
        assert!(format!("{err}").contains("fleet is full"), "got: {err}");
        // Unpinning frees the slot.
        router.unpin(1);
        assert_eq!(router.route(3).unwrap(), a);
    }

    #[test]
    fn repin_moves_load_and_keeps_stickiness() {
        let mut router = SessionRouter::new(16, 0);
        router.add_node(0);
        router.add_node(1);
        let from = router.route(9).unwrap();
        let to = 1 - from;
        router.repin(9, to).unwrap();
        assert_eq!(router.lookup(9), Some(to));
        assert_eq!(router.load(from), 0);
        assert_eq!(router.load(to), 1);
        assert!(router.repin(77, 0).is_err(), "unknown key");
    }

    #[test]
    fn join_rebalance_targets_only_newly_owned_keys() {
        let mut router = SessionRouter::new(16, 0);
        for n in 0..3 {
            router.add_node(n);
        }
        for k in 0..200u64 {
            router.route(k).unwrap();
        }
        router.add_node(3);
        let moved = router.rebalance_keys_for(3);
        assert!(!moved.is_empty(), "a join must attract some keys");
        assert!(
            moved.len() < 150,
            "consistent hashing moves ~1/N, got {}/200",
            moved.len()
        );
        for &k in &moved {
            assert_eq!(router.ring().owner(k), Some(3));
            assert_ne!(router.lookup(k), Some(3), "not yet migrated");
        }
        // Keys the new node does not own stay put.
        for k in 0..200u64 {
            if !moved.contains(&k) {
                assert_ne!(router.ring().owner(k), Some(3));
            }
        }
    }

    #[test]
    fn empty_ring_routes_nothing() {
        let mut router = SessionRouter::new(8, 0);
        assert!(router.route(1).is_err());
        assert_eq!(router.ring().owner(1), None);
        assert!(router.ring().candidates(1).is_empty());
    }
}
