//! Inter-node traffic accounting for the fleet tier.
//!
//! The single-node energy ledger (`crate::coordinator::metrics`) prices
//! on-chip movement and DRAM spills; scale-out adds a third, more
//! expensive lane: the chip-to-chip link. [`FleetLedger`] records every
//! modeled transfer on that lane by `(from, to)` link —
//!
//! * **weight pushes** — the controller broadcasting a replica's full
//!   weight image at join (replicated placement), or layers re-homing
//!   between shard owners (layer-sharded placement). Weight stationarity
//!   makes this a one-off per join, amortized across every session the
//!   node then serves.
//! * **vmem moves** — live-session migrations: the serialized
//!   [`crate::runtime::StateSnapshot`] at the session's current
//!   precision tier, unicast old node → new node.
//! * **boundary spikes** — layer-sharded placement streams binary spike
//!   planes across every owner cut, per frame (modeled; execution stays
//!   replicated in simulation).
//!
//! Totals convert to energy at a flat `link_pj_per_bit` and export
//! through the telemetry registry with `from`/`to` node labels.

use std::collections::BTreeMap;

use crate::telemetry::Registry;

/// Pseudo-node id for the deployment controller (weight images originate
/// there, not on a serving node).
pub const CONTROLLER: usize = usize::MAX;

fn node_label(node: usize) -> String {
    if node == CONTROLLER {
        "ctl".to_string()
    } else {
        format!("n{node}")
    }
}

/// Per-link bit counters for the fleet interconnect, plus event tallies.
#[derive(Debug, Clone, Default)]
pub struct FleetLedger {
    /// Link energy per transferred bit (pJ/bit).
    pub link_pj_per_bit: f64,
    /// Bits moved per `(from, to)` link.
    pub links: BTreeMap<(usize, usize), u64>,
    /// Bits spent distributing weight images (joins + shard re-homing).
    pub weight_push_bits: u64,
    /// Bits spent moving live-session membrane checkpoints.
    pub vmem_move_bits: u64,
    /// Bits spent streaming spike planes across shard boundaries.
    pub boundary_bits: u64,
    /// Fleet windows already priced into `boundary_bits` (high-water mark
    /// so repeated accounting passes stay idempotent).
    pub boundary_windows: u64,
    /// Completed live-session migrations.
    pub migrations: u64,
    /// Node joins (including boot activations).
    pub joins: u64,
    /// Node leaves/drains.
    pub leaves: u64,
}

impl FleetLedger {
    /// A zeroed ledger pricing the link at `link_pj_per_bit`.
    pub fn new(link_pj_per_bit: f64) -> FleetLedger {
        FleetLedger { link_pj_per_bit, ..FleetLedger::default() }
    }

    fn add_link(&mut self, from: usize, to: usize, bits: u64) {
        *self.links.entry((from, to)).or_insert(0) += bits;
    }

    /// Price a weight image pushed over `from → to` (controller broadcast
    /// or shard re-homing).
    pub fn record_weight_push(&mut self, from: usize, to: usize, bits: u64) {
        self.weight_push_bits += bits;
        self.add_link(from, to, bits);
    }

    /// Price a live-session state move of `bits` over `from → to`.
    pub fn record_migration(&mut self, from: usize, to: usize, bits: u64) {
        self.vmem_move_bits += bits;
        self.migrations += 1;
        self.add_link(from, to, bits);
    }

    /// Price shard-boundary spike traffic for one window batch.
    pub fn record_boundary(&mut self, from: usize, to: usize, bits: u64) {
        self.boundary_bits += bits;
        self.add_link(from, to, bits);
    }

    /// Total bits moved over the fleet interconnect.
    pub fn total_bits(&self) -> u64 {
        self.links.values().sum()
    }

    /// Total link energy in pJ.
    pub fn energy_pj(&self) -> f64 {
        self.total_bits() as f64 * self.link_pj_per_bit
    }

    /// One-line human summary.
    pub fn line(&self) -> String {
        format!(
            "fleet link: {} bits ({} weight-push, {} vmem-move, {} boundary) \
             over {} links = {:.1} nJ | {} migrations, {} joins, {} leaves",
            self.total_bits(),
            self.weight_push_bits,
            self.vmem_move_bits,
            self.boundary_bits,
            self.links.len(),
            self.energy_pj() / 1e3,
            self.migrations,
            self.joins,
            self.leaves,
        )
    }

    /// Mirror the ledger into `registry` as monotonic counters:
    /// `flexspim_fleet_link_bits_total{from,to}` per link and
    /// `flexspim_fleet_migrations_total`. Idempotent — each counter is
    /// raised by the delta since the last publish, so repeated report or
    /// `--dump-telemetry` passes never double-count.
    pub fn publish(&self, registry: &Registry) {
        for (&(from, to), &bits) in &self.links {
            let (fl, tl) = (node_label(from), node_label(to));
            let c = registry.counter(
                "flexspim_fleet_link_bits_total",
                &[("from", fl.as_str()), ("to", tl.as_str())],
            );
            let cur = c.get();
            if bits > cur {
                c.add(bits - cur);
            }
        }
        let m = registry.counter("flexspim_fleet_migrations_total", &[]);
        let cur = m.get();
        if self.migrations > cur {
            m.add(self.migrations - cur);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_tallies_categories_and_links() {
        let mut l = FleetLedger::new(30.0);
        l.record_weight_push(CONTROLLER, 0, 1000);
        l.record_weight_push(CONTROLLER, 1, 1000);
        l.record_migration(0, 1, 256);
        l.record_boundary(0, 1, 64);
        assert_eq!(l.weight_push_bits, 2000);
        assert_eq!(l.vmem_move_bits, 256);
        assert_eq!(l.boundary_bits, 64);
        assert_eq!(l.migrations, 1);
        assert_eq!(l.total_bits(), 2320);
        assert_eq!(l.links[&(0, 1)], 320, "migration + boundary share a link");
        assert!((l.energy_pj() - 2320.0 * 30.0).abs() < 1e-9);
        assert!(l.line().contains("1 migrations"));
    }

    #[test]
    fn publish_is_idempotent() {
        let mut l = FleetLedger::new(30.0);
        l.record_migration(0, 1, 128);
        let reg = Registry::new();
        l.publish(&reg);
        l.publish(&reg);
        assert_eq!(reg.counter_total("flexspim_fleet_migrations_total"), 1);
        assert_eq!(reg.counter_total("flexspim_fleet_link_bits_total"), 128);
        // New traffic raises the counters by the delta only.
        l.record_migration(1, 0, 64);
        l.publish(&reg);
        assert_eq!(reg.counter_total("flexspim_fleet_migrations_total"), 2);
        assert_eq!(reg.counter_total("flexspim_fleet_link_bits_total"), 192);
    }

    #[test]
    fn controller_label_is_distinct() {
        assert_eq!(node_label(CONTROLLER), "ctl");
        assert_eq!(node_label(3), "n3");
    }
}
