//! Fleet tier: scale-out serving across replicated accelerator nodes.
//!
//! One [`crate::serve::StreamingService`] models a single FlexSpIM chip
//! serving sessions out of resident CIM state. A deployment that outgrows
//! one chip adds nodes — and because the paper's layer-wise weight/output
//! stationarity makes both weights *and* membrane potentials resident
//! state, scale-out is not stateless load balancing: placing a session is
//! a commitment (its vmem lives on that node), and rebalancing means
//! moving a live checkpoint over a chip-to-chip link that is far more
//! expensive per bit than any on-chip lane. This module models exactly
//! that:
//!
//! * [`router`] — a consistent-hash ring with virtual nodes and sticky
//!   session pins, so joins/leaves remap only ~1/N of the key space and
//!   every remap is an explicit, priced migration.
//! * [`ledger`] — per-link bit accounting for the fleet interconnect:
//!   weight pushes at join (broadcast under replicated placement,
//!   per-layer re-homing under layer sharding), vmem checkpoint moves for
//!   session migrations, and modeled shard-boundary spike traffic;
//!   totals convert to energy at `link_pj_per_bit` and export through the
//!   telemetry registry.
//! * [`Fleet`] — N pre-spawned service replicas built from one
//!   [`crate::deploy::Deployment`]-style `(plan, factory, config)`
//!   triple, a nested worker-pool scope running all replicas at once, an
//!   open-loop traffic driver that replays the same
//!   [`crate::serve::load`] timeline through the router, and a mean-load
//!   autoscaler that activates standby nodes and migrates the ring share
//!   of existing sessions onto them.
//!
//! Correctness anchor: a session migrated mid-stream (snapshot → link →
//! restore on a freshly built replica, including across a precision-tier
//! switch) finishes bit-identical to the same stream served on one node —
//! pinned by `rust/tests/property_fleet.rs`. Everything the move needs
//! travels in [`crate::serve::SessionExport`]; bit-identity holds because
//! all replicas share one plan and backend factory (same seed → same
//! weights) and [`crate::runtime::StepBackend::restore`] reinstates the
//! exact membrane words.
//!
//! Modeling note: under [`Placement::LayerSharded`] the *pricing* places
//! layer weights round-robin across live nodes and charges every
//! owner-cut spike plane to the link, but *execution* stays replicated in
//! simulation — the traffic model is the deliverable, not a distributed
//! runtime.

pub mod ledger;
pub mod router;

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure};

use crate::coordinator::engine::{BackendFactory, SamplePlan};
use crate::coordinator::{LatencyStats, RunMetrics};
use crate::dataflow::Policy;
use crate::deploy::{FleetSpec, Placement};
use crate::runtime::{NativeScnn, StepBackend};
use crate::serve::load::{build_schedule, Action};
use crate::serve::{
    tiers_for, LoadConfig, ServiceConfig, SessionResult, SessionTraffic, StreamingService,
};
use crate::snn::events::AdjacencyCache;
use crate::snn::Network;
use crate::telemetry::Registry;
use crate::util::rng::Rng;
use crate::Result;

pub use ledger::{FleetLedger, CONTROLLER};
pub use router::{HashRing, SessionRouter};

/// Round-robin shard owner of `layer` among the sorted live set.
fn shard_owner(live: &[usize], layer: usize) -> usize {
    live[layer % live.len()]
}

/// Everything the fleet mutates besides the services themselves — split
/// out so a driver holding `&[StreamingService]` (all pools running) can
/// still mutate routing and accounting through one `&mut`.
struct FleetControl {
    spec: FleetSpec,
    router: SessionRouter,
    ledger: FleetLedger,
    /// Resolution tier table (shared by every node; see
    /// [`crate::serve::tiers_for`]).
    tiers: Vec<Vec<(u32, u32)>>,
    /// Full weight image of the deployed network, bits.
    total_weight_bits: u64,
    /// Per-layer weight image, bits.
    layer_weight_bits: Vec<u64>,
    /// Per-layer output-neuron counts (shard-boundary plane widths).
    layer_out_neurons: Vec<u64>,
    /// Timesteps per micro-window (boundary planes per window).
    frames_per_window: u64,
    /// Fleet-owned metrics registry (nodes keep their own).
    registry: Arc<Registry>,
}

impl FleetControl {
    /// Price shard-boundary spike traffic up to `windows_total` executed
    /// windows: one binary spike plane per frame per owner cut, charged
    /// to the cut's link at the *current* shard layout. High-water
    /// marked, so repeated reporting passes never double-count.
    fn account_boundary(&mut self, windows_total: u64) {
        let fresh = windows_total.saturating_sub(self.ledger.boundary_windows);
        if fresh == 0 {
            return;
        }
        self.ledger.boundary_windows = windows_total;
        if self.spec.placement != Placement::LayerSharded {
            return;
        }
        let live = self.router.live().to_vec();
        if live.len() < 2 {
            return;
        }
        for l in 0..self.layer_out_neurons.len().saturating_sub(1) {
            let a = shard_owner(&live, l);
            let b = shard_owner(&live, l + 1);
            if a != b {
                let bits = fresh * self.frames_per_window * self.layer_out_neurons[l];
                self.ledger.record_boundary(a, b, bits);
            }
        }
    }

    /// Price the weight movement a join of `node` causes and put it on
    /// the ring.
    fn activate(&mut self, node: usize) {
        let live_before = self.router.live().to_vec();
        match self.spec.placement {
            // Replicated placement: the controller broadcasts the full
            // weight image to every joining node, once — weight
            // stationarity amortizes it over the node's lifetime.
            Placement::Replicated => {
                self.ledger.record_weight_push(CONTROLLER, node, self.total_weight_bits);
            }
            // Layer sharding: layers re-home round-robin over the new
            // live set; each moved layer is a unicast old-owner → new
            // owner push (controller-sourced while the ring is empty).
            Placement::LayerSharded => {
                let mut live_after = live_before.clone();
                let pos = live_after.binary_search(&node).unwrap_err();
                live_after.insert(pos, node);
                for (l, &bits) in self.layer_weight_bits.iter().enumerate() {
                    let old = if live_before.is_empty() {
                        CONTROLLER
                    } else {
                        shard_owner(&live_before, l)
                    };
                    let new = shard_owner(&live_after, l);
                    if old != new {
                        self.ledger.record_weight_push(old, new, bits);
                    }
                }
            }
        }
        self.router.add_node(node);
        self.ledger.joins += 1;
    }
}

/// A scale-out serving fleet: pre-spawned service replicas plus routing
/// and interconnect accounting.
///
/// All `max(nodes, max_nodes)` replicas are constructed up front; ring
/// membership (not the `Vec`) defines liveness, so a mid-drive autoscale
/// join only activates a standby replica whose worker pool is already
/// running — mirroring how the serve autoscaler pre-spawns
/// `max_workers` threads and parks the surplus.
pub struct Fleet {
    nodes: Vec<StreamingService>,
    ctrl: FleetControl,
}

/// Mutable fleet operations, valid both outside any worker pool (ingest
/// and migration work queue-only) and inside [`Fleet::run_with`] (windows
/// execute concurrently). Obtained from [`Fleet::handle`] or passed to
/// the `run_with` driver.
pub struct FleetHandle<'a> {
    nodes: &'a [StreamingService],
    ctrl: &'a mut FleetControl,
}

impl Fleet {
    /// Build a fleet over a shared plan and backend factory: one service
    /// replica per potential node (boot + autoscale headroom), the boot
    /// nodes activated with their weight pushes priced.
    pub fn new(
        plan: Arc<SamplePlan>,
        factory: Arc<BackendFactory>,
        cfg: ServiceConfig,
        spec: FleetSpec,
    ) -> Result<Fleet> {
        spec.validate()?;
        let net = &plan.net;
        let tiers = tiers_for(net, cfg.precision.max_delta);
        let ctrl = FleetControl {
            router: SessionRouter::new(spec.vnodes, spec.capacity_sessions),
            ledger: FleetLedger::new(spec.link_pj_per_bit),
            tiers,
            total_weight_bits: net.total_weight_bits(),
            layer_weight_bits: net.layers.iter().map(|l| l.weight_bits()).collect(),
            layer_out_neurons: net.layers.iter().map(|l| l.num_neurons() as u64).collect(),
            frames_per_window: cfg.session.frames_per_window as u64,
            registry: Arc::new(Registry::default()),
            spec: spec.clone(),
        };
        let total = spec.nodes.max(spec.max_nodes);
        let nodes = (0..total)
            .map(|_| StreamingService::new(plan.clone(), factory.clone(), cfg.clone()))
            .collect();
        let mut fleet = Fleet { nodes, ctrl };
        for _ in 0..spec.nodes {
            fleet.handle().join()?;
        }
        Ok(fleet)
    }

    /// Convenience: a fleet of pure-Rust [`NativeScnn`] replicas,
    /// deterministic from `seed` — every node builds backends from the
    /// same factory, so weights are identical fleet-wide (the migration
    /// bit-identity precondition).
    pub fn native(
        net: Network,
        seed: u64,
        num_macros: usize,
        policy: Policy,
        cfg: ServiceConfig,
        spec: FleetSpec,
    ) -> Result<Fleet> {
        let plan = Arc::new(SamplePlan::new(net.clone(), num_macros, policy));
        let adj = Arc::new(AdjacencyCache::new());
        let factory: Arc<BackendFactory> = Arc::new(move || {
            Ok(Box::new(NativeScnn::with_adjacency_cache(net.clone(), seed, adj.clone()))
                as Box<dyn StepBackend>)
        });
        Fleet::new(plan, factory, cfg, spec)
    }

    /// The fleet spec in force.
    pub fn spec(&self) -> &FleetSpec {
        &self.ctrl.spec
    }

    /// All replicas (live and standby), by node id.
    pub fn nodes(&self) -> &[StreamingService] {
        &self.nodes
    }

    /// One replica by node id.
    pub fn node(&self, id: usize) -> &StreamingService {
        &self.nodes[id]
    }

    /// Live node ids, ascending.
    pub fn live_nodes(&self) -> Vec<usize> {
        self.ctrl.router.live().to_vec()
    }

    /// The interconnect ledger.
    pub fn ledger(&self) -> &FleetLedger {
        &self.ctrl.ledger
    }

    /// The session router (read-only; mutate through a handle).
    pub fn router(&self) -> &SessionRouter {
        &self.ctrl.router
    }

    /// The fleet-owned metrics registry (per-link traffic counters and
    /// per-node session gauges; nodes export their own registries).
    pub fn metrics(&self) -> &Arc<Registry> {
        &self.ctrl.registry
    }

    /// The node a session is pinned to, if any.
    pub fn session_node(&self, id: u64) -> Option<usize> {
        self.ctrl.router.lookup(id)
    }

    /// A session's current results, wherever it lives.
    pub fn session_result(&self, id: u64) -> Option<SessionResult> {
        let node = self.ctrl.router.lookup(id)?;
        self.nodes[node].session_result(id)
    }

    /// Mutable fleet operations outside any worker pool: opens, ingest,
    /// and migrations all work (windows queue without executing until
    /// [`Self::run_with`]).
    pub fn handle(&mut self) -> FleetHandle<'_> {
        FleetHandle { nodes: &self.nodes, ctrl: &mut self.ctrl }
    }

    /// Run `driver` with every replica's worker pool live (standby nodes
    /// idle until activated). Each service spawns its pool once and shuts
    /// down when the driver returns — like
    /// [`StreamingService::run_with`], one run per fleet.
    pub fn run_with<T>(
        &mut self,
        driver: impl FnOnce(&mut FleetHandle<'_>) -> Result<T>,
    ) -> Result<T> {
        fn nested<T, F>(
            nodes: &[StreamingService],
            idx: usize,
            ctrl: &mut FleetControl,
            driver: &mut Option<F>,
        ) -> Result<T>
        where
            F: FnOnce(&mut FleetHandle<'_>) -> Result<T>,
        {
            match nodes.get(idx) {
                None => {
                    let f = driver.take().expect("driver runs exactly once");
                    f(&mut FleetHandle { nodes, ctrl })
                }
                Some(svc) => svc.run_with(|_| nested(nodes, idx + 1, ctrl, driver)),
            }
        }
        let mut once = Some(driver);
        nested(&self.nodes, 0, &mut self.ctrl, &mut once)
    }

    /// Replay `traffic` open-loop through the fleet: the same
    /// wall-clock schedule as [`crate::serve::drive_open_loop`], with
    /// every action routed by the session ring and the autoscaler
    /// consulted at each arrival.
    pub fn drive_open_loop(
        &mut self,
        traffic: &[SessionTraffic],
        cfg: &LoadConfig,
    ) -> Result<FleetLoadReport> {
        let _span = crate::telemetry::trace::span("fleet.drive_open_loop");
        ensure!(
            cfg.time_scale.is_finite() && cfg.time_scale > 0.0,
            "load time_scale must be positive and finite (got {})",
            cfg.time_scale
        );
        let chunk = cfg.chunk.max(1);
        let mut rng = Rng::new(cfg.seed);
        let starts = cfg.arrivals.sample_starts(traffic.len(), &mut rng);
        let schedule = build_schedule(traffic, &starts, cfg.time_scale, chunk);

        let (drive_wall_s, max_lag_s) = self.run_with(|h| {
            let epoch = Instant::now();
            let mut max_lag_s = 0.0f64;
            for item in &schedule {
                let due = epoch + Duration::from_secs_f64(item.due_s.max(0.0));
                let now = Instant::now();
                if now < due {
                    std::thread::sleep(due - now);
                } else {
                    max_lag_s = max_lag_s.max((now - due).as_secs_f64());
                }
                match item.action {
                    Action::Open(i) => {
                        h.maybe_scale()?;
                        h.open_session(traffic[i].id, traffic[i].label)?;
                    }
                    Action::Ingest { session, lo, hi } => {
                        h.ingest(traffic[session].id, &traffic[session].events[lo..hi])?
                    }
                    Action::Close(i) => h.close_session(traffic[i].id, traffic[i].end_us)?,
                }
            }
            h.drain()?;
            Ok((epoch.elapsed().as_secs_f64(), max_lag_s))
        })?;

        let session = &self.nodes[0].config().session;
        let window_us = (session.step_us * session.frames_per_window as u64).max(1);
        let n = traffic.len().max(1) as f64;
        let mean_windows: f64 =
            traffic.iter().map(|t| (t.end_us / window_us + 1) as f64).sum::<f64>() / n;
        let rate = cfg.arrivals.rate_per_sec();
        let fleet = self.report(drive_wall_s);
        Ok(FleetLoadReport {
            offered_sessions_per_sec: rate,
            offered_windows_per_sec: rate * mean_windows,
            goodput_windows_per_sec: fleet.windows_done as f64 / drive_wall_s.max(1e-9),
            drive_wall_s,
            max_lag_s,
            fleet,
        })
    }

    /// Assemble the fleet-wide report: every node's
    /// [`StreamingService::report`] merged (metrics via the exact-
    /// partition [`RunMetrics::merge`]), shard-boundary traffic brought
    /// up to date, link energy folded into the movement ledger, and the
    /// fleet registry refreshed (per-link counters, per-node session
    /// gauges).
    pub fn report(&mut self, wallclock_s: f64) -> FleetReport {
        let mut metrics = RunMetrics::default();
        let mut latency = LatencyStats::new();
        let mut per_node_sessions = Vec::with_capacity(self.nodes.len());
        let mut sessions = 0u64;
        let mut finished_sessions = 0u64;
        let mut windows_done = 0u64;
        let mut windows_shed = 0u64;
        let mut events_dropped = 0u64;
        let mut early_exits = 0u64;
        let mut precision_shifts = 0u64;
        for node in &self.nodes {
            let r = node.report(wallclock_s);
            metrics.merge(&r.metrics);
            latency.merge(&r.latency);
            per_node_sessions.push(r.sessions);
            sessions += r.sessions;
            finished_sessions += r.finished_sessions;
            windows_done += r.windows_done;
            windows_shed += r.windows_shed;
            events_dropped += r.events_dropped;
            early_exits += r.early_exits;
            precision_shifts += r.precision_shifts;
        }
        self.ctrl.account_boundary(windows_done);
        let ledger = &self.ctrl.ledger;
        // The link is the fleet's movement lane; price it alongside the
        // nodes' DRAM spill traffic already inside `metrics.energy`.
        metrics.energy.movement_pj += ledger.energy_pj();
        ledger.publish(&self.ctrl.registry);
        for (i, node) in self.nodes.iter().enumerate() {
            let label = format!("n{i}");
            self.ctrl
                .registry
                .gauge("flexspim_fleet_node_sessions", &[("node", label.as_str())])
                .set(node.session_count() as i64);
        }
        self.ctrl
            .registry
            .gauge("flexspim_fleet_nodes_live", &[])
            .set(self.ctrl.router.live().len() as i64);
        FleetReport {
            nodes_total: self.nodes.len(),
            nodes_live: self.ctrl.router.live().len(),
            per_node_sessions,
            sessions,
            finished_sessions,
            windows_done,
            windows_shed,
            events_dropped,
            early_exits,
            precision_shifts,
            migrations: ledger.migrations,
            joins: ledger.joins,
            leaves: ledger.leaves,
            link_bits: ledger.total_bits(),
            weight_push_bits: ledger.weight_push_bits,
            vmem_move_bits: ledger.vmem_move_bits,
            boundary_bits: ledger.boundary_bits,
            link_energy_pj: ledger.energy_pj(),
            latency,
            metrics,
            wallclock_s,
        }
    }
}

impl FleetHandle<'_> {
    /// Live node ids, ascending.
    pub fn live_nodes(&self) -> Vec<usize> {
        self.ctrl.router.live().to_vec()
    }

    /// One replica by node id.
    pub fn node(&self, id: usize) -> &StreamingService {
        &self.nodes[id]
    }

    /// The node a session is pinned to, if any.
    pub fn session_node(&self, id: u64) -> Option<usize> {
        self.ctrl.router.lookup(id)
    }

    fn owning_node(&self, id: u64) -> Result<usize> {
        self.ctrl
            .router
            .lookup(id)
            .ok_or_else(|| anyhow!("session {id} is not routed to any node"))
    }

    /// Open a session on the node the ring picks (sticky thereafter).
    /// Returns the node id.
    pub fn open_session(&mut self, id: u64, label: Option<usize>) -> Result<usize> {
        let already_pinned = self.ctrl.router.lookup(id).is_some();
        let node = self.ctrl.router.route(id)?;
        if let Err(e) = self.nodes[node].open_session(id, label) {
            if !already_pinned {
                self.ctrl.router.unpin(id);
            }
            return Err(e);
        }
        Ok(node)
    }

    /// Deliver events to wherever the session lives now.
    pub fn ingest(&mut self, id: u64, events: &[crate::events::DvsEvent]) -> Result<()> {
        let node = self.owning_node(id)?;
        self.nodes[node].ingest(id, events)
    }

    /// Close a session's stream on its owning node.
    pub fn close_session(&mut self, id: u64, end_us: u64) -> Result<()> {
        let node = self.owning_node(id)?;
        self.nodes[node].close_session(id, end_us)
    }

    /// Administratively retier a session on its owning node (see
    /// [`StreamingService::set_session_tier`]).
    pub fn set_session_tier(&mut self, id: u64, tier: usize) -> Result<()> {
        let node = self.owning_node(id)?;
        self.nodes[node].set_session_tier(id, tier)
    }

    /// Move a live session to node `to`: export its state from the owner,
    /// install it on the target, repin, and price the checkpoint on the
    /// link. Returns `false` without side effects when the session has a
    /// window in flight right now (callers under a running pool retry or
    /// skip — stickiness makes skipping safe) or already lives on `to`.
    pub fn migrate_session(&mut self, id: u64, to: usize) -> Result<bool> {
        let from = self.owning_node(id)?;
        if from == to {
            return Ok(false);
        }
        ensure!(self.ctrl.router.contains(to), "target node {to} is not live");
        let Some(export) = self.nodes[from].try_export_session(id)? else {
            return Ok(false);
        };
        let bits = export.state_bits(&self.ctrl.tiers[export.tier]);
        self.nodes[to].import_session(export)?;
        self.ctrl.router.repin(id, to)?;
        self.ctrl.ledger.record_migration(from, to, bits);
        Ok(true)
    }

    /// Activate the lowest-id standby replica: price its weight push,
    /// add it to the ring, and migrate onto it the pinned sessions whose
    /// ring owner it now is (~1/N — the consistent-hash dividend).
    /// Sessions momentarily in flight stay where they are (sticky), as
    /// do sessions beyond the new node's capacity. Returns the node id.
    pub fn join(&mut self) -> Result<usize> {
        let node = (0..self.nodes.len())
            .find(|&i| !self.ctrl.router.contains(i))
            .ok_or_else(|| {
                anyhow!("no standby replica available ({} spawned)", self.nodes.len())
            })?;
        self.ctrl.activate(node);
        for id in self.ctrl.router.rebalance_keys_for(node) {
            if !self.ctrl.router.has_capacity(node) {
                break;
            }
            self.migrate_session(id, node)?;
        }
        Ok(node)
    }

    /// Drain a node out of the fleet: take it off the ring, re-home its
    /// shard layers (layer-sharded placement), and migrate every one of
    /// its sessions to ring successors — waiting out any in-flight
    /// window. The replica itself stays spawned (a later [`Self::join`]
    /// may re-activate it). Returns the number of sessions moved.
    pub fn leave(&mut self, node: usize) -> Result<u64> {
        ensure!(self.ctrl.router.contains(node), "node {node} is not live");
        ensure!(
            self.ctrl.router.live().len() > 1,
            "cannot drain the last live node"
        );
        let live_before = self.ctrl.router.live().to_vec();
        self.ctrl.router.remove_node(node);
        if self.ctrl.spec.placement == Placement::LayerSharded {
            let live_after = self.ctrl.router.live().to_vec();
            for (l, &bits) in self.ctrl.layer_weight_bits.iter().enumerate() {
                let old = shard_owner(&live_before, l);
                let new = shard_owner(&live_after, l);
                if old != new {
                    self.ctrl.ledger.record_weight_push(old, new, bits);
                }
            }
        }
        let mut moved = 0u64;
        for id in self.ctrl.router.keys_on(node) {
            let to = self
                .ctrl
                .router
                .ring()
                .candidates(id)
                .into_iter()
                .find(|&n| self.ctrl.router.has_capacity(n))
                .ok_or_else(|| anyhow!("fleet is full: cannot drain node {node}"))?;
            let export = loop {
                match self.nodes[node].try_export_session(id)? {
                    Some(e) => break e,
                    // A window of this session is on a worker; its commit
                    // is imminent (the node routes no new work).
                    None => std::thread::yield_now(),
                }
            };
            let bits = export.state_bits(&self.ctrl.tiers[export.tier]);
            self.nodes[to].import_session(export)?;
            self.ctrl.router.repin(id, to)?;
            self.ctrl.ledger.record_migration(node, to, bits);
            moved += 1;
        }
        self.ctrl.ledger.leaves += 1;
        Ok(moved)
    }

    /// One autoscaler tick: activate a standby node when mean pinned
    /// sessions per live node exceed the spec watermark (and the spec
    /// allows growth). Returns the joined node id, if any.
    pub fn maybe_scale(&mut self) -> Result<Option<usize>> {
        let spec = &self.ctrl.spec;
        if spec.max_nodes == 0 {
            return Ok(None);
        }
        let live = self.ctrl.router.live().len();
        if live >= spec.max_nodes.min(self.nodes.len()) {
            return Ok(None);
        }
        if self.ctrl.router.total_pinned() > spec.scale_high_sessions * live {
            return self.join().map(Some);
        }
        Ok(None)
    }

    /// Wait until every replica's queue is empty and no window is in
    /// flight (first error surfaces).
    pub fn drain(&mut self) -> Result<()> {
        for node in self.nodes {
            node.drain()?;
        }
        Ok(())
    }
}

/// Fleet-wide results: every node's serve report merged, plus the
/// interconnect ledger.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Replicas spawned (boot + autoscale headroom).
    pub nodes_total: usize,
    /// Nodes on the ring at report time.
    pub nodes_live: usize,
    /// Sessions opened per node id (standby nodes report 0).
    pub per_node_sessions: Vec<u64>,
    /// Sessions opened fleet-wide.
    pub sessions: u64,
    /// Sessions whose final window executed.
    pub finished_sessions: u64,
    /// Windows executed fleet-wide.
    pub windows_done: u64,
    /// Windows shed fleet-wide.
    pub windows_shed: u64,
    /// Events dropped at ingest fleet-wide.
    pub events_dropped: u64,
    /// Sessions that early-exited on the confidence bound.
    pub early_exits: u64,
    /// Precision-controller tier moves fleet-wide.
    pub precision_shifts: u64,
    /// Completed session migrations.
    pub migrations: u64,
    /// Node joins (including boot activations).
    pub joins: u64,
    /// Node leaves.
    pub leaves: u64,
    /// Total interconnect traffic, bits.
    pub link_bits: u64,
    /// Interconnect bits spent on weight distribution.
    pub weight_push_bits: u64,
    /// Interconnect bits spent on session-state moves.
    pub vmem_move_bits: u64,
    /// Interconnect bits spent on shard-boundary spike planes.
    pub boundary_bits: u64,
    /// Interconnect energy, pJ.
    pub link_energy_pj: f64,
    /// Per-window latency merged across nodes.
    pub latency: LatencyStats,
    /// Merged model metrics (node DRAM pricing included; link energy
    /// folded into `energy.movement_pj`).
    pub metrics: RunMetrics,
    /// Wall-clock the report covers, seconds.
    pub wallclock_s: f64,
}

impl FleetReport {
    /// Mean sessions per live node.
    pub fn sessions_per_node(&self) -> f64 {
        self.sessions as f64 / self.nodes_live.max(1) as f64
    }

    /// Total modeled energy per finished session, pJ (link included).
    pub fn energy_per_session_pj(&self) -> f64 {
        self.metrics.energy.total_pj() / self.finished_sessions.max(1) as f64
    }

    /// Migration traffic per finished session, bits.
    pub fn migration_bits_per_session(&self) -> f64 {
        self.vmem_move_bits as f64 / self.finished_sessions.max(1) as f64
    }

    /// Render a report block.
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "fleet              {} live of {} spawned nodes, {:.1} sessions/node\n",
            self.nodes_live,
            self.nodes_total,
            self.sessions_per_node(),
        ));
        out.push_str(&format!(
            "sessions           {} opened, {} finished; {} windows done, {} shed\n",
            self.sessions, self.finished_sessions, self.windows_done, self.windows_shed,
        ));
        out.push_str(&format!(
            "rebalancing        {} migrations ({} bits vmem), {} joins, {} leaves\n",
            self.migrations, self.vmem_move_bits, self.joins, self.leaves,
        ));
        out.push_str(&format!(
            "interconnect       {} bits ({} weight-push, {} boundary) = {:.1} nJ\n",
            self.link_bits,
            self.weight_push_bits,
            self.boundary_bits,
            self.link_energy_pj / 1e3,
        ));
        out.push_str(&format!(
            "energy/session     {:.1} nJ (fleet total {:.1} nJ)\n",
            self.energy_per_session_pj() / 1e3,
            self.metrics.energy.total_pj() / 1e3,
        ));
        out.push_str(&format!("window latency     {}\n", self.latency.line()));
        out
    }
}

/// What a fleet open-loop drive observed.
#[derive(Debug, Clone)]
pub struct FleetLoadReport {
    /// Mean offered session arrival rate (wall sessions/s).
    pub offered_sessions_per_sec: f64,
    /// Offered micro-window rate fleet-wide.
    pub offered_windows_per_sec: f64,
    /// Windows executed per wall second across the fleet.
    pub goodput_windows_per_sec: f64,
    /// Wall time of the whole drive.
    pub drive_wall_s: f64,
    /// Worst schedule lateness (generator fell behind its timeline).
    pub max_lag_s: f64,
    /// The fleet's own report for the run.
    pub fleet: FleetReport,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::gesture_traffic;
    use crate::serve::ArrivalProcess;
    use crate::snn::{LayerSpec, Resolution};

    fn small_net() -> Network {
        let r = Resolution::new(4, 9);
        Network::new(
            "fleet-test",
            vec![
                LayerSpec::conv("C1", 2, 4, 3, 4, 1, 48, 48, r),
                LayerSpec::fc("F1", 4 * 12 * 12, 10, Resolution::new(5, 10)),
            ],
            16,
        )
    }

    fn fleet(spec: FleetSpec, cfg_mut: impl FnOnce(&mut ServiceConfig)) -> Fleet {
        let mut cfg = ServiceConfig::nominal(1);
        cfg_mut(&mut cfg);
        Fleet::native(small_net(), 0xF1EE7, 2, Policy::HsOpt, cfg, spec).unwrap()
    }

    #[test]
    fn replicated_boot_broadcasts_the_weight_image_per_node() {
        let f = fleet(FleetSpec { nodes: 2, ..FleetSpec::default() }, |_| {});
        let per_node = small_net().total_weight_bits();
        assert_eq!(f.ledger().weight_push_bits, 2 * per_node);
        assert_eq!(f.ledger().joins, 2);
        assert_eq!(f.live_nodes(), vec![0, 1]);
        // Both pushes came from the controller.
        assert_eq!(f.ledger().links[&(CONTROLLER, 0)], per_node);
        assert_eq!(f.ledger().links[&(CONTROLLER, 1)], per_node);
    }

    #[test]
    fn sharded_join_rehomes_only_moved_layers() {
        let mut f = fleet(
            FleetSpec {
                nodes: 1,
                max_nodes: 2,
                placement: Placement::LayerSharded,
                ..FleetSpec::default()
            },
            |_| {},
        );
        let net = small_net();
        let total = net.total_weight_bits();
        // Boot: the single node owns every layer, all pushed from the
        // controller.
        assert_eq!(f.ledger().weight_push_bits, total);
        f.handle().join().unwrap();
        // Join: round-robin over {0, 1} re-homes odd layers to node 1.
        let moved: u64 = net
            .layers
            .iter()
            .enumerate()
            .filter(|(l, _)| l % 2 == 1)
            .map(|(_, layer)| layer.weight_bits())
            .sum();
        assert!(moved > 0);
        assert_eq!(f.ledger().weight_push_bits, total + moved);
        assert_eq!(f.ledger().links[&(0, 1)], moved);
    }

    #[test]
    fn opens_route_sticky_and_spread() {
        let mut f = fleet(FleetSpec { nodes: 4, ..FleetSpec::default() }, |_| {});
        let mut h = f.handle();
        let mut nodes_used = std::collections::BTreeSet::new();
        for id in 0..32u64 {
            let node = h.open_session(id, None).unwrap();
            assert_eq!(h.session_node(id), Some(node));
            nodes_used.insert(node);
        }
        assert!(nodes_used.len() >= 2, "32 sessions all landed on one node");
        // A duplicate open errors without disturbing the pin.
        let pinned = f.session_node(3).unwrap();
        assert!(f.handle().open_session(3, None).is_err());
        assert_eq!(f.session_node(3), Some(pinned));
        let total: usize = f.live_nodes().iter().map(|&n| f.node(n).session_count()).sum();
        assert_eq!(total, 32);
    }

    #[test]
    fn migration_moves_queued_windows_and_prices_the_checkpoint() {
        let mut f = fleet(FleetSpec { nodes: 2, ..FleetSpec::default() }, |_| {});
        let traffic = &gesture_traffic(1, 42, 0)[0];
        let (from, to) = {
            let mut h = f.handle();
            let from = h.open_session(7, traffic.label).unwrap();
            h.ingest(7, &traffic.events).unwrap();
            h.close_session(7, traffic.end_us).unwrap();
            let to = h.live_nodes().into_iter().find(|&n| n != from).unwrap();
            assert!(h.migrate_session(7, to).unwrap());
            (from, to)
        };
        assert_eq!(f.session_node(7), Some(to));
        assert_eq!(f.node(from).session_count(), 0);
        assert_eq!(f.ledger().migrations, 1);
        // Tier-0 checkpoint: every neuron at its layer's membrane width.
        let expected: u64 = small_net()
            .layers
            .iter()
            .map(|l| l.num_neurons() as u64 * l.res.p_bits as u64)
            .sum();
        assert_eq!(f.ledger().vmem_move_bits, expected);
        // The queued windows traveled: the run executes them on `to`.
        f.run_with(|h| h.drain()).unwrap();
        let res = f.session_result(7).unwrap();
        assert!(res.finished);
        assert!(res.windows_done > 0);
    }

    #[test]
    fn watermark_autoscale_joins_and_rebalances() {
        let mut f = fleet(
            FleetSpec { nodes: 1, max_nodes: 2, scale_high_sessions: 2, ..FleetSpec::default() },
            |_| {},
        );
        assert_eq!(f.live_nodes(), vec![0]);
        let mut h = f.handle();
        for id in 0..4u64 {
            h.open_session(id, None).unwrap();
            h.maybe_scale().unwrap();
        }
        assert_eq!(h.live_nodes(), vec![0, 1], "3rd open crosses 2/node watermark");
        // At the ceiling the autoscaler holds.
        assert_eq!(h.maybe_scale().unwrap(), None);
        drop(h);
        assert_eq!(f.ledger().joins, 2);
        // Pins and physical session placement agree after rebalancing,
        // and every migrated checkpoint was priced at the tier-0 width.
        assert_eq!(f.node(0).session_count() + f.node(1).session_count(), 4);
        assert_eq!(f.node(1).session_count(), f.router().load(1));
        let per_session: u64 = small_net()
            .layers
            .iter()
            .map(|l| l.num_neurons() as u64 * l.res.p_bits as u64)
            .sum();
        assert_eq!(f.ledger().vmem_move_bits, f.ledger().migrations * per_session);
    }

    #[test]
    fn leave_drains_all_sessions_to_survivors() {
        let mut f = fleet(FleetSpec { nodes: 2, ..FleetSpec::default() }, |_| {});
        let mut h = f.handle();
        for id in 0..8u64 {
            h.open_session(id, None).unwrap();
        }
        let victim = 1usize;
        let had = h.node(victim).session_count() as u64;
        let moved = h.leave(victim).unwrap();
        assert_eq!(moved, had);
        assert_eq!(h.live_nodes(), vec![0]);
        assert_eq!(h.node(victim).session_count(), 0);
        assert!(h.leave(0).is_err(), "cannot drain the last node");
        drop(h);
        assert_eq!(f.node(0).session_count(), 8);
    }

    #[test]
    fn open_loop_drive_finishes_sessions_across_the_fleet() {
        let mut f = fleet(FleetSpec { nodes: 2, ..FleetSpec::default() }, |c| {
            c.workers = 1;
        });
        let traffic = gesture_traffic(4, 9, 0);
        let cfg = LoadConfig {
            arrivals: ArrivalProcess::Poisson { rate_per_sec: 400.0 },
            time_scale: 50.0,
            chunk: 512,
            seed: 5,
        };
        let report = f.drive_open_loop(&traffic, &cfg).unwrap();
        assert_eq!(report.fleet.sessions, 4);
        assert_eq!(report.fleet.finished_sessions, 4);
        assert!(report.fleet.windows_done > 0);
        assert!(report.goodput_windows_per_sec > 0.0);
        assert!(report.fleet.link_bits > 0, "boot weight pushes are on the ledger");
        assert!(
            report.fleet.metrics.energy.movement_pj >= report.fleet.link_energy_pj,
            "link energy folds into movement"
        );
        assert!(report.fleet.report().contains("sessions/node"));
        // Telemetry mirrors the ledger.
        let reg = f.metrics();
        assert_eq!(
            reg.counter_total("flexspim_fleet_link_bits_total"),
            report.fleet.link_bits
        );
    }
}
