//! Open-loop saturation harness for the streaming service.
//!
//! The synchronous driver behind [`StreamingService::serve`] is
//! *closed-loop*: `ingest()` only returns after admission ran, so a slow
//! service implicitly throttles its own offered load and every throughput
//! number it produces is really a self-paced equilibrium. Saturation
//! behaviour — where does goodput stop tracking offered load, when does
//! shedding start — only shows up under an **open-loop** generator that
//! commits to an arrival schedule up front and holds to it against the
//! wall clock regardless of how the service is coping.
//!
//! This module is that generator:
//!
//! * [`ArrivalProcess`] — session arrivals as a Poisson process (i.i.d.
//!   exponential gaps) or a bursty variant (whole groups of sessions
//!   landing at the same instant, group arrivals Poisson) at the same
//!   mean rate, so burstiness is isolated from load.
//! * Each session gets a **virtual event clock**: its DVS events are
//!   scheduled relative to its own arrival time, with intra-session
//!   microsecond timestamps compressed by `time_scale` (10 → the
//!   100-ms gesture plays out in 10 ms of wall time). Chunks and the
//!   close are due at the *virtual* time of their newest event, exactly
//!   as a live sensor would emit them.
//! * [`drive_open_loop`] — merges every session's schedule into one
//!   deterministic timeline, sleeps until each item is due (recording
//!   how far behind it falls when the service back-pressures the ingest
//!   path), and reports offered vs. delivered rates side by side.
//!
//! The harness deliberately reuses the public session API
//! (`open_session` / `ingest` / `close_session`) — it measures the same
//! front door a client would hit, not a private fast path. The stepped
//! ramp over offered load and worker-pool sizes lives in
//! `rust/benches/serve_saturation.rs`.

use std::time::{Duration, Instant};

use anyhow::ensure;

use crate::util::rng::Rng;
use crate::Result;

use super::service::{ServeReport, SessionTraffic, StreamingService};

/// Session arrival process for the open-loop generator.
///
/// Both variants have the same mean rate; `Bursty` concentrates it into
/// simultaneous groups, which stresses admission control and the jitter
/// buffers at identical average load.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals: exponential inter-arrival gaps at
    /// `rate_per_sec` sessions per wall-clock second.
    Poisson {
        /// Mean session arrival rate (sessions / wall second).
        rate_per_sec: f64,
    },
    /// Groups of `burst` sessions arriving at the same instant; the
    /// groups themselves are Poisson at `rate_per_sec / burst`, keeping
    /// the mean session rate at `rate_per_sec`.
    Bursty {
        /// Mean session arrival rate (sessions / wall second).
        rate_per_sec: f64,
        /// Sessions per burst (≥ 1; 1 degenerates to `Poisson`).
        burst: usize,
    },
}

impl ArrivalProcess {
    /// Mean session arrival rate in sessions per wall second.
    pub fn rate_per_sec(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate_per_sec } => rate_per_sec,
            ArrivalProcess::Bursty { rate_per_sec, .. } => rate_per_sec,
        }
    }

    /// Sample `n` session start times (wall seconds from the drive
    /// epoch), non-decreasing. Deterministic in `rng`.
    pub fn sample_starts(&self, n: usize, rng: &mut Rng) -> Vec<f64> {
        // Inverse-CDF exponential gap; `1.0 - f64()` keeps ln() off zero.
        let mut exp_gap = |rate: f64| -(1.0 - rng.f64()).ln() / rate;
        let mut starts = Vec::with_capacity(n);
        match *self {
            ArrivalProcess::Poisson { rate_per_sec } => {
                let rate = rate_per_sec.max(1e-9);
                let mut t = 0.0;
                for _ in 0..n {
                    t += exp_gap(rate);
                    starts.push(t);
                }
            }
            ArrivalProcess::Bursty { rate_per_sec, burst } => {
                let burst = burst.max(1);
                let group_rate = (rate_per_sec / burst as f64).max(1e-9);
                let mut t = 0.0;
                while starts.len() < n {
                    t += exp_gap(group_rate);
                    for _ in 0..burst.min(n - starts.len()) {
                        starts.push(t);
                    }
                }
            }
        }
        starts
    }
}

/// Open-loop generator configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadConfig {
    /// Session arrival process (wall-clock rate).
    pub arrivals: ArrivalProcess,
    /// Intra-session time compression: virtual event microseconds are
    /// divided by this to get wall time (10 → sessions play 10× faster
    /// than the sensor recorded them). Must be positive.
    pub time_scale: f64,
    /// Events delivered per `ingest()` call (≥ 1); a chunk is due when
    /// its newest event's virtual time arrives.
    pub chunk: usize,
    /// Seed for the arrival-process draws.
    pub seed: u64,
}

impl Default for LoadConfig {
    fn default() -> LoadConfig {
        LoadConfig {
            arrivals: ArrivalProcess::Poisson { rate_per_sec: 50.0 },
            time_scale: 1.0,
            chunk: 64,
            seed: 0x10AD,
        }
    }
}

/// One timeline item; `order` breaks due-time ties so the merged
/// schedule is a deterministic total order (open before first chunk
/// before close within a session). Crate-visible so the fleet driver
/// ([`crate::fleet`]) can replay the same timeline through its router.
pub(crate) struct Scheduled {
    pub(crate) due_s: f64,
    pub(crate) order: u64,
    pub(crate) action: Action,
}

pub(crate) enum Action {
    Open(usize),
    Ingest { session: usize, lo: usize, hi: usize },
    Close(usize),
}

/// What the open-loop drive observed, offered and delivered side by side.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Mean offered session arrival rate (wall sessions/s).
    pub offered_sessions_per_sec: f64,
    /// Offered micro-window rate: session rate × mean windows per
    /// session under the service's session clock.
    pub offered_windows_per_sec: f64,
    /// Offered event rate (wall events/s).
    pub offered_events_per_sec: f64,
    /// Windows actually executed per wall second of the drive.
    pub goodput_windows_per_sec: f64,
    /// Wall time of the whole drive (last item through drain).
    pub drive_wall_s: f64,
    /// Worst lateness of a schedule item (seconds the generator fell
    /// behind its own timeline; > 0 means the ingest path back-pressured
    /// the open loop).
    pub max_lag_s: f64,
    /// The service's own report for the run.
    pub serve: ServeReport,
}

/// Build the merged per-session schedule for `traffic` under `cfg`.
pub(crate) fn build_schedule(
    traffic: &[SessionTraffic],
    starts: &[f64],
    time_scale: f64,
    chunk: usize,
) -> Vec<Scheduled> {
    let mut schedule = Vec::new();
    let mut order = 0u64;
    let mut push = |due_s: f64, order: &mut u64, action: Action| {
        schedule.push(Scheduled { due_s, order: *order, action });
        *order += 1;
    };
    let to_wall = |t_us: u64| t_us as f64 / (time_scale * 1e6);
    for (i, t) in traffic.iter().enumerate() {
        let start = starts[i];
        push(start, &mut order, Action::Open(i));
        let mut lo = 0;
        while lo < t.events.len() {
            let hi = (lo + chunk).min(t.events.len());
            // Arrival-order delivery: the chunk is due when its newest
            // event would have left the (jittered) transport.
            let newest = t.events[lo..hi].iter().map(|e| e.t_us).max().unwrap_or(0);
            push(start + to_wall(newest), &mut order, Action::Ingest { session: i, lo, hi });
            lo = hi;
        }
        push(start + to_wall(t.end_us), &mut order, Action::Close(i));
    }
    schedule.sort_by(|a, b| {
        a.due_s
            .partial_cmp(&b.due_s)
            .expect("schedule times are finite")
            .then(a.order.cmp(&b.order))
    });
    schedule
}

/// Drive `traffic` through `svc` open-loop: spawn the worker pool, hold
/// to the arrival schedule against the wall clock, drain, report.
///
/// The generator never waits on the service: if an item comes due while
/// a previous `ingest()` is still blocked on the state lock, the
/// schedule slips and the slip is recorded in
/// [`LoadReport::max_lag_s`] rather than silently stretching the
/// offered load.
pub fn drive_open_loop(
    svc: &StreamingService,
    traffic: &[SessionTraffic],
    cfg: &LoadConfig,
) -> Result<LoadReport> {
    let _span = crate::telemetry::trace::span("load.drive_open_loop");
    ensure!(
        cfg.time_scale.is_finite() && cfg.time_scale > 0.0,
        "load time_scale must be positive and finite (got {})",
        cfg.time_scale
    );
    let chunk = cfg.chunk.max(1);
    let mut rng = Rng::new(cfg.seed);
    let starts = cfg.arrivals.sample_starts(traffic.len(), &mut rng);
    let schedule = build_schedule(traffic, &starts, cfg.time_scale, chunk);

    let (drive_wall_s, max_lag_s) = svc.run_with(|s| {
        let epoch = Instant::now();
        let mut max_lag_s = 0.0f64;
        for item in &schedule {
            let due = epoch + Duration::from_secs_f64(item.due_s.max(0.0));
            let now = Instant::now();
            if now < due {
                std::thread::sleep(due - now);
            } else {
                max_lag_s = max_lag_s.max((now - due).as_secs_f64());
            }
            match item.action {
                Action::Open(i) => s.open_session(traffic[i].id, traffic[i].label)?,
                Action::Ingest { session, lo, hi } => {
                    s.ingest(traffic[session].id, &traffic[session].events[lo..hi])?
                }
                Action::Close(i) => s.close_session(traffic[i].id, traffic[i].end_us)?,
            }
        }
        s.drain()?;
        Ok((epoch.elapsed().as_secs_f64(), max_lag_s))
    })?;

    // Offered load under the service's session clock: a session spanning
    // `end_us` holds `end_us / window_us + 1` micro-windows (the final
    // flush always emits a last marker).
    let session = &svc.config().session;
    let window_us = (session.step_us * session.frames_per_window as u64).max(1);
    let n = traffic.len().max(1) as f64;
    let mean_windows: f64 = traffic
        .iter()
        .map(|t| (t.end_us / window_us + 1) as f64)
        .sum::<f64>()
        / n;
    let mean_events: f64 = traffic.iter().map(|t| t.events.len() as f64).sum::<f64>() / n;
    let rate = cfg.arrivals.rate_per_sec();
    let serve = svc.report(drive_wall_s);
    Ok(LoadReport {
        offered_sessions_per_sec: rate,
        offered_windows_per_sec: rate * mean_windows,
        offered_events_per_sec: rate * mean_events,
        goodput_windows_per_sec: serve.windows_done as f64 / drive_wall_s.max(1e-9),
        drive_wall_s,
        max_lag_s,
        serve,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::Policy;
    use crate::serve::service::gesture_traffic;
    use crate::serve::ServiceConfig;
    use crate::snn::{LayerSpec, Network, Resolution};

    fn small_net() -> Network {
        let r = Resolution::new(4, 9);
        Network::new(
            "load-test",
            vec![
                LayerSpec::conv("C1", 2, 4, 3, 4, 1, 48, 48, r),
                LayerSpec::fc("F1", 4 * 12 * 12, 10, Resolution::new(5, 10)),
            ],
            16,
        )
    }

    fn service(workers: usize, cfg_mut: impl FnOnce(&mut ServiceConfig)) -> StreamingService {
        let mut cfg = ServiceConfig::nominal(workers);
        cfg_mut(&mut cfg);
        StreamingService::native(small_net(), 0xBEEF, 2, Policy::HsOpt, cfg)
    }

    #[test]
    fn poisson_starts_are_sorted_with_the_right_mean() {
        let p = ArrivalProcess::Poisson { rate_per_sec: 100.0 };
        let mut rng = Rng::new(7);
        let starts = p.sample_starts(2000, &mut rng);
        assert_eq!(starts.len(), 2000);
        assert!(starts.windows(2).all(|w| w[0] <= w[1]), "starts are sorted");
        assert!(starts[0] > 0.0);
        // 2000 arrivals at 100/s span ~20 s; the sample mean of i.i.d.
        // exponential gaps concentrates tightly at this count.
        let span = starts.last().unwrap();
        assert!((15.0..25.0).contains(span), "span {span} outside ±25% of 20 s");
    }

    #[test]
    fn bursty_starts_share_group_instants_at_the_same_mean_rate() {
        let p = ArrivalProcess::Bursty { rate_per_sec: 100.0, burst: 4 };
        let mut rng = Rng::new(7);
        let starts = p.sample_starts(200, &mut rng);
        assert_eq!(starts.len(), 200);
        for group in starts.chunks(4) {
            assert!(
                group.iter().all(|&t| t == group[0]),
                "every burst member shares the group instant"
            );
        }
        let distinct = starts.chunks(4).count();
        assert_eq!(distinct, 50);
        let span = starts.last().unwrap();
        assert!((1.0..4.0).contains(span), "50 groups at 25/s span ~2 s, got {span}");
        assert_eq!(p.rate_per_sec(), 100.0);
    }

    #[test]
    fn schedule_orders_open_before_chunks_before_close() {
        let traffic = gesture_traffic(3, 11, 0);
        let starts = [0.5, 0.0, 0.25];
        let schedule = build_schedule(&traffic, &starts, 10.0, 64);
        let mut opened = [false; 3];
        let mut closed = [false; 3];
        let mut last_due = f64::NEG_INFINITY;
        for item in &schedule {
            assert!(item.due_s >= last_due, "schedule is time-sorted");
            last_due = item.due_s;
            match item.action {
                Action::Open(i) => opened[i] = true,
                Action::Ingest { session, .. } => {
                    assert!(opened[session] && !closed[session]);
                }
                Action::Close(i) => {
                    assert!(opened[i] && !closed[i]);
                    closed[i] = true;
                }
            }
        }
        assert!(opened.iter().all(|&o| o) && closed.iter().all(|&c| c));
    }

    #[test]
    fn open_loop_drive_completes_every_session() {
        let svc = service(2, |_| {});
        let traffic = gesture_traffic(4, 21, 0);
        let cfg = LoadConfig {
            arrivals: ArrivalProcess::Poisson { rate_per_sec: 200.0 },
            time_scale: 100.0,
            chunk: 64,
            seed: 3,
        };
        let report = drive_open_loop(&svc, &traffic, &cfg).unwrap();
        assert_eq!(report.serve.finished_sessions, 4);
        assert_eq!(report.serve.windows_shed, 0);
        assert!(report.goodput_windows_per_sec > 0.0);
        assert!(report.offered_windows_per_sec > 0.0);
        assert!(report.drive_wall_s > 0.0);
        assert!(report.max_lag_s >= 0.0);
        // In-order delivery within jitter slack: nothing dropped.
        assert_eq!(report.serve.events_dropped, 0);
    }

    #[test]
    fn zero_capacity_sheds_under_open_loop_without_stalling() {
        let svc = service(1, |c| c.queue_capacity = 0);
        let traffic = gesture_traffic(3, 33, 0);
        let cfg = LoadConfig {
            arrivals: ArrivalProcess::Bursty { rate_per_sec: 500.0, burst: 3 },
            time_scale: 200.0,
            chunk: 128,
            seed: 9,
        };
        let report = drive_open_loop(&svc, &traffic, &cfg).unwrap();
        assert!(report.serve.windows_shed > 0, "zero capacity must shed");
        assert_eq!(
            report.serve.finished_sessions, 3,
            "shedding degrades sessions, never stalls them"
        );
    }

    #[test]
    fn nonpositive_time_scale_is_rejected() {
        let svc = service(1, |_| {});
        let traffic = gesture_traffic(1, 1, 0);
        let cfg = LoadConfig { time_scale: 0.0, ..LoadConfig::default() };
        let err = drive_open_loop(&svc, &traffic, &cfg).unwrap_err();
        assert!(err.to_string().contains("time_scale"));
    }
}
