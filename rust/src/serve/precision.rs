//! Per-session serve-time precision control.
//!
//! FlexSpIM's headline circuit feature is bitwise-granular operand
//! resolution — the paper's "up to 90% energy saving" comes from running
//! layers at fewer weight/vmem bits. The fig6 sweeps exercise that
//! statically; this module turns it into a closed-loop serve policy:
//!
//! * **drop** a session's resolution one tier when the service is loaded
//!   (rolling p99 over the SLO, or queue depth past the high-water mark —
//!   the same signals the autoscaler reads), shedding energy instead of
//!   requests;
//! * **raise** it one tier when the session's smoothed classification
//!   margin is low (the early-exit confidence machinery read in reverse:
//!   an uncertain session gets its precision back even under load);
//! * **relax** one tier back toward full precision when the service is
//!   calm.
//!
//! Tiers are uniform down-scalings of the deployed net's per-layer
//! `(w_bits, p_bits)` — the same grid as the fig6 resolution sweep
//! ([`crate::figures::fig6::scaling_configs_for`]): tier δ subtracts δ
//! bits from every layer, floored at 2 weight / 4 membrane bits. Tier 0
//! is the deployed (full) resolution.
//!
//! The controller is a pure function ([`PrecisionConfig::decide`]) in the
//! style of the autoscaler's `AutoscaleConfig::decide`, called at each
//! window commit; the service applies a verdict by rescaling the
//! session's checkpoint ([`StateSnapshot::rescaled`]) and letting the
//! next dispatch reconfigure its worker's backend via `set_resolutions`
//! (cheap: conv adjacencies come out of the shared `AdjacencyCache`).
//!
//! [`StateSnapshot::rescaled`]: crate::runtime::StateSnapshot::rescaled

use crate::snn::Network;

/// Hard cap on `max_delta`: tier tables never exceed 8 entries, so the
/// per-tier telemetry labels stay a fixed static set.
pub const MAX_DELTA_LIMIT: u32 = 7;

/// Static per-tier label values for telemetry (`resolution_tier` label).
pub const TIER_LABELS: [&str; MAX_DELTA_LIMIT as usize + 1] =
    ["0", "1", "2", "3", "4", "5", "6", "7"];

/// Precision-controller policy knobs. `decide` is pure — the service owns
/// the clock and the signals.
#[derive(Debug, Clone, PartialEq)]
pub struct PrecisionConfig {
    /// Master switch; disabled costs one branch per window commit.
    pub enabled: bool,
    /// Deepest tier: every layer may lose up to this many bits
    /// (clamped to the fig6 floor of 2 weight / 4 membrane bits).
    pub max_delta: u32,
    /// Rolling-p99 window latency above which a tier is dropped (seconds).
    pub drop_p99_s: f64,
    /// Queued windows per active worker considered overloaded.
    pub queue_high: usize,
    /// Smoothed classification margin below which precision is raised.
    pub raise_margin: f64,
    /// Windows a session must have executed before margin-driven raises
    /// may trigger (the margin estimate needs samples first).
    pub min_windows: u64,
}

impl PrecisionConfig {
    /// Adaptation off; knobs at their nominal values.
    pub fn disabled() -> PrecisionConfig {
        PrecisionConfig {
            enabled: false,
            max_delta: 3,
            drop_p99_s: 0.020,
            queue_high: 8,
            raise_margin: 0.5,
            min_windows: 2,
        }
    }

    /// One pure control decision for one session: current `tier` plus the
    /// service signals (rolling p99 seconds, queued windows, active
    /// workers) and the session signals (smoothed margin, windows done)
    /// in, target tier out.
    ///
    /// Priority order:
    /// 1. an uncertain session (margin below `raise_margin` after
    ///    `min_windows` windows) is raised one tier — uncertainty beats
    ///    load;
    /// 2. a loaded service (p99 over `drop_p99_s` or queue past
    ///    `queue_high` per worker) drops one tier, capped at `max_delta`;
    /// 3. a calm service (p99 under half the drop threshold — or no
    ///    samples yet — and queue under half the high-water mark) relaxes
    ///    one tier back toward full precision;
    /// 4. otherwise hold.
    ///
    /// A NaN p99 (empty latency window) never reads as load.
    pub fn decide(
        &self,
        tier: usize,
        p99_s: f64,
        queued: usize,
        workers: usize,
        margin: f64,
        windows_done: u64,
    ) -> usize {
        let max_tier = self.max_delta.min(MAX_DELTA_LIMIT) as usize;
        let tier = tier.min(max_tier);
        let w = workers.max(1);
        if tier > 0 && windows_done >= self.min_windows && margin < self.raise_margin {
            return tier - 1;
        }
        let loaded = p99_s > self.drop_p99_s || queued > self.queue_high * w;
        if loaded {
            return (tier + 1).min(max_tier);
        }
        // `!(p99 >= …)` so an empty window (NaN) reads as calm.
        let calm = !(p99_s >= 0.5 * self.drop_p99_s) && queued * 2 <= self.queue_high * w;
        if calm && tier > 0 {
            return tier - 1;
        }
        tier
    }
}

/// The tier table for `net`: entry δ holds the per-layer `(w_bits,
/// p_bits)` with every layer uniformly down-scaled by δ bits, floored at
/// 2 weight / 4 membrane bits — the fig6 sweep grid. Entry 0 is the
/// deployed resolution unchanged.
pub fn tiers_for(net: &Network, max_delta: u32) -> Vec<Vec<(u32, u32)>> {
    let base: Vec<(u32, u32)> =
        net.layers.iter().map(|l| (l.res.w_bits, l.res.p_bits)).collect();
    (0..=max_delta.min(MAX_DELTA_LIMIT) as i64)
        .map(|delta| {
            base.iter()
                .map(|&(w, p)| {
                    ((w as i64 - delta).max(2) as u32, (p as i64 - delta).max(4) as u32)
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::{LayerSpec, Resolution};

    fn cfg() -> PrecisionConfig {
        PrecisionConfig {
            enabled: true,
            max_delta: 3,
            drop_p99_s: 0.020,
            queue_high: 8,
            raise_margin: 0.5,
            min_windows: 2,
        }
    }

    #[test]
    fn load_drops_and_saturates_at_max_delta() {
        let c = cfg();
        // p99 over the threshold drops one tier per decision…
        assert_eq!(c.decide(0, 0.050, 0, 4, 9.0, 10), 1);
        assert_eq!(c.decide(1, 0.050, 0, 4, 9.0, 10), 2);
        // …and saturates at max_delta.
        assert_eq!(c.decide(3, 0.050, 0, 4, 9.0, 10), 3);
        // Queue depth past high-water per worker is the same signal.
        assert_eq!(c.decide(0, 0.001, 8 * 4 + 1, 4, 9.0, 10), 1);
    }

    #[test]
    fn calm_relaxes_toward_full_precision_with_hysteresis_band() {
        let c = cfg();
        // Calm (p99 < half the drop threshold) relaxes one tier…
        assert_eq!(c.decide(2, 0.005, 0, 4, 9.0, 10), 1);
        // …an empty latency window (NaN) reads as calm, never as load…
        assert_eq!(c.decide(2, f64::NAN, 0, 4, 9.0, 10), 1);
        assert_eq!(c.decide(0, f64::NAN, 0, 4, 9.0, 10), 0);
        // …and the band between half and full threshold holds.
        assert_eq!(c.decide(2, 0.015, 0, 4, 9.0, 10), 2);
    }

    #[test]
    fn low_margin_raises_even_under_load() {
        let c = cfg();
        // Uncertainty beats load: margin under raise_margin raises a tier
        // although the p99 screams overload.
        assert_eq!(c.decide(3, 0.100, 100, 1, 0.1, 10), 2);
        // But not before min_windows margin samples exist…
        assert_eq!(c.decide(3, 0.100, 100, 1, 0.1, 1), 3);
        // …and never above full precision.
        assert_eq!(c.decide(0, 0.001, 0, 4, 0.1, 10), 0);
    }

    #[test]
    fn tier_table_matches_the_fig6_grid() {
        let net = crate::snn::Network::new(
            "t",
            vec![
                LayerSpec::conv("C1", 2, 4, 3, 4, 1, 48, 48, Resolution::new(4, 9)),
                LayerSpec::fc("F1", 4 * 12 * 12, 10, Resolution::new(5, 10)),
            ],
            4,
        );
        let tiers = tiers_for(&net, 3);
        assert_eq!(tiers.len(), 4);
        assert_eq!(tiers[0], vec![(4, 9), (5, 10)], "tier 0 is the deployed resolution");
        assert_eq!(tiers[1], vec![(3, 8), (4, 9)]);
        assert_eq!(tiers[3], vec![(2, 6), (2, 7)], "w_bits floored at 2");
        // Same grid as the fig6 sweep.
        let fig6 = crate::figures::fig6::scaling_configs_for(&net);
        for (t, (_, cfg)) in tiers.iter().zip(&fig6) {
            assert_eq!(t, cfg);
        }
    }

    #[test]
    fn max_delta_is_capped_for_static_tier_labels() {
        let net = crate::snn::Network::new(
            "t",
            vec![LayerSpec::fc("F1", 16, 10, Resolution::new(8, 12))],
            4,
        );
        assert_eq!(tiers_for(&net, 99).len(), TIER_LABELS.len());
        let c = PrecisionConfig { max_delta: 99, ..cfg() };
        assert_eq!(c.decide(50, 0.050, 0, 1, 9.0, 10), MAX_DELTA_LIMIT as usize);
    }
}
