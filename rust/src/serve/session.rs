//! Per-client session state and vmem residency management.
//!
//! The paper's layer-wise weight/output stationarity makes an SNN's
//! membrane potentials *persistent state* held in the unified CIM storage.
//! A streaming session exploits exactly that: each micro-window resumes
//! from the previous window's vmem ([`StateSnapshot`]) instead of
//! re-simulating from reset, so serving is incremental in the same sense
//! the chip is output-stationary.
//!
//! Residency is a budget, not a given: the CIM array plus global buffer
//! hold only so many sessions' vmem. [`SessionManager`] tracks an LRU set
//! of resident sessions against `resident_budget_bits`; admitting a window
//! of a non-resident session refills its state from DRAM, and overflowing
//! the budget evicts the least-recently-used session — both priced as DRAM
//! traffic in [`RunMetrics`] (`state_spill_bits` / `state_evictions` and
//! `energy.movement_pj`), the serving-tier analogue of the paper's
//! streamed-operand energy.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::coordinator::engine::WindowTotals;
use crate::coordinator::metrics::{LatencyStats, RunMetrics};
use crate::events::SpikeFrame;
use crate::runtime::{ScnnRunner, StateSnapshot};
use crate::snn::events::SpikeList;
use crate::snn::Network;
use crate::Result;

use super::ingest::{IngestConfig, MicroWindow, ReorderBuffer};

/// Session-level configuration, shared by every session of a service.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Sensor width in pixels.
    pub width: u16,
    /// Sensor height in pixels.
    pub height: u16,
    /// SNN timestep width in microseconds (one spike frame per step).
    pub step_us: u64,
    /// Timesteps per micro-window (window span = `step_us` × this).
    pub frames_per_window: usize,
    /// Reorder slack for the jitter buffer (microseconds).
    pub max_lateness_us: u64,
    /// Ingest buffer bound (events per session).
    pub max_pending_events: usize,
    /// Bound on timestamps past the emitted frontier (malformed-input
    /// guard; see [`IngestConfig::max_future_us`]).
    pub max_future_us: u64,
    /// EMA coefficient for rolling (label-smoothed) classification: the
    /// weight of the newest window's class rates.
    pub smoothing: f64,
}

impl SessionConfig {
    /// Defaults matched to the 48×48 gesture workload: 6.25-ms timesteps
    /// (16 per 100-ms sample), 4 timesteps per window.
    pub fn default_48() -> SessionConfig {
        SessionConfig {
            width: 48,
            height: 48,
            step_us: 6_250,
            frames_per_window: 4,
            max_lateness_us: 12_500,
            max_pending_events: 1 << 16,
            max_future_us: 10_000_000,
            smoothing: 0.35,
        }
    }

    /// Micro-window span in microseconds.
    pub fn window_us(&self) -> u64 {
        self.step_us * self.frames_per_window as u64
    }

    /// The matching ingest configuration.
    pub fn ingest(&self) -> IngestConfig {
        IngestConfig {
            width: self.width,
            height: self.height,
            window_us: self.window_us(),
            max_lateness_us: self.max_lateness_us,
            max_pending: self.max_pending_events,
            max_future_us: self.max_future_us,
        }
    }
}

/// Number of spike frames [`encode_window`] would emit for `w` — exposed
/// so the early-exit accounting can price a *skipped* window in saved
/// frames without encoding it.
pub fn window_frames(cfg: &SessionConfig, w: &MicroWindow) -> usize {
    if w.last {
        // Partial tail window: only as many frames as its span needs,
        // capped at the nominal window size. A zero-span last marker
        // (stream closed at or before the emitted frontier) encodes to
        // zero frames — nothing runs past the declared end.
        (w.span_us().div_ceil(cfg.step_us.max(1)) as usize).min(cfg.frames_per_window)
    } else {
        cfg.frames_per_window
    }
}

/// Per-worker reusable encoder scratch: the spike-list frames of
/// [`encode_window_into`] live here across windows, so the serve hot path
/// encodes without a single heap allocation once the buffers are warm.
#[derive(Debug, Clone, Default)]
pub struct EncodeScratch {
    frames: Vec<SpikeList>,
}

/// Encode one micro-window into per-timestep sparse spike lists with the
/// same binning rule as [`crate::events::encode_frames_sparse`]: frame `k`
/// of the window owns `[t0 + k·step, t0 + (k+1)·step)`, and the final
/// frame of a `last` window absorbs the tail (clamped index) — so a window
/// sequence aligned to the monolithic frame grid encodes bit-identically
/// to the monolithic encoder.
///
/// The frames are built in `scratch`'s reusable buffers (grown on first
/// use, allocation-free thereafter) and returned as a borrowed slice.
pub fn encode_window_into<'a>(
    cfg: &SessionConfig,
    w: &MicroWindow,
    scratch: &'a mut EncodeScratch,
) -> &'a [SpikeList] {
    let step = cfg.step_us.max(1);
    let n = window_frames(cfg, w);
    let dim = 2 * cfg.height as usize * cfg.width as usize;
    if scratch.frames.len() < n {
        scratch.frames.resize_with(n, SpikeList::default);
    }
    for f in &mut scratch.frames[..n] {
        f.begin(dim);
    }
    if n > 0 {
        let hw = cfg.height as usize * cfg.width as usize;
        for e in &w.events {
            let idx = (((e.t_us.saturating_sub(w.t0_us)) / step) as usize).min(n - 1);
            let c = if e.polarity { 0usize } else { 1 };
            scratch.frames[idx].push_unordered(
                (c * hw + e.y as usize * cfg.width as usize + e.x as usize) as u32,
            );
        }
        for f in &mut scratch.frames[..n] {
            f.seal();
        }
    }
    &scratch.frames[..n]
}

/// Allocating dense-frame wrapper around [`encode_window_into`] (compat
/// boundary for callers that want [`SpikeFrame`]s; the serve workers use
/// the scratch-reusing sparse path directly).
pub fn encode_window(cfg: &SessionConfig, w: &MicroWindow) -> Vec<SpikeFrame> {
    let mut scratch = EncodeScratch::default();
    encode_window_into(cfg, w, &mut scratch)
        .iter()
        .map(|sl| SpikeFrame::from_spike_list(cfg.width, cfg.height, sl))
        .collect()
}

/// A queued, not-yet-executed window with its admission timestamp (the
/// start of the latency measurement).
#[derive(Debug, Clone)]
pub struct QueuedWindow {
    /// The window to run.
    pub window: MicroWindow,
    /// When the service admitted it.
    pub enqueued_at: std::time::Instant,
    /// Global admission sequence number — the dispatch order key of the
    /// service's deterministic-admission mode.
    pub seq: u64,
}

/// One executed window's outcome, handed from a worker back to its
/// session at commit time.
#[derive(Debug, Clone)]
pub struct WindowOutcome {
    /// Classifier spike counts of this window alone.
    pub rate: Vec<i64>,
    /// Membrane state after the window (the next checkpoint).
    pub state: StateSnapshot,
    /// Model totals of the window.
    pub totals: WindowTotals,
    /// Admission→completion latency (seconds).
    pub latency_s: f64,
    /// Host wall-clock of the execution alone (seconds).
    pub wallclock_s: f64,
    /// This was the session's final window.
    pub last: bool,
}

/// One client session: jitter buffer, checkpointed vmem, rolling
/// classification, and per-session serving metrics.
#[derive(Debug)]
pub struct Session {
    /// Session identity.
    pub id: u64,
    /// Ground-truth label when known (synthetic traffic / evaluation).
    pub label: Option<usize>,
    /// The reorder/jitter buffer in front of this session.
    pub ingest: ReorderBuffer,
    /// Checkpointed membrane state between windows — the session's
    /// output-stationary residency.
    pub state: StateSnapshot,
    /// Admitted windows awaiting execution (in time order).
    pub queue: VecDeque<QueuedWindow>,
    /// Accumulated classifier spike counts across all executed windows.
    pub rate: Vec<i64>,
    /// Exponentially smoothed per-class window rates (rolling prediction).
    pub smoothed: Vec<f64>,
    /// Executed windows.
    pub windows_done: u64,
    /// Windows dropped by the load-shed policy.
    pub windows_shed: u64,
    /// Accumulated model totals (frames, SOPs, energy, CIM ledger) across
    /// executed windows.
    pub totals: WindowTotals,
    /// Per-window admission→completion latency.
    pub latency: LatencyStats,
    /// Summed host wall-clock of this session's window executions.
    pub wallclock_s: f64,
    /// A worker is currently executing a window of this session (window
    /// order is a state dependency, so at most one is ever in flight).
    pub running: bool,
    /// The client closed the stream; the final window is queued or done.
    pub closed: bool,
    /// The session has executed its final window.
    pub finished: bool,
    /// Currently counted resident in the vmem budget.
    pub resident: bool,
    /// Has ever been resident (a fresh session zero-initializes instead of
    /// refilling from DRAM).
    pub ever_resident: bool,
    /// The rolling classification crossed the early-exit confidence bound;
    /// remaining windows are skipped instead of executed.
    pub early_exited: bool,
    /// Windows skipped after early exit (distinct from load-shed drops).
    pub windows_saved: u64,
    /// Spike frames those skipped windows would have executed.
    pub frames_saved: u64,
    /// Resolution tier the precision controller currently holds this
    /// session at: 0 is the deployed (full) resolution, tier δ runs every
    /// layer δ bits narrower (see [`crate::serve::precision`]). The
    /// session's `state` checkpoint is always aligned to this tier.
    pub tier: usize,
    /// Last ingest/commit activity — the idle reaper's clock.
    pub last_activity: Instant,
}

impl Session {
    /// Open a session for `net` (state starts at reset).
    pub fn new(id: u64, cfg: &SessionConfig, net: &Network, label: Option<usize>) -> Session {
        Session {
            id,
            label,
            ingest: ReorderBuffer::new(cfg.ingest()),
            state: StateSnapshot::zeros(net),
            queue: VecDeque::new(),
            rate: vec![0i64; 10],
            smoothed: vec![0f64; 10],
            windows_done: 0,
            windows_shed: 0,
            totals: WindowTotals::default(),
            latency: LatencyStats::new(),
            wallclock_s: 0.0,
            running: false,
            closed: false,
            finished: false,
            resident: false,
            ever_resident: false,
            early_exited: false,
            windows_saved: 0,
            frames_saved: 0,
            tier: 0,
            last_activity: Instant::now(),
        }
    }

    /// Commit one executed window: accumulate spikes, smooth the rolling
    /// logits, merge totals, record latency.
    pub fn commit_window(&mut self, smoothing: f64, outcome: WindowOutcome) {
        for (acc, &r) in self.rate.iter_mut().zip(&outcome.rate) {
            *acc += r;
        }
        for (s, &r) in self.smoothed.iter_mut().zip(&outcome.rate) {
            *s = (1.0 - smoothing) * *s + smoothing * r as f64;
        }
        self.state = outcome.state;
        self.totals.add(&outcome.totals);
        self.latency.push(outcome.latency_s);
        self.wallclock_s += outcome.wallclock_s;
        self.windows_done += 1;
        self.last_activity = Instant::now();
        if outcome.last {
            self.finished = true;
        }
    }

    /// Final prediction from the accumulated (unsmoothed) rate — identical
    /// to the offline path's argmax for the same spikes.
    pub fn prediction(&self) -> usize {
        ScnnRunner::predict(&self.rate)
    }

    /// Confidence margin of the rolling classification: top-1 minus top-2
    /// of the smoothed per-class window rates. The early-exit policy stops
    /// serving a session once this clears its configured bound.
    pub fn smoothed_margin(&self) -> f64 {
        let mut top = f64::NEG_INFINITY;
        let mut second = f64::NEG_INFINITY;
        for &s in &self.smoothed {
            if s > top {
                second = top;
                top = s;
            } else if s > second {
                second = s;
            }
        }
        if second.is_finite() {
            top - second
        } else {
            top
        }
    }

    /// Rolling prediction from the label-smoothed window rates.
    pub fn rolling_prediction(&self) -> usize {
        self.smoothed
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Assemble this session's serving metrics as a [`RunMetrics`] block
    /// (one session = one sample; spill traffic is accounted service-wide,
    /// not here).
    pub fn metrics(&self) -> RunMetrics {
        let correct = match (self.label, self.finished) {
            (Some(l), true) => (l == self.prediction()) as u64,
            _ => 0,
        };
        RunMetrics {
            samples: 1,
            correct,
            timesteps: self.totals.frames,
            in_events: self.totals.in_events,
            sops: self.totals.sops,
            mean_sparsity: self.totals.sparsity_acc / self.totals.frames.max(1) as f64,
            energy: self.totals.energy,
            cim: self.totals.cim,
            modeled_latency_s: self.totals.modeled_latency_s,
            wallclock_s: self.wallclock_s,
            ..Default::default()
        }
    }
}

/// Residency charge of admitting one session window (bits of DRAM
/// traffic; the service prices them with the plan's energy model).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResidencyCharge {
    /// Bits read from DRAM to refill the admitted session's vmem.
    pub fill_bits: u64,
    /// Bits written to DRAM spilling evicted sessions' vmem.
    pub spill_bits: u64,
    /// Sessions evicted to make room.
    pub evictions: u64,
}

/// Owner of all sessions plus the vmem residency budget.
#[derive(Debug)]
pub struct SessionManager {
    cfg: SessionConfig,
    /// Per-session vmem footprint in bits (uniform: one workload per
    /// service).
    vmem_bits: u64,
    /// Residency budget in bits (CIM array + global buffer share).
    budget_bits: u64,
    sessions: HashMap<u64, Session>,
    /// Resident sessions, least-recently-used first.
    lru: VecDeque<u64>,
    resident_bits: u64,
    /// Next never-used id for [`Self::allocate_id`].
    next_id: u64,
    /// Ids released by [`Self::remove`] / [`Self::reap_idle`], reused
    /// LIFO — long-running services recycle ids instead of counting up
    /// forever.
    free_ids: Vec<u64>,
    /// Cumulative refills from DRAM (bits).
    pub fill_bits: u64,
    /// Cumulative spills to DRAM (bits).
    pub spill_bits: u64,
    /// Cumulative evictions.
    pub evictions: u64,
    /// Sessions closed by the idle reaper.
    pub reaped: u64,
}

impl SessionManager {
    /// Empty manager for sessions of `net` under `budget_bits` of vmem
    /// residency.
    pub fn new(cfg: SessionConfig, net: &Network, budget_bits: u64) -> SessionManager {
        SessionManager {
            cfg,
            vmem_bits: net.total_vmem_bits(),
            budget_bits,
            sessions: HashMap::new(),
            lru: VecDeque::new(),
            resident_bits: 0,
            next_id: 0,
            free_ids: Vec::new(),
            fill_bits: 0,
            spill_bits: 0,
            evictions: 0,
            reaped: 0,
        }
    }

    /// The session configuration.
    pub fn config(&self) -> &SessionConfig {
        &self.cfg
    }

    /// Per-session vmem footprint in bits.
    pub fn vmem_bits(&self) -> u64 {
        self.vmem_bits
    }

    /// Open a new session; errors if the id is taken.
    pub fn open(&mut self, id: u64, net: &Network, label: Option<usize>) -> Result<()> {
        anyhow::ensure!(
            !self.sessions.contains_key(&id),
            "session {id} already exists"
        );
        self.sessions.insert(id, Session::new(id, &self.cfg, net, label));
        // Keep the auto-allocator clear of explicitly chosen ids.
        self.next_id = self.next_id.max(id + 1);
        Ok(())
    }

    /// Hand out an unused session id, preferring recycled ones — a
    /// long-running front end reuses the id space instead of growing it
    /// unboundedly.
    pub fn allocate_id(&mut self) -> u64 {
        while let Some(id) = self.free_ids.pop() {
            // An explicitly reopened id may have re-entered use since it
            // was recycled.
            if !self.sessions.contains_key(&id) {
                return id;
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Close every session that is safe to reap: no window running, no
    /// window queued, and either finished or idle for at least `max_idle`.
    /// Returns the reaped ids (ascending); their ids are recycled.
    pub fn reap_idle(&mut self, max_idle: Duration) -> Vec<u64> {
        let now = Instant::now();
        let mut victims: Vec<u64> = self
            .sessions
            .iter()
            .filter(|(_, s)| {
                !s.running
                    && s.queue.is_empty()
                    && (s.finished
                        || now.saturating_duration_since(s.last_activity) >= max_idle)
            })
            .map(|(&id, _)| id)
            .collect();
        victims.sort_unstable();
        for &id in &victims {
            self.remove(id);
        }
        self.reaped += victims.len() as u64;
        victims
    }

    /// Look up a session.
    pub fn get(&self, id: u64) -> Option<&Session> {
        self.sessions.get(&id)
    }

    /// Look up a session mutably.
    pub fn get_mut(&mut self, id: u64) -> Option<&mut Session> {
        self.sessions.get_mut(&id)
    }

    /// All session ids, ascending (deterministic iteration/report order).
    pub fn ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.sessions.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// Open session count.
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    /// True when no sessions are open.
    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Sessions currently counted resident.
    pub fn resident_count(&self) -> usize {
        self.lru.len()
    }

    /// Make `id` resident for a window execution, evicting LRU sessions if
    /// the budget overflows. Returns the DRAM traffic this admission
    /// caused. A fresh session (never resident) zero-initializes in place
    /// of a DRAM refill, exactly like the chip's reset path.
    pub fn admit(&mut self, id: u64) -> ResidencyCharge {
        let mut charge = ResidencyCharge::default();
        let session = match self.sessions.get_mut(&id) {
            Some(s) => s,
            None => return charge,
        };
        if session.resident {
            // Refresh LRU position.
            if let Some(pos) = self.lru.iter().position(|&x| x == id) {
                let _ = self.lru.remove(pos);
            }
            self.lru.push_back(id);
            return charge;
        }
        if session.ever_resident {
            charge.fill_bits = self.vmem_bits;
            self.fill_bits += self.vmem_bits;
        }
        session.resident = true;
        session.ever_resident = true;
        self.lru.push_back(id);
        self.resident_bits += self.vmem_bits;
        // Evict least-recently-used sessions (never the one just
        // admitted) until the budget holds.
        while self.resident_bits > self.budget_bits && self.lru.len() > 1 {
            let victim = self.lru.pop_front().expect("len > 1");
            if victim == id {
                // Should be at the back, but guard anyway.
                self.lru.push_back(victim);
                continue;
            }
            if let Some(v) = self.sessions.get_mut(&victim) {
                v.resident = false;
            }
            self.resident_bits -= self.vmem_bits;
            charge.spill_bits += self.vmem_bits;
            charge.evictions += 1;
            self.spill_bits += self.vmem_bits;
            self.evictions += 1;
        }
        charge
    }

    /// Drop a session entirely (its residency share is released without a
    /// spill — the state is dead). The id returns to the recycle pool.
    pub fn remove(&mut self, id: u64) -> Option<Session> {
        if let Some(pos) = self.lru.iter().position(|&x| x == id) {
            let _ = self.lru.remove(pos);
            self.resident_bits -= self.vmem_bits;
        }
        let mut removed = self.sessions.remove(&id);
        if let Some(s) = removed.as_mut() {
            s.resident = false;
            self.free_ids.push(id);
        }
        removed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::DvsEvent;
    use crate::snn::{LayerSpec, Resolution};

    fn small_net() -> Network {
        let r = Resolution::new(4, 9);
        Network::new(
            "serve-session-test",
            vec![
                LayerSpec::fc("F1", 2 * 48 * 48, 16, r),
                LayerSpec::fc("F2", 16, 10, Resolution::new(5, 10)),
            ],
            16,
        )
    }

    fn mw(t0: u64, t1: u64, events: Vec<DvsEvent>, last: bool) -> MicroWindow {
        MicroWindow { t0_us: t0, t1_us: t1, events, last }
    }

    #[test]
    fn encode_window_matches_global_binning() {
        let cfg = SessionConfig::default_48();
        // Window 3 of a 16-frame stream: global frames 12..16.
        let t0 = 3 * cfg.window_us();
        let e = |t: u64| DvsEvent { t_us: t, x: 1, y: 2, polarity: true };
        let w = mw(
            t0,
            t0 + cfg.window_us(),
            vec![e(t0), e(t0 + cfg.step_us), e(t0 + 4 * cfg.step_us - 1)],
            false,
        );
        let frames = encode_window(&cfg, &w);
        assert_eq!(frames.len(), 4);
        assert!(frames[0].get(0, 1, 2));
        assert!(frames[1].get(0, 1, 2));
        assert!(frames[3].get(0, 1, 2));
        assert_eq!(frames[2].count(), 0);
    }

    #[test]
    fn encode_last_window_absorbs_tail_and_clamps() {
        let cfg = SessionConfig::default_48();
        let t0 = 3 * cfg.window_us();
        let end = 16 * cfg.step_us; // 100 ms
        let e = |t: u64| DvsEvent { t_us: t, x: 0, y: 0, polarity: false };
        // Flush-style last window: t1 = end + 1.
        let w = mw(t0, end + 1, vec![e(end)], true);
        let frames = encode_window(&cfg, &w);
        assert_eq!(frames.len(), 4, "span 25001 us still yields 4 frames");
        assert!(frames[3].get(1, 0, 0), "t == end lands in the final frame");
    }

    #[test]
    fn encode_short_last_window_shrinks() {
        let cfg = SessionConfig::default_48();
        // A session closed mid-window: only 2 steps of span.
        let w = mw(0, 2 * cfg.step_us + 1, vec![], true);
        assert_eq!(encode_window(&cfg, &w).len(), 3, "ceil(12501/6250)");
        let w = mw(0, 2 * cfg.step_us, vec![], true);
        assert_eq!(encode_window(&cfg, &w).len(), 2);
        // Zero-span last marker: no frames at all.
        let w = mw(3 * cfg.window_us(), 3 * cfg.window_us(), vec![], true);
        assert!(encode_window(&cfg, &w).is_empty());
    }

    #[test]
    fn session_commit_accumulates_and_smooths() {
        let net = small_net();
        let cfg = SessionConfig::default_48();
        let mut s = Session::new(7, &cfg, &net, Some(3));
        let mut rate = vec![0i64; 10];
        rate[3] = 4;
        rate[1] = 1;
        let totals = WindowTotals { frames: 4, sops: 100, ..Default::default() };
        let outcome = |latency_s: f64, last: bool| WindowOutcome {
            rate: rate.clone(),
            state: StateSnapshot::zeros(&net),
            totals: totals.clone(),
            latency_s,
            wallclock_s: 0.02,
            last,
        };
        s.commit_window(0.5, outcome(0.01, false));
        s.commit_window(0.5, outcome(0.03, true));
        assert_eq!(s.rate[3], 8);
        assert_eq!(s.windows_done, 2);
        assert!(s.finished);
        assert_eq!(s.prediction(), 3);
        assert_eq!(s.rolling_prediction(), 3);
        assert!((s.smoothed[3] - 3.0).abs() < 1e-12, "EMA: 0.5·4 then 0.5·2+0.5·4");
        let m = s.metrics();
        assert_eq!(m.samples, 1);
        assert_eq!(m.correct, 1);
        assert_eq!(m.timesteps, 8);
        assert_eq!(m.sops, 200);
        assert_eq!(m.modeled_latency_s, 0.0);
        assert!((m.wallclock_s - 0.04).abs() < 1e-12);
        assert_eq!(s.latency.count(), 2);
    }

    #[test]
    fn residency_budget_evicts_lru_and_charges_dram() {
        let net = small_net();
        let vmem = net.total_vmem_bits();
        // Room for exactly two resident sessions.
        let mut m = SessionManager::new(SessionConfig::default_48(), &net, 2 * vmem);
        for id in 0..3u64 {
            m.open(id, &net, None).unwrap();
        }
        // Fresh admissions: zero-init, no DRAM fill.
        assert_eq!(m.admit(0), ResidencyCharge::default());
        assert_eq!(m.admit(1), ResidencyCharge::default());
        assert_eq!(m.resident_count(), 2);
        // Third session overflows: LRU (0) spills.
        let c = m.admit(2);
        assert_eq!(c.evictions, 1);
        assert_eq!(c.spill_bits, vmem);
        assert_eq!(c.fill_bits, 0, "2 was never resident");
        assert!(!m.get(0).unwrap().resident);
        // Re-admitting 0 now refills from DRAM and evicts 1.
        let c = m.admit(0);
        assert_eq!(c.fill_bits, vmem);
        assert_eq!(c.evictions, 1);
        assert_eq!(m.fill_bits, vmem);
        assert_eq!(m.spill_bits, 2 * vmem);
        assert_eq!(m.evictions, 2);
        // Touching a resident session is free and refreshes LRU order.
        assert_eq!(m.admit(0), ResidencyCharge::default());
        assert_eq!(m.resident_count(), 2);
    }

    #[test]
    fn unbounded_budget_never_evicts() {
        let net = small_net();
        let mut m = SessionManager::new(SessionConfig::default_48(), &net, u64::MAX);
        for id in 0..16u64 {
            m.open(id, &net, None).unwrap();
            assert_eq!(m.admit(id), ResidencyCharge::default());
        }
        assert_eq!(m.resident_count(), 16);
        assert_eq!(m.evictions, 0);
    }

    #[test]
    fn duplicate_open_is_an_error() {
        let net = small_net();
        let mut m = SessionManager::new(SessionConfig::default_48(), &net, u64::MAX);
        m.open(1, &net, None).unwrap();
        assert!(m.open(1, &net, None).is_err());
    }

    #[test]
    fn allocate_recycles_removed_ids() {
        let net = small_net();
        let mut m = SessionManager::new(SessionConfig::default_48(), &net, u64::MAX);
        let a = m.allocate_id();
        let b = m.allocate_id();
        assert_eq!((a, b), (0, 1));
        m.open(a, &net, None).unwrap();
        m.open(b, &net, None).unwrap();
        m.remove(a);
        assert_eq!(m.allocate_id(), a, "removed id is recycled first");
        // Explicit opens keep the allocator clear of their ids.
        m.open(7, &net, None).unwrap();
        assert_eq!(m.allocate_id(), 8);
    }

    #[test]
    fn allocate_skips_recycled_id_reopened_explicitly() {
        let net = small_net();
        let mut m = SessionManager::new(SessionConfig::default_48(), &net, u64::MAX);
        let a = m.allocate_id();
        m.open(a, &net, None).unwrap();
        m.remove(a);
        m.open(a, &net, None).unwrap(); // client re-claims the id itself
        let next = m.allocate_id();
        assert_ne!(next, a, "an in-use recycled id must not be handed out");
    }

    #[test]
    fn reaper_closes_finished_and_idle_sessions_only() {
        let net = small_net();
        let mut m = SessionManager::new(SessionConfig::default_48(), &net, u64::MAX);
        for id in 0..4u64 {
            m.open(id, &net, None).unwrap();
            m.admit(id);
        }
        m.get_mut(1).unwrap().finished = true;
        m.get_mut(2).unwrap().running = true;
        m.get_mut(3).unwrap().queue.push_back(QueuedWindow {
            window: mw(0, 1, vec![], false),
            enqueued_at: Instant::now(),
            seq: 0,
        });
        // Huge idle bound: only the finished session qualifies.
        let reaped = m.reap_idle(Duration::from_secs(3600));
        assert_eq!(reaped, vec![1]);
        assert_eq!(m.len(), 3);
        assert_eq!(m.reaped, 1);
        assert_eq!(m.resident_count(), 3, "reaped session released residency");
        // Zero idle bound: everything idle goes; running/queued stay.
        let reaped = m.reap_idle(Duration::ZERO);
        assert_eq!(reaped, vec![0]);
        assert!(m.get(2).is_some() && m.get(3).is_some());
        // Reaped ids recycle.
        assert_eq!(m.allocate_id(), 0);
    }

    #[test]
    fn smoothed_margin_is_top1_minus_top2() {
        let net = small_net();
        let mut s = Session::new(1, &SessionConfig::default_48(), &net, None);
        assert_eq!(s.smoothed_margin(), 0.0, "all-zero logits have no margin");
        s.smoothed[3] = 5.0;
        s.smoothed[7] = 2.0;
        assert!((s.smoothed_margin() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn window_frames_matches_encode_window() {
        let cfg = SessionConfig::default_48();
        let cases = [
            mw(0, cfg.window_us(), vec![], false),
            mw(0, 2 * cfg.step_us + 1, vec![], true),
            mw(0, 2 * cfg.step_us, vec![], true),
            mw(100, 100, vec![], true),
        ];
        for w in &cases {
            assert_eq!(window_frames(&cfg, w), encode_window(&cfg, w).len());
        }
    }

    #[test]
    fn encode_window_into_matches_dense_and_reuses_scratch() {
        let cfg = SessionConfig::default_48();
        let mut scratch = EncodeScratch::default();
        let e = |t: u64, x: u16, y: u16, p: bool| DvsEvent { t_us: t, x, y, polarity: p };
        let windows = [
            mw(
                0,
                cfg.window_us(),
                vec![e(0, 1, 2, true), e(0, 1, 2, true), e(cfg.step_us, 3, 4, false)],
                false,
            ),
            mw(
                cfg.window_us(),
                2 * cfg.window_us(),
                vec![e(cfg.window_us() + 7, 47, 47, false), e(cfg.window_us(), 0, 0, true)],
                false,
            ),
            // Shrunken tail window, then a zero-span last marker.
            mw(2 * cfg.window_us(), 2 * cfg.window_us() + cfg.step_us, vec![], true),
            mw(100, 100, vec![], true),
        ];
        for w in &windows {
            let dense = encode_window(&cfg, w);
            let sparse = encode_window_into(&cfg, w, &mut scratch);
            assert_eq!(sparse.len(), dense.len());
            for (sl, f) in sparse.iter().zip(&dense) {
                assert_eq!(*sl, f.to_spike_list(), "window [{}, {})", w.t0_us, w.t1_us);
            }
        }
        // The scratch keeps the high-water frame count around for reuse.
        assert_eq!(scratch.frames.len(), cfg.frames_per_window);
    }

    #[test]
    fn remove_releases_residency_without_spill() {
        let net = small_net();
        let vmem = net.total_vmem_bits();
        let mut m = SessionManager::new(SessionConfig::default_48(), &net, vmem);
        m.open(1, &net, None).unwrap();
        m.admit(1);
        assert_eq!(m.resident_count(), 1);
        assert!(m.remove(1).is_some());
        assert_eq!(m.resident_count(), 0);
        assert_eq!(m.spill_bits, 0);
        assert!(m.is_empty());
    }
}
