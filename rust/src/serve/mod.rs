//! Streaming inference service: sessionized DVS ingestion with
//! vmem-resident incremental windows.
//!
//! The offline tiers ([`crate::coordinator::Coordinator`] and the batched
//! [`crate::coordinator::Engine`]) replay whole pre-recorded samples and
//! discard all state between them. Real event-based deployments are
//! continuous: a DVS camera never stops, and the paper's central
//! system-level idea — layer-wise weight/output stationarity with unified
//! CIM storage for weights *and* membrane potentials — means the SNN's
//! vmem is persistent state that should stay resident across consecutive
//! input windows. This module is that serving tier:
//!
//! * [`ingest`] — per-session AER ingestion: a reorder/jitter buffer that
//!   accepts out-of-order [`crate::events::DvsEvent`]s, rejects invalid
//!   client input with recoverable errors, and emits time-ordered
//!   micro-windows under a watermark discipline.
//! * [`session`] — per-client state: checkpointed membrane potentials
//!   ([`crate::runtime::StateSnapshot`]) so each window resumes where the
//!   last ended, rolling label-smoothed classification, and an LRU
//!   residency budget whose spills are priced as DRAM traffic in
//!   [`crate::coordinator::RunMetrics`].
//! * [`service`] — the admission/backpressure front end: bounded queues,
//!   round-robin session fairness (or deterministic admission-order
//!   dispatch for reproducible residency reports), newest-first load
//!   shedding, early-exit on the rolling classification's confidence
//!   margin, an idle-session reaper with id recycling, a worker pool
//!   multiplexing sessions over [`crate::runtime::StepBackend`]s, an
//!   SLO-driven autoscaler that grows/shrinks the active pool, and
//!   p50/p95/p99 window-latency + sessions/sec instrumentation.
//! * [`precision`] — per-session serve-time precision control: a pure
//!   policy ([`PrecisionConfig::decide`]) that drops weight/vmem
//!   resolution one fig6-grid tier under load (the autoscaler's p99 and
//!   queue-depth signals) and raises it when a session's smoothed
//!   classification margin is low, applied by rescaling the session
//!   checkpoint and reconfiguring worker backends via
//!   `set_resolutions` + the shared `AdjacencyCache`.
//! * [`load`] — an open-loop saturation harness: Poisson/bursty arrival
//!   processes drive sessions against the wall clock regardless of
//!   service backpressure, exposing the linear → knee → shedding
//!   regimes that closed-loop replay hides.
//!
//! Observability: with `[telemetry]` enabled each service owns a
//! [`crate::telemetry::Registry`] (admission/shed/commit counters,
//! queue-wait and window-latency histograms, a target-workers gauge)
//! and a [`crate::telemetry::FlightRecorder`] holding the last N
//! structured events — admissions, sheds, evictions, early exits, and
//! every autoscaler decide tick with its inputs and verdict. The hot
//! seams (ingest poll, window run, snapshot/restore) carry
//! [`crate::telemetry::trace`] spans for Chrome-trace export. See
//! `flexspim serve --dump-telemetry` and README §Observability.
//!
//! Correctness anchor: a sample streamed through the service in aligned
//! micro-windows is bit-identical (spikes, final vmem, prediction, SOPs,
//! CIM ledger) to the same sample run monolithically through the
//! sequential coordinator — pinned by `rust/tests/integration_serve.rs`.

pub mod ingest;
pub mod load;
pub mod precision;
pub mod session;
pub mod service;

pub use ingest::{IngestConfig, MicroWindow, ReorderBuffer};
pub use load::{drive_open_loop, ArrivalProcess, LoadConfig, LoadReport};
pub use precision::{tiers_for, PrecisionConfig};
pub use service::{
    gesture_traffic, AutoscaleConfig, ServeReport, ServiceConfig, SessionExport, SessionResult,
    SessionTraffic, StreamingService,
};
pub use session::{
    encode_window, encode_window_into, window_frames, EncodeScratch, QueuedWindow,
    ResidencyCharge, Session, SessionConfig, SessionManager, WindowOutcome,
};
