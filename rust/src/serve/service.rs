//! The streaming front end: admission, backpressure, and the worker pool.
//!
//! ```text
//!  clients ──ingest──► ReorderBuffer (per session, jitter absorb)
//!                         │ poll: time-ordered MicroWindows
//!                         ▼
//!                 admission control ──over capacity──► shed (counted)
//!                         │
//!                         ▼
//!              per-session FIFO + ready queue (round-robin fairness)
//!                         │ one window per session in flight
//!                         ▼
//!               worker pool (own StepBackend each, via factory)
//!                restore vmem → run_frames → snapshot vmem
//!                         │
//!                         ▼
//!            Session commit: rate, smoothed logits, metrics, latency
//! ```
//!
//! Two invariants make streamed inference equal offline inference:
//!
//! 1. **Per-session order.** A session's window `n + 1` depends on the
//!    vmem left by window `n`, so at most one window per session is ever
//!    in flight, and windows run in emission order. Different sessions'
//!    windows interleave freely across the pool.
//! 2. **State travels by snapshot.** A worker restores the session's
//!    checkpointed [`StateSnapshot`] into its own backend before the
//!    window and checkpoints it back after, so *which* worker runs a
//!    window never matters (the per-seed determinism of
//!    [`crate::runtime::NativeScnn`] makes backends interchangeable).
//!
//! Fairness is round-robin: a session that finishes a window re-enters
//! the ready queue at the back. Overload is handled by shedding newest
//! windows once the global or per-session queue bound is hit — sessions
//! degrade by skipping time rather than stalling the service.
//!
//! With the SLO autoscaler ([`AutoscaleConfig`]) enabled, a control
//! thread grows the active pool when the rolling p99 window latency
//! breaches the objective (or the queue runs deep) and shrinks it after
//! a hysteresis run of calm ticks; workers above the current target park
//! on the pool condvar and own no backend until first dispatched.

use std::collections::{BTreeSet, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure};

use crate::coordinator::engine::{BackendFactory, SampleBuffers, SamplePlan, WindowTotals};
use crate::coordinator::metrics::{LatencyStats, LatencyWindow, RunMetrics};
use crate::dataflow::Policy;
use crate::events::{DvsEvent, GestureClass, GestureGenerator};
use crate::runtime::{NativeScnn, StateSnapshot, StepBackend};
use crate::snn::events::AdjacencyCache;
use crate::snn::Network;
use crate::telemetry::{
    trace, Counter, FlightEvent, FlightRecorder, Gauge, Histogram, Registry, TelemetryConfig,
};
use crate::util::rng::Rng;
use crate::Result;

use super::ingest::{MicroWindow, ReorderBuffer};
use super::precision::{tiers_for, PrecisionConfig, TIER_LABELS};
use super::session::{
    encode_window_into, window_frames, EncodeScratch, QueuedWindow, SessionConfig,
    SessionManager, WindowOutcome,
};

/// Rolling-latency window feeding the autoscaler's p99 (recent windows
/// only — the whole-run [`LatencyStats`] would average a spike away).
const ROLLING_WINDOW: usize = 512;

/// SLO-driven worker-pool autoscaler configuration.
///
/// The control loop ticks every `interval`: when the rolling p99 window
/// latency exceeds the SLO — or the queue is deeper than `queue_high`
/// windows per active worker — the pool grows one worker (up to
/// `max_workers`); after `hysteresis_ticks` consecutive *calm* ticks
/// (p99 under half the SLO and a near-empty queue) it shrinks one
/// (down to `min_workers`). Threads are spawned up to `max_workers` up
/// front; workers above the current target park on the service condvar
/// and construct no backend until first dispatched, so an unused ceiling
/// costs one idle thread each, not one backend each.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleConfig {
    /// Master switch; when off the pool stays at `ServiceConfig::workers`.
    pub enabled: bool,
    /// Pool floor.
    pub min_workers: usize,
    /// Pool ceiling (threads spawned up front).
    pub max_workers: usize,
    /// Rolling-p99 latency objective in seconds.
    pub slo_p99_s: f64,
    /// Control-loop tick interval.
    pub interval: Duration,
    /// Queued windows per active worker considered overloaded even while
    /// the latency SLO still holds.
    pub queue_high: usize,
    /// Consecutive calm ticks required before one shrink step.
    pub hysteresis_ticks: u32,
}

impl AutoscaleConfig {
    /// The disabled configuration (fixed pool), with the same knob
    /// defaults as [`crate::deploy::AutoscaleSpec`].
    pub fn disabled() -> AutoscaleConfig {
        AutoscaleConfig {
            enabled: false,
            min_workers: 1,
            max_workers: 16,
            slo_p99_s: 0.020,
            interval: Duration::from_millis(10),
            queue_high: 8,
            hysteresis_ticks: 5,
        }
    }

    /// One control decision, pure for testability: given the current
    /// target pool size, the rolling p99 (NaN when no samples yet), the
    /// queued-window depth, and the consecutive-calm-tick count, return
    /// `(new_target, new_calm_ticks)`.
    ///
    /// Grow resets the calm streak; a NaN p99 (no recent samples) never
    /// triggers the latency condition on its own — an idle service must
    /// not flap on the absence of data.
    pub fn decide(
        &self,
        current: usize,
        p99_s: f64,
        queued: usize,
        calm_ticks: u32,
    ) -> (usize, u32) {
        let cur = current.max(1);
        let overloaded = p99_s > self.slo_p99_s || queued > self.queue_high * cur;
        if overloaded {
            if current < self.max_workers {
                return (current + 1, 0);
            }
            return (current, 0);
        }
        let calm = p99_s < 0.5 * self.slo_p99_s && queued * 2 <= self.queue_high * cur;
        if calm && current > self.min_workers {
            let streak = calm_ticks + 1;
            if streak >= self.hysteresis_ticks {
                return (current - 1, 0);
            }
            return (current, streak);
        }
        (current, 0)
    }
}

/// Service-level configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker threads (each constructs its own backend). With the
    /// autoscaler enabled this is the *starting* pool size.
    pub workers: usize,
    /// Global bound on admitted-but-unexecuted windows; admissions beyond
    /// it are shed.
    pub queue_capacity: usize,
    /// Per-session bound on queued windows.
    pub per_session_capacity: usize,
    /// Vmem residency budget in bits. `0` derives it from the plan's
    /// system config (CIM array + global buffer capacity).
    pub resident_budget_bits: u64,
    /// Serialize window dispatch — and therefore vmem residency admission
    /// and its spill/refill accounting — in global admission order, so
    /// residency and energy reports are bit-reproducible at any worker
    /// count. Window *execution* still overlaps across the pool; only the
    /// dispatch (and the LRU transitions it drives) is ordered, at some
    /// head-of-line throughput cost. Scoped to shed-free runs: shedding
    /// decisions depend on worker drain timing, so an overloaded queue
    /// reintroduces pool-size dependence.
    pub deterministic_admission: bool,
    /// Early-exit confidence bound: stop serving a session once the
    /// rolling classification's smoothed margin (top-1 − top-2 of the
    /// EMA'd window rates) reaches this value. Remaining windows are
    /// skipped and counted as saved. `0` disables.
    pub early_exit_margin: f64,
    /// Executed windows required before early exit may trigger (guards
    /// against deciding on a single noisy window).
    pub early_exit_min_windows: u64,
    /// SLO-driven worker-pool autoscaler (disabled by default).
    pub autoscale: AutoscaleConfig,
    /// Per-session serve-time precision controller (disabled by default;
    /// see [`crate::serve::precision`]).
    pub precision: PrecisionConfig,
    /// Service telemetry: metrics registry updates and flight-recorder
    /// events (disabled by default; see [`crate::telemetry`]).
    pub telemetry: TelemetryConfig,
    /// Session parameters (shared by all sessions).
    pub session: SessionConfig,
}

impl ServiceConfig {
    /// Nominal operating point: deep queues, budget derived from the
    /// modeled chip capacity, 48×48 gesture sessions, no early exit,
    /// fixed pool.
    pub fn nominal(workers: usize) -> ServiceConfig {
        ServiceConfig {
            workers,
            queue_capacity: 4096,
            per_session_capacity: 256,
            resident_budget_bits: 0,
            deterministic_admission: false,
            early_exit_margin: 0.0,
            early_exit_min_windows: 2,
            autoscale: AutoscaleConfig::disabled(),
            precision: PrecisionConfig::disabled(),
            telemetry: TelemetryConfig::disabled(),
            session: SessionConfig::default_48(),
        }
    }
}

/// One synthetic client stream for the traffic driver: events in arrival
/// order (not necessarily time order) plus the declared stream end.
#[derive(Debug, Clone)]
pub struct SessionTraffic {
    /// Session id to open.
    pub id: u64,
    /// Ground-truth label, when known.
    pub label: Option<usize>,
    /// Declared end of the stream (microseconds).
    pub end_us: u64,
    /// Events in arrival order.
    pub events: Vec<DvsEvent>,
}

/// Synthetic gesture traffic: `n` sessions cycling through the ten
/// classes, each a generated DVS gesture sample whose events are delivered
/// with up to `jitter_us` of arrival jitter (events stay roughly
/// time-ordered but locally reordered, as a real transport does). Keep
/// `jitter_us` at or below the session's reorder slack for zero-drop
/// delivery.
pub fn gesture_traffic(n: usize, seed: u64, jitter_us: u64) -> Vec<SessionTraffic> {
    let gen = GestureGenerator::default_48();
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let label = i % 10;
            let stream = gen.sample(GestureClass::from_label(label), &mut rng);
            let end_us = stream.duration_us;
            let mut keyed: Vec<(u64, DvsEvent)> = stream
                .events
                .iter()
                .map(|&e| (e.t_us + rng.below(jitter_us.max(1)), e))
                .collect();
            keyed.sort_by_key(|&(k, _)| k);
            SessionTraffic {
                id: i as u64,
                label: Some(label),
                end_us,
                events: keyed.into_iter().map(|(_, e)| e).collect(),
            }
        })
        .collect()
}

/// Shared mutable service state (behind one mutex; compute happens outside
/// it, only admission/commit bookkeeping inside).
struct ServiceState {
    sessions: SessionManager,
    /// Sessions with queued windows and no window in flight, FIFO.
    ready: VecDeque<u64>,
    /// Admitted, unexecuted windows (global, for the capacity bound).
    queued_windows: usize,
    /// Windows currently executing on workers.
    in_flight: usize,
    /// Windows dropped by admission control.
    shed: u64,
    /// Next global admission sequence number (dispatch order key).
    next_seq: u64,
    /// Seqs admitted but not yet dispatched. In deterministic-admission
    /// mode the only dispatchable window is the one holding the smallest
    /// outstanding seq; early-exit drops prune their seqs so the order
    /// never stalls on a skipped window.
    outstanding: BTreeSet<u64>,
    /// Active pool size: workers with `idx >= target_workers` park on the
    /// condvar and pick no work. Fixed at `cfg.workers` unless the
    /// autoscaler is driving it.
    target_workers: usize,
    /// Largest pool size the autoscaler reached.
    peak_workers: usize,
    /// Autoscaler grow steps taken.
    scale_ups: u64,
    /// Autoscaler shrink steps taken.
    scale_downs: u64,
    /// Recent window latencies feeding the autoscaler's rolling p99.
    recent_latency: LatencyWindow,
    /// Precision-controller tier moves applied (drops + raises).
    precision_shifts: u64,
    /// Windows committed per resolution tier (index = tier).
    tier_windows: Vec<u64>,
    shutdown: bool,
    first_error: Option<anyhow::Error>,
}

/// One unit of worker work, captured under the state lock.
struct Job {
    id: u64,
    window: MicroWindow,
    enqueued_at: Instant,
    state: StateSnapshot,
    /// The session's resolution tier at dispatch — the worker reconfigures
    /// its backend to this tier before running (consistent with `state`:
    /// both are read under the same lock, and at most one window per
    /// session is in flight).
    tier: usize,
}

/// Cached handles into the service's [`Registry`]: resolved once at
/// construction so the hot paths touch atomics/reservoirs, never the
/// registry map.
struct ServiceMetrics {
    admitted: Counter,
    shed: Counter,
    windows_done: Counter,
    queue_wait: Histogram,
    window_latency: Histogram,
    target_workers: Gauge,
    /// Precision-controller tier moves.
    precision_shifts: Counter,
    /// Windows committed per resolution tier (`resolution_tier` label).
    tier_windows: Vec<Counter>,
}

impl ServiceMetrics {
    fn register(registry: &Registry, tiers: usize) -> ServiceMetrics {
        let labels = &[("tier", "serve")];
        ServiceMetrics {
            admitted: registry.counter("flexspim_serve_admitted_total", labels),
            shed: registry.counter("flexspim_serve_shed_total", labels),
            windows_done: registry.counter("flexspim_serve_windows_done_total", labels),
            queue_wait: registry.histogram("flexspim_serve_queue_wait_seconds", labels),
            window_latency: registry.histogram("flexspim_serve_window_latency_seconds", labels),
            target_workers: registry.gauge("flexspim_serve_target_workers", labels),
            precision_shifts: registry
                .counter("flexspim_serve_precision_shifts_total", labels),
            tier_windows: TIER_LABELS[..tiers]
                .iter()
                .map(|&t| {
                    registry.counter(
                        "flexspim_serve_tier_windows_total",
                        &[("tier", "serve"), ("resolution_tier", t)],
                    )
                })
                .collect(),
        }
    }
}

/// The streaming inference service.
pub struct StreamingService {
    plan: Arc<SamplePlan>,
    factory: Arc<BackendFactory>,
    cfg: ServiceConfig,
    /// Resolution tier table for the precision controller: entry δ holds
    /// the per-layer `(w_bits, p_bits)` at down-scaling δ; entry 0 is the
    /// plan's deployed resolution (see [`tiers_for`]).
    tiers: Vec<Vec<(u32, u32)>>,
    state: Mutex<ServiceState>,
    signal: Condvar,
    registry: Arc<Registry>,
    tel: ServiceMetrics,
    recorder: Arc<FlightRecorder>,
}

impl StreamingService {
    /// Build a service over a shared plan and backend factory.
    pub fn new(
        plan: Arc<SamplePlan>,
        factory: Arc<BackendFactory>,
        mut cfg: ServiceConfig,
    ) -> StreamingService {
        if cfg.resident_budget_bits == 0 {
            cfg.resident_budget_bits =
                plan.energy.cfg.cim_bits() + plan.energy.cfg.gbuf_bits;
        }
        let sessions =
            SessionManager::new(cfg.session.clone(), &plan.net, cfg.resident_budget_bits);
        let start_workers = if cfg.autoscale.enabled {
            cfg.workers
                .clamp(cfg.autoscale.min_workers.max(1), cfg.autoscale.max_workers.max(1))
        } else {
            cfg.workers.max(1)
        };
        let tiers = tiers_for(&plan.net, cfg.precision.max_delta);
        let registry = Arc::new(Registry::default());
        let tel = ServiceMetrics::register(&registry, tiers.len());
        tel.target_workers.set(start_workers as i64);
        let recorder = Arc::new(FlightRecorder::new(cfg.telemetry.flight_capacity));
        let tier_windows = vec![0u64; tiers.len()];
        StreamingService {
            plan,
            factory,
            cfg,
            tiers,
            registry,
            tel,
            recorder,
            state: Mutex::new(ServiceState {
                sessions,
                ready: VecDeque::new(),
                queued_windows: 0,
                in_flight: 0,
                shed: 0,
                next_seq: 0,
                outstanding: BTreeSet::new(),
                target_workers: start_workers,
                peak_workers: start_workers,
                scale_ups: 0,
                scale_downs: 0,
                recent_latency: LatencyWindow::new(ROLLING_WINDOW),
                precision_shifts: 0,
                tier_windows,
                shutdown: false,
                first_error: None,
            }),
            signal: Condvar::new(),
        }
    }

    /// Convenience: a service over the pure-Rust [`NativeScnn`] backend,
    /// deterministic from `seed`. Thin shim over the same wiring
    /// [`crate::deploy::Deployment::service`] performs; all workers share
    /// one conv-adjacency cache.
    pub fn native(
        net: Network,
        seed: u64,
        num_macros: usize,
        policy: Policy,
        cfg: ServiceConfig,
    ) -> StreamingService {
        let plan = Arc::new(SamplePlan::new(net.clone(), num_macros, policy));
        let adj = Arc::new(AdjacencyCache::new());
        let factory: Arc<BackendFactory> = Arc::new(move || {
            Ok(Box::new(NativeScnn::with_adjacency_cache(net.clone(), seed, adj.clone()))
                as Box<dyn StepBackend>)
        });
        StreamingService::new(plan, factory, cfg)
    }

    /// The shared per-sample plan.
    pub fn plan(&self) -> &SamplePlan {
        &self.plan
    }

    /// The service configuration (with the residency budget resolved).
    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// This service's metrics registry. Populated only while
    /// `cfg.telemetry.enabled`; always exportable
    /// ([`Registry::prometheus_text`] / [`Registry::snapshot`]).
    pub fn metrics(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// This service's flight recorder (admissions, sheds, evictions,
    /// early exits, autoscaler decisions). Populated only while
    /// `cfg.telemetry.enabled`.
    pub fn recorder(&self) -> &Arc<FlightRecorder> {
        &self.recorder
    }

    /// Open a new session.
    pub fn open_session(&self, id: u64, label: Option<usize>) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        ensure!(!st.shutdown, "service is shut down");
        st.sessions.open(id, &self.plan.net, label)
    }

    /// Open a new session under a service-allocated id — recycled from a
    /// reaped/removed session when one is free, so long-running traffic
    /// reuses the id space instead of growing it without bound.
    pub fn open_session_auto(&self, label: Option<usize>) -> Result<u64> {
        let mut st = self.state.lock().unwrap();
        ensure!(!st.shutdown, "service is shut down");
        let id = st.sessions.allocate_id();
        st.sessions.open(id, &self.plan.net, label)?;
        Ok(id)
    }

    /// Run the idle-session reaper: close every session with no queued or
    /// running window that is finished or idle for at least `max_idle`,
    /// releasing its residency share and recycling its id. Returns the
    /// reaped ids (their results are gone afterwards — read them first).
    pub fn reap_idle(&self, max_idle: Duration) -> Vec<u64> {
        self.state.lock().unwrap().sessions.reap_idle(max_idle)
    }

    /// Deliver a batch of events for a session. Out-of-bounds events are a
    /// recoverable error; late/overflow events are dropped and counted by
    /// the session's jitter buffer. Completed windows are admitted to the
    /// run queue (or shed under overload).
    pub fn ingest(&self, id: u64, events: &[DvsEvent]) -> Result<()> {
        let _span = trace::span("serve.ingest");
        let mut st = self.state.lock().unwrap();
        ensure!(!st.shutdown, "service is shut down");
        let st_ref = &mut *st;
        let windows = {
            let s = st_ref
                .sessions
                .get_mut(id)
                .ok_or_else(|| anyhow!("unknown session {id}"))?;
            ensure!(!s.closed, "session {id} is closed");
            s.last_activity = Instant::now();
            for &e in events {
                let _ = s.ingest.push(e)?;
            }
            s.ingest.poll()
        };
        self.admit_windows(st_ref, id, windows);
        drop(st);
        self.signal.notify_all();
        Ok(())
    }

    /// Close a session's stream at `end_us`: flush the jitter buffer and
    /// admit the remaining windows (the final one marked `last`).
    pub fn close_session(&self, id: u64, end_us: u64) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        ensure!(!st.shutdown, "service is shut down");
        let st_ref = &mut *st;
        let windows = {
            let s = st_ref
                .sessions
                .get_mut(id)
                .ok_or_else(|| anyhow!("unknown session {id}"))?;
            ensure!(!s.closed, "session {id} already closed");
            // Validate the declared end before committing the close: a
            // rejected end leaves the session open for a corrected retry.
            let windows = s.ingest.flush(end_us)?;
            s.closed = true;
            s.last_activity = Instant::now();
            windows
        };
        self.admit_windows(st_ref, id, windows);
        drop(st);
        self.signal.notify_all();
        Ok(())
    }

    /// Admission control: bound the global and per-session queues,
    /// shedding the newest windows on overflow (degrade by skipping time,
    /// never by stalling).
    fn admit_windows(&self, st: &mut ServiceState, id: u64, windows: Vec<MicroWindow>) {
        let cfg = &self.cfg;
        let tel = cfg.telemetry.enabled;
        for w in windows {
            let over_global = st.queued_windows >= cfg.queue_capacity;
            let s = match st.sessions.get_mut(id) {
                Some(s) => s,
                None => return,
            };
            if s.early_exited {
                // The rolling classification already cleared the
                // confidence bound: skip the window outright (saved, not
                // shed — the decision stands without it). The window still
                // consumes an admission seq: whether a post-exit window is
                // skipped here or queued-then-dropped at the exit commit
                // is a wall-clock race, and burning the seq either way
                // keeps the global dispatch order — and with it the
                // deterministic-admission residency accounting —
                // independent of that race.
                st.next_seq += 1;
                s.windows_saved += 1;
                s.frames_saved += window_frames(&cfg.session, &w) as u64;
                if w.last {
                    s.finished = true;
                }
                continue;
            }
            if over_global || s.queue.len() >= cfg.per_session_capacity {
                s.windows_shed += 1;
                st.shed += 1;
                if w.last {
                    // A shed final window still finishes the session.
                    s.finished = true;
                }
                if tel {
                    self.tel.shed.inc();
                    self.recorder.record(FlightEvent::Shed { session: id });
                }
                continue;
            }
            let was_idle = s.queue.is_empty() && !s.running;
            let seq = st.next_seq;
            st.next_seq += 1;
            s.queue.push_back(QueuedWindow { window: w, enqueued_at: Instant::now(), seq });
            st.outstanding.insert(seq);
            st.queued_windows += 1;
            if was_idle {
                st.ready.push_back(id);
            }
            if tel {
                self.tel.admitted.inc();
                self.recorder.record(FlightEvent::Admit { session: id, seq });
            }
        }
    }

    /// Worker body: steal the next ready session's window, run it on this
    /// worker's backend with the session's restored state, commit.
    ///
    /// `idx` is this worker's position in the spawned pool: workers with
    /// `idx >= target_workers` park on the condvar (the autoscaler moves
    /// the target; commits and scale steps wake them). The backend is
    /// constructed lazily at first dispatch so a parked worker above the
    /// target never pays for one.
    fn worker_loop(&self, idx: usize) {
        let make: &BackendFactory = self.factory.as_ref();
        let mut backend: Option<Box<dyn StepBackend>> = None;
        // Which resolution tier this worker's backend currently holds
        // (freshly constructed backends come out at tier 0, the plan's
        // deployed resolution).
        let mut backend_tier = 0usize;
        let mut bufs = SampleBuffers::default();
        // Per-worker encoder scratch: windows re-encode into these
        // buffers instead of allocating fresh frames every micro-window.
        let mut encode_scratch = EncodeScratch::default();
        loop {
            let job = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if st.shutdown {
                        return;
                    }
                    if idx >= st.target_workers {
                        st = self.signal.wait(st).unwrap();
                        continue;
                    }
                    // Dispatch policy: FIFO over ready sessions, or — in
                    // deterministic-admission mode — strictly the window
                    // holding the smallest outstanding admission seq, so
                    // residency transitions replay identically at any
                    // worker count. If that window's session is still
                    // running its previous window, everyone waits (its
                    // commit wakes us).
                    let picked = if self.cfg.deterministic_admission {
                        let next = st.outstanding.iter().next().copied();
                        let mut found = None;
                        if let Some(next) = next {
                            let st_ref = &mut *st;
                            let pos = st_ref.ready.iter().position(|&rid| {
                                st_ref
                                    .sessions
                                    .get(rid)
                                    .and_then(|s| s.queue.front())
                                    .is_some_and(|qw| qw.seq == next)
                            });
                            if let Some(pos) = pos {
                                found = st_ref.ready.remove(pos);
                            }
                        }
                        found
                    } else {
                        st.ready.pop_front()
                    };
                    if let Some(id) = picked {
                        let st_ref = &mut *st;
                        let (window, enqueued_at, seq, state, tier) = {
                            let s = st_ref
                                .sessions
                                .get_mut(id)
                                .expect("ready session exists");
                            let qw = s.queue.pop_front().expect("ready implies queued");
                            s.running = true;
                            (qw.window, qw.enqueued_at, qw.seq, s.state.clone(), s.tier)
                        };
                        st_ref.outstanding.remove(&seq);
                        st_ref.queued_windows -= 1;
                        st_ref.in_flight += 1;
                        // Residency: admitting this window makes the
                        // session's vmem resident (possibly spilling LRU
                        // peers) — accounted in the SessionManager and
                        // priced at report time.
                        let charge = st_ref.sessions.admit(id);
                        if self.cfg.telemetry.enabled && charge.evictions > 0 {
                            self.recorder.record(FlightEvent::Evict {
                                session: id,
                                evictions: charge.evictions,
                                spill_bits: charge.spill_bits,
                            });
                        }
                        break Job { id, window, enqueued_at, state, tier };
                    }
                    st = self.signal.wait(st).unwrap();
                }
            };
            if self.cfg.telemetry.enabled {
                self.tel.queue_wait.observe(job.enqueued_at.elapsed().as_secs_f64());
            }
            if self.cfg.deterministic_admission {
                // Taking the smallest seq may have unblocked a sibling on
                // the next one.
                self.signal.notify_all();
            }

            if backend.is_none() {
                match make() {
                    Ok(b) => {
                        backend = Some(b);
                        backend_tier = 0;
                    }
                    Err(e) => {
                        if self.cfg.telemetry.enabled {
                            self.recorder
                                .record(FlightEvent::Error { message: format!("{e:#}") });
                            crate::log_error!(
                                "serve worker {idx}: backend construction failed: {e:#}\n{}",
                                self.recorder.dump()
                            );
                        }
                        // The job is already accounted in-flight: undo that
                        // under the same lock that records the error, so
                        // drain() never sees in_flight == 0 with it unset.
                        let mut st = self.state.lock().unwrap();
                        st.in_flight -= 1;
                        if st.first_error.is_none() {
                            st.first_error = Some(e);
                        }
                        st.shutdown = true;
                        drop(st);
                        self.signal.notify_all();
                        return;
                    }
                }
            }
            let t0 = Instant::now();
            let outcome = {
                let b = backend.as_mut().expect("constructed above").as_mut();
                if job.tier != backend_tier {
                    // Reconfigure this worker's backend to the session's
                    // tier. Cheap: conv adjacencies come out of the shared
                    // AdjacencyCache, and run_window restores the session's
                    // (already rescaled) checkpoint right after — so the
                    // PJRT runner's reset-on-reconfigure divergence is
                    // harmless here.
                    let _s = trace::span("serve.set_resolutions");
                    b.set_resolutions(&self.tiers[job.tier]);
                    backend_tier = job.tier;
                }
                self.run_window(b, &mut bufs, &mut encode_scratch, &job)
            };
            let wall_s = t0.elapsed().as_secs_f64();

            match outcome {
                Ok((window_rate, new_state, totals)) => {
                    let mut st = self.state.lock().unwrap();
                    let st_ref = &mut *st;
                    let latency_s = job.enqueued_at.elapsed().as_secs_f64();
                    st_ref.recent_latency.push(latency_s);
                    st_ref.tier_windows[job.tier] += 1;
                    if self.cfg.telemetry.enabled {
                        self.tel.windows_done.inc();
                        self.tel.window_latency.observe(latency_s);
                        self.tel.tier_windows[job.tier].inc();
                    }
                    // Precision-controller load inputs, read before the
                    // session borrow — the same rolling-p99/queue-depth
                    // signals the autoscaler consumes.
                    let p99_s = st_ref.recent_latency.pct(99.0);
                    let queued = st_ref.queued_windows;
                    let active = st_ref.target_workers;
                    let mut dropped_seqs = Vec::new();
                    let mut tier_shift = None;
                    let requeue = {
                        let s = st_ref
                            .sessions
                            .get_mut(job.id)
                            .expect("session exists while running");
                        s.commit_window(
                            self.cfg.session.smoothing,
                            WindowOutcome {
                                rate: window_rate,
                                state: new_state,
                                totals,
                                latency_s,
                                wallclock_s: wall_s,
                                last: job.window.last,
                            },
                        );
                        s.running = false;
                        // Early exit: once the rolling classification's
                        // smoothed margin clears the configured bound, the
                        // decision is made — skip the session's remaining
                        // windows (queued now or arriving later) instead of
                        // spending frames on them.
                        if self.cfg.early_exit_margin > 0.0
                            && !s.early_exited
                            && !s.finished
                            && s.windows_done >= self.cfg.early_exit_min_windows
                            && s.smoothed_margin() >= self.cfg.early_exit_margin
                        {
                            s.early_exited = true;
                            if self.cfg.telemetry.enabled {
                                self.recorder.record(FlightEvent::EarlyExit {
                                    session: job.id,
                                    margin: s.smoothed_margin(),
                                });
                            }
                        }
                        if s.early_exited {
                            while let Some(qw) = s.queue.pop_front() {
                                dropped_seqs.push(qw.seq);
                                s.windows_saved += 1;
                                s.frames_saved +=
                                    window_frames(&self.cfg.session, &qw.window) as u64;
                                if qw.window.last {
                                    s.finished = true;
                                }
                            }
                        }
                        // Precision controller: one pure decision per
                        // committed window. A tier move realigns the
                        // session's membrane checkpoint into the new
                        // accumulator range here; the next dispatch carries
                        // the tier to a worker, which reconfigures its
                        // backend before running.
                        if self.cfg.precision.enabled && !s.finished && !s.early_exited {
                            let margin = s.smoothed_margin();
                            let next = self.cfg.precision.decide(
                                s.tier,
                                p99_s,
                                queued,
                                active,
                                margin,
                                s.windows_done,
                            );
                            if next != s.tier {
                                s.state = s
                                    .state
                                    .rescaled(&self.tiers[s.tier], &self.tiers[next]);
                                tier_shift = Some((s.tier, next, margin));
                                s.tier = next;
                            }
                        }
                        !s.queue.is_empty()
                    };
                    if let Some((from, to, margin)) = tier_shift {
                        st_ref.precision_shifts += 1;
                        if self.cfg.telemetry.enabled {
                            self.tel.precision_shifts.inc();
                            self.recorder.record(FlightEvent::PrecisionDecision {
                                session: job.id,
                                from,
                                to,
                                p99_ms: p99_s * 1e3,
                                queued,
                                margin,
                            });
                        }
                    }
                    for seq in &dropped_seqs {
                        st_ref.outstanding.remove(seq);
                    }
                    st_ref.queued_windows -= dropped_seqs.len();
                    if requeue {
                        st_ref.ready.push_back(job.id);
                    }
                    st_ref.in_flight -= 1;
                    drop(st);
                    self.signal.notify_all();
                }
                Err(e) => {
                    if self.cfg.telemetry.enabled {
                        self.recorder
                            .record(FlightEvent::Error { message: format!("{e:#}") });
                        crate::log_error!(
                            "serve worker {idx}: window failed: {e:#}\n{}",
                            self.recorder.dump()
                        );
                    }
                    // One lock for decrement + error record: drain() must
                    // never observe in_flight == 0 with the error unset.
                    let mut st = self.state.lock().unwrap();
                    st.in_flight -= 1;
                    if st.first_error.is_none() {
                        st.first_error = Some(e);
                    }
                    st.shutdown = true;
                    drop(st);
                    self.signal.notify_all();
                    return;
                }
            }
        }
    }

    /// Execute one window on a worker's backend (no locks held): restore
    /// the session checkpoint, run the encoded frames, checkpoint back.
    fn run_window(
        &self,
        backend: &mut dyn StepBackend,
        bufs: &mut SampleBuffers,
        scratch: &mut EncodeScratch,
        job: &Job,
    ) -> Result<(Vec<i64>, StateSnapshot, WindowTotals)> {
        let _span = trace::span("serve.window");
        let frames = encode_window_into(&self.cfg.session, &job.window, scratch);
        {
            let _s = trace::span("serve.restore");
            backend.restore(&job.state)?;
        }
        let mut window_rate = vec![0i64; 10];
        let totals = self.plan.run_frames(backend, bufs, frames, &mut window_rate)?;
        let snapshot = {
            let _s = trace::span("serve.snapshot");
            backend.snapshot()
        };
        Ok((window_rate, snapshot, totals))
    }

    /// Block until every admitted window has executed (or a worker
    /// failed). Errors surface here.
    pub fn drain(&self) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.first_error.is_some() {
                return Err(st.first_error.take().expect("just checked"));
            }
            if st.shutdown || (st.queued_windows == 0 && st.in_flight == 0) {
                return Ok(());
            }
            st = self.signal.wait(st).unwrap();
        }
    }

    /// Release the worker pool (idempotent).
    pub fn stop(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        drop(st);
        self.signal.notify_all();
    }

    /// One autoscaler decision applied to the live state; returns the
    /// updated calm-tick streak. Split from the paced control loop so the
    /// decision path is testable without wall-clock timing.
    fn autoscale_tick(&self, calm_ticks: u32) -> u32 {
        let a = &self.cfg.autoscale;
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return calm_ticks;
        }
        let p99 = st.recent_latency.pct(99.0);
        let current = st.target_workers;
        let (target, calm) = a.decide(current, p99, st.queued_windows, calm_ticks);
        if self.cfg.telemetry.enabled {
            self.recorder.record(FlightEvent::AutoscaleDecision {
                current,
                p99_ms: p99 * 1e3,
                queued: st.queued_windows,
                calm_ticks,
                target,
            });
        }
        if target != current {
            st.target_workers = target;
            if target > current {
                st.scale_ups += 1;
                st.peak_workers = st.peak_workers.max(target);
            } else {
                st.scale_downs += 1;
            }
            if self.cfg.telemetry.enabled {
                self.tel.target_workers.set(target as i64);
                self.recorder.record(if target > current {
                    FlightEvent::ScaleUp { from: current, to: target }
                } else {
                    FlightEvent::ScaleDown { from: current, to: target }
                });
            }
            drop(st);
            // Grown: parked workers above the old target are waiting on
            // the condvar. Shrunk: nothing to wake — workers above the
            // new target park themselves at their next pick attempt.
            self.signal.notify_all();
        }
        calm
    }

    /// The autoscaler control loop: decide every `interval`, exit
    /// promptly on shutdown (paced by `wait_timeout`, so `stop()` never
    /// waits out a long tick).
    fn autoscale_loop(&self) {
        let mut calm_ticks = 0u32;
        loop {
            calm_ticks = self.autoscale_tick(calm_ticks);
            let deadline = Instant::now() + self.cfg.autoscale.interval;
            let mut st = self.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (g, _timeout) = self.signal.wait_timeout(st, deadline - now).unwrap();
                st = g;
            }
        }
    }

    /// Spawn the worker pool (plus the autoscaler thread when enabled),
    /// run `driver` against the live service, then stop the pool. This is
    /// how every traffic driver — [`Self::serve`]'s synchronous one, the
    /// open-loop generator in [`crate::serve::load`] — borrows the pool:
    /// the driver ingests/closes sessions and typically ends with
    /// [`Self::drain`]. A worker failure surfacing indirectly through the
    /// driver (e.g. as "service is shut down") is replaced by the root
    /// cause.
    pub fn run_with<T>(
        &self,
        driver: impl FnOnce(&StreamingService) -> Result<T>,
    ) -> Result<T> {
        let n_threads = if self.cfg.autoscale.enabled {
            self.cfg.autoscale.max_workers.max(1)
        } else {
            self.cfg.workers.max(1)
        };
        std::thread::scope(|scope| -> Result<T> {
            for idx in 0..n_threads {
                scope.spawn(move || self.worker_loop(idx));
            }
            if self.cfg.autoscale.enabled {
                scope.spawn(|| self.autoscale_loop());
            }
            let outcome = driver(self);
            self.stop();
            match outcome {
                Err(e) => {
                    let mut st = self.state.lock().unwrap();
                    Err(st.first_error.take().unwrap_or(e))
                }
                ok => ok,
            }
        })
    }

    /// The synthetic-traffic ingest driver: open all sessions, interleave
    /// event delivery `chunk` events at a time round-robin across sessions
    /// (simulating concurrent streams), close every session, drain.
    fn drive(&self, traffic: &[SessionTraffic], chunk: usize) -> Result<()> {
        for t in traffic {
            self.open_session(t.id, t.label)?;
        }
        let mut offsets = vec![0usize; traffic.len()];
        let mut live = true;
        while live {
            live = false;
            for (i, t) in traffic.iter().enumerate() {
                if offsets[i] >= t.events.len() {
                    continue;
                }
                let hi = (offsets[i] + chunk).min(t.events.len());
                self.ingest(t.id, &t.events[offsets[i]..hi])?;
                offsets[i] = hi;
                if hi < t.events.len() {
                    live = true;
                }
            }
        }
        for t in traffic {
            self.close_session(t.id, t.end_us)?;
        }
        self.drain()
    }

    /// Drive a full synthetic-traffic run: spawn the worker pool, run the
    /// ingest driver, and report.
    pub fn serve(&self, traffic: &[SessionTraffic], chunk: usize) -> Result<ServeReport> {
        let chunk = chunk.max(1);
        let t0 = Instant::now();
        self.run_with(|svc| svc.drive(traffic, chunk))?;
        Ok(self.report(t0.elapsed().as_secs_f64()))
    }

    /// Copy out one session's results (for equivalence tests and
    /// clients polling a rolling classification).
    pub fn session_result(&self, id: u64) -> Option<SessionResult> {
        let st = self.state.lock().unwrap();
        st.sessions.get(id).map(|s| SessionResult {
            id: s.id,
            label: s.label,
            rate: s.rate.clone(),
            prediction: s.prediction(),
            rolling_prediction: s.rolling_prediction(),
            state: s.state.clone(),
            windows_done: s.windows_done,
            windows_shed: s.windows_shed,
            early_exited: s.early_exited,
            windows_saved: s.windows_saved,
            frames_saved: s.frames_saved,
            tier: s.tier,
            finished: s.finished,
            metrics: s.metrics(),
        })
    }

    /// Open sessions on this node right now (the fleet router's capacity
    /// and rebalance signal).
    pub fn session_count(&self) -> usize {
        self.state.lock().unwrap().sessions.len()
    }

    /// All open session ids, ascending.
    pub fn session_ids(&self) -> Vec<u64> {
        self.state.lock().unwrap().sessions.ids()
    }

    /// Pack a live session for migration to another node: remove it from
    /// this service and return its portable state. Returns `Ok(None)`
    /// while a window of the session is executing — its checkpoint is in
    /// a worker's hands, so the caller retries after the commit (the
    /// fleet rebalancer treats in-flight sessions as momentarily
    /// unmovable). The session's residency share is released *without* a
    /// DRAM spill: the state leaves over the inter-node link instead, and
    /// the fleet ledger prices that move.
    ///
    /// Queued-but-unexecuted windows travel inside the export and are
    /// re-admitted by [`Self::import_session`] under the target's own
    /// admission control; their seqs leave this node's dispatch order
    /// here so deterministic admission never stalls on a departed
    /// session.
    pub fn try_export_session(&self, id: u64) -> Result<Option<SessionExport>> {
        let mut st = self.state.lock().unwrap();
        ensure!(!st.shutdown, "service is shut down");
        let st_ref = &mut *st;
        let seqs: Vec<u64> = {
            let s = st_ref
                .sessions
                .get(id)
                .ok_or_else(|| anyhow!("unknown session {id}"))?;
            if s.running {
                return Ok(None);
            }
            s.queue.iter().map(|qw| qw.seq).collect()
        };
        // Un-admit the queued windows: their seqs leave the dispatch
        // order and their slots return to the global queue bound. The
        // session leaves the ready ring with them.
        if let Some(pos) = st_ref.ready.iter().position(|&x| x == id) {
            let _ = st_ref.ready.remove(pos);
        }
        for seq in &seqs {
            st_ref.outstanding.remove(seq);
        }
        st_ref.queued_windows -= seqs.len();
        let s = st_ref.sessions.remove(id).expect("looked up above");
        drop(st);
        // A sibling worker may have been waiting on one of the departed
        // seqs in deterministic-admission mode.
        self.signal.notify_all();
        Ok(Some(SessionExport {
            id: s.id,
            label: s.label,
            ingest: s.ingest,
            state: s.state,
            queued: s.queue.into_iter().map(|qw| qw.window).collect(),
            rate: s.rate,
            smoothed: s.smoothed,
            windows_done: s.windows_done,
            windows_shed: s.windows_shed,
            totals: s.totals,
            latency: s.latency,
            wallclock_s: s.wallclock_s,
            closed: s.closed,
            finished: s.finished,
            early_exited: s.early_exited,
            windows_saved: s.windows_saved,
            frames_saved: s.frames_saved,
            tier: s.tier,
        }))
    }

    /// Install a migrated session on this node (the receive side of a
    /// fleet move): open its id, restore the packed state, and re-admit
    /// the in-transit windows under this node's own admission control
    /// (fresh seqs; an overloaded target sheds them exactly like local
    /// arrivals). Errors if the id is already in use here or the packed
    /// tier does not fit this service's tier table.
    pub fn import_session(&self, export: SessionExport) -> Result<()> {
        ensure!(
            export.tier < self.tiers.len(),
            "imported session tier {} outside this service's {}-tier table",
            export.tier,
            self.tiers.len()
        );
        let mut st = self.state.lock().unwrap();
        ensure!(!st.shutdown, "service is shut down");
        let st_ref = &mut *st;
        st_ref.sessions.open(export.id, &self.plan.net, export.label)?;
        {
            let s = st_ref.sessions.get_mut(export.id).expect("just opened");
            s.ingest = export.ingest;
            s.state = export.state;
            s.rate = export.rate;
            s.smoothed = export.smoothed;
            s.windows_done = export.windows_done;
            s.windows_shed = export.windows_shed;
            s.totals = export.totals;
            s.latency = export.latency;
            s.wallclock_s = export.wallclock_s;
            s.closed = export.closed;
            s.finished = export.finished;
            s.early_exited = export.early_exited;
            s.windows_saved = export.windows_saved;
            s.frames_saved = export.frames_saved;
            s.tier = export.tier;
            s.last_activity = Instant::now();
        }
        self.admit_windows(st_ref, export.id, export.queued);
        drop(st);
        self.signal.notify_all();
        Ok(())
    }

    /// Administratively move a session to resolution tier `tier`,
    /// rescaling its membrane checkpoint across the switch exactly as
    /// the precision controller does (the next dispatch reconfigures a
    /// worker backend to match). The fleet's bit-identity pins use this
    /// to replay identical tier trajectories on different nodes. Errors
    /// on an unknown session, an out-of-range tier, or a session with a
    /// window in flight.
    pub fn set_session_tier(&self, id: u64, tier: usize) -> Result<()> {
        ensure!(
            tier < self.tiers.len(),
            "tier {tier} outside this service's {}-tier table",
            self.tiers.len()
        );
        let mut st = self.state.lock().unwrap();
        ensure!(!st.shutdown, "service is shut down");
        let shifted = {
            let s = st
                .sessions
                .get_mut(id)
                .ok_or_else(|| anyhow!("unknown session {id}"))?;
            ensure!(!s.running, "session {id} has a window in flight");
            if s.tier == tier {
                false
            } else {
                s.state = s.state.rescaled(&self.tiers[s.tier], &self.tiers[tier]);
                s.tier = tier;
                true
            }
        };
        if shifted {
            st.precision_shifts += 1;
            if self.cfg.telemetry.enabled {
                self.tel.precision_shifts.inc();
            }
        }
        Ok(())
    }

    /// Assemble the service-wide report: per-session metrics merged in id
    /// order plus service-level residency traffic priced at the DRAM
    /// energy of the plan's system model.
    pub fn report(&self, wallclock_s: f64) -> ServeReport {
        let st = self.state.lock().unwrap();
        let mut metrics = RunMetrics::default();
        let mut latency = LatencyStats::new();
        let mut windows_done = 0u64;
        let mut events_late = 0u64;
        let mut events_overflow = 0u64;
        let mut events_flush_discarded = 0u64;
        let mut finished = 0u64;
        let mut rolling_correct = 0u64;
        let mut early_exits = 0u64;
        let mut windows_saved = 0u64;
        let mut frames_saved = 0u64;
        for id in st.sessions.ids() {
            let s = st.sessions.get(id).expect("listed id exists");
            metrics.merge(&s.metrics());
            latency.merge(&s.latency);
            windows_done += s.windows_done;
            events_late += s.ingest.late_dropped;
            events_overflow += s.ingest.overflow_dropped;
            events_flush_discarded += s.ingest.flush_discarded;
            if s.finished {
                finished += 1;
            }
            if s.early_exited {
                early_exits += 1;
            }
            windows_saved += s.windows_saved;
            frames_saved += s.frames_saved;
            if let Some(l) = s.label {
                rolling_correct += (s.rolling_prediction() == l) as u64;
            }
        }
        let dram_bits = st.sessions.spill_bits + st.sessions.fill_bits;
        metrics.state_spill_bits = dram_bits;
        metrics.state_evictions = st.sessions.evictions;
        metrics.energy.movement_pj += dram_bits as f64 * self.plan.energy.cfg.e_dram_pj_bit;
        ServeReport {
            workers: self.cfg.workers,
            workers_peak: st.peak_workers,
            scale_ups: st.scale_ups,
            scale_downs: st.scale_downs,
            sessions: st.sessions.len() as u64,
            finished_sessions: finished,
            windows_done,
            windows_shed: st.shed,
            events_dropped: events_late + events_overflow + events_flush_discarded,
            events_late,
            events_overflow,
            events_flush_discarded,
            rolling_correct,
            early_exits,
            windows_saved,
            frames_saved,
            evictions: st.sessions.evictions,
            state_dram_bits: dram_bits,
            precision_shifts: st.precision_shifts,
            tier_windows: st.tier_windows.clone(),
            latency,
            metrics,
            wallclock_s,
        }
    }
}

/// Snapshot of one session's serving results.
#[derive(Debug, Clone)]
pub struct SessionResult {
    /// Session id.
    pub id: u64,
    /// Ground-truth label, when known.
    pub label: Option<usize>,
    /// Accumulated classifier spike counts.
    pub rate: Vec<i64>,
    /// Final prediction (argmax of the accumulated rate).
    pub prediction: usize,
    /// Rolling prediction (argmax of the label-smoothed window rates).
    pub rolling_prediction: usize,
    /// Checkpointed membrane state after the last executed window.
    pub state: StateSnapshot,
    /// Windows executed.
    pub windows_done: u64,
    /// Windows shed.
    pub windows_shed: u64,
    /// The rolling classification cleared the early-exit bound.
    pub early_exited: bool,
    /// Windows skipped after early exit.
    pub windows_saved: u64,
    /// Spike frames those skipped windows would have executed.
    pub frames_saved: u64,
    /// Resolution tier the session ended at (0 = deployed precision).
    pub tier: usize,
    /// The final window has executed (or was shed/skipped after close).
    pub finished: bool,
    /// This session's model metrics.
    pub metrics: RunMetrics,
}

/// A live session packed for migration to another node: everything a
/// freshly built replica needs to continue the stream bit-identically.
/// Produced by [`StreamingService::try_export_session`], consumed by
/// [`StreamingService::import_session`]; the fleet ledger prices
/// [`Self::state_bits`] as unicast inter-node traffic.
#[derive(Debug, Clone)]
pub struct SessionExport {
    /// Session id (preserved across the move).
    pub id: u64,
    /// Ground-truth label, when known.
    pub label: Option<usize>,
    /// The reorder/jitter buffer, drop counters included.
    pub ingest: ReorderBuffer,
    /// Membrane checkpoint at `tier`'s resolution — the payload a
    /// migration actually moves over the wire.
    pub state: StateSnapshot,
    /// Admitted-but-unexecuted windows, in admission order.
    pub queued: Vec<MicroWindow>,
    /// Accumulated classifier spike counts.
    pub rate: Vec<i64>,
    /// Smoothed per-class window rates.
    pub smoothed: Vec<f64>,
    /// Windows executed so far.
    pub windows_done: u64,
    /// Windows shed so far.
    pub windows_shed: u64,
    /// Accumulated model totals.
    pub totals: WindowTotals,
    /// Per-window latency record.
    pub latency: LatencyStats,
    /// Summed host wall-clock of executed windows.
    pub wallclock_s: f64,
    /// The client closed the stream.
    pub closed: bool,
    /// The final window has executed.
    pub finished: bool,
    /// The rolling classification cleared the early-exit bound.
    pub early_exited: bool,
    /// Windows skipped after early exit.
    pub windows_saved: u64,
    /// Frames those skipped windows would have executed.
    pub frames_saved: u64,
    /// Resolution tier the checkpoint is aligned to.
    pub tier: usize,
}

impl SessionExport {
    /// Bits a migration moves over the wire for this session's vmem
    /// checkpoint under per-layer `(w_bits, p_bits)` resolutions `res` —
    /// each layer's neurons at its membrane width, the fleet analogue of
    /// the serve tier's DRAM-spill pricing.
    pub fn state_bits(&self, res: &[(u32, u32)]) -> u64 {
        self.state
            .vmems
            .iter()
            .zip(res)
            .map(|(v, &(_, p_bits))| v.len() as u64 * p_bits as u64)
            .sum()
    }
}

/// Result of a traffic run through [`StreamingService::serve`].
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Worker threads configured (the starting pool under autoscaling).
    pub workers: usize,
    /// Largest pool size reached (equals `workers` without autoscaling).
    pub workers_peak: usize,
    /// Autoscaler grow steps taken.
    pub scale_ups: u64,
    /// Autoscaler shrink steps taken.
    pub scale_downs: u64,
    /// Sessions opened.
    pub sessions: u64,
    /// Sessions whose final window executed (or was shed after close).
    pub finished_sessions: u64,
    /// Windows executed.
    pub windows_done: u64,
    /// Windows shed by admission control.
    pub windows_shed: u64,
    /// Events dropped at ingest (late + overflow + end-of-stream
    /// discards; the split lives in the three fields below).
    pub events_dropped: u64,
    /// Events dropped because their window had already been emitted.
    pub events_late: u64,
    /// Events dropped because a session's jitter buffer was full.
    pub events_overflow: u64,
    /// Events discarded at stream close (timestamped past the declared
    /// end — truncation, not lateness).
    pub events_flush_discarded: u64,
    /// Sessions whose *rolling* (label-smoothed) prediction was correct.
    pub rolling_correct: u64,
    /// Sessions that stopped early on the confidence bound.
    pub early_exits: u64,
    /// Windows skipped by early exit across all sessions.
    pub windows_saved: u64,
    /// Spike frames those skipped windows would have executed.
    pub frames_saved: u64,
    /// Session-state evictions under the residency budget.
    pub evictions: u64,
    /// Session-state DRAM traffic (spill + refill), bits.
    pub state_dram_bits: u64,
    /// Precision-controller tier moves applied (drops + raises).
    pub precision_shifts: u64,
    /// Windows executed per resolution tier (index = tier, 0 = deployed
    /// precision; all windows land in tier 0 when the controller is off).
    pub tier_windows: Vec<u64>,
    /// Per-window admission→completion latency.
    pub latency: LatencyStats,
    /// Merged model metrics (per-session, id order, plus spill pricing).
    pub metrics: RunMetrics,
    /// End-to-end host wall-clock of the run (seconds).
    pub wallclock_s: f64,
}

impl ServeReport {
    /// Completed sessions per second of host wall-clock.
    pub fn sessions_per_sec(&self) -> f64 {
        if self.wallclock_s <= 0.0 {
            return 0.0;
        }
        self.finished_sessions as f64 / self.wallclock_s
    }

    /// Executed windows per second of host wall-clock.
    pub fn windows_per_sec(&self) -> f64 {
        if self.wallclock_s <= 0.0 {
            return 0.0;
        }
        self.windows_done as f64 / self.wallclock_s
    }

    /// Fraction of admitted-or-shed windows that were shed.
    pub fn shed_rate(&self) -> f64 {
        let total = self.windows_done + self.windows_shed;
        if total == 0 {
            return 0.0;
        }
        self.windows_shed as f64 / total as f64
    }

    /// Render a report block.
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "sessions           {} opened, {} finished ({:.1} sessions/s)\n",
            self.sessions,
            self.finished_sessions,
            self.sessions_per_sec(),
        ));
        out.push_str(&format!(
            "windows            {} done, {} shed ({:.2} % shed rate), {:.1} windows/s\n",
            self.windows_done,
            self.windows_shed,
            100.0 * self.shed_rate(),
            self.windows_per_sec(),
        ));
        if self.early_exits > 0 {
            out.push_str(&format!(
                "early exits        {} sessions, {} windows / {} frames saved\n",
                self.early_exits, self.windows_saved, self.frames_saved,
            ));
        }
        if self.scale_ups + self.scale_downs > 0 {
            out.push_str(&format!(
                "autoscaler         {} -> peak {} workers ({} ups, {} downs)\n",
                self.workers, self.workers_peak, self.scale_ups, self.scale_downs,
            ));
        }
        if self.precision_shifts > 0 {
            let tiers: Vec<String> = self
                .tier_windows
                .iter()
                .enumerate()
                .map(|(t, &w)| format!("t{t}:{w}"))
                .collect();
            out.push_str(&format!(
                "precision          {} tier shifts, windows per tier [{}]\n",
                self.precision_shifts,
                tiers.join(" "),
            ));
        }
        out.push_str(&format!("window latency     {}\n", self.latency.line()));
        out.push_str(&format!(
            "ingest drops       {} events ({} late, {} overflow, {} end-of-stream)\n",
            self.events_dropped, self.events_late, self.events_overflow,
            self.events_flush_discarded,
        ));
        // Residency traffic is reported by the embedded metrics block
        // ("state spills" line) when any eviction occurred.
        out.push_str(&format!(
            "rolling accuracy   {:.1} % ({} of {} sessions)\n",
            100.0 * self.rolling_correct as f64 / self.sessions.max(1) as f64,
            self.rolling_correct,
            self.sessions,
        ));
        out.push_str(&self.metrics.report());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::SamplePlan;
    use crate::snn::{LayerSpec, Resolution};

    const SEED: u64 = 0xBEEF;
    const MACROS: usize = 2;

    /// Small two-layer net over the 48×48 substrate, 16 timesteps (so a
    /// 100-ms sample chops into 4 windows of 4 frames).
    fn small_net() -> Network {
        let r = Resolution::new(4, 9);
        Network::new(
            "serve-test",
            vec![
                LayerSpec::conv("C1", 2, 4, 3, 4, 1, 48, 48, r),
                LayerSpec::fc("F1", 4 * 12 * 12, 10, Resolution::new(5, 10)),
            ],
            16,
        )
    }

    fn service(workers: usize, cfg_mut: impl FnOnce(&mut ServiceConfig)) -> StreamingService {
        let mut cfg = ServiceConfig::nominal(workers);
        cfg_mut(&mut cfg);
        StreamingService::native(small_net(), SEED, MACROS, Policy::HsOpt, cfg)
    }

    #[test]
    fn single_session_streamed_matches_monolithic() {
        // The module-level smoke version of the acceptance test (the full
        // ≥4-window bit-identity pin lives in rust/tests/integration_serve.rs).
        let gen = GestureGenerator::default_48();
        let mut rng = Rng::new(5);
        let stream = gen.sample(GestureClass::RightCw, &mut rng);

        // Monolithic reference.
        let plan = SamplePlan::new(small_net(), MACROS, Policy::HsOpt);
        let mut backend = NativeScnn::new(small_net(), SEED);
        let mut bufs = SampleBuffers::default();
        let mono = plan
            .run_sample(&mut backend, &mut bufs, &stream, Some(3))
            .unwrap();
        let mono_state = backend.snapshot();

        // Streamed: one session, in-order delivery, 4 windows of 4 frames.
        let svc = service(1, |_| {});
        let traffic = vec![SessionTraffic {
            id: 0,
            label: Some(3),
            end_us: stream.duration_us,
            events: stream.events.clone(),
        }];
        let report = svc.serve(&traffic, 64).unwrap();
        assert_eq!(report.finished_sessions, 1);
        assert_eq!(report.windows_done, 4);
        assert_eq!(report.windows_shed, 0);
        assert_eq!(report.events_dropped, 0);
        assert_eq!(report.evictions, 0, "one session fits the nominal budget");

        let s = svc.session_result(0).unwrap();
        assert_eq!(s.rate, mono.rate, "streamed spikes == monolithic spikes");
        assert_eq!(s.prediction, mono.prediction);
        assert_eq!(s.state, mono_state, "final vmem bit-identical");
        assert_eq!(s.metrics.timesteps, 16);
        assert_eq!(s.metrics.sops, mono.metrics.sops);
        assert_eq!(s.metrics.cim, mono.metrics.cim);
    }

    #[test]
    fn worker_count_does_not_change_session_results() {
        let traffic = gesture_traffic(6, 11, 5_000);
        let run = |workers: usize| {
            let svc = service(workers, |_| {});
            let report = svc.serve(&traffic, 32).unwrap();
            assert_eq!(report.finished_sessions, 6);
            assert_eq!(report.windows_shed, 0, "nominal load never sheds");
            (0..6u64)
                .map(|id| {
                    let s = svc.session_result(id).unwrap();
                    (s.rate, s.prediction, s.state, s.metrics.sops)
                })
                .collect::<Vec<_>>()
        };
        let a = run(1);
        let b = run(4);
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x, y, "session {i} must not depend on the pool size");
        }
    }

    #[test]
    fn tiny_residency_budget_spills_and_prices_dram() {
        let traffic = gesture_traffic(4, 3, 0);
        // Budget of exactly one session's vmem: interleaved sessions evict
        // each other constantly.
        let vmem = small_net().total_vmem_bits();
        let tight = service(2, |c| c.resident_budget_bits = vmem);
        let tight_report = tight.serve(&traffic, 16).unwrap();
        assert!(tight_report.evictions > 0, "interleaving must evict");
        assert!(tight_report.state_dram_bits > 0);
        assert!(tight_report.metrics.state_evictions > 0);

        let roomy = service(2, |_| {});
        let roomy_report = roomy.serve(&traffic, 16).unwrap();
        assert_eq!(roomy_report.evictions, 0);
        assert!(
            tight_report.metrics.energy.movement_pj
                > roomy_report.metrics.energy.movement_pj,
            "spill traffic must show up as DRAM movement energy"
        );
        // Residency never changes what is computed — only what it costs.
        assert_eq!(tight_report.metrics.sops, roomy_report.metrics.sops);
        assert_eq!(tight_report.metrics.correct, roomy_report.metrics.correct);
    }

    #[test]
    fn zero_capacity_sheds_every_window_without_stalling() {
        let traffic = gesture_traffic(3, 7, 0);
        let svc = service(2, |c| c.queue_capacity = 0);
        let report = svc.serve(&traffic, 32).unwrap();
        assert_eq!(report.windows_done, 0);
        assert!(report.windows_shed > 0);
        assert!((report.shed_rate() - 1.0).abs() < 1e-12);
        assert_eq!(
            report.finished_sessions, 3,
            "shed final windows still finish their sessions"
        );
    }

    #[test]
    fn ingest_rejects_unknown_closed_and_invalid() {
        let svc = service(1, |_| {});
        let e = DvsEvent { t_us: 0, x: 0, y: 0, polarity: true };
        assert!(svc.ingest(9, &[e]).is_err(), "unknown session");
        svc.open_session(9, None).unwrap();
        assert!(svc.open_session(9, None).is_err(), "duplicate id");
        let bad = DvsEvent { t_us: 0, x: 48, y: 0, polarity: true };
        let err = svc.ingest(9, &[bad]).unwrap_err();
        assert!(format!("{err}").contains("out of sensor bounds"));
        svc.close_session(9, 1_000).unwrap();
        assert!(svc.ingest(9, &[e]).is_err(), "closed session");
        assert!(svc.close_session(9, 1_000).is_err(), "double close");
        svc.stop();
    }

    #[test]
    fn backend_failure_surfaces_from_serve() {
        let plan = Arc::new(SamplePlan::new(small_net(), MACROS, Policy::HsOpt));
        let factory: Arc<BackendFactory> =
            Arc::new(|| Err(anyhow!("backend construction refused")));
        let svc = StreamingService::new(plan, factory, ServiceConfig::nominal(2));
        let traffic = gesture_traffic(1, 1, 0);
        let err = svc.serve(&traffic, 32).unwrap_err();
        assert!(format!("{err}").contains("refused"));
    }

    #[test]
    fn deterministic_admission_reproduces_residency_at_any_worker_count() {
        // A budget of one session's vmem makes every interleaved window an
        // eviction battle: under free scheduling the spill pattern depends
        // on worker timing, but deterministic-admission mode must replay
        // the exact same residency transitions — and so the same DRAM
        // traffic — at any pool size.
        let traffic = gesture_traffic(4, 19, 0);
        let vmem = small_net().total_vmem_bits();
        let run = |workers: usize| {
            let svc = service(workers, |c| {
                c.resident_budget_bits = vmem;
                c.deterministic_admission = true;
            });
            let r = svc.serve(&traffic, 16).unwrap();
            assert_eq!(r.finished_sessions, 4);
            assert_eq!(r.windows_shed, 0);
            (r.evictions, r.state_dram_bits, r.metrics.sops, r.metrics.in_events)
        };
        let a = run(1);
        let b = run(4);
        assert!(a.0 > 0, "tight budget must evict");
        assert_eq!(a, b, "residency accounting must be pool-size invariant");

        // The guarantee must survive early exit: a post-exit window burns
        // its admission seq whether it is skipped at ingest or
        // queued-then-dropped at the exit commit, so the dispatch order
        // (and the spill pattern it drives) stays identical.
        let run_exit = |workers: usize| {
            let svc = service(workers, |c| {
                c.resident_budget_bits = vmem;
                c.deterministic_admission = true;
                c.early_exit_margin = 1e-6;
                c.early_exit_min_windows = 1;
            });
            let r = svc.serve(&traffic, 16).unwrap();
            (
                r.evictions,
                r.state_dram_bits,
                r.windows_done,
                r.windows_saved,
                r.frames_saved,
            )
        };
        assert_eq!(run_exit(1), run_exit(4), "deterministic with early exit on");
    }

    #[test]
    fn early_exit_saves_windows_and_still_finishes() {
        let traffic = gesture_traffic(6, 23, 0);
        let baseline = service(2, |_| {}).serve(&traffic, 32).unwrap();
        assert_eq!(baseline.early_exits, 0);
        assert_eq!(baseline.windows_saved, 0);

        let svc = service(2, |c| {
            // A margin this low triggers as soon as any class leads.
            c.early_exit_margin = 1e-6;
            c.early_exit_min_windows = 1;
        });
        let report = svc.serve(&traffic, 32).unwrap();
        assert_eq!(report.finished_sessions, 6, "exited sessions still finish");
        assert!(report.early_exits > 0, "the bound must trigger");
        assert!(report.windows_saved > 0);
        assert!(report.frames_saved > 0);
        assert!(
            report.windows_done < baseline.windows_done,
            "early exit must cut executed windows"
        );
        assert_eq!(
            report.windows_done + report.windows_saved,
            baseline.windows_done,
            "every window is either executed or saved, never lost"
        );
        for id in 0..6u64 {
            let s = svc.session_result(id).unwrap();
            assert!(s.finished);
            if s.early_exited {
                assert!(s.windows_saved > 0 || s.windows_done == 4);
            }
        }
    }

    #[test]
    fn reaper_recycles_ids_after_serving() {
        let traffic = gesture_traffic(3, 29, 0);
        let svc = service(2, |_| {});
        let report = svc.serve(&traffic, 32).unwrap();
        assert_eq!(report.finished_sessions, 3);
        // All three sessions are finished and idle: the reaper closes them
        // regardless of the idle bound.
        let reaped = svc.reap_idle(Duration::from_secs(3600));
        assert_eq!(reaped, vec![0, 1, 2]);
        assert!(svc.session_result(0).is_none(), "reaped results are gone");
        assert_eq!(svc.reap_idle(Duration::from_secs(3600)), Vec::<u64>::new());
    }

    fn fast_autoscale(max_workers: usize) -> AutoscaleConfig {
        AutoscaleConfig {
            enabled: true,
            min_workers: 1,
            max_workers,
            slo_p99_s: 0.010,
            interval: Duration::from_millis(1),
            queue_high: 8,
            hysteresis_ticks: 3,
        }
    }

    #[test]
    fn autoscale_decision_grows_on_spike_and_shrinks_with_hysteresis() {
        let a = fast_autoscale(4);
        // Latency spike: one grow step per tick up to the ceiling.
        let mut w = 1;
        for _ in 0..5 {
            let (next, calm) = a.decide(w, 0.050, 0, 0);
            assert_eq!(calm, 0, "growth resets the calm streak");
            assert!(next >= w);
            w = next;
        }
        assert_eq!(w, 4, "grows to the ceiling, never past it");
        // Queue depth overloads even when p99 is unknown (NaN).
        assert_eq!(a.decide(1, f64::NAN, 100, 0), (2, 0));
        // Calm: shrink only after hysteresis_ticks consecutive calm ticks.
        assert_eq!(a.decide(4, 0.001, 0, 0), (4, 1));
        assert_eq!(a.decide(4, 0.001, 0, 1), (4, 2));
        assert_eq!(a.decide(4, 0.001, 0, 2), (3, 0));
        // Floor: never below min_workers, streak resets at the floor.
        assert_eq!(a.decide(1, 0.001, 0, 99), (1, 0));
        // Mid-band (neither overloaded nor calm): hold and reset.
        assert_eq!(a.decide(2, 0.008, 0, 2), (2, 0));
        // NaN p99 with an empty queue: hold — no data is not calm.
        assert_eq!(a.decide(2, f64::NAN, 0, 1), (2, 0));
    }

    #[test]
    fn autoscale_ticks_grow_on_spike_and_shrink_after_it_passes() {
        // Deterministic (no wall-clock): feed the rolling window by hand
        // and step the control decisions directly.
        let svc = service(1, |c| c.autoscale = fast_autoscale(4));
        let target = |svc: &StreamingService| svc.state.lock().unwrap().target_workers;
        assert_eq!(target(&svc), 1);

        // Spike: p99 far above the 10-ms SLO.
        svc.state.lock().unwrap().recent_latency.push(0.5);
        let mut calm = 0;
        for expect in [2, 3, 4, 4] {
            calm = svc.autoscale_tick(calm);
            assert_eq!(target(&svc), expect);
        }
        let st = svc.state.lock().unwrap();
        assert_eq!(st.scale_ups, 3);
        assert_eq!(st.peak_workers, 4);
        drop(st);

        // Spike passes: the ring rolls the outlier out behind calm samples.
        {
            let mut st = svc.state.lock().unwrap();
            for _ in 0..ROLLING_WINDOW {
                st.recent_latency.push(1e-4);
            }
        }
        for expect in [4, 4, 3] {
            calm = svc.autoscale_tick(calm);
            assert_eq!(target(&svc), expect, "3-tick hysteresis before the shrink");
        }
        assert_eq!(svc.state.lock().unwrap().scale_downs, 1);
        svc.stop();
    }

    #[test]
    fn autoscaled_serve_grows_the_pool_and_matches_fixed_results() {
        let traffic = gesture_traffic(12, 31, 0);
        let fixed = service(1, |_| {}).serve(&traffic, 32).unwrap();
        assert_eq!(fixed.workers_peak, 1);
        assert_eq!(fixed.scale_ups + fixed.scale_downs, 0);

        let svc = service(1, |c| {
            c.autoscale = AutoscaleConfig {
                // An unreachable SLO plus a hair-trigger queue bound: the
                // sustained backlog forces growth on the first busy tick.
                slo_p99_s: 1e-9,
                queue_high: 1,
                hysteresis_ticks: 1,
                ..fast_autoscale(4)
            };
        });
        let report = svc.serve(&traffic, 32).unwrap();
        assert!(report.scale_ups > 0, "sustained overload must grow the pool");
        assert!(report.workers_peak > 1);
        assert_eq!(report.finished_sessions, 12);
        assert_eq!(report.windows_shed, 0, "capacity is deep enough to never shed");
        assert_eq!(
            report.metrics.sops, fixed.metrics.sops,
            "pool scaling must never change what is computed"
        );
        assert_eq!(report.metrics.correct, fixed.metrics.correct);
    }

    #[test]
    fn precision_disabled_keeps_every_window_at_tier_zero() {
        let traffic = gesture_traffic(3, 17, 0);
        let svc = service(2, |_| {});
        let report = svc.serve(&traffic, 32).unwrap();
        assert_eq!(report.precision_shifts, 0);
        assert_eq!(report.tier_windows[0], report.windows_done);
        assert!(report.tier_windows[1..].iter().all(|&w| w == 0));
        for id in 0..3 {
            assert_eq!(svc.session_result(id).unwrap().tier, 0);
        }
    }

    #[test]
    fn precision_drops_under_load_sheds_energy_and_records_decisions() {
        let traffic = gesture_traffic(8, 23, 0);
        let fixed = service(1, |_| {}).serve(&traffic, 32).unwrap();
        assert_eq!(fixed.precision_shifts, 0);

        let svc = service(1, |c| {
            c.precision = PrecisionConfig {
                enabled: true,
                // Unreachable latency bound: every committed window reads
                // as load, so sessions sink toward max_delta tier by tier.
                drop_p99_s: 1e-9,
                raise_margin: 0.0,
                ..PrecisionConfig::disabled()
            };
            c.telemetry = TelemetryConfig { enabled: true, flight_capacity: 4096 };
        });
        let report = svc.serve(&traffic, 32).unwrap();
        assert_eq!(report.finished_sessions, 8);
        assert!(report.precision_shifts > 0, "sustained load must drop tiers");
        assert!(
            report.tier_windows[1..].iter().sum::<u64>() > 0,
            "windows must execute below full precision"
        );
        assert_eq!(report.tier_windows.iter().sum::<u64>(), report.windows_done);
        assert!(
            report.metrics.energy.compute_pj < fixed.metrics.energy.compute_pj,
            "narrower operands must price cheaper SOPs: {} !< {}",
            report.metrics.energy.compute_pj,
            fixed.metrics.energy.compute_pj
        );
        // Sessions end below tier 0 (nothing ever reads calm here).
        assert!((0..8).any(|id| svc.session_result(id).unwrap().tier > 0));

        // Controller decisions reach the flight recorder and the registry.
        let decisions = svc.recorder().events_of_kind("precision-decision");
        assert_eq!(decisions.len() as u64, report.precision_shifts);
        assert!(decisions.iter().any(|r| matches!(
            r.event,
            FlightEvent::PrecisionDecision { from, to, .. } if to == from + 1
        )));
        let snap = svc.metrics().snapshot();
        assert_eq!(
            snap.counter_total("flexspim_serve_precision_shifts_total"),
            report.precision_shifts
        );
        assert_eq!(
            snap.counter_total("flexspim_serve_tier_windows_total"),
            report.windows_done,
            "per-tier counters must partition the committed windows"
        );
    }

    #[test]
    fn precision_raises_back_toward_full_precision_when_calm() {
        // Calm service, sessions pre-sunk to tier 2: with no load and no
        // margin pressure the controller relaxes one tier per commit, and
        // the realigned checkpoints keep serving without error.
        let traffic = gesture_traffic(2, 41, 0);
        let svc = service(1, |c| {
            c.precision = PrecisionConfig {
                enabled: true,
                drop_p99_s: 1e9, // nothing ever reads as load
                raise_margin: 0.0,
                ..PrecisionConfig::disabled()
            };
        });
        for t in &traffic {
            svc.open_session(t.id, t.label).unwrap();
        }
        {
            let mut st = svc.state.lock().unwrap();
            for t in &traffic {
                st.sessions.get_mut(t.id).unwrap().tier = 2;
            }
        }
        svc.run_with(|s| {
            for t in &traffic {
                s.ingest(t.id, &t.events)?;
                s.close_session(t.id, t.end_us)?;
            }
            s.drain()
        })
        .unwrap();
        let report = svc.report(1.0);
        assert_eq!(report.finished_sessions, 2);
        assert!(report.precision_shifts > 0, "calm must relax tiers");
        assert!(report.tier_windows[2] > 0, "first windows ran at tier 2");
        for t in &traffic {
            assert!(
                svc.session_result(t.id).unwrap().tier < 2,
                "calm sessions relax back toward full precision"
            );
        }
    }

    #[test]
    fn auto_ids_recycle_through_the_session_lifecycle() {
        let svc = service(1, |_| {});
        let a = svc.open_session_auto(None).unwrap();
        let b = svc.open_session_auto(None).unwrap();
        assert_eq!((a, b), (0, 1));
        // Both sessions are idle (no queued or running windows): a
        // zero-bound reap closes them and recycles their ids.
        let reaped = svc.reap_idle(Duration::ZERO);
        assert_eq!(reaped, vec![0, 1]);
        let c = svc.open_session_auto(None).unwrap();
        assert_eq!(c, 1, "the most recently reaped id is reused first");
        svc.stop();
    }
}
