//! Per-session AER ingestion: a reorder/jitter buffer.
//!
//! Real DVS front ends deliver events over links that reorder and delay
//! (USB bursts, network transport). The chip's 4.25-kB spike buffer
//! assumes time-ordered per-timestep input, so the serving tier puts a
//! jitter buffer in front of every session: out-of-order [`DvsEvent`]s are
//! accepted up to a configurable reorder slack and re-emitted as
//! time-ordered [`MicroWindow`]s, each spanning a fixed number of SNN
//! timesteps. Invalid client input (out-of-bounds pixels) is rejected with
//! a descriptive [`Err`] — never a panic — and events that arrive after
//! their window has already been emitted are dropped and counted, exactly
//! like a media jitter buffer.
//!
//! Watermark discipline: a window `[t0, t0 + window_us)` is only released
//! by [`ReorderBuffer::poll`] once the *watermark* (the newest event
//! timestamp seen so far) has passed the window end by `max_lateness_us`,
//! so any event delayed by at most the slack still lands in its window.
//! [`ReorderBuffer::flush`] closes the session at an explicit end time,
//! releasing everything left — its final window absorbs the stream tail
//! (including events at exactly the end timestamp), mirroring the
//! tail-absorbing last frame of [`crate::events::encode_frames`].

use crate::events::DvsEvent;
use crate::Result;

/// Ingest-side configuration of one session.
#[derive(Debug, Clone)]
pub struct IngestConfig {
    /// Sensor width in pixels.
    pub width: u16,
    /// Sensor height in pixels.
    pub height: u16,
    /// Width of one emitted micro-window in microseconds.
    pub window_us: u64,
    /// Reorder slack: an event may trail the watermark by up to this long
    /// and still be placed into its window.
    pub max_lateness_us: u64,
    /// Upper bound on buffered events (per-session memory bound); arrivals
    /// beyond it are dropped and counted, not buffered.
    pub max_pending: usize,
    /// Upper bound on how far past the emitted frontier an event timestamp
    /// (or a declared stream end) may point. A malformed/hostile timestamp
    /// would otherwise inflate the watermark and make `poll`/`flush` emit
    /// an unbounded run of empty windows inside the service lock; beyond
    /// this bound the input is rejected with a descriptive error instead.
    pub max_future_us: u64,
}

/// One time-ordered micro-window of events, ready for encoding.
#[derive(Debug, Clone)]
pub struct MicroWindow {
    /// Window start, inclusive (microseconds).
    pub t0_us: u64,
    /// Window end, exclusive (microseconds). The final window of a flush
    /// ends just past the declared stream end (inclusive of it), which may
    /// be shorter or longer than the nominal stride.
    pub t1_us: u64,
    /// Events with `t0_us <= t_us < t1_us`, sorted by timestamp. The
    /// final window of a flush also owns the inclusive session end.
    pub events: Vec<DvsEvent>,
    /// True for the final window emitted by [`ReorderBuffer::flush`].
    pub last: bool,
}

impl MicroWindow {
    /// Window span in microseconds.
    pub fn span_us(&self) -> u64 {
        self.t1_us.saturating_sub(self.t0_us)
    }
}

/// The per-session reorder/jitter buffer.
///
/// Drop accounting partitions exactly: every valid event offered to
/// [`ReorderBuffer::push`] increments `pushed`, and from then on lands in
/// exactly one of `delivered` (emitted inside a window), the pending
/// buffer, `late_dropped`, `overflow_dropped`, or `flush_discarded` — so
/// at any point
///
/// ```text
/// delivered + pending + late_dropped + overflow_dropped
///     + flush_discarded == pushed
/// ```
///
/// and after [`ReorderBuffer::flush`] the pending term is zero. The
/// saturation harness relies on this invariant to report honest loss
/// figures; a property test in `rust/tests/property_ingest.rs` enforces
/// it under bursty/out-of-order arrivals.
#[derive(Debug, Clone)]
pub struct ReorderBuffer {
    cfg: IngestConfig,
    /// Buffered events not yet assigned to an emitted window (arrival
    /// order; sorted per window at emission).
    pending: Vec<DvsEvent>,
    /// Newest event timestamp seen.
    watermark_us: u64,
    /// Windows have been emitted up to this time.
    emitted_until_us: u64,
    /// Valid events offered to [`ReorderBuffer::push`] (accepted or
    /// dropped; excludes `Err` rejections, which never enter the ledger).
    pub pushed: u64,
    /// Events accepted into the buffer.
    pub accepted: u64,
    /// Events handed out inside an emitted [`MicroWindow`].
    pub delivered: u64,
    /// Events dropped because their window was already emitted.
    pub late_dropped: u64,
    /// Events dropped because the buffer was full.
    pub overflow_dropped: u64,
    /// Events discarded at [`ReorderBuffer::flush`] because they were
    /// timestamped past the declared stream end. Distinct from
    /// `late_dropped`: these arrived in time but the session closed before
    /// their window — end-of-stream truncation, not transport lateness.
    pub flush_discarded: u64,
}

impl ReorderBuffer {
    /// Empty buffer at session time zero.
    pub fn new(cfg: IngestConfig) -> ReorderBuffer {
        assert!(cfg.window_us > 0, "window must be non-empty");
        ReorderBuffer {
            cfg,
            pending: Vec::new(),
            watermark_us: 0,
            emitted_until_us: 0,
            pushed: 0,
            accepted: 0,
            delivered: 0,
            late_dropped: 0,
            overflow_dropped: 0,
            flush_discarded: 0,
        }
    }

    /// The ingest configuration.
    pub fn config(&self) -> &IngestConfig {
        &self.cfg
    }

    /// Newest event timestamp seen so far.
    pub fn watermark_us(&self) -> u64 {
        self.watermark_us
    }

    /// Windows have been emitted up to this session time.
    pub fn emitted_until_us(&self) -> u64 {
        self.emitted_until_us
    }

    /// Events currently buffered.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Accept one event. Returns `Ok(true)` when buffered, `Ok(false)`
    /// when dropped (late beyond the reorder slack, or buffer full), and
    /// `Err` for invalid client input (out-of-bounds pixel).
    pub fn push(&mut self, e: DvsEvent) -> Result<bool> {
        e.ensure_in_bounds(self.cfg.width, self.cfg.height)?;
        anyhow::ensure!(
            e.t_us <= self.emitted_until_us.saturating_add(self.cfg.max_future_us),
            "event at t={} us is more than {} us past the emitted frontier ({} us)",
            e.t_us,
            self.cfg.max_future_us,
            self.emitted_until_us
        );
        self.pushed += 1;
        if e.t_us < self.emitted_until_us {
            self.late_dropped += 1;
            return Ok(false);
        }
        if self.pending.len() >= self.cfg.max_pending {
            self.overflow_dropped += 1;
            return Ok(false);
        }
        self.watermark_us = self.watermark_us.max(e.t_us);
        self.pending.push(e);
        self.accepted += 1;
        Ok(true)
    }

    /// Release every window whose end the watermark has passed by the
    /// reorder slack. Call after a batch of [`Self::push`]es.
    pub fn poll(&mut self) -> Vec<MicroWindow> {
        let _span = crate::telemetry::trace::span("ingest.poll");
        let mut out = Vec::new();
        while self
            .emitted_until_us
            .saturating_add(self.cfg.window_us)
            .saturating_add(self.cfg.max_lateness_us)
            <= self.watermark_us
        {
            let t1 = self.emitted_until_us + self.cfg.window_us;
            out.push(self.take_window(t1, t1, false));
        }
        out
    }

    /// Close the session at `end_us`: release everything still pending.
    /// Full strides come out as ordinary windows; the final window is
    /// marked `last` and owns the tail `[t0, end_us]` inclusive. A
    /// declared end absurdly far past the emitted frontier is rejected
    /// (it would amplify into an unbounded run of empty windows).
    pub fn flush(&mut self, end_us: u64) -> Result<Vec<MicroWindow>> {
        anyhow::ensure!(
            end_us <= self.emitted_until_us.saturating_add(self.cfg.max_future_us),
            "stream end {} us is more than {} us past the emitted frontier ({} us)",
            end_us,
            self.cfg.max_future_us,
            self.emitted_until_us
        );
        let mut out = Vec::new();
        while self.emitted_until_us.saturating_add(self.cfg.window_us) < end_us {
            let t1 = self.emitted_until_us + self.cfg.window_us;
            out.push(self.take_window(t1, t1, false));
        }
        if self.emitted_until_us >= end_us {
            // The frontier already passed the declared end (poll emitted
            // beyond it): nothing is left to run — emit a zero-span `last`
            // marker so the session still completes, without executing
            // spurious post-end timesteps.
            let t1 = self.emitted_until_us;
            out.push(self.take_window(t1, t1, true));
        } else {
            // Final window: it ends at `end_us` *inclusive* (the
            // tail-absorbing frame owns the exact stream end), so a
            // mid-stride close encodes only the frames up to the declared
            // end instead of a full stride of phantom post-end timesteps.
            // Anything timestamped past the declared end is left behind.
            let t1 = end_us.saturating_add(1);
            out.push(self.take_window(t1, t1, true));
        }
        // Anything left was timestamped past the declared end. These
        // events were *not* late — they arrived within slack but the
        // session closed before their window — so they get their own
        // counter to keep the drop partition honest.
        self.flush_discarded += self.pending.len() as u64;
        self.pending.clear();
        Ok(out)
    }

    /// Emit the window `[emitted_until, t1)`, collecting pending events
    /// with `t_us < cut` (sorted by timestamp).
    fn take_window(&mut self, t1: u64, cut: u64, last: bool) -> MicroWindow {
        let t0 = self.emitted_until_us;
        let mut events = Vec::new();
        let mut keep = Vec::with_capacity(self.pending.len());
        for e in self.pending.drain(..) {
            if e.t_us < cut {
                events.push(e);
            } else {
                keep.push(e);
            }
        }
        self.pending = keep;
        events.sort_by_key(|e| e.t_us);
        self.delivered += events.len() as u64;
        self.emitted_until_us = t1;
        MicroWindow { t0_us: t0, t1_us: t1, events, last }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(window_us: u64, slack_us: u64) -> IngestConfig {
        IngestConfig {
            width: 8,
            height: 8,
            window_us,
            max_lateness_us: slack_us,
            max_pending: 1024,
            max_future_us: 1 << 20,
        }
    }

    fn ev(t: u64, x: u16, y: u16) -> DvsEvent {
        DvsEvent { t_us: t, x, y, polarity: true }
    }

    #[test]
    fn out_of_bounds_event_is_a_recoverable_error() {
        let mut b = ReorderBuffer::new(cfg(100, 10));
        let err = b.push(ev(5, 8, 0)).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("out of sensor bounds"), "got: {msg}");
        // The buffer survives and keeps accepting valid input.
        assert!(b.push(ev(5, 7, 7)).unwrap());
        assert_eq!(b.accepted, 1);
    }

    #[test]
    fn windows_wait_for_the_watermark_slack() {
        let mut b = ReorderBuffer::new(cfg(100, 50));
        b.push(ev(10, 0, 0)).unwrap();
        b.push(ev(120, 1, 1)).unwrap();
        // Watermark 120 < 100 + 50: window [0, 100) not yet safe.
        assert!(b.poll().is_empty());
        b.push(ev(150, 2, 2)).unwrap();
        let w = b.poll();
        assert_eq!(w.len(), 1);
        assert_eq!((w[0].t0_us, w[0].t1_us), (0, 100));
        assert_eq!(w[0].events.len(), 1);
        assert!(!w[0].last);
        assert_eq!(b.pending_len(), 2, "later events stay buffered");
    }

    #[test]
    fn heavily_out_of_order_arrivals_reassemble_in_order() {
        let mut b = ReorderBuffer::new(cfg(100, 100));
        // Arrival order is fully reversed across three windows.
        for t in [290u64, 250, 210, 190, 150, 110, 90, 50, 10] {
            assert!(b.push(ev(t, (t % 8) as u16, 0)).unwrap());
        }
        // Watermark is the max seen (290, pushed first), so polling after
        // the batch releases [0,100) and [100,200) but not [200,300).
        let w = b.poll();
        assert_eq!(w.len(), 1, "only [0,100) has end+slack <= 290");
        assert_eq!(
            w[0].events.iter().map(|e| e.t_us).collect::<Vec<_>>(),
            vec![10, 50, 90],
            "window events are time-ordered despite reversed arrival"
        );
        let rest = b.flush(300).unwrap();
        assert_eq!(rest.len(), 2);
        assert_eq!(
            rest[0].events.iter().map(|e| e.t_us).collect::<Vec<_>>(),
            vec![110, 150, 190]
        );
        assert_eq!(
            rest[1].events.iter().map(|e| e.t_us).collect::<Vec<_>>(),
            vec![210, 250, 290]
        );
        assert!(rest[1].last);
        assert_eq!(b.late_dropped, 0);
    }

    #[test]
    fn duplicate_timestamps_are_kept_and_ordered() {
        let mut b = ReorderBuffer::new(cfg(100, 0));
        b.push(ev(40, 1, 1)).unwrap();
        b.push(ev(40, 2, 2)).unwrap();
        b.push(ev(40, 1, 1)).unwrap();
        let w = b.flush(99).unwrap();
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].events.len(), 3, "dedup is the encoder's job, not ingest's");
        assert!(w[0].events.windows(2).all(|p| p[0].t_us <= p[1].t_us));
    }

    #[test]
    fn empty_stream_flush_covers_the_whole_session() {
        let mut b = ReorderBuffer::new(cfg(100, 10));
        let w = b.flush(250).unwrap();
        // [0,100), [100,200), then the last window absorbing to 250.
        assert_eq!(w.len(), 3);
        assert_eq!((w[0].t0_us, w[0].t1_us), (0, 100));
        assert_eq!((w[1].t0_us, w[1].t1_us), (100, 200));
        assert_eq!(w[2].t0_us, 200);
        assert!(w[2].t1_us > 250, "tail window owns t == end");
        assert!(w.iter().all(|x| x.events.is_empty()));
        assert!(w[2].last && !w[0].last && !w[1].last);
    }

    #[test]
    fn event_at_exact_session_end_lands_in_last_window() {
        let mut b = ReorderBuffer::new(cfg(100, 10));
        b.push(ev(200, 3, 3)).unwrap();
        let w = b.flush(200).unwrap();
        let last = w.last().unwrap();
        assert!(last.last);
        assert_eq!(last.events.len(), 1);
        assert_eq!(b.late_dropped, 0);
    }

    #[test]
    fn late_event_is_dropped_and_counted() {
        let mut b = ReorderBuffer::new(cfg(100, 0));
        b.push(ev(250, 0, 0)).unwrap();
        let w = b.poll();
        assert_eq!(w.len(), 2, "[0,100) and [100,200) are past the watermark");
        // An event for the already-emitted first window arrives now.
        assert!(!b.push(ev(50, 1, 1)).unwrap());
        assert_eq!(b.late_dropped, 1);
        assert_eq!(b.accepted, 1);
    }

    #[test]
    fn overflow_drops_and_counts() {
        let mut b = ReorderBuffer::new(IngestConfig { max_pending: 2, ..cfg(100, 0) });
        assert!(b.push(ev(1, 0, 0)).unwrap());
        assert!(b.push(ev(2, 0, 0)).unwrap());
        assert!(!b.push(ev(3, 0, 0)).unwrap());
        assert_eq!(b.overflow_dropped, 1);
        assert_eq!(b.pending_len(), 2);
    }

    #[test]
    fn far_future_timestamps_are_rejected_not_amplified() {
        // A hostile/corrupt timestamp must become an error, not an
        // unbounded run of empty windows inside the service lock.
        let mut b = ReorderBuffer::new(IngestConfig { max_future_us: 1_000, ..cfg(100, 0) });
        let err = b.push(ev(2_000, 0, 0)).unwrap_err();
        assert!(format!("{err}").contains("past the emitted frontier"), "got: {err}");
        assert!(b.push(ev(900, 0, 0)).unwrap(), "in-bound events still accepted");
        // Same bound for a declared stream end.
        let err = b.flush(500_000).unwrap_err();
        assert!(format!("{err}").contains("past the emitted frontier"), "got: {err}");
        let w = b.flush(950).unwrap();
        assert!(w.last().unwrap().last);
        assert_eq!(w.iter().map(|x| x.events.len()).sum::<usize>(), 1);
    }

    #[test]
    fn mid_stride_close_ends_the_final_window_at_the_declared_end() {
        let mut b = ReorderBuffer::new(cfg(100, 0));
        b.push(ev(130, 0, 0)).unwrap();
        let w = b.flush(150).unwrap();
        // One full stride, then a short final window — no phantom span
        // past the declared end.
        assert_eq!(w.len(), 2);
        assert_eq!((w[1].t0_us, w[1].t1_us), (100, 151));
        assert!(w[1].last);
        assert_eq!(w[1].events.len(), 1);
    }

    #[test]
    fn flush_after_frontier_passed_end_emits_zero_span_last_marker() {
        // poll() already emitted past the (late, inconsistent) declared
        // end: the close must not fabricate post-end timesteps.
        let mut b = ReorderBuffer::new(cfg(100, 0));
        b.push(ev(250, 0, 0)).unwrap();
        assert_eq!(b.poll().len(), 2, "frontier advances to 200");
        let w = b.flush(150).unwrap();
        assert_eq!(w.len(), 1);
        assert!(w[0].last);
        assert_eq!(w[0].span_us(), 0, "no post-end stride");
        assert!(w[0].events.is_empty());
        assert_eq!(b.late_dropped, 0, "the t=250 event was never late");
        assert_eq!(b.flush_discarded, 1, "it was truncated by the early close");
    }

    #[test]
    fn events_past_the_declared_end_are_dropped_at_flush() {
        let mut b = ReorderBuffer::new(cfg(100, 50));
        b.push(ev(50, 0, 0)).unwrap();
        b.push(ev(500, 0, 0)).unwrap();
        let w = b.flush(100).unwrap();
        assert_eq!(w.last().unwrap().events.len(), 1);
        assert_eq!(b.late_dropped, 0, "t=500 arrived in time");
        assert_eq!(b.flush_discarded, 1, "t=500 is past the declared end");
    }

    #[test]
    fn drop_counters_partition_every_pushed_event() {
        // One event per fate: delivered, late, overflow, flush-discarded —
        // plus an Err rejection that must stay outside the ledger.
        let mut b = ReorderBuffer::new(IngestConfig { max_pending: 2, ..cfg(100, 0) });
        assert!(b.push(ev(50, 0, 0)).unwrap()); // delivered eventually
        assert!(b.push(ev(250, 1, 1)).unwrap()); // flush-discarded later
        assert!(!b.push(ev(60, 2, 2)).unwrap(), "buffer full"); // overflow
        assert!(b.push(ev(999, 9, 9)).is_err(), "out of bounds: not pushed");
        let _ = b.poll(); // frontier advances to 200 (watermark 250)
        assert!(!b.push(ev(10, 3, 3)).unwrap(), "window emitted"); // late
        b.flush(200).unwrap();
        assert_eq!(b.pushed, 4);
        assert_eq!(b.delivered, 1);
        assert_eq!(b.late_dropped, 1);
        assert_eq!(b.overflow_dropped, 1);
        assert_eq!(b.flush_discarded, 1);
        assert_eq!(b.pending_len(), 0);
        assert_eq!(
            b.delivered + b.late_dropped + b.overflow_dropped + b.flush_discarded,
            b.pushed,
            "drop counters partition exactly"
        );
    }
}
