//! DVS event primitives (address-event representation).

use crate::Result;

/// One DVS event: a pixel fired at a microsecond timestamp with a
/// brightness-change polarity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DvsEvent {
    /// Timestamp in microseconds from stream start.
    pub t_us: u64,
    /// Pixel x coordinate.
    pub x: u16,
    /// Pixel y coordinate.
    pub y: u16,
    /// `true` = ON (brightness increase), `false` = OFF.
    pub polarity: bool,
}

impl DvsEvent {
    /// Validate this event's pixel against a sensor geometry — the single
    /// client-facing bounds check shared by [`EventStream::new`] and the
    /// serve tier's ingest buffer.
    pub fn ensure_in_bounds(&self, width: u16, height: u16) -> Result<()> {
        anyhow::ensure!(
            self.x < width && self.y < height,
            "event at t={} us out of sensor bounds: pixel ({}, {}) on a {}x{} sensor",
            self.t_us,
            self.x,
            self.y,
            width,
            height
        );
        Ok(())
    }
}

/// A sensor-resolution-tagged stream of events, sorted by timestamp.
#[derive(Debug, Clone)]
pub struct EventStream {
    /// Sensor width in pixels.
    pub width: u16,
    /// Sensor height in pixels.
    pub height: u16,
    /// Stream duration in microseconds.
    pub duration_us: u64,
    /// Events sorted by `t_us`.
    pub events: Vec<DvsEvent>,
}

impl EventStream {
    /// Validate coordinates/order and build the stream.
    ///
    /// Events arrive from outside the process (a sensor, a network client),
    /// so invalid input is a recoverable [`Err`] with a descriptive
    /// message, never a panic.
    pub fn new(
        width: u16,
        height: u16,
        duration_us: u64,
        mut events: Vec<DvsEvent>,
    ) -> Result<Self> {
        events.sort_by_key(|e| e.t_us);
        for e in &events {
            e.ensure_in_bounds(width, height)?;
            anyhow::ensure!(
                e.t_us <= duration_us,
                "event at t={} us after stream end ({} us)",
                e.t_us,
                duration_us
            );
        }
        Ok(EventStream { width, height, duration_us, events })
    }

    /// Mean event rate in events/second.
    pub fn rate_hz(&self) -> f64 {
        if self.duration_us == 0 {
            return 0.0;
        }
        self.events.len() as f64 / (self.duration_us as f64 * 1e-6)
    }

    /// Events within `[t0_us, t1_us)` (binary-searched slice).
    pub fn window(&self, t0_us: u64, t1_us: u64) -> &[DvsEvent] {
        let lo = self.events.partition_point(|e| e.t_us < t0_us);
        let hi = self.events.partition_point(|e| e.t_us < t1_us);
        &self.events[lo..hi]
    }

    /// Fraction of (pixel × polarity × timestep) slots with no event, for
    /// the given timestep width — the paper's "input sparsity".
    pub fn sparsity(&self, timestep_us: u64) -> f64 {
        assert!(timestep_us > 0);
        let steps = self.duration_us.div_ceil(timestep_us).max(1);
        let slots = steps * self.width as u64 * self.height as u64 * 2;
        // Count occupied slots (deduplicate multiple events per slot).
        let mut occupied = std::collections::HashSet::new();
        for e in &self.events {
            let step = e.t_us / timestep_us;
            occupied.insert((step.min(steps - 1), e.x, e.y, e.polarity));
        }
        1.0 - occupied.len() as f64 / slots as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: u64, x: u16, y: u16, p: bool) -> DvsEvent {
        DvsEvent { t_us: t, x, y, polarity: p }
    }

    #[test]
    fn stream_sorts_events() {
        let s =
            EventStream::new(8, 8, 100, vec![ev(50, 1, 1, true), ev(10, 2, 2, false)]).unwrap();
        assert_eq!(s.events[0].t_us, 10);
    }

    #[test]
    fn oob_event_rejected_with_descriptive_error() {
        let err = EventStream::new(8, 8, 100, vec![ev(3, 8, 0, true)]).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("out of sensor bounds"), "got: {msg}");
        assert!(msg.contains("(8, 0)") && msg.contains("8x8"), "got: {msg}");
    }

    #[test]
    fn late_event_rejected_with_descriptive_error() {
        let err = EventStream::new(8, 8, 100, vec![ev(101, 0, 0, true)]).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("after stream end"), "got: {msg}");
        assert!(msg.contains("101"), "got: {msg}");
    }

    #[test]
    fn event_at_exact_stream_end_is_valid() {
        let s = EventStream::new(8, 8, 100, vec![ev(100, 0, 0, true)]).unwrap();
        assert_eq!(s.events.len(), 1);
    }

    #[test]
    fn rate_and_window() {
        let events: Vec<DvsEvent> = (0..100).map(|i| ev(i * 10, 0, 0, true)).collect();
        let s = EventStream::new(4, 4, 1000, events).unwrap();
        assert!((s.rate_hz() - 1e5).abs() < 1.0);
        assert_eq!(s.window(100, 200).len(), 10); // t = 100..190
        assert_eq!(s.window(0, 10).len(), 1);
        assert_eq!(s.window(995, 2000).len(), 0);
    }

    #[test]
    fn sparsity_extremes() {
        // Empty stream: fully sparse.
        let s = EventStream::new(4, 4, 100, vec![]).unwrap();
        assert_eq!(s.sparsity(10), 1.0);
        // One event per slot in a 1-step stream: count occupied.
        let mut evs = Vec::new();
        for x in 0..4 {
            for y in 0..4 {
                evs.push(ev(0, x, y, true));
                evs.push(ev(0, x, y, false));
            }
        }
        let s = EventStream::new(4, 4, 9, evs).unwrap();
        assert_eq!(s.sparsity(10), 0.0);
    }

    #[test]
    fn sparsity_deduplicates_same_slot() {
        let s =
            EventStream::new(4, 4, 9, vec![ev(0, 0, 0, true), ev(5, 0, 0, true)]).unwrap();
        // 2 events, 1 slot occupied of 32.
        assert!((s.sparsity(10) - (1.0 - 1.0 / 32.0)).abs() < 1e-12);
    }
}
