//! Event→spike-frame encoder (the per-timestep input buffer of Fig. 5a).
//!
//! The accelerator buffers one timestep of input events (the chip's
//! 4.25-kB spike buffer) and presents them to the first SNN layer as a
//! binary 2-channel (ON/OFF polarity) frame. Multiple events in the same
//! (pixel, polarity, timestep) slot collapse into a single spike, exactly
//! as a single-bit buffer does in hardware.

use super::dvs::EventStream;
use crate::snn::events::SpikeList;

/// One timestep of binary input spikes: channel-major `[2][h][w]` bits.
#[derive(Debug, Clone)]
pub struct SpikeFrame {
    /// Frame height.
    pub height: u16,
    /// Frame width.
    pub width: u16,
    /// Bit per (channel, y, x): `bits[c * h * w + y * w + x]`.
    pub bits: Vec<bool>,
}

impl SpikeFrame {
    /// Empty frame.
    pub fn new(width: u16, height: u16) -> Self {
        SpikeFrame {
            height,
            width,
            bits: vec![false; 2 * width as usize * height as usize],
        }
    }

    #[inline]
    fn index(&self, channel: usize, x: u16, y: u16) -> usize {
        debug_assert!(channel < 2 && x < self.width && y < self.height);
        channel * self.height as usize * self.width as usize
            + y as usize * self.width as usize
            + x as usize
    }

    /// Read one spike bit. Channel 0 = ON polarity, 1 = OFF.
    pub fn get(&self, channel: usize, x: u16, y: u16) -> bool {
        self.bits[self.index(channel, x, y)]
    }

    /// Set one spike bit.
    pub fn set(&mut self, channel: usize, x: u16, y: u16) {
        let i = self.index(channel, x, y);
        self.bits[i] = true;
    }

    /// Number of active spikes.
    pub fn count(&self) -> usize {
        self.bits.iter().filter(|&&b| b).count()
    }

    /// Sparsity of this frame (1 − active fraction).
    pub fn sparsity(&self) -> f64 {
        1.0 - self.count() as f64 / self.bits.len() as f64
    }

    /// Buffer footprint in bytes (1 bit per slot) — 4.25 kB holds a
    /// 128×128×2 frame plus control words on the chip; a 48×48 workload
    /// needs 576 B of it.
    pub fn buffer_bytes(&self) -> usize {
        self.bits.len().div_ceil(8)
    }

    /// Flatten to the `[channels × h × w]` boolean layout the SNN layer
    /// expects as its fan-in vector.
    pub fn as_input_vector(&self) -> &[bool] {
        &self.bits
    }

    /// Emit the frame as a sparse [`SpikeList`] (sorted active indices
    /// over the same channel-major layout) — what the event-driven
    /// execution stack consumes directly, AER-style.
    pub fn to_spike_list(&self) -> SpikeList {
        SpikeList::from_dense(&self.bits)
    }
}

/// Bin an event stream into `timesteps` spike frames (paper Fig. 1c:
/// per-timestep processing for low-latency decisions).
pub fn encode_frames(stream: &EventStream, timesteps: usize) -> Vec<SpikeFrame> {
    assert!(timesteps > 0);
    let step_us = (stream.duration_us / timesteps as u64).max(1);
    let mut frames = Vec::with_capacity(timesteps);
    for i in 0..timesteps {
        let t0 = i as u64 * step_us;
        let t1 = if i == timesteps - 1 {
            stream.duration_us + 1 // last frame absorbs the tail
        } else {
            (i + 1) as u64 * step_us
        };
        let mut f = SpikeFrame::new(stream.width, stream.height);
        for e in stream.window(t0, t1) {
            f.set(if e.polarity { 0 } else { 1 }, e.x, e.y);
        }
        frames.push(f);
    }
    frames
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::dvs::DvsEvent;
    use crate::events::synthetic::{GestureClass, GestureGenerator};
    use crate::util::rng::Rng;

    fn ev(t: u64, x: u16, y: u16, p: bool) -> DvsEvent {
        DvsEvent { t_us: t, x, y, polarity: p }
    }

    #[test]
    fn binning_and_polarity_channels() {
        let s = EventStream::new(
            4,
            4,
            100,
            vec![ev(5, 1, 2, true), ev(55, 3, 0, false), ev(99, 3, 3, true)],
        )
        .unwrap();
        let frames = encode_frames(&s, 2);
        assert_eq!(frames.len(), 2);
        assert!(frames[0].get(0, 1, 2));
        assert!(!frames[0].get(1, 1, 2));
        assert!(frames[1].get(1, 3, 0));
        assert!(frames[1].get(0, 3, 3), "tail event lands in last frame");
        assert_eq!(frames[0].count(), 1);
        assert_eq!(frames[1].count(), 2);
    }

    #[test]
    fn duplicate_events_collapse() {
        let s = EventStream::new(
            4,
            4,
            100,
            vec![ev(1, 0, 0, true), ev(2, 0, 0, true), ev(3, 0, 0, true)],
        )
        .unwrap();
        let frames = encode_frames(&s, 1);
        assert_eq!(frames[0].count(), 1, "single-bit buffer semantics");
    }

    #[test]
    fn empty_stream_encodes_to_empty_frames() {
        let s = EventStream::new(4, 4, 100, vec![]).unwrap();
        let frames = encode_frames(&s, 4);
        assert_eq!(frames.len(), 4);
        assert!(frames.iter().all(|f| f.count() == 0));
        assert!(frames.iter().all(|f| (f.sparsity() - 1.0).abs() < 1e-12));
    }

    #[test]
    fn all_events_at_stream_end_land_in_last_frame() {
        // t_us == duration_us is a valid timestamp; the tail-absorbing last
        // frame must own it for every frame count.
        let evs = vec![ev(100, 0, 0, true), ev(100, 1, 1, false), ev(100, 2, 2, true)];
        let s = EventStream::new(4, 4, 100, evs).unwrap();
        for timesteps in [1usize, 2, 3, 16] {
            let frames = encode_frames(&s, timesteps);
            assert_eq!(frames.len(), timesteps);
            for f in &frames[..timesteps - 1] {
                assert_eq!(f.count(), 0, "{timesteps} steps: early frame empty");
            }
            assert_eq!(frames[timesteps - 1].count(), 3, "{timesteps} steps: tail owns all");
        }
    }

    #[test]
    fn duplicate_timestamps_collapse_per_slot_not_per_time() {
        // Three events share t=10: two on the same (pixel, polarity) slot
        // collapse, the third targets another pixel and survives.
        let evs = vec![ev(10, 0, 0, true), ev(10, 0, 0, true), ev(10, 3, 3, true)];
        let s = EventStream::new(4, 4, 100, evs).unwrap();
        let frames = encode_frames(&s, 1);
        assert_eq!(frames[0].count(), 2);
        assert!(frames[0].get(0, 0, 0) && frames[0].get(0, 3, 3));
    }

    #[test]
    fn out_of_order_arrival_encodes_identically_to_sorted() {
        // EventStream::new sorts, so heavily out-of-order client input must
        // produce the same frames as the time-ordered stream.
        let ordered: Vec<DvsEvent> =
            (0..50).map(|i| ev(i * 2, (i % 4) as u16, ((i / 4) % 4) as u16, i % 2 == 0)).collect();
        let mut shuffled = ordered.clone();
        shuffled.reverse();
        shuffled.swap(3, 41);
        shuffled.swap(0, 25);
        let a = EventStream::new(4, 4, 100, ordered).unwrap();
        let b = EventStream::new(4, 4, 100, shuffled).unwrap();
        let fa = encode_frames(&a, 8);
        let fb = encode_frames(&b, 8);
        for (x, y) in fa.iter().zip(&fb) {
            assert_eq!(x.bits, y.bits);
        }
    }

    #[test]
    fn spike_list_matches_dense_bits() {
        let g = GestureGenerator::default_48();
        let mut rng = Rng::new(4);
        let s = g.sample(GestureClass::LeftCw, &mut rng);
        for f in encode_frames(&s, 8) {
            let sl = f.to_spike_list();
            assert_eq!(sl.dim(), f.bits.len());
            assert_eq!(sl.count(), f.count());
            assert_eq!(sl.to_dense(), f.bits);
        }
    }

    #[test]
    fn input_vector_layout_is_channel_major() {
        let mut f = SpikeFrame::new(3, 2);
        f.set(1, 2, 1); // OFF channel, x=2, y=1
        let v = f.as_input_vector();
        assert_eq!(v.len(), 12);
        assert!(v[6 + 1 * 3 + 2]);
        assert_eq!(v.iter().filter(|&&b| b).count(), 1);
    }

    #[test]
    fn buffer_footprint_matches_chip_scale() {
        // 128×128 sensor: 2 × 128 × 128 bits = 4 kB — the chip's 4.25-kB
        // buffer (with control overhead).
        let f = SpikeFrame::new(128, 128);
        assert_eq!(f.buffer_bytes(), 4096);
        let f48 = SpikeFrame::new(48, 48);
        assert_eq!(f48.buffer_bytes(), 576);
    }

    #[test]
    fn gesture_frames_match_network_input() {
        let g = GestureGenerator::default_48();
        let mut rng = Rng::new(1);
        let s = g.sample(GestureClass::HandClap, &mut rng);
        let frames = encode_frames(&s, 16);
        assert_eq!(frames.len(), 16);
        // The SCNN input layer expects 2×48×48 = 4608 inputs.
        assert_eq!(frames[0].as_input_vector().len(), 4608);
        // Mid-gesture frames carry signal.
        assert!(frames[8].count() > 0);
    }

    #[test]
    fn frame_sparsity_consistent_with_stream_sparsity() {
        let g = GestureGenerator::default_48();
        let mut rng = Rng::new(9);
        let s = g.sample(GestureClass::RightCw, &mut rng);
        let frames = encode_frames(&s, 16);
        let mean_frame_sparsity: f64 =
            frames.iter().map(SpikeFrame::sparsity).sum::<f64>() / frames.len() as f64;
        let stream_sparsity = s.sparsity(s.duration_us / 16);
        assert!(
            (mean_frame_sparsity - stream_sparsity).abs() < 0.02,
            "frame {mean_frame_sparsity:.4} vs stream {stream_sparsity:.4}"
        );
    }
}
