//! Event→spike-frame encoder (the per-timestep input buffer of Fig. 5a).
//!
//! The accelerator buffers one timestep of input events (the chip's
//! 4.25-kB spike buffer) and presents them to the first SNN layer as a
//! binary 2-channel (ON/OFF polarity) frame. Multiple events in the same
//! (pixel, polarity, timestep) slot collapse into a single spike, exactly
//! as a single-bit buffer does in hardware.

use super::dvs::EventStream;
use crate::snn::events::SpikeList;

/// One timestep of binary input spikes: channel-major `[2][h][w]` bits.
#[derive(Debug, Clone)]
pub struct SpikeFrame {
    /// Frame height.
    pub height: u16,
    /// Frame width.
    pub width: u16,
    /// Bit per (channel, y, x): `bits[c * h * w + y * w + x]`.
    pub bits: Vec<bool>,
}

impl SpikeFrame {
    /// Empty frame.
    pub fn new(width: u16, height: u16) -> Self {
        SpikeFrame {
            height,
            width,
            bits: vec![false; 2 * width as usize * height as usize],
        }
    }

    #[inline]
    fn index(&self, channel: usize, x: u16, y: u16) -> usize {
        debug_assert!(channel < 2 && x < self.width && y < self.height);
        channel * self.height as usize * self.width as usize
            + y as usize * self.width as usize
            + x as usize
    }

    /// Read one spike bit. Channel 0 = ON polarity, 1 = OFF.
    pub fn get(&self, channel: usize, x: u16, y: u16) -> bool {
        self.bits[self.index(channel, x, y)]
    }

    /// Set one spike bit.
    pub fn set(&mut self, channel: usize, x: u16, y: u16) {
        let i = self.index(channel, x, y);
        self.bits[i] = true;
    }

    /// Number of active spikes.
    pub fn count(&self) -> usize {
        self.bits.iter().filter(|&&b| b).count()
    }

    /// Sparsity of this frame (1 − active fraction).
    pub fn sparsity(&self) -> f64 {
        1.0 - self.count() as f64 / self.bits.len() as f64
    }

    /// Buffer footprint in bytes (1 bit per slot) — 4.25 kB holds a
    /// 128×128×2 frame plus control words on the chip; a 48×48 workload
    /// needs 576 B of it.
    pub fn buffer_bytes(&self) -> usize {
        self.bits.len().div_ceil(8)
    }

    /// Flatten to the `[channels × h × w]` boolean layout the SNN layer
    /// expects as its fan-in vector.
    pub fn as_input_vector(&self) -> &[bool] {
        &self.bits
    }

    /// Emit the frame as a sparse [`SpikeList`] (sorted active indices
    /// over the same channel-major layout) — what the event-driven
    /// execution stack consumes directly, AER-style.
    pub fn to_spike_list(&self) -> SpikeList {
        SpikeList::from_dense(&self.bits)
    }

    /// Densify a [`SpikeList`] back into a frame (compat boundary for the
    /// dense golden models; the list's dimension must be `2 × h × w`).
    pub fn from_spike_list(width: u16, height: u16, spikes: &SpikeList) -> SpikeFrame {
        let mut f = SpikeFrame::new(width, height);
        assert_eq!(
            spikes.dim(),
            f.bits.len(),
            "spike list does not match the frame geometry"
        );
        for &i in spikes.active() {
            f.bits[i as usize] = true;
        }
        f
    }
}

/// One timestep of binary input spikes packed 64 slots per `u64` word —
/// the bit-plane twin of [`SpikeFrame`] (same channel-major `[2][h][w]`
/// slot order, bit `i & 63` of word `i >> 6`).
///
/// This is the in-memory image of the chip's single-bit spike buffer: the
/// popcount of the words *is* the event count the energy ledger charges,
/// and [`Self::to_spike_list_into`] unpacks straight into the sorted
/// [`SpikeList`] order the event-driven layers consume, with no dense
/// `Vec<bool>` in between.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitPlaneFrame {
    /// Frame height.
    pub height: u16,
    /// Frame width.
    pub width: u16,
    words: Vec<u64>,
}

impl BitPlaneFrame {
    /// Empty frame.
    pub fn new(width: u16, height: u16) -> Self {
        let dim = 2 * width as usize * height as usize;
        BitPlaneFrame { height, width, words: vec![0u64; SpikeList::words_for(dim)] }
    }

    /// Dense dimension of the underlying spike vector (`2 × h × w`).
    pub fn dim(&self) -> usize {
        2 * self.width as usize * self.height as usize
    }

    #[inline]
    fn index(&self, channel: usize, x: u16, y: u16) -> usize {
        debug_assert!(channel < 2 && x < self.width && y < self.height);
        channel * self.height as usize * self.width as usize
            + y as usize * self.width as usize
            + x as usize
    }

    /// Set one spike bit. Channel 0 = ON polarity, 1 = OFF.
    pub fn set(&mut self, channel: usize, x: u16, y: u16) {
        let i = self.index(channel, x, y);
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    /// Read one spike bit.
    pub fn get(&self, channel: usize, x: u16, y: u16) -> bool {
        let i = self.index(channel, x, y);
        self.words[i >> 6] >> (i & 63) & 1 == 1
    }

    /// Clear every bit, keeping the buffer.
    pub fn clear(&mut self) {
        self.words.fill(0);
    }

    /// Number of active spikes — a word-parallel popcount, the analytic
    /// source of the per-frame event count.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// The packed words (read-only; word-parallel consumers AND against
    /// these directly).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Pack a dense [`SpikeFrame`] (compat boundary).
    pub fn from_spike_frame(f: &SpikeFrame) -> Self {
        let mut p = BitPlaneFrame::new(f.width, f.height);
        for (i, &b) in f.bits.iter().enumerate() {
            if b {
                p.words[i >> 6] |= 1u64 << (i & 63);
            }
        }
        p
    }

    /// Unpack into a reusable [`SpikeList`] — set bits enumerate in
    /// ascending slot order via `trailing_zeros`, so the list comes out
    /// sorted without a sort, and the buffer is reused (no allocation at
    /// steady state).
    pub fn to_spike_list_into(&self, out: &mut SpikeList) {
        out.begin(self.dim());
        for (wi, &w) in self.words.iter().enumerate() {
            let mut m = w;
            while m != 0 {
                let b = m.trailing_zeros() as usize;
                m &= m - 1;
                out.push(((wi << 6) | b) as u32);
            }
        }
    }

    /// Allocating wrapper around [`Self::to_spike_list_into`].
    pub fn to_spike_list(&self) -> SpikeList {
        let mut out = SpikeList::default();
        self.to_spike_list_into(&mut out);
        out
    }

    /// Buffer footprint in bytes — 1 bit per slot rounded up to whole
    /// `u64` words (matches the dense frame's footprint whenever the slot
    /// count is word-aligned, as the 48×48 and 128×128 sensors are).
    pub fn buffer_bytes(&self) -> usize {
        self.words.len() * 8
    }
}

/// Bin an event stream into `timesteps` spike frames (paper Fig. 1c:
/// per-timestep processing for low-latency decisions).
pub fn encode_frames(stream: &EventStream, timesteps: usize) -> Vec<SpikeFrame> {
    assert!(timesteps > 0);
    let step_us = (stream.duration_us / timesteps as u64).max(1);
    let mut frames = Vec::with_capacity(timesteps);
    for i in 0..timesteps {
        let t0 = i as u64 * step_us;
        let t1 = if i == timesteps - 1 {
            stream.duration_us + 1 // last frame absorbs the tail
        } else {
            (i + 1) as u64 * step_us
        };
        let mut f = SpikeFrame::new(stream.width, stream.height);
        for e in stream.window(t0, t1) {
            f.set(if e.polarity { 0 } else { 1 }, e.x, e.y);
        }
        frames.push(f);
    }
    frames
}

/// Bin an event stream straight into per-timestep [`SpikeList`]s — same
/// binning rule and slot layout as [`encode_frames`], but fully sparse:
/// each event appends its slot index and the list is sealed (sorted +
/// deduped, collapsing same-slot repeats exactly like the single-bit
/// buffer), with no intermediate dense bitmap. Work and memory scale with
/// the event count, not the sensor area.
pub fn encode_frames_sparse(stream: &EventStream, timesteps: usize) -> Vec<SpikeList> {
    assert!(timesteps > 0);
    let step_us = (stream.duration_us / timesteps as u64).max(1);
    let hw = stream.height as usize * stream.width as usize;
    let width = stream.width as usize;
    let dim = 2 * hw;
    let mut frames = Vec::with_capacity(timesteps);
    for i in 0..timesteps {
        let t0 = i as u64 * step_us;
        let t1 = if i == timesteps - 1 {
            stream.duration_us + 1 // last frame absorbs the tail
        } else {
            (i + 1) as u64 * step_us
        };
        let mut sl = SpikeList::empty(dim);
        for e in stream.window(t0, t1) {
            let c = if e.polarity { 0usize } else { 1 };
            sl.push_unordered((c * hw + e.y as usize * width + e.x as usize) as u32);
        }
        sl.seal();
        frames.push(sl);
    }
    frames
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::dvs::DvsEvent;
    use crate::events::synthetic::{GestureClass, GestureGenerator};
    use crate::util::rng::Rng;

    fn ev(t: u64, x: u16, y: u16, p: bool) -> DvsEvent {
        DvsEvent { t_us: t, x, y, polarity: p }
    }

    #[test]
    fn binning_and_polarity_channels() {
        let s = EventStream::new(
            4,
            4,
            100,
            vec![ev(5, 1, 2, true), ev(55, 3, 0, false), ev(99, 3, 3, true)],
        )
        .unwrap();
        let frames = encode_frames(&s, 2);
        assert_eq!(frames.len(), 2);
        assert!(frames[0].get(0, 1, 2));
        assert!(!frames[0].get(1, 1, 2));
        assert!(frames[1].get(1, 3, 0));
        assert!(frames[1].get(0, 3, 3), "tail event lands in last frame");
        assert_eq!(frames[0].count(), 1);
        assert_eq!(frames[1].count(), 2);
    }

    #[test]
    fn duplicate_events_collapse() {
        let s = EventStream::new(
            4,
            4,
            100,
            vec![ev(1, 0, 0, true), ev(2, 0, 0, true), ev(3, 0, 0, true)],
        )
        .unwrap();
        let frames = encode_frames(&s, 1);
        assert_eq!(frames[0].count(), 1, "single-bit buffer semantics");
    }

    #[test]
    fn empty_stream_encodes_to_empty_frames() {
        let s = EventStream::new(4, 4, 100, vec![]).unwrap();
        let frames = encode_frames(&s, 4);
        assert_eq!(frames.len(), 4);
        assert!(frames.iter().all(|f| f.count() == 0));
        assert!(frames.iter().all(|f| (f.sparsity() - 1.0).abs() < 1e-12));
    }

    #[test]
    fn all_events_at_stream_end_land_in_last_frame() {
        // t_us == duration_us is a valid timestamp; the tail-absorbing last
        // frame must own it for every frame count.
        let evs = vec![ev(100, 0, 0, true), ev(100, 1, 1, false), ev(100, 2, 2, true)];
        let s = EventStream::new(4, 4, 100, evs).unwrap();
        for timesteps in [1usize, 2, 3, 16] {
            let frames = encode_frames(&s, timesteps);
            assert_eq!(frames.len(), timesteps);
            for f in &frames[..timesteps - 1] {
                assert_eq!(f.count(), 0, "{timesteps} steps: early frame empty");
            }
            assert_eq!(frames[timesteps - 1].count(), 3, "{timesteps} steps: tail owns all");
        }
    }

    #[test]
    fn duplicate_timestamps_collapse_per_slot_not_per_time() {
        // Three events share t=10: two on the same (pixel, polarity) slot
        // collapse, the third targets another pixel and survives.
        let evs = vec![ev(10, 0, 0, true), ev(10, 0, 0, true), ev(10, 3, 3, true)];
        let s = EventStream::new(4, 4, 100, evs).unwrap();
        let frames = encode_frames(&s, 1);
        assert_eq!(frames[0].count(), 2);
        assert!(frames[0].get(0, 0, 0) && frames[0].get(0, 3, 3));
    }

    #[test]
    fn out_of_order_arrival_encodes_identically_to_sorted() {
        // EventStream::new sorts, so heavily out-of-order client input must
        // produce the same frames as the time-ordered stream.
        let ordered: Vec<DvsEvent> =
            (0..50).map(|i| ev(i * 2, (i % 4) as u16, ((i / 4) % 4) as u16, i % 2 == 0)).collect();
        let mut shuffled = ordered.clone();
        shuffled.reverse();
        shuffled.swap(3, 41);
        shuffled.swap(0, 25);
        let a = EventStream::new(4, 4, 100, ordered).unwrap();
        let b = EventStream::new(4, 4, 100, shuffled).unwrap();
        let fa = encode_frames(&a, 8);
        let fb = encode_frames(&b, 8);
        for (x, y) in fa.iter().zip(&fb) {
            assert_eq!(x.bits, y.bits);
        }
    }

    #[test]
    fn spike_list_matches_dense_bits() {
        let g = GestureGenerator::default_48();
        let mut rng = Rng::new(4);
        let s = g.sample(GestureClass::LeftCw, &mut rng);
        for f in encode_frames(&s, 8) {
            let sl = f.to_spike_list();
            assert_eq!(sl.dim(), f.bits.len());
            assert_eq!(sl.count(), f.count());
            assert_eq!(sl.to_dense(), f.bits);
        }
    }

    #[test]
    fn sparse_encoder_matches_dense_encoder() {
        // The fully sparse path must reproduce the dense path's binning,
        // polarity channels, and duplicate collapse exactly, for every
        // timestep count including the tail-absorbing last frame.
        let g = GestureGenerator::default_48();
        for seed in [1u64, 7, 23] {
            let mut rng = Rng::new(seed);
            let s = g.sample(GestureClass::ALL[seed as usize % GestureClass::ALL.len()], &mut rng);
            for ts in [1usize, 5, 16] {
                let dense = encode_frames(&s, ts);
                let sparse = encode_frames_sparse(&s, ts);
                assert_eq!(dense.len(), sparse.len());
                for (d, sp) in dense.iter().zip(&sparse) {
                    assert_eq!(d.to_spike_list(), *sp, "seed {seed} ts {ts}");
                }
            }
        }
    }

    #[test]
    fn sparse_encoder_collapses_duplicates_and_binds_tail() {
        // The synthetic edge cases the dense tests pin, on the sparse path.
        let dup = EventStream::new(
            4,
            4,
            100,
            vec![ev(10, 0, 0, true), ev(10, 0, 0, true), ev(10, 3, 3, true)],
        )
        .unwrap();
        let frames = encode_frames_sparse(&dup, 1);
        assert_eq!(frames[0].count(), 2, "same-slot events collapse");

        let tail = EventStream::new(4, 4, 100, vec![ev(100, 2, 2, false)]).unwrap();
        let frames = encode_frames_sparse(&tail, 4);
        assert!(frames[..3].iter().all(SpikeList::is_empty));
        assert_eq!(frames[3].count(), 1, "t == duration lands in last frame");
        // OFF polarity is channel 1: slot = 1*16 + 2*4 + 2.
        assert_eq!(frames[3].active(), &[16 + 10]);
    }

    #[test]
    fn spike_frame_roundtrips_through_spike_list() {
        let g = GestureGenerator::default_48();
        let mut rng = Rng::new(12);
        let s = g.sample(GestureClass::HandClap, &mut rng);
        for f in encode_frames(&s, 8) {
            let back = SpikeFrame::from_spike_list(f.width, f.height, &f.to_spike_list());
            assert_eq!(back.bits, f.bits);
        }
    }

    #[test]
    #[should_panic(expected = "does not match the frame geometry")]
    fn from_spike_list_rejects_wrong_dim() {
        let _ = SpikeFrame::from_spike_list(4, 4, &SpikeList::empty(7));
    }

    #[test]
    fn bit_plane_frame_roundtrips() {
        let g = GestureGenerator::default_48();
        let mut rng = Rng::new(3);
        let s = g.sample(GestureClass::LeftCw, &mut rng);
        for f in encode_frames(&s, 6) {
            let p = BitPlaneFrame::from_spike_frame(&f);
            assert_eq!(p.dim(), f.bits.len());
            assert_eq!(p.count(), f.count(), "popcount == dense count");
            assert_eq!(p.to_spike_list(), f.to_spike_list());
            assert_eq!(p.buffer_bytes(), f.buffer_bytes(), "48×48 is word-aligned");
        }
    }

    #[test]
    fn bit_plane_frame_set_get_clear() {
        let mut p = BitPlaneFrame::new(48, 48);
        assert_eq!(p.dim(), 4608);
        assert_eq!(p.words().len(), 72);
        p.set(0, 5, 7);
        p.set(1, 47, 0);
        assert!(p.get(0, 5, 7));
        assert!(p.get(1, 47, 0));
        assert!(!p.get(0, 5, 8));
        assert_eq!(p.count(), 2);
        // Unpacked order is sorted slot order.
        let sl = p.to_spike_list();
        assert_eq!(sl.active(), &[7 * 48 + 5, 2304 + 47]);
        p.clear();
        assert_eq!(p.count(), 0);
        assert_eq!(p.words().len(), 72, "clear keeps the buffer");
    }

    #[test]
    fn input_vector_layout_is_channel_major() {
        let mut f = SpikeFrame::new(3, 2);
        f.set(1, 2, 1); // OFF channel, x=2, y=1
        let v = f.as_input_vector();
        assert_eq!(v.len(), 12);
        assert!(v[6 + 1 * 3 + 2]);
        assert_eq!(v.iter().filter(|&&b| b).count(), 1);
    }

    #[test]
    fn buffer_footprint_matches_chip_scale() {
        // 128×128 sensor: 2 × 128 × 128 bits = 4 kB — the chip's 4.25-kB
        // buffer (with control overhead).
        let f = SpikeFrame::new(128, 128);
        assert_eq!(f.buffer_bytes(), 4096);
        let f48 = SpikeFrame::new(48, 48);
        assert_eq!(f48.buffer_bytes(), 576);
    }

    #[test]
    fn gesture_frames_match_network_input() {
        let g = GestureGenerator::default_48();
        let mut rng = Rng::new(1);
        let s = g.sample(GestureClass::HandClap, &mut rng);
        let frames = encode_frames(&s, 16);
        assert_eq!(frames.len(), 16);
        // The SCNN input layer expects 2×48×48 = 4608 inputs.
        assert_eq!(frames[0].as_input_vector().len(), 4608);
        // Mid-gesture frames carry signal.
        assert!(frames[8].count() > 0);
    }

    #[test]
    fn frame_sparsity_consistent_with_stream_sparsity() {
        let g = GestureGenerator::default_48();
        let mut rng = Rng::new(9);
        let s = g.sample(GestureClass::RightCw, &mut rng);
        let frames = encode_frames(&s, 16);
        let mean_frame_sparsity: f64 =
            frames.iter().map(SpikeFrame::sparsity).sum::<f64>() / frames.len() as f64;
        let stream_sparsity = s.sparsity(s.duration_us / 16);
        assert!(
            (mean_frame_sparsity - stream_sparsity).abs() < 0.02,
            "frame {mean_frame_sparsity:.4} vs stream {stream_sparsity:.4}"
        );
    }
}
