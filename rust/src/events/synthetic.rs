//! Synthetic DVS gesture generator (substitute for IBM DVS Gesture [1]).
//!
//! Each of the ten classes is a parametric spatio-temporal motion of a
//! bright blob (plus a static noise floor). A moving edge produces ON
//! events on its leading side and OFF events on its trailing side, which
//! is what a real DVS emits; the per-class trajectories differ in
//! direction, curvature and frequency so a spiking CNN must integrate
//! motion over time to classify them — the same computational task as the
//! real dataset, at the same controllable sparsity.

use super::dvs::{DvsEvent, EventStream};
use crate::util::rng::Rng;

/// Ten gesture classes, mirroring the IBM set's structure (10-class
/// variant, Table I footnote b).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GestureClass {
    /// Both-hands oscillation toward the center.
    HandClap = 0,
    /// Right-hand horizontal wave.
    RightWave = 1,
    /// Left-hand horizontal wave.
    LeftWave = 2,
    /// Right-hand clockwise circle.
    RightCw = 3,
    /// Right-hand counter-clockwise circle.
    RightCcw = 4,
    /// Left-hand clockwise circle.
    LeftCw = 5,
    /// Left-hand counter-clockwise circle.
    LeftCcw = 6,
    /// Forearm roll: large slow circle.
    ArmRoll = 7,
    /// Air drums: two blobs in vertical anti-phase.
    AirDrums = 8,
    /// Air guitar: diagonal strum oscillation.
    AirGuitar = 9,
}

impl GestureClass {
    /// All classes in label order.
    pub const ALL: [GestureClass; 10] = [
        GestureClass::HandClap,
        GestureClass::RightWave,
        GestureClass::LeftWave,
        GestureClass::RightCw,
        GestureClass::RightCcw,
        GestureClass::LeftCw,
        GestureClass::LeftCcw,
        GestureClass::ArmRoll,
        GestureClass::AirDrums,
        GestureClass::AirGuitar,
    ];

    /// Class from a label index.
    pub fn from_label(label: usize) -> GestureClass {
        Self::ALL[label]
    }

    /// Integer label.
    pub fn label(self) -> usize {
        self as usize
    }

    /// Blob center(s) at normalized time `t ∈ [0, 1)`, in normalized
    /// sensor coordinates `[0, 1]²`.
    fn centers(self, t: f64) -> Vec<(f64, f64)> {
        use std::f64::consts::TAU;
        let osc = (TAU * 3.0 * t).sin(); // three periods per sample
        match self {
            GestureClass::HandClap => vec![
                (0.5 - 0.25 * osc.abs(), 0.5),
                (0.5 + 0.25 * osc.abs(), 0.5),
            ],
            GestureClass::RightWave => vec![(0.7 + 0.18 * osc, 0.35)],
            GestureClass::LeftWave => vec![(0.3 + 0.18 * osc, 0.35)],
            GestureClass::RightCw => {
                let a = TAU * 2.0 * t;
                vec![(0.65 + 0.18 * a.cos(), 0.5 - 0.18 * a.sin())]
            }
            GestureClass::RightCcw => {
                let a = TAU * 2.0 * t;
                vec![(0.65 + 0.18 * a.cos(), 0.5 + 0.18 * a.sin())]
            }
            GestureClass::LeftCw => {
                let a = TAU * 2.0 * t;
                vec![(0.35 + 0.18 * a.cos(), 0.5 - 0.18 * a.sin())]
            }
            GestureClass::LeftCcw => {
                let a = TAU * 2.0 * t;
                vec![(0.35 + 0.18 * a.cos(), 0.5 + 0.18 * a.sin())]
            }
            GestureClass::ArmRoll => {
                let a = TAU * 1.0 * t;
                vec![(0.5 + 0.3 * a.cos(), 0.5 + 0.3 * a.sin())]
            }
            GestureClass::AirDrums => vec![
                (0.35, 0.5 + 0.2 * osc),
                (0.65, 0.5 - 0.2 * osc),
            ],
            GestureClass::AirGuitar => vec![(0.5 + 0.15 * osc, 0.6 + 0.15 * osc)],
        }
    }
}

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct GestureGenerator {
    /// Sensor width (pixels).
    pub width: u16,
    /// Sensor height (pixels).
    pub height: u16,
    /// Sample duration in microseconds.
    pub duration_us: u64,
    /// Number of frames the motion is discretized into internally.
    pub motion_steps: usize,
    /// Blob radius in normalized units.
    pub blob_radius: f64,
    /// Per-pixel event probability on the blob's moving edge per motion
    /// step (controls foreground density).
    pub edge_event_prob: f64,
    /// Background noise events per pixel per second.
    pub noise_rate_hz: f64,
}

impl GestureGenerator {
    /// Defaults matched to the SCNN workload: 48×48 sensor, 16 motion
    /// steps over 100 ms, ~95 % sparsity at 6.25-ms timesteps.
    pub fn default_48() -> Self {
        GestureGenerator {
            width: 48,
            height: 48,
            duration_us: 100_000,
            motion_steps: 64,
            blob_radius: 0.10,
            edge_event_prob: 0.55,
            noise_rate_hz: 2.0,
        }
    }

    /// Generate one labeled sample.
    pub fn sample(&self, class: GestureClass, rng: &mut Rng) -> EventStream {
        let mut events = Vec::new();
        let w = self.width as f64;
        let h = self.height as f64;
        let step_us = self.duration_us / self.motion_steps as u64;

        let mut prev: Vec<(f64, f64)> = class.centers(0.0);
        for step in 1..self.motion_steps {
            let t = step as f64 / self.motion_steps as f64;
            let centers = class.centers(t);
            let t_us = step as u64 * step_us;
            for (ci, &(cx, cy)) in centers.iter().enumerate() {
                let (px, py) = prev[ci.min(prev.len() - 1)];
                let (dx, dy) = (cx - px, cy - py);
                let speed = (dx * dx + dy * dy).sqrt();
                if speed < 1e-9 {
                    continue;
                }
                // Emit ON events on the leading edge, OFF on the trailing
                // edge of the moving disc.
                let r = self.blob_radius;
                let x_lo = ((cx - r) * w).floor().max(0.0) as i64;
                let x_hi = ((cx + r) * w).ceil().min(w - 1.0) as i64;
                let y_lo = ((cy - r) * h).floor().max(0.0) as i64;
                let y_hi = ((cy + r) * h).ceil().min(h - 1.0) as i64;
                for px_i in x_lo..=x_hi {
                    for py_i in y_lo..=y_hi {
                        let nx = (px_i as f64 + 0.5) / w - cx;
                        let ny = (py_i as f64 + 0.5) / h - cy;
                        let d = (nx * nx + ny * ny).sqrt();
                        if d > r || d < r * 0.55 {
                            continue; // only the rim produces edge events
                        }
                        // Dot product with motion direction decides
                        // leading (ON) vs trailing (OFF) side.
                        let along = (nx * dx + ny * dy) / (d * speed);
                        if rng.chance(self.edge_event_prob * along.abs()) {
                            let jitter = rng.below(step_us.max(1));
                            events.push(DvsEvent {
                                t_us: (t_us + jitter).min(self.duration_us),
                                x: px_i as u16,
                                y: py_i as u16,
                                polarity: along > 0.0,
                            });
                        }
                    }
                }
            }
            prev = centers;
        }

        // Uniform background noise.
        let expected_noise = self.noise_rate_hz
            * (self.width as f64 * self.height as f64)
            * (self.duration_us as f64 * 1e-6);
        let n_noise = rng.poisson(expected_noise);
        for _ in 0..n_noise {
            events.push(DvsEvent {
                t_us: rng.below(self.duration_us),
                x: rng.below(self.width as u64) as u16,
                y: rng.below(self.height as u64) as u16,
                polarity: rng.chance(0.5),
            });
        }

        EventStream::new(self.width, self.height, self.duration_us, events)
            .expect("generator emits only in-bounds, in-range events")
    }

    /// Generate a labeled dataset: `per_class` samples of every class.
    pub fn dataset(&self, per_class: usize, rng: &mut Rng) -> Vec<(EventStream, usize)> {
        let mut out = Vec::new();
        for class in GestureClass::ALL {
            for _ in 0..per_class {
                out.push((self.sample(class, rng), class.label()));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_roundtrip() {
        for (i, c) in GestureClass::ALL.iter().enumerate() {
            assert_eq!(c.label(), i);
            assert_eq!(GestureClass::from_label(i), *c);
        }
    }

    #[test]
    fn samples_are_nonempty_and_in_bounds() {
        let g = GestureGenerator::default_48();
        let mut rng = Rng::new(7);
        for class in GestureClass::ALL {
            let s = g.sample(class, &mut rng);
            assert!(
                s.events.len() > 100,
                "{class:?} produced only {} events",
                s.events.len()
            );
            assert!(s.events.iter().all(|e| e.x < 48 && e.y < 48));
        }
    }

    #[test]
    fn sparsity_in_papers_sweep_range() {
        // Default parameters must land inside the paper's 85–99 % band at
        // the SNN timestep (duration / 16).
        let g = GestureGenerator::default_48();
        let mut rng = Rng::new(3);
        for class in [GestureClass::HandClap, GestureClass::ArmRoll, GestureClass::RightCw] {
            let s = g.sample(class, &mut rng);
            let sp = s.sparsity(g.duration_us / 16);
            assert!(
                (0.85..0.995).contains(&sp),
                "{class:?}: sparsity {sp:.4} outside 85-99 %"
            );
        }
    }

    #[test]
    fn classes_are_visually_distinct() {
        // Mean event position and polarity balance must differ between a
        // right-hand and a left-hand gesture — otherwise the classification
        // task would be degenerate.
        let g = GestureGenerator::default_48();
        let mut rng = Rng::new(11);
        let mean_x = |c: GestureClass, rng: &mut Rng| {
            let s = g.sample(c, rng);
            s.events.iter().map(|e| e.x as f64).sum::<f64>() / s.events.len() as f64
        };
        let rx = mean_x(GestureClass::RightWave, &mut rng);
        let lx = mean_x(GestureClass::LeftWave, &mut rng);
        assert!(rx > lx + 5.0, "right {rx:.1} vs left {lx:.1}");
    }

    #[test]
    fn circular_classes_differ_by_rotation_direction() {
        // CW vs CCW must differ in the phase relation between x and y
        // motion; test via the sign of the cross-correlation of event
        // centroid displacement.
        let g = GestureGenerator::default_48();
        let mut rng = Rng::new(5);
        let rotation_sign = |c: GestureClass, rng: &mut Rng| {
            let s = g.sample(c, rng);
            let step = g.duration_us / 16;
            let centroids: Vec<(f64, f64)> = (0..16)
                .map(|i| {
                    let w = s.window(i * step, (i + 1) * step);
                    if w.is_empty() {
                        return (0.0, 0.0);
                    }
                    let n = w.len() as f64;
                    (
                        w.iter().map(|e| e.x as f64).sum::<f64>() / n,
                        w.iter().map(|e| e.y as f64).sum::<f64>() / n,
                    )
                })
                .collect();
            let mut cross = 0.0;
            for i in 1..centroids.len() - 1 {
                let (dx0, dy0) = (
                    centroids[i].0 - centroids[i - 1].0,
                    centroids[i].1 - centroids[i - 1].1,
                );
                let (dx1, dy1) = (
                    centroids[i + 1].0 - centroids[i].0,
                    centroids[i + 1].1 - centroids[i].1,
                );
                cross += dx0 * dy1 - dy0 * dx1;
            }
            cross
        };
        let cw = rotation_sign(GestureClass::RightCw, &mut rng);
        let ccw = rotation_sign(GestureClass::RightCcw, &mut rng);
        assert!(
            cw * ccw < 0.0,
            "rotation directions must have opposite signs: {cw:.2} vs {ccw:.2}"
        );
    }

    #[test]
    fn dataset_shape() {
        let g = GestureGenerator {
            motion_steps: 16,
            ..GestureGenerator::default_48()
        };
        let mut rng = Rng::new(1);
        let d = g.dataset(2, &mut rng);
        assert_eq!(d.len(), 20);
        assert_eq!(d.iter().filter(|(_, l)| *l == 0).count(), 2);
    }

    #[test]
    fn deterministic_given_seed() {
        let g = GestureGenerator::default_48();
        let s1 = g.sample(GestureClass::ArmRoll, &mut Rng::new(42));
        let s2 = g.sample(GestureClass::ArmRoll, &mut Rng::new(42));
        assert_eq!(s1.events.len(), s2.events.len());
        assert_eq!(s1.events.first(), s2.events.first());
    }
}
