//! Event-based vision substrate.
//!
//! The paper evaluates on the IBM DVS gesture dataset [1], which cannot be
//! redistributed here; this module provides the documented substitution
//! (DESIGN.md §Substitutions): a parametric generator of DVS-like event
//! streams for ten gesture classes — moving/rotating/oscillating blobs
//! with Poisson noise — plus the event→spike-frame encoder that feeds the
//! SNN per timestep (paper Fig. 1a/c). Sparsity is controllable across the
//! 85–99 % range the paper sweeps.

pub mod dvs;
pub mod encoder;
pub mod synthetic;

pub use dvs::{DvsEvent, EventStream};
pub use encoder::{encode_frames, encode_frames_sparse, BitPlaneFrame, SpikeFrame};
pub use synthetic::{GestureClass, GestureGenerator};
