//! `weights.bin` reader and the bit-exact quantizer mirror.
//!
//! Format (little-endian, written by python/compile/train.py):
//! `"FSPW"`, `i32 n_layers`, then per layer: `i32 name_len`, name bytes,
//! `i32 w_bits`, `i32 p_bits`, `i32 ndim`, dims, `f32` data.
//!
//! Quantization must be bit-identical to `model.quantize_params`:
//! float32 scale `max|W| / (2^(w_bits−1) − 1)`, round-half-away-from-zero
//! (Rust's `f32::round`), `theta = round(1/scale)` clamped to the p_bits
//! range. The cross-check golden (`golden/quantize_check.txt`) pins both
//! implementations to the same integers.

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};
use byteorder::{LittleEndian, ReadBytesExt};

/// One layer's float weights plus its default resolution.
#[derive(Debug, Clone)]
pub struct LayerWeights {
    /// Layer name (`"L1"` … `"FC3"`).
    pub name: String,
    /// Default weight bit-width from the model description.
    pub w_bits: u32,
    /// Default membrane bit-width.
    pub p_bits: u32,
    /// Tensor dims (e.g. `[out_ch, in_ch, k, k]` or `[out, in]`).
    pub dims: Vec<usize>,
    /// Row-major float32 data.
    pub data: Vec<f32>,
}

impl LayerWeights {
    /// Number of weights.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if empty (never for valid files).
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Quantize to `w_bits`/`p_bits`, returning `(int_weights, qparams)`
    /// where qparams = (modulus, half, theta) as i32 — bit-identical to
    /// the Python quantizer.
    pub fn quantize(&self, w_bits: u32, p_bits: u32) -> (Vec<i32>, [i32; 3]) {
        let max_q = ((1i64 << (w_bits - 1)) - 1).max(1) as f32;
        let maxabs = self.data.iter().fold(0f32, |m, &v| m.max(v.abs()));
        let scale = (maxabs / max_q).max(1e-12);
        let lo = -(max_q as i32) - 1;
        let hi = max_q as i32;
        let q: Vec<i32> = self
            .data
            .iter()
            .map(|&v| ((v / scale).round() as i32).clamp(lo, hi))
            .collect();
        let theta_max = (1i64 << (p_bits - 1)) - 1;
        let theta = ((1.0 / scale).round() as i64).clamp(1, theta_max) as i32;
        let m = 1i32 << p_bits;
        let half = 1i32 << (p_bits - 1);
        (q, [m, half, theta])
    }
}

/// A parsed weights file.
#[derive(Debug, Clone)]
pub struct WeightFile {
    /// Layers in network order.
    pub layers: Vec<LayerWeights>,
}

impl WeightFile {
    /// Read and validate a weights file.
    pub fn load(path: &Path) -> Result<WeightFile> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"FSPW" {
            bail!("{}: bad magic {:?}", path.display(), magic);
        }
        let n = f.read_i32::<LittleEndian>()?;
        if !(1..=64).contains(&n) {
            bail!("implausible layer count {n}");
        }
        let mut layers = Vec::with_capacity(n as usize);
        for _ in 0..n {
            let name_len = f.read_i32::<LittleEndian>()? as usize;
            if name_len > 64 {
                bail!("implausible name length {name_len}");
            }
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let w_bits = f.read_i32::<LittleEndian>()? as u32;
            let p_bits = f.read_i32::<LittleEndian>()? as u32;
            let ndim = f.read_i32::<LittleEndian>()? as usize;
            if ndim > 8 {
                bail!("implausible rank {ndim}");
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(f.read_i32::<LittleEndian>()? as usize);
            }
            let count: usize = dims.iter().product();
            let mut data = vec![0f32; count];
            f.read_f32_into::<LittleEndian>(&mut data)?;
            layers.push(LayerWeights {
                name: String::from_utf8(name)?,
                w_bits,
                p_bits,
                dims,
                data,
            });
        }
        Ok(WeightFile { layers })
    }

    /// Quantize every layer at its default resolution.
    pub fn quantize_default(&self) -> (Vec<Vec<i32>>, Vec<[i32; 3]>) {
        self.layers
            .iter()
            .map(|l| l.quantize(l.w_bits, l.p_bits))
            .unzip()
    }

    /// Quantize every layer at explicit per-layer resolutions.
    pub fn quantize_at(&self, res: &[(u32, u32)]) -> (Vec<Vec<i32>>, Vec<[i32; 3]>) {
        assert_eq!(res.len(), self.layers.len());
        self.layers
            .iter()
            .zip(res)
            .map(|(l, &(w, p))| l.quantize(w, p))
            .unzip()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_layer(data: Vec<f32>) -> LayerWeights {
        LayerWeights {
            name: "T".into(),
            w_bits: 4,
            p_bits: 9,
            dims: vec![data.len()],
            data,
        }
    }

    #[test]
    fn quantize_basic() {
        // max|w| = 0.7, w_bits = 4 -> max_q = 7, scale = 0.1.
        let l = fake_layer(vec![0.7, -0.7, 0.35, 0.04, -0.06]);
        let (q, [m, half, theta]) = l.quantize(4, 9);
        assert_eq!(q, vec![7, -7, 4, 0, -1]); // 0.35/0.1=3.5 -> half-away = 4
        assert_eq!(m, 512);
        assert_eq!(half, 256);
        assert_eq!(theta, 10); // round(1/0.1)
    }

    #[test]
    fn quantize_half_away_from_zero() {
        // 0.25/0.1... construct scale exactly: max 0.5 at 2 bits -> max_q=1,
        // scale 0.5; 0.25/0.5 = 0.5 -> rounds to 1 (away from zero), and
        // -0.25 -> -1 (clamped to lo = -2? no, -1 is in range).
        let l = fake_layer(vec![0.5, 0.25, -0.25]);
        let (q, _) = l.quantize(2, 6);
        assert_eq!(q, vec![1, 1, -1]);
    }

    #[test]
    fn theta_clamped_to_p_range() {
        // Tiny weights -> huge 1/scale -> theta clamps to 2^(p-1)-1.
        let l = fake_layer(vec![1e-6, -1e-6]);
        let (_, [_, _, theta]) = l.quantize(4, 6);
        assert_eq!(theta, 31);
    }

    #[test]
    fn loads_shipped_weights_and_matches_golden() {
        let dir = crate::runtime::artifacts_dir();
        let wpath = dir.join("weights.bin");
        let gpath = dir.join("golden/quantize_check.txt");
        if !wpath.exists() || !gpath.exists() {
            crate::log_warn!("skipping: artifacts not built");
            return;
        }
        let wf = WeightFile::load(&wpath).unwrap();
        assert_eq!(wf.layers.len(), 9);
        assert_eq!(wf.layers[0].name, "L1");
        assert_eq!(wf.layers[0].dims, vec![12, 2, 3, 3]);

        // Golden cross-check: python and rust quantizers must produce
        // identical integers (checksums per layer).
        let text = std::fs::read_to_string(&gpath).unwrap();
        let mut lines = text.lines();
        let n: usize = lines.next().unwrap().trim().parse().unwrap();
        assert_eq!(n, wf.layers.len());
        let (qs, qparams) = wf.quantize_default();
        for (i, line) in lines.enumerate() {
            let v: Vec<i64> = line
                .split_whitespace()
                .map(|t| t.parse().unwrap())
                .collect();
            let [m, half, theta] = qparams[i];
            assert_eq!(v[0], m as i64, "layer {i} modulus");
            assert_eq!(v[1], half as i64, "layer {i} half");
            assert_eq!(v[2], theta as i64, "layer {i} theta");
            let q = &qs[i];
            let sum: i64 = q.iter().map(|&x| x as i64).sum();
            let abssum: i64 = q.iter().map(|&x| (x as i64).abs()).sum();
            let min = *q.iter().min().unwrap() as i64;
            let max = *q.iter().max().unwrap() as i64;
            assert_eq!(v[3], sum, "layer {i} sum");
            assert_eq!(v[4], abssum, "layer {i} abssum");
            assert_eq!(v[5], min, "layer {i} min");
            assert_eq!(v[6], max, "layer {i} max");
        }
    }
}
