//! Typed wrapper around the training-step artifact.
//!
//! `train_step.hlo.txt` signature (lowered by compile/aot.py):
//! `(p1..p9 f32, m1..m9 f32, frames f32[B,16,2,48,48], labels i32[B],
//!   lr f32)` → `(p1'..p9', m1'..m9', loss f32, acc f32)`.
//!
//! The Rust driver owns the parameter/momentum buffers and feeds
//! synthetic gesture batches — end-to-end training with Python nowhere on
//! the path (examples/train_snn.rs).

use std::path::Path;

use anyhow::{ensure, Context, Result};

use super::client::{lit_f32, lit_f32_scalar, lit_i32, to_vec_f32, Executable, Runtime};
use super::weights::{LayerWeights, WeightFile};
use crate::events::{encode_frames, GestureClass, GestureGenerator};
use crate::util::rng::Rng;

/// Batch size baked into the artifact by compile/aot.py.
pub const TRAIN_BATCH: usize = 4;
/// Timesteps per sample.
pub const TRAIN_TIMESTEPS: usize = 16;

/// One training step's outcome.
#[derive(Debug, Clone, Copy)]
pub struct TrainMetrics {
    /// Cross-entropy loss.
    pub loss: f32,
    /// Batch accuracy.
    pub accuracy: f32,
}

/// Compiled trainer holding parameters and momentum host-side.
pub struct TrainRunner {
    exe: Executable,
    /// Float parameters per layer.
    pub params: Vec<Vec<f32>>,
    momentum: Vec<Vec<f32>>,
    dims: Vec<Vec<i64>>,
    names: Vec<String>,
    resolutions: Vec<(u32, u32)>,
}

impl TrainRunner {
    /// Load artifact + initial weights from `dir` and compile.
    pub fn load(rt: &Runtime, dir: &Path) -> Result<Self> {
        let exe = rt.load_hlo(&dir.join("train_step.hlo.txt"))?;
        let wf = WeightFile::load(&dir.join("weights.bin"))?;
        let dims = wf
            .layers
            .iter()
            .map(|l| l.dims.iter().map(|&d| d as i64).collect())
            .collect();
        let names = wf.layers.iter().map(|l| l.name.clone()).collect();
        let resolutions = wf.layers.iter().map(|l| (l.w_bits, l.p_bits)).collect();
        let momentum = wf.layers.iter().map(|l| vec![0f32; l.len()]).collect();
        let params = wf.layers.into_iter().map(|l| l.data).collect();
        Ok(TrainRunner { exe, params, momentum, dims, names, resolutions })
    }

    /// One SGD step on a batch. `frames` is `[B][T][2*48*48]` flattened to
    /// `B*T*2*48*48` f32 values; `labels` has `B` entries.
    pub fn step(&mut self, frames: &[f32], labels: &[i32], lr: f32) -> Result<TrainMetrics> {
        let b = TRAIN_BATCH;
        ensure!(labels.len() == b, "batch must be {b}");
        ensure!(
            frames.len() == b * TRAIN_TIMESTEPS * 2 * 48 * 48,
            "frames length mismatch"
        );
        let n = self.params.len();
        let mut inputs = Vec::with_capacity(2 * n + 3);
        for (p, d) in self.params.iter().zip(&self.dims) {
            inputs.push(lit_f32(p, d)?);
        }
        for (m, d) in self.momentum.iter().zip(&self.dims) {
            inputs.push(lit_f32(m, d)?);
        }
        inputs.push(lit_f32(
            frames,
            &[b as i64, TRAIN_TIMESTEPS as i64, 2, 48, 48],
        )?);
        inputs.push(lit_i32(labels, &[b as i64])?);
        inputs.push(lit_f32_scalar(lr));

        let out = self.exe.run(&inputs).context("train_step execution")?;
        ensure!(out.len() == 2 * n + 2, "expected {} outputs", 2 * n + 2);
        for i in 0..n {
            self.params[i] = to_vec_f32(&out[i])?;
            self.momentum[i] = to_vec_f32(&out[n + i])?;
        }
        let loss = to_vec_f32(&out[2 * n])?[0];
        let accuracy = to_vec_f32(&out[2 * n + 1])?[0];
        Ok(TrainMetrics { loss, accuracy })
    }

    /// Export the current parameters as a [`WeightFile`] (so the
    /// inference runner can quantize and use them).
    pub fn to_weight_file(&self) -> WeightFile {
        let layers = self
            .params
            .iter()
            .zip(&self.dims)
            .zip(self.names.iter().zip(&self.resolutions))
            .map(|((data, dims), (name, &(w_bits, p_bits)))| LayerWeights {
                name: name.clone(),
                w_bits,
                p_bits,
                dims: dims.iter().map(|&d| d as usize).collect(),
                data: data.clone(),
            })
            .collect();
        WeightFile { layers }
    }
}

/// Generate one training batch from the synthetic gesture substrate:
/// returns `(frames f32 flat, labels)`.
pub fn synth_batch(gen: &GestureGenerator, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
    let mut frames = Vec::with_capacity(TRAIN_BATCH * TRAIN_TIMESTEPS * 2 * 48 * 48);
    let mut labels = Vec::with_capacity(TRAIN_BATCH);
    for _ in 0..TRAIN_BATCH {
        let label = rng.below(10) as usize;
        let stream = gen.sample(GestureClass::from_label(label), rng);
        let fs = encode_frames(&stream, TRAIN_TIMESTEPS);
        for f in &fs {
            frames.extend(f.as_input_vector().iter().map(|&b| b as u8 as f32));
        }
        labels.push(label as i32);
    }
    (frames, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_batch_shapes() {
        let gen = GestureGenerator::default_48();
        let mut rng = Rng::new(1);
        let (frames, labels) = synth_batch(&gen, &mut rng);
        assert_eq!(frames.len(), TRAIN_BATCH * TRAIN_TIMESTEPS * 2 * 48 * 48);
        assert_eq!(labels.len(), TRAIN_BATCH);
        assert!(labels.iter().all(|&l| (0..10).contains(&l)));
        assert!(frames.iter().all(|&v| v == 0.0 || v == 1.0));
        let active: f32 = frames.iter().sum();
        assert!(active > 100.0, "batch must contain spikes");
    }
}
