//! PJRT runtime: load and execute the AOT HLO artifacts.
//!
//! Python runs once at build time (`make artifacts`); from then on this
//! module is the only bridge to the compute graphs. HLO *text* is the
//! interchange format — jax ≥ 0.5 serializes protos with 64-bit ids that
//! xla_extension 0.5.1 rejects, while the text parser reassigns ids
//! (see /opt/xla-example/README.md and DESIGN.md).
//!
//! * [`backend`] — the [`StepBackend`] trait the coordinator and the
//!   parallel engine execute against (PJRT or pure Rust).
//! * [`native`] — artifact-free, `Send`, deterministic pure-Rust backend
//!   over the golden LIF/conv models.
//! * [`client`] — thin wrapper over `xla::PjRtClient` + compiled
//!   executables with typed int32/f32 literal helpers.
//! * [`weights`] — reader for `artifacts/weights.bin` (float32 weights)
//!   and the bit-exact mirror of the Python post-training quantizer.
//! * [`scnn`] — typed wrapper around `scnn_step.hlo.txt`: runtime-dynamic
//!   resolution, membrane state threading, per-layer spike counts.
//! * [`trainer`] — typed wrapper around `train_step.hlo.txt` for the
//!   end-to-end Rust-driven training example.

pub mod backend;
pub mod client;
pub mod native;
pub mod scnn;
pub mod trainer;
pub mod weights;

pub use backend::{StateSnapshot, StepBackend, StepResult};
pub use client::{Executable, Runtime};
pub use native::NativeScnn;
pub use scnn::ScnnRunner;
pub use trainer::TrainRunner;
pub use weights::{LayerWeights, WeightFile};

use std::path::{Path, PathBuf};

/// Locate the artifacts directory: `$FLEXSPIM_ARTIFACTS`, else
/// `./artifacts`, else `../artifacts` (when run from `rust/`).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("FLEXSPIM_ARTIFACTS") {
        return PathBuf::from(p);
    }
    for cand in ["artifacts", "../artifacts"] {
        let p = Path::new(cand);
        if p.join("scnn_step.hlo.txt").exists() {
            return p.to_path_buf();
        }
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_resolves() {
        let d = artifacts_dir();
        assert!(d.to_string_lossy().contains("artifacts"));
    }
}
