//! PJRT client + executable wrappers.
//!
//! Adapted from /opt/xla-example/load_hlo: CPU client, HLO-text →
//! `HloModuleProto` → compile → execute. Executables are compiled once
//! and reused on the hot path; Python never runs at request time.

use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT runtime handle (one CPU client per process is plenty).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// Platform string (e.g. `"Host"`), for diagnostics.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

/// A compiled computation plus conversion helpers.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Source artifact (diagnostics).
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; returns the flattened tuple elements
    /// (artifacts are lowered with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple()?;
        Ok(out)
    }
}

/// Build an int32 literal with the given logical dims.
pub fn lit_i32(values: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == values.len(), "dims {:?} != len {}", dims, values.len());
    Ok(xla::Literal::vec1(values).reshape(dims)?)
}

/// Build an f32 literal with the given logical dims.
pub fn lit_f32(values: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == values.len(), "dims {:?} != len {}", dims, values.len());
    Ok(xla::Literal::vec1(values).reshape(dims)?)
}

/// Build a scalar f32 literal.
pub fn lit_f32_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract an i32 vector from a literal.
pub fn to_vec_i32(l: &xla::Literal) -> Result<Vec<i32>> {
    Ok(l.to_vec::<i32>()?)
}

/// Extract an f32 vector from a literal.
pub fn to_vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = lit_i32(&[1, 2, 3, 4, 5, 6], &[2, 3]).unwrap();
        assert_eq!(to_vec_i32(&l).unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert!(lit_i32(&[1, 2], &[3]).is_err());
    }

    #[test]
    fn f32_scalar() {
        let l = lit_f32_scalar(2.5);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![2.5]);
    }
}
