//! Typed wrapper around the full-network timestep artifact.
//!
//! `scnn_step.hlo.txt` signature (20 inputs, lowered by compile/aot.py):
//! `(spikes i32[2,48,48], qparams i32[9,3], w1..w9, v1..v9)` →
//! `(out_spikes i32[10], v1'..v9', counts i32[9])`.
//!
//! Resolution is a *runtime* argument (qparams + requantized weights), so
//! one compiled executable serves every point of the Fig. 6 sweep —
//! mirroring the chip's runtime resolution reconfigurability.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use super::backend::StepResult;
use super::client::{lit_i32, to_vec_i32, Executable, Runtime};
use super::weights::WeightFile;
use crate::snn::events::SpikeList;
use crate::snn::network::scnn_dvs_gesture;
use crate::snn::Network;

/// Compiled SCNN with resident weights and threaded membrane state.
pub struct ScnnRunner {
    exe: Executable,
    net: Network,
    /// Quantized weights per layer (row-major i32).
    weights: Vec<Vec<i32>>,
    /// Quantization params per layer: (modulus, half, theta).
    qparams: Vec<[i32; 3]>,
    /// Membrane state per layer (persisted across timesteps — output
    /// stationarity at the runtime level).
    vmems: Vec<Vec<i32>>,
    /// Per-layer `(w_bits, p_bits)` the runner currently holds — the
    /// "from" side of the host-side vmem rescale when
    /// [`Self::set_resolutions`] switches resolutions under live state.
    res: Vec<(u32, u32)>,
    /// Float source weights (for requantization).
    weight_file: WeightFile,
}

impl ScnnRunner {
    /// Load the artifact and weights from `dir` and compile. Prefers
    /// `weights_trained.bin` (produced by the training driver) over the
    /// shipped random-init `weights.bin`.
    pub fn load(rt: &Runtime, dir: &Path) -> Result<Self> {
        let exe = rt.load_hlo(&dir.join("scnn_step.hlo.txt"))?;
        let trained = dir.join("weights_trained.bin");
        let wpath = if trained.exists() { trained } else { dir.join("weights.bin") };
        let weight_file = WeightFile::load(&wpath)?;
        Self::new(exe, weight_file)
    }

    /// Load with the shipped (untrained) weights explicitly — used by the
    /// golden-trace integration test, which pins the random-init model.
    pub fn load_untrained(rt: &Runtime, dir: &Path) -> Result<Self> {
        let exe = rt.load_hlo(&dir.join("scnn_step.hlo.txt"))?;
        let weight_file = WeightFile::load(&dir.join("weights.bin"))?;
        Self::new(exe, weight_file)
    }

    /// Build from a compiled executable + weights (testing hook).
    pub fn new(exe: Executable, weight_file: WeightFile) -> Result<Self> {
        let net = scnn_dvs_gesture();
        ensure!(
            weight_file.layers.len() == net.layers.len(),
            "weights.bin has {} layers, network has {}",
            weight_file.layers.len(),
            net.layers.len()
        );
        for (lw, ls) in weight_file.layers.iter().zip(&net.layers) {
            ensure!(
                lw.len() == ls.num_weights(),
                "layer {}: {} weights in file, {} in spec",
                ls.name,
                lw.len(),
                ls.num_weights()
            );
        }
        let (weights, qparams) = weight_file.quantize_default();
        let vmems = net.layers.iter().map(|l| vec![0i32; l.num_neurons()]).collect();
        let res = net.layers.iter().map(|l| (l.res.w_bits, l.res.p_bits)).collect();
        Ok(ScnnRunner { exe, net, weights, qparams, vmems, res, weight_file })
    }

    /// The workload description this runner mirrors.
    pub fn network(&self) -> &Network {
        &self.net
    }

    /// Requantize all layers at explicit resolutions, *preserving* the
    /// persistent membrane state by a host-side rescale into the new
    /// accumulator range ([`super::backend::StateSnapshot::rescaled`]) —
    /// the same contract the native backend honors, so the adaptive
    /// precision controller can switch a live session's tier mid-window
    /// on PJRT too.
    pub fn set_resolutions(&mut self, res: &[(u32, u32)]) {
        let (w, q) = self.weight_file.quantize_at(res);
        self.weights = w;
        self.qparams = q;
        let rescaled = super::backend::StateSnapshot { vmems: self.vmems_i64() }
            .rescaled(&self.res, res);
        for (dst, src) in self.vmems.iter_mut().zip(&rescaled.vmems) {
            for (d, &s) in dst.iter_mut().zip(src) {
                *d = s as i32;
            }
        }
        self.res = res.to_vec();
    }

    /// Zero all membrane potentials (new inference).
    pub fn reset(&mut self) {
        for v in &mut self.vmems {
            v.iter_mut().for_each(|x| *x = 0);
        }
    }

    /// Current membrane state of a layer (diagnostics).
    pub fn vmem(&self, layer: usize) -> &[i32] {
        &self.vmems[layer]
    }

    /// Copy out the full membrane state, widened to i64 (the
    /// [`super::backend::StateSnapshot`] representation).
    pub fn vmems_i64(&self) -> Vec<Vec<i64>> {
        self.vmems
            .iter()
            .map(|v| v.iter().map(|&x| x as i64).collect())
            .collect()
    }

    /// Restore membrane state captured with [`Self::vmems_i64`]. All
    /// layers are validated (shapes and i32 range) before the first write,
    /// so an `Err` leaves the runner's state untouched.
    pub fn set_vmems_i64(&mut self, vmems: &[Vec<i64>]) -> Result<()> {
        ensure!(
            vmems.len() == self.vmems.len(),
            "snapshot has {} layers, runner has {}",
            vmems.len(),
            self.vmems.len()
        );
        for (i, (dst, src)) in self.vmems.iter().zip(vmems).enumerate() {
            ensure!(
                src.len() == dst.len(),
                "layer {i}: snapshot has {} neurons, runner has {}",
                src.len(),
                dst.len()
            );
            for &s in src {
                ensure!(
                    i32::try_from(s).is_ok(),
                    "layer {i}: vmem value {s} exceeds the runner's i32 range"
                );
            }
        }
        for (dst, src) in self.vmems.iter_mut().zip(vmems) {
            for (d, &s) in dst.iter_mut().zip(src) {
                *d = s as i32;
            }
        }
        Ok(())
    }

    /// Current quantization parameters (modulus, half, theta) per layer.
    pub fn qparams(&self) -> &[[i32; 3]] {
        &self.qparams
    }

    /// Execute one timestep on a 2×48×48 binary input frame.
    pub fn step(&mut self, frame: &[i32]) -> Result<StepResult> {
        let n = self.net.layers.len();
        ensure!(frame.len() == 2 * 48 * 48, "frame must be 2x48x48");

        let mut inputs = Vec::with_capacity(2 + 2 * n);
        inputs.push(lit_i32(frame, &[2, 48, 48])?);
        let qflat: Vec<i32> = self.qparams.iter().flatten().copied().collect();
        inputs.push(lit_i32(&qflat, &[n as i64, 3])?);
        for (w, ls) in self.weights.iter().zip(&self.net.layers) {
            inputs.push(lit_i32(w, &weight_dims(ls))?);
        }
        for (v, ls) in self.vmems.iter().zip(&self.net.layers) {
            inputs.push(lit_i32(v, &vmem_dims(ls))?);
        }

        let out = self.exe.run(&inputs).context("scnn_step execution")?;
        ensure!(out.len() == n + 2, "expected {} outputs, got {}", n + 2, out.len());
        let out_spikes = SpikeList::from_i32_dense(&to_vec_i32(&out[0])?);
        for (i, v) in out[1..=n].iter().enumerate() {
            self.vmems[i] = to_vec_i32(v)?;
        }
        let counts = to_vec_i32(&out[n + 1])?;
        Ok(StepResult { out_spikes, counts })
    }

    /// Run a full inference: `frames` is a sequence of timestep frames;
    /// returns accumulated class spike counts (rate-coded logits).
    pub fn infer(&mut self, frames: &[Vec<i32>]) -> Result<Vec<i64>> {
        self.reset();
        let mut rate = vec![0i64; 10];
        for f in frames {
            let r = self.step(f)?;
            for &c in r.out_spikes.active() {
                rate[c as usize] += 1;
            }
        }
        Ok(rate)
    }

    /// Argmax helper over rate-coded logits.
    pub fn predict(rate: &[i64]) -> usize {
        rate.iter()
            .enumerate()
            .max_by_key(|&(i, v)| (*v, std::cmp::Reverse(i)))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// Weight tensor dims for a layer spec.
fn weight_dims(l: &crate::snn::LayerSpec) -> Vec<i64> {
    match l.kind {
        crate::snn::LayerKind::Conv { in_ch, out_ch, k, .. } => {
            vec![out_ch as i64, in_ch as i64, k as i64, k as i64]
        }
        crate::snn::LayerKind::Fc { in_dim, out_dim } => vec![out_dim as i64, in_dim as i64],
    }
}

/// Membrane tensor dims for a layer spec.
fn vmem_dims(l: &crate::snn::LayerSpec) -> Vec<i64> {
    match l.kind {
        crate::snn::LayerKind::Conv { .. } => {
            let (c, h, w) = l.out_shape();
            vec![c as i64, h as i64, w as i64]
        }
        crate::snn::LayerKind::Fc { out_dim, .. } => vec![out_dim as i64],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predict_is_argmax_with_low_index_tiebreak() {
        assert_eq!(ScnnRunner::predict(&[0, 3, 3, 1]), 1);
        assert_eq!(ScnnRunner::predict(&[5, 3, 3, 1]), 0);
        assert_eq!(ScnnRunner::predict(&[]), 0);
    }

    #[test]
    fn dims_helpers() {
        let net = scnn_dvs_gesture();
        assert_eq!(weight_dims(&net.layers[0]), vec![12, 2, 3, 3]);
        assert_eq!(vmem_dims(&net.layers[0]), vec![12, 48, 48]);
        assert_eq!(weight_dims(&net.layers[6]), vec![256, 3456]);
        assert_eq!(vmem_dims(&net.layers[8]), vec![10]);
    }
}
