//! Pure-Rust network backend: the event-driven sparse execution engine.
//!
//! [`NativeScnn`] interprets any [`Network`] with the bit-exact integer IF
//! semantics of [`crate::snn::lif::LifLayer`] and
//! [`crate::snn::conv::ConvLifLayer`] — the same semantics the CIM macro
//! simulator and the Pallas kernels are pinned to. Weights are generated
//! deterministically from a seed (per-layer forked RNG streams), so two
//! instances built from the same `(network, seed)` pair behave identically
//! on any thread. That property is what lets the parallel engine hand each
//! worker its own backend and still produce byte-identical results to the
//! sequential path (asserted by `rust/tests/integration_engine.rs`).
//!
//! Since the sparse-datapath refactor the default execution mode is
//! *event-driven*: spikes travel as [`SpikeList`]s and each timestep costs
//! work proportional to spike activity ([`crate::snn::events`]), not layer
//! size — the software equivalent of the chip's event-based operation.
//! [`NativeScnn::new_dense_reference`] builds the same weights into the
//! dense golden-model layers instead; it is the oracle the property tests
//! (`rust/tests/property_sparse.rs`) and the `sparse_speedup` bench
//! compare against, and is *not* used by any runtime tier.
//!
//! Unlike the PJRT runner this backend is `Send`, needs no artifacts, and
//! runs everywhere — it is the engine's throughput substrate and the
//! fallback when the XLA runtime is not vendored.

use std::sync::Arc;

use crate::snn::conv::ConvLifLayer;
use crate::snn::events::{AdjacencyCache, EventConvLayer, EventFcLayer, SpikeList};
use crate::snn::lif::LifLayer;
use crate::snn::quant::{max_val, min_val};
use crate::snn::{LayerKind, Network, Resolution};
use crate::util::rng::Rng;
use crate::Result;

use super::backend::{StateSnapshot, StepBackend, StepResult};

enum NativeLayer {
    Conv(EventConvLayer),
    Fc(EventFcLayer),
    /// Dense golden-model variants: the oracle path for the dense-vs-sparse
    /// property tests and the `sparse_speedup` bench.
    DenseConv(ConvLifLayer),
    DenseFc(LifLayer),
}

impl NativeLayer {
    fn step_into(&mut self, spikes: &SpikeList, out: &mut SpikeList) {
        match self {
            NativeLayer::Conv(l) => l.step_into(spikes, out),
            NativeLayer::Fc(l) => l.step_into(spikes, out),
            // The dense golden-model variants densify at their boundary —
            // they are the property-test oracle, not a runtime tier, so
            // their allocations are acceptable.
            NativeLayer::DenseConv(l) => dense_into(&l.step(&spikes.to_dense()), out),
            NativeLayer::DenseFc(l) => dense_into(&l.step(&spikes.to_dense()), out),
        }
    }

    fn reset(&mut self) {
        match self {
            NativeLayer::Conv(l) => l.reset(),
            NativeLayer::Fc(l) => l.reset(),
            NativeLayer::DenseConv(l) => l.v.iter_mut().for_each(|v| *v = 0),
            NativeLayer::DenseFc(l) => l.v.iter_mut().for_each(|v| *v = 0),
        }
    }

    fn vmem(&self) -> &[i64] {
        match self {
            NativeLayer::Conv(l) => l.vmem(),
            NativeLayer::Fc(l) => l.vmem(),
            NativeLayer::DenseConv(l) => &l.v,
            NativeLayer::DenseFc(l) => &l.v,
        }
    }

    fn set_vmem(&mut self, v: &[i64]) {
        match self {
            NativeLayer::Conv(l) => l.set_vmem(v),
            NativeLayer::Fc(l) => l.set_vmem(v),
            NativeLayer::DenseConv(l) => l.v.copy_from_slice(v),
            NativeLayer::DenseFc(l) => l.v.copy_from_slice(v),
        }
    }
}

/// Sparsify a dense golden-model output into a reusable [`SpikeList`].
fn dense_into(bits: &[bool], out: &mut SpikeList) {
    out.begin(bits.len());
    for (i, &b) in bits.iter().enumerate() {
        if b {
            out.push(i as u32);
        }
    }
}

/// Deterministic pure-Rust SCNN execution engine (event-driven sparse by
/// default).
pub struct NativeScnn {
    net: Network,
    seed: u64,
    sparse: bool,
    /// Shared conv scatter-adjacency tables: reused across
    /// [`Self::set_resolutions`] rebuilds (the adjacency depends only on
    /// geometry) and, when the same cache `Arc` is handed to several
    /// instances, across engine / serve workers.
    adj_cache: Arc<AdjacencyCache>,
    layers: Vec<NativeLayer>,
    /// Ping-pong spike scratch of the zero-alloc
    /// [`StepBackend::step_into`] path: `spike_a` feeds the layer being
    /// stepped, `spike_b` receives its output, then they swap. Both keep
    /// their capacity across windows, so the steady-state step performs
    /// no heap allocation (asserted by `rust/tests/alloc_steady_state.rs`).
    spike_a: SpikeList,
    spike_b: SpikeList,
}

impl NativeScnn {
    /// Build an event-driven interpreter for `net` with seed-derived
    /// quantized weights (private adjacency cache — resolution rebuilds
    /// still reuse it).
    pub fn new(net: Network, seed: u64) -> NativeScnn {
        Self::with_adjacency_cache(net, seed, Arc::new(AdjacencyCache::new()))
    }

    /// Build with a shared [`AdjacencyCache`]: hand the same `Arc` to
    /// every worker's backend and the conv adjacencies are compiled once
    /// per distinct geometry process-wide instead of once per worker.
    pub fn with_adjacency_cache(
        net: Network,
        seed: u64,
        cache: Arc<AdjacencyCache>,
    ) -> NativeScnn {
        let layers = Self::build_layers(&net, seed, true, &cache);
        NativeScnn {
            net,
            seed,
            sparse: true,
            adj_cache: cache,
            layers,
            spike_a: SpikeList::default(),
            spike_b: SpikeList::default(),
        }
    }

    /// Build the dense golden-model interpreter over the *same* weight
    /// streams — the oracle for dense-vs-sparse bit-identity tests and the
    /// baseline of the `sparse_speedup` bench. Runtime tiers never use it.
    pub fn new_dense_reference(net: Network, seed: u64) -> NativeScnn {
        let cache = Arc::new(AdjacencyCache::new());
        let layers = Self::build_layers(&net, seed, false, &cache);
        NativeScnn {
            net,
            seed,
            sparse: false,
            adj_cache: cache,
            layers,
            spike_a: SpikeList::default(),
            spike_b: SpikeList::default(),
        }
    }

    fn build_layers(
        net: &Network,
        seed: u64,
        sparse: bool,
        cache: &AdjacencyCache,
    ) -> Vec<NativeLayer> {
        let mut root = Rng::new(seed ^ 0x5EED_CE11_F1E2_D3C4);
        net.layers
            .iter()
            .map(|spec| {
                // One forked stream per layer: a layer's weights do not
                // depend on how many layers precede it being regenerated.
                // The sparse and dense builds consume identical RNG
                // sequences, so their weights are bit-identical.
                let mut rng = root.fork();
                // Excitation-biased weight range and a fan-in-scaled
                // threshold keep random-weight spike rates in a useful band
                // (a dead or saturated network would make the engine's
                // throughput and determinism tests vacuous). The spec's
                // default threshold targets trained weight distributions.
                let hi = max_val(spec.res.w_bits);
                let lo = (-hi / 3).min(-1).max(min_val(spec.res.w_bits));
                let fan_in = spec.fan_in() as i64;
                let theta = (fan_in * (hi / 4).max(1) / 2)
                    .clamp(1, max_val(spec.res.p_bits).max(1));
                match spec.kind {
                    LayerKind::Conv { .. } => {
                        let weights: Vec<i64> = (0..spec.num_weights())
                            .map(|_| rng.range_i64(lo, hi))
                            .collect();
                        if sparse {
                            NativeLayer::Conv(EventConvLayer::with_adjacency(
                                spec.clone(),
                                weights,
                                theta,
                                cache.get_or_build(spec),
                            ))
                        } else {
                            NativeLayer::DenseConv(ConvLifLayer::new(
                                spec.clone(),
                                weights,
                                theta,
                            ))
                        }
                    }
                    LayerKind::Fc { in_dim, out_dim } => {
                        let weights: Vec<Vec<i64>> = (0..out_dim)
                            .map(|_| (0..in_dim).map(|_| rng.range_i64(lo, hi)).collect())
                            .collect();
                        if sparse {
                            NativeLayer::Fc(EventFcLayer::new(weights, spec.res, theta))
                        } else {
                            NativeLayer::DenseFc(LifLayer::new(weights, spec.res, theta))
                        }
                    }
                }
            })
            .collect()
    }

    /// The seed the weights were derived from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// True when this instance runs the event-driven sparse datapath
    /// (false only for [`Self::new_dense_reference`] oracles).
    pub fn is_sparse(&self) -> bool {
        self.sparse
    }

    /// The conv-adjacency cache this backend compiles through (shared or
    /// private — see [`Self::with_adjacency_cache`]).
    pub fn adjacency_cache(&self) -> &Arc<AdjacencyCache> {
        &self.adj_cache
    }
}

impl StepBackend for NativeScnn {
    fn network(&self) -> &Network {
        &self.net
    }

    fn reset(&mut self) {
        for l in &mut self.layers {
            l.reset();
        }
    }

    fn step(&mut self, frame: &SpikeList) -> Result<StepResult> {
        let mut out = StepResult::default();
        self.step_into(frame, &mut out)?;
        Ok(out)
    }

    fn step_into(&mut self, frame: &SpikeList, out: &mut StepResult) -> Result<()> {
        let _span = crate::telemetry::trace::span("native.step");
        let (c, h, w) = self.net.layers[0].in_shape();
        anyhow::ensure!(
            frame.dim() == c * h * w,
            "frame has {} inputs, layer 0 expects {}",
            frame.dim(),
            c * h * w
        );
        out.counts.clear();
        self.spike_a.copy_from(frame);
        for layer in &mut self.layers {
            layer.step_into(&self.spike_a, &mut self.spike_b);
            out.counts.push(self.spike_b.count() as i32);
            std::mem::swap(&mut self.spike_a, &mut self.spike_b);
        }
        out.out_spikes.copy_from(&self.spike_a);
        Ok(())
    }

    fn set_resolutions(&mut self, res: &[(u32, u32)]) {
        let old: Vec<(u32, u32)> =
            self.net.layers.iter().map(|l| (l.res.w_bits, l.res.p_bits)).collect();
        let state = self.snapshot();
        let resolutions: Vec<Resolution> =
            res.iter().map(|&(w, p)| Resolution::new(w, p)).collect();
        self.net = self.net.with_resolutions(&resolutions);
        // Resolution changes do not move the conv geometry, so every
        // adjacency comes straight out of the cache.
        self.layers = Self::build_layers(&self.net, self.seed, self.sparse, &self.adj_cache);
        // A live session's membrane state survives the switch: realign it
        // into the new accumulator range instead of silently resetting
        // (the StepBackend contract — see StateSnapshot::rescaled).
        let rescaled = state.rescaled(&old, res);
        for (layer, v) in self.layers.iter_mut().zip(&rescaled.vmems) {
            layer.set_vmem(v);
        }
    }

    fn snapshot(&self) -> StateSnapshot {
        StateSnapshot {
            vmems: self.layers.iter().map(|l| l.vmem().to_vec()).collect(),
        }
    }

    fn snapshot_into(&self, out: &mut StateSnapshot) {
        out.vmems.resize_with(self.layers.len(), Vec::new);
        for (dst, l) in out.vmems.iter_mut().zip(&self.layers) {
            dst.clear();
            dst.extend_from_slice(l.vmem());
        }
    }

    fn restore(&mut self, state: &StateSnapshot) -> Result<()> {
        // Validate every layer before the first write: an Err must leave
        // the backend's state untouched, not half-restored.
        anyhow::ensure!(
            state.vmems.len() == self.layers.len(),
            "snapshot has {} layers, backend has {}",
            state.vmems.len(),
            self.layers.len()
        );
        for (i, (layer, v)) in self.layers.iter().zip(&state.vmems).enumerate() {
            let have = layer.vmem().len();
            anyhow::ensure!(
                v.len() == have,
                "layer {i}: snapshot has {} neurons, backend has {have}",
                v.len()
            );
        }
        for (layer, v) in self.layers.iter_mut().zip(&state.vmems) {
            layer.set_vmem(v);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::{encode_frames, GestureClass, GestureGenerator};
    use crate::snn::LayerSpec;

    fn tiny_net() -> Network {
        let r = Resolution::new(4, 9);
        Network::new(
            "tiny",
            vec![
                LayerSpec::conv("C1", 2, 4, 3, 4, 1, 48, 48, r),
                LayerSpec::fc("F1", 4 * 12 * 12, 16, r),
                LayerSpec::fc("F2", 16, 10, Resolution::new(5, 10)),
            ],
            4,
        )
    }

    fn frames_for(net: &Network, seed: u64) -> Vec<SpikeList> {
        let gen = GestureGenerator::default_48();
        let mut rng = Rng::new(seed);
        let stream = gen.sample(GestureClass::HandClap, &mut rng);
        encode_frames(&stream, net.timesteps)
            .iter()
            .map(|f| f.to_spike_list())
            .collect()
    }

    #[test]
    fn deterministic_across_instances() {
        let net = tiny_net();
        let frames = frames_for(&net, 3);
        let mut a = NativeScnn::new(net.clone(), 42);
        let mut b = NativeScnn::new(net, 42);
        for f in &frames {
            let ra = a.step(f).unwrap();
            let rb = b.step(f).unwrap();
            assert_eq!(ra.out_spikes, rb.out_spikes);
            assert_eq!(ra.counts, rb.counts);
        }
    }

    #[test]
    fn sparse_matches_dense_reference_end_to_end() {
        // The module-level smoke of the tentpole property: same seed, same
        // frames, sparse vs dense golden layers — identical spikes,
        // counts, and final state (the broad random-geometry sweep lives
        // in rust/tests/property_sparse.rs).
        let net = tiny_net();
        let frames = frames_for(&net, 8);
        let mut sparse = NativeScnn::new(net.clone(), 42);
        let mut dense = NativeScnn::new_dense_reference(net, 42);
        assert!(sparse.is_sparse() && !dense.is_sparse());
        for (t, f) in frames.iter().enumerate() {
            let a = sparse.step(f).unwrap();
            let b = dense.step(f).unwrap();
            assert_eq!(a.out_spikes, b.out_spikes, "t={t} spikes");
            assert_eq!(a.counts, b.counts, "t={t} counts");
        }
        assert_eq!(sparse.snapshot(), dense.snapshot(), "final vmem");
    }

    #[test]
    fn reset_restores_initial_state() {
        let net = tiny_net();
        let frames = frames_for(&net, 5);
        let mut m = NativeScnn::new(net, 7);
        let first: Vec<StepResult> =
            frames.iter().map(|f| m.step(f).unwrap()).collect();
        m.reset();
        for (i, f) in frames.iter().enumerate() {
            let r = m.step(f).unwrap();
            assert_eq!(r.out_spikes, first[i].out_spikes, "step {i}");
            assert_eq!(r.counts, first[i].counts, "step {i}");
        }
    }

    #[test]
    fn different_seeds_differ() {
        let net = tiny_net();
        let frames = frames_for(&net, 9);
        let mut a = NativeScnn::new(net.clone(), 1);
        let mut b = NativeScnn::new(net, 2);
        let ca: Vec<i32> = frames.iter().flat_map(|f| a.step(f).unwrap().counts).collect();
        let cb: Vec<i32> = frames.iter().flat_map(|f| b.step(f).unwrap().counts).collect();
        assert_ne!(ca, cb, "weight streams must differ across seeds");
    }

    #[test]
    fn resolution_rebuild_is_deterministic() {
        let net = tiny_net();
        let frames = frames_for(&net, 11);
        let res = vec![(3u32, 8u32); 3];
        let mut a = NativeScnn::new(net.clone(), 4);
        a.set_resolutions(&res);
        let mut b = NativeScnn::new(net.with_resolutions(&[Resolution::new(3, 8); 3]), 4);
        for f in &frames {
            assert_eq!(a.step(f).unwrap().counts, b.step(f).unwrap().counts);
        }
    }

    #[test]
    fn set_resolutions_preserves_vmem_by_rescale() {
        // A live session's membrane state survives a precision switch:
        // after set_resolutions the backend holds exactly the old snapshot
        // realigned into the new p_bits range, and continues bit-identically
        // to a fresh backend built at the target resolution restoring that
        // rescaled checkpoint (the broad random sweep lives in
        // rust/tests/property_sparse.rs).
        let net = tiny_net();
        let base: Vec<(u32, u32)> =
            net.layers.iter().map(|l| (l.res.w_bits, l.res.p_bits)).collect();
        let target = vec![(3u32, 7u32), (3, 7), (4, 12)];
        let frames = frames_for(&net, 13);
        let mut live = NativeScnn::new(net.clone(), 21);
        for f in &frames[..2] {
            live.step(f).unwrap();
        }
        let checkpoint = live.snapshot();
        assert!(checkpoint.vmems.iter().any(|v| v.iter().any(|&x| x != 0)));
        live.set_resolutions(&target);
        let rescaled = checkpoint.rescaled(&base, &target);
        assert_eq!(live.snapshot(), rescaled, "vmem realigned, not reset");
        let tnet = net.with_resolutions(&[
            Resolution::new(3, 7),
            Resolution::new(3, 7),
            Resolution::new(4, 12),
        ]);
        let mut fresh = NativeScnn::new(tnet, 21);
        fresh.restore(&rescaled).unwrap();
        for (t, f) in frames[2..].iter().enumerate() {
            let a = live.step(f).unwrap();
            let b = fresh.step(f).unwrap();
            assert_eq!(a.out_spikes, b.out_spikes, "t={t} spikes");
            assert_eq!(a.counts, b.counts, "t={t} counts");
        }
        assert_eq!(live.snapshot(), fresh.snapshot(), "final vmem");
    }

    #[test]
    fn frame_size_checked() {
        let mut m = NativeScnn::new(tiny_net(), 1);
        assert!(m.step(&SpikeList::empty(7)).is_err());
    }

    #[test]
    fn resolution_rebuild_reuses_adjacency() {
        // tiny_net has one conv layer: the first build compiles its
        // adjacency (a miss), every set_resolutions rebuild is a hit.
        let mut m = NativeScnn::new(tiny_net(), 1);
        let cache = m.adjacency_cache().clone();
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.hits(), 0);
        m.set_resolutions(&[(3, 8), (3, 8), (4, 9)]);
        assert_eq!(cache.len(), 1, "no new geometry appeared");
        assert_eq!(cache.hits(), 1, "rebuild must hit the cache");
        m.set_resolutions(&[(5, 10), (5, 10), (5, 10)]);
        assert_eq!(cache.hits(), 2);
    }

    #[test]
    fn workers_sharing_a_cache_stay_bit_identical() {
        let net = tiny_net();
        let frames = frames_for(&net, 21);
        let cache = Arc::new(AdjacencyCache::new());
        let mut a = NativeScnn::with_adjacency_cache(net.clone(), 9, cache.clone());
        let mut b = NativeScnn::with_adjacency_cache(net.clone(), 9, cache.clone());
        assert_eq!(cache.hits(), 1, "second instance reuses the table");
        let mut private = NativeScnn::new(net, 9);
        for f in &frames {
            let ra = a.step(f).unwrap();
            let rb = b.step(f).unwrap();
            let rp = private.step(f).unwrap();
            assert_eq!(ra.out_spikes, rb.out_spikes);
            assert_eq!(ra.out_spikes, rp.out_spikes);
            assert_eq!(ra.counts, rp.counts);
        }
    }

    #[test]
    fn step_into_matches_step_and_reuses_buffers() {
        // The zero-alloc reusable-buffer entry points must be observably
        // identical to the allocating forms, for the sparse and the dense
        // oracle backend alike.
        let net = tiny_net();
        let frames = frames_for(&net, 17);
        for dense in [false, true] {
            let mut a = if dense {
                NativeScnn::new_dense_reference(net.clone(), 6)
            } else {
                NativeScnn::new(net.clone(), 6)
            };
            let mut b = if dense {
                NativeScnn::new_dense_reference(net.clone(), 6)
            } else {
                NativeScnn::new(net.clone(), 6)
            };
            let mut out = StepResult::default();
            for f in &frames {
                b.step_into(f, &mut out).unwrap();
                assert_eq!(out, a.step(f).unwrap(), "dense={dense}");
            }
            let mut snap = StateSnapshot::default();
            b.snapshot_into(&mut snap);
            assert_eq!(snap, a.snapshot(), "dense={dense}");
        }
    }

    #[test]
    fn backend_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<NativeScnn>();
    }

    #[test]
    fn snapshot_restore_resumes_bit_identically() {
        // Run T steps monolithically; run T/2 steps, checkpoint, restore
        // into a *fresh* backend, run the rest: outputs and final state
        // must match exactly. This is the contract the serve tier's
        // incremental windows stand on — and since the refactor the
        // restore path must also rebuild the sparse refire sets.
        let net = tiny_net();
        let frames = frames_for(&net, 13);
        let mut mono = NativeScnn::new(net.clone(), 42);
        let mono_out: Vec<StepResult> = frames.iter().map(|f| mono.step(f).unwrap()).collect();

        let mut first = NativeScnn::new(net.clone(), 42);
        let half = frames.len() / 2;
        let mut windowed_out: Vec<StepResult> =
            frames[..half].iter().map(|f| first.step(f).unwrap()).collect();
        let checkpoint = first.snapshot();
        drop(first);

        let mut second = NativeScnn::new(net, 42);
        second.restore(&checkpoint).unwrap();
        windowed_out.extend(frames[half..].iter().map(|f| second.step(f).unwrap()));

        for (i, (a, b)) in mono_out.iter().zip(&windowed_out).enumerate() {
            assert_eq!(a.out_spikes, b.out_spikes, "step {i}");
            assert_eq!(a.counts, b.counts, "step {i}");
        }
        assert_eq!(mono.snapshot(), second.snapshot(), "final vmem");
    }

    #[test]
    fn restore_rejects_shape_mismatch() {
        let mut m = NativeScnn::new(tiny_net(), 1);
        let err = m.restore(&StateSnapshot { vmems: vec![vec![0; 3]] }).unwrap_err();
        assert!(format!("{err}").contains("layers"));
        let mut bad = m.snapshot();
        bad.vmems[1] = vec![0; 7];
        let err = m.restore(&bad).unwrap_err();
        assert!(format!("{err}").contains("neurons"));
    }

    #[test]
    fn zeros_snapshot_equals_reset_state() {
        let net = tiny_net();
        let frames = frames_for(&net, 2);
        let mut m = NativeScnn::new(net.clone(), 3);
        for f in &frames {
            m.step(f).unwrap();
        }
        m.restore(&StateSnapshot::zeros(&net)).unwrap();
        let mut fresh = NativeScnn::new(net, 3);
        assert_eq!(m.snapshot(), fresh.snapshot());
        assert_eq!(
            m.step(&frames[0]).unwrap().counts,
            fresh.step(&frames[0]).unwrap().counts
        );
    }
}
