//! The network-execution backend abstraction.
//!
//! The coordinator and the parallel engine drive one inference timestep at
//! a time through [`StepBackend`], so the same control plane, energy
//! accounting, and metrics code serves two engines:
//!
//! * [`super::scnn::ScnnRunner`] — the AOT-compiled HLO executed under
//!   PJRT (needs artifacts + the native XLA runtime). The PJRT client is
//!   `Rc`-based and **not `Send`**, so a runner can never migrate between
//!   threads: each engine worker must construct its own backend via a
//!   factory, inside the worker thread.
//! * [`super::native::NativeScnn`] — a pure-Rust bit-exact interpreter
//!   over the golden LIF/conv models. `Send`, artifact-free, and
//!   deterministic from a seed; the engine's offline reference.

use crate::snn::Network;
use crate::Result;

pub use super::scnn::StepResult;

/// One-timestep network execution engine with persistent membrane state.
pub trait StepBackend {
    /// The workload this backend executes.
    fn network(&self) -> &Network;

    /// Zero all membrane potentials (start of a new inference).
    fn reset(&mut self);

    /// Execute one timestep on a flattened binary input frame
    /// (channel-major `[c · h · w]`, 0/1 values).
    fn step(&mut self, frame: &[i32]) -> Result<StepResult>;

    /// Requantize at explicit per-layer `(w_bits, p_bits)` resolutions and
    /// reset state.
    fn set_resolutions(&mut self, res: &[(u32, u32)]);
}

impl StepBackend for super::scnn::ScnnRunner {
    fn network(&self) -> &Network {
        super::scnn::ScnnRunner::network(self)
    }

    fn reset(&mut self) {
        super::scnn::ScnnRunner::reset(self)
    }

    fn step(&mut self, frame: &[i32]) -> Result<StepResult> {
        super::scnn::ScnnRunner::step(self, frame)
    }

    fn set_resolutions(&mut self, res: &[(u32, u32)]) {
        super::scnn::ScnnRunner::set_resolutions(self, res)
    }
}
