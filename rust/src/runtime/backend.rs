//! The network-execution backend abstraction.
//!
//! The coordinator and the parallel engine drive one inference timestep at
//! a time through [`StepBackend`], so the same control plane, energy
//! accounting, and metrics code serves two engines:
//!
//! * [`super::scnn::ScnnRunner`] — the AOT-compiled HLO executed under
//!   PJRT (needs artifacts + the native XLA runtime). The PJRT client is
//!   `Rc`-based and **not `Send`**, so a runner can never migrate between
//!   threads: each engine worker must construct its own backend via a
//!   factory, inside the worker thread.
//! * [`super::native::NativeScnn`] — the pure-Rust event-driven sparse
//!   engine, bit-exact to the golden LIF/conv models. `Send`,
//!   artifact-free, and deterministic from a seed; the engine's offline
//!   reference.
//!
//! Spikes cross this interface as [`SpikeList`]s (the sparse AER-native
//! representation of `crate::snn::events`); backends that need dense
//! tensors — the PJRT artifact — densify at their own boundary.

use crate::snn::events::SpikeList;
use crate::snn::Network;
use crate::Result;

/// Result of one network timestep, in the sparse spike representation the
/// whole runtime datapath moves.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct StepResult {
    /// Output spikes of the classifier layer (10 classes).
    pub out_spikes: SpikeList,
    /// Per-layer spike counts (for energy accounting).
    pub counts: Vec<i32>,
}

/// A full copy of a backend's persistent per-neuron state: one membrane
/// vector per layer, in layer order.
///
/// This is what the chip's layer-wise output stationarity keeps resident in
/// CIM between timesteps. The serve tier (`crate::serve`) checkpoints it
/// between micro-windows so a session resumes from its previous membrane
/// potentials instead of re-simulating from reset, and spills it as DRAM
/// traffic when the residency budget is exceeded.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct StateSnapshot {
    /// Per-layer membrane potentials.
    pub vmems: Vec<Vec<i64>>,
}

impl StateSnapshot {
    /// The all-zero (reset) state of `net`.
    pub fn zeros(net: &Network) -> StateSnapshot {
        StateSnapshot {
            vmems: net.layers.iter().map(|l| vec![0i64; l.num_neurons()]).collect(),
        }
    }

    /// Total neurons captured.
    pub fn neurons(&self) -> usize {
        self.vmems.iter().map(Vec::len).sum()
    }

    /// Realign a snapshot captured at `from` per-layer `(w_bits, p_bits)`
    /// resolutions into the membrane range of `to`: each layer's
    /// potentials shift by the `p_bits` delta (`v << Δ` when the
    /// accumulator widens, arithmetic `v >> Δ` when it narrows), which is
    /// what the chip's bitwise-reconfigurable vmem words do when a layer's
    /// operand resolution is switched under a live session. `w_bits` does
    /// not move stored state (weights are requantized, not membranes).
    ///
    /// Shifting keeps every value inside `[min_val(p), max_val(p)]` of the
    /// target resolution, so a rescaled snapshot always restores cleanly
    /// into a backend rebuilt at `to`.
    pub fn rescaled(&self, from: &[(u32, u32)], to: &[(u32, u32)]) -> StateSnapshot {
        assert_eq!(from.len(), self.vmems.len(), "from-resolution layer count");
        assert_eq!(to.len(), self.vmems.len(), "to-resolution layer count");
        let vmems = self
            .vmems
            .iter()
            .zip(from.iter().zip(to))
            .map(|(v, (&(_, po), &(_, pn)))| {
                if pn >= po {
                    let sh = pn - po;
                    v.iter().map(|&x| x << sh).collect()
                } else {
                    let sh = po - pn;
                    v.iter().map(|&x| x >> sh).collect()
                }
            })
            .collect();
        StateSnapshot { vmems }
    }
}

/// One-timestep network execution engine with persistent membrane state.
pub trait StepBackend {
    /// The workload this backend executes.
    fn network(&self) -> &Network;

    /// Zero all membrane potentials (start of a new inference).
    fn reset(&mut self);

    /// Execute one timestep on a sparse input spike list (active indices
    /// over the channel-major `[c · h · w]` input space).
    fn step(&mut self, frame: &SpikeList) -> Result<StepResult>;

    /// Execute one timestep into a caller-owned [`StepResult`], reusing
    /// its buffers. The default delegates to [`StepBackend::step`]
    /// (allocating); backends with a zero-alloc hot path override this —
    /// the coordinator's window loop always calls it.
    fn step_into(&mut self, frame: &SpikeList, out: &mut StepResult) -> Result<()> {
        *out = self.step(frame)?;
        Ok(())
    }

    /// Requantize at explicit per-layer `(w_bits, p_bits)` resolutions.
    ///
    /// Contract for live sessions: the backend preserves its persistent
    /// membrane state across the switch by realigning it into the new
    /// accumulator range ([`StateSnapshot::rescaled`]) — a serve-time
    /// precision change must not silently reset a session mid-stream.
    /// Both backends honor this: [`super::native::NativeScnn`] rescales
    /// its accumulators in place, and [`super::scnn::ScnnRunner`]
    /// requantizes the AOT artifact's weights host-side and rescales its
    /// host-resident vmem copy through the same `StateSnapshot::rescaled`
    /// shift, so mid-inference switches keep state on PJRT too.
    fn set_resolutions(&mut self, res: &[(u32, u32)]);

    /// Copy out the persistent membrane state (a session checkpoint).
    fn snapshot(&self) -> StateSnapshot;

    /// Copy the persistent membrane state into a caller-owned snapshot,
    /// reusing its buffers. The default delegates to
    /// [`StepBackend::snapshot`] (allocating); backends on the serve hot
    /// path override this.
    fn snapshot_into(&self, out: &mut StateSnapshot) {
        *out = self.snapshot();
    }

    /// Restore state previously captured with [`StepBackend::snapshot`]
    /// (shape-checked against the current network).
    fn restore(&mut self, state: &StateSnapshot) -> Result<()>;
}

impl StepBackend for super::scnn::ScnnRunner {
    fn network(&self) -> &Network {
        super::scnn::ScnnRunner::network(self)
    }

    fn reset(&mut self) {
        super::scnn::ScnnRunner::reset(self)
    }

    fn step(&mut self, frame: &SpikeList) -> Result<StepResult> {
        // The PJRT artifact consumes a dense i32 tensor; densify at the
        // boundary (the sparse representation stays canonical upstream).
        super::scnn::ScnnRunner::step(self, &frame.to_i32())
    }

    fn set_resolutions(&mut self, res: &[(u32, u32)]) {
        super::scnn::ScnnRunner::set_resolutions(self, res)
    }

    fn snapshot(&self) -> StateSnapshot {
        StateSnapshot { vmems: self.vmems_i64() }
    }

    fn restore(&mut self, state: &StateSnapshot) -> Result<()> {
        self.set_vmems_i64(&state.vmems)
    }
}
