//! Arbitrary-resolution fixed-point quantization.
//!
//! FlexSpIM's first contribution is *bitwise-granular* operand resolution:
//! weights and membrane potentials may take any bit-width per layer. This
//! module defines the two's-complement ranges, wrap/saturate helpers, and
//! float↔fixed conversion used by the LIF reference, the CIM macro
//! simulator (which must agree bit-for-bit), and the footprint accounting.

/// Per-layer operand resolution: weight and membrane-potential bit-widths.
///
/// Both are ≥1; widths up to 64 are supported by the software models (the
/// fabricated macro supports up to the array dimensions, 512×256).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Resolution {
    /// Weight bit-width (two's complement, signed).
    pub w_bits: u32,
    /// Membrane-potential bit-width (two's complement, signed).
    pub p_bits: u32,
}

impl Resolution {
    /// Construct, validating supported widths.
    pub fn new(w_bits: u32, p_bits: u32) -> Self {
        assert!(
            (1..=64).contains(&w_bits) && (1..=64).contains(&p_bits),
            "resolution out of supported range: w={w_bits} p={p_bits}"
        );
        Resolution { w_bits, p_bits }
    }

    /// Bits per synapse+neuron pair (used for 1-bit normalization of
    /// throughput/efficiency, Table I footnotes ‡/†).
    pub fn norm_product(&self) -> u64 {
        self.w_bits as u64 * self.p_bits as u64
    }
}

impl std::fmt::Display for Resolution {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}b/{}b", self.w_bits, self.p_bits)
    }
}

/// Smallest representable value of a signed `bits`-wide integer.
#[inline]
pub fn min_val(bits: u32) -> i64 {
    debug_assert!((1..=64).contains(&bits));
    if bits == 64 {
        i64::MIN
    } else {
        -(1i64 << (bits - 1))
    }
}

/// Largest representable value of a signed `bits`-wide integer.
#[inline]
pub fn max_val(bits: u32) -> i64 {
    debug_assert!((1..=64).contains(&bits));
    if bits == 64 {
        i64::MAX
    } else {
        (1i64 << (bits - 1)) - 1
    }
}

/// Two's-complement wrap of `v` into `bits` width (what a bit-serial adder
/// with no saturation logic produces — and what the CIM macro does).
#[inline]
pub fn wrap(v: i64, bits: u32) -> i64 {
    if bits >= 64 {
        return v;
    }
    // i128 intermediate: `1 << 63` would overflow i64.
    let m = 1i128 << bits;
    let r = (v as i128).rem_euclid(m);
    let r = if r >= m / 2 { r - m } else { r };
    r as i64
}

/// Saturate `v` into `bits` width (used by the quantization-aware trainer).
#[inline]
pub fn saturate(v: i64, bits: u32) -> i64 {
    v.clamp(min_val(bits), max_val(bits))
}

/// Quantize a float in `[-1, 1)` to a signed `bits`-wide integer with
/// scale `2^(bits-1)` (symmetric, round-to-nearest-even via f64 rounding).
#[inline]
pub fn quantize_unit(x: f64, bits: u32) -> i64 {
    let scale = (1u64 << (bits - 1)) as f64;
    saturate((x * scale).round() as i64, bits)
}

/// Dequantize back to float with the same scale.
#[inline]
pub fn dequantize_unit(q: i64, bits: u32) -> f64 {
    let scale = (1u64 << (bits - 1)) as f64;
    q as f64 / scale
}

/// Extract bit `i` (LSB = 0) of the two's-complement representation of `v`
/// at width `bits`, with implicit sign extension for `i >= bits`.
/// This is exactly what the macro's emulation bits (EBs) provide in
/// silicon: reads of rows beyond the stored MSB return the sign bit.
#[inline]
pub fn bit_of(v: i64, i: u32, bits: u32) -> bool {
    let idx = i.min(bits - 1); // sign extension beyond MSB
    ((v >> idx) & 1) != 0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{check, prop_eq, Config};
    use crate::util::rng::Rng;

    #[test]
    fn ranges() {
        assert_eq!(min_val(1), -1);
        assert_eq!(max_val(1), 0);
        assert_eq!(min_val(8), -128);
        assert_eq!(max_val(8), 127);
        assert_eq!(min_val(64), i64::MIN);
        assert_eq!(max_val(64), i64::MAX);
    }

    #[test]
    fn wrap_examples() {
        assert_eq!(wrap(128, 8), -128);
        assert_eq!(wrap(-129, 8), 127);
        assert_eq!(wrap(255, 8), -1);
        assert_eq!(wrap(5, 4), 5);
        assert_eq!(wrap(8, 4), -8);
    }

    #[test]
    fn saturate_examples() {
        assert_eq!(saturate(1000, 8), 127);
        assert_eq!(saturate(-1000, 8), -128);
        assert_eq!(saturate(5, 8), 5);
    }

    #[test]
    fn quantize_roundtrip_monotone() {
        for bits in [2, 4, 5, 8, 12] {
            let mut last = i64::MIN;
            let mut x = -1.0;
            while x < 1.0 {
                let q = quantize_unit(x, bits);
                assert!(q >= last, "monotone at bits={bits}");
                assert!(q >= min_val(bits) && q <= max_val(bits));
                last = q;
                x += 0.01;
            }
        }
    }

    #[test]
    fn bit_of_sign_extension() {
        // -3 in 4 bits = 1101; bits beyond MSB replicate the sign.
        let v = -3i64;
        assert!(bit_of(v, 0, 4)); // 1
        assert!(!bit_of(v, 1, 4)); // 0
        assert!(bit_of(v, 2, 4)); // 1
        assert!(bit_of(v, 3, 4)); // 1 (sign)
        assert!(bit_of(v, 7, 4)); // EB sign extension
        let p = 5i64; // 0101
        assert!(!bit_of(p, 3, 4));
        assert!(!bit_of(p, 10, 4));
    }

    #[test]
    fn prop_wrap_is_additive_homomorphism() {
        // wrap(a+b) == wrap(wrap(a)+wrap(b)) — the property that lets the
        // bit-serial CIM adder accumulate without intermediate saturation.
        check("wrap-homomorphism", &Config::default(), |c| {
            let bits = c.rng.range_i64(1, 32) as u32;
            let a = c.rng.range_i64(-(1 << 40), 1 << 40);
            let b = c.rng.range_i64(-(1 << 40), 1 << 40);
            prop_eq(
                wrap(a + b, bits),
                wrap(wrap(a, bits) + wrap(b, bits), bits),
                &format!("bits={bits} a={a} b={b}"),
            )
        });
    }

    #[test]
    fn prop_wrap_identity_in_range() {
        check("wrap-identity", &Config::default(), |c| {
            let bits = c.rng.range_i64(1, 63) as u32;
            let v = c.rng.range_i64(min_val(bits), max_val(bits));
            prop_eq(wrap(v, bits), v, &format!("bits={bits}"))
        });
    }

    #[test]
    fn prop_bits_reconstruct_value() {
        // Reassembling bits must reproduce the value: the foundation of the
        // macro's bit-serial correctness.
        let mut rng = Rng::new(0xBEEF);
        for _ in 0..500 {
            let bits = rng.range_i64(1, 32) as u32;
            let v = rng.range_i64(min_val(bits), max_val(bits));
            let mut acc: i64 = 0;
            for i in 0..bits {
                if bit_of(v, i, bits) {
                    if i == bits - 1 {
                        acc -= 1i64 << i; // MSB carries negative weight
                    } else {
                        acc += 1i64 << i;
                    }
                }
            }
            assert_eq!(acc, v, "bits={bits} v={v}");
        }
    }

    #[test]
    fn resolution_display_and_norm() {
        let r = Resolution::new(8, 16);
        assert_eq!(r.to_string(), "8b/16b");
        assert_eq!(r.norm_product(), 128);
    }

    #[test]
    #[should_panic(expected = "resolution out of supported range")]
    fn zero_bits_rejected() {
        Resolution::new(0, 8);
    }
}
