//! SNN workload model.
//!
//! Defines the spiking-CNN layer/network descriptions the rest of the stack
//! consumes: operand footprints per `(w_bits, p_bits)` resolution, the
//! paper's six-conv + three-FC SCNN (Fig. 4a), a fixed-point
//! integrate-and-fire reference implementation, and quantization helpers
//! shared with the CIM macro simulator and the energy model.

pub mod conv;
pub mod events;
pub mod layer;
pub mod lif;
pub mod network;
pub mod quant;

pub use conv::ConvLifLayer;

pub use events::{AdjacencyCache, ConvAdjacency, EventConvLayer, EventFcLayer, SpikeList};
pub use layer::{LayerKind, LayerSpec};
pub use lif::LifNeuron;
pub use network::{Network, scnn_dvs_gesture};
pub use quant::Resolution;
