//! Fixed-point convolutional integrate-and-fire layer.
//!
//! The Rust-native golden model for the conv layers: exact integer
//! semantics (wrap at `p_bits`, fire, reset-by-subtraction), matching the
//! Python oracle (`ref.if_step_conv`) and, via im2col, the CIM macro's
//! matvec execution. Used by golden tests and by workload generators that
//! need conv spike statistics without the PJRT runtime.

use super::layer::{LayerKind, LayerSpec};
use super::quant::{max_val, min_val, wrap};

/// A conv layer of IF neurons with quantized weights and persistent
/// membrane state.
#[derive(Debug, Clone)]
pub struct ConvLifLayer {
    /// Geometry (must be `LayerKind::Conv`).
    pub spec: LayerSpec,
    /// Weights `[out_ch][in_ch][k][k]` flattened row-major.
    pub weights: Vec<i64>,
    /// Membrane potentials `[out_ch][oh][ow]` flattened.
    pub v: Vec<i64>,
    /// Firing threshold.
    pub threshold: i64,
}

impl ConvLifLayer {
    /// Build from a spec and flattened weights (validated against the
    /// spec's weight count and resolution range).
    pub fn new(spec: LayerSpec, weights: Vec<i64>, threshold: i64) -> Self {
        assert!(matches!(spec.kind, LayerKind::Conv { .. }), "conv spec required");
        assert_eq!(weights.len(), spec.num_weights());
        let (lo, hi) = (min_val(spec.res.w_bits), max_val(spec.res.w_bits));
        assert!(
            weights.iter().all(|&w| (lo..=hi).contains(&w)),
            "weight exceeds {}b",
            spec.res.w_bits
        );
        assert!(threshold > 0);
        let v = vec![0i64; spec.num_neurons()];
        ConvLifLayer { spec, weights, v, threshold }
    }

    fn dims(&self) -> (usize, usize, usize, usize, usize, usize, usize) {
        match self.spec.kind {
            LayerKind::Conv { in_ch, out_ch, k, stride, pad, in_h, in_w } => {
                (in_ch, out_ch, k, stride, pad, in_h, in_w)
            }
            _ => unreachable!(),
        }
    }

    /// One timestep: binary input spikes `[in_ch * in_h * in_w]`
    /// (channel-major), returns output spikes `[out_ch * oh * ow]`.
    pub fn step(&mut self, spikes_in: &[bool]) -> Vec<bool> {
        let (in_ch, out_ch, k, stride, pad, in_h, in_w) = self.dims();
        assert_eq!(spikes_in.len(), in_ch * in_h * in_w);
        let (_, oh, ow) = self.spec.out_shape();
        let p_bits = self.spec.res.p_bits;
        let mut out = vec![false; out_ch * oh * ow];

        for oc in 0..out_ch {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc: i64 = 0;
                    for ic in 0..in_ch {
                        for dy in 0..k {
                            let iy = (oy * stride + dy) as i64 - pad as i64;
                            if iy < 0 || iy >= in_h as i64 {
                                continue;
                            }
                            for dx in 0..k {
                                let ix = (ox * stride + dx) as i64 - pad as i64;
                                if ix < 0 || ix >= in_w as i64 {
                                    continue;
                                }
                                let s = spikes_in
                                    [ic * in_h * in_w + iy as usize * in_w + ix as usize];
                                if s {
                                    acc += self.weights
                                        [((oc * in_ch + ic) * k + dy) * k + dx];
                                }
                            }
                        }
                    }
                    let idx = oc * oh * ow + oy * ow + ox;
                    let mut v = wrap(self.v[idx] + acc, p_bits);
                    if v >= self.threshold {
                        v = wrap(v - self.threshold, p_bits);
                        out[idx] = true;
                    }
                    self.v[idx] = v;
                }
            }
        }
        out
    }

    /// SOPs triggered by an input spike vector (event-driven count: each
    /// input spike reaches at most `out_ch × k × k` positions, clipped at
    /// the borders).
    pub fn sops(&self, spikes_in: &[bool]) -> u64 {
        let (in_ch, out_ch, k, stride, pad, in_h, in_w) = self.dims();
        let (_, oh, ow) = self.spec.out_shape();
        let mut count = 0u64;
        for ic in 0..in_ch {
            for iy in 0..in_h {
                for ix in 0..in_w {
                    if !spikes_in[ic * in_h * in_w + iy * in_w + ix] {
                        continue;
                    }
                    // Output positions whose receptive field covers (iy, ix).
                    let mut positions = 0u64;
                    for oy in 0..oh {
                        let dy = iy as i64 + pad as i64 - (oy * stride) as i64;
                        if !(0..k as i64).contains(&dy) {
                            continue;
                        }
                        for ox in 0..ow {
                            let dx = ix as i64 + pad as i64 - (ox * stride) as i64;
                            if (0..k as i64).contains(&dx) {
                                positions += 1;
                            }
                        }
                    }
                    count += positions * out_ch as u64;
                }
            }
        }
        count
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::Resolution;
    use crate::util::proptest_lite::{check, prop_eq, Config};

    fn small_spec() -> LayerSpec {
        LayerSpec::conv("c", 2, 3, 3, 1, 1, 5, 5, Resolution::new(4, 10))
    }

    #[test]
    fn identity_kernel_passes_spikes_through() {
        // One input channel, one output channel, center-tap kernel equal
        // to the threshold: every input spike fires its own position.
        let spec = LayerSpec::conv("id", 1, 1, 3, 1, 1, 4, 4, Resolution::new(4, 8));
        let mut w = vec![0i64; 9];
        w[4] = 7; // center tap
        let mut layer = ConvLifLayer::new(spec, w, 7);
        let mut spikes = vec![false; 16];
        spikes[5] = true;
        spikes[10] = true;
        let out = layer.step(&spikes);
        assert_eq!(out, spikes);
        assert!(layer.v.iter().all(|&v| v == 0), "reset by subtraction");
    }

    #[test]
    fn stride_and_padding_geometry() {
        let spec = LayerSpec::conv("s", 1, 1, 3, 2, 1, 6, 6, Resolution::new(4, 10));
        let (c, h, w) = spec.out_shape();
        assert_eq!((c, h, w), (1, 3, 3));
        let layer = ConvLifLayer::new(spec, vec![1; 9], 100);
        assert_eq!(layer.v.len(), 9);
    }

    #[test]
    fn prop_matches_fc_lif_via_im2col() {
        // A conv layer must equal an FC LIF layer built from its unrolled
        // (im2col) weight matrix — the same equivalence the CIM controller
        // exploits to run conv on the macro.
        check("conv-vs-im2col-fc", &Config { cases: 30, ..Default::default() }, |c| {
            let in_ch = c.rng.range_usize(1, 3);
            let out_ch = c.rng.range_usize(1, 4);
            let h = c.rng.range_usize(3, 6);
            let stride = *c.rng.choose(&[1usize, 2]);
            let res = Resolution::new(4, 12);
            let spec = LayerSpec::conv("p", in_ch, out_ch, 3, stride, 1, h, h, res);
            let weights: Vec<i64> = (0..spec.num_weights())
                .map(|_| c.rng.range_i64(-7, 7))
                .collect();
            let theta = c.rng.range_i64(1, 50);
            let mut conv = ConvLifLayer::new(spec.clone(), weights.clone(), theta);

            // Build the equivalent FC weight matrix: rows = output
            // neurons (oc, oy, ox), cols = inputs (ic, iy, ix).
            let (_, oh, ow) = spec.out_shape();
            let k = 3usize;
            let pad = 1i64;
            let in_dim = in_ch * h * h;
            let mut fc_w = vec![vec![0i64; in_dim]; out_ch * oh * ow];
            for oc in 0..out_ch {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let row = oc * oh * ow + oy * ow + ox;
                        for ic in 0..in_ch {
                            for dy in 0..k {
                                for dx in 0..k {
                                    let iy = (oy * stride + dy) as i64 - pad;
                                    let ix = (ox * stride + dx) as i64 - pad;
                                    if iy < 0 || ix < 0 || iy >= h as i64 || ix >= h as i64 {
                                        continue;
                                    }
                                    fc_w[row][ic * h * h
                                        + iy as usize * h
                                        + ix as usize] = weights
                                        [((oc * in_ch + ic) * k + dy) * k + dx];
                                }
                            }
                        }
                    }
                }
            }
            let mut fc = crate::snn::lif::LifLayer::new(fc_w, res, theta);

            for t in 0..3 {
                let spikes: Vec<bool> =
                    (0..in_dim).map(|_| c.rng.chance(0.3)).collect();
                let a = conv.step(&spikes);
                let b = fc.step(&spikes);
                prop_eq(a, b, &format!("t={t} spikes"))?;
                prop_eq(conv.v.clone(), fc.v.clone(), &format!("t={t} vmem"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn sops_counts_border_clipping() {
        let spec = LayerSpec::conv("b", 1, 2, 3, 1, 1, 4, 4, Resolution::new(4, 10));
        let layer = ConvLifLayer::new(spec, vec![1; 18], 100);
        // Corner spike reaches only 2x2 output positions; center 3x3.
        let mut corner = vec![false; 16];
        corner[0] = true;
        assert_eq!(layer.sops(&corner), 2 * 4);
        let mut center = vec![false; 16];
        center[5] = true; // (1,1)
        assert_eq!(layer.sops(&center), 2 * 9);
    }

    #[test]
    fn state_persists_and_wraps() {
        let spec = LayerSpec::conv("w", 1, 1, 1, 1, 0, 1, 1, Resolution::new(4, 4));
        let mut layer = ConvLifLayer::new(spec, vec![6], 100);
        let on = vec![true];
        layer.step(&on); // v = 6
        layer.step(&on); // v = 12 -> wraps to -4 in 4 bits
        assert_eq!(layer.v[0], -4);
    }

    #[test]
    #[should_panic(expected = "conv spec required")]
    fn rejects_fc_spec() {
        let spec = LayerSpec::fc("f", 4, 2, Resolution::new(4, 8));
        ConvLifLayer::new(spec, vec![0; 8], 1);
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn rejects_overwide_weights() {
        ConvLifLayer::new(small_spec(), vec![100; 54], 1);
    }
}
