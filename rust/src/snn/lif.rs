//! Fixed-point integrate-and-fire reference implementation.
//!
//! This is the *architectural* golden model: plain integer arithmetic with
//! the exact wrap semantics of the CIM macro's bit-serial adder. The CIM
//! simulator (`cim::macro_`) must match it bit-for-bit, and the python
//! oracle (`python/compile/kernels/ref.py`) matches it at tensor level
//! (golden-vector tests in `rust/tests/`).

use super::quant::{wrap, Resolution};

/// One integrate-and-fire neuron with a `p_bits`-wide membrane potential.
///
/// Update rule (paper Fig. 1b):
/// ```text
/// v   <- wrap(v + Σ_j w_j · s_j)       (accumulate spiking inputs)
/// out <- v >= threshold                 (compare in the PC)
/// v   <- out ? v - threshold : v        (reset by subtraction)
/// ```
#[derive(Debug, Clone)]
pub struct LifNeuron {
    /// Membrane potential (two's complement, `p_bits` wide).
    pub v: i64,
    /// Firing threshold.
    pub threshold: i64,
    /// Operand resolution.
    pub res: Resolution,
}

impl LifNeuron {
    /// New neuron at rest.
    pub fn new(res: Resolution, threshold: i64) -> Self {
        assert!(threshold > 0);
        LifNeuron { v: 0, threshold, res }
    }

    /// Accumulate a single weighted input spike: `v += w` with wrap
    /// semantics at `p_bits` (exactly the bit-serial CIM adder).
    #[inline]
    pub fn integrate(&mut self, w: i64) {
        self.v = wrap(self.v + w, self.res.p_bits);
    }

    /// Accumulate all weighted spikes of one timestep, then apply the
    /// threshold comparison and reset-by-subtraction. Returns `true` when
    /// an output spike fires.
    pub fn step(&mut self, weighted_inputs: &[i64]) -> bool {
        for &w in weighted_inputs {
            self.integrate(w);
        }
        self.fire()
    }

    /// Threshold comparison + conditional subtraction (end of timestep).
    #[inline]
    pub fn fire(&mut self) -> bool {
        if self.v >= self.threshold {
            self.v = wrap(self.v - self.threshold, self.res.p_bits);
            true
        } else {
            false
        }
    }
}

/// A dense layer of IF neurons with a quantized weight matrix, used as the
/// layer-level golden model and by the workload generators.
#[derive(Debug, Clone)]
pub struct LifLayer {
    /// Weights `[out][in]` (each `w_bits` wide).
    pub weights: Vec<Vec<i64>>,
    /// Membrane potentials per output neuron.
    pub v: Vec<i64>,
    /// Firing threshold.
    pub threshold: i64,
    /// Operand resolution.
    pub res: Resolution,
}

impl LifLayer {
    /// Create a layer from a weight matrix.
    pub fn new(weights: Vec<Vec<i64>>, res: Resolution, threshold: i64) -> Self {
        assert!(!weights.is_empty());
        let in_dim = weights[0].len();
        assert!(weights.iter().all(|r| r.len() == in_dim));
        for row in &weights {
            for &w in row {
                assert!(
                    w >= super::quant::min_val(res.w_bits)
                        && w <= super::quant::max_val(res.w_bits),
                    "weight {w} exceeds {}b", res.w_bits
                );
            }
        }
        let n = weights.len();
        LifLayer { weights, v: vec![0; n], threshold, res }
    }

    /// Number of output neurons.
    pub fn out_dim(&self) -> usize {
        self.weights.len()
    }

    /// Number of inputs.
    pub fn in_dim(&self) -> usize {
        self.weights[0].len()
    }

    /// Process one timestep of binary input spikes; returns output spikes.
    /// Event-driven: only columns with an input spike contribute (this is
    /// what makes SOP count sparsity-dependent).
    pub fn step(&mut self, spikes_in: &[bool]) -> Vec<bool> {
        assert_eq!(spikes_in.len(), self.in_dim());
        let p = self.res.p_bits;
        let mut out = vec![false; self.out_dim()];
        for (o, row) in self.weights.iter().enumerate() {
            let mut v = self.v[o];
            for (i, &s) in spikes_in.iter().enumerate() {
                if s {
                    v = wrap(v + row[i], p);
                }
            }
            if v >= self.threshold {
                v = wrap(v - self.threshold, p);
                out[o] = true;
            }
            self.v[o] = v;
        }
        out
    }

    /// Count synaptic operations for a given input spike vector.
    pub fn sops(&self, spikes_in: &[bool]) -> u64 {
        spikes_in.iter().filter(|&&s| s).count() as u64 * self.out_dim() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::quant::{max_val, min_val};
    use crate::util::proptest_lite::{check, prop_eq, Config};

    fn res() -> Resolution {
        Resolution::new(4, 8)
    }

    #[test]
    fn integrates_and_fires() {
        let mut n = LifNeuron::new(res(), 10);
        assert!(!n.step(&[3, 3]));
        assert_eq!(n.v, 6);
        assert!(n.step(&[5])); // 11 >= 10 -> fire
        assert_eq!(n.v, 1); // reset by subtraction keeps the residue
    }

    #[test]
    fn subthreshold_never_fires() {
        let mut n = LifNeuron::new(res(), 100);
        for _ in 0..10 {
            assert!(!n.step(&[7]));
        }
        assert_eq!(n.v, 70);
    }

    #[test]
    fn wraparound_matches_two_complement() {
        let mut n = LifNeuron::new(Resolution::new(4, 4), 7);
        // 4-bit potential: range [-8, 7]. 6 + 5 = 11 -> wraps to -5.
        n.integrate(6);
        n.integrate(5);
        assert_eq!(n.v, -5);
    }

    #[test]
    fn negative_weights_inhibit() {
        let mut n = LifNeuron::new(res(), 5);
        n.step(&[4, -3]);
        assert_eq!(n.v, 1);
        assert!(!n.fire());
    }

    #[test]
    fn layer_event_driven_equals_dense_matmul() {
        // With no wraparound, one LIF step == integer matvec on the spike
        // mask followed by threshold/reset.
        check("lif-layer-vs-matvec", &Config { cases: 64, ..Default::default() }, |c| {
            let res = Resolution::new(4, 16); // wide potential: no wrap
            let out_dim = c.rng.range_usize(1, 6);
            let in_dim = c.rng.range_usize(1, c.size.max(1).min(16));
            let weights: Vec<Vec<i64>> = (0..out_dim)
                .map(|_| {
                    (0..in_dim)
                        .map(|_| c.rng.range_i64(min_val(4), max_val(4)))
                        .collect()
                })
                .collect();
            let spikes: Vec<bool> = (0..in_dim).map(|_| c.rng.chance(0.4)).collect();
            let threshold = c.rng.range_i64(1, 20);
            let mut layer = LifLayer::new(weights.clone(), res, threshold);
            let out = layer.step(&spikes);
            for o in 0..out_dim {
                let acc: i64 = weights[o]
                    .iter()
                    .zip(&spikes)
                    .filter(|(_, &s)| s)
                    .map(|(&w, _)| w)
                    .sum();
                let fired = acc >= threshold;
                prop_eq(out[o], fired, &format!("neuron {o} spike"))?;
                let expect_v = if fired { acc - threshold } else { acc };
                prop_eq(layer.v[o], expect_v, &format!("neuron {o} vmem"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn layer_state_persists_across_timesteps() {
        let w = vec![vec![2, 2]];
        let mut layer = LifLayer::new(w, Resolution::new(4, 8), 7);
        let all = vec![true, true];
        assert_eq!(layer.step(&all), vec![false]); // v = 4
        assert_eq!(layer.step(&all), vec![true]); // v = 8 >= 7 -> 1
        assert_eq!(layer.v[0], 1);
    }

    #[test]
    fn sop_count_tracks_sparsity() {
        let w = vec![vec![1; 100]; 10];
        let layer = LifLayer::new(w, res(), 5);
        let dense = vec![true; 100];
        let sparse: Vec<bool> = (0..100).map(|i| i % 10 == 0).collect();
        assert_eq!(layer.sops(&dense), 1000);
        assert_eq!(layer.sops(&sparse), 100);
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn overwide_weight_rejected() {
        LifLayer::new(vec![vec![100]], Resolution::new(4, 8), 1);
    }
}
