//! Layer specifications and operand-footprint accounting.
//!
//! The hybrid-stationary dataflow decision (paper §II-B, Fig. 4) is driven
//! entirely by per-layer memory requirements of the two operand classes:
//! weights (stationary under WS) and membrane potentials (stationary under
//! OS). This module computes those footprints for arbitrary resolutions.

use super::quant::Resolution;

/// Geometry of a layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// 2-D convolution over a `in_ch × in_h × in_w` spike tensor.
    Conv {
        /// Input channels.
        in_ch: usize,
        /// Output channels.
        out_ch: usize,
        /// Square kernel size.
        k: usize,
        /// Stride (same both dims).
        stride: usize,
        /// Symmetric zero padding.
        pad: usize,
        /// Input height.
        in_h: usize,
        /// Input width.
        in_w: usize,
    },
    /// Fully-connected layer.
    Fc {
        /// Input neurons.
        in_dim: usize,
        /// Output neurons.
        out_dim: usize,
    },
}

/// A layer of the spiking CNN: geometry plus per-operand resolution.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    /// Human-readable name (`"L1"`, `"FC2"`, …).
    pub name: String,
    /// Geometry.
    pub kind: LayerKind,
    /// Operand resolution (weight / membrane-potential bit-widths).
    pub res: Resolution,
    /// Integrate-and-fire threshold in weight-LSB units.
    pub threshold: i64,
}

impl LayerSpec {
    /// Convolution constructor.
    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        name: &str,
        in_ch: usize,
        out_ch: usize,
        k: usize,
        stride: usize,
        pad: usize,
        in_h: usize,
        in_w: usize,
        res: Resolution,
    ) -> Self {
        assert!(k > 0 && stride > 0 && in_h >= k && in_w >= k);
        LayerSpec {
            name: name.to_string(),
            kind: LayerKind::Conv { in_ch, out_ch, k, stride, pad, in_h, in_w },
            res,
            threshold: default_threshold(res),
        }
    }

    /// Fully-connected constructor.
    pub fn fc(name: &str, in_dim: usize, out_dim: usize, res: Resolution) -> Self {
        assert!(in_dim > 0 && out_dim > 0);
        LayerSpec {
            name: name.to_string(),
            kind: LayerKind::Fc { in_dim, out_dim },
            res,
            threshold: default_threshold(res),
        }
    }

    /// Output spatial size `(channels, height, width)`; FC maps to
    /// `(out_dim, 1, 1)`.
    pub fn out_shape(&self) -> (usize, usize, usize) {
        match self.kind {
            LayerKind::Conv { out_ch, k, stride, pad, in_h, in_w, .. } => {
                let oh = (in_h + 2 * pad - k) / stride + 1;
                let ow = (in_w + 2 * pad - k) / stride + 1;
                (out_ch, oh, ow)
            }
            LayerKind::Fc { out_dim, .. } => (out_dim, 1, 1),
        }
    }

    /// Input shape `(channels, height, width)`.
    pub fn in_shape(&self) -> (usize, usize, usize) {
        match self.kind {
            LayerKind::Conv { in_ch, in_h, in_w, .. } => (in_ch, in_h, in_w),
            LayerKind::Fc { in_dim, .. } => (in_dim, 1, 1),
        }
    }

    /// Number of weights (no biases in the IF model).
    pub fn num_weights(&self) -> usize {
        match self.kind {
            LayerKind::Conv { in_ch, out_ch, k, .. } => in_ch * out_ch * k * k,
            LayerKind::Fc { in_dim, out_dim } => in_dim * out_dim,
        }
    }

    /// Number of output neurons (= membrane potentials to keep).
    pub fn num_neurons(&self) -> usize {
        let (c, h, w) = self.out_shape();
        c * h * w
    }

    /// Weight footprint in bits at this layer's resolution.
    pub fn weight_bits(&self) -> u64 {
        self.num_weights() as u64 * self.res.w_bits as u64
    }

    /// Membrane-potential footprint in bits at this layer's resolution.
    pub fn vmem_bits(&self) -> u64 {
        self.num_neurons() as u64 * self.res.p_bits as u64
    }

    /// Synaptic operations per timestep at dense (0 % sparsity) input:
    /// one SOP = one accumulate + membrane update (Table I footnote `*`).
    pub fn sops_dense(&self) -> u64 {
        match self.kind {
            LayerKind::Conv { in_ch, k, .. } => {
                self.num_neurons() as u64 * (in_ch * k * k) as u64
            }
            LayerKind::Fc { in_dim, .. } => self.num_neurons() as u64 * in_dim as u64,
        }
    }

    /// Fan-in per output neuron.
    pub fn fan_in(&self) -> usize {
        match self.kind {
            LayerKind::Conv { in_ch, k, .. } => in_ch * k * k,
            LayerKind::Fc { in_dim, .. } => in_dim,
        }
    }

    /// Clone with a different resolution (used by the Fig. 6 sweeps).
    pub fn with_resolution(&self, res: Resolution) -> LayerSpec {
        let mut l = self.clone();
        l.res = res;
        l.threshold = default_threshold(res);
        l
    }
}

/// Default IF threshold: half the positive membrane range, a common choice
/// that keeps quantized IF neurons in their useful dynamic range.
pub fn default_threshold(res: Resolution) -> i64 {
    (crate::snn::quant::max_val(res.p_bits) / 2).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn r88() -> Resolution {
        Resolution::new(8, 8)
    }

    #[test]
    fn conv_shapes() {
        let l = LayerSpec::conv("L1", 2, 16, 3, 1, 1, 64, 64, r88());
        assert_eq!(l.out_shape(), (16, 64, 64));
        assert_eq!(l.in_shape(), (2, 64, 64));
        assert_eq!(l.num_weights(), 2 * 16 * 9);
        assert_eq!(l.num_neurons(), 16 * 64 * 64);
        assert_eq!(l.fan_in(), 18);
    }

    #[test]
    fn conv_stride_shapes() {
        let l = LayerSpec::conv("L2", 16, 32, 3, 2, 1, 64, 64, r88());
        assert_eq!(l.out_shape(), (32, 32, 32));
    }

    #[test]
    fn conv_no_pad() {
        let l = LayerSpec::conv("c", 1, 1, 3, 1, 0, 5, 5, r88());
        assert_eq!(l.out_shape(), (1, 3, 3));
    }

    #[test]
    fn fc_shapes() {
        let l = LayerSpec::fc("FC1", 512, 10, r88());
        assert_eq!(l.out_shape(), (10, 1, 1));
        assert_eq!(l.num_weights(), 5120);
        assert_eq!(l.num_neurons(), 10);
        assert_eq!(l.sops_dense(), 5120);
    }

    #[test]
    fn footprints_scale_with_resolution() {
        let l = LayerSpec::fc("FC", 100, 10, Resolution::new(5, 10));
        assert_eq!(l.weight_bits(), 1000 * 5);
        assert_eq!(l.vmem_bits(), 10 * 10);
        let l2 = l.with_resolution(Resolution::new(3, 7));
        assert_eq!(l2.weight_bits(), 3000);
        assert_eq!(l2.vmem_bits(), 70);
    }

    #[test]
    fn sops_conv() {
        let l = LayerSpec::conv("c", 2, 4, 3, 1, 1, 8, 8, r88());
        // 4*8*8 neurons × fan-in 18
        assert_eq!(l.sops_dense(), 256 * 18);
    }

    #[test]
    fn threshold_positive_and_in_range() {
        for p in 2..20 {
            let t = default_threshold(Resolution::new(4, p));
            assert!(t >= 1);
            assert!(t <= crate::snn::quant::max_val(p));
        }
    }
}
