//! Network-level workload description.
//!
//! [`scnn_dvs_gesture`] is the paper's reference workload (Fig. 4a): a
//! six-convolution spiking CNN followed by three fully-connected layers,
//! sized for DVS-gesture-style 2×64×64 event frames and 10 output classes.
//! Early conv layers are membrane-potential dominated (OS-friendly), late
//! layers weight dominated (WS-friendly) — exactly the asymmetry that makes
//! the hybrid-stationary dataflow pay off.

use super::layer::{LayerKind, LayerSpec};
use super::quant::Resolution;

/// An ordered stack of layers forming the SNN workload.
#[derive(Debug, Clone)]
pub struct Network {
    /// Model name for reports.
    pub name: String,
    /// Layers, input to output.
    pub layers: Vec<LayerSpec>,
    /// Number of timesteps per inference (per-timestep execution, Fig. 1c).
    pub timesteps: usize,
}

impl Network {
    /// Validate inter-layer shape compatibility and return the network.
    pub fn new(name: &str, layers: Vec<LayerSpec>, timesteps: usize) -> Self {
        assert!(!layers.is_empty() && timesteps > 0);
        for w in layers.windows(2) {
            let (c, h, wd) = w[0].out_shape();
            let expect = c * h * wd;
            let (ic, ih, iw) = w[1].in_shape();
            let got = ic * ih * iw;
            assert_eq!(
                expect, got,
                "shape mismatch {} -> {}: {} vs {}",
                w[0].name, w[1].name, expect, got
            );
        }
        Network { name: name.to_string(), layers, timesteps }
    }

    /// Total weight footprint in bits.
    pub fn total_weight_bits(&self) -> u64 {
        self.layers.iter().map(LayerSpec::weight_bits).sum()
    }

    /// Total membrane-potential footprint in bits.
    pub fn total_vmem_bits(&self) -> u64 {
        self.layers.iter().map(LayerSpec::vmem_bits).sum()
    }

    /// Model size in bits excluding FC layers (Fig. 6b reports conv-only).
    pub fn conv_weight_bits(&self) -> u64 {
        self.layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv { .. }))
            .map(LayerSpec::weight_bits)
            .sum()
    }

    /// Dense SOPs per timestep over all layers.
    pub fn sops_dense(&self) -> u64 {
        self.layers.iter().map(LayerSpec::sops_dense).sum()
    }

    /// Replace every layer's resolution (uniform sweep helper, Fig. 6b).
    pub fn with_uniform_resolution(&self, res: Resolution) -> Network {
        Network {
            name: self.name.clone(),
            layers: self.layers.iter().map(|l| l.with_resolution(res)).collect(),
            timesteps: self.timesteps,
        }
    }

    /// Replace resolutions per layer (must match layer count).
    pub fn with_resolutions(&self, res: &[Resolution]) -> Network {
        assert_eq!(res.len(), self.layers.len());
        Network {
            name: self.name.clone(),
            layers: self
                .layers
                .iter()
                .zip(res)
                .map(|(l, r)| l.with_resolution(*r))
                .collect(),
            timesteps: self.timesteps,
        }
    }
}

/// The paper's six-conv + three-FC SCNN for IBM-DVS-gesture-class workloads
/// (Fig. 4a), at the FlexSpIM *unconstrained* per-layer resolutions of
/// Fig. 6a. Input: 2×48×48 binary event frames (polarity channels of the
/// downsampled DVS stream); output: 10 classes.
///
/// Dimensions are chosen so that (a) early layers are membrane-potential
/// dominated and late layers weight dominated (the Fig. 4a crossover), and
/// (b) the sum of each layer's smaller operand fits two 16-kB macros — the
/// paper's observation that *two* macros suffice for full hybrid
/// stationarity of at least one operand per layer (§II-B).
pub fn scnn_dvs_gesture() -> Network {
    // Fig. 6a's fine-grained per-layer resolutions (bitwise granularity):
    // early layers tolerate narrow potentials; later layers narrow weights.
    let r = |w, p| Resolution::new(w, p);
    let layers = vec![
        LayerSpec::conv("L1", 2, 12, 3, 1, 1, 48, 48, r(4, 9)),
        LayerSpec::conv("L2", 12, 24, 3, 2, 1, 48, 48, r(5, 10)),
        LayerSpec::conv("L3", 24, 24, 3, 1, 1, 24, 24, r(5, 10)),
        LayerSpec::conv("L4", 24, 48, 3, 2, 1, 24, 24, r(6, 11)),
        LayerSpec::conv("L5", 48, 48, 3, 1, 1, 12, 12, r(6, 11)),
        LayerSpec::conv("L6", 48, 96, 3, 2, 1, 12, 12, r(7, 12)),
        LayerSpec::fc("FC1", 96 * 6 * 6, 256, r(5, 10)),
        LayerSpec::fc("FC2", 256, 128, r(5, 10)),
        LayerSpec::fc("FC3", 128, 10, r(7, 12)),
    ];
    Network::new("SCNN-DVS-gesture", layers, 16)
}

/// The same SCNN constrained to the fixed resolution menu of [4]
/// (ISSCC'24: 4/8-bit weights, 16-bit membrane potentials) — the
/// comparison point of Fig. 6a / Fig. 7c.
pub fn scnn_constrained_isscc24() -> Network {
    let base = scnn_dvs_gesture();
    let res: Vec<Resolution> = base
        .layers
        .iter()
        .map(|l| {
            // Round each weight width up to the nearest supported option.
            let w = if l.res.w_bits <= 4 { 4 } else { 8 };
            Resolution::new(w, 16)
        })
        .collect();
    let mut n = base.with_resolutions(&res);
    n.name = "SCNN-constrained-[4]".into();
    n
}

/// The same SCNN at IMPULSE's fixed 6-bit weight / 11-bit membrane
/// resolution [3] — the comparison point of Fig. 7d.
pub fn scnn_impulse_resolution() -> Network {
    let mut n = scnn_dvs_gesture().with_uniform_resolution(Resolution::new(6, 11));
    n.name = "SCNN-IMPULSE-6b11b".into();
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_network_is_consistent() {
        let n = scnn_dvs_gesture();
        assert_eq!(n.layers.len(), 9);
        assert_eq!(n.layers[0].in_shape(), (2, 48, 48));
        assert_eq!(n.layers[5].out_shape(), (96, 6, 6));
        assert_eq!(n.layers[8].out_shape(), (10, 1, 1));
    }

    #[test]
    fn early_layers_vmem_dominated_late_weight_dominated() {
        // The asymmetry that motivates hybrid stationarity (paper §I, §II-B).
        let n = scnn_dvs_gesture();
        let l1 = &n.layers[0];
        assert!(
            l1.vmem_bits() > 10 * l1.weight_bits(),
            "L1 must be strongly vmem-dominated: {} vs {}",
            l1.vmem_bits(),
            l1.weight_bits()
        );
        let l6 = &n.layers[5];
        assert!(
            l6.weight_bits() > l6.vmem_bits(),
            "L6 must be weight-dominated: {} vs {}",
            l6.weight_bits(),
            l6.vmem_bits()
        );
    }

    #[test]
    fn constrained_network_is_larger() {
        // Fig. 6a: flexible per-layer resolution shrinks the model ~30 %
        // versus the fixed menu of [4].
        let flex = scnn_dvs_gesture();
        let fixed = scnn_constrained_isscc24();
        let f = flex.total_weight_bits() as f64;
        let c = fixed.total_weight_bits() as f64;
        let reduction = 1.0 - f / c;
        assert!(
            reduction > 0.15 && reduction < 0.5,
            "footprint reduction {reduction:.3} outside plausible band"
        );
    }

    #[test]
    fn uniform_resolution_override() {
        let n = scnn_dvs_gesture().with_uniform_resolution(Resolution::new(2, 4));
        assert!(n.layers.iter().all(|l| l.res == Resolution::new(2, 4)));
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_mismatch_detected() {
        let r = Resolution::new(8, 8);
        Network::new(
            "bad",
            vec![
                LayerSpec::fc("a", 10, 20, r),
                LayerSpec::fc("b", 21, 5, r),
            ],
            1,
        );
    }

    #[test]
    fn impulse_resolution_applied() {
        let n = scnn_impulse_resolution();
        assert!(n.layers.iter().all(|l| l.res == Resolution::new(6, 11)));
    }

    #[test]
    fn sops_positive_and_conv_dominated() {
        let n = scnn_dvs_gesture();
        let conv: u64 = n.layers[..6].iter().map(|l| l.sops_dense()).sum();
        let fc: u64 = n.layers[6..].iter().map(|l| l.sops_dense()).sum();
        assert!(conv > fc, "conv stack dominates compute");
    }
}
