//! Event-driven sparse spike datapath.
//!
//! The paper's headline is *event-based* execution: synaptic accumulates
//! fire only on input spikes (Fig. 1c/2c), so the work per timestep scales
//! with spike activity, not with layer size. This module makes that
//! structural in the software engine:
//!
//! * [`SpikeList`] — the first-class sparse spike representation (sorted
//!   active indices over a known dense dimension). The whole runtime
//!   datapath — encoder → [`crate::runtime::StepBackend`] → coordinator —
//!   moves spikes in this form; dense `Vec<bool>` survives only at the
//!   golden-model boundary.
//! * [`ConvAdjacency`] — per-layer precomputed scatter adjacency: conv
//!   geometry compiled once into CSR-style per-input-position synapse
//!   offsets, so each event walks straight to the output taps its
//!   receptive field covers (no per-event stride/pad arithmetic on the
//!   clipped borders).
//! * [`EventConvLayer`] / [`EventFcLayer`] — event-driven stepping that
//!   only touches the membrane potentials of neurons reached by an active
//!   spike, and fire-checks only touched neurons plus the *refire set*
//!   (see below).
//!
//! **Soundness of sparse fire-checking.** Reset-by-subtraction leaves a
//! residual `v - θ` that can itself still clear the threshold (when
//! `v ≥ 2θ`), and the dense golden models fire-check *every* neuron
//! *every* timestep — an untouched neuron with `v ≥ θ` fires on zero
//! input. The sparse layers therefore carry the set of neurons whose
//! potential still clears the threshold after each step (`pending`) into
//! the next step's fire-check. Untouched neurons with `v < θ` are
//! genuinely inert (their potential is unchanged and below threshold), so
//! skipping them is exact, not approximate. Bit-identity with the dense
//! oracles ([`crate::snn::conv::ConvLifLayer`] /
//! [`crate::snn::lif::LifLayer`]) over random geometries and resolutions
//! is pinned by `rust/tests/property_sparse.rs`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::layer::{LayerKind, LayerSpec};
use super::quant::{max_val, min_val, wrap, Resolution};

// -------------------------------------------------------------- spike list

/// A sparse binary spike vector: the sorted indices of the active bits
/// over a known dense dimension.
///
/// This is the AER-native representation the accelerator's event queues
/// move — storage and bandwidth scale with activity, and the event-driven
/// layers consume it directly without a densify step.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct SpikeList {
    /// Active indices, strictly increasing.
    indices: Vec<u32>,
    /// Dense dimension of the underlying spike vector.
    dim: usize,
}

impl SpikeList {
    /// The all-silent spike vector of dimension `dim`.
    pub fn empty(dim: usize) -> SpikeList {
        SpikeList { indices: Vec::new(), dim }
    }

    /// Build from a dense boolean vector (indices come out sorted).
    pub fn from_dense(bits: &[bool]) -> SpikeList {
        let indices = bits
            .iter()
            .enumerate()
            .filter(|(_, &b)| b)
            .map(|(i, _)| i as u32)
            .collect();
        SpikeList { indices, dim: bits.len() }
    }

    /// Build from a dense 0/1 `i32` vector (any non-zero is a spike) —
    /// the PJRT tensor boundary.
    pub fn from_i32_dense(vals: &[i32]) -> SpikeList {
        let indices = vals
            .iter()
            .enumerate()
            .filter(|(_, &v)| v != 0)
            .map(|(i, _)| i as u32)
            .collect();
        SpikeList { indices, dim: vals.len() }
    }

    /// Build from already-sorted active indices. Sortedness, uniqueness,
    /// and bounds are asserted — a malformed spike list is a caller bug,
    /// not a recoverable condition.
    pub fn from_sorted(indices: Vec<u32>, dim: usize) -> SpikeList {
        assert!(
            indices.windows(2).all(|w| w[0] < w[1]),
            "spike indices must be strictly increasing"
        );
        if let Some(&last) = indices.last() {
            assert!((last as usize) < dim, "spike index {last} out of dim {dim}");
        }
        SpikeList { indices, dim }
    }

    /// Dense dimension of the spike vector.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of active spikes.
    pub fn count(&self) -> usize {
        self.indices.len()
    }

    /// True when no spike is active.
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// The sorted active indices.
    pub fn active(&self) -> &[u32] {
        &self.indices
    }

    /// Active fraction (`count / dim`; 0 for a zero-dim list).
    pub fn activity(&self) -> f64 {
        if self.dim == 0 {
            return 0.0;
        }
        self.indices.len() as f64 / self.dim as f64
    }

    /// Densify to booleans (golden-model boundary).
    pub fn to_dense(&self) -> Vec<bool> {
        let mut bits = vec![false; self.dim];
        for &i in &self.indices {
            bits[i as usize] = true;
        }
        bits
    }

    /// Densify to the 0/1 `i32` layout the PJRT artifacts expect.
    pub fn to_i32(&self) -> Vec<i32> {
        let mut vals = vec![0i32; self.dim];
        for &i in &self.indices {
            vals[i as usize] = 1;
        }
        vals
    }
}

// ---------------------------------------------------------- conv adjacency

/// One precomputed synapse tap: an input spatial position reaches output
/// position `out_pos` through kernel element `ker_pos`.
#[derive(Debug, Clone, Copy)]
struct Tap {
    /// `oy * ow + ox` of the reached output position.
    out_pos: u32,
    /// `dy * k + dx` of the kernel element connecting them.
    ker_pos: u32,
}

/// CSR-style scatter adjacency for a conv layer: for every input spatial
/// position, the list of (output position, kernel element) taps its spikes
/// reach, with border clipping folded in at build time.
///
/// The spatial structure is channel-independent, so one adjacency row per
/// `(iy, ix)` serves all `in_ch × out_ch` channel pairs — the per-event
/// walk adds the channel strides on top.
#[derive(Debug, Clone)]
pub struct ConvAdjacency {
    /// The geometry this adjacency was compiled for (shared-table safety
    /// check — see [`EventConvLayer::with_adjacency`]).
    key: AdjKey,
    /// Row offsets into `taps`, one row per input position (`in_h × in_w`
    /// rows, `offsets.len() == rows + 1`).
    offsets: Vec<u32>,
    taps: Vec<Tap>,
}

impl ConvAdjacency {
    /// Compile the scatter adjacency of `spec` (must be a conv layer).
    pub fn build(spec: &LayerSpec) -> ConvAdjacency {
        let key = geometry_key(spec);
        let (k, stride, pad, in_h, in_w) = key;
        let (_, oh, ow) = spec.out_shape();
        let mut offsets = Vec::with_capacity(in_h * in_w + 1);
        let mut taps = Vec::new();
        offsets.push(0u32);
        for iy in 0..in_h {
            for ix in 0..in_w {
                for dy in 0..k {
                    // Output row oy with oy*stride + dy - pad == iy.
                    let oy_num = iy as i64 + pad as i64 - dy as i64;
                    if oy_num < 0 || oy_num % stride as i64 != 0 {
                        continue;
                    }
                    let oy = (oy_num / stride as i64) as usize;
                    if oy >= oh {
                        continue;
                    }
                    for dx in 0..k {
                        let ox_num = ix as i64 + pad as i64 - dx as i64;
                        if ox_num < 0 || ox_num % stride as i64 != 0 {
                            continue;
                        }
                        let ox = (ox_num / stride as i64) as usize;
                        if ox >= ow {
                            continue;
                        }
                        taps.push(Tap {
                            out_pos: (oy * ow + ox) as u32,
                            ker_pos: (dy * k + dx) as u32,
                        });
                    }
                }
                offsets.push(taps.len() as u32);
            }
        }
        ConvAdjacency { key, offsets, taps }
    }

    /// Total taps across all input positions (diagnostics: equals the sum
    /// of per-position receptive-output counts, i.e. `sops / out_ch` of a
    /// fully dense frame).
    pub fn tap_count(&self) -> usize {
        self.taps.len()
    }
}

/// Geometry key of a conv adjacency: `(k, stride, pad, in_h, in_w)` —
/// everything [`ConvAdjacency::build`] depends on. Channel counts and
/// operand resolutions do not shape the spatial scatter pattern, so layers
/// that differ only in those share one table.
type AdjKey = (usize, usize, usize, usize, usize);

/// The [`AdjKey`] of a conv layer spec (panics on FC specs).
fn geometry_key(spec: &LayerSpec) -> AdjKey {
    match spec.kind {
        LayerKind::Conv { k, stride, pad, in_h, in_w, .. } => (k, stride, pad, in_h, in_w),
        _ => panic!("conv spec required"),
    }
}

/// Shared, thread-safe cache of [`ConvAdjacency`] tables keyed by conv
/// geometry.
///
/// The adjacency is read-only and a pure function of geometry, so one
/// table can serve every rebuild of [`crate::runtime::NativeScnn`] across
/// a resolution sweep *and* every worker of the parallel engine / serve
/// pool. Build cost is paid once per distinct geometry; every later lookup
/// is an `Arc` clone. Share it by cloning the `Arc<AdjacencyCache>` into
/// each backend factory closure.
#[derive(Debug, Default)]
pub struct AdjacencyCache {
    state: Mutex<CacheState>,
}

#[derive(Debug, Default)]
struct CacheState {
    map: HashMap<AdjKey, Arc<ConvAdjacency>>,
    hits: u64,
}

impl AdjacencyCache {
    /// An empty cache.
    pub fn new() -> AdjacencyCache {
        AdjacencyCache::default()
    }

    /// The adjacency for `spec` (must be a conv layer): built on first
    /// use, shared afterwards.
    pub fn get_or_build(&self, spec: &LayerSpec) -> Arc<ConvAdjacency> {
        let key = geometry_key(spec);
        let mut st = self.state.lock().unwrap();
        if let Some(adj) = st.map.get(&key) {
            st.hits += 1;
            return adj.clone();
        }
        let adj = Arc::new(ConvAdjacency::build(spec));
        st.map.insert(key, adj.clone());
        adj
    }

    /// Distinct geometries cached so far.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().map.len()
    }

    /// True when nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups served from the cache (observability for the sharing tests).
    pub fn hits(&self) -> u64 {
        self.state.lock().unwrap().hits
    }
}

// ------------------------------------------------------- event conv layer

/// Event-driven conv layer of IF neurons: bit-identical to
/// [`crate::snn::conv::ConvLifLayer`] but with per-timestep work
/// proportional to input activity instead of layer size.
#[derive(Debug, Clone)]
pub struct EventConvLayer {
    /// Geometry (must be `LayerKind::Conv`).
    pub spec: LayerSpec,
    /// Weights `[out_ch][in_ch][k][k]` flattened row-major (dense layout,
    /// indexed through the adjacency's kernel positions).
    weights: Vec<i64>,
    /// Shared read-only scatter adjacency (see [`AdjacencyCache`]).
    adj: Arc<ConvAdjacency>,
    /// Membrane potentials `[out_ch][oh][ow]` flattened.
    v: Vec<i64>,
    /// Firing threshold.
    pub threshold: i64,
    /// Refire set: neurons whose potential still clears the threshold
    /// after the previous step (sorted) — they fire on zero input, exactly
    /// as the dense per-neuron scan would.
    pending: Vec<u32>,
    // Scratch (persistent to avoid per-step allocation): per-neuron raw
    // accumulator, valid only where `stamp == generation`.
    acc: Vec<i64>,
    stamp: Vec<u32>,
    generation: u32,
    touched: Vec<u32>,
}

impl EventConvLayer {
    /// Build from a spec and flattened weights — same validation as the
    /// dense golden model. The scatter adjacency is compiled privately;
    /// use [`Self::with_adjacency`] to share one across layers/instances.
    pub fn new(spec: LayerSpec, weights: Vec<i64>, threshold: i64) -> Self {
        let adj = Arc::new(ConvAdjacency::build(&spec));
        Self::with_adjacency(spec, weights, threshold, adj)
    }

    /// Build with a shared precomputed adjacency (see [`AdjacencyCache`]):
    /// the adjacency depends only on conv geometry, so resolution rebuilds
    /// and sibling engine workers reuse one table instead of recompiling
    /// it per instance.
    pub fn with_adjacency(
        spec: LayerSpec,
        weights: Vec<i64>,
        threshold: i64,
        adj: Arc<ConvAdjacency>,
    ) -> Self {
        assert_eq!(
            adj.key,
            geometry_key(&spec),
            "adjacency does not match the layer geometry"
        );
        assert_eq!(weights.len(), spec.num_weights());
        let (lo, hi) = (min_val(spec.res.w_bits), max_val(spec.res.w_bits));
        assert!(
            weights.iter().all(|&w| (lo..=hi).contains(&w)),
            "weight exceeds {}b",
            spec.res.w_bits
        );
        assert!(threshold > 0);
        let n = spec.num_neurons();
        EventConvLayer {
            spec,
            weights,
            adj,
            v: vec![0i64; n],
            threshold,
            pending: Vec::new(),
            acc: vec![0i64; n],
            stamp: vec![0u32; n],
            generation: 0,
            touched: Vec::new(),
        }
    }

    fn dims(&self) -> (usize, usize, usize, usize, usize) {
        match self.spec.kind {
            LayerKind::Conv { in_ch, out_ch, k, in_h, in_w, .. } => {
                (in_ch, out_ch, k, in_h, in_w)
            }
            _ => unreachable!(),
        }
    }

    /// Current membrane potentials.
    pub fn vmem(&self) -> &[i64] {
        &self.v
    }

    /// Overwrite the membrane state (snapshot restore). The refire set is
    /// recomputed from the new potentials — restoring mid-stream must
    /// reproduce exactly the fire-checks the dense scan would perform.
    pub fn set_vmem(&mut self, v: &[i64]) {
        self.v.copy_from_slice(v);
        self.rebuild_pending();
    }

    /// Zero all membrane potentials.
    pub fn reset(&mut self) {
        self.v.iter_mut().for_each(|x| *x = 0);
        self.pending.clear();
    }

    fn rebuild_pending(&mut self) {
        self.pending.clear();
        for (i, &v) in self.v.iter().enumerate() {
            if v >= self.threshold {
                self.pending.push(i as u32);
            }
        }
    }

    /// One event-driven timestep: scatter every input spike through the
    /// adjacency, then fire-check the touched ∪ refire neurons only.
    pub fn step(&mut self, spikes_in: &SpikeList) -> SpikeList {
        let (in_ch, out_ch, k, in_h, in_w) = self.dims();
        assert_eq!(spikes_in.dim(), in_ch * in_h * in_w);
        let (_, oh, ow) = self.spec.out_shape();
        let plane = in_h * in_w;
        let out_plane = oh * ow;
        let kk = k * k;
        let p_bits = self.spec.res.p_bits;

        self.generation = self.generation.wrapping_add(1);
        if self.generation == 0 {
            // Stamp wrap-around (once per 2^32 steps): clear and restart.
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.generation = 1;
        }
        let gen = self.generation;

        for &idx in spikes_in.active() {
            let idx = idx as usize;
            let ic = idx / plane;
            let pos = idx % plane;
            let lo = self.adj.offsets[pos] as usize;
            let hi = self.adj.offsets[pos + 1] as usize;
            for oc in 0..out_ch {
                let w_base = (oc * in_ch + ic) * kk;
                let v_base = oc * out_plane;
                for t in &self.adj.taps[lo..hi] {
                    let n = v_base + t.out_pos as usize;
                    let w = self.weights[w_base + t.ker_pos as usize];
                    if self.stamp[n] == gen {
                        self.acc[n] += w;
                    } else {
                        self.stamp[n] = gen;
                        self.acc[n] = w;
                        self.touched.push(n as u32);
                    }
                }
            }
        }

        // Refire set: untouched neurons whose residual potential still
        // clears the threshold fire on zero input (reset-by-subtraction
        // leaves v ≥ θ when the pre-reset potential was ≥ 2θ).
        let pending = std::mem::take(&mut self.pending);
        for &n in &pending {
            let ni = n as usize;
            if self.stamp[ni] != gen {
                self.stamp[ni] = gen;
                self.acc[ni] = 0;
                self.touched.push(n);
            }
        }

        // Sorted processing keeps the output spike order identical to the
        // dense per-neuron scan.
        self.touched.sort_unstable();
        let mut out = Vec::new();
        let mut next_pending = Vec::new();
        for &n in &self.touched {
            let ni = n as usize;
            let mut v = wrap(self.v[ni] + self.acc[ni], p_bits);
            if v >= self.threshold {
                v = wrap(v - self.threshold, p_bits);
                out.push(n);
            }
            self.v[ni] = v;
            if v >= self.threshold {
                next_pending.push(n);
            }
        }
        self.touched.clear();
        self.pending = next_pending;
        SpikeList::from_sorted(out, out_ch * out_plane)
    }
}

// --------------------------------------------------------- event FC layer

/// Event-driven fully-connected layer of IF neurons: bit-identical to
/// [`crate::snn::lif::LifLayer`]. The weight matrix is stored transposed
/// (per presynaptic neuron), so each active input adds one contiguous
/// column — the classic event-driven SNN layout. An FC layer's fan-out is
/// structurally dense, so any active input touches every neuron; the
/// sparsity win is on the input side, and an all-silent timestep reduces
/// to the refire set alone.
#[derive(Debug, Clone)]
pub struct EventFcLayer {
    /// Transposed weights: `wt[i * out_dim + o]` (column of input `i`
    /// contiguous).
    wt: Vec<i64>,
    in_dim: usize,
    out_dim: usize,
    v: Vec<i64>,
    /// Firing threshold.
    pub threshold: i64,
    /// Operand resolution.
    pub res: Resolution,
    /// Refire set (see [`EventConvLayer::step`]).
    pending: Vec<u32>,
    /// Per-step accumulator scratch (`out_dim` entries).
    acc: Vec<i64>,
}

impl EventFcLayer {
    /// Create from a `[out][in]` weight matrix — same validation as the
    /// dense golden model, transposed internally.
    pub fn new(weights: Vec<Vec<i64>>, res: Resolution, threshold: i64) -> Self {
        assert!(!weights.is_empty());
        assert!(threshold > 0);
        let out_dim = weights.len();
        let in_dim = weights[0].len();
        assert!(weights.iter().all(|r| r.len() == in_dim));
        let (lo, hi) = (min_val(res.w_bits), max_val(res.w_bits));
        let mut wt = vec![0i64; in_dim * out_dim];
        for (o, row) in weights.iter().enumerate() {
            for (i, &w) in row.iter().enumerate() {
                assert!((lo..=hi).contains(&w), "weight {w} exceeds {}b", res.w_bits);
                wt[i * out_dim + o] = w;
            }
        }
        EventFcLayer {
            wt,
            in_dim,
            out_dim,
            v: vec![0i64; out_dim],
            threshold,
            res,
            pending: Vec::new(),
            acc: vec![0i64; out_dim],
        }
    }

    /// Number of inputs.
    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    /// Number of output neurons.
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Current membrane potentials.
    pub fn vmem(&self) -> &[i64] {
        &self.v
    }

    /// Overwrite the membrane state (snapshot restore) and recompute the
    /// refire set.
    pub fn set_vmem(&mut self, v: &[i64]) {
        self.v.copy_from_slice(v);
        self.pending.clear();
        for (i, &x) in self.v.iter().enumerate() {
            if x >= self.threshold {
                self.pending.push(i as u32);
            }
        }
    }

    /// Zero all membrane potentials.
    pub fn reset(&mut self) {
        self.v.iter_mut().for_each(|x| *x = 0);
        self.pending.clear();
    }

    /// One event-driven timestep.
    pub fn step(&mut self, spikes_in: &SpikeList) -> SpikeList {
        assert_eq!(spikes_in.dim(), self.in_dim);
        let p = self.res.p_bits;
        let out_dim = self.out_dim;
        let mut out = Vec::new();

        if spikes_in.is_empty() {
            // No input: only refire candidates can change state; every
            // other neuron is unchanged and below threshold.
            let pending = std::mem::take(&mut self.pending);
            let mut next_pending = Vec::new();
            for &n in &pending {
                let ni = n as usize;
                let mut v = self.v[ni];
                if v >= self.threshold {
                    v = wrap(v - self.threshold, p);
                    out.push(n);
                }
                self.v[ni] = v;
                if v >= self.threshold {
                    next_pending.push(n);
                }
            }
            self.pending = next_pending;
            return SpikeList::from_sorted(out, out_dim);
        }

        self.acc.iter_mut().for_each(|a| *a = 0);
        for &i in spikes_in.active() {
            let col = &self.wt[i as usize * out_dim..(i as usize + 1) * out_dim];
            for (a, &w) in self.acc.iter_mut().zip(col) {
                *a += w;
            }
        }
        self.pending.clear();
        for o in 0..out_dim {
            let mut v = wrap(self.v[o] + self.acc[o], p);
            if v >= self.threshold {
                v = wrap(v - self.threshold, p);
                out.push(o as u32);
            }
            self.v[o] = v;
            if v >= self.threshold {
                self.pending.push(o as u32);
            }
        }
        SpikeList::from_sorted(out, out_dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::conv::ConvLifLayer;
    use crate::snn::lif::LifLayer;

    #[test]
    fn spike_list_roundtrips_dense() {
        let bits = vec![false, true, false, false, true, true];
        let s = SpikeList::from_dense(&bits);
        assert_eq!(s.dim(), 6);
        assert_eq!(s.count(), 3);
        assert_eq!(s.active(), &[1, 4, 5]);
        assert_eq!(s.to_dense(), bits);
        assert_eq!(s.to_i32(), vec![0, 1, 0, 0, 1, 1]);
        assert!((s.activity() - 0.5).abs() < 1e-12);
        assert_eq!(SpikeList::from_i32_dense(&s.to_i32()), s);
    }

    #[test]
    fn spike_list_empty_and_bounds() {
        let e = SpikeList::empty(4);
        assert!(e.is_empty());
        assert_eq!(e.to_dense(), vec![false; 4]);
        assert_eq!(e.activity(), 0.0);
        let s = SpikeList::from_sorted(vec![0, 3], 4);
        assert_eq!(s.count(), 2);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn unsorted_indices_rejected() {
        SpikeList::from_sorted(vec![3, 1], 4);
    }

    #[test]
    #[should_panic(expected = "out of dim")]
    fn out_of_range_index_rejected() {
        SpikeList::from_sorted(vec![4], 4);
    }

    #[test]
    fn adjacency_matches_sops_reach() {
        // The adjacency row of a corner covers the clipped receptive
        // outputs — the same counts ConvLifLayer::sops computes.
        let spec = LayerSpec::conv("a", 1, 2, 3, 1, 1, 4, 4, Resolution::new(4, 10));
        let layer = ConvLifLayer::new(spec.clone(), vec![1; 18], 100);
        let adj = ConvAdjacency::build(&spec);
        let mut corner = vec![false; 16];
        corner[0] = true;
        // sops counts out_ch × positions; the adjacency row is per spatial
        // position (channel-independent).
        assert_eq!(
            (adj.offsets[1] - adj.offsets[0]) as u64 * 2,
            layer.sops(&corner)
        );
        assert!(adj.tap_count() > 0);
    }

    #[test]
    fn event_conv_matches_dense_on_identity_kernel() {
        let spec = LayerSpec::conv("id", 1, 1, 3, 1, 1, 4, 4, Resolution::new(4, 8));
        let mut w = vec![0i64; 9];
        w[4] = 7;
        let mut sparse = EventConvLayer::new(spec.clone(), w.clone(), 7);
        let mut dense = ConvLifLayer::new(spec, w, 7);
        let mut spikes = vec![false; 16];
        spikes[5] = true;
        spikes[10] = true;
        let sl = SpikeList::from_dense(&spikes);
        let out = sparse.step(&sl);
        assert_eq!(out.to_dense(), dense.step(&spikes));
        assert_eq!(sparse.vmem(), &dense.v[..]);
    }

    #[test]
    fn untouched_neuron_refires_on_residual() {
        // One strong spike drives v to 3θ: the neuron fires three steps in
        // a row, the last two with *no* input — the dense scan does this,
        // and the sparse refire set must reproduce it.
        let spec = LayerSpec::conv("r", 1, 1, 1, 1, 0, 1, 1, Resolution::new(6, 12));
        let mut sparse = EventConvLayer::new(spec.clone(), vec![30], 10);
        let mut dense = ConvLifLayer::new(spec, vec![30], 10);
        let on = SpikeList::from_dense(&[true]);
        let off = SpikeList::empty(1);
        assert_eq!(sparse.step(&on).to_dense(), dense.step(&[true]));
        assert_eq!(sparse.vmem()[0], 20);
        assert_eq!(sparse.step(&off).to_dense(), dense.step(&[false]));
        assert_eq!(sparse.vmem()[0], 10);
        assert_eq!(sparse.step(&off).to_dense(), dense.step(&[false]));
        assert_eq!(sparse.vmem()[0], 0);
        assert_eq!(sparse.step(&off).count(), 0, "residual exhausted");
        assert_eq!(sparse.vmem(), &dense.v[..]);
    }

    #[test]
    fn event_fc_matches_dense_including_silent_steps() {
        let res = Resolution::new(4, 8);
        let weights = vec![vec![5, 2], vec![-3, 7], vec![6, 6]];
        let mut sparse = EventFcLayer::new(weights.clone(), res, 4);
        let mut dense = LifLayer::new(weights, res, 4);
        let patterns = [
            vec![true, true],
            vec![false, false], // silent: refire path
            vec![true, false],
            vec![false, false],
            vec![false, true],
        ];
        for (t, p) in patterns.iter().enumerate() {
            let a = sparse.step(&SpikeList::from_dense(p));
            let b = dense.step(p);
            assert_eq!(a.to_dense(), b, "t={t} spikes");
            assert_eq!(sparse.vmem(), &dense.v[..], "t={t} vmem");
        }
    }

    #[test]
    fn set_vmem_rebuilds_refire_set() {
        // Restoring a snapshot whose potentials clear the threshold must
        // fire on the next silent step, exactly like the dense scan.
        let res = Resolution::new(4, 10);
        let weights = vec![vec![1, 1]];
        let mut sparse = EventFcLayer::new(weights.clone(), res, 3);
        let mut dense = LifLayer::new(weights, res, 3);
        sparse.set_vmem(&[7]);
        dense.v[0] = 7;
        let silent = SpikeList::empty(2);
        assert_eq!(sparse.step(&silent).to_dense(), dense.step(&[false, false]));
        assert_eq!(sparse.vmem(), &dense.v[..]);

        let spec = LayerSpec::conv("s", 1, 1, 1, 1, 0, 2, 2, Resolution::new(4, 10));
        let mut c_sparse = EventConvLayer::new(spec.clone(), vec![1], 3);
        let mut c_dense = ConvLifLayer::new(spec, vec![1], 3);
        c_sparse.set_vmem(&[7, 0, 4, 2]);
        c_dense.v.copy_from_slice(&[7, 0, 4, 2]);
        let silent = SpikeList::empty(4);
        assert_eq!(
            c_sparse.step(&silent).to_dense(),
            c_dense.step(&[false; 4])
        );
        assert_eq!(c_sparse.vmem(), &c_dense.v[..]);
    }

    #[test]
    fn reset_clears_state_and_refire() {
        let res = Resolution::new(4, 10);
        let mut l = EventFcLayer::new(vec![vec![7]], res, 2);
        l.step(&SpikeList::from_dense(&[true])); // v = 7 - 2 = 5, refire
        assert!(l.vmem()[0] > 0);
        l.reset();
        assert_eq!(l.vmem(), &[0]);
        assert_eq!(l.step(&SpikeList::empty(1)).count(), 0);
    }

    #[test]
    fn adjacency_cache_shares_by_geometry() {
        let cache = AdjacencyCache::new();
        let a = LayerSpec::conv("a", 2, 4, 3, 1, 1, 8, 8, Resolution::new(4, 9));
        // Same geometry, different channels/resolution: one shared table.
        let b = LayerSpec::conv("b", 8, 16, 3, 1, 1, 8, 8, Resolution::new(6, 11));
        // Different stride: its own table.
        let c = LayerSpec::conv("c", 2, 4, 3, 2, 1, 8, 8, Resolution::new(4, 9));
        let adj_a = cache.get_or_build(&a);
        let adj_b = cache.get_or_build(&b);
        let adj_c = cache.get_or_build(&c);
        assert!(Arc::ptr_eq(&adj_a, &adj_b), "same geometry must share");
        assert!(!Arc::ptr_eq(&adj_a, &adj_c), "different geometry must not");
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.hits(), 1);
        assert!(!cache.is_empty());
    }

    #[test]
    fn shared_adjacency_layer_matches_private_one() {
        let spec = LayerSpec::conv("s", 1, 2, 3, 1, 1, 6, 6, Resolution::new(4, 9));
        let weights: Vec<i64> = (0..spec.num_weights()).map(|i| (i as i64 % 7) - 3).collect();
        let cache = AdjacencyCache::new();
        let mut shared = EventConvLayer::with_adjacency(
            spec.clone(),
            weights.clone(),
            5,
            cache.get_or_build(&spec),
        );
        let mut private = EventConvLayer::new(spec.clone(), weights, 5);
        let frame = SpikeList::from_sorted(vec![0, 7, 20, 35], 36);
        for t in 0..4 {
            let a = shared.step(&frame);
            let b = private.step(&frame);
            assert_eq!(a, b, "t={t}");
        }
        assert_eq!(shared.vmem(), private.vmem());
    }

    #[test]
    #[should_panic(expected = "does not match the layer geometry")]
    fn mismatched_adjacency_rejected() {
        let small = LayerSpec::conv("s", 1, 1, 3, 1, 1, 4, 4, Resolution::new(4, 9));
        let big = LayerSpec::conv("b", 1, 1, 3, 1, 1, 8, 8, Resolution::new(4, 9));
        let adj = Arc::new(ConvAdjacency::build(&small));
        let weights = vec![0i64; big.num_weights()];
        let _ = EventConvLayer::with_adjacency(big, weights, 1, adj);
    }

    #[test]
    #[should_panic(expected = "does not match the layer geometry")]
    fn same_plane_different_padding_rejected() {
        // Same input plane (so the offsets row count matches) but a
        // different output grid: only the full geometry key catches it.
        let padded = LayerSpec::conv("p", 1, 1, 3, 1, 1, 8, 8, Resolution::new(4, 9));
        let unpadded = LayerSpec::conv("u", 1, 1, 3, 1, 0, 8, 8, Resolution::new(4, 9));
        let adj = Arc::new(ConvAdjacency::build(&padded));
        let weights = vec![0i64; unpadded.num_weights()];
        let _ = EventConvLayer::with_adjacency(unpadded, weights, 1, adj);
    }
}
