//! Event-driven sparse spike datapath.
//!
//! The paper's headline is *event-based* execution: synaptic accumulates
//! fire only on input spikes (Fig. 1c/2c), so the work per timestep scales
//! with spike activity, not with layer size. This module makes that
//! structural in the software engine:
//!
//! * [`SpikeList`] — the first-class sparse spike representation (sorted
//!   active indices over a known dense dimension). The whole runtime
//!   datapath — encoder → [`crate::runtime::StepBackend`] → coordinator —
//!   moves spikes in this form; dense `Vec<bool>` survives only at the
//!   golden-model boundary. The builder API ([`SpikeList::begin`] /
//!   [`SpikeList::push`] / [`SpikeList::copy_from`]) reuses the index
//!   buffer so the steady-state window loop performs no heap allocation,
//!   and [`SpikeList::to_words_into`] packs the list into `u64` bit-plane
//!   words for the word-parallel kernels below.
//! * [`ConvAdjacency`] — per-layer precomputed scatter adjacency: conv
//!   geometry compiled once into CSR-style per-input-position synapse
//!   offsets, so each event walks straight to the output taps its
//!   receptive field covers (no per-event stride/pad arithmetic on the
//!   clipped borders).
//! * [`EventConvLayer`] / [`EventFcLayer`] — event-driven stepping that
//!   only touches the membrane potentials of neurons reached by an active
//!   spike, and fire-checks only touched neurons plus the *refire set*
//!   (see below).
//!
//! **Word-parallel packed hot path.** Mirroring the word-level
//! `cim_accumulate` rewrite of the CIM macro
//! ([`crate::cim::macro_unit`]), the layer steps operate on packed `u64`
//! words instead of scalar per-neuron state:
//!
//! * The conv step keeps its weights in *scatter order* (one contiguous
//!   `out_ch` row per `(in_ch, kernel element)` pair) so every adjacency
//!   tap becomes a single linear row-add over the position-major
//!   accumulator — an auto-vectorizable inner loop with no stamp
//!   branches. Touched output positions and the refire set are packed
//!   bitmasks, and the fire-check enumerates set bits with
//!   `trailing_zeros`, which yields the dense scan order for free.
//! * The FC step stores the weight matrix as two's-complement *bit
//!   planes* over the input dimension and recovers the exact integer
//!   dot product from popcounts (`acc = Σ_b ±2^b · popcount(in ∧
//!   plane_b)`); at high activity this replaces per-spike column adds
//!   with an activity-independent `w_bits × words_in` word ops per
//!   output. The spike-count cutover between the two is tunable
//!   ([`EventFcLayer::set_packed_cutover`]) and both modes are pinned
//!   bit-identical to the dense oracle.
//!
//! The scalar per-spike reference path survives as
//! [`EventConvLayer::step_scalar`] — the packed-vs-scalar property tests
//! and the `perf_hotpath` speedup gate both measure against it. All
//! paths share the packed refire mask, so they interleave freely on one
//! instance.
//!
//! **Soundness of sparse fire-checking.** Reset-by-subtraction leaves a
//! residual `v - θ` that can itself still clear the threshold (when
//! `v ≥ 2θ`), and the dense golden models fire-check *every* neuron
//! *every* timestep — an untouched neuron with `v ≥ θ` fires on zero
//! input. The sparse layers therefore carry the set of neurons whose
//! potential still clears the threshold after each step (`pending`) into
//! the next step's fire-check. Untouched neurons with `v < θ` are
//! genuinely inert (their potential is unchanged and below threshold), so
//! skipping them is exact, not approximate. Bit-identity with the dense
//! oracles ([`crate::snn::conv::ConvLifLayer`] /
//! [`crate::snn::lif::LifLayer`]) over random geometries and resolutions
//! is pinned by `rust/tests/property_sparse.rs`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::layer::{LayerKind, LayerSpec};
use super::quant::{bit_of, max_val, min_val, wrap, Resolution};

// -------------------------------------------------------------- spike list

/// A sparse binary spike vector: the sorted indices of the active bits
/// over a known dense dimension.
///
/// This is the AER-native representation the accelerator's event queues
/// move — storage and bandwidth scale with activity, and the event-driven
/// layers consume it directly without a densify step.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct SpikeList {
    /// Active indices, strictly increasing.
    indices: Vec<u32>,
    /// Dense dimension of the underlying spike vector.
    dim: usize,
}

impl SpikeList {
    /// The all-silent spike vector of dimension `dim`.
    pub fn empty(dim: usize) -> SpikeList {
        SpikeList { indices: Vec::new(), dim }
    }

    /// Build from a dense boolean vector (indices come out sorted).
    pub fn from_dense(bits: &[bool]) -> SpikeList {
        let indices = bits
            .iter()
            .enumerate()
            .filter(|(_, &b)| b)
            .map(|(i, _)| i as u32)
            .collect();
        SpikeList { indices, dim: bits.len() }
    }

    /// Build from a dense 0/1 `i32` vector (any non-zero is a spike) —
    /// the PJRT tensor boundary.
    pub fn from_i32_dense(vals: &[i32]) -> SpikeList {
        let indices = vals
            .iter()
            .enumerate()
            .filter(|(_, &v)| v != 0)
            .map(|(i, _)| i as u32)
            .collect();
        SpikeList { indices, dim: vals.len() }
    }

    /// Build from already-sorted active indices. Sortedness, uniqueness,
    /// and bounds are asserted — a malformed spike list is a caller bug,
    /// not a recoverable condition.
    pub fn from_sorted(indices: Vec<u32>, dim: usize) -> SpikeList {
        assert!(
            indices.windows(2).all(|w| w[0] < w[1]),
            "spike indices must be strictly increasing"
        );
        if let Some(&last) = indices.last() {
            assert!((last as usize) < dim, "spike index {last} out of dim {dim}");
        }
        SpikeList { indices, dim }
    }

    /// Dense dimension of the spike vector.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of active spikes.
    pub fn count(&self) -> usize {
        self.indices.len()
    }

    /// True when no spike is active.
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// The sorted active indices.
    pub fn active(&self) -> &[u32] {
        &self.indices
    }

    /// Active fraction (`count / dim`; 0 for a zero-dim list).
    pub fn activity(&self) -> f64 {
        if self.dim == 0 {
            return 0.0;
        }
        self.indices.len() as f64 / self.dim as f64
    }

    /// Densify to booleans (golden-model boundary).
    pub fn to_dense(&self) -> Vec<bool> {
        let mut bits = vec![false; self.dim];
        for &i in &self.indices {
            bits[i as usize] = true;
        }
        bits
    }

    /// Densify to the 0/1 `i32` layout the PJRT artifacts expect.
    pub fn to_i32(&self) -> Vec<i32> {
        let mut vals = vec![0i32; self.dim];
        for &i in &self.indices {
            vals[i as usize] = 1;
        }
        vals
    }

    // ------------------- reusable-buffer builder API (zero-alloc path)

    /// Reset to the all-silent vector of dimension `dim`, keeping the
    /// index buffer's capacity — the entry point of every zero-alloc
    /// producer (layer steps, sparse encoder, serve scratch).
    pub fn begin(&mut self, dim: usize) {
        self.indices.clear();
        self.dim = dim;
    }

    /// Append the next active index. Callers must push in strictly
    /// increasing order (debug-asserted); use [`Self::push_unordered`] +
    /// [`Self::seal`] when the producer is unsorted.
    pub fn push(&mut self, idx: u32) {
        debug_assert!(
            (idx as usize) < self.dim,
            "spike index {idx} out of dim {}",
            self.dim
        );
        debug_assert!(
            self.indices.last().map_or(true, |&last| last < idx),
            "spike indices must be strictly increasing"
        );
        self.indices.push(idx);
    }

    /// Append an active index in arbitrary order; [`Self::seal`] must run
    /// before the list is read.
    pub fn push_unordered(&mut self, idx: u32) {
        debug_assert!(
            (idx as usize) < self.dim,
            "spike index {idx} out of dim {}",
            self.dim
        );
        self.indices.push(idx);
    }

    /// Sort and dedupe after a [`Self::push_unordered`] fill. Both
    /// `sort_unstable` and `dedup` work in place, so sealing never
    /// allocates.
    pub fn seal(&mut self) {
        self.indices.sort_unstable();
        self.indices.dedup();
    }

    /// Become a copy of `other`, reusing this list's buffer. The derived
    /// `Clone::clone_from` may reallocate; this never does once the
    /// capacity suffices.
    pub fn copy_from(&mut self, other: &SpikeList) {
        self.dim = other.dim;
        self.indices.clear();
        self.indices.extend_from_slice(&other.indices);
    }

    /// `u64` words needed to pack a `dim`-bit spike vector.
    pub fn words_for(dim: usize) -> usize {
        dim.div_ceil(64)
    }

    /// Pack into `u64` words (bit `i & 63` of word `i >> 6`, LSB-first),
    /// reusing `words`' buffer. The packed form is what the word-parallel
    /// kernels consume.
    pub fn to_words_into(&self, words: &mut Vec<u64>) {
        words.clear();
        words.resize(Self::words_for(self.dim), 0);
        for &i in &self.indices {
            words[(i >> 6) as usize] |= 1u64 << (i & 63);
        }
    }
}

// ---------------------------------------------------------- conv adjacency

/// One precomputed synapse tap: an input spatial position reaches output
/// position `out_pos` through kernel element `ker_pos`.
#[derive(Debug, Clone, Copy)]
struct Tap {
    /// `oy * ow + ox` of the reached output position.
    out_pos: u32,
    /// `dy * k + dx` of the kernel element connecting them.
    ker_pos: u32,
}

/// CSR-style scatter adjacency for a conv layer: for every input spatial
/// position, the list of (output position, kernel element) taps its spikes
/// reach, with border clipping folded in at build time.
///
/// The spatial structure is channel-independent, so one adjacency row per
/// `(iy, ix)` serves all `in_ch × out_ch` channel pairs — the per-event
/// walk adds the channel strides on top.
#[derive(Debug, Clone)]
pub struct ConvAdjacency {
    /// The geometry this adjacency was compiled for (shared-table safety
    /// check — see [`EventConvLayer::with_adjacency`]).
    key: AdjKey,
    /// Row offsets into `taps`, one row per input position (`in_h × in_w`
    /// rows, `offsets.len() == rows + 1`).
    offsets: Vec<u32>,
    taps: Vec<Tap>,
}

impl ConvAdjacency {
    /// Compile the scatter adjacency of `spec` (must be a conv layer).
    pub fn build(spec: &LayerSpec) -> ConvAdjacency {
        let key = geometry_key(spec);
        let (k, stride, pad, in_h, in_w) = key;
        let (_, oh, ow) = spec.out_shape();
        let mut offsets = Vec::with_capacity(in_h * in_w + 1);
        let mut taps = Vec::new();
        offsets.push(0u32);
        for iy in 0..in_h {
            for ix in 0..in_w {
                for dy in 0..k {
                    // Output row oy with oy*stride + dy - pad == iy.
                    let oy_num = iy as i64 + pad as i64 - dy as i64;
                    if oy_num < 0 || oy_num % stride as i64 != 0 {
                        continue;
                    }
                    let oy = (oy_num / stride as i64) as usize;
                    if oy >= oh {
                        continue;
                    }
                    for dx in 0..k {
                        let ox_num = ix as i64 + pad as i64 - dx as i64;
                        if ox_num < 0 || ox_num % stride as i64 != 0 {
                            continue;
                        }
                        let ox = (ox_num / stride as i64) as usize;
                        if ox >= ow {
                            continue;
                        }
                        taps.push(Tap {
                            out_pos: (oy * ow + ox) as u32,
                            ker_pos: (dy * k + dx) as u32,
                        });
                    }
                }
                offsets.push(taps.len() as u32);
            }
        }
        ConvAdjacency { key, offsets, taps }
    }

    /// Total taps across all input positions (diagnostics: equals the sum
    /// of per-position receptive-output counts, i.e. `sops / out_ch` of a
    /// fully dense frame).
    pub fn tap_count(&self) -> usize {
        self.taps.len()
    }
}

/// Geometry key of a conv adjacency: `(k, stride, pad, in_h, in_w)` —
/// everything [`ConvAdjacency::build`] depends on. Channel counts and
/// operand resolutions do not shape the spatial scatter pattern, so layers
/// that differ only in those share one table.
type AdjKey = (usize, usize, usize, usize, usize);

/// The [`AdjKey`] of a conv layer spec (panics on FC specs).
fn geometry_key(spec: &LayerSpec) -> AdjKey {
    match spec.kind {
        LayerKind::Conv { k, stride, pad, in_h, in_w, .. } => (k, stride, pad, in_h, in_w),
        _ => panic!("conv spec required"),
    }
}

/// Shared, thread-safe cache of [`ConvAdjacency`] tables keyed by conv
/// geometry.
///
/// The adjacency is read-only and a pure function of geometry, so one
/// table can serve every rebuild of [`crate::runtime::NativeScnn`] across
/// a resolution sweep *and* every worker of the parallel engine / serve
/// pool. Build cost is paid once per distinct geometry; every later lookup
/// is an `Arc` clone. Share it by cloning the `Arc<AdjacencyCache>` into
/// each backend factory closure.
#[derive(Debug, Default)]
pub struct AdjacencyCache {
    state: Mutex<CacheState>,
}

#[derive(Debug, Default)]
struct CacheState {
    map: HashMap<AdjKey, Arc<ConvAdjacency>>,
    hits: u64,
}

impl AdjacencyCache {
    /// An empty cache.
    pub fn new() -> AdjacencyCache {
        AdjacencyCache::default()
    }

    /// The adjacency for `spec` (must be a conv layer): built on first
    /// use, shared afterwards.
    pub fn get_or_build(&self, spec: &LayerSpec) -> Arc<ConvAdjacency> {
        let key = geometry_key(spec);
        let mut st = self.state.lock().unwrap();
        if let Some(adj) = st.map.get(&key) {
            st.hits += 1;
            return adj.clone();
        }
        let adj = Arc::new(ConvAdjacency::build(spec));
        st.map.insert(key, adj.clone());
        adj
    }

    /// Distinct geometries cached so far.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().map.len()
    }

    /// True when nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups served from the cache (observability for the sharing tests).
    pub fn hits(&self) -> u64 {
        self.state.lock().unwrap().hits
    }
}

// ------------------------------------------------------- event conv layer

/// Per-step scratch of the scalar reference path (stamp/generation lazy
/// clear, exactly the pre-packed engine) — built lazily so the packed hot
/// path pays nothing for carrying the baseline around.
#[derive(Debug, Clone)]
struct ScalarScratch {
    acc: Vec<i64>,
    stamp: Vec<u32>,
    generation: u32,
    touched: Vec<u32>,
}

/// Event-driven conv layer of IF neurons: bit-identical to
/// [`crate::snn::conv::ConvLifLayer`] but with per-timestep work
/// proportional to input activity instead of layer size.
///
/// The default [`Self::step`] runs the word-parallel packed kernel (see
/// the module docs); [`Self::step_scalar`] is the per-spike scalar
/// reference it is measured and property-tested against. Both share the
/// packed refire mask and membrane state, so they interleave freely on
/// one instance.
#[derive(Debug, Clone)]
pub struct EventConvLayer {
    /// Geometry (must be `LayerKind::Conv`).
    pub spec: LayerSpec,
    /// Weights `[out_ch][in_ch][k][k]` flattened row-major (dense layout,
    /// used by the scalar reference path).
    weights: Vec<i64>,
    /// Scatter-order weights: `w_tap[(ic * k² + ker_pos) * out_ch + oc]`
    /// — one contiguous `out_ch` row per (input channel, kernel element)
    /// pair, so the packed step adds a whole output-channel row per tap
    /// in one linear pass the compiler can vectorize.
    w_tap: Vec<i64>,
    /// Shared read-only scatter adjacency (see [`AdjacencyCache`]).
    adj: Arc<ConvAdjacency>,
    /// Membrane potentials `[out_ch][oh][ow]` flattened.
    v: Vec<i64>,
    /// Firing threshold.
    pub threshold: i64,
    /// Refire set as packed bitmasks: block `oc` spans `words_pp` words
    /// and bit `pos` of it marks neuron `oc * out_plane + pos`, whose
    /// residual potential still clears the threshold after the previous
    /// step — it fires on zero input, exactly as the dense per-neuron
    /// scan would. Shared by the packed and scalar paths.
    pending: Vec<u64>,
    // Scratch (persistent to avoid per-step allocation): position-major
    // accumulator `acc[pos * out_ch + oc]`, valid only where the packed
    // `touched` mask (one bit per output position — the scatter is
    // spatial, so a single bit covers all out_ch) is set.
    acc: Vec<i64>,
    touched: Vec<u64>,
    /// Scratch of [`Self::step_scalar`], `None` until first use.
    scalar: Option<Box<ScalarScratch>>,
}

impl EventConvLayer {
    /// Build from a spec and flattened weights — same validation as the
    /// dense golden model. The scatter adjacency is compiled privately;
    /// use [`Self::with_adjacency`] to share one across layers/instances.
    pub fn new(spec: LayerSpec, weights: Vec<i64>, threshold: i64) -> Self {
        let adj = Arc::new(ConvAdjacency::build(&spec));
        Self::with_adjacency(spec, weights, threshold, adj)
    }

    /// Build with a shared precomputed adjacency (see [`AdjacencyCache`]):
    /// the adjacency depends only on conv geometry, so resolution rebuilds
    /// and sibling engine workers reuse one table instead of recompiling
    /// it per instance.
    pub fn with_adjacency(
        spec: LayerSpec,
        weights: Vec<i64>,
        threshold: i64,
        adj: Arc<ConvAdjacency>,
    ) -> Self {
        assert_eq!(
            adj.key,
            geometry_key(&spec),
            "adjacency does not match the layer geometry"
        );
        assert_eq!(weights.len(), spec.num_weights());
        let (lo, hi) = (min_val(spec.res.w_bits), max_val(spec.res.w_bits));
        assert!(
            weights.iter().all(|&w| (lo..=hi).contains(&w)),
            "weight exceeds {}b",
            spec.res.w_bits
        );
        assert!(threshold > 0);
        let n = spec.num_neurons();
        let (in_ch, out_ch, k) = match spec.kind {
            LayerKind::Conv { in_ch, out_ch, k, .. } => (in_ch, out_ch, k),
            _ => unreachable!("geometry_key rejects non-conv specs"),
        };
        let kk = k * k;
        let mut w_tap = vec![0i64; weights.len()];
        for oc in 0..out_ch {
            for ic in 0..in_ch {
                for kp in 0..kk {
                    w_tap[(ic * kk + kp) * out_ch + oc] =
                        weights[(oc * in_ch + ic) * kk + kp];
                }
            }
        }
        let (_, oh, ow) = spec.out_shape();
        let out_plane = oh * ow;
        let words_pp = out_plane.div_ceil(64);
        EventConvLayer {
            spec,
            weights,
            w_tap,
            adj,
            v: vec![0i64; n],
            threshold,
            pending: vec![0u64; out_ch * words_pp],
            acc: vec![0i64; n],
            touched: vec![0u64; words_pp],
            scalar: None,
        }
    }

    fn dims(&self) -> (usize, usize, usize, usize, usize) {
        match self.spec.kind {
            LayerKind::Conv { in_ch, out_ch, k, in_h, in_w, .. } => {
                (in_ch, out_ch, k, in_h, in_w)
            }
            _ => unreachable!(),
        }
    }

    /// Current membrane potentials.
    pub fn vmem(&self) -> &[i64] {
        &self.v
    }

    /// Overwrite the membrane state (snapshot restore). The refire set is
    /// recomputed from the new potentials — restoring mid-stream must
    /// reproduce exactly the fire-checks the dense scan would perform.
    pub fn set_vmem(&mut self, v: &[i64]) {
        self.v.copy_from_slice(v);
        self.rebuild_pending();
    }

    /// Zero all membrane potentials.
    pub fn reset(&mut self) {
        self.v.iter_mut().for_each(|x| *x = 0);
        self.pending.fill(0);
    }

    fn rebuild_pending(&mut self) {
        let (_, oh, ow) = self.spec.out_shape();
        let out_plane = oh * ow;
        let words_pp = out_plane.div_ceil(64);
        self.pending.fill(0);
        let theta = self.threshold;
        for (i, &v) in self.v.iter().enumerate() {
            if v >= theta {
                let oc = i / out_plane;
                let pos = i % out_plane;
                self.pending[oc * words_pp + (pos >> 6)] |= 1u64 << (pos & 63);
            }
        }
    }

    /// One event-driven timestep (word-parallel packed kernel), appending
    /// the output spikes into `out` (buffer reused, no allocation at
    /// steady state).
    ///
    /// Scatter phase: every input spike walks its adjacency row and adds
    /// one contiguous scatter-order weight row (`out_ch` wide) into the
    /// position-major accumulator; first touch of a position copies the
    /// row instead of clearing, and marks one bit in the packed touched
    /// mask. Fire phase: for each output channel, enumerate the set bits
    /// of `touched ∪ pending` with `trailing_zeros` — ascending bit order
    /// is ascending neuron order, so the output matches the dense scan
    /// without a sort.
    pub fn step_into(&mut self, spikes_in: &SpikeList, out: &mut SpikeList) {
        let (in_ch, out_ch, k, in_h, in_w) = self.dims();
        assert_eq!(spikes_in.dim(), in_ch * in_h * in_w);
        let (_, oh, ow) = self.spec.out_shape();
        let plane = in_h * in_w;
        let out_plane = oh * ow;
        let kk = k * k;
        let p_bits = self.spec.res.p_bits;
        let words_pp = out_plane.div_ceil(64);

        self.touched.fill(0);
        for &idx in spikes_in.active() {
            let idx = idx as usize;
            let ic = idx / plane;
            let pos = idx % plane;
            let lo = self.adj.offsets[pos] as usize;
            let hi = self.adj.offsets[pos + 1] as usize;
            let row_base = ic * kk;
            for t in &self.adj.taps[lo..hi] {
                let op = t.out_pos as usize;
                let wrow = &self.w_tap[(row_base + t.ker_pos as usize) * out_ch..][..out_ch];
                let arow = &mut self.acc[op * out_ch..][..out_ch];
                let bit = 1u64 << (op & 63);
                let word = &mut self.touched[op >> 6];
                if *word & bit == 0 {
                    *word |= bit;
                    arow.copy_from_slice(wrow);
                } else {
                    for (a, &w) in arow.iter_mut().zip(wrow) {
                        *a += w;
                    }
                }
            }
        }

        // Fire-check touched ∪ refire positions; refire bits (packed
        // `pending` mask) cover untouched neurons whose residual
        // potential still clears the threshold (reset-by-subtraction
        // leaves v ≥ θ when the pre-reset potential was ≥ 2θ).
        out.begin(out_ch * out_plane);
        let theta = self.threshold;
        for oc in 0..out_ch {
            let pend_off = oc * words_pp;
            let v_base = oc * out_plane;
            for wi in 0..words_pp {
                let t_word = self.touched[wi];
                let mut m = t_word | self.pending[pend_off + wi];
                if m == 0 {
                    continue;
                }
                let mut still = 0u64;
                while m != 0 {
                    let b = m.trailing_zeros() as usize;
                    m &= m - 1;
                    let pos = (wi << 6) | b;
                    let ni = v_base + pos;
                    let a = if t_word >> b & 1 == 1 {
                        self.acc[pos * out_ch + oc]
                    } else {
                        0
                    };
                    let mut vv = wrap(self.v[ni] + a, p_bits);
                    if vv >= theta {
                        vv = wrap(vv - theta, p_bits);
                        out.push(ni as u32);
                    }
                    self.v[ni] = vv;
                    if vv >= theta {
                        still |= 1u64 << b;
                    }
                }
                self.pending[pend_off + wi] = still;
            }
        }
    }

    /// One event-driven timestep (packed kernel), allocating the output —
    /// see [`Self::step_into`] for the reusable-buffer form.
    pub fn step(&mut self, spikes_in: &SpikeList) -> SpikeList {
        let mut out = SpikeList::default();
        self.step_into(spikes_in, &mut out);
        out
    }

    /// One event-driven timestep on the *scalar* per-spike reference path
    /// (stamp/generation lazy clear, sorted touched list) — the baseline
    /// the packed kernel is property-tested and benchmarked against.
    /// Shares membrane state and the refire mask with [`Self::step_into`].
    pub fn step_scalar_into(&mut self, spikes_in: &SpikeList, out: &mut SpikeList) {
        let (in_ch, out_ch, k, in_h, in_w) = self.dims();
        assert_eq!(spikes_in.dim(), in_ch * in_h * in_w);
        let (_, oh, ow) = self.spec.out_shape();
        let plane = in_h * in_w;
        let out_plane = oh * ow;
        let kk = k * k;
        let p_bits = self.spec.res.p_bits;
        let words_pp = out_plane.div_ceil(64);
        let n = out_ch * out_plane;

        if self.scalar.is_none() {
            self.scalar = Some(Box::new(ScalarScratch {
                acc: vec![0i64; n],
                stamp: vec![0u32; n],
                generation: 0,
                touched: Vec::new(),
            }));
        }
        let s = self.scalar.as_deref_mut().expect("scratch built above");

        s.generation = s.generation.wrapping_add(1);
        if s.generation == 0 {
            // Stamp wrap-around (once per 2^32 steps): clear and restart.
            s.stamp.iter_mut().for_each(|x| *x = 0);
            s.generation = 1;
        }
        let gen = s.generation;

        for &idx in spikes_in.active() {
            let idx = idx as usize;
            let ic = idx / plane;
            let pos = idx % plane;
            let lo = self.adj.offsets[pos] as usize;
            let hi = self.adj.offsets[pos + 1] as usize;
            for oc in 0..out_ch {
                let w_base = (oc * in_ch + ic) * kk;
                let v_base = oc * out_plane;
                for t in &self.adj.taps[lo..hi] {
                    let nn = v_base + t.out_pos as usize;
                    let w = self.weights[w_base + t.ker_pos as usize];
                    if s.stamp[nn] == gen {
                        s.acc[nn] += w;
                    } else {
                        s.stamp[nn] = gen;
                        s.acc[nn] = w;
                        s.touched.push(nn as u32);
                    }
                }
            }
        }

        // Merge the refire candidates out of the shared packed mask.
        for oc in 0..out_ch {
            let pend_off = oc * words_pp;
            let v_base = oc * out_plane;
            for wi in 0..words_pp {
                let mut m = self.pending[pend_off + wi];
                while m != 0 {
                    let b = m.trailing_zeros() as usize;
                    m &= m - 1;
                    let nn = v_base + ((wi << 6) | b);
                    if s.stamp[nn] != gen {
                        s.stamp[nn] = gen;
                        s.acc[nn] = 0;
                        s.touched.push(nn as u32);
                    }
                }
            }
        }
        self.pending.fill(0);

        // Sorted processing keeps the output spike order identical to the
        // dense per-neuron scan.
        s.touched.sort_unstable();
        out.begin(n);
        let theta = self.threshold;
        for &nn in &s.touched {
            let ni = nn as usize;
            let mut v = wrap(self.v[ni] + s.acc[ni], p_bits);
            if v >= theta {
                v = wrap(v - theta, p_bits);
                out.push(nn);
            }
            self.v[ni] = v;
            if v >= theta {
                let oc = ni / out_plane;
                let pos = ni % out_plane;
                self.pending[oc * words_pp + (pos >> 6)] |= 1u64 << (pos & 63);
            }
        }
        s.touched.clear();
    }

    /// Allocating wrapper around [`Self::step_scalar_into`].
    pub fn step_scalar(&mut self, spikes_in: &SpikeList) -> SpikeList {
        let mut out = SpikeList::default();
        self.step_scalar_into(spikes_in, &mut out);
        out
    }
}

// --------------------------------------------------------- event FC layer

// ------------------------------------------------------ fc kernel cutover

/// Cost-model estimate of the FC packed-kernel cutover: per output the
/// scalar kernel costs one add per input spike and the bit-plane kernel
/// a fixed `w_bits × words_in` word ops, so they break even where the
/// spike count meets that product. This is the hermetic default when no
/// measured trajectory is available.
pub fn fc_cutover_estimate(w_bits: u32, words_in: usize) -> usize {
    w_bits as usize * words_in
}

/// Parse `(activity, scalar_us, packed_us)` records for the
/// `packed_step_fc` bench out of BENCH_JSON trajectory text (the
/// append-only `BENCH_perf_hotpath.json` format: schema/run meta lines
/// and records for other benches are skipped; malformed lines are
/// ignored rather than fatal — a half-written trajectory must never
/// break layer construction).
pub fn parse_packed_fc_records(text: &str) -> Vec<(f64, f64, f64)> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim().trim_start_matches("BENCH_JSON ");
        if line.is_empty() {
            continue;
        }
        let Ok(v) = crate::util::json_lite::parse(line) else {
            continue;
        };
        if v.get("meta").is_some() || v.get("bench").and_then(|b| b.as_str()) != Some("packed_step_fc")
        {
            continue;
        }
        let field = |k: &str| v.get(k).and_then(|x| x.as_num());
        if let (Some(a), Some(s), Some(p)) =
            (field("activity"), field("scalar_us"), field("packed_us"))
        {
            if a.is_finite() && s.is_finite() && p.is_finite() && a > 0.0 {
                out.push((a, s, p));
            }
        }
    }
    out.sort_by(|a, b| a.0.total_cmp(&b.0));
    out
}

/// Choose the packed cutover for a layer with `in_dim` inputs from the
/// cost-model `estimate` and measured `(activity, scalar_us, packed_us)`
/// records (sorted by activity). Measurements are carried as activity
/// *fractions*, so a trajectory captured at one FC geometry transfers to
/// any layer width. Selection:
///
/// * no records — the estimate stands;
/// * packed already wins at the lowest measured activity — cut over at
///   that activity's spike count (never extrapolate below measurement);
/// * the advantage crosses zero between two neighbors — cut over at the
///   linear interpolation of the crossing;
/// * scalar wins everywhere measured — push the cutover past the last
///   measured point (and never below the estimate).
pub fn fc_cutover_select(estimate: usize, records: &[(f64, f64, f64)], in_dim: usize) -> usize {
    let spikes = |activity: f64| ((activity * in_dim as f64).ceil() as usize).max(1);
    let adv = |r: &(f64, f64, f64)| r.1 - r.2; // scalar_us - packed_us; > 0 = packed wins
    let Some(first) = records.first() else {
        return estimate;
    };
    if adv(first) > 0.0 {
        return spikes(first.0);
    }
    for pair in records.windows(2) {
        let (lose, win) = (&pair[0], &pair[1]);
        if adv(lose) <= 0.0 && adv(win) > 0.0 {
            let (a0, a1) = (adv(lose), adv(win));
            let cross = lose.0 + (win.0 - lose.0) * (-a0) / (a1 - a0);
            return spikes(cross);
        }
    }
    let last = records.last().expect("non-empty");
    estimate.max(spikes(last.0) + 1)
}

/// The process-wide measured trajectory, loaded once from the file named
/// by `FLEXSPIM_FC_CUTOVER_TRAJECTORY` (typically the repo's
/// `BENCH_perf_hotpath.json`). Unset, unreadable, or record-free files
/// all yield the empty trajectory — the cost-model estimate stays the
/// default, so builds are hermetic unless a trajectory is supplied
/// explicitly.
fn fc_cutover_records() -> &'static [(f64, f64, f64)] {
    static RECORDS: std::sync::OnceLock<Vec<(f64, f64, f64)>> = std::sync::OnceLock::new();
    RECORDS.get_or_init(|| {
        std::env::var_os("FLEXSPIM_FC_CUTOVER_TRAJECTORY")
            .and_then(|p| std::fs::read_to_string(p).ok())
            .map(|t| parse_packed_fc_records(&t))
            .unwrap_or_default()
    })
}

/// Event-driven fully-connected layer of IF neurons: bit-identical to
/// [`crate::snn::lif::LifLayer`]. An FC layer's fan-out is structurally
/// dense, so any active input touches every neuron; the sparsity win is
/// on the input side, and an all-silent timestep reduces to the refire
/// set alone.
///
/// Two accumulate kernels cover the activity range, picked per step by a
/// spike-count cutover: below it, each active input adds one contiguous
/// transposed-weight column (the classic event-driven layout); at or
/// above it, the *bit-plane* kernel packs the input spikes into `u64`
/// words and recovers the exact dot product from popcounts against the
/// precomputed weight bit-planes — activity-independent word work, the
/// software mirror of the CIM macro's bit-serial operand ALUs.
#[derive(Debug, Clone)]
pub struct EventFcLayer {
    /// Transposed weights: `wt[i * out_dim + o]` (column of input `i`
    /// contiguous).
    wt: Vec<i64>,
    /// Weight bit-planes over the input dimension:
    /// `planes[(o * w_bits + b) * words_in + w]` holds bit `b` of the
    /// two's-complement `w_bits` encoding of every weight feeding output
    /// `o`, packed 64 inputs per word. The exact dot product is
    /// `acc[o] = Σ_{b < w_bits-1} 2^b · popcount(in ∧ plane_b)
    /// − 2^(w_bits-1) · popcount(in ∧ plane_msb)`.
    planes: Vec<u64>,
    in_dim: usize,
    out_dim: usize,
    /// `in_dim.div_ceil(64)` — words per packed input / plane row.
    words_in: usize,
    v: Vec<i64>,
    /// Firing threshold.
    pub threshold: i64,
    /// Operand resolution.
    pub res: Resolution,
    /// Refire set (see [`EventConvLayer::step_into`]), kept sorted.
    pending: Vec<u32>,
    /// Double buffer for the silent-step refire walk (zero-alloc).
    pending_next: Vec<u32>,
    /// Per-step accumulator scratch (`out_dim` entries).
    acc: Vec<i64>,
    /// Packed input scratch of the bit-plane kernel.
    in_words: Vec<u64>,
    /// Spike count at or above which the bit-plane kernel engages.
    packed_cutover: usize,
}

impl EventFcLayer {
    /// Create from a `[out][in]` weight matrix — same validation as the
    /// dense golden model, transposed and bit-plane-packed internally.
    pub fn new(weights: Vec<Vec<i64>>, res: Resolution, threshold: i64) -> Self {
        assert!(!weights.is_empty());
        assert!(threshold > 0);
        let out_dim = weights.len();
        let in_dim = weights[0].len();
        assert!(weights.iter().all(|r| r.len() == in_dim));
        let (lo, hi) = (min_val(res.w_bits), max_val(res.w_bits));
        let mut wt = vec![0i64; in_dim * out_dim];
        for (o, row) in weights.iter().enumerate() {
            for (i, &w) in row.iter().enumerate() {
                assert!((lo..=hi).contains(&w), "weight {w} exceeds {}b", res.w_bits);
                wt[i * out_dim + o] = w;
            }
        }
        let words_in = in_dim.div_ceil(64);
        let wb = res.w_bits as usize;
        let mut planes = vec![0u64; out_dim * wb * words_in];
        for (o, row) in weights.iter().enumerate() {
            for b in 0..wb {
                let base = (o * wb + b) * words_in;
                for (i, &w) in row.iter().enumerate() {
                    if bit_of(w, b as u32, res.w_bits) {
                        planes[base + (i >> 6)] |= 1u64 << (i & 63);
                    }
                }
            }
        }
        EventFcLayer {
            wt,
            planes,
            in_dim,
            out_dim,
            words_in,
            v: vec![0i64; out_dim],
            threshold,
            res,
            pending: Vec::new(),
            pending_next: Vec::new(),
            acc: vec![0i64; out_dim],
            in_words: Vec::new(),
            // Measured trajectory when one is supplied, the cost-model
            // break-even otherwise (see fc_cutover_select).
            packed_cutover: fc_cutover_select(
                fc_cutover_estimate(res.w_bits, words_in),
                fc_cutover_records(),
                in_dim,
            ),
        }
    }

    /// Number of inputs.
    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    /// Number of output neurons.
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Override the packed-vs-scalar cutover (input spike count at which
    /// the bit-plane kernel engages). `0` forces packed on every
    /// non-silent step, `usize::MAX` forces scalar — the property tests
    /// pin both modes against the dense oracle at every activity.
    pub fn set_packed_cutover(&mut self, cutover: usize) {
        self.packed_cutover = cutover;
    }

    /// Current membrane potentials.
    pub fn vmem(&self) -> &[i64] {
        &self.v
    }

    /// Overwrite the membrane state (snapshot restore) and recompute the
    /// refire set.
    pub fn set_vmem(&mut self, v: &[i64]) {
        self.v.copy_from_slice(v);
        self.pending.clear();
        for (i, &x) in self.v.iter().enumerate() {
            if x >= self.threshold {
                self.pending.push(i as u32);
            }
        }
    }

    /// Zero all membrane potentials.
    pub fn reset(&mut self) {
        self.v.iter_mut().for_each(|x| *x = 0);
        self.pending.clear();
    }

    /// One event-driven timestep, appending the output spikes into `out`
    /// (buffer reused, no allocation at steady state).
    pub fn step_into(&mut self, spikes_in: &SpikeList, out: &mut SpikeList) {
        assert_eq!(spikes_in.dim(), self.in_dim);
        let p = self.res.p_bits;
        let out_dim = self.out_dim;
        out.begin(out_dim);
        let theta = self.threshold;

        if spikes_in.is_empty() {
            // No input: only refire candidates can change state; every
            // other neuron is unchanged and below threshold.
            self.pending_next.clear();
            let next = &mut self.pending_next;
            for &n in self.pending.iter() {
                let ni = n as usize;
                let mut v = self.v[ni];
                if v >= theta {
                    v = wrap(v - theta, p);
                    out.push(n);
                }
                self.v[ni] = v;
                if v >= theta {
                    next.push(n);
                }
            }
            std::mem::swap(&mut self.pending, &mut self.pending_next);
            return;
        }

        if spikes_in.count() >= self.packed_cutover {
            // Bit-plane kernel: popcount the packed input against every
            // weight plane; the signed two's-complement recomposition is
            // exact, so this is bit-identical to the scalar adds.
            spikes_in.to_words_into(&mut self.in_words);
            let wb = self.res.w_bits as usize;
            let words_in = self.words_in;
            for o in 0..out_dim {
                let base = o * wb * words_in;
                let mut a = 0i64;
                for b in 0..wb {
                    let row = &self.planes[base + b * words_in..][..words_in];
                    let mut cnt = 0u64;
                    for (iw, pw) in self.in_words.iter().zip(row) {
                        cnt += (iw & pw).count_ones() as u64;
                    }
                    let term = (cnt as i64) << b;
                    a += if b + 1 == wb { -term } else { term };
                }
                self.acc[o] = a;
            }
        } else {
            self.acc.iter_mut().for_each(|a| *a = 0);
            for &i in spikes_in.active() {
                let col = &self.wt[i as usize * out_dim..(i as usize + 1) * out_dim];
                for (a, &w) in self.acc.iter_mut().zip(col) {
                    *a += w;
                }
            }
        }

        self.pending.clear();
        for o in 0..out_dim {
            let mut v = wrap(self.v[o] + self.acc[o], p);
            if v >= theta {
                v = wrap(v - theta, p);
                out.push(o as u32);
            }
            self.v[o] = v;
            if v >= theta {
                self.pending.push(o as u32);
            }
        }
    }

    /// One event-driven timestep, allocating the output — see
    /// [`Self::step_into`] for the reusable-buffer form.
    pub fn step(&mut self, spikes_in: &SpikeList) -> SpikeList {
        let mut out = SpikeList::default();
        self.step_into(spikes_in, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::conv::ConvLifLayer;
    use crate::snn::lif::LifLayer;

    #[test]
    fn fc_cutover_estimate_is_the_default_without_a_trajectory() {
        // Hermetic builds (no FLEXSPIM_FC_CUTOVER_TRAJECTORY, or a
        // schema-only trajectory file) keep the cost-model break-even.
        assert_eq!(fc_cutover_select(20, &[], 1000), 20);
        let weights: Vec<Vec<i64>> = vec![vec![1i64; 100]; 4];
        let layer = EventFcLayer::new(weights, Resolution::new(4, 9), 5);
        assert_eq!(
            layer.packed_cutover,
            fc_cutover_estimate(4, 100usize.div_ceil(64)),
            "estimate path is the live default"
        );
    }

    #[test]
    fn fc_cutover_selects_from_measured_records() {
        // Packed already wins at the lowest measured activity: cut over
        // there, never extrapolate below measurement.
        let packed_wins = [(0.05, 10.0, 5.0), (0.5, 50.0, 6.0)];
        assert_eq!(fc_cutover_select(3, &packed_wins, 1000), 50);
        // The advantage crosses zero between neighbors: adv(-4) at 0.1,
        // adv(+4) at 0.3 interpolates to 0.2.
        let crossing = [(0.1, 4.0, 8.0), (0.3, 12.0, 8.0)];
        assert_eq!(fc_cutover_select(3, &crossing, 1000), 200);
        // Scalar wins everywhere measured: past the last point, and
        // never below the estimate.
        let scalar_wins = [(0.1, 2.0, 8.0), (0.5, 6.0, 8.0)];
        assert_eq!(fc_cutover_select(3, &scalar_wins, 1000), 501);
        assert_eq!(fc_cutover_select(900, &scalar_wins, 1000), 900);
        // A spike count never rounds to zero.
        let tiny = [(0.001, 9.0, 1.0)];
        assert_eq!(fc_cutover_select(3, &tiny, 10), 1);
    }

    #[test]
    fn fc_cutover_parses_the_trajectory_format() {
        let text = concat!(
            "{\"meta\":\"schema\",\"bench\":\"packed_step_conv\",\"fields\":[\"activity\"]}\n",
            "{\"meta\":\"run\",\"bench\":\"packed_step_conv\",\"date\":\"2026-08-07\"}\n",
            "{\"bench\":\"packed_step_conv\",\"activity\":0.1,\"scalar_us\":3,\"packed_us\":1,\"speedup\":3}\n",
            "BENCH_JSON {\"bench\":\"packed_step_fc\",\"activity\":0.25,\"scalar_us\":8.0,\"packed_us\":2.0,\"speedup\":4.0}\n",
            "{\"bench\":\"packed_step_fc\",\"activity\":0.1,\"scalar_us\":4.0,\"packed_us\":2.0,\"speedup\":2.0}\n",
            "not json at all\n",
            "{\"bench\":\"packed_step_fc\",\"activity\":0.5,\"scalar_us\":null,\"packed_us\":2.0}\n",
        );
        let records = parse_packed_fc_records(text);
        // Only the two complete packed_step_fc records survive, sorted by
        // activity; meta lines, other benches, junk, and null fields are
        // skipped.
        assert_eq!(records, vec![(0.1, 4.0, 2.0), (0.25, 8.0, 2.0)]);
    }

    #[test]
    fn spike_list_roundtrips_dense() {
        let bits = vec![false, true, false, false, true, true];
        let s = SpikeList::from_dense(&bits);
        assert_eq!(s.dim(), 6);
        assert_eq!(s.count(), 3);
        assert_eq!(s.active(), &[1, 4, 5]);
        assert_eq!(s.to_dense(), bits);
        assert_eq!(s.to_i32(), vec![0, 1, 0, 0, 1, 1]);
        assert!((s.activity() - 0.5).abs() < 1e-12);
        assert_eq!(SpikeList::from_i32_dense(&s.to_i32()), s);
    }

    #[test]
    fn spike_list_empty_and_bounds() {
        let e = SpikeList::empty(4);
        assert!(e.is_empty());
        assert_eq!(e.to_dense(), vec![false; 4]);
        assert_eq!(e.activity(), 0.0);
        let s = SpikeList::from_sorted(vec![0, 3], 4);
        assert_eq!(s.count(), 2);
    }

    #[test]
    fn spike_list_builder_reuses_buffer() {
        let mut s = SpikeList::default();
        s.begin(8);
        s.push(1);
        s.push(5);
        assert_eq!(s.active(), &[1, 5]);
        assert_eq!(s.dim(), 8);
        // Unordered fill with a duplicate, then seal.
        s.begin(6);
        s.push_unordered(4);
        s.push_unordered(0);
        s.push_unordered(4);
        s.seal();
        assert_eq!(s, SpikeList::from_sorted(vec![0, 4], 6));
        // copy_from matches the source exactly.
        let src = SpikeList::from_sorted(vec![2, 3], 5);
        s.copy_from(&src);
        assert_eq!(s, src);
    }

    #[test]
    fn spike_list_packs_into_words() {
        let s = SpikeList::from_sorted(vec![0, 63, 64, 70], 130);
        assert_eq!(SpikeList::words_for(130), 3);
        let mut words = Vec::new();
        s.to_words_into(&mut words);
        assert_eq!(words, vec![1 | (1 << 63), 1 | (1 << 6), 0]);
        // Reuse shrinks and re-zeroes the buffer.
        SpikeList::empty(64).to_words_into(&mut words);
        assert_eq!(words, vec![0]);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn unsorted_indices_rejected() {
        SpikeList::from_sorted(vec![3, 1], 4);
    }

    #[test]
    #[should_panic(expected = "out of dim")]
    fn out_of_range_index_rejected() {
        SpikeList::from_sorted(vec![4], 4);
    }

    #[test]
    fn adjacency_matches_sops_reach() {
        // The adjacency row of a corner covers the clipped receptive
        // outputs — the same counts ConvLifLayer::sops computes.
        let spec = LayerSpec::conv("a", 1, 2, 3, 1, 1, 4, 4, Resolution::new(4, 10));
        let layer = ConvLifLayer::new(spec.clone(), vec![1; 18], 100);
        let adj = ConvAdjacency::build(&spec);
        let mut corner = vec![false; 16];
        corner[0] = true;
        // sops counts out_ch × positions; the adjacency row is per spatial
        // position (channel-independent).
        assert_eq!(
            (adj.offsets[1] - adj.offsets[0]) as u64 * 2,
            layer.sops(&corner)
        );
        assert!(adj.tap_count() > 0);
    }

    #[test]
    fn event_conv_matches_dense_on_identity_kernel() {
        let spec = LayerSpec::conv("id", 1, 1, 3, 1, 1, 4, 4, Resolution::new(4, 8));
        let mut w = vec![0i64; 9];
        w[4] = 7;
        let mut sparse = EventConvLayer::new(spec.clone(), w.clone(), 7);
        let mut dense = ConvLifLayer::new(spec, w, 7);
        let mut spikes = vec![false; 16];
        spikes[5] = true;
        spikes[10] = true;
        let sl = SpikeList::from_dense(&spikes);
        let out = sparse.step(&sl);
        assert_eq!(out.to_dense(), dense.step(&spikes));
        assert_eq!(sparse.vmem(), &dense.v[..]);
    }

    #[test]
    fn untouched_neuron_refires_on_residual() {
        // One strong spike drives v to 3θ: the neuron fires three steps in
        // a row, the last two with *no* input — the dense scan does this,
        // and the sparse refire set must reproduce it.
        let spec = LayerSpec::conv("r", 1, 1, 1, 1, 0, 1, 1, Resolution::new(6, 12));
        let mut sparse = EventConvLayer::new(spec.clone(), vec![30], 10);
        let mut dense = ConvLifLayer::new(spec, vec![30], 10);
        let on = SpikeList::from_dense(&[true]);
        let off = SpikeList::empty(1);
        assert_eq!(sparse.step(&on).to_dense(), dense.step(&[true]));
        assert_eq!(sparse.vmem()[0], 20);
        assert_eq!(sparse.step(&off).to_dense(), dense.step(&[false]));
        assert_eq!(sparse.vmem()[0], 10);
        assert_eq!(sparse.step(&off).to_dense(), dense.step(&[false]));
        assert_eq!(sparse.vmem()[0], 0);
        assert_eq!(sparse.step(&off).count(), 0, "residual exhausted");
        assert_eq!(sparse.vmem(), &dense.v[..]);
    }

    #[test]
    fn conv_packed_and_scalar_paths_interleave() {
        // The packed and scalar kernels share membrane state and the
        // refire mask: alternating them on one instance must still match
        // the dense oracle step for step.
        let spec = LayerSpec::conv("mix", 2, 3, 3, 1, 1, 5, 5, Resolution::new(4, 9));
        let weights: Vec<i64> =
            (0..spec.num_weights()).map(|i| (i as i64 % 15) - 7).collect();
        let mut sparse = EventConvLayer::new(spec.clone(), weights.clone(), 6);
        let mut dense = ConvLifLayer::new(spec, weights, 6);
        for t in 0..8 {
            let bits: Vec<bool> = (0..50).map(|i| (i * 7 + t * 13) % 11 < 3).collect();
            let sl = SpikeList::from_dense(&bits);
            let got = if t % 2 == 0 {
                sparse.step(&sl)
            } else {
                sparse.step_scalar(&sl)
            };
            assert_eq!(got.to_dense(), dense.step(&bits), "t={t}");
            assert_eq!(sparse.vmem(), &dense.v[..], "t={t} vmem");
        }
    }

    #[test]
    fn event_fc_matches_dense_including_silent_steps() {
        let res = Resolution::new(4, 8);
        let weights = vec![vec![5, 2], vec![-3, 7], vec![6, 6]];
        let mut sparse = EventFcLayer::new(weights.clone(), res, 4);
        let mut dense = LifLayer::new(weights, res, 4);
        let patterns = [
            vec![true, true],
            vec![false, false], // silent: refire path
            vec![true, false],
            vec![false, false],
            vec![false, true],
        ];
        for (t, p) in patterns.iter().enumerate() {
            let a = sparse.step(&SpikeList::from_dense(p));
            let b = dense.step(p);
            assert_eq!(a.to_dense(), b, "t={t} spikes");
            assert_eq!(sparse.vmem(), &dense.v[..], "t={t} vmem");
        }
    }

    #[test]
    fn fc_bit_plane_kernel_matches_column_adds() {
        // Forced packed vs forced scalar vs dense, including negative
        // weights (MSB plane) and a 1-bit weight resolution (sign-only).
        for w_bits in [1u32, 3, 4] {
            let res = Resolution::new(w_bits, 10);
            let (lo, hi) = (min_val(w_bits), max_val(w_bits));
            let weights: Vec<Vec<i64>> = (0..5)
                .map(|o| {
                    (0..70)
                        .map(|i| lo + ((o * 31 + i * 17) as i64 % (hi - lo + 1)))
                        .collect()
                })
                .collect();
            let mut packed = EventFcLayer::new(weights.clone(), res, 3);
            packed.set_packed_cutover(0);
            let mut scalar = EventFcLayer::new(weights.clone(), res, 3);
            scalar.set_packed_cutover(usize::MAX);
            let mut dense = LifLayer::new(weights, res, 3);
            for t in 0..6 {
                let bits: Vec<bool> = (0..70).map(|i| (i * 5 + t * 29) % 9 < 4).collect();
                let a = packed.step(&SpikeList::from_dense(&bits));
                let b = scalar.step(&SpikeList::from_dense(&bits));
                let d = dense.step(&bits);
                assert_eq!(a.to_dense(), d, "w_bits={w_bits} t={t} packed");
                assert_eq!(b.to_dense(), d, "w_bits={w_bits} t={t} scalar");
                assert_eq!(packed.vmem(), &dense.v[..], "w_bits={w_bits} t={t}");
                assert_eq!(scalar.vmem(), &dense.v[..], "w_bits={w_bits} t={t}");
            }
        }
    }

    #[test]
    fn set_vmem_rebuilds_refire_set() {
        // Restoring a snapshot whose potentials clear the threshold must
        // fire on the next silent step, exactly like the dense scan.
        let res = Resolution::new(4, 10);
        let weights = vec![vec![1, 1]];
        let mut sparse = EventFcLayer::new(weights.clone(), res, 3);
        let mut dense = LifLayer::new(weights, res, 3);
        sparse.set_vmem(&[7]);
        dense.v[0] = 7;
        let silent = SpikeList::empty(2);
        assert_eq!(sparse.step(&silent).to_dense(), dense.step(&[false, false]));
        assert_eq!(sparse.vmem(), &dense.v[..]);

        let spec = LayerSpec::conv("s", 1, 1, 1, 1, 0, 2, 2, Resolution::new(4, 10));
        let mut c_sparse = EventConvLayer::new(spec.clone(), vec![1], 3);
        let mut c_dense = ConvLifLayer::new(spec, vec![1], 3);
        c_sparse.set_vmem(&[7, 0, 4, 2]);
        c_dense.v.copy_from_slice(&[7, 0, 4, 2]);
        let silent = SpikeList::empty(4);
        assert_eq!(
            c_sparse.step(&silent).to_dense(),
            c_dense.step(&[false; 4])
        );
        assert_eq!(c_sparse.vmem(), &c_dense.v[..]);
    }

    #[test]
    fn reset_clears_state_and_refire() {
        let res = Resolution::new(4, 10);
        let mut l = EventFcLayer::new(vec![vec![7]], res, 2);
        l.step(&SpikeList::from_dense(&[true])); // v = 7 - 2 = 5, refire
        assert!(l.vmem()[0] > 0);
        l.reset();
        assert_eq!(l.vmem(), &[0]);
        assert_eq!(l.step(&SpikeList::empty(1)).count(), 0);
    }

    #[test]
    fn adjacency_cache_shares_by_geometry() {
        let cache = AdjacencyCache::new();
        let a = LayerSpec::conv("a", 2, 4, 3, 1, 1, 8, 8, Resolution::new(4, 9));
        // Same geometry, different channels/resolution: one shared table.
        let b = LayerSpec::conv("b", 8, 16, 3, 1, 1, 8, 8, Resolution::new(6, 11));
        // Different stride: its own table.
        let c = LayerSpec::conv("c", 2, 4, 3, 2, 1, 8, 8, Resolution::new(4, 9));
        let adj_a = cache.get_or_build(&a);
        let adj_b = cache.get_or_build(&b);
        let adj_c = cache.get_or_build(&c);
        assert!(Arc::ptr_eq(&adj_a, &adj_b), "same geometry must share");
        assert!(!Arc::ptr_eq(&adj_a, &adj_c), "different geometry must not");
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.hits(), 1);
        assert!(!cache.is_empty());
    }

    #[test]
    fn shared_adjacency_layer_matches_private_one() {
        let spec = LayerSpec::conv("s", 1, 2, 3, 1, 1, 6, 6, Resolution::new(4, 9));
        let weights: Vec<i64> = (0..spec.num_weights()).map(|i| (i as i64 % 7) - 3).collect();
        let cache = AdjacencyCache::new();
        let mut shared = EventConvLayer::with_adjacency(
            spec.clone(),
            weights.clone(),
            5,
            cache.get_or_build(&spec),
        );
        let mut private = EventConvLayer::new(spec.clone(), weights, 5);
        let frame = SpikeList::from_sorted(vec![0, 7, 20, 35], 36);
        for t in 0..4 {
            let a = shared.step(&frame);
            let b = private.step(&frame);
            assert_eq!(a, b, "t={t}");
        }
        assert_eq!(shared.vmem(), private.vmem());
    }

    #[test]
    #[should_panic(expected = "does not match the layer geometry")]
    fn mismatched_adjacency_rejected() {
        let small = LayerSpec::conv("s", 1, 1, 3, 1, 1, 4, 4, Resolution::new(4, 9));
        let big = LayerSpec::conv("b", 1, 1, 3, 1, 1, 8, 8, Resolution::new(4, 9));
        let adj = Arc::new(ConvAdjacency::build(&small));
        let weights = vec![0i64; big.num_weights()];
        let _ = EventConvLayer::with_adjacency(big, weights, 1, adj);
    }

    #[test]
    #[should_panic(expected = "does not match the layer geometry")]
    fn same_plane_different_padding_rejected() {
        // Same input plane (so the offsets row count matches) but a
        // different output grid: only the full geometry key catches it.
        let padded = LayerSpec::conv("p", 1, 1, 3, 1, 1, 8, 8, Resolution::new(4, 9));
        let unpadded = LayerSpec::conv("u", 1, 1, 3, 1, 0, 8, 8, Resolution::new(4, 9));
        let adj = Arc::new(ConvAdjacency::build(&padded));
        let weights = vec![0i64; unpadded.num_weights()];
        let _ = EventConvLayer::with_adjacency(unpadded, weights, 1, adj);
    }
}
