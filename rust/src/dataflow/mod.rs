//! Hybrid-stationary dataflow (paper §II-B, Fig. 4).
//!
//! FlexSpIM's unified weight/membrane-potential CIM storage lets every
//! layer choose which operand stays resident (weight stationarity, WS, or
//! output/membrane stationarity, OS). This module turns a workload
//! ([`crate::snn::Network`]) plus a CIM budget (number of macros) into a
//! [`mapper::Mapping`]: per-layer stationarity decisions, macro placement,
//! and the stationary/streamed traffic accounting that drives the Fig. 4
//! and Fig. 7(c–d) results.

pub mod mapper;
pub mod policy;
pub mod stationarity;

pub use mapper::{LayerAssignment, Mapper, Mapping, Shard};
pub use policy::Policy;
pub use stationarity::{Operand, Stationarity};
