//! Dataflow policies compared in the paper.

use super::stationarity::{self, Stationarity};
use crate::snn::LayerSpec;

/// Mapping policy: how each layer picks its stationary operand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Weight-stationary everywhere — what all prior CIM-SNNs do
    /// ([3]–[6], [9]–[12]); the Fig. 4(b) baseline.
    WsOnly,
    /// Output-stationary everywhere (ablation point; not in the paper but
    /// the natural dual of WS-only).
    OsOnly,
    /// Hybrid: keep each layer's *smaller* operand resident (Fig. 4a,
    /// brown line) — maximizes the number of layers with full
    /// stationarity under a tight CIM budget.
    HsMin,
    /// Hybrid: keep each layer's *larger* operand resident (Fig. 4a, pink
    /// line) — pays off once the macro count grows (Fig. 7c/d).
    HsMax,
    /// Hybrid with free per-layer choice, searched to maximize avoided
    /// traffic under the capacity constraint (the "optimal layer mapping"
    /// of Fig. 4b).
    HsOpt,
}

impl Policy {
    /// All policies, for sweep drivers.
    pub const ALL: [Policy; 5] =
        [Policy::WsOnly, Policy::OsOnly, Policy::HsMin, Policy::HsMax, Policy::HsOpt];

    /// Fixed per-layer choice for the non-searching policies;
    /// `None` for [`Policy::HsOpt`] (the mapper searches instead).
    pub fn fixed_choice(self, layer: &LayerSpec) -> Option<Stationarity> {
        match self {
            Policy::WsOnly => Some(Stationarity::Ws),
            Policy::OsOnly => Some(Stationarity::Os),
            Policy::HsMin => Some(stationarity::min_footprint_choice(layer)),
            Policy::HsMax => Some(stationarity::max_footprint_choice(layer)),
            Policy::HsOpt => None,
        }
    }

    /// Short label for tables.
    pub fn label(self) -> &'static str {
        match self {
            Policy::WsOnly => "WS-only",
            Policy::OsOnly => "OS-only",
            Policy::HsMin => "HS-min",
            Policy::HsMax => "HS-max",
            Policy::HsOpt => "HS-opt",
        }
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::{LayerSpec, Resolution};

    #[test]
    fn fixed_choices() {
        let vmem_heavy = LayerSpec::conv("c", 2, 8, 3, 1, 1, 32, 32, Resolution::new(4, 9));
        assert_eq!(Policy::WsOnly.fixed_choice(&vmem_heavy), Some(Stationarity::Ws));
        assert_eq!(Policy::OsOnly.fixed_choice(&vmem_heavy), Some(Stationarity::Os));
        assert_eq!(Policy::HsMin.fixed_choice(&vmem_heavy), Some(Stationarity::Ws));
        assert_eq!(Policy::HsMax.fixed_choice(&vmem_heavy), Some(Stationarity::Os));
        assert_eq!(Policy::HsOpt.fixed_choice(&vmem_heavy), None);
    }

    #[test]
    fn labels_unique() {
        let labels: Vec<&str> = Policy::ALL.iter().map(|p| p.label()).collect();
        let mut dedup = labels.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), labels.len());
    }
}
