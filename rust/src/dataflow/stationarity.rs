//! Per-layer stationarity primitives and traffic accounting.

use crate::snn::LayerSpec;

/// The two operand classes held in the unified CIM storage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Operand {
    /// Synaptic weights.
    Weight,
    /// Membrane potentials (the layer's *output* state).
    Vmem,
}

/// A layer's dataflow choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stationarity {
    /// Weight-stationary: weights resident, membrane potentials streamed.
    Ws,
    /// Output-stationary: membrane potentials resident, weights streamed.
    Os,
}

impl Stationarity {
    /// Which operand stays in the macro.
    pub fn stationary_operand(self) -> Operand {
        match self {
            Stationarity::Ws => Operand::Weight,
            Stationarity::Os => Operand::Vmem,
        }
    }

    /// Which operand is streamed every timestep.
    pub fn streamed_operand(self) -> Operand {
        match self {
            Stationarity::Ws => Operand::Vmem,
            Stationarity::Os => Operand::Weight,
        }
    }
}

/// Footprint in bits of one operand of a layer.
pub fn operand_bits(layer: &LayerSpec, op: Operand) -> u64 {
    match op {
        Operand::Weight => layer.weight_bits(),
        Operand::Vmem => layer.vmem_bits(),
    }
}

/// Per-timestep operand movement (bits) *avoided* by keeping `op`
/// stationary, under the event-driven execution model:
///
/// * a streamed **weight** operand is fetched once per timestep
///   (`weight_bits`) — broadcast weights are reused across output
///   positions within the timestep;
/// * a streamed **membrane potential** must be read *and* written back
///   every timestep (`2 × vmem_bits`) — this factor-2 asymmetry is why
///   OS wins for potential-dominated early layers (paper Fig. 4a).
pub fn avoided_traffic_bits(layer: &LayerSpec, op: Operand) -> u64 {
    match op {
        Operand::Weight => layer.weight_bits(),
        Operand::Vmem => 2 * layer.vmem_bits(),
    }
}

/// The stationarity that minimizes the layer's resident footprint
/// (the HS-min rule of Fig. 4a).
pub fn min_footprint_choice(layer: &LayerSpec) -> Stationarity {
    if layer.weight_bits() <= layer.vmem_bits() {
        Stationarity::Ws
    } else {
        Stationarity::Os
    }
}

/// The stationarity that keeps the *larger* operand resident
/// (the HS-max rule of Fig. 4a — best when CIM capacity is plentiful).
pub fn max_footprint_choice(layer: &LayerSpec) -> Stationarity {
    if layer.weight_bits() >= layer.vmem_bits() {
        Stationarity::Ws
    } else {
        Stationarity::Os
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::{LayerSpec, Resolution};

    fn vmem_heavy() -> LayerSpec {
        // Small kernel, large feature map.
        LayerSpec::conv("c", 2, 8, 3, 1, 1, 32, 32, Resolution::new(4, 9))
    }

    fn weight_heavy() -> LayerSpec {
        LayerSpec::fc("f", 1024, 16, Resolution::new(8, 8))
    }

    #[test]
    fn operand_roles() {
        assert_eq!(Stationarity::Ws.stationary_operand(), Operand::Weight);
        assert_eq!(Stationarity::Ws.streamed_operand(), Operand::Vmem);
        assert_eq!(Stationarity::Os.stationary_operand(), Operand::Vmem);
        assert_eq!(Stationarity::Os.streamed_operand(), Operand::Weight);
    }

    #[test]
    fn footprints() {
        let l = weight_heavy();
        assert_eq!(operand_bits(&l, Operand::Weight), 1024 * 16 * 8);
        assert_eq!(operand_bits(&l, Operand::Vmem), 16 * 8);
    }

    #[test]
    fn vmem_avoidance_counts_read_and_write() {
        let l = vmem_heavy();
        assert_eq!(avoided_traffic_bits(&l, Operand::Vmem), 2 * l.vmem_bits());
        assert_eq!(avoided_traffic_bits(&l, Operand::Weight), l.weight_bits());
    }

    #[test]
    fn min_max_choices() {
        assert_eq!(min_footprint_choice(&vmem_heavy()), Stationarity::Ws);
        assert_eq!(max_footprint_choice(&vmem_heavy()), Stationarity::Os);
        assert_eq!(min_footprint_choice(&weight_heavy()), Stationarity::Os);
        assert_eq!(max_footprint_choice(&weight_heavy()), Stationarity::Ws);
    }
}
