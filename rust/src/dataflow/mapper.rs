//! Layer→macro mapping search (the "optimal layer mapping" of Fig. 4b).
//!
//! Given a workload, a policy, and a CIM budget (`num_macros × 16 kB`),
//! the mapper decides per layer (a) which operand is nominally stationary,
//! (b) whether that operand actually receives CIM residency under the
//! capacity constraint, and (c) whether the *other* operand can also be
//! parked in CIM (full-layer stationarity). Residency choices are searched
//! exhaustively for small networks (≤12 layers, exact optimum) and by
//! density-greedy otherwise. The objective is per-timestep avoided operand
//! traffic — the quantity the paper calls "the amount of stationary
//! operands" (membrane potentials count twice: read + write-back).

use super::policy::Policy;
use super::stationarity::{avoided_traffic_bits, operand_bits, Stationarity};
use crate::snn::Network;

/// Per-layer residency plan.
#[derive(Debug, Clone)]
pub struct LayerAssignment {
    /// Index into `network.layers`.
    pub layer_idx: usize,
    /// Nominal stationarity choice.
    pub stationarity: Stationarity,
    /// The stationary operand actually fits in the CIM budget.
    pub stationary_resident: bool,
    /// The streamed operand *also* got parked in CIM (spare capacity).
    pub extra_resident: bool,
    /// `(macro_index, bits)` spans for the resident operands.
    pub spans: Vec<(usize, u64)>,
}

impl LayerAssignment {
    /// Bits this layer keeps resident in CIM.
    pub fn resident_bits(&self, net: &Network) -> u64 {
        let l = &net.layers[self.layer_idx];
        let mut b = 0;
        if self.stationary_resident {
            b += operand_bits(l, self.stationarity.stationary_operand());
        }
        if self.extra_resident {
            b += operand_bits(l, self.stationarity.streamed_operand());
        }
        b
    }

    /// Per-timestep traffic avoided by this layer's residency.
    pub fn avoided_bits(&self, net: &Network) -> u64 {
        let l = &net.layers[self.layer_idx];
        let mut b = 0;
        if self.stationary_resident {
            b += avoided_traffic_bits(l, self.stationarity.stationary_operand());
        }
        if self.extra_resident {
            b += avoided_traffic_bits(l, self.stationarity.streamed_operand());
        }
        b
    }

    /// Per-timestep bits still streamed for this layer (weights once,
    /// membrane potentials read+write).
    pub fn streamed_bits(&self, net: &Network) -> u64 {
        let l = &net.layers[self.layer_idx];
        let mut b = 0;
        if !self.stationary_resident {
            b += avoided_traffic_bits(l, self.stationarity.stationary_operand());
        }
        if !self.extra_resident {
            b += avoided_traffic_bits(l, self.stationarity.streamed_operand());
        }
        b
    }
}

/// A complete mapping of the workload onto the CIM budget.
#[derive(Debug, Clone)]
pub struct Mapping {
    /// Policy that produced it.
    pub policy: Policy,
    /// One assignment per layer, in layer order.
    pub assignments: Vec<LayerAssignment>,
    /// Total CIM capacity in bits.
    pub capacity_bits: u64,
    /// Capacity of one macro in bits (drives span→shard conversion).
    pub macro_capacity_bits: u64,
    /// Bits actually resident.
    pub used_bits: u64,
}

/// One layer shard: a contiguous slice of a layer's output neurons placed
/// on one macro. The parallel engine instantiates one
/// [`crate::cim::CimMacro`] per shard; shards of the same layer sit on
/// *different* macros running concurrently, so the engine's ledger sums
/// their events (each macro burns its own row-cycles). Splitting a layer
/// into column groups *within* one macro is the separate lockstep model of
/// [`crate::cim::ShardedMacro`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    /// Index into `network.layers`.
    pub layer_idx: usize,
    /// Macro hosting the shard.
    pub macro_index: usize,
    /// First neuron (global index within the layer).
    pub neuron_start: usize,
    /// Number of neurons in the shard.
    pub neuron_count: usize,
}

impl Mapping {
    /// Per-timestep avoided operand traffic (the Fig. 4b metric).
    pub fn avoided_traffic_bits(&self, net: &Network) -> u64 {
        self.assignments.iter().map(|a| a.avoided_bits(net)).sum()
    }

    /// Per-timestep streamed operand traffic.
    pub fn streamed_traffic_bits(&self, net: &Network) -> u64 {
        self.assignments.iter().map(|a| a.streamed_bits(net)).sum()
    }

    /// CIM utilization in [0, 1].
    pub fn utilization(&self) -> f64 {
        self.used_bits as f64 / self.capacity_bits as f64
    }

    /// Number of layers whose nominal stationary operand is resident.
    pub fn layers_with_stationarity(&self) -> usize {
        self.assignments.iter().filter(|a| a.stationary_resident).count()
    }

    /// Number of macros in the budget that produced this mapping.
    pub fn num_macros(&self) -> usize {
        (self.capacity_bits / self.macro_capacity_bits.max(1)).max(1) as usize
    }

    /// Per-layer shard decomposition for the parallel engine.
    ///
    /// Resident layers are split across the macros their spans occupy,
    /// with neurons apportioned to each macro proportionally to its bit
    /// span (floor shares, leftover neurons handed out one each in span
    /// order, zero-neuron spans dropped).
    /// Streamed layers — nothing resident — still need a compute home, so
    /// they get a single shard round-robined over the macro array.
    pub fn shards(&self, net: &Network) -> Vec<Vec<Shard>> {
        let macros = self.num_macros();
        self.assignments
            .iter()
            .map(|a| {
                let layer_idx = a.layer_idx;
                let neurons = net.layers[layer_idx].num_neurons();
                let span_total: u64 = a.spans.iter().map(|&(_, b)| b).sum();
                if span_total == 0 || a.spans.is_empty() {
                    return vec![Shard {
                        layer_idx,
                        macro_index: layer_idx % macros,
                        neuron_start: 0,
                        neuron_count: neurons,
                    }];
                }
                // Proportional floor split, then hand out the remainder in
                // span order so counts always sum to `neurons`.
                let mut counts: Vec<usize> = a
                    .spans
                    .iter()
                    .map(|&(_, b)| ((neurons as u128 * b as u128) / span_total as u128) as usize)
                    .collect();
                let mut rem = neurons - counts.iter().sum::<usize>();
                for c in counts.iter_mut() {
                    if rem == 0 {
                        break;
                    }
                    *c += 1;
                    rem -= 1;
                }
                let mut out = Vec::with_capacity(a.spans.len());
                let mut start = 0usize;
                for (&(macro_index, _), &count) in a.spans.iter().zip(&counts) {
                    if count == 0 {
                        continue;
                    }
                    out.push(Shard {
                        layer_idx,
                        macro_index,
                        neuron_start: start,
                        neuron_count: count,
                    });
                    start += count;
                }
                debug_assert_eq!(start, neurons, "shards must cover the layer");
                out
            })
            .collect()
    }

    /// Render a Fig. 4(b)-style table.
    pub fn table(&self, net: &Network) -> String {
        let mut s = format!(
            "{:<6} {:<6} {:>12} {:>12} {:>10} {:>10}\n",
            "layer", "mode", "W bits", "V bits", "resident", "streamed"
        );
        for a in &self.assignments {
            let l = &net.layers[a.layer_idx];
            let mode = match (a.stationarity, a.stationary_resident) {
                (Stationarity::Ws, true) => "WS",
                (Stationarity::Os, true) => "OS",
                (_, false) => "--",
            };
            s.push_str(&format!(
                "{:<6} {:<6} {:>12} {:>12} {:>10} {:>10}\n",
                l.name,
                mode,
                l.weight_bits(),
                l.vmem_bits(),
                a.resident_bits(net),
                a.streamed_bits(net),
            ));
        }
        s.push_str(&format!(
            "capacity {} bits, used {} ({:.1}%), avoided/timestep {}\n",
            self.capacity_bits,
            self.used_bits,
            100.0 * self.utilization(),
            self.avoided_traffic_bits(net),
        ));
        s
    }
}

/// Residency option for one layer during the search.
#[derive(Debug, Clone, Copy)]
struct OptionCandidate {
    stationarity: Stationarity,
    stationary_resident: bool,
    extra_resident: bool,
    cost_bits: u64,
    value_bits: u64,
}

/// The mapping search engine.
#[derive(Debug, Clone)]
pub struct Mapper {
    /// Capacity of one macro in bits (16 kB = 131 072 for the chip).
    pub macro_capacity_bits: u64,
    /// Number of macros in the system.
    pub num_macros: usize,
}

impl Mapper {
    /// Mapper for `num_macros` FlexSpIM macros (512×256 bits each).
    pub fn flexspim(num_macros: usize) -> Self {
        Mapper { macro_capacity_bits: 512 * 256, num_macros }
    }

    /// Total capacity in bits.
    pub fn capacity_bits(&self) -> u64 {
        self.macro_capacity_bits * self.num_macros as u64
    }

    /// Compute the optimal mapping for `policy`.
    ///
    /// Semantics per policy family:
    /// * **WsOnly / OsOnly** — prior-art designs with a *fixed* operand
    ///   location: only the policy's operand may reside in CIM. The mapper
    ///   picks which layers' operands get residency (exact knapsack on
    ///   avoided traffic for small networks).
    /// * **HsMin / HsMax** — rule-based hybrids (Fig. 4a): the chosen
    ///   operand of *every* layer is made resident first (smallest-first
    ///   when capacity is short), then leftover capacity parks the other
    ///   operand of layers by traffic density.
    /// * **HsOpt** — free per-layer search (Fig. 4b "optimal layer
    ///   mapping"): any combination of {nothing, one operand, both}.
    pub fn map(&self, net: &Network, policy: Policy) -> Mapping {
        let cap = self.capacity_bits();
        let choice = match policy {
            Policy::WsOnly | Policy::OsOnly => {
                let options = fixed_location_options(net, policy);
                if search_space(&options) <= 2_000_000 {
                    exhaustive_search(&options, cap)
                } else {
                    greedy_search(&options, cap)
                }
            }
            Policy::HsMin | Policy::HsMax => rule_based_hybrid(net, policy, cap),
            Policy::HsOpt => {
                let options = free_options(net);
                if search_space(&options) <= 2_000_000 {
                    exhaustive_search(&options, cap)
                } else {
                    greedy_search(&options, cap)
                }
            }
        };

        // Pack resident operands into discrete macros (first-fit with
        // splitting — operands may span macro boundaries).
        let mut macro_free: Vec<u64> = vec![self.macro_capacity_bits; self.num_macros];
        let mut assignments = Vec::new();
        let mut used = 0u64;
        for (idx, opt) in choice.iter().enumerate() {
            let mut spans = Vec::new();
            let mut remaining = opt.cost_bits;
            used += opt.cost_bits;
            for (m, free) in macro_free.iter_mut().enumerate() {
                if remaining == 0 {
                    break;
                }
                if *free == 0 {
                    continue;
                }
                let take = remaining.min(*free);
                *free -= take;
                remaining -= take;
                spans.push((m, take));
            }
            assert_eq!(remaining, 0, "search result exceeded capacity");
            assignments.push(LayerAssignment {
                layer_idx: idx,
                stationarity: opt.stationarity,
                stationary_resident: opt.stationary_resident,
                extra_resident: opt.extra_resident,
                spans,
            });
        }
        Mapping {
            policy,
            assignments,
            capacity_bits: cap,
            macro_capacity_bits: self.macro_capacity_bits,
            used_bits: used,
        }
    }
}

/// Options for fixed-operand-location designs: nothing resident or the
/// policy's operand resident. No "both" option — prior-art arrays store
/// only one operand class.
fn fixed_location_options(net: &Network, policy: Policy) -> Vec<Vec<OptionCandidate>> {
    net.layers
        .iter()
        .map(|l| {
            let s = policy.fixed_choice(l).expect("fixed policy");
            let stat_op = s.stationary_operand();
            vec![
                OptionCandidate {
                    stationarity: s,
                    stationary_resident: false,
                    extra_resident: false,
                    cost_bits: 0,
                    value_bits: 0,
                },
                OptionCandidate {
                    stationarity: s,
                    stationary_resident: true,
                    extra_resident: false,
                    cost_bits: operand_bits(l, stat_op),
                    value_bits: avoided_traffic_bits(l, stat_op),
                },
            ]
        })
        .collect()
}

/// Full option set for the free HS-opt search: nothing, weights resident,
/// potentials resident, or both.
fn free_options(net: &Network) -> Vec<Vec<OptionCandidate>> {
    net.layers
        .iter()
        .map(|l| {
            let mut opts = Vec::new();
            for s in [Stationarity::Ws, Stationarity::Os] {
                let stat_op = s.stationary_operand();
                let stream_op = s.streamed_operand();
                opts.push(OptionCandidate {
                    stationarity: s,
                    stationary_resident: false,
                    extra_resident: false,
                    cost_bits: 0,
                    value_bits: 0,
                });
                opts.push(OptionCandidate {
                    stationarity: s,
                    stationary_resident: true,
                    extra_resident: false,
                    cost_bits: operand_bits(l, stat_op),
                    value_bits: avoided_traffic_bits(l, stat_op),
                });
                opts.push(OptionCandidate {
                    stationarity: s,
                    stationary_resident: true,
                    extra_resident: true,
                    cost_bits: operand_bits(l, stat_op) + operand_bits(l, stream_op),
                    value_bits: avoided_traffic_bits(l, stat_op)
                        + avoided_traffic_bits(l, stream_op),
                });
            }
            // Deduplicate by (cost, value): the "nothing resident" and
            // "both resident" options are identical under either
            // stationarity label, which would needlessly square the
            // search space (6^n → 4^n).
            opts.sort_by_key(|o| (o.cost_bits, o.value_bits));
            opts.dedup_by_key(|o| (o.cost_bits, o.value_bits));
            opts
        })
        .collect()
}

/// Rule-based HS-min / HS-max: mandatory residency of the rule's operand
/// (smallest-cost-first when capacity is short), then leftover capacity
/// parks the other operand of layers in traffic-density order.
fn rule_based_hybrid(net: &Network, policy: Policy, cap: u64) -> Vec<OptionCandidate> {
    let n = net.layers.len();
    let mut out: Vec<OptionCandidate> = net
        .layers
        .iter()
        .map(|l| {
            let s = policy.fixed_choice(l).expect("fixed policy");
            OptionCandidate {
                stationarity: s,
                stationary_resident: false,
                extra_resident: false,
                cost_bits: 0,
                value_bits: 0,
            }
        })
        .collect();

    // Phase 1: mandatory stationary residency, smallest cost first so the
    // number of layers with stationarity is maximized when capacity binds.
    let costs: Vec<u64> = (0..n)
        .map(|i| operand_bits(&net.layers[i], out[i].stationarity.stationary_operand()))
        .collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| costs[i]);
    let mut used = 0u64;
    for &i in &order {
        let c = costs[i];
        if used + c <= cap {
            used += c;
            out[i].stationary_resident = true;
            out[i].cost_bits = c;
            out[i].value_bits =
                avoided_traffic_bits(&net.layers[i], out[i].stationarity.stationary_operand());
        }
    }

    // Phase 2: park the other operand of layers in leftover capacity,
    // densest (avoided bits per resident bit) first.
    let mut extras: Vec<(usize, u64, u64)> = (0..n)
        .filter(|&i| out[i].stationary_resident)
        .map(|i| {
            let l = &net.layers[i];
            let op = out[i].stationarity.streamed_operand();
            (i, operand_bits(l, op), avoided_traffic_bits(l, op))
        })
        .filter(|&(_, c, _)| c > 0)
        .collect();
    extras.sort_by(|a, b| {
        let da = a.2 as f64 / a.1 as f64;
        let db = b.2 as f64 / b.1 as f64;
        db.partial_cmp(&da).unwrap()
    });
    for (i, c, v) in extras {
        if used + c <= cap {
            used += c;
            out[i].extra_resident = true;
            out[i].cost_bits += c;
            out[i].value_bits += v;
        }
    }
    out
}

fn search_space(options: &[Vec<OptionCandidate>]) -> u64 {
    options.iter().fold(1u64, |acc, o| acc.saturating_mul(o.len() as u64))
}

/// Exact exhaustive search over per-layer options (small networks).
fn exhaustive_search(options: &[Vec<OptionCandidate>], cap: u64) -> Vec<OptionCandidate> {
    let n = options.len();
    let mut best: Option<(u64, Vec<usize>)> = None;
    let mut idx = vec![0usize; n];
    loop {
        let mut cost = 0u64;
        let mut value = 0u64;
        for (l, &i) in idx.iter().enumerate() {
            cost += options[l][i].cost_bits;
            value += options[l][i].value_bits;
        }
        if cost <= cap && best.as_ref().map_or(true, |(bv, _)| value > *bv) {
            best = Some((value, idx.clone()));
        }
        // Odometer increment.
        let mut l = 0;
        loop {
            if l == n {
                let (_, bi) = best.expect("zero-cost option always feasible");
                return bi
                    .iter()
                    .enumerate()
                    .map(|(layer, &i)| options[layer][i])
                    .collect();
            }
            idx[l] += 1;
            if idx[l] < options[l].len() {
                break;
            }
            idx[l] = 0;
            l += 1;
        }
    }
}

/// Density-greedy fallback for large networks: sort candidate *upgrades*
/// by value/cost and apply while capacity lasts.
fn greedy_search(options: &[Vec<OptionCandidate>], cap: u64) -> Vec<OptionCandidate> {
    let n = options.len();
    // Start from the all-streamed option of the first stationarity choice.
    let mut current: Vec<OptionCandidate> = options.iter().map(|o| o[0]).collect();
    let mut used: u64 = 0;
    loop {
        // Best upgrade across layers by marginal density.
        let mut best: Option<(usize, OptionCandidate, f64)> = None;
        for l in 0..n {
            for cand in &options[l] {
                let dc = cand.cost_bits as i64 - current[l].cost_bits as i64;
                let dv = cand.value_bits as i64 - current[l].value_bits as i64;
                if dv <= 0 || dc <= 0 {
                    continue;
                }
                if used + dc as u64 > cap {
                    continue;
                }
                let density = dv as f64 / dc as f64;
                if best.as_ref().map_or(true, |&(_, _, d)| density > d) {
                    best = Some((l, *cand, density));
                }
            }
        }
        match best {
            Some((l, cand, _)) => {
                used = used + cand.cost_bits - current[l].cost_bits;
                current[l] = cand;
            }
            None => return current,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::network::{scnn_dvs_gesture, Network};
    use crate::snn::{LayerSpec, Resolution};

    #[test]
    fn ws_only_respects_capacity_and_policy() {
        let net = scnn_dvs_gesture();
        let m = Mapper::flexspim(2).map(&net, Policy::WsOnly);
        assert!(m.used_bits <= m.capacity_bits);
        assert!(m
            .assignments
            .iter()
            .all(|a| a.stationarity == Stationarity::Ws));
        // The big FC1 weights cannot fit in 2 macros.
        let fc1 = &m.assignments[6];
        assert!(!fc1.stationary_resident, "FC1 weights exceed 2 macros");
    }

    #[test]
    fn hs_min_gives_every_layer_stationarity_with_two_macros() {
        // Paper §II-B: two macros suffice for full per-layer stationarity
        // of at least one operand under HS.
        let net = scnn_dvs_gesture();
        let m = Mapper::flexspim(2).map(&net, Policy::HsMin);
        assert_eq!(m.layers_with_stationarity(), net.layers.len());
    }

    #[test]
    fn one_macro_cannot_give_full_hs() {
        // ...and one macro does not (the other half of the same claim).
        let net = scnn_dvs_gesture();
        let m = Mapper::flexspim(1).map(&net, Policy::HsMin);
        assert!(m.layers_with_stationarity() < net.layers.len());
    }

    #[test]
    fn fig4b_hs_min_gain_over_ws_only() {
        // Fig. 4(b): HS-min increases the amount of stationary operands by
        // ~46 % over WS-only on two macros with optimal mapping.
        let net = scnn_dvs_gesture();
        let mapper = Mapper::flexspim(2);
        let ws = mapper.map(&net, Policy::WsOnly);
        let hs = mapper.map(&net, Policy::HsMin);
        let gain = hs.avoided_traffic_bits(&net) as f64
            / ws.avoided_traffic_bits(&net) as f64
            - 1.0;
        assert!(
            (0.35..0.60).contains(&gain),
            "HS-min gain {:.3} outside the Fig. 4b band (paper: 0.46)",
            gain
        );
    }

    #[test]
    fn hs_opt_dominates_fixed_policies() {
        let net = scnn_dvs_gesture();
        for macros in [1usize, 2, 4, 16] {
            let mapper = Mapper::flexspim(macros);
            let opt = mapper.map(&net, Policy::HsOpt).avoided_traffic_bits(&net);
            for p in [Policy::WsOnly, Policy::OsOnly, Policy::HsMin, Policy::HsMax] {
                let v = mapper.map(&net, p).avoided_traffic_bits(&net);
                assert!(
                    opt >= v,
                    "HS-opt ({opt}) must dominate {p} ({v}) at {macros} macros"
                );
            }
        }
    }

    #[test]
    fn plentiful_capacity_keeps_everything_resident() {
        let net = scnn_dvs_gesture();
        // 64 macros = 1 MB: all operands of all layers fit.
        let m = Mapper::flexspim(64).map(&net, Policy::HsOpt);
        assert_eq!(m.streamed_traffic_bits(&net), 0);
        let total: u64 = net.total_weight_bits() + net.total_vmem_bits();
        assert_eq!(m.used_bits, total);
    }

    #[test]
    fn spans_are_consistent_with_residency() {
        let net = scnn_dvs_gesture();
        let m = Mapper::flexspim(4).map(&net, Policy::HsOpt);
        for a in &m.assignments {
            let spanned: u64 = a.spans.iter().map(|&(_, b)| b).sum();
            assert_eq!(spanned, a.resident_bits(&net));
        }
        // Per-macro occupancy must not exceed macro capacity.
        let mut occupancy = vec![0u64; 4];
        for a in &m.assignments {
            for &(mi, b) in &a.spans {
                occupancy[mi] += b;
            }
        }
        assert!(occupancy.iter().all(|&o| o <= 512 * 256));
    }

    #[test]
    fn greedy_engaged_for_large_networks() {
        // 20 layers × HsOpt = 6^20 options: must fall back to greedy and
        // still respect capacity.
        let r = Resolution::new(8, 8);
        let layers: Vec<LayerSpec> = (0..20)
            .map(|i| LayerSpec::fc(&format!("f{i}"), 64, 64, r))
            .collect();
        let net = Network::new("deep", layers, 4);
        let m = Mapper::flexspim(1).map(&net, Policy::HsOpt);
        assert!(m.used_bits <= m.capacity_bits);
        assert!(m.avoided_traffic_bits(&net) > 0);
    }

    #[test]
    fn shards_cover_every_layer_exactly_once() {
        let net = scnn_dvs_gesture();
        for macros in [1usize, 2, 4, 16] {
            let m = Mapper::flexspim(macros).map(&net, Policy::HsOpt);
            assert_eq!(m.num_macros(), macros);
            let shards = m.shards(&net);
            assert_eq!(shards.len(), net.layers.len());
            for (li, (layer_shards, layer)) in shards.iter().zip(&net.layers).enumerate() {
                assert!(!layer_shards.is_empty(), "layer {li} must have a shard");
                let mut next = 0usize;
                for s in layer_shards {
                    assert_eq!(s.layer_idx, li);
                    assert!(s.macro_index < macros, "macro index in range");
                    assert_eq!(s.neuron_start, next, "shards contiguous");
                    assert!(s.neuron_count > 0);
                    next += s.neuron_count;
                }
                assert_eq!(next, layer.num_neurons(), "layer {li} fully covered");
            }
        }
    }

    #[test]
    fn shards_follow_spans_proportionally() {
        // A resident layer split across two macros must shard its neurons
        // roughly proportionally to the per-macro bit spans.
        let net = scnn_dvs_gesture();
        let m = Mapper::flexspim(2).map(&net, Policy::HsMin);
        let shards = m.shards(&net);
        for (a, layer_shards) in m.assignments.iter().zip(&shards) {
            if a.spans.len() < 2 || layer_shards.len() != a.spans.len() {
                continue;
            }
            let neurons = net.layers[a.layer_idx].num_neurons() as f64;
            let bits: u64 = a.spans.iter().map(|&(_, b)| b).sum();
            for (&(_, span_bits), s) in a.spans.iter().zip(layer_shards) {
                let expect = neurons * span_bits as f64 / bits as f64;
                assert!(
                    (s.neuron_count as f64 - expect).abs() <= 1.0 + neurons * 0.01,
                    "layer {} shard {} neurons vs expected {expect:.1}",
                    a.layer_idx,
                    s.neuron_count
                );
            }
        }
    }

    #[test]
    fn table_renders() {
        let net = scnn_dvs_gesture();
        let m = Mapper::flexspim(2).map(&net, Policy::HsMin);
        let t = m.table(&net);
        assert!(t.contains("L1") && t.contains("FC3") && t.contains("capacity"));
    }
}
