//! Per-timestep execution planning.
//!
//! Turns a workload + dataflow mapping into a per-layer [`LayerPlan`]:
//! which macro shape executes the layer, how many macro passes and
//! row-cycles one timestep takes, and what traffic crosses the buffers.
//! The paper's latency claims (µs-level per timestep) follow from the
//! cycle counts here and the operating point (Fig. 2c clocks).

use crate::cim::ops::OperatingPoint;
use crate::cim::OperandShape;
use crate::dataflow::{Mapping, Operand};
use crate::snn::{LayerSpec, Network};

/// Execution plan for one layer.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    /// Layer name.
    pub name: String,
    /// Chosen operand shape columns (`N_C`) for the membrane potential.
    pub n_c: u32,
    /// Neurons processed in parallel per macro pass.
    pub parallel_neurons: usize,
    /// Macro passes to cover all output neurons once.
    pub passes_per_synapse: u64,
    /// Row-cycles per accumulate pass.
    pub cycles_per_pass: u64,
    /// Dense SOPs per timestep (before sparsity).
    pub sops_dense: u64,
    /// Bits streamed through buffers per timestep (dense estimate).
    pub streamed_bits: u64,
}

impl LayerPlan {
    /// Macro row-cycles for one timestep at the given input activity.
    /// Event-driven: only spiking synapses trigger accumulate passes,
    /// plus one fire pass (compare + conditional subtract).
    pub fn cycles_per_timestep(&self, activity: f64) -> u64 {
        let fan_in_active = (self.fan_in() as f64 * activity).ceil() as u64;
        let accumulate = fan_in_active * self.passes_per_synapse * self.cycles_per_pass;
        let fire = self.passes_per_synapse * 2 * self.cycles_per_pass;
        accumulate + fire
    }

    fn fan_in(&self) -> u64 {
        if self.passes_per_synapse == 0 || self.parallel_neurons == 0 {
            return 0;
        }
        self.sops_dense / (self.passes_per_synapse * self.parallel_neurons as u64).max(1)
    }
}

/// A full-network schedule.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Per-layer plans, in execution order.
    pub layers: Vec<LayerPlan>,
    /// Operating point used for latency conversion.
    pub op: OperatingPoint,
}

impl Schedule {
    /// Total macro cycles for one timestep at `activity` (layers execute
    /// sequentially on the macro array in the per-timestep flow, Fig. 1c).
    pub fn cycles_per_timestep(&self, activity: f64) -> u64 {
        self.layers.iter().map(|l| l.cycles_per_timestep(activity)).sum()
    }

    /// Wall-clock latency of one timestep (seconds).
    pub fn timestep_latency_s(&self, activity: f64) -> f64 {
        self.op.latency_s(self.cycles_per_timestep(activity))
    }

    /// Peak throughput in SOP/s at the operating point, summed over the
    /// layer the plan parallelizes best (diagnostics).
    pub fn peak_sops(&self) -> f64 {
        self.layers
            .iter()
            .map(|l| {
                l.parallel_neurons as f64 * self.op.system_clock_hz
                    / l.cycles_per_pass as f64
            })
            .fold(0.0, f64::max)
    }
}

/// The planner.
#[derive(Debug, Clone)]
pub struct Scheduler {
    /// Macro columns available per pass.
    pub macro_cols: usize,
    /// Operating point.
    pub op: OperatingPoint,
}

impl Default for Scheduler {
    fn default() -> Self {
        Scheduler { macro_cols: 256, op: OperatingPoint::nominal() }
    }
}

impl Scheduler {
    /// Pick the energy/latency-efficient operand shape for a layer: the
    /// widest `N_C` that still lets all requested neurons fit in one pass
    /// if possible (fewer row-cycles), otherwise the shape minimizing
    /// passes × cycles.
    pub fn choose_shape(&self, layer: &LayerSpec) -> (u32, usize) {
        let p_bits = layer.res.p_bits;
        let neurons = layer.num_neurons();
        let mut best: Option<(u64, u32, usize)> = None;
        for n_c in 1..=p_bits {
            let shape = OperandShape::new(p_bits, n_c);
            let parallel = (self.macro_cols / n_c as usize).max(1).min(neurons);
            let passes = neurons.div_ceil(parallel) as u64;
            let cost = passes * shape.n_r() as u64;
            if best.map_or(true, |(c, _, _)| cost < c) {
                best = Some((cost, n_c, parallel));
            }
        }
        let (_, n_c, parallel) = best.unwrap();
        (n_c, parallel)
    }

    /// Build the full-network schedule under a dataflow mapping.
    pub fn plan(&self, net: &Network, mapping: &Mapping) -> Schedule {
        let layers = net
            .layers
            .iter()
            .zip(&mapping.assignments)
            .map(|(l, a)| {
                let (n_c, parallel) = self.choose_shape(l);
                let shape = OperandShape::new(l.res.p_bits, n_c);
                let passes = l.num_neurons().div_ceil(parallel) as u64;
                // Streamed traffic per timestep (dense): operands without
                // residency move through the banks.
                let mut streamed = 0u64;
                if !a.stationary_resident {
                    streamed += match a.stationarity.stationary_operand() {
                        Operand::Weight => l.weight_bits(),
                        Operand::Vmem => 2 * l.vmem_bits(),
                    };
                }
                if !a.extra_resident {
                    streamed += match a.stationarity.streamed_operand() {
                        Operand::Weight => l.weight_bits(),
                        Operand::Vmem => 2 * l.vmem_bits(),
                    };
                }
                LayerPlan {
                    name: l.name.clone(),
                    n_c,
                    parallel_neurons: parallel,
                    passes_per_synapse: passes,
                    cycles_per_pass: shape.n_r() as u64,
                    sops_dense: l.sops_dense(),
                    streamed_bits: streamed,
                }
            })
            .collect();
        Schedule { layers, op: self.op }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{Mapper, Policy};
    use crate::snn::network::scnn_dvs_gesture;
    use crate::snn::Resolution;

    #[test]
    fn shape_choice_minimizes_cost() {
        let s = Scheduler::default();
        // 16-bit potential, few neurons: wide shapes win (1 row-cycle).
        let small = LayerSpec::fc("f", 8, 16, Resolution::new(8, 16));
        let (n_c, parallel) = s.choose_shape(&small);
        assert_eq!(parallel, 16);
        assert_eq!(n_c, 16, "all 16 neurons fit even bit-parallel");
        // Many neurons: bit-serial shapes maximize parallelism.
        let big = LayerSpec::fc("g", 8, 4096, Resolution::new(8, 16));
        let (n_c_big, par_big) = s.choose_shape(&big);
        assert_eq!(n_c_big, 1);
        assert_eq!(par_big, 256);
    }

    #[test]
    fn schedule_latency_is_microseconds_scale() {
        // The paper motivates µs-level inference latency per timestep.
        let net = scnn_dvs_gesture();
        let mapping = Mapper::flexspim(16).map(&net, Policy::HsOpt);
        let sched = Scheduler::default().plan(&net, &mapping);
        let dt = sched.timestep_latency_s(0.05); // 95 % sparsity
        assert!(dt > 1e-7 && dt < 2e-3, "timestep latency {dt:.2e} s");
    }

    #[test]
    fn latency_scales_with_activity() {
        let net = scnn_dvs_gesture();
        let mapping = Mapper::flexspim(16).map(&net, Policy::HsOpt);
        let sched = Scheduler::default().plan(&net, &mapping);
        assert!(sched.cycles_per_timestep(0.15) > sched.cycles_per_timestep(0.01));
    }

    #[test]
    fn full_residency_streams_nothing() {
        let net = scnn_dvs_gesture();
        let mapping = Mapper::flexspim(64).map(&net, Policy::HsOpt);
        let sched = Scheduler::default().plan(&net, &mapping);
        assert!(sched.layers.iter().all(|l| l.streamed_bits == 0));
    }

    #[test]
    fn peak_sops_matches_macro_model() {
        // Best-case layer: 256 parallel neurons, bit-serial p=16
        // → ~2.5 GSOPS at 157 MHz (Table I).
        let net = scnn_dvs_gesture().with_uniform_resolution(Resolution::new(8, 16));
        let mapping = Mapper::flexspim(16).map(&net, Policy::HsOpt);
        let sched = Scheduler::default().plan(&net, &mapping);
        let gsops = sched.peak_sops() / 1e9;
        assert!(gsops > 1.0 && gsops < 45.0, "peak {gsops:.2} GSOPS");
    }
}
