//! L3 coordinator: the accelerator control plane.
//!
//! Owns the event loop of Fig. 5a: DVS events → per-timestep spike buffer
//! → layer execution across the CIM macro array (via the PJRT-compiled
//! compute graph) → spikes out, while accounting energy (calibrated
//! model), latency (macro timing model), and buffer traffic
//! (merge-and-shift + SRAM banks). Python never runs here.
//!
//! * [`buffers`] — 4×4 × 2 kB SRAM banks and the 32-to-256-bit
//!   merge-and-shift bandwidth adapter.
//! * [`scheduler`] — per-timestep, per-layer execution plan from a
//!   dataflow [`crate::dataflow::Mapping`]: cycles, macro passes, traffic.
//! * [`metrics`] — run-level aggregation and reporting.
//! * [`engine`] — the sharded, batched parallel inference engine
//!   ([`engine::Engine`]) and the shared per-sample code path
//!   ([`engine::SamplePlan`]).
//! * [`pipeline`] — the sequential end-to-end inference driver
//!   ([`pipeline::Coordinator`]), a single-backend view of the engine's
//!   per-sample path.

pub mod buffers;
pub mod engine;
pub mod metrics;
pub mod pipeline;
pub mod scheduler;

pub use buffers::{BankArray, MergeShiftUnit};
pub use engine::{BatchResult, Engine, SampleBuffers, SamplePlan, ShardLedger, WindowTotals};
pub use metrics::{EnergyBreakdown, LatencyStats, LatencyWindow, RunMetrics};
pub use pipeline::{Coordinator, InferenceResult};
pub use scheduler::{LayerPlan, Schedule, Scheduler};
