//! End-to-end inference coordinator.
//!
//! The Fig. 5a control plane as one object: events → per-timestep sparse
//! spike lists ([`crate::snn::events::SpikeList`]) → network step on a
//! [`StepBackend`] (PJRT-compiled graph or the event-driven pure-Rust
//! engine) → prediction, with energy priced from *measured*
//! per-layer spike counts (not dense estimates), latency from the macro
//! timing model, buffer traffic through the merge-and-shift unit, and the
//! per-shard CIM event ledger charged from bit-sim-calibrated deltas.
//!
//! The per-sample execution itself lives in
//! [`super::engine::SamplePlan::run_sample`]; the coordinator is the
//! sequential, single-backend view of the same code path the parallel
//! [`super::engine::Engine`] drives from its worker pool.

use std::path::Path;

use anyhow::Result;

use super::buffers::{BankArray, MergeShiftUnit};
use super::engine::{merge_ordered, SampleBuffers, SamplePlan};
use super::metrics::RunMetrics;
use crate::dataflow::{Mapping, Policy};
use crate::events::EventStream;
use crate::runtime::{Runtime, ScnnRunner, StateSnapshot, StepBackend};
use crate::snn::Network;

/// Result of one sample inference.
#[derive(Debug, Clone)]
pub struct InferenceResult {
    /// Predicted class.
    pub prediction: usize,
    /// Rate-coded logits (spike counts per class).
    pub rate: Vec<i64>,
    /// Metrics for this sample.
    pub metrics: RunMetrics,
}

/// The end-to-end coordinator.
pub struct Coordinator {
    backend: Box<dyn StepBackend>,
    plan: SamplePlan,
    bufs: SampleBuffers,
}

impl Coordinator {
    /// Build the full stack: PJRT runtime + artifacts + HS-opt mapping on
    /// `num_macros` macros. Thin shim kept for artifact-gated tests; new
    /// code should materialize a coordinator from a
    /// [`crate::deploy::DeploymentSpec`].
    pub fn new(rt: &Runtime, artifacts: &Path, num_macros: usize) -> Result<Self> {
        let runner = ScnnRunner::load(rt, artifacts)?;
        Self::with_runner(runner, num_macros, Policy::HsOpt)
    }

    /// Build with an explicit PJRT runner and policy (thin shim over
    /// [`Self::with_backend`] for artifact-gated tests / ablations).
    pub fn with_runner(runner: ScnnRunner, num_macros: usize, policy: Policy) -> Result<Self> {
        Self::with_backend(Box::new(runner), num_macros, policy)
    }

    /// Build over any execution backend (PJRT or the pure-Rust
    /// [`crate::runtime::NativeScnn`]), deriving the plan from the
    /// backend's own network.
    pub fn with_backend(
        backend: Box<dyn StepBackend>,
        num_macros: usize,
        policy: Policy,
    ) -> Result<Self> {
        let net = backend.network().clone();
        let plan = SamplePlan::new(net, num_macros, policy);
        Ok(Self::from_plan(backend, plan))
    }

    /// Build from a pre-built plan and a backend already matched to it —
    /// the [`crate::deploy::Deployment`] entry point. The backend must
    /// execute the same topology the plan was built for (asserted layer
    /// by layer; a mismatch is a wiring bug, not a runtime condition).
    pub fn from_plan(backend: Box<dyn StepBackend>, plan: SamplePlan) -> Coordinator {
        {
            let (b, p) = (backend.network(), &plan.net);
            assert_eq!(
                b.layers.len(),
                p.layers.len(),
                "backend/plan layer count mismatch"
            );
            for (lb, lp) in b.layers.iter().zip(&p.layers) {
                assert_eq!(lb.in_shape(), lp.in_shape(), "layer {}: in-shape", lp.name);
                assert_eq!(lb.out_shape(), lp.out_shape(), "layer {}: out-shape", lp.name);
            }
        }
        Coordinator { backend, plan, bufs: SampleBuffers::default() }
    }

    /// Timesteps per inference (fixed by the workload's plan).
    pub fn timesteps(&self) -> usize {
        self.plan.timesteps
    }

    /// The dataflow mapping in force.
    pub fn mapping(&self) -> &Mapping {
        &self.plan.mapping
    }

    /// The workload.
    pub fn network(&self) -> &Network {
        &self.plan.net
    }

    /// The shared per-sample plan (what the parallel engine distributes).
    pub fn plan(&self) -> &SamplePlan {
        &self.plan
    }

    /// Buffer-model observability: the SRAM bank array.
    pub fn banks(&self) -> &BankArray {
        &self.bufs.banks
    }

    /// Buffer-model observability: the merge-and-shift unit.
    pub fn merge_shift(&self) -> &MergeShiftUnit {
        &self.bufs.merge_shift
    }

    /// Requantize at explicit per-layer resolutions (Fig. 6 sweeps).
    pub fn set_resolutions(&mut self, res: &[(u32, u32)]) {
        self.backend.set_resolutions(res);
    }

    /// Checkpoint the backend's membrane state (serve-tier equivalence
    /// tests and diagnostics).
    pub fn state(&self) -> StateSnapshot {
        self.backend.snapshot()
    }

    /// Run one event-stream sample end to end — the same code path the
    /// engine workers execute ([`SamplePlan::run_sample`]).
    pub fn run_sample(&mut self, stream: &EventStream, label: Option<usize>) -> Result<InferenceResult> {
        self.plan
            .run_sample(self.backend.as_mut(), &mut self.bufs, stream, label)
    }

    /// Run a labeled dataset sequentially; returns metrics merged in
    /// submission order — the same merge the batched engine applies, so
    /// sequential and parallel aggregates are identical.
    pub fn run_dataset(&mut self, data: &[(EventStream, usize)]) -> Result<RunMetrics> {
        let mut results = Vec::with_capacity(data.len());
        for (stream, label) in data {
            results.push(self.run_sample(stream, Some(*label))?);
        }
        Ok(merge_ordered(&results))
    }
}

#[cfg(test)]
mod tests {
    // Pipeline tests that need the PJRT runtime + artifacts live in
    // rust/tests/integration_runtime.rs; the engine-vs-sequential
    // equivalence lives in rust/tests/integration_engine.rs. Here we test
    // the pure parts.
    use super::*;
    use crate::events::encode_frames;
    use crate::runtime::NativeScnn;
    use crate::snn::network::scnn_dvs_gesture;
    use crate::snn::{LayerSpec, Resolution};
    use crate::util::rng::Rng;

    #[test]
    fn inference_result_fields() {
        let r = InferenceResult {
            prediction: 3,
            rate: vec![0; 10],
            metrics: RunMetrics::default(),
        };
        assert_eq!(r.prediction, 3);
    }

    #[test]
    fn merge_shift_tracks_event_traffic() {
        let mut ms = MergeShiftUnit::default();
        let mut rng = Rng::new(1);
        let gen = crate::events::GestureGenerator::default_48();
        let s = gen.sample(crate::events::GestureClass::HandClap, &mut rng);
        let frames = encode_frames(&s, 16);
        for f in &frames {
            ms.transfer(f.count() as u64, 16);
        }
        assert!(ms.beats > 0 && ms.payload_bits > 0);
    }

    #[test]
    fn coordinator_runs_on_native_backend() {
        // The coordinator no longer needs artifacts: the pure-Rust backend
        // exercises the full control plane (energy, latency, CIM ledger).
        let r = Resolution::new(4, 9);
        let net = Network::new(
            "native-pipe",
            vec![
                LayerSpec::conv("C1", 2, 4, 3, 4, 1, 48, 48, r),
                LayerSpec::fc("F1", 4 * 12 * 12, 10, r),
            ],
            4,
        );
        let backend = Box::new(NativeScnn::new(net, 5));
        let mut coord = Coordinator::with_backend(backend, 2, Policy::HsOpt).unwrap();
        assert_eq!(coord.network().layers.len(), 2);
        assert_eq!(coord.mapping().assignments.len(), 2);
        let gen = crate::events::GestureGenerator::default_48();
        let mut rng = Rng::new(2);
        let s = gen.sample(crate::events::GestureClass::ArmRoll, &mut rng);
        let r = coord.run_sample(&s, Some(7)).unwrap();
        assert!(r.prediction < 10);
        assert_eq!(r.metrics.timesteps, 4);
        assert!(r.metrics.in_events > 0, "event counts observed");
        assert!(r.metrics.sops > 0);
        assert!(r.metrics.energy.total_pj() > 0.0);
        assert!(r.metrics.cim.cim_cycles > 0, "shard ledger charged");
        assert!(coord.merge_shift().beats > 0, "buffer models observed traffic");
    }

    #[test]
    fn plan_exposes_shard_topology() {
        let plan = SamplePlan::new(scnn_dvs_gesture(), 4, Policy::HsOpt);
        assert_eq!(plan.shards.per_layer.len(), 9);
        assert!(plan.shards.shard_count() >= 9);
    }
}
