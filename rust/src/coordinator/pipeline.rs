//! End-to-end inference coordinator.
//!
//! The Fig. 5a control plane as one object: events → per-timestep spike
//! buffer → PJRT-executed network step → prediction, with energy priced
//! from *measured* per-layer spike counts (not dense estimates), latency
//! from the macro timing model, and buffer traffic through the
//! merge-and-shift unit. The hot loop is pure Rust + the compiled XLA
//! executable.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use super::buffers::{BankArray, MergeShiftUnit};
use super::metrics::{EnergyBreakdown, RunMetrics};
use super::scheduler::{Schedule, Scheduler};
use crate::dataflow::{Mapper, Mapping, Operand, Policy};
use crate::energy::SystemEnergyModel;
use crate::events::{encode_frames, EventStream};
use crate::runtime::{Runtime, ScnnRunner};
use crate::snn::Network;

/// Result of one sample inference.
#[derive(Debug, Clone)]
pub struct InferenceResult {
    /// Predicted class.
    pub prediction: usize,
    /// Rate-coded logits (spike counts per class).
    pub rate: Vec<i64>,
    /// Metrics for this sample.
    pub metrics: RunMetrics,
}

/// The end-to-end coordinator.
pub struct Coordinator {
    runner: ScnnRunner,
    net: Network,
    mapping: Mapping,
    schedule: Schedule,
    energy: SystemEnergyModel,
    /// Buffer models (observability; energy uses the calibrated paths).
    pub banks: BankArray,
    /// Merge-and-shift unit model.
    pub merge_shift: MergeShiftUnit,
    /// Timesteps per inference.
    pub timesteps: usize,
}

impl Coordinator {
    /// Build the full stack: PJRT runtime + artifacts + HS-opt mapping on
    /// `num_macros` macros.
    pub fn new(rt: &Runtime, artifacts: &Path, num_macros: usize) -> Result<Self> {
        let runner = ScnnRunner::load(rt, artifacts)?;
        Self::with_runner(runner, num_macros, Policy::HsOpt)
    }

    /// Build with an explicit runner and policy (testing / ablations).
    pub fn with_runner(runner: ScnnRunner, num_macros: usize, policy: Policy) -> Result<Self> {
        let net = runner.network().clone();
        let mapping = Mapper::flexspim(num_macros).map(&net, policy);
        let schedule = Scheduler::default().plan(&net, &mapping);
        let energy = SystemEnergyModel::flexspim(num_macros);
        let timesteps = net.timesteps;
        Ok(Coordinator {
            runner,
            net,
            mapping,
            schedule,
            energy,
            banks: BankArray::flexspim(),
            merge_shift: MergeShiftUnit::default(),
            timesteps,
        })
    }

    /// The dataflow mapping in force.
    pub fn mapping(&self) -> &Mapping {
        &self.mapping
    }

    /// The workload.
    pub fn network(&self) -> &Network {
        &self.net
    }

    /// Requantize at explicit per-layer resolutions (Fig. 6 sweeps).
    pub fn set_resolutions(&mut self, res: &[(u32, u32)]) {
        self.runner.set_resolutions(res);
    }

    /// Run one event-stream sample end to end.
    pub fn run_sample(&mut self, stream: &EventStream, label: Option<usize>) -> Result<InferenceResult> {
        let t0 = Instant::now();
        let frames = encode_frames(stream, self.timesteps);
        self.runner.reset();

        let mut rate = vec![0i64; 10];
        let mut energy = EnergyBreakdown::default();
        let mut total_sops = 0u64;
        let mut modeled_latency = 0.0;
        let mut sparsity_acc = 0.0;

        for frame in &frames {
            let in_bits: Vec<i32> = frame.as_input_vector().iter().map(|&b| b as i32).collect();
            // Buffer traffic: the input frame enters through the
            // merge-and-shift unit as 1-bit operands.
            let in_count = frame.count() as u64;
            self.merge_shift.transfer(in_count.max(1), 16); // AER events
            self.banks.write(in_count * 16);

            let step = self.runner.step(&in_bits)?;
            for (acc, s) in rate.iter_mut().zip(&step.out_spikes) {
                *acc += *s as i64;
            }

            // Energy from measured per-layer activity: layer l's input
            // spikes are the previous layer's output count (layer 0 sees
            // the frame).
            let mut in_events = frame.count() as f64;
            for (li, (layer, assign)) in self
                .net
                .layers
                .iter()
                .zip(&self.mapping.assignments)
                .enumerate()
            {
                let in_neurons = {
                    let (c, h, w) = layer.in_shape();
                    (c * h * w) as f64
                };
                let activity = (in_events / in_neurons).min(1.0);
                let sops = layer.sops_dense() as f64 * activity;
                total_sops += sops as u64;
                energy.compute_pj +=
                    sops * self.energy.sop_pj(layer.res.w_bits, layer.res.p_bits, None);
                for op in [Operand::Weight, Operand::Vmem] {
                    let resident = if op == assign.stationarity.stationary_operand() {
                        assign.stationary_resident
                    } else {
                        assign.extra_resident
                    };
                    if !resident {
                        energy.movement_pj += self.energy.streamed_pj(
                            layer,
                            op,
                            sops,
                            self.energy.cfg.vmem_discipline,
                        );
                    }
                }
                let out_events = step.counts[li] as f64;
                energy.spike_pj += (in_events + out_events)
                    * self.energy.cfg.spike_addr_bits as f64
                    * self.energy.cfg.e_gbuf_pj_bit;
                in_events = out_events;
            }

            let frame_activity = frame.count() as f64 / frame.as_input_vector().len() as f64;
            sparsity_acc += 1.0 - frame_activity;
            modeled_latency += self.schedule.timestep_latency_s(frame_activity);
        }

        let prediction = ScnnRunner::predict(&rate);
        let correct = label.map_or(0, |l| (l == prediction) as u64);
        let metrics = RunMetrics {
            samples: 1,
            correct,
            timesteps: frames.len() as u64,
            sops: total_sops,
            mean_sparsity: sparsity_acc / frames.len() as f64,
            energy,
            modeled_latency_s: modeled_latency,
            wallclock_s: t0.elapsed().as_secs_f64(),
        };
        Ok(InferenceResult { prediction, rate, metrics })
    }

    /// Run a labeled dataset; returns aggregated metrics.
    pub fn run_dataset(&mut self, data: &[(EventStream, usize)]) -> Result<RunMetrics> {
        let mut total = RunMetrics::default();
        for (stream, label) in data {
            let r = self.run_sample(stream, Some(*label))?;
            total.merge(&r.metrics);
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    // Pipeline tests that need the PJRT runtime + artifacts live in
    // rust/tests/integration_runtime.rs; here we only test the pure parts.
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn inference_result_fields() {
        let r = InferenceResult {
            prediction: 3,
            rate: vec![0; 10],
            metrics: RunMetrics::default(),
        };
        assert_eq!(r.prediction, 3);
    }

    #[test]
    fn merge_shift_tracks_event_traffic() {
        let mut ms = MergeShiftUnit::default();
        let mut rng = Rng::new(1);
        let gen = crate::events::GestureGenerator::default_48();
        let s = gen.sample(crate::events::GestureClass::HandClap, &mut rng);
        let frames = encode_frames(&s, 16);
        for f in &frames {
            ms.transfer(f.count() as u64, 16);
        }
        assert!(ms.beats > 0 && ms.payload_bits > 0);
    }
}
