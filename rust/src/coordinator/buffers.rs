//! On-chip buffer models (paper Fig. 5a).
//!
//! Beyond the CIM macro, the accelerator has 4×4 banks of 2-kB SRAM that
//! buffer the streamed operand (weights in OS mode, membrane potentials in
//! WS mode), and a 32-to-256-bit *merge-and-shift* unit that aligns
//! arbitrary-width operands to the macro's I/O port — the piece that makes
//! bitwise-granular resolutions practical at the system level.

/// One SRAM bank.
#[derive(Debug, Clone)]
pub struct Bank {
    /// Capacity in bits.
    pub capacity_bits: u64,
    /// Bits currently allocated.
    pub used_bits: u64,
    /// Total read traffic (bits).
    pub reads_bits: u64,
    /// Total write traffic (bits).
    pub writes_bits: u64,
}

/// The 4×4 bank array.
#[derive(Debug, Clone)]
pub struct BankArray {
    banks: Vec<Bank>,
}

impl Default for BankArray {
    fn default() -> Self {
        Self::flexspim()
    }
}

impl BankArray {
    /// The chip's configuration: 16 banks × 2 kB.
    pub fn flexspim() -> Self {
        BankArray {
            banks: (0..16)
                .map(|_| Bank {
                    capacity_bits: 2 * 1024 * 8,
                    used_bits: 0,
                    reads_bits: 0,
                    writes_bits: 0,
                })
                .collect(),
        }
    }

    /// Total capacity in bits (32 kB for the chip).
    pub fn capacity_bits(&self) -> u64 {
        self.banks.iter().map(|b| b.capacity_bits).sum()
    }

    /// Free bits across banks.
    pub fn free_bits(&self) -> u64 {
        self.banks.iter().map(|b| b.capacity_bits - b.used_bits).sum()
    }

    /// Allocate `bits` across banks (first-fit, spanning allowed).
    /// Returns false if it does not fit.
    pub fn allocate(&mut self, bits: u64) -> bool {
        if bits > self.free_bits() {
            return false;
        }
        let mut remaining = bits;
        for b in &mut self.banks {
            if remaining == 0 {
                break;
            }
            let take = remaining.min(b.capacity_bits - b.used_bits);
            b.used_bits += take;
            remaining -= take;
        }
        true
    }

    /// Release everything (between layers).
    pub fn clear(&mut self) {
        for b in &mut self.banks {
            b.used_bits = 0;
        }
    }

    /// Record read traffic (spread across banks round-robin in hardware;
    /// aggregate counters suffice for energy).
    pub fn read(&mut self, bits: u64) {
        self.banks[0].reads_bits += bits;
    }

    /// Record write traffic.
    pub fn write(&mut self, bits: u64) {
        self.banks[0].writes_bits += bits;
    }

    /// Total (reads, writes) bits.
    pub fn traffic(&self) -> (u64, u64) {
        (
            self.banks.iter().map(|b| b.reads_bits).sum(),
            self.banks.iter().map(|b| b.writes_bits).sum(),
        )
    }
}

/// The 32-to-256-bit bandwidth-adaptive merge-and-shift unit: packs/unpacks
/// arbitrary-width operands (any `w_bits`/`p_bits`) into the macro port.
#[derive(Debug, Clone, Default)]
pub struct MergeShiftUnit {
    /// Port transfers executed (256-bit beats).
    pub beats: u64,
    /// Shift micro-ops performed for alignment.
    pub shift_ops: u64,
    /// Bits transferred (payload).
    pub payload_bits: u64,
}

impl MergeShiftUnit {
    /// Bus width into the macro (bits).
    pub const PORT_BITS: u64 = 256;
    /// Narrow side granularity (bits).
    pub const WORD_BITS: u64 = 32;

    /// Transfer `count` operands of `op_bits` each; returns beats used.
    /// Operands are packed back-to-back (no padding waste — that is the
    /// unit's purpose); each operand that straddles a 32-bit word boundary
    /// costs one shift micro-op.
    pub fn transfer(&mut self, count: u64, op_bits: u64) -> u64 {
        assert!(op_bits >= 1);
        let total = count * op_bits;
        let beats = total.div_ceil(Self::PORT_BITS);
        self.beats += beats;
        self.payload_bits += total;
        // An operand straddles a word boundary unless op_bits divides 32
        // and stays aligned; count straddles exactly.
        let mut shifts = 0;
        if op_bits % Self::WORD_BITS != 0 {
            let mut bit = 0u64;
            for _ in 0..count {
                let start_word = bit / Self::WORD_BITS;
                let end_word = (bit + op_bits - 1) / Self::WORD_BITS;
                if start_word != end_word || bit % Self::WORD_BITS != 0 {
                    shifts += 1;
                }
                bit += op_bits;
            }
        }
        self.shift_ops += shifts;
        beats
    }

    /// Port utilization: payload bits over raw beat capacity.
    pub fn utilization(&self) -> f64 {
        if self.beats == 0 {
            return 0.0;
        }
        self.payload_bits as f64 / (self.beats * Self::PORT_BITS) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_array_capacity() {
        let b = BankArray::flexspim();
        assert_eq!(b.capacity_bits(), 16 * 2 * 1024 * 8); // 32 kB
    }

    #[test]
    fn allocation_spans_banks() {
        let mut b = BankArray::flexspim();
        assert!(b.allocate(3 * 2 * 1024 * 8)); // 3 banks worth
        assert_eq!(b.free_bits(), 13 * 2 * 1024 * 8);
        assert!(!b.allocate(14 * 2 * 1024 * 8), "overcommit rejected");
        b.clear();
        assert_eq!(b.free_bits(), b.capacity_bits());
    }

    #[test]
    fn traffic_counters() {
        let mut b = BankArray::flexspim();
        b.read(100);
        b.write(50);
        b.read(10);
        assert_eq!(b.traffic(), (110, 50));
    }

    #[test]
    fn merge_shift_packs_tightly() {
        let mut ms = MergeShiftUnit::default();
        // 256 operands of 5 bits = 1280 bits = 5 beats, zero padding.
        let beats = ms.transfer(256, 5);
        assert_eq!(beats, 5);
        assert!((ms.utilization() - 1.0).abs() < 1e-12);
        // 11-bit operands mostly straddle word boundaries.
        let mut ms2 = MergeShiftUnit::default();
        ms2.transfer(64, 11);
        assert!(ms2.shift_ops > 0);
    }

    #[test]
    fn aligned_operands_need_no_shifts() {
        let mut ms = MergeShiftUnit::default();
        ms.transfer(100, 32);
        assert_eq!(ms.shift_ops, 0);
        let mut ms64 = MergeShiftUnit::default();
        ms64.transfer(10, 64);
        assert_eq!(ms64.shift_ops, 0);
    }

    #[test]
    fn beats_round_up() {
        let mut ms = MergeShiftUnit::default();
        assert_eq!(ms.transfer(1, 1), 1, "one bit still costs one beat");
        assert_eq!(ms.transfer(257, 1), 2);
    }
}
