//! Parallel multi-macro inference engine with batched scheduling.
//!
//! The single-threaded [`super::Coordinator`] models one sample at a time.
//! The FlexSpIM system claim, however, is about *scale*: many CIM macros
//! holding different layer shards, all busy at once, with the hybrid
//! weight-/output-stationary dataflow keeping operand movement minimal.
//! This module is the software equivalent of that regime: a sharded,
//! batched engine that drives a pool of worker threads over a shared
//! request queue of inference samples.
//!
//! ```text
//!                        ┌───────────────────────────────┐
//!   batch of             │            Engine             │
//!   EventStreams ──────► │  RequestQueue<WorkUnit>       │
//!   (sample i)           │   │ steal  │ steal  │ steal   │
//!                        │   ▼        ▼        ▼         │
//!                        │ worker0  worker1  worker2 …   │
//!                        │  ├ StepBackend (own instance) │
//!                        │  ├ SampleBuffers (banks+MS)   │
//!                        │  └ SamplePlan::run_sample ────┼──► (i, InferenceResult)
//!                        │        ▲ shared, read-only    │
//!                        │  SamplePlan                   │
//!                        │   ├ Network / Mapping         │
//!                        │   ├ Schedule / energy model   │
//!                        │   └ ShardLedger               │
//!                        │      one CimMacro per layer   │
//!                        │      shard (Mapper spans),    │
//!                        │      per-op deltas calibrated │
//!                        │      by running the bit-sim   │
//!                        └───────────────────────────────┘
//!                                     │ merge_ordered (sample order)
//!                                     ▼
//!                                 RunMetrics
//! ```
//!
//! **One code path.** [`SamplePlan::run_sample`] is the per-sample
//! pipeline — event encoding, backend stepping, energy pricing, shard
//! ledger charging. The sequential [`super::Coordinator`] and every engine
//! worker call exactly this function, and both merge per-sample metrics
//! with [`merge_ordered`] in submission order, so a 4-worker batch is
//! bit-identical (spikes, rates, energy, ledger — everything except host
//! wall-clock) to the sequential run. `rust/tests/integration_engine.rs`
//! pins that property.
//!
//! **`Send` constraints.** The PJRT client behind
//! [`crate::runtime::ScnnRunner`] is `Rc`-based and not `Send`, so a
//! backend can never migrate between threads. The engine therefore takes a
//! *factory* (`Fn() -> Result<Box<dyn StepBackend>> + Send + Sync`) and
//! each worker constructs its own backend inside its thread — per-worker
//! runner handles, the same pattern the artifact-gated tests use. The
//! pure-Rust [`crate::runtime::NativeScnn`] is deterministic from a seed,
//! which is what makes per-worker instances interchangeable.
//!
//! **Shards.** [`ShardLedger::calibrate`] instantiates one
//! [`CimMacro`](crate::cim::CimMacro) per layer shard from the
//! [`Mapping::shards`] decomposition, executes one real accumulate and one
//! fire pass on the bit-level simulator, and caches the per-op
//! [`EnergyCounters`] deltas (which are pure functions of the macro
//! configuration). Workers then charge `delta × events` per timestep —
//! grounded in the simulator without paying bit-sim cost per spike — and
//! the aggregate lands in [`RunMetrics::cim`].

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::cim::{CimMacro, EnergyCounters, MacroConfig};
use crate::coordinator::buffers::{BankArray, MergeShiftUnit};
use crate::coordinator::metrics::{EnergyBreakdown, RunMetrics};
use crate::coordinator::scheduler::{Schedule, Scheduler};
use crate::dataflow::{Mapper, Mapping, Operand, Policy, Shard};
use crate::energy::SystemEnergyModel;
use crate::events::{encode_frames_sparse, EventStream};
use crate::runtime::{NativeScnn, ScnnRunner, StepBackend, StepResult};
use crate::snn::events::{AdjacencyCache, SpikeList};
use crate::snn::Network;
use crate::Result;

pub use super::pipeline::InferenceResult;

// ------------------------------------------------------------ shard ledger

/// A layer shard plus its calibrated per-operation counter deltas.
///
/// A shard larger than one macro pass runs `full_passes` passes with the
/// full neuron group plus (when the division has a remainder) one final
/// pass with only the leftover neurons active — the remainder pass gets
/// its own calibration so partial passes are not over-charged.
#[derive(Debug, Clone)]
pub struct ShardCal {
    /// The shard this calibration covers.
    pub shard: Shard,
    /// Ledger delta of one full-group synaptic accumulate pass.
    pub accumulate: EnergyCounters,
    /// Ledger delta of one full-group threshold-compare pass.
    pub fire: EnergyCounters,
    /// Passes with the full per-pass neuron group.
    pub full_passes: u64,
    /// Ledger delta of the remainder accumulate pass (zero if none).
    pub accumulate_rem: EnergyCounters,
    /// Ledger delta of the remainder compare pass (zero if none).
    pub fire_rem: EnergyCounters,
}

impl ShardCal {
    /// Total macro passes to cover the shard's neurons once.
    pub fn passes(&self) -> u64 {
        self.full_passes + (self.accumulate_rem.sops > 0) as u64
    }

    /// Ledger charge for one timestep of this shard seeing `in_events`
    /// input spikes: `in_events` accumulate passes plus one fire pass per
    /// pass group.
    pub fn charge(&self, in_events: u64) -> EnergyCounters {
        let mut total = self.accumulate.scaled(in_events * self.full_passes);
        total.merge(&self.accumulate_rem.scaled(in_events));
        total.merge(&self.fire.scaled(self.full_passes));
        total.merge(&self.fire_rem);
        total
    }
}

/// Per-layer shard calibrations for a mapped workload.
#[derive(Debug, Clone, Default)]
pub struct ShardLedger {
    /// Outer index: layer; inner: shards of that layer.
    pub per_layer: Vec<Vec<ShardCal>>,
}

impl ShardLedger {
    /// Instantiate one [`CimMacro`] per mapped layer shard and measure its
    /// per-op ledger deltas on the bit-level simulator.
    ///
    /// Accumulate and compare-pass deltas are pure functions of the macro
    /// configuration (they do not depend on stored data), so a single
    /// execution calibrates the shard exactly. The conditional
    /// reset-by-subtraction pass *is* data-dependent; its events are folded
    /// into the analytic energy model instead of this ledger.
    pub fn calibrate(net: &Network, mapping: &Mapping, schedule: &Schedule) -> ShardLedger {
        // Measure one accumulate + one compare pass on a freshly built
        // macro of `neurons` resident neurons. The scheduler guarantees a
        // fitting shape (n_c ≤ p_bits, neurons × n_c ≤ cols); fail loudly
        // rather than silently under-reporting a shard's ledger.
        let measure = |layer: &crate::snn::LayerSpec,
                       n_c: u32,
                       neurons: usize|
         -> (EnergyCounters, EnergyCounters) {
            let cfg =
                MacroConfig::flexspim(layer.res.w_bits, layer.res.p_bits, n_c, 1, neurons);
            let mut mac = CimMacro::new(cfg).unwrap_or_else(|e| {
                panic!(
                    "shard calibration: layer {} shape N_C={n_c} x{neurons} \
                     rejected by the macro: {e}",
                    layer.name
                )
            });
            let before = *mac.counters();
            mac.cim_accumulate(0, None);
            let accumulate = mac.counters().delta(&before);
            let before = *mac.counters();
            let _ = mac.cim_fire(layer.threshold.max(1));
            let fire = mac.counters().delta(&before);
            (accumulate, fire)
        };

        let shards = mapping.shards(net);
        let per_layer = shards
            .into_iter()
            .map(|layer_shards| {
                layer_shards
                    .into_iter()
                    .map(|shard| {
                        let layer = &net.layers[shard.layer_idx];
                        let plan = &schedule.layers[shard.layer_idx];
                        let n_c = plan.n_c.max(1);
                        // Column budget comes from the macro geometry, not
                        // a duplicated literal.
                        let cols = MacroConfig::flexspim(1, 1, 1, 1, 1).cols;
                        let per_pass = (cols / n_c as usize).max(1).min(shard.neuron_count);
                        let (accumulate, fire) = measure(layer, n_c, per_pass);
                        let rem = shard.neuron_count % per_pass;
                        let (accumulate_rem, fire_rem) = if rem > 0 {
                            measure(layer, n_c, rem)
                        } else {
                            (EnergyCounters::new(), EnergyCounters::new())
                        };
                        ShardCal {
                            shard,
                            accumulate,
                            fire,
                            full_passes: (shard.neuron_count / per_pass) as u64,
                            accumulate_rem,
                            fire_rem,
                        }
                    })
                    .collect()
            })
            .collect();
        ShardLedger { per_layer }
    }

    /// Total shard count across layers.
    pub fn shard_count(&self) -> usize {
        self.per_layer.iter().map(Vec::len).sum()
    }

    /// Ledger charge for one timestep of `layer_idx` seeing `in_events`
    /// input spikes: `in_events` accumulate passes plus one fire pass, on
    /// every pass group of every shard.
    pub fn charge_layer(&self, layer_idx: usize, in_events: u64) -> EnergyCounters {
        let mut total = EnergyCounters::new();
        for cal in &self.per_layer[layer_idx] {
            total.merge(&cal.charge(in_events));
        }
        total
    }
}

// ------------------------------------------------------------- sample plan

/// Per-worker mutable buffer models (observability only — the priced
/// energy comes from the calibrated analytic paths).
#[derive(Debug, Clone)]
pub struct SampleBuffers {
    /// 4×4 × 2 kB SRAM bank array.
    pub banks: BankArray,
    /// 32-to-256-bit merge-and-shift unit.
    pub merge_shift: MergeShiftUnit,
    /// Reusable per-step result scratch — [`SamplePlan::run_frames`] steps
    /// the backend into this so the steady-state window loop stays
    /// allocation-free (`rust/tests/alloc_steady_state.rs`).
    pub step: StepResult,
}

impl Default for SampleBuffers {
    fn default() -> Self {
        SampleBuffers {
            banks: BankArray::flexspim(),
            merge_shift: MergeShiftUnit::default(),
            step: StepResult::default(),
        }
    }
}

/// Everything shared and immutable across samples: the workload, its
/// mapping, the execution schedule, the energy model, and the calibrated
/// shard ledger. `Sync`, so one instance serves all workers.
#[derive(Debug, Clone)]
pub struct SamplePlan {
    /// The workload.
    pub net: Network,
    /// Dataflow mapping in force.
    pub mapping: Mapping,
    /// Per-layer execution schedule.
    pub schedule: Schedule,
    /// Calibrated system energy model.
    pub energy: SystemEnergyModel,
    /// Per-shard calibrated CIM ledgers.
    pub shards: ShardLedger,
    /// Timesteps per inference.
    pub timesteps: usize,
}

impl SamplePlan {
    /// Build the plan for `net` on `num_macros` macros under `policy` at
    /// the nominal energy operating point.
    pub fn new(net: Network, num_macros: usize, policy: Policy) -> SamplePlan {
        let energy = SystemEnergyModel::flexspim(num_macros);
        Self::with_energy(net, num_macros, policy, energy)
    }

    /// Build with an explicit energy model — the [`crate::deploy`] tier's
    /// entry point for non-nominal substrate settings (vdd envelope).
    pub fn with_energy(
        net: Network,
        num_macros: usize,
        policy: Policy,
        energy: SystemEnergyModel,
    ) -> SamplePlan {
        let mapping = Mapper::flexspim(num_macros).map(&net, policy);
        let schedule = Scheduler::default().plan(&net, &mapping);
        let shards = ShardLedger::calibrate(&net, &mapping, &schedule);
        let timesteps = net.timesteps;
        SamplePlan { net, mapping, schedule, energy, shards, timesteps }
    }

    /// Run a window of already-encoded sparse frames on `backend`
    /// **without resetting state**, accumulating classifier spikes into
    /// `rate` — the inner loop of [`Self::run_sample`], shared with the
    /// streaming serve tier ([`crate::serve`]), whose micro-windows resume
    /// from the session's persistent membrane potentials.
    ///
    /// Frames arrive as borrowed [`SpikeList`]s (the encoder emits them
    /// directly — no dense bitmap or per-frame conversion) and the backend
    /// steps into `bufs.step`, so the loop performs no heap allocation in
    /// steady state.
    pub fn run_frames(
        &self,
        backend: &mut dyn StepBackend,
        bufs: &mut SampleBuffers,
        frames: &[SpikeList],
        rate: &mut [i64],
    ) -> Result<WindowTotals> {
        let _span = crate::telemetry::trace::span("plan.run_frames");
        let mut totals = WindowTotals::default();

        for spikes_in in frames {
            // The sparse datapath: the frame enters as an AER spike list
            // and stays sparse through every layer of the backend.
            let in_count = spikes_in.count() as u64;
            // Buffer traffic: the input events flow through the
            // merge-and-shift unit.
            bufs.merge_shift.transfer(in_count.max(1), 16);
            bufs.banks.write(in_count * 16);

            {
                let _s = crate::telemetry::trace::span("backend.step");
                backend.step_into(spikes_in, &mut bufs.step)?;
            }
            let step = &bufs.step;
            for &c in step.out_spikes.active() {
                rate[c as usize] += 1;
            }
            totals.in_events += in_count;

            // Energy from measured per-layer activity: layer l's input
            // spikes are the previous layer's output count (layer 0 sees
            // the frame). Per-layer operand resolutions come from the
            // *backend's* live network, not the plan's: a serve-time
            // precision switch (`set_resolutions`) changes the energy of
            // every subsequent window. Geometry is identical to the plan's
            // net either way; only the CIM shard ledger below stays
            // calibrated at the plan's base resolution.
            let mut in_events_n = in_count;
            for (li, (layer, assign)) in backend
                .network()
                .layers
                .iter()
                .zip(&self.mapping.assignments)
                .enumerate()
            {
                let in_events = in_events_n as f64;
                let in_neurons = {
                    let (c, h, w) = layer.in_shape();
                    (c * h * w) as f64
                };
                let activity = (in_events / in_neurons).min(1.0);
                let sops = layer.sops_dense() as f64 * activity;
                totals.sops += sops as u64;
                totals.energy.compute_pj +=
                    sops * self.energy.sop_pj(layer.res.w_bits, layer.res.p_bits, None);
                for op in [Operand::Weight, Operand::Vmem] {
                    let resident = if op == assign.stationarity.stationary_operand() {
                        assign.stationary_resident
                    } else {
                        assign.extra_resident
                    };
                    if !resident {
                        totals.energy.movement_pj += self.energy.streamed_pj(
                            layer,
                            op,
                            sops,
                            self.energy.cfg.vmem_discipline,
                        );
                    }
                }
                // Charge the calibrated per-shard CIM ledgers for this
                // layer-timestep (event-driven: one accumulate pass per
                // input spike, one fire pass).
                totals.cim.merge(&self.shards.charge_layer(li, in_events_n));

                let out_events = step.counts[li] as f64;
                totals.energy.spike_pj += (in_events + out_events)
                    * self.energy.cfg.spike_addr_bits as f64
                    * self.energy.cfg.e_gbuf_pj_bit;
                in_events_n = step.counts[li].max(0) as u64;
            }

            let frame_activity = spikes_in.activity();
            totals.sparsity_acc += 1.0 - frame_activity;
            totals.modeled_latency_s += self.schedule.timestep_latency_s(frame_activity);
            totals.frames += 1;
        }

        if crate::telemetry::enabled() {
            crate::telemetry::metrics::hot().record_window(
                totals.frames,
                totals.in_events,
                totals.sops,
            );
        }
        Ok(totals)
    }

    /// Run one event-stream sample end to end on `backend` — the single
    /// per-sample code path shared by [`super::Coordinator::run_sample`]
    /// and every engine worker.
    pub fn run_sample(
        &self,
        backend: &mut dyn StepBackend,
        bufs: &mut SampleBuffers,
        stream: &EventStream,
        label: Option<usize>,
    ) -> Result<InferenceResult> {
        let t0 = Instant::now();
        let frames = encode_frames_sparse(stream, self.timesteps);
        backend.reset();

        let mut rate = vec![0i64; 10];
        let w = self.run_frames(backend, bufs, &frames, &mut rate)?;

        let prediction = ScnnRunner::predict(&rate);
        let correct = label.map_or(0, |l| (l == prediction) as u64);
        let metrics = RunMetrics {
            samples: 1,
            correct,
            timesteps: w.frames,
            in_events: w.in_events,
            sops: w.sops,
            mean_sparsity: w.sparsity_acc / w.frames.max(1) as f64,
            energy: w.energy,
            cim: w.cim,
            modeled_latency_s: w.modeled_latency_s,
            wallclock_s: t0.elapsed().as_secs_f64(),
            ..Default::default()
        };
        Ok(InferenceResult { prediction, rate, metrics })
    }
}

/// Totals of one window of frames through [`SamplePlan::run_frames`] —
/// everything [`RunMetrics`] needs except the per-sample bookkeeping, so
/// the offline per-sample path and the streaming serve tier assemble their
/// metrics from the same numbers.
#[derive(Debug, Clone, Default)]
pub struct WindowTotals {
    /// Frames (timesteps) executed.
    pub frames: u64,
    /// Input spike events entering layer 0 (the event-driven work driver).
    pub in_events: u64,
    /// Synaptic operations executed.
    pub sops: u64,
    /// Summed per-frame input sparsity (divide by `frames` for the mean).
    pub sparsity_acc: f64,
    /// Modeled energy.
    pub energy: EnergyBreakdown,
    /// CIM shard-ledger charges.
    pub cim: EnergyCounters,
    /// Modeled accelerator latency (seconds).
    pub modeled_latency_s: f64,
}

impl WindowTotals {
    /// Accumulate another window's totals (window order = frame order, so
    /// sequential accumulation mirrors the monolithic loop).
    pub fn add(&mut self, other: &WindowTotals) {
        self.frames += other.frames;
        self.in_events += other.in_events;
        self.sops += other.sops;
        self.sparsity_acc += other.sparsity_acc;
        self.energy.add(&other.energy);
        self.cim.merge(&other.cim);
        self.modeled_latency_s += other.modeled_latency_s;
    }
}

/// Merge per-sample metrics in submission order — deterministic float
/// accumulation, shared by the sequential and batched paths.
pub fn merge_ordered(results: &[InferenceResult]) -> RunMetrics {
    let mut total = RunMetrics::default();
    for r in results {
        total.merge(&r.metrics);
    }
    total
}

// ------------------------------------------------------------ work queue

/// A blocking multi-producer multi-consumer request queue: one shared
/// injector deque that idle workers steal from. Work units are whole
/// inference samples (coarse enough that per-worker local deques would buy
/// nothing), so "stealing" degenerates to popping the shared front.
pub struct RequestQueue<T> {
    state: Mutex<QueueState<T>>,
    ready: Condvar,
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> Default for RequestQueue<T> {
    fn default() -> Self {
        RequestQueue {
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
        }
    }
}

impl<T> RequestQueue<T> {
    /// Enqueue a work unit; wakes one idle worker.
    pub fn push(&self, item: T) {
        let mut s = self.state.lock().unwrap();
        assert!(!s.closed, "push after close");
        s.items.push_back(item);
        drop(s);
        self.ready.notify_one();
    }

    /// Close the queue: workers drain the backlog, then `pop` returns
    /// `None` instead of blocking.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.ready.notify_all();
    }

    /// Drop every queued item (first-error cancellation): in-flight work
    /// finishes, idle workers see the queue empty and exit.
    pub fn clear(&self) {
        self.state.lock().unwrap().items.clear();
        self.ready.notify_all();
    }

    /// Steal the next work unit, blocking while the queue is open and
    /// empty. `None` once closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(item) = s.items.pop_front() {
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.ready.wait(s).unwrap();
        }
    }

    /// Items currently queued (diagnostics).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    /// True when no items are queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ----------------------------------------------------------------- engine

/// Constructor for per-worker backends (built *inside* each worker thread
/// — see the module docs on `Send` constraints).
pub type BackendFactory = dyn Fn() -> Result<Box<dyn StepBackend>> + Send + Sync;

/// Result of one batched engine run.
#[derive(Debug, Clone)]
pub struct BatchResult {
    /// Per-sample results, in submission order.
    pub results: Vec<InferenceResult>,
    /// Metrics merged in submission order (identical to the sequential
    /// path's aggregate).
    pub metrics: RunMetrics,
    /// End-to-end host wall-clock of the batch (seconds).
    pub wallclock_s: f64,
    /// Worker threads used.
    pub workers: usize,
}

impl BatchResult {
    /// Batch throughput in samples per second of host wall-clock.
    pub fn samples_per_sec(&self) -> f64 {
        if self.wallclock_s <= 0.0 {
            return 0.0;
        }
        self.results.len() as f64 / self.wallclock_s
    }
}

/// The sharded, batched inference engine.
pub struct Engine {
    plan: Arc<SamplePlan>,
    factory: Arc<BackendFactory>,
    workers: usize,
}

impl Engine {
    /// Build an engine from a shared plan and a backend factory.
    pub fn new(plan: Arc<SamplePlan>, factory: Arc<BackendFactory>, workers: usize) -> Engine {
        assert!(workers >= 1, "engine needs at least one worker");
        Engine { plan, factory, workers: workers.min(256) }
    }

    /// Convenience: an engine over the pure-Rust [`NativeScnn`] backend,
    /// deterministic from `seed`. Thin shim over the same wiring
    /// [`crate::deploy::Deployment::engine`] performs; all workers share
    /// one conv-adjacency cache.
    pub fn native(
        net: Network,
        seed: u64,
        num_macros: usize,
        policy: Policy,
        workers: usize,
    ) -> Engine {
        let plan = Arc::new(SamplePlan::new(net.clone(), num_macros, policy));
        let adj = Arc::new(AdjacencyCache::new());
        let factory: Arc<BackendFactory> = Arc::new(move || {
            Ok(Box::new(NativeScnn::with_adjacency_cache(net.clone(), seed, adj.clone()))
                as Box<dyn StepBackend>)
        });
        Engine::new(plan, factory, workers)
    }

    /// The shared per-sample plan.
    pub fn plan(&self) -> &SamplePlan {
        &self.plan
    }

    /// Worker count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Process a batch of labeled samples across the worker pool.
    ///
    /// Every sample is one work unit; results are reassembled and merged in
    /// submission order regardless of which worker ran them, so the output
    /// is independent of scheduling (and of `workers`).
    pub fn run_batch(&self, data: &[(EventStream, usize)]) -> Result<BatchResult> {
        let t0 = Instant::now();
        let queue: RequestQueue<usize> = RequestQueue::default();
        for i in 0..data.len() {
            queue.push(i);
        }
        queue.close();

        let n_workers = self.workers.min(data.len()).max(1);
        let slots: Mutex<Vec<Option<InferenceResult>>> =
            Mutex::new((0..data.len()).map(|_| None).collect());
        let first_error: Mutex<Option<anyhow::Error>> = Mutex::new(None);

        std::thread::scope(|scope| {
            for _ in 0..n_workers {
                let queue = &queue;
                let slots = &slots;
                let first_error = &first_error;
                let plan = &self.plan;
                let factory = &self.factory;
                scope.spawn(move || {
                    let make: &BackendFactory = factory.as_ref();
                    let mut backend = match make() {
                        Ok(b) => b,
                        Err(e) => {
                            let mut fe = first_error.lock().unwrap();
                            if fe.is_none() {
                                *fe = Some(e);
                            }
                            queue.clear();
                            return;
                        }
                    };
                    let mut bufs = SampleBuffers::default();
                    while let Some(i) = queue.pop() {
                        let (stream, label) = &data[i];
                        match plan.run_sample(backend.as_mut(), &mut bufs, stream, Some(*label))
                        {
                            Ok(r) => slots.lock().unwrap()[i] = Some(r),
                            Err(e) => {
                                let mut fe = first_error.lock().unwrap();
                                if fe.is_none() {
                                    *fe = Some(e);
                                }
                                // Don't burn the rest of the batch: drop
                                // queued work so siblings exit promptly.
                                queue.clear();
                                return;
                            }
                        }
                    }
                });
            }
        });

        if let Some(e) = first_error.into_inner().unwrap() {
            return Err(e);
        }
        let results: Vec<InferenceResult> = slots
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("no error reported, so every slot must be filled"))
            .collect();
        let metrics = merge_ordered(&results);
        Ok(BatchResult {
            results,
            metrics,
            wallclock_s: t0.elapsed().as_secs_f64(),
            workers: n_workers,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::network::scnn_dvs_gesture;
    use crate::snn::{LayerSpec, Resolution};

    fn small_net() -> Network {
        let r = Resolution::new(4, 9);
        Network::new(
            "engine-test",
            vec![
                LayerSpec::conv("C1", 2, 4, 3, 4, 1, 48, 48, r),
                LayerSpec::fc("F1", 4 * 12 * 12, 16, r),
                LayerSpec::fc("F2", 16, 10, Resolution::new(5, 10)),
            ],
            4,
        )
    }

    #[test]
    fn request_queue_drains_in_order_then_closes() {
        let q: RequestQueue<u32> = RequestQueue::default();
        q.push(1);
        q.push(2);
        assert_eq!(q.len(), 2);
        q.close();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn request_queue_feeds_parallel_consumers() {
        let q: RequestQueue<usize> = RequestQueue::default();
        let seen: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    while let Some(v) = q.pop() {
                        seen.lock().unwrap().push(v);
                    }
                });
            }
            for i in 0..100 {
                q.push(i);
            }
            q.close();
        });
        let mut got = seen.into_inner().unwrap();
        got.sort_unstable();
        assert_eq!(got, (0..100).collect::<Vec<_>>(), "every unit processed once");
    }

    #[test]
    fn shard_ledger_calibrates_every_shard() {
        let net = scnn_dvs_gesture();
        let mapping = Mapper::flexspim(4).map(&net, Policy::HsOpt);
        let schedule = Scheduler::default().plan(&net, &mapping);
        let ledger = ShardLedger::calibrate(&net, &mapping, &schedule);
        assert_eq!(ledger.per_layer.len(), net.layers.len());
        assert!(ledger.shard_count() >= net.layers.len());
        for (li, layer) in ledger.per_layer.iter().enumerate() {
            for cal in layer {
                assert!(cal.accumulate.cim_cycles > 0, "layer {li}: accumulate measured");
                assert!(cal.accumulate.sops > 0);
                assert!(cal.fire.compare_ops > 0, "layer {li}: fire measured");
                assert!(cal.passes() >= 1);
                // Every pass group together covers the shard exactly once:
                // activity-proportional charging must see every neuron.
                let sops_per_event = cal.accumulate.sops * cal.full_passes
                    + cal.accumulate_rem.sops;
                assert_eq!(
                    sops_per_event, cal.shard.neuron_count as u64,
                    "layer {li}: partial passes must not over-charge"
                );
            }
        }
        // Charging is linear in events and zero only for the fire floor.
        let one = ledger.charge_layer(0, 1);
        let ten = ledger.charge_layer(0, 10);
        assert!(ten.adder_ops > one.adder_ops);
        let per_event: u64 = ledger.per_layer[0]
            .iter()
            .map(|c| c.accumulate.sops * c.full_passes + c.accumulate_rem.sops)
            .sum();
        assert_eq!(ten.sops - one.sops, 9 * per_event);
    }

    #[test]
    fn engine_batch_is_worker_count_invariant() {
        use crate::events::{GestureClass, GestureGenerator};
        use crate::util::rng::Rng;
        let net = small_net();
        let gen = GestureGenerator::default_48();
        let mut rng = Rng::new(17);
        let data: Vec<(EventStream, usize)> = (0..6)
            .map(|i| (gen.sample(GestureClass::ALL[i % 10], &mut rng), i % 10))
            .collect();
        let run = |workers| {
            Engine::native(net.clone(), 99, 4, Policy::HsOpt, workers)
                .run_batch(&data)
                .unwrap()
        };
        let a = run(1);
        let b = run(3);
        assert_eq!(a.results.len(), b.results.len());
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.prediction, y.prediction);
            assert_eq!(x.rate, y.rate);
            assert_eq!(x.metrics.sops, y.metrics.sops);
            assert_eq!(x.metrics.cim, y.metrics.cim);
        }
        assert_eq!(a.metrics.samples, 6);
        assert_eq!(a.metrics.cim, b.metrics.cim);
        assert_eq!(a.metrics.energy.total_pj(), b.metrics.energy.total_pj());
    }

    #[test]
    fn engine_surfaces_factory_errors() {
        let net = small_net();
        let plan = Arc::new(SamplePlan::new(net, 2, Policy::HsOpt));
        let factory: Arc<BackendFactory> =
            Arc::new(|| Err(anyhow::anyhow!("backend construction refused")));
        let engine = Engine::new(plan, factory, 2);
        let gen = crate::events::GestureGenerator::default_48();
        let mut rng = crate::util::rng::Rng::new(1);
        let data = vec![(gen.sample(crate::events::GestureClass::HandClap, &mut rng), 0)];
        let err = engine.run_batch(&data).unwrap_err();
        assert!(format!("{err}").contains("refused"));
    }
}
