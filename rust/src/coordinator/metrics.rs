//! Run-level metrics aggregation and reporting.

use crate::cim::EnergyCounters;
use crate::util::bench::fmt_time;
use crate::util::si;
use crate::util::stats::percentile;

/// Energy breakdown of a run (picojoules).
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyBreakdown {
    /// CIM compute energy.
    pub compute_pj: f64,
    /// Streamed operand movement.
    pub movement_pj: f64,
    /// Spike I/O.
    pub spike_pj: f64,
    /// Amortized stationary loads.
    pub load_pj: f64,
}

impl EnergyBreakdown {
    /// Total pJ.
    pub fn total_pj(&self) -> f64 {
        self.compute_pj + self.movement_pj + self.spike_pj + self.load_pj
    }

    /// Accumulate another breakdown.
    pub fn add(&mut self, other: &EnergyBreakdown) {
        self.compute_pj += other.compute_pj;
        self.movement_pj += other.movement_pj;
        self.spike_pj += other.spike_pj;
        self.load_pj += other.load_pj;
    }
}

/// Aggregated metrics over an inference run (one or many samples).
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// Samples processed.
    pub samples: u64,
    /// Correct predictions.
    pub correct: u64,
    /// Timesteps executed.
    pub timesteps: u64,
    /// Input spike events entering layer 0 — the quantity the event-driven
    /// execution engine's work actually scales with.
    pub in_events: u64,
    /// Synaptic operations executed.
    pub sops: u64,
    /// Mean input sparsity observed.
    pub mean_sparsity: f64,
    /// Modeled energy.
    pub energy: EnergyBreakdown,
    /// Aggregated CIM macro event ledger across all layer shards (charged
    /// per timestep from the engine's shard-calibrated per-op deltas).
    pub cim: EnergyCounters,
    /// Modeled accelerator latency (seconds, summed).
    pub modeled_latency_s: f64,
    /// Host wall-clock (seconds, summed) — the simulator's own speed.
    pub wallclock_s: f64,
    /// Session-state DRAM traffic in bits (vmem spill + refill) charged by
    /// the serve tier when its residency budget overflows. Zero for
    /// offline batch runs, whose state never leaves the array.
    pub state_spill_bits: u64,
    /// Session-state evictions behind `state_spill_bits`.
    pub state_evictions: u64,
}

impl RunMetrics {
    /// Classification accuracy.
    pub fn accuracy(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.correct as f64 / self.samples as f64
        }
    }

    /// Energy per synaptic operation (pJ/SOP).
    pub fn pj_per_sop(&self) -> f64 {
        if self.sops == 0 {
            0.0
        } else {
            self.energy.total_pj() / self.sops as f64
        }
    }

    /// Energy per inference (µJ).
    pub fn uj_per_inference(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.energy.total_pj() * 1e-6 / self.samples as f64
        }
    }

    /// Merge another run's metrics.
    pub fn merge(&mut self, other: &RunMetrics) {
        let n = (self.samples + other.samples).max(1);
        self.mean_sparsity = (self.mean_sparsity * self.samples as f64
            + other.mean_sparsity * other.samples as f64)
            / n as f64;
        self.samples += other.samples;
        self.correct += other.correct;
        self.timesteps += other.timesteps;
        self.in_events += other.in_events;
        self.sops += other.sops;
        self.energy.add(&other.energy);
        self.cim.merge(&other.cim);
        self.modeled_latency_s += other.modeled_latency_s;
        self.wallclock_s += other.wallclock_s;
        self.state_spill_bits += other.state_spill_bits;
        self.state_evictions += other.state_evictions;
    }

    /// Render a report block.
    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("samples            {}\n", self.samples));
        s.push_str(&format!("accuracy           {:.1} %\n", 100.0 * self.accuracy()));
        s.push_str(&format!("timesteps          {}\n", self.timesteps));
        s.push_str(&format!("mean sparsity      {:.1} %\n", 100.0 * self.mean_sparsity));
        if self.in_events > 0 {
            s.push_str(&format!(
                "input events       {} ({:.1} events/timestep)\n",
                si(self.in_events as f64),
                self.in_events as f64 / self.timesteps.max(1) as f64,
            ));
        }
        s.push_str(&format!("SOPs               {}\n", si(self.sops as f64)));
        s.push_str(&format!(
            "energy             {}J (compute {:.0} %, movement {:.0} %)\n",
            si(self.energy.total_pj() * 1e-12),
            100.0 * self.energy.compute_pj / self.energy.total_pj().max(1e-12),
            100.0 * self.energy.movement_pj / self.energy.total_pj().max(1e-12),
        ));
        s.push_str(&format!("energy/SOP         {:.2} pJ\n", self.pj_per_sop()));
        if self.cim.cim_cycles > 0 {
            s.push_str(&format!(
                "CIM ledger         {} row-cycles, {} adder ops, {} SOPs\n",
                si(self.cim.cim_cycles as f64),
                si(self.cim.adder_ops as f64),
                si(self.cim.sops as f64),
            ));
        }
        if self.state_evictions > 0 {
            s.push_str(&format!(
                "state spills       {} evictions, {}b DRAM traffic\n",
                self.state_evictions,
                si(self.state_spill_bits as f64),
            ));
        }
        s.push_str(&format!("energy/inference   {:.2} µJ\n", self.uj_per_inference()));
        s.push_str(&format!(
            "modeled latency    {}s/timestep\n",
            si(self.modeled_latency_s / self.timesteps.max(1) as f64)
        ));
        s.push_str(&format!("host wallclock     {:.2} s\n", self.wallclock_s));
        s
    }
}

/// Latency sample accumulator with percentile reporting — the serve tier
/// pushes one observation per completed micro-window (admission →
/// completion, host wall-clock).
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples: Vec<f64>,
}

impl LatencyStats {
    /// Empty accumulator.
    pub fn new() -> Self {
        LatencyStats::default()
    }

    /// Absorb one latency observation (seconds).
    pub fn push(&mut self, seconds: f64) {
        self.samples.push(seconds);
    }

    /// Absorb another accumulator's samples.
    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples.extend_from_slice(&other.samples);
    }

    /// Observations recorded.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Percentile in seconds (NaN when empty).
    pub fn pct(&self, p: f64) -> f64 {
        percentile(&self.samples, p)
    }

    /// Median latency (seconds).
    pub fn p50(&self) -> f64 {
        self.pct(50.0)
    }

    /// 95th-percentile latency (seconds).
    pub fn p95(&self) -> f64 {
        self.pct(95.0)
    }

    /// 99th-percentile latency (seconds).
    pub fn p99(&self) -> f64 {
        self.pct(99.0)
    }

    /// Mean latency in seconds (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// One aligned report line: `p50 … p95 … p99 … (n windows)`.
    pub fn line(&self) -> String {
        format!(
            "p50 {:>10}  p95 {:>10}  p99 {:>10}  ({} windows)",
            fmt_time(self.p50()),
            fmt_time(self.p95()),
            fmt_time(self.p99()),
            self.count(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_total_and_add() {
        let mut a = EnergyBreakdown {
            compute_pj: 1.0,
            movement_pj: 2.0,
            spike_pj: 0.5,
            load_pj: 0.5,
        };
        assert_eq!(a.total_pj(), 4.0);
        a.add(&EnergyBreakdown { compute_pj: 1.0, ..Default::default() });
        assert_eq!(a.total_pj(), 5.0);
    }

    #[test]
    fn metrics_accuracy_and_merge() {
        let mut a = RunMetrics {
            samples: 4,
            correct: 3,
            mean_sparsity: 0.9,
            ..Default::default()
        };
        let b = RunMetrics {
            samples: 4,
            correct: 1,
            mean_sparsity: 0.8,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.samples, 8);
        assert!((a.accuracy() - 0.5).abs() < 1e-12);
        assert!((a.mean_sparsity - 0.85).abs() < 1e-12);
    }

    #[test]
    fn zero_division_guards() {
        let m = RunMetrics::default();
        assert_eq!(m.accuracy(), 0.0);
        assert_eq!(m.pj_per_sop(), 0.0);
        assert!(m.report().contains("samples"));
    }

    #[test]
    fn in_events_merge_and_report() {
        let mut a = RunMetrics { in_events: 30, timesteps: 3, ..Default::default() };
        let b = RunMetrics { in_events: 12, timesteps: 1, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.in_events, 42);
        assert!(a.report().contains("input events"));
        assert!(!RunMetrics::default().report().contains("input events"));
    }

    #[test]
    fn spill_fields_merge_and_report() {
        let mut a = RunMetrics { state_spill_bits: 100, state_evictions: 2, ..Default::default() };
        let b = RunMetrics { state_spill_bits: 50, state_evictions: 1, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.state_spill_bits, 150);
        assert_eq!(a.state_evictions, 3);
        assert!(a.report().contains("state spills"));
        assert!(!RunMetrics::default().report().contains("state spills"));
    }

    #[test]
    fn latency_stats_percentiles() {
        let mut l = LatencyStats::new();
        for i in 1..=100 {
            l.push(i as f64 * 1e-3);
        }
        assert_eq!(l.count(), 100);
        assert!((l.p50() - 0.0505).abs() < 1e-9);
        assert!((l.p99() - 0.09901).abs() < 1e-6);
        assert!((l.mean() - 0.0505).abs() < 1e-9);
        let mut other = LatencyStats::new();
        other.push(1.0);
        l.merge(&other);
        assert_eq!(l.count(), 101);
        assert!(l.line().contains("101 windows"));
    }
}
