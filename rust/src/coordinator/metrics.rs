//! Run-level metrics aggregation and reporting.

use std::collections::VecDeque;

use crate::cim::EnergyCounters;
use crate::util::bench::fmt_time;
use crate::util::rng::splitmix64;
use crate::util::si;
use crate::util::stats::{percentile, percentile_sorted};

/// Energy breakdown of a run (picojoules).
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyBreakdown {
    /// CIM compute energy.
    pub compute_pj: f64,
    /// Streamed operand movement.
    pub movement_pj: f64,
    /// Spike I/O.
    pub spike_pj: f64,
    /// Amortized stationary loads.
    pub load_pj: f64,
}

impl EnergyBreakdown {
    /// Total pJ.
    pub fn total_pj(&self) -> f64 {
        self.compute_pj + self.movement_pj + self.spike_pj + self.load_pj
    }

    /// Accumulate another breakdown.
    pub fn add(&mut self, other: &EnergyBreakdown) {
        self.compute_pj += other.compute_pj;
        self.movement_pj += other.movement_pj;
        self.spike_pj += other.spike_pj;
        self.load_pj += other.load_pj;
    }
}

/// Aggregated metrics over an inference run (one or many samples).
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// Samples processed.
    pub samples: u64,
    /// Correct predictions.
    pub correct: u64,
    /// Timesteps executed.
    pub timesteps: u64,
    /// Input spike events entering layer 0 — the quantity the event-driven
    /// execution engine's work actually scales with.
    pub in_events: u64,
    /// Synaptic operations executed.
    pub sops: u64,
    /// Mean input sparsity observed.
    pub mean_sparsity: f64,
    /// Modeled energy.
    pub energy: EnergyBreakdown,
    /// Aggregated CIM macro event ledger across all layer shards (charged
    /// per timestep from the engine's shard-calibrated per-op deltas).
    pub cim: EnergyCounters,
    /// Modeled accelerator latency (seconds, summed).
    pub modeled_latency_s: f64,
    /// Host wall-clock (seconds, summed) — the simulator's own speed.
    pub wallclock_s: f64,
    /// Session-state DRAM traffic in bits (vmem spill + refill) charged by
    /// the serve tier when its residency budget overflows. Zero for
    /// offline batch runs, whose state never leaves the array.
    pub state_spill_bits: u64,
    /// Session-state evictions behind `state_spill_bits`.
    pub state_evictions: u64,
}

impl RunMetrics {
    /// Classification accuracy.
    pub fn accuracy(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.correct as f64 / self.samples as f64
        }
    }

    /// Energy per synaptic operation (pJ/SOP).
    pub fn pj_per_sop(&self) -> f64 {
        if self.sops == 0 {
            0.0
        } else {
            self.energy.total_pj() / self.sops as f64
        }
    }

    /// Energy per inference (µJ).
    pub fn uj_per_inference(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.energy.total_pj() * 1e-6 / self.samples as f64
        }
    }

    /// Merge another run's metrics.
    ///
    /// Across serve sessions — and across fleet nodes — merging must stay
    /// an *exact partition*: every field is either summed or
    /// sample-weighted, never dropped. The exhaustive destructuring makes
    /// adding a `RunMetrics` field without deciding its merge rule a
    /// compile error instead of a silent undercount of a whole node.
    pub fn merge(&mut self, other: &RunMetrics) {
        let RunMetrics {
            samples,
            correct,
            timesteps,
            in_events,
            sops,
            mean_sparsity,
            energy,
            cim,
            modeled_latency_s,
            wallclock_s,
            state_spill_bits,
            state_evictions,
        } = other;
        // Sample-weighted mean, computed before `samples` accumulates.
        let n = (self.samples + samples).max(1);
        self.mean_sparsity = (self.mean_sparsity * self.samples as f64
            + mean_sparsity * *samples as f64)
            / n as f64;
        self.samples += samples;
        self.correct += correct;
        self.timesteps += timesteps;
        self.in_events += in_events;
        self.sops += sops;
        self.energy.add(energy);
        self.cim.merge(cim);
        self.modeled_latency_s += modeled_latency_s;
        self.wallclock_s += wallclock_s;
        self.state_spill_bits += state_spill_bits;
        self.state_evictions += state_evictions;
    }

    /// Render a report block.
    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("samples            {}\n", self.samples));
        s.push_str(&format!("accuracy           {:.1} %\n", 100.0 * self.accuracy()));
        s.push_str(&format!("timesteps          {}\n", self.timesteps));
        s.push_str(&format!("mean sparsity      {:.1} %\n", 100.0 * self.mean_sparsity));
        if self.in_events > 0 {
            s.push_str(&format!(
                "input events       {} ({:.1} events/timestep)\n",
                si(self.in_events as f64),
                self.in_events as f64 / self.timesteps.max(1) as f64,
            ));
        }
        s.push_str(&format!("SOPs               {}\n", si(self.sops as f64)));
        s.push_str(&format!(
            "energy             {}J (compute {:.0} %, movement {:.0} %)\n",
            si(self.energy.total_pj() * 1e-12),
            100.0 * self.energy.compute_pj / self.energy.total_pj().max(1e-12),
            100.0 * self.energy.movement_pj / self.energy.total_pj().max(1e-12),
        ));
        s.push_str(&format!("energy/SOP         {:.2} pJ\n", self.pj_per_sop()));
        if self.cim.cim_cycles > 0 {
            s.push_str(&format!(
                "CIM ledger         {} row-cycles, {} adder ops, {} SOPs\n",
                si(self.cim.cim_cycles as f64),
                si(self.cim.adder_ops as f64),
                si(self.cim.sops as f64),
            ));
        }
        if self.state_evictions > 0 {
            s.push_str(&format!(
                "state spills       {} evictions, {}b DRAM traffic\n",
                self.state_evictions,
                si(self.state_spill_bits as f64),
            ));
        }
        s.push_str(&format!("energy/inference   {:.2} µJ\n", self.uj_per_inference()));
        s.push_str(&format!(
            "modeled latency    {}s/timestep\n",
            si(self.modeled_latency_s / self.timesteps.max(1) as f64)
        ));
        s.push_str(&format!("host wallclock     {:.2} s\n", self.wallclock_s));
        s
    }
}

/// Default [`LatencyStats`] retention bound: plenty for exact percentiles
/// over any bench/test run while keeping week-long serve runs at a fixed
/// memory footprint.
const LATENCY_DEFAULT_CAP: usize = 1 << 16;

/// Latency sample accumulator with percentile reporting — the serve tier
/// pushes one observation per completed micro-window (admission →
/// completion, host wall-clock).
///
/// Samples are kept *sorted on insert*, so every percentile query —
/// including the three in [`LatencyStats::line`] — is O(1) with zero
/// sorts (the earlier implementation cloned and re-sorted the full vector
/// per query). Retention is bounded: up to the capacity every observation
/// is kept and percentiles are exact; beyond it, reservoir sampling
/// (Algorithm R, seeded deterministically) keeps a uniform subsample so
/// long-running services get unbiased percentile estimates at a fixed
/// memory footprint. [`LatencyStats::count`] always reports the total
/// observed, not the retained subset.
#[derive(Debug, Clone)]
pub struct LatencyStats {
    /// Retained samples, ascending.
    sorted: Vec<f64>,
    /// Total observations (retained or not).
    seen: u64,
    /// Sum over *all* observations (exact mean survives eviction).
    sum: f64,
    /// Retention bound.
    cap: usize,
    /// SplitMix64 state for reservoir eviction decisions.
    rng_state: u64,
}

impl Default for LatencyStats {
    fn default() -> Self {
        LatencyStats::new()
    }
}

impl LatencyStats {
    /// Empty accumulator with the default retention bound.
    pub fn new() -> Self {
        LatencyStats::with_capacity(LATENCY_DEFAULT_CAP)
    }

    /// Empty accumulator retaining at most `cap` samples.
    pub fn with_capacity(cap: usize) -> Self {
        LatencyStats {
            sorted: Vec::new(),
            seen: 0,
            sum: 0.0,
            cap: cap.max(1),
            rng_state: 0x1A7E_4C5A_75_u64,
        }
    }

    /// Absorb one latency observation (seconds).
    pub fn push(&mut self, seconds: f64) {
        self.seen += 1;
        self.sum += seconds;
        if self.sorted.len() < self.cap {
            self.insert_sorted(seconds);
            return;
        }
        // Algorithm R: keep the newcomer with probability cap/seen,
        // evicting a uniformly random retained sample.
        let j = splitmix64(&mut self.rng_state) % self.seen;
        if (j as usize) < self.cap {
            self.sorted.remove(j as usize);
            self.insert_sorted(seconds);
        }
    }

    fn insert_sorted(&mut self, seconds: f64) {
        let at = self.sorted.partition_point(|&x| x < seconds);
        self.sorted.insert(at, seconds);
    }

    /// Absorb another accumulator's samples (one merge-sort pass; evicts
    /// uniformly back down to this accumulator's bound if needed).
    pub fn merge(&mut self, other: &LatencyStats) {
        self.seen += other.seen;
        self.sum += other.sum;
        self.sorted.extend_from_slice(&other.sorted);
        self.sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        while self.sorted.len() > self.cap {
            let j = splitmix64(&mut self.rng_state) % self.sorted.len() as u64;
            self.sorted.remove(j as usize);
        }
    }

    /// Observations recorded (total, including evicted ones).
    pub fn count(&self) -> usize {
        self.seen as usize
    }

    /// Samples currently retained for percentile queries.
    pub fn retained(&self) -> usize {
        self.sorted.len()
    }

    /// Percentile in seconds (NaN when empty). O(1): the samples are
    /// already sorted.
    pub fn pct(&self, p: f64) -> f64 {
        percentile_sorted(&self.sorted, p)
    }

    /// Median latency (seconds).
    pub fn p50(&self) -> f64 {
        self.pct(50.0)
    }

    /// 95th-percentile latency (seconds).
    pub fn p95(&self) -> f64 {
        self.pct(95.0)
    }

    /// 99th-percentile latency (seconds).
    pub fn p99(&self) -> f64 {
        self.pct(99.0)
    }

    /// Mean latency in seconds (0 when empty). Exact over all
    /// observations, even evicted ones.
    pub fn mean(&self) -> f64 {
        if self.seen == 0 {
            return 0.0;
        }
        self.sum / self.seen as f64
    }

    /// One aligned report line: `p50 … p95 … p99 … (n windows)`.
    pub fn line(&self) -> String {
        format!(
            "p50 {:>10}  p95 {:>10}  p99 {:>10}  ({} windows)",
            fmt_time(self.p50()),
            fmt_time(self.p95()),
            fmt_time(self.p99()),
            self.count(),
        )
    }
}

/// Rolling latency window: the last `cap` observations, for control loops
/// that must react to *recent* behaviour (the serve autoscaler's rolling
/// p99) rather than the whole-run distribution [`LatencyStats`] keeps.
///
/// Percentile queries sort a bounded copy — one sort of at most `cap`
/// elements per control tick, independent of run length.
#[derive(Debug, Clone)]
pub struct LatencyWindow {
    buf: VecDeque<f64>,
    cap: usize,
}

impl LatencyWindow {
    /// Window over the last `cap` observations.
    pub fn new(cap: usize) -> Self {
        LatencyWindow { buf: VecDeque::new(), cap: cap.max(1) }
    }

    /// Absorb one observation, evicting the oldest when full.
    pub fn push(&mut self, seconds: f64) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(seconds);
    }

    /// Observations currently in the window.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when no observations have been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Percentile over the window (NaN when empty).
    pub fn pct(&self, p: f64) -> f64 {
        let v: Vec<f64> = self.buf.iter().copied().collect();
        percentile(&v, p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_total_and_add() {
        let mut a = EnergyBreakdown {
            compute_pj: 1.0,
            movement_pj: 2.0,
            spike_pj: 0.5,
            load_pj: 0.5,
        };
        assert_eq!(a.total_pj(), 4.0);
        a.add(&EnergyBreakdown { compute_pj: 1.0, ..Default::default() });
        assert_eq!(a.total_pj(), 5.0);
    }

    #[test]
    fn metrics_accuracy_and_merge() {
        let mut a = RunMetrics {
            samples: 4,
            correct: 3,
            mean_sparsity: 0.9,
            ..Default::default()
        };
        let b = RunMetrics {
            samples: 4,
            correct: 1,
            mean_sparsity: 0.8,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.samples, 8);
        assert!((a.accuracy() - 0.5).abs() < 1e-12);
        assert!((a.mean_sparsity - 0.85).abs() < 1e-12);
    }

    #[test]
    fn merge_is_an_exact_partition_of_every_field() {
        // Every field of RunMetrics carries a distinct nonzero value, so a
        // field silently dropped by merge() shows up as a wrong sum here
        // (the destructuring in merge() catches *new* fields at compile
        // time; this pins the rule for the existing ones).
        let block = |k: u64| RunMetrics {
            samples: k,
            correct: k + 1,
            timesteps: k + 2,
            in_events: k + 3,
            sops: k + 4,
            mean_sparsity: 0.5,
            energy: EnergyBreakdown {
                compute_pj: k as f64,
                movement_pj: k as f64 + 1.0,
                spike_pj: k as f64 + 2.0,
                load_pj: k as f64 + 3.0,
            },
            cim: EnergyCounters { cim_cycles: k + 5, adder_ops: k + 6, ..Default::default() },
            modeled_latency_s: k as f64 + 4.0,
            wallclock_s: k as f64 + 5.0,
            state_spill_bits: k + 7,
            state_evictions: k + 8,
        };
        let mut a = block(10);
        a.merge(&block(100));
        assert_eq!(a.samples, 110);
        assert_eq!(a.correct, 112);
        assert_eq!(a.timesteps, 114);
        assert_eq!(a.in_events, 116);
        assert_eq!(a.sops, 118);
        assert!((a.mean_sparsity - 0.5).abs() < 1e-12, "sample-weighted mean");
        assert_eq!(a.energy.compute_pj, 110.0);
        assert_eq!(a.energy.movement_pj, 112.0);
        assert_eq!(a.energy.spike_pj, 114.0);
        assert_eq!(a.energy.load_pj, 116.0);
        assert_eq!(a.cim.cim_cycles, 120);
        assert_eq!(a.cim.adder_ops, 122);
        assert_eq!(a.modeled_latency_s, 118.0);
        assert_eq!(a.wallclock_s, 120.0);
        assert_eq!(a.state_spill_bits, 124);
        assert_eq!(a.state_evictions, 126);
    }

    #[test]
    fn zero_division_guards() {
        let m = RunMetrics::default();
        assert_eq!(m.accuracy(), 0.0);
        assert_eq!(m.pj_per_sop(), 0.0);
        assert!(m.report().contains("samples"));
    }

    #[test]
    fn in_events_merge_and_report() {
        let mut a = RunMetrics { in_events: 30, timesteps: 3, ..Default::default() };
        let b = RunMetrics { in_events: 12, timesteps: 1, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.in_events, 42);
        assert!(a.report().contains("input events"));
        assert!(!RunMetrics::default().report().contains("input events"));
    }

    #[test]
    fn spill_fields_merge_and_report() {
        let mut a = RunMetrics { state_spill_bits: 100, state_evictions: 2, ..Default::default() };
        let b = RunMetrics { state_spill_bits: 50, state_evictions: 1, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.state_spill_bits, 150);
        assert_eq!(a.state_evictions, 3);
        assert!(a.report().contains("state spills"));
        assert!(!RunMetrics::default().report().contains("state spills"));
    }

    #[test]
    fn latency_stats_percentiles() {
        let mut l = LatencyStats::new();
        for i in 1..=100 {
            l.push(i as f64 * 1e-3);
        }
        assert_eq!(l.count(), 100);
        assert!((l.p50() - 0.0505).abs() < 1e-9);
        assert!((l.p99() - 0.09901).abs() < 1e-6);
        assert!((l.mean() - 0.0505).abs() < 1e-9);
        let mut other = LatencyStats::new();
        other.push(1.0);
        l.merge(&other);
        assert_eq!(l.count(), 101);
        assert!(l.line().contains("101 windows"));
    }

    #[test]
    fn latency_stats_capacity_bound_holds() {
        let mut l = LatencyStats::with_capacity(16);
        for i in 0..100_000u64 {
            l.push((i % 1000) as f64 * 1e-6);
        }
        assert_eq!(l.count(), 100_000, "count reports total seen");
        assert!(l.retained() <= 16, "retained {} exceeds cap", l.retained());
        // The reservoir subsample still lies inside the observed range.
        let (lo, hi) = (0.0, 999.0 * 1e-6);
        for p in [0.0, 50.0, 99.0, 100.0] {
            let v = l.pct(p);
            assert!((lo..=hi).contains(&v), "p{p} = {v} outside [{lo}, {hi}]");
        }
        // Exact mean survives eviction: values cycle 0..1000 uniformly.
        assert!((l.mean() - 499.5e-6).abs() < 1e-9);
    }

    #[test]
    fn latency_stats_merge_respects_capacity() {
        let mut a = LatencyStats::with_capacity(8);
        let mut b = LatencyStats::with_capacity(8);
        for i in 0..8 {
            a.push(i as f64);
            b.push((i + 100) as f64);
        }
        a.merge(&b);
        assert_eq!(a.count(), 16);
        assert!(a.retained() <= 8);
        // Retained samples stay sorted after merge eviction.
        let p0 = a.pct(0.0);
        let p100 = a.pct(100.0);
        assert!(p0 <= p100);
    }

    #[test]
    fn latency_window_rolls() {
        let mut w = LatencyWindow::new(4);
        assert!(w.is_empty());
        assert!(w.pct(99.0).is_nan());
        for i in 1..=10 {
            w.push(i as f64);
        }
        assert_eq!(w.len(), 4);
        // Only 7..=10 remain.
        assert!((w.pct(0.0) - 7.0).abs() < 1e-12);
        assert!((w.pct(100.0) - 10.0).abs() < 1e-12);
        assert!((w.pct(50.0) - 8.5).abs() < 1e-12);
    }
}
