//! Calibrated energy models.
//!
//! The macro simulator produces an [`crate::cim::EnergyCounters`] event
//! ledger; [`macro_model`] prices it in joules with coefficients fitted to
//! the paper's silicon measurements (Table I, Fig. 7a). [`system`] builds
//! the many-macro + global-buffer + DRAM hierarchy of Fig. 7b on top, and
//! [`baselines`] models the prior-art comparison points ([3] IMPULSE and
//! [4] ISSCC'24) under their published constraints.
//!
//! ## Calibration anchors (from the paper)
//!
//! * 7.2 pJ/SOP at 1.1 V / 157 MHz and 5.7 pJ/SOP at 0.9 V / 75.5 MHz for
//!   the 8-bit-weight / 16-bit-potential bit-serial mapping (Table I).
//! * 17.9 mW at the nominal point, 6.8 mW at the low-voltage point.
//! * PC standby cuts inactive-column energy by 87 %.
//! * Carry propagation adds <5 % with growing resolution.
//! * Shape-dependent variation ≤24 %; up to ~4.3× saving vs row-wise
//!   kernel stacking without standby (Fig. 7a).

pub mod baselines;
pub mod macro_model;
pub mod system;

pub use macro_model::{MacroEnergyModel, SopEnergyBreakdown};
pub use system::{SystemConfig, SystemEnergyModel, SystemEnergyReport};
