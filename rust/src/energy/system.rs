//! System-level energy extrapolation (paper §III-B, Fig. 7b–d).
//!
//! Architecture template (Fig. 7b): a many-macro CIM array, a global
//! on-chip buffer, and an external DRAM. Per-layer, per-timestep energy is
//! the sum of
//!
//! * **compute** — SOPs × macro energy/SOP (from the calibrated
//!   [`MacroEnergyModel`], at the layer's resolution and best shape);
//! * **streamed-operand movement** — operands without CIM residency move
//!   through the buffer hierarchy every timestep. Weights stream at most
//!   once per timestep (broadcast reuse); membrane potentials are
//!   read-modify-write. The *discipline* (per-spike RMW as in spike-driven
//!   designs, per-timestep tile sweep, or best-of-both) is configurable —
//!   FlexSpIM's controller uses `Best`, the spike-driven baselines use
//!   `PerSop` (that is their published operating principle);
//! * **spike I/O** — AER events in/out of the array;
//! * **amortized loads** — one-time DRAM→CIM placement of stationary
//!   operands, divided over the run length.
//!
//! Input sparsity applies uniformly across layers (documented
//! simplification; the paper sweeps input sparsity 85–99 % the same way).

use super::macro_model::MacroEnergyModel;
use crate::dataflow::{Mapping, Operand};
use crate::snn::{LayerSpec, Network};

/// How a streamed operand moves per timestep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Discipline {
    /// Event-driven read-modify-write per SOP (spike-driven designs).
    PerSop,
    /// One tile sweep of the full operand per timestep.
    PerTimestepTile,
    /// The cheaper of the two (an optimizing controller).
    Best,
}

/// System-level configuration knobs.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// Number of CIM macros.
    pub num_macros: usize,
    /// Bits per macro (131 072 for FlexSpIM's 16 kB).
    pub macro_bits: u64,
    /// Global buffer capacity in bits.
    pub gbuf_bits: u64,
    /// Global-buffer access energy (pJ/bit).
    pub e_gbuf_pj_bit: f64,
    /// External DRAM access energy (pJ/bit) — Horowitz-style [16].
    pub e_dram_pj_bit: f64,
    /// AER event word width (bits) for spike I/O.
    pub spike_addr_bits: u32,
    /// Timesteps over which one-time stationary loads amortize.
    pub amortize_timesteps: u64,
    /// Supply voltage for the macro model.
    pub vdd: f64,
    /// Streaming discipline for non-resident membrane potentials.
    pub vmem_discipline: Discipline,
    /// Streaming discipline for non-resident weights.
    pub weight_discipline: Discipline,
}

impl SystemConfig {
    /// FlexSpIM system defaults at the nominal operating point.
    pub fn flexspim(num_macros: usize) -> Self {
        SystemConfig {
            num_macros,
            macro_bits: 512 * 256,
            gbuf_bits: 256 * 1024 * 8, // 256 kB
            e_gbuf_pj_bit: 0.6,
            e_dram_pj_bit: 20.0,
            spike_addr_bits: 16,
            amortize_timesteps: 1600, // 100 inferences × 16 timesteps
            vdd: 1.1,
            vmem_discipline: Discipline::Best,
            weight_discipline: Discipline::Best,
        }
    }

    /// Total CIM capacity in bits.
    pub fn cim_bits(&self) -> u64 {
        self.macro_bits * self.num_macros as u64
    }
}

/// Per-layer energy line of a report (all pJ, per timestep).
#[derive(Debug, Clone)]
pub struct LayerEnergy {
    /// Layer name.
    pub name: String,
    /// SOPs executed this timestep.
    pub sops: f64,
    /// Macro compute energy.
    pub compute_pj: f64,
    /// Streamed operand movement energy.
    pub stream_pj: f64,
    /// Spike I/O energy.
    pub spike_pj: f64,
    /// Amortized stationary-load energy.
    pub load_pj: f64,
}

impl LayerEnergy {
    /// Layer total (pJ/timestep).
    pub fn total_pj(&self) -> f64 {
        self.compute_pj + self.stream_pj + self.spike_pj + self.load_pj
    }
}

/// Whole-system energy report for one timestep.
#[derive(Debug, Clone)]
pub struct SystemEnergyReport {
    /// Per-layer lines.
    pub per_layer: Vec<LayerEnergy>,
}

impl SystemEnergyReport {
    /// Total energy per timestep (pJ).
    pub fn total_pj(&self) -> f64 {
        self.per_layer.iter().map(LayerEnergy::total_pj).sum()
    }

    /// Total compute component (pJ).
    pub fn compute_pj(&self) -> f64 {
        self.per_layer.iter().map(|l| l.compute_pj).sum()
    }

    /// Total movement component (pJ).
    pub fn stream_pj(&self) -> f64 {
        self.per_layer.iter().map(|l| l.stream_pj).sum()
    }
}

/// The system-level model: configuration + calibrated macro pricing.
#[derive(Debug, Clone)]
pub struct SystemEnergyModel {
    /// System knobs.
    pub cfg: SystemConfig,
    /// Macro-level pricing at `cfg.vdd`.
    pub model: MacroEnergyModel,
}

impl SystemEnergyModel {
    /// Build from a config.
    pub fn new(cfg: SystemConfig) -> Self {
        let model = MacroEnergyModel::at_vdd(cfg.vdd);
        SystemEnergyModel { cfg, model }
    }

    /// FlexSpIM defaults with `num_macros` macros.
    pub fn flexspim(num_macros: usize) -> Self {
        Self::new(SystemConfig::flexspim(num_macros))
    }

    /// Best (minimum-energy) per-SOP cost over the operand shapes the
    /// macro supports for this resolution — FlexSpIM picks the shape per
    /// layer (Fig. 7a); pass `force_n_c = Some(1)` to model prior-art
    /// row-wise bit-serial mapping.
    pub fn sop_pj(&self, w_bits: u32, p_bits: u32, force_n_c: Option<u32>) -> f64 {
        let cols = 256usize;
        let candidates: Vec<u32> = match force_n_c {
            Some(n) => vec![n],
            None => (1..=p_bits.min(cols as u32)).collect(),
        };
        candidates
            .into_iter()
            .map(|n_c| {
                let neurons = cols / n_c as usize;
                self.model
                    .sop_pj_analytic(w_bits, p_bits, n_c, neurons.max(1), cols)
                    .total_pj()
            })
            .fold(f64::INFINITY, f64::min)
    }

    /// Energy (pJ) to move `bits` through the hierarchy: global buffer if
    /// the per-timestep working set fits, DRAM otherwise.
    fn path_pj(&self, bits: f64, working_set_bits: u64) -> f64 {
        let per_bit = if working_set_bits <= self.cfg.gbuf_bits {
            self.cfg.e_gbuf_pj_bit
        } else {
            self.cfg.e_dram_pj_bit
        };
        bits * per_bit
    }

    /// Energy to stream one operand of `layer` for one timestep at the
    /// given SOP count, under a discipline (public: the coordinator prices
    /// measured traffic with it too).
    pub fn streamed_pj(
        &self,
        layer: &LayerSpec,
        op: Operand,
        sops: f64,
        discipline: Discipline,
    ) -> f64 {
        let (per_sop_bits, tile_bits) = match op {
            // Read + write back the affected potential on every SOP, or
            // sweep the whole map once per timestep.
            Operand::Vmem => (
                2.0 * layer.res.p_bits as f64,
                2.0 * layer.vmem_bits() as f64,
            ),
            // Fetch the triggering weight per SOP, or broadcast the full
            // kernel once per timestep.
            Operand::Weight => (layer.res.w_bits as f64, layer.weight_bits() as f64),
        };
        let per_sop = sops * per_sop_bits;
        let bits = match discipline {
            Discipline::PerSop => per_sop,
            Discipline::PerTimestepTile => tile_bits,
            Discipline::Best => per_sop.min(tile_bits),
        };
        let working_set = match op {
            Operand::Vmem => layer.vmem_bits(),
            Operand::Weight => layer.weight_bits(),
        };
        self.path_pj(bits, working_set)
    }

    /// Evaluate one timestep of `net` under `mapping` at the given input
    /// sparsity, using the macro energy at a freely-chosen shape
    /// (`force_n_c = None`) or a forced one (prior-art bit-serial).
    pub fn evaluate(
        &self,
        net: &Network,
        mapping: &Mapping,
        sparsity: f64,
        force_n_c: Option<u32>,
    ) -> SystemEnergyReport {
        assert!((0.0..=1.0).contains(&sparsity));
        assert_eq!(mapping.assignments.len(), net.layers.len());
        let activity = 1.0 - sparsity;
        let mut per_layer = Vec::new();
        for a in &mapping.assignments {
            let l = &net.layers[a.layer_idx];
            let sops = l.sops_dense() as f64 * activity;
            let compute_pj = sops * self.sop_pj(l.res.w_bits, l.res.p_bits, force_n_c);

            let mut stream_pj = 0.0;
            let mut load_pj = 0.0;
            let stat_op = a.stationarity.stationary_operand();
            let stream_op = a.stationarity.streamed_operand();
            if a.stationary_resident {
                // One-time DRAM→CIM load, amortized.
                let bits = match stat_op {
                    Operand::Weight => l.weight_bits(),
                    Operand::Vmem => l.vmem_bits(),
                };
                load_pj += bits as f64 * self.cfg.e_dram_pj_bit
                    / self.cfg.amortize_timesteps as f64;
            } else {
                let d = match stat_op {
                    Operand::Vmem => self.cfg.vmem_discipline,
                    Operand::Weight => self.cfg.weight_discipline,
                };
                stream_pj += self.streamed_pj(l, stat_op, sops, d);
            }
            if a.extra_resident {
                let bits = match stream_op {
                    Operand::Weight => l.weight_bits(),
                    Operand::Vmem => l.vmem_bits(),
                };
                load_pj += bits as f64 * self.cfg.e_dram_pj_bit
                    / self.cfg.amortize_timesteps as f64;
            } else {
                let d = match stream_op {
                    Operand::Vmem => self.cfg.vmem_discipline,
                    Operand::Weight => self.cfg.weight_discipline,
                };
                stream_pj += self.streamed_pj(l, stream_op, sops, d);
            }

            // AER spike I/O: input events reach the array, output spikes
            // leave it.
            let (ic, ih, iw) = l.in_shape();
            let in_events = (ic * ih * iw) as f64 * activity;
            let out_events = l.num_neurons() as f64 * activity;
            let spike_pj = (in_events + out_events)
                * self.cfg.spike_addr_bits as f64
                * self.cfg.e_gbuf_pj_bit;

            per_layer.push(LayerEnergy {
                name: l.name.clone(),
                sops,
                compute_pj,
                stream_pj,
                spike_pj,
                load_pj,
            });
        }
        SystemEnergyReport { per_layer }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{Mapper, Policy};
    use crate::snn::network::scnn_dvs_gesture;
    use crate::snn::Resolution;

    fn conv_net() -> Network {
        let full = scnn_dvs_gesture();
        Network::new(
            "SCNN-conv",
            full.layers[..6].to_vec(),
            full.timesteps,
        )
    }

    #[test]
    fn sop_pj_best_shape_beats_bit_serial() {
        let m = SystemEnergyModel::flexspim(16);
        let best = m.sop_pj(8, 16, None);
        let serial = m.sop_pj(8, 16, Some(1));
        assert!(best <= serial);
        assert!(best > 0.0);
    }

    #[test]
    fn full_residency_means_no_streaming() {
        let net = conv_net();
        let mapping = Mapper::flexspim(16).map(&net, Policy::HsOpt);
        let m = SystemEnergyModel::flexspim(16);
        let r = m.evaluate(&net, &mapping, 0.95, None);
        assert_eq!(r.stream_pj(), 0.0, "16 macros hold the whole conv stack");
        assert!(r.compute_pj() > 0.0);
    }

    #[test]
    fn energy_scales_with_activity() {
        let net = conv_net();
        let mapping = Mapper::flexspim(16).map(&net, Policy::HsOpt);
        let m = SystemEnergyModel::flexspim(16);
        let hi = m.evaluate(&net, &mapping, 0.85, None).total_pj();
        let lo = m.evaluate(&net, &mapping, 0.99, None).total_pj();
        assert!(hi > lo, "less sparsity -> more energy");
    }

    #[test]
    fn ws_only_streams_early_vmem() {
        let net = conv_net();
        let mapping = Mapper::flexspim(2).map(&net, Policy::WsOnly);
        let m = SystemEnergyModel::flexspim(2);
        let r = m.evaluate(&net, &mapping, 0.95, None);
        // L1's membrane potentials dominate and are streamed under WS.
        assert!(r.per_layer[0].stream_pj > 0.0);
        let hs = Mapper::flexspim(2).map(&net, Policy::HsOpt);
        let r_hs = m.evaluate(&net, &hs, 0.95, None);
        assert!(
            r_hs.total_pj() < r.total_pj(),
            "HS must beat WS-only at equal capacity"
        );
    }

    #[test]
    fn dram_spill_engages_for_oversized_working_sets() {
        let mut cfg = SystemConfig::flexspim(1);
        cfg.gbuf_bits = 1024; // absurdly small buffer
        let m = SystemEnergyModel::new(cfg);
        let net = conv_net();
        let mapping = Mapper::flexspim(1).map(&net, Policy::WsOnly);
        let r = m.evaluate(&net, &mapping, 0.95, None);
        let m2 = SystemEnergyModel::flexspim(1);
        let r2 = m2.evaluate(&net, &mapping, 0.95, None);
        assert!(
            r.stream_pj() > 5.0 * r2.stream_pj(),
            "DRAM path must be much more expensive than gbuf"
        );
    }

    #[test]
    fn per_sop_discipline_scales_with_sparsity_tile_does_not() {
        let l = crate::snn::LayerSpec::conv("c", 8, 8, 3, 1, 1, 16, 16, Resolution::new(6, 11));
        let m = SystemEnergyModel::flexspim(1);
        let s_lo = m.streamed_pj(&l, Operand::Vmem, 100.0, Discipline::PerSop);
        let s_hi = m.streamed_pj(&l, Operand::Vmem, 1000.0, Discipline::PerSop);
        assert!((s_hi / s_lo - 10.0).abs() < 1e-9);
        let t_lo = m.streamed_pj(&l, Operand::Vmem, 100.0, Discipline::PerTimestepTile);
        let t_hi = m.streamed_pj(&l, Operand::Vmem, 1000.0, Discipline::PerTimestepTile);
        assert_eq!(t_lo, t_hi);
        let b = m.streamed_pj(&l, Operand::Vmem, 1000.0, Discipline::Best);
        assert!(b <= s_hi && b <= t_hi);
    }

    /// Fig. 7(c): FlexSpIM (16 macros, HS, optimal resolutions) vs a
    /// [4]-based system — 87–90 % energy gain over 85–99 % sparsity.
    #[test]
    fn fig7c_band() {
        let report = super::super::baselines::fig7c_gain_sweep(&[0.85, 0.92, 0.99]);
        for (s, gain) in report {
            assert!(
                (0.80..0.95).contains(&gain),
                "gain {gain:.3} at sparsity {s} outside Fig. 7c band (paper: 0.87-0.90)"
            );
        }
    }

    /// Fig. 7(d): FlexSpIM (18 macros, 6b/11b) vs an IMPULSE-based system —
    /// 79–86 % gain over the same sparsity range.
    #[test]
    fn fig7d_band() {
        let report = super::super::baselines::fig7d_gain_sweep(&[0.85, 0.92, 0.99]);
        for (s, gain) in report {
            assert!(
                (0.70..0.92).contains(&gain),
                "gain {gain:.3} at sparsity {s} outside Fig. 7d band (paper: 0.79-0.86)"
            );
        }
    }
}
