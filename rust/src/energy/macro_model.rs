//! Macro-level energy pricing.
//!
//! Event coefficients are expressed at the 1.1-V reference and scaled to
//! other supply points with a mixed quadratic/linear law fitted to the two
//! published silicon measurements (7.2 pJ/SOP at 1.1 V, 5.7 pJ/SOP at
//! 0.9 V → scale(0.9 V) = 0.792).
//!
//! Coefficient derivation (see DESIGN.md §Energy-Calibration): with the
//! 8b/16b bit-serial mapping and all 256 columns busy, one SOP costs 16
//! active column-cycles plus a 1/256 share of the per-cycle overhead:
//! `16·e_active + 16·e_shared/256 = 7.2 pJ` with `e_shared = 0.5·e_active`
//! gives `e_active ≈ 0.449 pJ`, which also reproduces the measured 17.9 mW
//! (256·e_active + e_shared ≈ 115 pJ/cycle × 157 MHz) and, at the
//! low-voltage point, 6.8 mW. The active column-cycle energy is split over
//! precharge / sense / add / write-back in ratios typical of 6T digital
//! CIM (precharge-heavy), which the ledger counts separately.

use crate::cim::EnergyCounters;

/// Joules-per-event coefficients at the 1.1-V reference point.
#[derive(Debug, Clone)]
pub struct MacroEnergyModel {
    /// Precharge energy per active column-cycle (pJ).
    pub e_precharge: f64,
    /// One sense-amplifier evaluation (pJ).
    pub e_sa: f64,
    /// One full-adder evaluation (pJ).
    pub e_adder: f64,
    /// One bit write-back (pJ).
    pub e_writeback: f64,
    /// One carry hop between neighboring PCs (pJ).
    pub e_carry_hop: f64,
    /// One emulation-bit read (pJ).
    pub e_eb: f64,
    /// One comparator step (pJ).
    pub e_compare: f64,
    /// Idle-unselected column-cycle without standby gating — what prior
    /// row-wise designs pay on unused columns (pJ).
    pub e_idle_unselected: f64,
    /// Standby column-cycle with PC gating (pJ) = 0.13 × idle-unselected
    /// (the paper's 87 % reduction).
    pub e_standby: f64,
    /// Shared per-cycle overhead: WL pair, decoder, clock, FSM (pJ).
    pub e_shared_cycle: f64,
    /// One bit through the macro I/O port (pJ).
    pub e_io_bit: f64,
    /// One plain SRAM bit write via the port (pJ).
    pub e_sram_write: f64,
    /// One plain SRAM bit read via the port (pJ).
    pub e_sram_read: f64,
    /// Supply voltage this model is evaluated at (V).
    pub vdd: f64,
}

/// Reference active column-cycle energy at 1.1 V (pJ); see module docs.
pub const E_ACTIVE_COL_CYCLE_PJ: f64 = 0.449;

impl MacroEnergyModel {
    /// Model at the 1.1-V nominal point.
    pub fn nominal() -> Self {
        let e_a = E_ACTIVE_COL_CYCLE_PJ;
        // Idle-unselected factor fitted so the Fig. 7a shaping study lands
        // on the paper's "up to 4.3×" saving (DESIGN.md §Energy-Calibration).
        let e_idle = 0.617 * e_a;
        MacroEnergyModel {
            e_precharge: 0.267 * e_a,
            e_sa: 0.100 * e_a, // ×2 per CIM cycle
            e_adder: 0.178 * e_a,
            e_writeback: 0.355 * e_a,
            e_carry_hop: 0.030 * e_a,
            e_eb: 0.045 * e_a,
            e_compare: 0.045 * e_a,
            e_idle_unselected: e_idle,
            e_standby: 0.13 * e_idle, // 87 % reduction (paper §III-A)
            e_shared_cycle: 0.5 * e_a,
            e_io_bit: 0.050,
            e_sram_write: 0.080,
            e_sram_read: 0.040,
            vdd: 1.1,
        }
    }

    /// Voltage-scaling factor fitted to the two measured efficiency points:
    /// `scale(1.1) = 1`, `scale(0.9) = 5.7/7.2 = 0.792`. A pure-V² law
    /// would give 0.669; the silicon shows a substantial voltage-
    /// independent component, captured by the linear mix below.
    pub fn voltage_scale(vdd: f64) -> f64 {
        let r = vdd / 1.1;
        0.174 * r * r + 0.826 * r
    }

    /// Model rescaled to a supply point in the measured 0.9–1.1 V range.
    pub fn at_vdd(vdd: f64) -> Self {
        assert!((0.9..=1.1).contains(&vdd), "vdd {vdd} outside silicon range");
        let s = Self::voltage_scale(vdd);
        let n = Self::nominal();
        MacroEnergyModel {
            e_precharge: n.e_precharge * s,
            e_sa: n.e_sa * s,
            e_adder: n.e_adder * s,
            e_writeback: n.e_writeback * s,
            e_carry_hop: n.e_carry_hop * s,
            e_eb: n.e_eb * s,
            e_compare: n.e_compare * s,
            e_idle_unselected: n.e_idle_unselected * s,
            e_standby: n.e_standby * s,
            e_shared_cycle: n.e_shared_cycle * s,
            e_io_bit: n.e_io_bit * s,
            e_sram_write: n.e_sram_write * s,
            e_sram_read: n.e_sram_read * s,
            vdd,
        }
    }

    /// Price an event ledger in picojoules.
    pub fn price_pj(&self, c: &EnergyCounters) -> f64 {
        c.active_col_cycles as f64 * self.e_precharge
            + c.sa_reads as f64 * self.e_sa
            + c.adder_ops as f64 * self.e_adder
            + c.writebacks as f64 * self.e_writeback
            + c.carry_hops as f64 * self.e_carry_hop
            + c.eb_reads as f64 * self.e_eb
            + c.compare_ops as f64 * self.e_compare
            + c.standby_col_cycles as f64 * self.e_standby
            + c.cim_cycles as f64 * self.e_shared_cycle
            + c.io_bits as f64 * self.e_io_bit
            + c.sram_writes as f64 * self.e_sram_write
            + c.sram_reads as f64 * self.e_sram_read
    }

    /// Price a ledger as pJ *per SOP*.
    pub fn pj_per_sop(&self, c: &EnergyCounters) -> f64 {
        assert!(c.sops > 0, "ledger contains no SOPs");
        self.price_pj(c) / c.sops as f64
    }

    /// Analytic per-SOP energy for a shaped accumulate (no bit simulation;
    /// used by the system-level extrapolation where billions of SOPs are
    /// priced). Mirrors exactly what the simulator's ledger would produce
    /// for one `cim_accumulate` amortized over the parallel neurons —
    /// asserted against the simulator in the unit tests.
    pub fn sop_pj_analytic(
        &self,
        w_bits: u32,
        p_bits: u32,
        n_c: u32,
        parallel_neurons: usize,
        total_cols: usize,
    ) -> SopEnergyBreakdown {
        let n_r_p = p_bits.div_ceil(n_c) as f64;
        let n = parallel_neurons as f64;
        let active_cols = n * n_c as f64;
        assert!(active_cols <= total_cols as f64, "columns oversubscribed");
        let standby_cols = total_cols as f64 - active_cols;

        // Per-SOP event counts (one accumulate for one neuron).
        let col_cycles = n_c as f64 * n_r_p; // includes padding cells
        let adds = p_bits as f64;
        let carry_hops = (n_c as f64 - 1.0) * n_r_p;
        let eb_reads = (p_bits.saturating_sub(w_bits)) as f64;

        let compute = col_cycles * self.e_precharge
            + 2.0 * col_cycles * self.e_sa
            + adds * (self.e_adder + self.e_writeback)
            + carry_hops * self.e_carry_hop
            + eb_reads * self.e_eb;
        let shared = n_r_p * self.e_shared_cycle / n;
        let standby = standby_cols * n_r_p * self.e_standby / n;
        SopEnergyBreakdown { compute_pj: compute, shared_pj: shared, standby_pj: standby }
    }

    /// Same accumulate priced under a *row-wise kernel-stacking* prior-art
    /// discipline ([3]–[7]): no operand shaping (bit-serial only) and no
    /// standby mode — unused columns keep toggling at idle-unselected cost.
    pub fn sop_pj_rowwise_baseline(
        &self,
        p_bits: u32,
        parallel_neurons: usize,
        total_cols: usize,
    ) -> f64 {
        let n_r_p = p_bits as f64; // N_C = 1 forced
        let n = parallel_neurons as f64;
        let idle_cols = total_cols as f64 - n;
        let compute = n_r_p * (self.e_precharge + 2.0 * self.e_sa)
            + p_bits as f64 * (self.e_adder + self.e_writeback);
        let idle = idle_cols * n_r_p * self.e_idle_unselected / n;
        let shared = n_r_p * self.e_shared_cycle / n;
        compute + idle + shared
    }
}

/// Per-SOP energy decomposition (pJ).
#[derive(Debug, Clone, Copy)]
pub struct SopEnergyBreakdown {
    /// Active-column compute energy.
    pub compute_pj: f64,
    /// Amortized shared per-cycle overhead.
    pub shared_pj: f64,
    /// Amortized standby energy of gated columns.
    pub standby_pj: f64,
}

impl SopEnergyBreakdown {
    /// Total pJ per SOP.
    pub fn total_pj(&self) -> f64 {
        self.compute_pj + self.shared_pj + self.standby_pj
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::{CimMacro, MacroConfig};
    use crate::util::stats::rel_diff;

    /// Table I anchor: 8b/16b bit-serial, 256 neurons → 7.2 pJ/SOP at 1.1 V.
    #[test]
    fn calibration_nominal_pj_per_sop() {
        let m = MacroEnergyModel::nominal();
        let e = m.sop_pj_analytic(8, 16, 1, 256, 256).total_pj();
        assert!(
            (e - 7.2).abs() < 0.45,
            "nominal 8b/16b should be ~7.2 pJ/SOP, got {e:.3}"
        );
    }

    /// Table I anchor: 5.7 pJ/SOP at 0.9 V.
    #[test]
    fn calibration_low_voltage_pj_per_sop() {
        let m = MacroEnergyModel::at_vdd(0.9);
        let e = m.sop_pj_analytic(8, 16, 1, 256, 256).total_pj();
        assert!(
            (e - 5.7).abs() < 0.4,
            "low-voltage 8b/16b should be ~5.7 pJ/SOP, got {e:.3}"
        );
    }

    /// Table I anchor: 17.9 mW at nominal, 6.8 mW at low voltage.
    #[test]
    fn calibration_power() {
        let nominal = MacroEnergyModel::nominal();
        let e_sop = nominal.sop_pj_analytic(8, 16, 1, 256, 256).total_pj();
        let p_mw = 2.512e9 * e_sop * 1e-12 * 1e3; // 2.5 GSOPS × pJ/SOP
        assert!((p_mw - 17.9).abs() < 1.5, "nominal power ~17.9 mW, got {p_mw:.2}");

        let lv = MacroEnergyModel::at_vdd(0.9);
        let e_sop_lv = lv.sop_pj_analytic(8, 16, 1, 256, 256).total_pj();
        let p_lv = 1.208e9 * e_sop_lv * 1e-12 * 1e3;
        assert!((p_lv - 6.8).abs() < 0.8, "low-voltage power ~6.8 mW, got {p_lv:.2}");
    }

    /// 1-bit-normalized efficiency lands in Table I's 44.5–56.3 fJ/SOP band.
    #[test]
    fn calibration_1b_normalized_efficiency() {
        for (vdd, _expect) in [(1.1, 56.3), (0.9, 44.5)] {
            let m = MacroEnergyModel::at_vdd(vdd);
            let e = m.sop_pj_analytic(8, 16, 1, 256, 256).total_pj();
            let norm_fj = e * 1e3 / 128.0; // / (8 × 16)
            assert!(
                (40.0..62.0).contains(&norm_fj),
                "1b-norm {norm_fj:.1} fJ/SOP out of Table I band at {vdd} V"
            );
        }
    }

    /// The paper's 87 % standby reduction is definitional in the model.
    #[test]
    fn standby_reduction_is_87_percent() {
        let m = MacroEnergyModel::nominal();
        let reduction = 1.0 - m.e_standby / m.e_idle_unselected;
        assert!((reduction - 0.87).abs() < 1e-9);
    }

    /// Voltage scale hits both fitted endpoints and is monotone.
    #[test]
    fn voltage_scale_fit() {
        assert!((MacroEnergyModel::voltage_scale(1.1) - 1.0).abs() < 1e-12);
        assert!((MacroEnergyModel::voltage_scale(0.9) - 0.792).abs() < 2e-3);
        assert!(MacroEnergyModel::voltage_scale(1.0) < 1.0);
        assert!(MacroEnergyModel::voltage_scale(1.0) > 0.792);
    }

    /// Analytic pricing must agree with the bit-accurate simulator's ledger
    /// (same events, same price) across shapes.
    #[test]
    fn analytic_matches_simulated_ledger() {
        let model = MacroEnergyModel::nominal();
        for (w, p, n_c, neurons) in [(8u32, 16u32, 1u32, 32usize), (8, 16, 4, 32), (8, 16, 8, 32), (4, 9, 3, 16)] {
            let cfg = MacroConfig::flexspim(w, p, n_c, 1, neurons);
            let mut mac = CimMacro::new(cfg).unwrap();
            for n in 0..neurons {
                mac.load_weight(n, 0, ((n as i64) % 5) - 2);
                mac.load_vmem(n, n as i64);
            }
            mac.reset_counters();
            mac.cim_accumulate(0, None);
            let sim_pj = model.pj_per_sop(mac.counters());
            let ana_pj = model
                .sop_pj_analytic(w, p, n_c, neurons, cfg.cols)
                .total_pj();
            assert!(
                rel_diff(sim_pj, ana_pj) < 0.06,
                "{w}b/{p}b n_c={n_c}: sim {sim_pj:.3} vs analytic {ana_pj:.3}"
            );
        }
    }

    /// Fig. 7a: energy grows linearly with resolution (single-row shapes),
    /// carry overhead <5 %.
    #[test]
    fn linear_resolution_scaling_with_small_carry_overhead() {
        let m = MacroEnergyModel::nominal();
        // Single-row shape: N_C = bits, N_R = 1; equal w/p resolution.
        let e_at = |bits: u32| {
            m.sop_pj_analytic(bits, bits, bits, (256 / bits) as usize, 256)
                .total_pj()
        };
        let e4 = e_at(4);
        let e8 = e_at(8);
        let e16 = e_at(16);
        let e32 = e_at(32);
        // Linearity: doubling resolution ≈ doubles energy, within the <5 %
        // carry-propagation overhead the paper reports.
        for (lo, hi, f) in [(e4, e8, 2.0), (e8, e16, 2.0), (e4, e16, 4.0), (e8, e32, 4.0)] {
            let ratio = hi / lo;
            assert!(
                ratio > f * 0.95 && ratio < f * 1.08,
                "scaling {ratio:.3} vs ideal {f} outside <5-8 % overhead band"
            );
        }
        // Carry contribution alone stays under 5 % of the total.
        let b = m.sop_pj_analytic(16, 16, 16, 16, 256);
        let carry_pj = 15.0 * m.e_carry_hop;
        assert!(carry_pj / b.total_pj() < 0.05);
    }

    /// Fig. 7a headline: shaping + standby saves ~4.3× vs row-wise kernel
    /// stacking at 16-bit resolution with 32 output channels, while energy
    /// variation across FlexSpIM shapes stays below ~24 %.
    #[test]
    fn shaping_study_savings_and_homogeneity() {
        let m = MacroEnergyModel::nominal();
        let base = m.sop_pj_rowwise_baseline(16, 32, 256);
        // FlexSpIM shapes for a 16-bit operand (Fig. 7a sweep).
        let shapes = [(2u32, 8u32), (4, 4), (8, 2), (16, 1)]; // (N_C, N_R)
        let energies: Vec<f64> = shapes
            .iter()
            .map(|&(n_c, _)| {
                let parallel = (256 / n_c as usize).min(32);
                m.sop_pj_analytic(8, 16, n_c, parallel, 256).total_pj()
            })
            .collect();
        let best = energies.iter().cloned().fold(f64::INFINITY, f64::min);
        let worst = energies.iter().cloned().fold(0.0f64, f64::max);
        let saving = base / worst; // conservative: vs the worst flex shape
        let saving_best = base / best;
        assert!(
            saving > 3.4 && saving_best < 7.0,
            "saving range [{saving:.2}, {saving_best:.2}] should bracket the paper's 4.3×"
        );
        assert!(
            (worst - best) / best < 0.30,
            "shape variation {:.1}% should be ≤ ~24 %",
            (worst - best) / best * 100.0
        );
    }

    #[test]
    #[should_panic(expected = "outside silicon range")]
    fn vdd_envelope_enforced() {
        MacroEnergyModel::at_vdd(1.3);
    }
}
