//! Prior-art baseline systems for the Fig. 7(c–d) comparison.
//!
//! The paper extrapolates system-level energy for three designs placed in
//! the same template (many macros + global buffer + DRAM, Fig. 7b):
//!
//! * **FlexSpIM** — arbitrary resolution, operand shaping, hybrid
//!   stationarity;
//! * **[4] ISSCC'24** — spike-driven analog-assisted CIM-SNN: 4 kB macros,
//!   fixed {4, 8}-bit weights / 16-bit potentials, WS-only, no shaping
//!   (bit-serial), per-spike membrane read-modify-write (that is the
//!   "spike-driven" operating principle the paper names);
//! * **[3] IMPULSE** — 65-nm digital CIM-SNN: 1.37 kB macros, fixed
//!   6-bit/11-bit fused weight/potential storage, WS-only, row-wise
//!   bit-serial mapping.
//!
//! Technology normalization: both baselines are priced with *our*
//! calibrated 40-nm macro model under their architectural constraints
//! (capacity, fixed resolution, forced bit-serial shape, WS-only,
//! per-spike streaming). This isolates the *flexibility* contribution the
//! paper claims, rather than cross-technology circuit differences —
//! documented in DESIGN.md §Substitutions.

use super::system::{Discipline, SystemConfig, SystemEnergyModel};
use crate::dataflow::{Mapper, Policy};
use crate::snn::network::{scnn_dvs_gesture, scnn_impulse_resolution};
use crate::snn::{Network, Resolution};

/// The six-conv SCNN used in the system-level study (the paper's Fig. 4a
/// workload; the system extrapolation operates on the convolutional stack).
pub fn system_workload() -> Network {
    let full = scnn_dvs_gesture();
    Network::new("SCNN-conv6", full.layers[..6].to_vec(), full.timesteps)
}

/// The same workload at [4]'s constrained resolutions (4/8-bit weights,
/// 16-bit potentials).
pub fn system_workload_isscc24() -> Network {
    let base = system_workload();
    let res: Vec<Resolution> = base
        .layers
        .iter()
        .map(|l| Resolution::new(if l.res.w_bits <= 4 { 4 } else { 8 }, 16))
        .collect();
    base.with_resolutions(&res)
}

/// The same workload at IMPULSE's fixed 6-bit/11-bit resolution.
pub fn system_workload_impulse() -> Network {
    let full = scnn_impulse_resolution();
    Network::new("SCNN-conv6-6b11b", full.layers[..6].to_vec(), full.timesteps)
}

/// A [4]-based system: `n` macros of 4 kB, WS-only, spike-driven
/// streaming, bit-serial mapping.
pub fn isscc24_system(num_macros: usize) -> SystemEnergyModel {
    let mut cfg = SystemConfig::flexspim(num_macros);
    cfg.macro_bits = 4 * 1024 * 8; // 4 kB macros (Table I)
    cfg.vmem_discipline = Discipline::PerSop; // spike-driven RMW
    cfg.weight_discipline = Discipline::PerTimestepTile;
    SystemEnergyModel::new(cfg)
}

/// An IMPULSE-based system: `n` macros of 1.37 kB, WS-only, row-wise
/// bit-serial.
pub fn impulse_system(num_macros: usize) -> SystemEnergyModel {
    let mut cfg = SystemConfig::flexspim(num_macros);
    cfg.macro_bits = (1.37 * 1024.0 * 8.0) as u64; // 1.37 kB macros (Table I)
    cfg.vmem_discipline = Discipline::PerSop;
    cfg.weight_discipline = Discipline::PerTimestepTile;
    SystemEnergyModel::new(cfg)
}

/// Fig. 7(c): energy-efficiency gain of a 16-macro FlexSpIM system (HS,
/// optimal resolutions) over a 16-macro [4] system, per sparsity point.
/// Returns `(sparsity, gain)` pairs where `gain = 1 - E_flex / E_base`.
pub fn fig7c_gain_sweep(sparsities: &[f64]) -> Vec<(f64, f64)> {
    let flex_net = system_workload();
    let base_net = system_workload_isscc24();

    let flex_sys = SystemEnergyModel::flexspim(16);
    let base_sys = isscc24_system(16);

    let flex_map = Mapper {
        macro_capacity_bits: flex_sys.cfg.macro_bits,
        num_macros: 16,
    }
    .map(&flex_net, Policy::HsOpt);
    let base_map = Mapper {
        macro_capacity_bits: base_sys.cfg.macro_bits,
        num_macros: 16,
    }
    .map(&base_net, Policy::WsOnly);

    sparsities
        .iter()
        .map(|&s| {
            let e_flex = flex_sys.evaluate(&flex_net, &flex_map, s, None).total_pj();
            // Baseline forced to bit-serial shapes (no operand shaping).
            let e_base = base_sys.evaluate(&base_net, &base_map, s, Some(1)).total_pj();
            (s, 1.0 - e_flex / e_base)
        })
        .collect()
}

/// Fig. 7(d): gain of an 18-macro FlexSpIM system over an 18-macro
/// IMPULSE system, both at 6-bit/11-bit resolution.
pub fn fig7d_gain_sweep(sparsities: &[f64]) -> Vec<(f64, f64)> {
    let net = system_workload_impulse();

    let flex_sys = SystemEnergyModel::flexspim(18);
    let base_sys = impulse_system(18);

    let flex_map = Mapper {
        macro_capacity_bits: flex_sys.cfg.macro_bits,
        num_macros: 18,
    }
    .map(&net, Policy::HsOpt);
    let base_map = Mapper {
        macro_capacity_bits: base_sys.cfg.macro_bits,
        num_macros: 18,
    }
    .map(&net, Policy::WsOnly);

    sparsities
        .iter()
        .map(|&s| {
            let e_flex = flex_sys.evaluate(&net, &flex_map, s, None).total_pj();
            let e_base = base_sys.evaluate(&net, &base_map, s, Some(1)).total_pj();
            (s, 1.0 - e_flex / e_base)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workloads_have_expected_resolutions() {
        let w4 = system_workload_isscc24();
        assert!(w4.layers.iter().all(|l| l.res.p_bits == 16));
        assert!(w4.layers.iter().all(|l| l.res.w_bits == 4 || l.res.w_bits == 8));
        let wi = system_workload_impulse();
        assert!(wi.layers.iter().all(|l| l.res == Resolution::new(6, 11)));
    }

    #[test]
    fn baseline_capacity_is_much_smaller() {
        let flex = SystemEnergyModel::flexspim(16);
        let b4 = isscc24_system(16);
        let bi = impulse_system(18);
        assert!(b4.cfg.cim_bits() < flex.cfg.cim_bits() / 3);
        assert!(bi.cfg.cim_bits() < flex.cfg.cim_bits() / 8);
    }

    #[test]
    fn gains_increase_with_or_stay_flat_in_sparsity() {
        // The paper's gains are roughly flat (87→90 % and 79→86 % over
        // 85→99 % sparsity); ours must not *decrease* materially.
        let g = fig7c_gain_sweep(&[0.85, 0.99]);
        assert!(g[1].1 >= g[0].1 - 0.03, "gain dropped: {g:?}");
        let d = fig7d_gain_sweep(&[0.85, 0.99]);
        assert!(d[1].1 >= d[0].1 - 0.03, "gain dropped: {d:?}");
    }

    #[test]
    fn flexspim_wins_at_every_swept_point() {
        for (_, gain) in fig7c_gain_sweep(&[0.85, 0.90, 0.95, 0.99]) {
            assert!(gain > 0.5);
        }
        for (_, gain) in fig7d_gain_sweep(&[0.85, 0.90, 0.95, 0.99]) {
            assert!(gain > 0.5);
        }
    }
}
