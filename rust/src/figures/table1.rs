//! Table I — macro-level comparison with the state of the art.
//!
//! Our column is *measured* on the simulator + calibrated energy model at
//! both operating points; the prior-art columns quote the paper's
//! published numbers (they are reference data, not things we can measure).

use crate::cim::ops::OperatingPoint;
use crate::cim::MacroConfig;
use crate::energy::MacroEnergyModel;

/// One accelerator column of Table I.
#[derive(Debug, Clone)]
pub struct Column {
    /// Design name.
    pub name: &'static str,
    /// Technology node.
    pub tech: &'static str,
    /// Macro capacity (kB), if applicable.
    pub capacity_kb: f64,
    /// Peak throughput range (GSOPS).
    pub peak_gsops: (f64, f64),
    /// Efficiency range (pJ/SOP).
    pub pj_per_sop: (f64, f64),
    /// 1-bit-normalized efficiency (fJ/SOP).
    pub norm_fj_per_sop: (f64, f64),
    /// Resolution support description.
    pub resolution: &'static str,
    /// Hybrid-stationarity support.
    pub hs_support: bool,
}

/// Measure our column at the two operating points (8b/16b mapping, the
/// Table I reference configuration).
pub fn flexspim_column() -> Column {
    let cfg = MacroConfig::flexspim(8, 16, 1, 1, 256);
    let hi = OperatingPoint::nominal();
    let lo = OperatingPoint::low_voltage();
    let gsops_hi = cfg.peak_sops(hi.system_clock_hz) / 1e9;
    let gsops_lo = cfg.peak_sops(lo.system_clock_hz) / 1e9;
    let e_hi = MacroEnergyModel::at_vdd(hi.vdd)
        .sop_pj_analytic(8, 16, 1, 256, 256)
        .total_pj();
    let e_lo = MacroEnergyModel::at_vdd(lo.vdd)
        .sop_pj_analytic(8, 16, 1, 256, 256)
        .total_pj();
    Column {
        name: "FlexSpIM (this sim)",
        tech: "40nm (modeled)",
        capacity_kb: 16.0,
        peak_gsops: (gsops_lo, gsops_hi),
        pj_per_sop: (e_lo, e_hi),
        norm_fj_per_sop: (e_lo * 1e3 / 128.0, e_hi * 1e3 / 128.0),
        resolution: "any/any (bitwise)",
        hs_support: true,
    }
}

/// Published prior-art rows (quoted from the paper's Table I).
pub fn prior_art() -> Vec<Column> {
    vec![
        Column {
            name: "IMPULSE [3]",
            tech: "65nm",
            capacity_kb: 1.37,
            peak_gsops: (0.07, 0.5),
            pj_per_sop: (1.09, 1.74),
            norm_fj_per_sop: (16.5, 26.4),
            resolution: "6b/11b fixed",
            hs_support: false,
        },
        Column {
            name: "ISSCC'24 [4]",
            tech: "22nm",
            capacity_kb: 4.0,
            peak_gsops: (f64::NAN, f64::NAN),
            pj_per_sop: (3.78, 10.01),
            norm_fj_per_sop: (29.5, 78.2),
            resolution: "4/8b + 16b",
            hs_support: false,
        },
        Column {
            name: "ReckOn [15]",
            tech: "28nm",
            capacity_kb: f64::NAN,
            peak_gsops: (0.013, 0.115),
            pj_per_sop: (5.3, 12.8),
            norm_fj_per_sop: (41.4, 100.0),
            resolution: "8b/16b fixed",
            hs_support: false,
        },
    ]
}

/// The paper's headline: ≥2× better 1-bit-normalized efficiency than
/// prior *digital CIM* at full flexibility. Our modeled column must land
/// in the published 44.5–56.3 fJ/SOP band.
pub fn normalized_efficiency_in_band() -> bool {
    let c = flexspim_column();
    c.norm_fj_per_sop.0 > 38.0 && c.norm_fj_per_sop.1 < 62.0
}

/// Render the comparison table.
pub fn render() -> String {
    let ours = flexspim_column();
    let mut cols = vec![ours];
    cols.extend(prior_art());
    let mut s = String::from(
        "Table I — macro-level comparison (our column measured on the \
         simulator; others quoted from the paper)\n\n",
    );
    s.push_str(&format!(
        "{:<22} {:<16} {:>8} {:>16} {:>16} {:>18} {:>20} {:>4}\n",
        "design", "tech", "cap kB", "peak GSOPS", "pJ/SOP", "1b-norm fJ/SOP", "resolution", "HS"
    ));
    for c in &cols {
        s.push_str(&format!(
            "{:<22} {:<16} {:>8.2} {:>7.2}-{:<8.2} {:>7.2}-{:<8.2} {:>9.1}-{:<8.1} {:>20} {:>4}\n",
            c.name,
            c.tech,
            c.capacity_kb,
            c.peak_gsops.0,
            c.peak_gsops.1,
            c.pj_per_sop.0,
            c.pj_per_sop.1,
            c.norm_fj_per_sop.0,
            c.norm_fj_per_sop.1,
            c.resolution,
            if c.hs_support { "yes" } else { "no" },
        ));
    }
    s.push_str(&format!(
        "\npaper anchors: peak 1.2-2.5 GSOPS, 5.7-7.2 pJ/SOP, 44.5-56.3 fJ/SOP (1b-norm)\n\
         normalized efficiency in published band: {}\n",
        normalized_efficiency_in_band()
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn our_column_matches_paper_anchors() {
        let c = flexspim_column();
        assert!((c.peak_gsops.0 - 1.2).abs() < 0.1, "{:?}", c.peak_gsops);
        assert!((c.peak_gsops.1 - 2.5).abs() < 0.1);
        assert!((c.pj_per_sop.0 - 5.7).abs() < 0.5, "{:?}", c.pj_per_sop);
        assert!((c.pj_per_sop.1 - 7.2).abs() < 0.5);
        assert!(normalized_efficiency_in_band());
    }

    #[test]
    fn flexibility_flags() {
        let c = flexspim_column();
        assert!(c.hs_support);
        assert!(prior_art().iter().all(|p| !p.hs_support));
    }

    #[test]
    fn render_includes_all_designs() {
        let s = render();
        for name in ["FlexSpIM", "IMPULSE", "ISSCC'24", "ReckOn"] {
            assert!(s.contains(name), "missing {name}");
        }
    }
}
