//! Fig. 7 — macro-level shaping study (a) and many-macro system
//! extrapolation (c, d).

use crate::cim::{CimMacro, MacroConfig};
use crate::energy::baselines::{fig7c_gain_sweep, fig7d_gain_sweep};
use crate::energy::MacroEnergyModel;

/// One point of the Fig. 7(a) resolution-linearity sweep.
#[derive(Debug, Clone, Copy)]
pub struct ResolutionPoint {
    /// Equal weight/potential resolution (bits).
    pub bits: u32,
    /// Energy per SOP (pJ), single-row shape over all columns.
    pub pj_per_sop: f64,
}

/// One point of the Fig. 7(a) shape sweep.
#[derive(Debug, Clone, Copy)]
pub struct ShapePoint {
    /// Columns per operand.
    pub n_c: u32,
    /// Rows per operand.
    pub n_r: u32,
    /// Energy per SOP (pJ), measured on the bit-accurate simulator.
    pub pj_per_sop: f64,
}

/// Full Fig. 7(a) result.
#[derive(Debug, Clone)]
pub struct Fig7a {
    /// Energy vs resolution (linearity + carry overhead).
    pub resolution_sweep: Vec<ResolutionPoint>,
    /// Energy vs shape at 16-bit operands, 32 output channels.
    pub shape_sweep: Vec<ShapePoint>,
    /// Row-wise kernel-stacking baseline ([3]-style, no standby).
    pub rowwise_baseline_pj: f64,
}

impl Fig7a {
    /// Max/min across FlexSpIM shapes (paper: ≤24 % variation).
    pub fn shape_variation(&self) -> f64 {
        let lo = self.shape_sweep.iter().map(|p| p.pj_per_sop).fold(f64::INFINITY, f64::min);
        let hi = self.shape_sweep.iter().map(|p| p.pj_per_sop).fold(0.0f64, f64::max);
        hi / lo - 1.0
    }

    /// Best-case saving vs the row-wise baseline (paper: up to 4.3×).
    pub fn max_saving(&self) -> f64 {
        let lo = self.shape_sweep.iter().map(|p| p.pj_per_sop).fold(f64::INFINITY, f64::min);
        self.rowwise_baseline_pj / lo
    }

    /// Worst-case saving vs the row-wise baseline.
    pub fn min_saving(&self) -> f64 {
        let hi = self.shape_sweep.iter().map(|p| p.pj_per_sop).fold(0.0f64, f64::max);
        self.rowwise_baseline_pj / hi
    }
}

/// Run Fig. 7(a): the shape sweep uses the *bit-accurate* macro simulator
/// (every precharge/adder/carry event counted), the resolution sweep uses
/// the analytic model (identical by the cross-validation test in
/// energy::macro_model).
pub fn run_fig7a() -> Fig7a {
    let model = MacroEnergyModel::nominal();

    // Energy vs resolution: single-row shapes (N_R = 1, N_C = bits),
    // operands spread over all 256 columns.
    let resolution_sweep = [2u32, 4, 8, 12, 16, 24, 32]
        .iter()
        .map(|&bits| {
            let e = model
                .sop_pj_analytic(bits, bits, bits, (256 / bits).max(1) as usize, 256)
                .total_pj();
            ResolutionPoint { bits, pj_per_sop: e }
        })
        .collect();

    // Shape sweep at 16-bit potentials / 8-bit weights, 32 channels:
    // simulate one accumulate on the real macro per shape.
    let shape_sweep = [2u32, 4, 8, 16]
        .iter()
        .map(|&n_c| {
            let neurons = (256 / n_c as usize).min(32);
            let cfg = MacroConfig::flexspim(8, 16, n_c, 1, neurons);
            let mut mac = CimMacro::new(cfg).expect("config fits");
            for n in 0..neurons {
                mac.load_weight(n, 0, (n as i64 % 11) - 5);
                mac.load_vmem(n, (n as i64 * 7) % 100);
            }
            mac.reset_counters();
            // Average a few accumulates for stable operand-dependent toggles.
            for _ in 0..4 {
                mac.cim_accumulate(0, None);
            }
            let pj = model.price_pj(mac.counters()) / mac.counters().sops as f64;
            ShapePoint { n_c, n_r: 16u32.div_ceil(n_c), pj_per_sop: pj }
        })
        .collect();

    let rowwise_baseline_pj = model.sop_pj_rowwise_baseline(16, 32, 256);
    Fig7a { resolution_sweep, shape_sweep, rowwise_baseline_pj }
}

/// Fig. 7(c)/(d) sweeps re-exported with the paper's sparsity grid.
pub fn run_fig7c() -> Vec<(f64, f64)> {
    fig7c_gain_sweep(&[0.85, 0.88, 0.91, 0.94, 0.97, 0.99])
}

/// See [`run_fig7c`].
pub fn run_fig7d() -> Vec<(f64, f64)> {
    fig7d_gain_sweep(&[0.85, 0.88, 0.91, 0.94, 0.97, 0.99])
}

/// Render the Fig. 7 report.
pub fn render(a: &Fig7a, c: &[(f64, f64)], d: &[(f64, f64)]) -> String {
    let mut s = String::from("Fig. 7(a) — energy vs resolution (single-row shapes)\n");
    s.push_str("bits   pJ/SOP   pJ/SOP/bit\n");
    for p in &a.resolution_sweep {
        s.push_str(&format!(
            "{:>4} {:>8.3} {:>10.4}\n",
            p.bits,
            p.pj_per_sop,
            p.pj_per_sop / p.bits as f64
        ));
    }
    s.push_str("\nFig. 7(a) — shape sweep (8b/16b, 32 channels, bit-accurate sim)\n");
    s.push_str("shape (NRxNC)   pJ/SOP\n");
    for p in &a.shape_sweep {
        s.push_str(&format!("{:>6}x{:<6} {:>8.3}\n", p.n_r, p.n_c, p.pj_per_sop));
    }
    s.push_str(&format!(
        "row-wise stacking baseline: {:.3} pJ/SOP\n\
         saving vs baseline: {:.2}x – {:.2}x   (paper: up to 4.3x)\n\
         shape variation: {:.1} %            (paper: < 24 %)\n",
        a.rowwise_baseline_pj,
        a.min_saving(),
        a.max_saving(),
        100.0 * a.shape_variation(),
    ));
    s.push_str("\nFig. 7(c) — vs [4] ISSCC'24, 16 macros (paper: 87-90 % gain)\n");
    for (sp, g) in c {
        s.push_str(&format!("sparsity {:.2}: gain {:.1} %\n", sp, 100.0 * g));
    }
    s.push_str("\nFig. 7(d) — vs [3] IMPULSE, 18 macros (paper: 79-86 % gain)\n");
    for (sp, g) in d {
        s.push_str(&format!("sparsity {:.2}: gain {:.1} %\n", sp, 100.0 * g));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolution_sweep_is_linear() {
        let f = run_fig7a();
        // pJ/SOP/bit roughly constant (< 8 % spread).
        let per_bit: Vec<f64> = f
            .resolution_sweep
            .iter()
            .map(|p| p.pj_per_sop / p.bits as f64)
            .collect();
        let lo = per_bit.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = per_bit.iter().cloned().fold(0.0f64, f64::max);
        assert!(hi / lo < 1.10, "per-bit energy spread {:.3}", hi / lo);
    }

    #[test]
    fn shape_study_headlines() {
        let f = run_fig7a();
        assert!(f.shape_variation() < 0.30, "variation {:.3}", f.shape_variation());
        assert!(
            f.max_saving() > 3.4 && f.max_saving() < 7.0,
            "max saving {:.2}",
            f.max_saving()
        );
    }

    #[test]
    fn system_gains_in_band() {
        for (_, g) in run_fig7c() {
            assert!((0.80..0.95).contains(&g), "7c gain {g:.3}");
        }
        for (_, g) in run_fig7d() {
            assert!((0.70..0.92).contains(&g), "7d gain {g:.3}");
        }
    }

    #[test]
    fn render_has_all_sections() {
        let a = run_fig7a();
        let s = render(&a, &run_fig7c(), &run_fig7d());
        assert!(s.contains("Fig. 7(a)") && s.contains("Fig. 7(c)") && s.contains("Fig. 7(d)"));
    }
}
