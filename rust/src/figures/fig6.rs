//! Fig. 6 — resolution flexibility: accuracy vs memory footprint.
//!
//! (a) the FlexSpIM per-layer resolution choice vs the same model
//! constrained to [4]'s fixed menu (paper: 30 % smaller at iso-accuracy);
//! (b) accuracy sensitivity to uniform resolution scaling and its model-
//! size impact (paper: a further 36 % reduction at 90 % accuracy).
//!
//! Model sizes are exact (pure accounting). Accuracy points require the
//! PJRT runtime + trained weights: the driver takes a `&mut Coordinator`
//! and a labeled synthetic dataset; with random weights accuracy is
//! chance (~10 %) — train first (examples/train_snn or `flexspim train`).

use crate::coordinator::Coordinator;
use crate::events::EventStream;
use crate::snn::network::{scnn_constrained_isscc24, scnn_dvs_gesture};
use crate::Result;

/// One configuration point.
#[derive(Debug, Clone)]
pub struct Fig6Point {
    /// Configuration label.
    pub label: String,
    /// Per-layer (w_bits, p_bits).
    pub resolutions: Vec<(u32, u32)>,
    /// Total weight footprint (bits).
    pub model_bits: u64,
    /// Conv-only weight footprint (bits) — Fig. 6(b) excludes FC layers.
    pub conv_bits: u64,
    /// Measured accuracy (None when run size-only).
    pub accuracy: Option<f64>,
}

/// Size-only study for Fig. 6(a): flexible vs constrained footprints.
pub fn size_study() -> (Fig6Point, Fig6Point) {
    let flex = scnn_dvs_gesture();
    let fixed = scnn_constrained_isscc24();
    let point = |net: &crate::snn::Network, label: &str| Fig6Point {
        label: label.to_string(),
        resolutions: net.layers.iter().map(|l| (l.res.w_bits, l.res.p_bits)).collect(),
        model_bits: net.total_weight_bits(),
        conv_bits: net.conv_weight_bits(),
        accuracy: None,
    };
    (point(&flex, "FlexSpIM (unconstrained)"), point(&fixed, "[4]-constrained"))
}

/// Fig. 6(a) headline: relative footprint reduction (paper: 0.30).
pub fn footprint_reduction() -> f64 {
    let (flex, fixed) = size_study();
    1.0 - flex.model_bits as f64 / fixed.model_bits as f64
}

/// Fig. 6(b) sweep configurations for the reference SCNN (shorthand for
/// [`scaling_configs_for`] over [`scnn_dvs_gesture`]).
pub fn scaling_configs() -> Vec<(String, Vec<(u32, u32)>)> {
    scaling_configs_for(&scnn_dvs_gesture())
}

/// Sweep configurations for an arbitrary workload: uniform down-scaling
/// of its per-layer resolutions (bitwise granularity — only FlexSpIM can
/// run all of them). Lets `flexspim sweep --config` sweep any
/// TOML-defined topology, not just the paper SCNN.
pub fn scaling_configs_for(net: &crate::snn::Network) -> Vec<(String, Vec<(u32, u32)>)> {
    let base: Vec<(u32, u32)> = net
        .layers
        .iter()
        .map(|l| (l.res.w_bits, l.res.p_bits))
        .collect();
    let mut out = Vec::new();
    for delta in 0..=3i64 {
        let cfg: Vec<(u32, u32)> = base
            .iter()
            .map(|&(w, p)| {
                (
                    (w as i64 - delta).max(2) as u32,
                    (p as i64 - delta).max(4) as u32,
                )
            })
            .collect();
        out.push((format!("base-{delta}b"), cfg));
    }
    out
}

/// Measure accuracy at each configuration on a labeled dataset.
pub fn accuracy_sweep(
    coord: &mut Coordinator,
    data: &[(EventStream, usize)],
    configs: &[(String, Vec<(u32, u32)>)],
) -> Result<Vec<Fig6Point>> {
    let mut out = Vec::new();
    for (label, res) in configs {
        coord.set_resolutions(res);
        let metrics = coord.run_dataset(data)?;
        let net = coord.network().with_resolutions(
            &res.iter()
                .map(|&(w, p)| crate::snn::Resolution::new(w, p))
                .collect::<Vec<_>>(),
        );
        out.push(Fig6Point {
            label: label.clone(),
            resolutions: res.clone(),
            model_bits: net.total_weight_bits(),
            conv_bits: net.conv_weight_bits(),
            accuracy: Some(metrics.accuracy()),
        });
    }
    Ok(out)
}

/// Render the Fig. 6 report.
pub fn render_sizes() -> String {
    let (flex, fixed) = size_study();
    let mut s = String::from("Fig. 6(a) — resolution choice and model size\n");
    for p in [&flex, &fixed] {
        s.push_str(&format!(
            "{:<28} total {:>9} bits ({:>7.1} kB), conv-only {:>9} bits\n",
            p.label,
            p.model_bits,
            p.model_bits as f64 / 8192.0,
            p.conv_bits
        ));
        s.push_str("   per-layer (w/p): ");
        for (w, pb) in &p.resolutions {
            s.push_str(&format!("{w}/{pb} "));
        }
        s.push('\n');
    }
    s.push_str(&format!(
        "footprint reduction: {:.1} %   (paper: 30 %)\n",
        100.0 * footprint_reduction()
    ));
    s
}

/// Render accuracy sweep points.
pub fn render_sweep(points: &[Fig6Point]) -> String {
    let mut s = String::from(
        "Fig. 6(b) — accuracy vs resolution (synthetic gesture set)\n\
         config      conv bits    total bits   accuracy\n",
    );
    for p in points {
        s.push_str(&format!(
            "{:<10} {:>11} {:>13}   {}\n",
            p.label,
            p.conv_bits,
            p.model_bits,
            p.accuracy
                .map(|a| format!("{:.1} %", 100.0 * a))
                .unwrap_or_else(|| "n/a".into()),
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn footprint_reduction_in_paper_band() {
        let r = footprint_reduction();
        assert!((0.15..0.5).contains(&r), "reduction {r:.3}");
    }

    #[test]
    fn scaling_configs_shrink_monotonically() {
        let configs = scaling_configs();
        assert_eq!(configs.len(), 4);
        let sizes: Vec<u64> = configs
            .iter()
            .map(|(_, res)| {
                scnn_dvs_gesture()
                    .with_resolutions(
                        &res.iter()
                            .map(|&(w, p)| crate::snn::Resolution::new(w, p))
                            .collect::<Vec<_>>(),
                    )
                    .total_weight_bits()
            })
            .collect();
        for w in sizes.windows(2) {
            assert!(w[1] < w[0], "sizes must shrink: {sizes:?}");
        }
        // Fig. 6(b): the -2b config lands near the paper's "additional
        // 36 %" region relative to base.
        let extra = 1.0 - sizes[2] as f64 / sizes[0] as f64;
        assert!((0.25..0.50).contains(&extra), "extra reduction {extra:.3}");
    }

    #[test]
    fn render_sizes_has_headline() {
        let s = render_sizes();
        assert!(s.contains("footprint reduction"));
    }
}
