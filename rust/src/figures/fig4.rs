//! Fig. 4 — layer-wise memory requirements and the hybrid-stationary gain.
//!
//! (a) per-layer weight vs membrane-potential footprints of the six-conv
//! SCNN with the WS/OS crossover; (b) WS-only vs HS-min mapping on two
//! macros, reporting the increase in stationary operands (paper: +46 %).

use crate::dataflow::{Mapper, Policy, Stationarity};
use crate::snn::network::scnn_dvs_gesture;
use crate::snn::Network;

/// One layer row of Fig. 4(a).
#[derive(Debug, Clone)]
pub struct LayerRow {
    /// Layer name.
    pub name: String,
    /// Weight footprint (bits).
    pub weight_bits: u64,
    /// Membrane footprint (bits).
    pub vmem_bits: u64,
    /// HS-min choice for this layer.
    pub hs_min_choice: Stationarity,
    /// HS-max choice.
    pub hs_max_choice: Stationarity,
}

/// Full Fig. 4 result.
#[derive(Debug, Clone)]
pub struct Fig4 {
    /// Per-layer rows (a).
    pub rows: Vec<LayerRow>,
    /// Avoided traffic per timestep under WS-only, 2 macros (b).
    pub ws_only_avoided: u64,
    /// Avoided traffic per timestep under HS-min, 2 macros (b).
    pub hs_min_avoided: u64,
    /// Layers with full stationarity under each policy.
    pub ws_only_covered: usize,
    /// Layers with full stationarity under HS-min.
    pub hs_min_covered: usize,
}

impl Fig4 {
    /// The headline: relative increase in stationary operands (paper 0.46).
    pub fn hs_gain(&self) -> f64 {
        self.hs_min_avoided as f64 / self.ws_only_avoided as f64 - 1.0
    }
}

/// Compute Fig. 4 on the reference workload with two macros.
pub fn run() -> Fig4 {
    run_on(&scnn_dvs_gesture(), 2)
}

/// Compute Fig. 4 on any workload/macro count.
pub fn run_on(net: &Network, macros: usize) -> Fig4 {
    let rows = net
        .layers
        .iter()
        .map(|l| LayerRow {
            name: l.name.clone(),
            weight_bits: l.weight_bits(),
            vmem_bits: l.vmem_bits(),
            hs_min_choice: crate::dataflow::stationarity::min_footprint_choice(l),
            hs_max_choice: crate::dataflow::stationarity::max_footprint_choice(l),
        })
        .collect();
    let mapper = Mapper::flexspim(macros);
    let ws = mapper.map(net, Policy::WsOnly);
    let hs = mapper.map(net, Policy::HsMin);
    Fig4 {
        rows,
        ws_only_avoided: ws.avoided_traffic_bits(net),
        hs_min_avoided: hs.avoided_traffic_bits(net),
        ws_only_covered: ws.layers_with_stationarity(),
        hs_min_covered: hs.layers_with_stationarity(),
    }
}

/// Render the paper-style report.
pub fn render(f: &Fig4) -> String {
    let mut s = String::from(
        "Fig. 4(a) — per-layer operand footprints (bits)\n\
         layer      weights         vmem   HS-min  HS-max\n",
    );
    for r in &f.rows {
        s.push_str(&format!(
            "{:<6} {:>12} {:>12}   {:>5}  {:>5}\n",
            r.name,
            r.weight_bits,
            r.vmem_bits,
            match r.hs_min_choice {
                Stationarity::Ws => "WS",
                Stationarity::Os => "OS",
            },
            match r.hs_max_choice {
                Stationarity::Ws => "WS",
                Stationarity::Os => "OS",
            },
        ));
    }
    s.push_str(&format!(
        "\nFig. 4(b) — 2-macro mapping\n\
         WS-only: avoided {} bits/timestep, {} layers covered\n\
         HS-min : avoided {} bits/timestep, {} layers covered\n\
         stationary-operand gain: +{:.1} %  (paper: +46 %)\n",
        f.ws_only_avoided,
        f.ws_only_covered,
        f.hs_min_avoided,
        f.hs_min_covered,
        100.0 * f.hs_gain(),
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crossover_exists() {
        // Fig. 4a's defining feature: early layers OS-preferred, late
        // layers WS-preferred under HS-min.
        let f = run();
        assert_eq!(f.rows[0].hs_min_choice, Stationarity::Ws); // tiny kernel
        assert_eq!(f.rows[5].hs_min_choice, Stationarity::Os); // big kernel
    }

    #[test]
    fn gain_in_paper_band() {
        let f = run();
        let g = f.hs_gain();
        assert!((0.35..0.60).contains(&g), "gain {g:.3}");
    }

    #[test]
    fn hs_covers_all_layers_with_two_macros() {
        let f = run();
        assert_eq!(f.hs_min_covered, 9);
        assert!(f.ws_only_covered < 9);
    }

    #[test]
    fn render_contains_key_lines() {
        let s = render(&run());
        assert!(s.contains("Fig. 4(a)"));
        assert!(s.contains("stationary-operand gain"));
        assert!(s.contains("L6"));
    }
}
