//! Paper-figure reproduction drivers.
//!
//! One module per table/figure of the evaluation (DESIGN.md §5 maps each
//! to its bench target). Every driver returns structured data *and*
//! renders the paper-style rows, so the benches, the CLI (`flexspim
//! reproduce <id>`), and EXPERIMENTS.md all consume the same source.

pub mod fig4;
pub mod fig6;
pub mod fig7;
pub mod table1;
